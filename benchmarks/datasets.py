"""Surrogates for the paper's four SOSD datasets (offline container — see
DESIGN.md §5.5). Same qualitative CDF shapes, 64-bit key scale:

  amzn  — book popularity: Zipf-ish counts -> cumulative ids (heavy head)
  face  — user ids: near-uniform with random gaps
  osm   — cell ids: multi-modal clusters (spatial locality)
  wiki  — edit timestamps: bursty arrival (piecewise-intensity Poisson)

Plus the paper's skew family: uniform keys raised to powers alpha.
"""
from __future__ import annotations

import numpy as np

DEFAULT_N = 200_000     # paper: 200M; CPU-scaled (flag --n to raise)


def amzn(n=DEFAULT_N, seed=0):
    rng = np.random.default_rng(seed)
    # heavy-tailed but smooth popularity counts (id = cumulative popularity)
    pop = rng.lognormal(3.0, 1.5, n)
    keys = np.cumsum(pop) + rng.random(n)
    return np.sort(keys * 1e3)


def face(n=DEFAULT_N, seed=1):
    rng = np.random.default_rng(seed)
    gaps = rng.integers(1, 200, n).astype(np.float64)
    gaps[rng.random(n) < 0.001] += 1e7          # rare big holes
    return np.sort(np.cumsum(gaps))


def osm(n=DEFAULT_N, seed=2):
    rng = np.random.default_rng(seed)
    n_clusters = 64
    centers = np.sort(rng.random(n_clusters)) * 1.8e19
    widths = rng.lognormal(30, 2, n_clusters)
    counts = rng.multinomial(n, rng.dirichlet(np.ones(n_clusters) * 0.4))
    parts = [rng.normal(c, w, k) for c, w, k in zip(centers, widths, counts, strict=True)]
    return np.sort(np.abs(np.concatenate(parts)))


def wiki(n=DEFAULT_N, seed=3):
    rng = np.random.default_rng(seed)
    n_bursts = 500
    rates = rng.lognormal(0, 1.5, n_bursts)
    counts = np.maximum((rates / rates.sum() * n).astype(int), 1)
    t, parts = 0.0, []
    for c, r in zip(counts, rates, strict=True):
        parts.append(t + np.cumsum(rng.exponential(1.0 / r, c)))
        t = parts[-1][-1] + rng.exponential(50.0)
    keys = np.concatenate(parts)[:n]
    return np.sort(keys * 1e6)


def skew(alpha: int, n=DEFAULT_N, seed=4):
    rng = np.random.default_rng(seed)
    return np.sort((rng.random(n) ** alpha) * 1e12)


REAL = {"amzn": amzn, "face": face, "osm": osm, "wiki": wiki}
