"""Shared benchmark machinery: the paper's index roster, timed builds and
lookups, CSV rows for run.py, and the per-PR trajectory appender for the
committed BENCH_*.json baselines."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from dataclasses import dataclass
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp

import repro  # noqa: F401
from repro.core import btree, pgm, radix_spline, reuse, rmi, rmrt, synth

_POOLS: dict = {}


def git_sha() -> str:
    """Short HEAD sha of the repo the benchmarks live in ("unknown" outside
    a checkout) — the trajectory key, together with the suite name."""
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=Path(__file__).resolve().parent, text=True,
            stderr=subprocess.DEVNULL).strip()
    except Exception:
        return "unknown"


def append_bench(path, suite: str, rows: list, mode: str = "interpret/CPU",
                 note: str = "") -> dict:
    """Append a per-PR trajectory entry to a committed BENCH json.

    The file's top-level ``meta``/``rows`` (the original baseline) are left
    untouched; entries accumulate under ``trajectory`` keyed by
    (git sha, suite) — re-running the same suite at the same sha replaces
    its entry instead of duplicating it, so the trajectory stays one row
    per PR per suite.  Returns the written document."""
    p = Path(path)
    data = json.loads(p.read_text()) if p.exists() else \
        {"meta": {}, "rows": []}
    sha = git_sha()
    traj = data.setdefault("trajectory", [])
    traj[:] = [e for e in traj
               if (e.get("sha"), e.get("suite")) != (sha, suite)]
    entry = {"sha": sha, "suite": suite, "mode": mode,
             "date": time.strftime("%Y-%m-%d"), "rows": rows}
    if note:
        entry["note"] = note
    traj.append(entry)
    p.write_text(json.dumps(data, indent=1) + "\n")
    print(f"appended {len(rows)} rows to {p.name} "
          f"(suite={suite}, sha={sha})")
    return data


def worker_rows(module: str, flag: str, n_devices: int, argv: list,
                timeout: int = 3600) -> list:
    """Collect benchmark rows from a forced-host-device-count subprocess.

    XLA's host device count locks at first jax init, so any bench needing a
    >1-device CPU mesh re-execs itself: ``python -m <module> <flag>
    <n_devices> <argv...>`` with XLA_FLAGS forcing the count; the worker
    prints its rows as JSON on the last stdout line.  Shared by the
    distributed rows of bench_lookup and the sharded rows of
    bench_updates.  Returns [] (with the worker's stderr echoed) on any
    failure, so a broken mesh bench never sinks the host run."""
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count"
                         f"={n_devices}")
    try:
        proc = subprocess.run(
            [sys.executable, "-m", module, flag, str(n_devices),
             *map(str, argv)],
            env=env, capture_output=True, text=True, timeout=timeout)
    except subprocess.TimeoutExpired:
        print(f"{module} worker timed out after {timeout}s", file=sys.stderr)
        return []
    if proc.returncode != 0:
        print(f"{module} worker failed:\n{proc.stderr[-2000:]}",
              file=sys.stderr)
        return []
    try:
        return json.loads(proc.stdout.splitlines()[-1])
    except (json.JSONDecodeError, IndexError):
        print(f"{module} worker emitted no parseable rows:\n"
              f"{proc.stdout[-2000:]}", file=sys.stderr)
        return []


def worker_suite(module: str, flag: str, n_devices: int, n: int,
                 timeout: int = 3600) -> list:
    """The one-knob ``--n``-sized worker call shared by the bench suites
    (sharded/restack/recover rows of bench_updates, the serve suite): one
    place owns the forced-device-count re-exec convention instead of a
    per-suite wrapper each."""
    return worker_rows(module, flag, n_devices, ["--n", n], timeout=timeout)


def poisson_arrivals(rate_qps: float, duration_s: float,
                     seed: int = 0) -> np.ndarray:
    """Open-loop Poisson arrival times in [0, duration_s): exponential
    inter-arrival gaps at ``rate_qps``, cumulatively summed.  Open-loop
    means the offered load never backs off when the server lags — queueing
    delay shows up in the measured latency instead of silently throttling
    the generator — which is what an SLO benchmark must measure."""
    rng = np.random.default_rng(seed)
    out = []
    t = 0.0
    chunk = max(int(rate_qps * duration_s * 1.25) + 16, 16)
    while t < duration_s:
        gaps = rng.exponential(1.0 / rate_qps, size=chunk)
        ts = t + np.cumsum(gaps)
        out.append(ts)
        t = float(ts[-1])
    ts = np.concatenate(out)
    return ts[ts < duration_s]


def pools(eps: float = 0.9):
    """Cached (linear, mlp) pools; pre-train time reported separately."""
    if eps not in _POOLS:
        sp = synth.generate_pool(eps)
        t0 = time.time()
        lin = reuse.build_pool(sp, kind="linear")
        jax.block_until_ready(lin.err_hi)
        t_lin = time.time() - t0
        t0 = time.time()
        mlp = reuse.build_pool(sp, kind="mlp", train_steps=400)
        jax.block_until_ready(mlp.err_hi)
        t_mlp = time.time() - t0
        _POOLS[eps] = (lin, mlp, t_lin, t_mlp, sp.size)
    return _POOLS[eps]


@dataclass
class IndexSpec:
    name: str
    build: callable
    lookup: callable


def roster(eps: float = 0.9, n_leaves: int = 1024, warm: bool = True):
    """The paper's §5 roster: BTree, RMI, RMI-NN, RMI-MR, RMI-NN-MR, PGM,
    RS, RMRT."""
    lin_pool, mlp_pool, *_ = pools(eps)
    return [
        IndexSpec("BTree", lambda k: btree.build_btree(k, fanout=16),
                  btree.lookup),
        IndexSpec("RMI", lambda k: rmi.build_rmi(k, n_leaves, kind="linear"),
                  rmi.lookup),
        IndexSpec("RMI-MR", lambda k: rmi.build_rmi(k, n_leaves,
                                                    kind="linear",
                                                    pool=lin_pool),
                  rmi.lookup),
        IndexSpec("RMI-NN", lambda k: rmi.build_rmi(k, n_leaves, kind="mlp",
                                                    train_steps=150),
                  rmi.lookup),
        IndexSpec("RMI-NN-MR", lambda k: rmi.build_rmi(k, n_leaves,
                                                       kind="mlp",
                                                       pool=mlp_pool,
                                                       train_steps=150),
                  rmi.lookup),
        IndexSpec("PGM", lambda k: pgm.build_pgm(k, eps=64), pgm.lookup),
        IndexSpec("RS", lambda k: radix_spline.build_rs(k, eps=32),
                  radix_spline.lookup),
        IndexSpec("RMRT", lambda k: rmrt.build_rmrt(k, leaf_cap=4096,
                                                    fanout=64, kind="linear",
                                                    pool=lin_pool),
                  rmrt.lookup),
    ]


def timed_build(spec: IndexSpec, keys, repeats: int = 2):
    """Median warm build time (first build pays jit compile; excluded)."""
    times = []
    idx = None
    for r in range(repeats + 1):
        t0 = time.time()
        idx = spec.build(keys)
        _block(idx)
        if r:
            times.append(time.time() - t0)
    return idx, float(np.median(times))


def timed_lookup(spec: IndexSpec, idx, queries, repeats: int = 3):
    res = spec.lookup(idx, queries)
    jax.block_until_ready(res)
    times = []
    for _ in range(repeats):
        t0 = time.time()
        jax.block_until_ready(spec.lookup(idx, queries))
        times.append(time.time() - t0)
    ns_per_q = float(np.median(times)) / queries.shape[0] * 1e9
    return res, ns_per_q


def _block(idx):
    for leaf in jax.tree.leaves(idx.__dict__ if hasattr(idx, "__dict__")
                                else idx):
        if isinstance(leaf, jax.Array):
            leaf.block_until_ready()


def verify(keys, queries, result) -> bool:
    truth = jnp.searchsorted(jnp.asarray(keys), queries, side="left")
    return bool(jnp.all(jnp.asarray(result) == truth))
