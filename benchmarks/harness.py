"""Shared benchmark machinery: the paper's index roster, timed builds and
lookups, CSV rows for run.py."""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp

import repro  # noqa: F401
from repro.core import btree, pgm, radix_spline, reuse, rmi, rmrt, synth

_POOLS: dict = {}


def pools(eps: float = 0.9):
    """Cached (linear, mlp) pools; pre-train time reported separately."""
    if eps not in _POOLS:
        sp = synth.generate_pool(eps)
        t0 = time.time()
        lin = reuse.build_pool(sp, kind="linear")
        jax.block_until_ready(lin.err_hi)
        t_lin = time.time() - t0
        t0 = time.time()
        mlp = reuse.build_pool(sp, kind="mlp", train_steps=400)
        jax.block_until_ready(mlp.err_hi)
        t_mlp = time.time() - t0
        _POOLS[eps] = (lin, mlp, t_lin, t_mlp, sp.size)
    return _POOLS[eps]


@dataclass
class IndexSpec:
    name: str
    build: callable
    lookup: callable


def roster(eps: float = 0.9, n_leaves: int = 1024, warm: bool = True):
    """The paper's §5 roster: BTree, RMI, RMI-NN, RMI-MR, RMI-NN-MR, PGM,
    RS, RMRT."""
    lin_pool, mlp_pool, *_ = pools(eps)
    return [
        IndexSpec("BTree", lambda k: btree.build_btree(k, fanout=16),
                  btree.lookup),
        IndexSpec("RMI", lambda k: rmi.build_rmi(k, n_leaves, kind="linear"),
                  rmi.lookup),
        IndexSpec("RMI-MR", lambda k: rmi.build_rmi(k, n_leaves,
                                                    kind="linear",
                                                    pool=lin_pool),
                  rmi.lookup),
        IndexSpec("RMI-NN", lambda k: rmi.build_rmi(k, n_leaves, kind="mlp",
                                                    train_steps=150),
                  rmi.lookup),
        IndexSpec("RMI-NN-MR", lambda k: rmi.build_rmi(k, n_leaves,
                                                       kind="mlp",
                                                       pool=mlp_pool,
                                                       train_steps=150),
                  rmi.lookup),
        IndexSpec("PGM", lambda k: pgm.build_pgm(k, eps=64), pgm.lookup),
        IndexSpec("RS", lambda k: radix_spline.build_rs(k, eps=32),
                  radix_spline.lookup),
        IndexSpec("RMRT", lambda k: rmrt.build_rmrt(k, leaf_cap=4096,
                                                    fanout=64, kind="linear",
                                                    pool=lin_pool),
                  rmrt.lookup),
    ]


def timed_build(spec: IndexSpec, keys, repeats: int = 2):
    """Median warm build time (first build pays jit compile; excluded)."""
    times = []
    idx = None
    for r in range(repeats + 1):
        t0 = time.time()
        idx = spec.build(keys)
        _block(idx)
        if r:
            times.append(time.time() - t0)
    return idx, float(np.median(times))


def timed_lookup(spec: IndexSpec, idx, queries, repeats: int = 3):
    res = spec.lookup(idx, queries)
    jax.block_until_ready(res)
    times = []
    for _ in range(repeats):
        t0 = time.time()
        jax.block_until_ready(spec.lookup(idx, queries))
        times.append(time.time() - t0)
    ns_per_q = float(np.median(times)) / queries.shape[0] * 1e9
    return res, ns_per_q


def _block(idx):
    for leaf in jax.tree.leaves(idx.__dict__ if hasattr(idx, "__dict__")
                                else idx):
        if isinstance(leaf, jax.Array):
            leaf.block_until_ready()


def verify(keys, queries, result) -> bool:
    truth = jnp.searchsorted(jnp.asarray(keys), queries, side="left")
    return bool(jnp.all(jnp.asarray(result) == truth))
