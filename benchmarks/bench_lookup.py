"""Lookup-path microbenchmark — the serving perf trajectory.

Times ns/query for the paper's §5 roster across key counts and lookup
paths, and writes ``BENCH_lookup.json`` (committed) so subsequent PRs can
track the hot path:

  jnp-full-depth      the pre-PR serving path: XLA bounded search at
                      ceil(log2 n) + 1 iterations (``clamp_iters=False``)
  jnp-window-clamped  same path with the §4 error-window-clamped static
                      depth (RMIIndex.search_iters) — the "after" row
  pallas-interpret    the fused Pallas kernel (in-kernel leaf routing +
                      tiled keys) under the interpreter; correctness-grade
                      timing only — on CPU containers this measures the
                      interpreter, not the kernel, but pins the trajectory
                      for TPU runs
  native              variants without a depth toggle (BTree; PGM/RS are
                      always eps-clamped now)

  PYTHONPATH=src python -m benchmarks.bench_lookup [--sizes 65536 262144]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

import repro  # noqa: F401
from repro.core import btree, pgm, radix_spline, rmi, rmrt

from . import harness

Q = 16_384
REPEATS = 3


def _time(fn, queries) -> float:
    import jax
    jax.block_until_ready(fn(queries))          # compile / warm
    times = []
    for _ in range(REPEATS):
        t0 = time.time()
        jax.block_until_ready(fn(queries))
        times.append(time.time() - t0)
    return float(np.median(times)) / queries.shape[0] * 1e9


def bench(sizes: list[int], eps: float = 0.9) -> list[dict]:
    import jax.numpy as jnp
    from repro.kernels.lookup import full_iters

    lin_pool, mlp_pool, *_ = harness.pools(eps)
    rows: list[dict] = []
    rng = np.random.default_rng(7)
    for n in sizes:
        keys = np.sort(rng.lognormal(0, 0.7, n) * 1e6)
        # tracelint: ok[f32-cast](f32-exact key synthesis: the roundtrip dedup is the point)
        keys = np.unique(keys.astype(np.float32)).astype(np.float64)
        kj = jnp.asarray(keys)
        q = jnp.asarray(rng.choice(keys, Q))

        builds = {
            "BTree": lambda kj=kj: btree.build_btree(kj, fanout=16),
            "RMI": lambda kj=kj: rmi.build_rmi(kj, 1024, kind="linear"),
            "RMI-MR": lambda kj=kj: rmi.build_rmi(kj, 1024, kind="linear",
                                            pool=lin_pool),
            "RMI-NN": lambda kj=kj: rmi.build_rmi(kj, 1024, kind="mlp",
                                            train_steps=150),
            "RMI-NN-MR": lambda kj=kj: rmi.build_rmi(kj, 1024, kind="mlp",
                                               pool=mlp_pool,
                                               train_steps=150),
            "PGM": lambda kj=kj: pgm.build_pgm(kj, eps=64),
            "RS": lambda kj=kj: radix_spline.build_rs(kj, eps=32),
            "RMRT": lambda kj=kj: rmrt.build_rmrt(kj, leaf_cap=4096, fanout=64,
                                            kind="linear", pool=lin_pool),
        }
        for name, build in builds.items():
            idx = build()
            paths: dict[str, tuple] = {}
            if name.startswith("RMI"):
                paths = {
                    "jnp-full-depth": (
                        lambda qq, i=idx: rmi.lookup(i, qq,
                                                     clamp_iters=False),
                        full_iters(idx.n)),
                    "jnp-window-clamped": (
                        lambda qq, i=idx: rmi.lookup(i, qq),
                        idx.search_iters),
                    "pallas-interpret": (
                        lambda qq, i=idx: rmi.lookup(i, qq, use_kernel=True),
                        idx.search_iters),
                }
            elif name == "RMRT":
                paths = {
                    "jnp-full-depth": (
                        lambda qq, i=idx: rmrt.lookup(i, qq,
                                                      clamp_iters=False),
                        full_iters(idx.n)),
                    "jnp-window-clamped": (
                        lambda qq, i=idx: rmrt.lookup(i, qq),
                        idx.search_iters),
                    "pallas-interpret": (
                        lambda qq, i=idx: rmrt.lookup(i, qq,
                                                      use_kernel=True),
                        idx.search_iters),
                }
            else:
                look = {"BTree": btree.lookup, "PGM": pgm.lookup,
                        "RS": radix_spline.lookup}[name]
                paths = {"native": (lambda qq, i=idx, lk=look: lk(i, qq),
                                    None)}
            for path, (fn, iters) in paths.items():
                ns = _time(fn, q)
                assert harness.verify(kj, q, fn(q)), (name, path)
                rows.append({"variant": name, "n_keys": int(kj.shape[0]),
                             "path": path, "ns_per_query": round(ns, 1),
                             "iters": iters})
                print(f"{name:10s} n={int(kj.shape[0]):>8d} {path:20s} "
                      f"{ns:10.0f} ns/q  iters={iters}")
    return rows


def _time_range(fn, q_lo, q_hi) -> float:
    import jax
    jax.block_until_ready(fn(q_lo, q_hi))       # compile / warm
    times = []
    for _ in range(REPEATS):
        t0 = time.time()
        jax.block_until_ready(fn(q_lo, q_hi))
        times.append(time.time() - t0)
    return float(np.median(times)) / q_lo.shape[0] * 1e9


def bench_range(sizes: list[int], eps: float = 0.9) -> list[dict]:
    """YCSB-style point/range/mixed mixes over the dynamic two-tier index.

    Per size: a churned DynamicRMI (batched inserts + tombstones so the
    delta tier and live-rank prefix sums are exercised) timed under three
    mixes —

      point   100% point lookups (YCSB-C)
      range   100% range lookups (YCSB-E's scan op)
      mixed   95% range / 5% point (YCSB-E's default mix)

    each on both lookup paths (jnp / pallas-interpret).  ns_per_query is
    per *operation* (a range op routes two endpoints but counts once).
    """
    import jax.numpy as jnp
    from repro.core.updates import DynamicRMI

    rows: list[dict] = []
    rng = np.random.default_rng(11)
    for n in sizes:
        keys = np.sort(rng.lognormal(0, 0.7, n) * 1e6)
        # tracelint: ok[f32-cast](f32-exact key synthesis: the roundtrip dedup is the point)
        keys = np.unique(keys.astype(np.float32)).astype(np.float64)
        dyn = DynamicRMI.build(jnp.asarray(keys), n_leaves=1024,
                               kind="linear")
        extra = np.unique((rng.lognormal(0, 0.7, n // 8) * 1e6)
                          .astype(np.float32)).astype(np.float64)
        extra = np.setdiff1d(extra, keys)
        dyn.insert_batch(jnp.asarray(extra))
        dyn.delete_batch(jnp.asarray(rng.choice(keys, n // 16,
                                                replace=False)))
        live = dyn.live_keys()
        qp = jnp.asarray(rng.choice(live, Q))
        q_lo = np.asarray(rng.choice(live, Q))
        # tracelint: ok[f32-cast](f32-exact range-hi synthesis, same roundtrip)
        q_hi = (q_lo * (1.0 + rng.uniform(0.0, 0.01, Q))).astype(
            np.float32).astype(np.float64)
        q_lo, q_hi = jnp.asarray(q_lo), jnp.asarray(q_hi)
        # verify once per size against the flat live-array oracle
        lf = np.asarray(live)
        el = np.searchsorted(lf, np.asarray(q_lo), side="left")
        eh = np.maximum(np.searchsorted(lf, np.asarray(q_hi), side="right"),
                        el)
        for use_kernel, path in ((False, "jnp-window-clamped"),
                                 (True, "pallas-interpret")):
            rl, rh = dyn.find_range(q_lo, q_hi, use_kernel=use_kernel)
            assert (np.array_equal(np.asarray(rl), el)
                    and np.array_equal(np.asarray(rh), eh)), path
            t_point = _time(
                lambda qq, uk=use_kernel, d=dyn: d.find(qq, use_kernel=uk)[1],
                qp)
            t_range = _time_range(
                lambda a, b, uk=use_kernel, d=dyn: d.find_range(
                    a, b, use_kernel=uk), q_lo, q_hi)
            for mix, ns in (("point", t_point), ("range", t_range),
                            ("mixed", 0.95 * t_range + 0.05 * t_point)):
                rows.append({"variant": "DynamicRMI", "mix": mix,
                             "n_keys": int(live.shape[0]), "path": path,
                             "ns_per_query": round(ns, 1)})
                print(f"DynamicRMI n={int(live.shape[0]):>8d} "
                      f"{mix:6s} {path:20s} {ns:10.0f} ns/op")
    return rows


def bench_distributed(n: int, n_shards: int) -> list[dict]:
    """Sharded-service rows on an ``n_shards``-device CPU mesh (kernel vs
    jnp per-shard path).  Must run in a process whose XLA host-device count
    is already >= n_shards (see --distributed-worker below)."""
    import jax
    import jax.numpy as jnp
    from repro.core import distributed

    rng = np.random.default_rng(7)
    keys = np.sort(rng.lognormal(0, 0.7, n) * 1e6)
    # tracelint: ok[f32-cast](f32-exact key synthesis: the roundtrip dedup is the point)
    keys = np.unique(keys.astype(np.float32)).astype(np.float64)
    q = jnp.asarray(rng.choice(keys, Q))
    mesh = jax.make_mesh((n_shards,), ("data",))
    idx = distributed.build_sharded(jnp.asarray(keys), mesh, axis="data",
                                    n_leaves=256)
    rows = []
    for path, use_kernel in (("shard-jnp-clamped", False),
                             ("shard-pallas-interpret", True)):
        fn = distributed.make_lookup_fn(idx, use_kernel=use_kernel)
        ns = _time(fn, q)
        rows.append({"variant": f"Distributed-{n_shards}shard",
                     "n_keys": int(keys.shape[0]), "path": path,
                     "ns_per_query": round(ns, 1),
                     "iters": idx.search_iters})
        print(f"Distributed-{n_shards}shard n={keys.shape[0]:>8d} "
              f"{path:20s} {ns:10.0f} ns/q  iters={idx.search_iters}")
    return rows


def _distributed_rows(n_shards: int, n: int) -> list[dict]:
    """Collect the distributed rows from a forced-device-count subprocess
    (harness.worker_rows — the host-device count locks at first jax
    init)."""
    return harness.worker_rows("benchmarks.bench_lookup",
                               "--distributed-worker", n_shards,
                               ["--sizes", n], timeout=1800)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=int, nargs="+",
                    default=[1 << 16, 1 << 18])
    ap.add_argument("--shards", type=int, default=4,
                    help="mesh width for the distributed rows (0 disables)")
    ap.add_argument("--distributed-worker", type=int, default=None,
                    help=argparse.SUPPRESS)   # internal: emit rows as JSON
    ap.add_argument("--out", default=str(Path(__file__).resolve().parent.parent
                                         / "BENCH_lookup.json"))
    args = ap.parse_args()
    if args.distributed_worker:
        rows = bench_distributed(max(args.sizes), args.distributed_worker)
        print(json.dumps(rows))
        return
    rows = bench(args.sizes)
    if args.shards:
        rows += _distributed_rows(args.shards, max(args.sizes))
    # Per-PR trajectory: append keyed by (git sha, suite) — the committed
    # baseline meta/rows from the seeding run stay untouched so every PR's
    # numbers remain comparable against them.
    harness.append_bench(
        args.out, "lookup", rows,
        note="pallas-interpret rows time the Pallas interpreter "
             "(correctness-grade); jnp rows are the XLA serving path. "
             "Distributed rows run the sharded service on a "
             "forced-host-device CPU mesh.")
    harness.append_bench(
        args.out, "lookup-range", bench_range(args.sizes),
        note="YCSB-style point/range/mixed mixes over the churned dynamic "
             "two-tier index; ns_per_query is per operation (a range op "
             "routes both endpoints in one fused pass but counts once).")


if __name__ == "__main__":
    main()
