"""Update-path microbenchmark — the dynamic-index perf trajectory.

Times ns/op for the §4 update subsystem and writes ``BENCH_updates.json``
(committed) so subsequent PRs can track the update hot path the way
``BENCH_lookup.json`` tracks lookups:

  insert        the paper's fig7 bulk-insertion workload (insert ratio 0.5,
                one batch, warm jit caches)
                  host-loop-seed   the seed implementation: per-leaf host
                                   Python buffers (np.sort/np.concatenate
                                   per touched leaf, one O(n) rebuild scan
                                   per over-budget leaf)
                  two-tier         the device-resident delta tier: one
                                   vectorized route-sort-merge per batch,
                                   one batched merge + refit per rebuild
  find-churn    point queries after >=10% inserts + tombstoned deletes
                  host-loop-seed   per-query Python scan over leaf buffers
                  two-tier-jnp     the fused jnp oracle path (XLA)
                  two-tier-pallas  the fused Pallas kernel (interpret mode
                                   on CPU: correctness-grade timing only)
  rebuild       an insert storm sized to exhaust Lemma 4.1 budgets —
                ns per *merged key* including the pool-reuse refits
  sharded       ShardedDynamicIndex insert/delete/find churn on a forced
                n-host-device CPU mesh vs single-device DynamicRMI at equal
                total keys (per-shard cost trajectory; runs in a worker
                subprocess because the device count locks at first jax init)
  restack       hot-shard maintenance sweep over 2/4/8-shard submeshes of
                one forced 8-device mesh: restack-churn rows (one shard
                takes every insert, rebalancing off) must stay ~flat in
                shard count — the per-shard slice cache makes per-batch
                restack work O(touched shards); migrate-skew rows count
                incremental (delta-riding) vs full-rebuild migrations
  recover       durability sweep (core.persist) on a forced 4-device mesh:
                snapshot cost vs index size, same-width restore latency,
                and restore-resharded 4->2 latency (the elastic-restart
                path) — full_rebuilds in the detail must stay 0
  drift         drifting-ingest trajectory through the ``repro.api.Index``
                facade: per-key insert+maintenance latency over a
                stationary -> shifted-lognormal -> zipf ingest, swap mode
                (online KS monitor + bound-checked pool hot-swaps, see
                core.drift) vs refit-only — swap-mode p99 must stay ~flat
                while refit-only spikes on the merge storms the shifted
                phases trigger

Rows *append* to ``BENCH_updates.json`` under ``trajectory``, keyed by
(git sha, suite) — the committed baseline rows stay untouched.

  PYTHONPATH=src python -m benchmarks.bench_updates [--n 65536] [--shards 4]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

import repro  # noqa: F401

Q = 8_192
REPEATS = 3


def _median(fn) -> float:
    times = []
    for _ in range(REPEATS):
        t0 = time.time()
        fn()
        times.append(time.time() - t0)
    return float(np.median(times))


def _keys(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    k = np.sort(rng.lognormal(0, 0.7, n) * 1e6)
    # tracelint: ok[f32-cast](f32-exact key synthesis: the roundtrip dedup is the point)
    return np.unique(k.astype(np.float32)).astype(np.float64)  # f32-exact


def bench(n: int = 1 << 17, eps: float = 0.9, n_leaves: int = 8192,
          with_pool: bool = True) -> list[dict]:
    import jax
    import jax.numpy as jnp
    from repro.core import reuse, synth
    from repro.core.updates import DynamicRMI, HostBufferDynamicRMI

    base = _keys(n)
    extra = _keys(2 * n, seed=9)
    ins = np.setdiff1d(extra, base)
    rng = np.random.default_rng(4)
    pool = reuse.build_pool(synth.generate_pool(eps, limit=300),
                            kind="linear") if with_pool else None
    rows: list[dict] = []

    def _row(op, impl, ns, detail):
        rows.append({"op": op, "impl": impl, "n_keys": int(base.size),
                     "ns_per_op": round(ns, 1), "detail": detail})
        print(f"{op:12s} {impl:16s} {ns:12.0f} ns/op  {detail}")

    # ---- batched insert: the paper's fig7 bulk-insertion workload (ratio
    # 0.5 of the base, one insert_batch call).  The seed rebuilds each
    # over-budget leaf with its own O(n) scan; the two-tier path batches the
    # merge + pool-policy refits.  Fresh structure per repeat (builds
    # untimed); one throwaway warm pass primes the jit caches. -------------
    bulk = ins[:n // 2]

    def _time_inserts(cls):
        times, rebuilds = [], 0
        w = cls.build(jnp.asarray(base), pool=pool, eps=eps,
                      n_leaves=n_leaves, kind="linear")
        w.insert_batch(bulk)                # warm (jit trace + capacity)
        for _ in range(REPEATS):
            d = cls.build(jnp.asarray(base), pool=pool, eps=eps,
                          n_leaves=n_leaves, kind="linear")
            t0 = time.time()
            d.insert_batch(bulk)
            times.append(time.time() - t0)
            rebuilds = d.rebuilds
        return float(np.median(times)) / bulk.size * 1e9, rebuilds

    ns_legacy, rb = _time_inserts(HostBufferDynamicRMI)
    _row("insert", "host-loop-seed", ns_legacy,
         f"bulk={bulk.size} leaves={n_leaves} rebuilds={rb}")
    ns_two, rb = _time_inserts(DynamicRMI)
    _row("insert", "two-tier", ns_two,
         f"bulk={bulk.size} leaves={n_leaves} rebuilds={rb} "
         f"speedup={ns_legacy / max(ns_two, 1e-9):.1f}x")

    # ---- find under churn (>=10% inserted, some tombstoned) --------------
    churn = ins[:max(n // 8, 1024)]         # ~12.5% of base
    dels = rng.choice(churn, churn.size // 10, replace=False)

    legacy = HostBufferDynamicRMI.build(jnp.asarray(base), pool=pool,
                                        eps=eps, n_leaves=n_leaves,
                                        kind="linear")
    legacy.insert_batch(churn)
    for k in dels[:64]:                     # seed delete is per-key only
        legacy.delete(k)
    dyn = DynamicRMI.build(jnp.asarray(base), pool=pool, eps=eps,
                           n_leaves=n_leaves, kind="linear")
    dyn.insert_batch(churn)
    dyn.delete_batch(dels)

    q = jnp.asarray(np.concatenate(
        [rng.choice(base, Q // 2), rng.choice(churn, Q - Q // 2)]))
    jax.block_until_ready(legacy.find(q))
    dt = _median(lambda: jax.block_until_ready(legacy.find(q)))
    _row("find-churn", "host-loop-seed", dt / Q * 1e9,
         f"Q={Q} churn={churn.size} tombstones=64")

    jax.block_until_ready(dyn.find(q, use_kernel=False))
    dt = _median(lambda: jax.block_until_ready(dyn.find(q,
                                                        use_kernel=False)))
    _row("find-churn", "two-tier-jnp", dt / Q * 1e9,
         f"Q={Q} churn={churn.size} tombstones={dels.size} "
         f"iters={dyn.index.search_iters}")

    jax.block_until_ready(dyn.find(q, use_kernel=True))
    dt = _median(lambda: jax.block_until_ready(dyn.find(q, use_kernel=True)))
    _row("find-churn", "two-tier-pallas", dt / Q * 1e9,
         f"Q={Q} interpret-mode (correctness-grade)")

    # ---- rebuild (budget-exhausting storm; merges + forced Algorithm-1
    # pool-reuse refits, reuse_on_rebuild=True) ----------------------------
    storm = ins[:max(n // 4, 2048)]
    for _warm in (True, False):         # first pass primes the jit caches
        dyn = DynamicRMI.build(jnp.asarray(base), pool=pool, eps=eps,
                               n_leaves=n_leaves, kind="linear",
                               reuse_on_rebuild=True if with_pool else None)
        t0 = time.time()
        dyn.insert_batch(storm)
        dt = time.time() - t0
    _row("rebuild", "two-tier", dt / storm.size * 1e9,
         f"storm={storm.size} rebuilds={dyn.rebuilds} "
         f"reuse={float(np.mean(np.asarray(dyn.index.reused_mask))):.2f} "
         f"live_keys={dyn.base_n + dyn.delta_live}")
    return rows


def bench_sharded(n: int = 1 << 16, n_shards: int = 4,
                  eps: float = 0.7) -> list[dict]:
    """Sharded insert/delete/find churn vs a single-device ``DynamicRMI``
    at equal total keys — the per-shard cost trajectory.  Must run in a
    process whose XLA host-device count is already >= n_shards (see
    --sharded-worker below); the single-device rows are measured in the
    same process so the comparison shares one XLA config."""
    import jax
    import jax.numpy as jnp
    from repro.core import distributed
    from repro.core.updates import DynamicRMI

    base = _keys(n)
    extra = _keys(2 * n, seed=9)
    ins = np.setdiff1d(extra, base)
    rng = np.random.default_rng(4)
    n_leaves = max(n // 64, 16)
    mesh = jax.make_mesh((n_shards,), ("data",))
    rows: list[dict] = []

    def _row(op, impl, ns, detail):
        rows.append({"op": op, "impl": impl, "n_keys": int(base.size),
                     "ns_per_op": round(ns, 1), "detail": detail})
        print(f"{op:12s} {impl:24s} {ns:12.0f} ns/op  {detail}")

    bulk = ins[:n // 2]
    churn = ins[n // 2:n // 2 + max(n // 8, 1024)]
    dels = rng.choice(churn, churn.size // 10, replace=False)
    q = jnp.asarray(np.concatenate(
        [rng.choice(base, Q // 2), rng.choice(churn, Q - Q // 2)]))

    # ---- single-device reference at equal total keys ---------------------
    def _build_single():
        return DynamicRMI.build(jnp.asarray(base), eps=eps,
                                n_leaves=n_leaves, kind="linear")

    def _time_mutation(build_fn, op):
        """Median over REPEATS of op(fresh structure) — builds untimed, one
        throwaway warm pass primes the jit caches (the protocol of
        :func:`bench`'s _time_inserts)."""
        op(build_fn())                      # warm
        times, last = [], None
        for _ in range(REPEATS):
            d = build_fn()
            t0 = time.time()
            op(d)
            times.append(time.time() - t0)
            last = d
        return float(np.median(times)), last

    dt, _ = _time_mutation(_build_single, lambda d: d.insert_batch(bulk))
    _row("insert", "single-device", dt / bulk.size * 1e9,
         f"bulk={bulk.size} leaves={n_leaves}")
    d = _build_single()
    d.insert_batch(churn)
    d.delete_batch(dels)
    jax.block_until_ready(d.find(q, use_kernel=False))
    dt = _median(lambda: jax.block_until_ready(d.find(q, use_kernel=False)))
    _row("find-churn", "single-device-jnp", dt / Q * 1e9,
         f"Q={Q} churn={churn.size} tombstones={dels.size}")

    # ---- sharded ---------------------------------------------------------
    def _build_sharded():
        return distributed.ShardedDynamicIndex.build(
            jnp.asarray(base), mesh, n_leaves=n_leaves, eps=eps)

    dt, s2 = _time_mutation(_build_sharded, lambda s: s.insert_batch(bulk))
    per_shard = bulk.size / n_shards
    _row("insert", f"sharded-{n_shards}", dt / bulk.size * 1e9,
         f"bulk={bulk.size} per_shard={per_shard:.0f} "
         f"rebalances={s2.rebalances}")

    def _churned():
        s = _build_sharded()
        s.insert_batch(churn)
        return s

    dt, s = _time_mutation(_churned, lambda s: s.delete_batch(dels))
    _row("delete", f"sharded-{n_shards}", dt / max(dels.size, 1) * 1e9,
         f"dels={dels.size} churn={churn.size}")
    for impl, uk in ((f"sharded-{n_shards}-jnp", False),
                     (f"sharded-{n_shards}-pallas", True)):
        jax.block_until_ready(s.find(q, use_kernel=uk))
        dt = _median(
            lambda uk=uk: jax.block_until_ready(s.find(q, use_kernel=uk)))
        _row("find-churn", impl, dt / Q * 1e9,
             f"Q={Q} churn={churn.size} tombstones={dels.size} "
             f"live={s.total_live}"
             + (" interpret-mode (correctness-grade)" if uk else ""))
    return rows


def bench_restack(n: int = 1 << 16, shard_counts=(2, 4, 8),
                  eps: float = 0.7) -> list[dict]:
    """Hot-shard maintenance cost vs total shard count.

    ``restack-churn``: every insert batch lands in ONE shard (rebalancing
    off); per-round cost = the routed merge into that shard + the slice-
    cache refresh the next ``find`` pays.  With the per-shard slice cache
    the per-round maintenance work is O(touched shards) = O(1), so ns/key
    must stay ~flat as the shard count grows — the pre-PR5 ``_stacked()``
    re-padded and re-stacked every shard per mutation, scaling O(all).

    ``migrate-skew``: the same ingest with rebalancing ON; the detail
    reports incremental (delta-riding) vs full-rebuild migrations — the
    common budget-respecting case must ride the receiver's delta tier, not
    rebuild both shards from scratch.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core import distributed

    base = _keys(n)
    rng = np.random.default_rng(4)
    n_leaves = max(n // 256, 16)
    rows: list[dict] = []

    def _row(op, impl, ns, detail):
        rows.append({"op": op, "impl": impl, "n_keys": int(base.size),
                     "ns_per_op": round(ns, 1), "detail": detail})
        print(f"{op:14s} {impl:14s} {ns:12.0f} ns/op  {detail}")

    prime, batch, rounds = 8192, 512, 8
    for S in shard_counts:
        if S > len(jax.devices()):
            continue
        mesh = Mesh(np.asarray(jax.devices()[:S]), ("data",))

        def _build(*, mesh=mesh, **kw):
            return distributed.ShardedDynamicIndex.build(
                jnp.asarray(base), mesh, n_leaves=n_leaves, eps=eps, **kw)

        # fresh f32-exact keys inside shard 0's range (hot for every batch)
        idx = _build(rebalance_ratio=None)
        splits0 = float(idx.splits[0])
        lo = base[0] / 2
        hot = np.setdiff1d(
            np.unique(rng.uniform(lo, splits0, prime + (rounds + 2) * batch
                                  + 20_000).astype(np.float32))
            .astype(np.float64), base)
        q = jnp.asarray(rng.choice(base, 2048))

        idx.insert_batch(hot[:prime])       # capacity ramp + jit warm
        jax.block_until_ready(idx.find(q, use_kernel=False))
        times = []
        r0_rows, r0_full = idx.restack_rows, idx.restack_full
        for r in range(rounds):
            chunk = hot[prime + r * batch: prime + (r + 1) * batch]
            t0 = time.time()
            idx.insert_batch(chunk)
            jax.block_until_ready(idx.find(q, use_kernel=False))
            times.append(time.time() - t0)
        _row("restack-churn", f"sharded-{S}",
             float(np.median(times)) / batch * 1e9,
             f"rounds={rounds} batch={batch} hot_shard=1/{S} "
             f"rows_written={idx.restack_rows - r0_rows} "
             f"full_restacks={idx.restack_full - r0_full}")

        # skewed ingest with rebalancing on: migrations must ride the
        # receiver's delta tier in the common case.  skew=1.5 because a
        # pure-insert hot shard can never exceed 2x the mean on a 2-shard
        # mesh (live_0 <= total) — 1.5 lets the 4/8-shard meshes trigger
        # within this ingest volume (the 2-shard row is a negative
        # control).
        idx = _build(rebalance_skew=1.5)
        t0 = time.time()
        for r in range(8):
            idx.insert_batch(hot[r * 2048:(r + 1) * 2048])
        jax.block_until_ready(idx.find(q, use_kernel=False))
        dt = time.time() - t0
        _row("migrate-skew", f"sharded-{S}", dt / (8 * 2048) * 1e9,
             f"ingest={8 * 2048} rebalances={idx.rebalances} "
             f"migrations_incremental={idx.migrations_incremental} "
             f"migrations_full={idx.migrations_full}")
    return rows


def bench_recover(n_values=(1 << 14, 1 << 16), eps: float = 0.7,
                  n_shards: int = 4) -> list[dict]:
    """Durability cost trajectory: snapshot cost vs index size, restore
    latency at the same width, and restore-resharded (N->2) latency — the
    elastic-restart path after host loss.  ``full_rebuilds`` in the detail
    must stay 0: resharding cuts fitted shards and rides delta merges, it
    never rebuilds from scratch.
    """
    import tempfile

    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core import distributed, persist

    rows: list[dict] = []

    def _row(op, impl, n_keys, ns, detail):
        rows.append({"op": op, "impl": impl, "n_keys": int(n_keys),
                     "ns_per_op": round(ns, 1), "detail": detail})
        print(f"{op:16s} {impl:12s} {ns:12.1f} ns/key  {detail}")

    mesh = Mesh(np.asarray(jax.devices()[:n_shards]), ("data",))
    mesh2 = Mesh(np.asarray(jax.devices()[:2]), ("data",))
    rng = np.random.default_rng(7)
    for n in n_values:
        base = _keys(n)
        n_leaves = max(n // 256, 16)
        idx = distributed.ShardedDynamicIndex.build(
            jnp.asarray(base), mesh, n_leaves=n_leaves, eps=eps)
        fresh = np.setdiff1d(_keys(4 * n, seed=9), base)
        idx.insert_batch(fresh[:n // 8])
        idx.delete_batch(rng.choice(base, n // 16, replace=False))
        nk = idx.total_live
        with tempfile.TemporaryDirectory() as d:
            store = persist.SnapshotStore(d, keep=2 + REPEATS)
            persist.snapshot_sharded(store, 0, idx, blocking=True)  # warm
            step = [0]

            def _snap(idx=idx, step=step, store=store):
                step[0] += 1
                persist.snapshot_sharded(store, step[0], idx,
                                         blocking=True)

            dt = _median(_snap)
            sd = Path(store.directory) / persist._STEP_FMT.format(step[0])
            nbytes = sum(f.stat().st_size for f in sd.iterdir())
            _row("snapshot", f"sharded-{n_shards}", nk, dt / nk * 1e9,
                 f"bytes={nbytes} files={len(list(sd.iterdir()))} "
                 f"keys={nk}")

            dt = _median(
                lambda store=store: persist.restore_sharded(store, mesh))
            _row("restore", f"sharded-{n_shards}", nk, dt / nk * 1e9,
                 f"keys={nk} same-width")

            st = [None]

            def _reshard(store=store):
                _, rep = persist.restore_sharded(store, mesh2)
                st[0] = rep.reshard

            dt = _median(_reshard)
            s = st[0]
            _row("restore-reshard", f"{n_shards}to2", nk, dt / nk * 1e9,
                 f"pieces={s.pieces} delta_merges={s.delta_merges} "
                 f"moved_keys={s.moved_keys} leaf_refits={s.leaf_refits} "
                 f"full_rebuilds={s.full_rebuilds}")
    return rows


def bench_drift(n: int = 1 << 17, batches: int = 8, batch: int = 2000,
                eps: float = 0.65) -> list[dict]:
    """Drift-adaptive serving trajectory (core.drift, through the
    ``repro.api.Index`` facade).

    One workload, two modes: ``batches`` insert batches per phase of a
    stationary -> shifted-lognormal -> zipf-hot ingest.  The timed section
    is the serving-path cost only — the insert call plus a blocking probe
    find; the idle-window maintenance that the serve frontend runs between
    batches (``Index.maybe_swap`` + a delta-bloat flush) is untimed,
    exactly like ``serve.frontend._maintain``.

    ``swap`` builds with the online KS monitor + ``swap_on_drift``: the
    insert path defers all structural repair to the idle window, where the
    bound-checked pool hot-swap absorbs drift pressure (rejected leaves
    take their refit there too, off the serving path).  ``refit-only`` is
    the same index without monitoring, so every over-budget leaf pays the
    O(n) merge + refit storm inline.  The committed claim: swap-mode p99
    per-key insert latency stays ~flat across the phase shifts while
    refit-only spikes by an order of magnitude.

    The zipf phase is drawn over base-*rank* space (hot CDF slots,
    interpolated between neighbouring base keys), not raw key space — a
    raw-key hot set lands on single wide leaves in sparse regions and
    models an out-of-support workload rather than hot-key drift.
    """
    import jax
    import jax.numpy as jnp
    from repro.api import Index
    from repro.core import reuse, synth

    def f32e(a):
        # tracelint: ok[f32-cast](f32-exact key synthesis)
        return np.unique(np.sort(np.asarray(a, np.float64))
                         .astype(np.float32).astype(np.float64))

    base = f32e(np.random.default_rng(10).lognormal(0.0, 0.5, n))
    pool = reuse.build_pool(synth.generate_pool(eps, ns=256, seed=1),
                            kind="linear", m_sim=64)
    rows: list[dict] = []

    def _row(impl, phase, ns, detail):
        rows.append({"op": "drift-ingest", "impl": impl, "phase": phase,
                     "n_keys": int(base.size), "ns_per_op": round(ns, 1),
                     "detail": detail})
        print(f"drift-ingest {impl:10s} {phase:16s} {ns:12.0f} ns/key(p99)"
              f"  {detail}")

    def _phases():
        """The phase schedule, regenerated per pass (same seed -> every
        pass sees byte-identical batches, so the warm pass compiles every
        shape the measured pass will hit)."""
        rng = np.random.default_rng(11)
        nb = base.shape[0]
        slots = rng.permutation(64)

        def zipf(s):
            # Hot CDF slots: zipf over 64 rank-space slots, keys drawn by
            # interpolating between neighbouring base keys inside the slot
            # (even per-leaf pressure — the hot set spans whole leaves).
            r = slots[(rng.zipf(1.2, s) - 1) % 64]
            pos = (r + rng.uniform(0.0, 1.0, s)) * (nb - 1) / 64.0
            i = pos.astype(int)
            frac = pos - i
            return (base[i] * (1.0 - frac)
                    + base[np.minimum(i + 1, nb - 1)] * frac)

        return [("stationary", lambda s: rng.lognormal(0.0, 0.5, s)),
                ("shift-lognormal", lambda s: rng.lognormal(0.9, 0.45, s)),
                ("zipf-hot", zipf)]

    def _run(impl, drift_kw, measure):
        ix = Index.build(jnp.asarray(base), eps=eps, n_leaves=256,
                         kind="linear", **drift_kw)
        d = ix.backend

        def maintain():
            # The serve idle window: proactive swaps + deferred refits,
            # plus the delta-bloat flush both modes share.
            ix.maybe_swap()
            if d.delta_live > d.base_n // 4:
                d.flush_delta()

        for phase, draw in _phases():
            ts = []
            rb_in = rb_mnt = 0
            sw0, rj0 = d.swaps_committed, d.swap_rejects
            for _ in range(batches):
                b = f32e(draw(batch))
                probe = b[:64]
                r0 = d.rebuilds
                t0 = time.perf_counter()
                ix.insert(b)
                jax.block_until_ready(ix.find(probe, path="jnp"))
                ts.append((time.perf_counter() - t0) / b.size * 1e9)
                rb_in += d.rebuilds - r0
                r1 = d.rebuilds
                maintain()
                rb_mnt += d.rebuilds - r1
            if measure:
                score = (float(np.max(np.asarray(d.drift.score)))
                         if d.drift is not None else 0.0)
                _row(impl, phase, float(np.percentile(ts, 99)),
                     f"batches={len(ts)} batch~{batch} "
                     f"p50={np.percentile(ts, 50):.0f} "
                     f"max={max(ts):.0f} "
                     f"swaps={d.swaps_committed - sw0} "
                     f"rejects={d.swap_rejects - rj0} "
                     f"rebuilds_inline={rb_in} "
                     f"rebuilds_maint={rb_mnt} ks={score:.3f}")

    for impl, drift_kw in (
            ("refit-only", {}),
            ("swap", dict(pool=pool, drift_bins=64, drift_hi=0.02,
                          drift_lo=0.01, swap_on_drift=True))):
        _run(impl, drift_kw, measure=False)   # warm: compile every shape
        _run(impl, drift_kw, measure=True)
    return rows


def drift_quick_rows(n: int = 1 << 14) -> list[dict]:
    """CSV rows for benchmarks.run's ``drift`` suite (single-host)."""
    return [{"name": f"drift_{r['impl']}_{r['phase']}",
             "us_per_call": r["ns_per_op"] / 1e3,
             "derived": r["detail"]}
            for r in bench_drift(n, batches=4, batch=1500)]


def _sharded_rows(n_shards: int, n: int) -> list[dict]:
    """Sharded rows via the shared forced-device-count worker call
    (harness.worker_suite — the host-device count locks at first jax
    init)."""
    from . import harness
    return harness.worker_suite("benchmarks.bench_updates",
                                "--sharded-worker", n_shards, n)


def _restack_rows_worker(n_devices: int, n: int) -> list[dict]:
    """Restack/migration sweep rows (shard counts 2/4/8 share one
    8-device worker)."""
    from . import harness
    return harness.worker_suite("benchmarks.bench_updates",
                                "--restack-worker", n_devices, n)


def _recover_rows_worker(n_devices: int, n: int) -> list[dict]:
    """Durability sweep rows (snapshot / restore /
    restore-resharded-to-2)."""
    from . import harness
    return harness.worker_suite("benchmarks.bench_updates",
                                "--recover-worker", n_devices, n)


def quick_rows(n: int = 1 << 15) -> list[dict]:
    """CSV rows for benchmarks.run (name/us_per_call/derived schema)."""
    return [{"name": f"updates_{r['op']}_{r['impl']}",
             "us_per_call": r["ns_per_op"] / 1e3,
             "derived": r["detail"]} for r in bench(n, with_pool=False)]


def sharded_quick_rows(n: int = 1 << 15, n_shards: int = 4) -> list[dict]:
    """CSV rows for benchmarks.run's ``sharded`` suite (subprocess mesh)."""
    return [{"name": f"sharded_{r['op']}_{r['impl']}",
             "us_per_call": r["ns_per_op"] / 1e3,
             "derived": r["detail"]} for r in _sharded_rows(n_shards, n)]


def restack_quick_rows(n: int = 1 << 15, n_devices: int = 8) -> list[dict]:
    """CSV rows for benchmarks.run's ``restack`` suite (subprocess mesh)."""
    return [{"name": f"restack_{r['op']}_{r['impl']}",
             "us_per_call": r["ns_per_op"] / 1e3,
             "derived": r["detail"]}
            for r in _restack_rows_worker(n_devices, n)]


def recover_quick_rows(n: int = 1 << 14, n_devices: int = 4) -> list[dict]:
    """CSV rows for benchmarks.run's ``recover`` suite (subprocess mesh)."""
    return [{"name": f"recover_{r['op']}_{r['impl']}",
             "us_per_call": r["ns_per_op"] / 1e3,
             "derived": r["detail"]}
            for r in _recover_rows_worker(n_devices, n)]


def main() -> None:
    from . import harness
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1 << 17)
    ap.add_argument("--shards", type=int, default=4,
                    help="mesh width for the sharded rows (0 disables)")
    ap.add_argument("--sharded-worker", type=int, default=None,
                    help=argparse.SUPPRESS)   # internal: emit rows as JSON
    ap.add_argument("--restack-worker", type=int, default=None,
                    help=argparse.SUPPRESS)   # internal: emit rows as JSON
    ap.add_argument("--recover-worker", type=int, default=None,
                    help=argparse.SUPPRESS)   # internal: emit rows as JSON
    ap.add_argument("--out", default=str(Path(__file__).resolve().parent.parent
                                         / "BENCH_updates.json"))
    args = ap.parse_args()
    if args.sharded_worker:
        rows = bench_sharded(args.n, args.sharded_worker)
        print(json.dumps(rows))
        return
    if args.restack_worker:
        rows = bench_restack(args.n)
        print(json.dumps(rows))
        return
    if args.recover_worker:
        rows = bench_recover((args.n, 4 * args.n))
        print(json.dumps(rows))
        return
    rows = bench(args.n)
    # Per-PR trajectory: append keyed by (git sha, suite); the committed
    # baseline meta/rows from the seeding run stay untouched.
    harness.append_bench(
        args.out, "updates", rows,
        note="host-loop-seed rows time the pre-PR2 per-leaf host buffer "
             "implementation; two-tier rows are the device-resident "
             "delta-tier subsystem. two-tier-pallas times the Pallas "
             "interpreter (correctness-grade).")
    if args.shards:
        srows = _sharded_rows(args.shards, min(args.n, 1 << 16))
        if srows:
            harness.append_bench(
                args.out, "sharded", srows,
                note=f"ShardedDynamicIndex churn on a forced "
                     f"{args.shards}-host-device CPU mesh vs single-device "
                     f"DynamicRMI at equal total keys; pallas rows are "
                     f"interpreter (correctness-grade).")
        rrows = _restack_rows_worker(8, min(args.n, 1 << 16))
        if rrows:
            harness.append_bench(
                args.out, "restack", rrows,
                note="Hot-shard maintenance sweep at equal total keys on "
                     "one forced 8-host-device CPU mesh (2/4/8-shard "
                     "submeshes): restack-churn rows must stay ~flat in "
                     "shard count (per-shard slice cache, O(touched) "
                     "restack); migrate-skew rows report incremental "
                     "(delta-riding) vs full-rebuild migrations.")
        krows = _recover_rows_worker(4, min(args.n, 1 << 14))
        if krows:
            harness.append_bench(
                args.out, "recover", krows,
                note="Durability sweep on a forced 4-host-device CPU mesh: "
                     "snapshot cost vs index size, same-width restore, and "
                     "restore-resharded 4->2 (elastic restart); "
                     "full_rebuilds must stay 0.")
    drows = bench_drift(min(args.n, 1 << 17))
    harness.append_bench(
        args.out, "drift", drows,
        note="Drifting ingest (stationary -> shifted lognormal -> zipf) "
             "through the repro.api.Index facade: p99 per-key "
             "insert+maintenance latency, swap mode (online KS monitor + "
             "bound-checked pool hot-swaps) vs refit-only — swap-mode p99 "
             "must stay ~flat while refit-only spikes on merge storms.")


if __name__ == "__main__":
    main()
