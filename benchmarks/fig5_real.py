"""Paper Fig. 5: index build time (a) and lookup time (b) on the four real
datasets (surrogates; DESIGN.md §5.5), full index roster."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

import repro  # noqa: F401
from . import datasets
from .harness import roster, timed_build, timed_lookup, verify


def run(n: int = datasets.DEFAULT_N, n_queries: int = 20_000):
    rng = np.random.default_rng(42)
    rows = []
    for dname, gen in datasets.REAL.items():
        keys = jnp.asarray(gen(n))
        q = jnp.asarray(rng.choice(np.asarray(keys), n_queries))
        for spec in roster():
            idx, bt = timed_build(spec, keys)
            res, ns = timed_lookup(spec, idx, q)
            ok = verify(keys, q, res)
            extra = ""
            if hasattr(idx, "reuse_fraction"):
                extra = f" reuse={idx.reuse_fraction:.2f}"
            rows.append({
                "name": f"fig5_{dname}_{spec.name}",
                "us_per_call": ns / 1e3,
                "derived": f"build={bt:.3f}s lookup={ns:.0f}ns/q "
                           f"correct={ok}{extra}",
            })
    return rows
