"""CI guard for the committed BENCH_*.json perf trajectories.

Three properties, enforced on every PR (ci.yml `bench-guard`):

  1. **Schema**: each file is ``{meta, rows, trajectory?}``; baseline rows
     carry the per-file required columns; every trajectory entry carries
     (sha, suite, mode, date, rows).
  2. **Keying**: trajectory entries are keyed by (git sha, suite) — the key
     is unique, so one PR contributes at most one entry per suite and
     re-runs replace instead of duplicating.
  3. **Append-only history**: the append flow (``harness.append_bench``)
     never mutates what a file already holds — exercised here by running a
     real append against a scratch copy and asserting the pre-existing
     document survives byte-identical.

Run: ``PYTHONPATH=src python -m benchmarks.check_bench [files...]``
Exits non-zero with one line per violation.
"""

from __future__ import annotations

import copy
import json
import re
import sys
import tempfile
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent

# Required columns of the baseline/trajectory rows, per file.
_ROW_KEYS = {
    "BENCH_updates.json": {"op", "impl", "n_keys", "ns_per_op", "detail"},
    "BENCH_lookup.json": {"variant", "n_keys", "path", "ns_per_query"},
    "BENCH_serve.json": {
        "workload",
        "tenants",
        "offered_qps",
        "achieved_qps",
        "p50_ms",
        "p99_ms",
        "p999_ms",
        "detail",
    },
}

# Suites whose trajectory rows carry extra dimensions beyond the file's
# baseline schema (the lookup-range suite adds the YCSB mix column).
_SUITE_ROW_KEYS = {
    ("BENCH_lookup.json", "lookup-range"): {
        "variant",
        "mix",
        "n_keys",
        "path",
        "ns_per_query",
    },
    # the drift suite adds the ingest-phase column
    ("BENCH_updates.json", "drift"): {
        "op",
        "impl",
        "phase",
        "n_keys",
        "ns_per_op",
        "detail",
    },
}

_ENTRY_KEYS = {"sha", "suite", "mode", "date", "rows"}
_DATE_RE = re.compile(r"^\d{4}-\d{2}-\d{2}$")


def check_schema(path: Path, doc: object) -> list[str]:
    """Structural checks (property 1 and 2). Returns human-readable
    violations, empty when clean."""
    errs: list[str] = []
    name = path.name

    def err(msg: str) -> None:
        errs.append(f"{name}: {msg}")

    if not isinstance(doc, dict):
        return [f"{name}: top level must be an object, got {type(doc).__name__}"]
    if not isinstance(doc.get("meta"), dict):
        err("missing/invalid 'meta' object")
    rows = doc.get("rows")
    if not (isinstance(rows, list) and rows):
        err("missing/empty baseline 'rows'")
        rows = []
    required = _ROW_KEYS.get(name, set())
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            err(f"rows[{i}] is not an object")
        elif required - row.keys():
            err(f"rows[{i}] missing columns {sorted(required - row.keys())}")

    traj = doc.get("trajectory", [])
    if not isinstance(traj, list):
        err("'trajectory' must be a list")
        traj = []
    seen: set[tuple[str, str]] = set()
    for i, entry in enumerate(traj):
        if not isinstance(entry, dict):
            err(f"trajectory[{i}] is not an object")
            continue
        missing = _ENTRY_KEYS - entry.keys()
        if missing:
            err(f"trajectory[{i}] missing fields {sorted(missing)}")
            continue
        if not _DATE_RE.match(str(entry["date"])):
            err(f"trajectory[{i}] date {entry['date']!r} is not YYYY-MM-DD")
        if not (isinstance(entry["rows"], list) and entry["rows"]):
            err(f"trajectory[{i}] ({entry['sha']}, {entry['suite']}) has no rows")
        else:
            req = _SUITE_ROW_KEYS.get((name, str(entry["suite"])), required)
            for j, row in enumerate(entry["rows"]):
                if not isinstance(row, dict) or req - row.keys():
                    bad = sorted(req - set(row)) if isinstance(row, dict) else "all"
                    err(f"trajectory[{i}].rows[{j}] missing columns {bad}")
                    break
        key = (str(entry["sha"]), str(entry["suite"]))
        if key in seen:
            err(f"duplicate trajectory key {key} — append flow must replace")
        seen.add(key)
    return errs


def check_append_immutable(path: Path) -> list[str]:
    """Property 3: a real ``harness.append_bench`` run against a scratch
    copy must leave every pre-existing byte of the document intact and must
    replace (not duplicate) a re-appended (sha, suite) key."""
    from . import harness

    before = json.loads(path.read_text())
    errs: list[str] = []
    fake_rows = [{k: 0 for k in _ROW_KEYS.get(path.name, {"x"})}]
    with tempfile.TemporaryDirectory() as td:
        scratch = Path(td) / path.name
        scratch.write_text(path.read_text())
        harness.append_bench(scratch, "guard-selftest", copy.deepcopy(fake_rows))
        after = json.loads(scratch.read_text())
        if after.get("meta") != before.get("meta"):
            errs.append(f"{path.name}: append flow mutated 'meta'")
        if after.get("rows") != before.get("rows"):
            errs.append(f"{path.name}: append flow mutated baseline 'rows'")
        old_traj = before.get("trajectory", [])
        new_traj = [
            e for e in after.get("trajectory", []) if e.get("suite") != "guard-selftest"
        ]
        if new_traj != old_traj:
            errs.append(
                f"{path.name}: append flow mutated pre-existing trajectory entries"
            )
        # Re-append the same (sha, suite): must replace, not duplicate.
        harness.append_bench(scratch, "guard-selftest", copy.deepcopy(fake_rows))
        again = json.loads(scratch.read_text())
        keys = [
            (e.get("sha"), e.get("suite"))
            for e in again.get("trajectory", [])
            if e.get("suite") == "guard-selftest"
        ]
        if len(keys) != 1:
            errs.append(
                f"{path.name}: re-appending the same (sha, suite) left "
                f"{len(keys)} entries, expected 1 (replace semantics)"
            )
    return errs


def check_file(path: Path) -> list[str]:
    try:
        doc = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as exc:
        return [f"{path.name}: unreadable ({exc})"]
    errs = check_schema(path, doc)
    if not errs:
        errs += check_append_immutable(path)
    return errs


def main(argv: list[str] | None = None) -> int:
    args = argv if argv is not None else sys.argv[1:]
    paths = [Path(a) for a in args] or sorted(_ROOT.glob("BENCH_*.json"))
    if not paths:
        print("check_bench: no BENCH_*.json files found", file=sys.stderr)
        return 1
    failures: list[str] = []
    for path in paths:
        errs = check_file(path)
        failures += errs
        traj = []
        if not errs:
            traj = json.loads(path.read_text()).get("trajectory", [])
        status = "FAIL" if errs else f"ok ({len(traj)} trajectory entries)"
        print(f"check_bench: {path.name}: {status}")
    for msg in failures:
        print(f"check_bench: {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
