"""Serving SLO benchmark: sustained QPS vs p50/p99/p999 latency under
open-loop Poisson arrivals (harness.poisson_arrivals).

Three tenant workload mixes drive the async batched front-end
(``repro.serve.frontend.BatchingFrontend``) over two tenants of different
build sizes on a small CPU mesh:

  * ``point``  — pure point lookups (70/30 tenant split),
  * ``insert`` — insert-heavy churn (80% inserts of 8 keys, 20% finds),
  * ``mixed``  — 50% finds / 30% inserts / 20% deletes.

The driver is open-loop: requests fire at their scheduled Poisson arrival
times whether or not the server keeps up, so queueing delay lands in the
measured latency (completion - *scheduled* arrival) instead of silently
throttling the offered load.  Rows append to BENCH_serve.json keyed by
(sha, suite) like the other trajectories.

Run ``python -m benchmarks.bench_serve`` for the committed sweep, or with
``--smoke`` for a seconds-scale CI pass (no file writes).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np


def _build_tenants(n: int, n_shards: int):
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core.distributed import ShardedDynamicIndex

    if len(jax.devices()) < n_shards:
        raise RuntimeError(f"need {n_shards} devices, "
                           f"have {len(jax.devices())}")
    mesh = Mesh(np.array(jax.devices()[:n_shards]), ("data",))
    rng = np.random.default_rng(7)
    tenants, fresh = [], []
    for i, (nt, nl) in enumerate(((n, 256), (n // 4, 64))):
        keys = np.sort(rng.choice(
            np.arange(i << 24, (i << 24) + (1 << 23), dtype=np.float64),
            size=nt, replace=False))
        tenants.append(ShardedDynamicIndex.build(
            jnp.asarray(keys), mesh, "data", n_leaves=nl))
        # disjoint insert feed + delete feed per tenant
        ins = np.setdiff1d(np.arange(
            (i << 24) + (1 << 23), (i << 24) + (1 << 23) + (1 << 22),
            dtype=np.float64), keys)
        rng.shuffle(ins)
        dels = keys.copy()
        rng.shuffle(dels)
        fresh.append([ins, 0, dels, 0])
    return tenants, fresh


_MIXES = {
    # (find_frac, insert_frac) — the rest are deletes
    "point": (1.0, 0.0),
    "insert": (0.2, 0.8),
    "mixed": (0.5, 0.3),
}

# Per-workload offered rates (CPU-interpret scale): a host-driven insert
# costs ~3 orders of magnitude more than a batched find lane, so the
# update-heavy mixes are driven at rates that probe saturation instead of
# drowning the queue from the first second.
_RATES = {
    "point": (500.0, 2000.0),
    "insert": (5.0, 25.0),
    "mixed": (10.0, 40.0),
}
_SMOKE_RATES = {"point": (200.0,), "insert": (5.0,), "mixed": (8.0,)}


def _drive(frontend, fresh, workload: str, rate: float, duration: float,
           keys_per_update: int = 8, seed: int = 0) -> dict:
    """One open-loop run: returns the latency/throughput row."""
    from benchmarks import harness

    find_f, ins_f = _MIXES[workload]
    arrivals = harness.poisson_arrivals(rate, duration, seed=seed)
    rng = np.random.default_rng(seed + 1)
    n_tenants = frontend.pack.n_tenants
    kinds = rng.choice(3, size=arrivals.size,
                       p=[find_f, ins_f, 1.0 - find_f - ins_f])
    tenant_of = rng.choice(n_tenants, size=arrivals.size, p=[0.7, 0.3])
    live0 = [t.live_keys() for t in frontend.pack.tenants]

    reqs = []
    clock = frontend.clock
    t0 = clock()
    for dt, kind, tid in zip(arrivals, kinds, tenant_of, strict=True):
        sched = t0 + dt
        lag = sched - clock()
        if lag > 0:
            time.sleep(lag)
        if kind == 0:
            q = rng.choice(live0[tid], 1)
            reqs.append((sched, frontend.submit_find(tid, q)))
        elif kind == 1:
            feed = fresh[tid]
            ks = feed[0][feed[1]:feed[1] + keys_per_update]
            feed[1] += keys_per_update
            reqs.append((sched, frontend.submit_insert(tid, ks)))
        else:
            feed = fresh[tid]
            ks = feed[2][feed[3]:feed[3] + keys_per_update]
            feed[3] += keys_per_update
            reqs.append((sched, frontend.submit_delete(tid, ks)))
    for _, r in reqs:
        r.result(timeout=120.0)
    lats = np.asarray([r.done_at - sched for sched, r in reqs])
    span = max(r.done_at for _, r in reqs) - t0
    q = lambda p: float(np.percentile(lats, p) * 1e3)
    st = frontend.stats
    return {
        "workload": workload,
        "tenants": n_tenants,
        "offered_qps": float(rate),
        "achieved_qps": float(len(reqs) / span),
        "p50_ms": q(50), "p99_ms": q(99), "p999_ms": q(99.9),
        "detail": f"reqs={len(reqs)} batches={st.batches} "
                  f"pad_frac={st.pad_fraction:.2f} "
                  f"qcaps={sorted(st.qcaps)}",
    }


def bench_serve(n: int = 1 << 14, n_shards: int = 2, rates=None,
                duration: float = 1.0) -> list[dict]:
    """The full sweep: every workload mix at its offered rates (``rates``
    overrides with one dict or tuple for all).  Tenants rebuild per run so
    insert churn in one mix doesn't skew the next."""
    from repro.serve.frontend import BatchingFrontend, ServeConfig

    rows = []
    for workload in _MIXES:
        wrates = rates.get(workload, ()) if isinstance(rates, dict) else \
            (rates if rates is not None else _RATES[workload])
        for k, rate in enumerate(wrates):
            tenants, fresh = _build_tenants(n, n_shards)
            fe = BatchingFrontend(
                tenants, config=ServeConfig(latency_budget_s=2e-3))
            with fe:
                fe.warmup((1, fe.config.batch_floor))
                _warm_updates(fe, fresh)
                rows.append(_drive(fe, fresh, workload, rate, duration,
                                   seed=17 * k + 1))
            print(f"[bench_serve] {rows[-1]}", file=sys.stderr)
    return rows


def _warm_updates(fe, fresh, k: int = 8) -> None:
    """Pre-warm the insert/delete/restack jits so one-time compiles don't
    masquerade as serving latency (capacity-class crossings mid-run still
    show up in p999 — that spike is the honest dynamic)."""
    for tid, feed in enumerate(fresh):
        fe.submit_insert(tid, feed[0][feed[1]:feed[1] + k])
        feed[1] += k
        fe.submit_delete(tid, feed[2][feed[3]:feed[3] + k])
        feed[3] += k
        fe.lookup(tid, feed[2][feed[3]:feed[3] + 1])


def quick_rows(n: int = 1 << 14, n_shards: int = 2) -> list[dict]:
    """CSV rows for benchmarks.run's ``serve`` suite (subprocess mesh).
    Each row keeps the full BENCH_serve schema underneath the CSV keys so
    ``run.py --record`` stays compatible with the trajectory guard."""
    from benchmarks import harness

    return [{**r,
             "name": f"serve_{r['workload']}_{int(r['offered_qps'])}qps",
             "us_per_call": r["p50_ms"] * 1e3,
             "derived": f"p99={r['p99_ms']:.2f}ms "
                        f"achieved={r['achieved_qps']:.0f}qps"}
            for r in harness.worker_suite("benchmarks.bench_serve",
                                          "--serve-worker", n_shards, n)]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1 << 14)
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale run, print rows, write nothing")
    ap.add_argument("--serve-worker", type=int, default=None,
                    help=argparse.SUPPRESS)
    args = ap.parse_args()

    if args.serve_worker is not None:
        # forced-device-count subprocess (harness.worker_suite protocol):
        # rows as JSON on the last stdout line.
        if args.smoke:
            rows = bench_serve(args.n, args.serve_worker,
                               rates=_SMOKE_RATES, duration=0.4)
        else:
            rows = bench_serve(args.n, args.serve_worker)
        print(json.dumps(rows))
        return

    from benchmarks import harness

    if args.smoke:
        rows = harness.worker_rows(
            "benchmarks.bench_serve", "--serve-worker", args.shards,
            ["--n", min(args.n, 1 << 13), "--smoke"], timeout=900)
        if not rows:
            raise SystemExit("serve smoke produced no rows")
        print(json.dumps(rows, indent=1))
        return

    rows = harness.worker_suite("benchmarks.bench_serve", "--serve-worker",
                                args.shards, args.n)
    if rows:
        harness.append_bench("BENCH_serve.json", "serve", rows,
                             note=f"n={args.n} shards={args.shards} "
                                  f"open-loop poisson")


if __name__ == "__main__":
    main()
