"""Benchmark entry point — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # all, quick sizes
  PYTHONPATH=src python -m benchmarks.run --only fig5 --n 1000000
  PYTHONPATH=src python -m benchmarks.run --only sharded --record

Prints ``name,us_per_call,derived`` CSV rows (plus a kernel microbench and
the serving-path row for the Pallas lookup kernel).  ``--record`` appends
the collected rows to the committed BENCH_*.json trajectories keyed by
(git sha, suite) — appended, never regenerated, so per-PR history
accumulates.
"""
from __future__ import annotations

import argparse
import sys
import time


def kernel_rows(n: int = 200_000, q: int = 16_384):
    """Pallas kernels (interpret mode on CPU): correctness-grade timing."""
    import numpy as np
    import jax.numpy as jnp
    import repro  # noqa: F401
    from repro.kernels import ops

    from repro.core import rmi

    rng = np.random.default_rng(0)
    keys = np.sort(rng.lognormal(0, 1, n)).astype(np.float32)
    qs = jnp.asarray(rng.choice(keys, q))
    idx = rmi.build_rmi(jnp.asarray(keys), n_leaves=256, kind="linear")
    root, mat, vec = idx.packed_tables()
    args = (qs, root, mat, vec, jnp.asarray(keys))
    kw = dict(n_leaves=idx.n_leaves, root_kind=idx.root_kind,
              leaf_kind=idx.leaf_kind, iters=idx.search_iters)
    r = ops.index_lookup(*args, **kw)
    r.block_until_ready()
    t0 = time.time()
    ops.index_lookup(*args, **kw).block_until_ready()
    dt = time.time() - t0
    h = ops.histogram(jnp.asarray(keys), 64, float(keys[0]), float(keys[-1]))
    h.block_until_ready()
    t0 = time.time()
    ops.histogram(jnp.asarray(keys), 64, float(keys[0]),
                  float(keys[-1])).block_until_ready()
    dth = time.time() - t0
    return [
        {"name": "kernel_lookup_fused", "us_per_call": dt / q * 1e6,
         "derived": f"{dt/q*1e9:.0f}ns/q interpret-mode n={n}"},
        {"name": "kernel_histogram", "us_per_call": dth * 1e6,
         "derived": f"{dth*1e3:.1f}ms for {n} keys m=64 interpret-mode"},
    ]


def rmrt_rows(n: int = 200_000, q: int = 16_384):
    """RMRT serving paths: fused Pallas kernel (in-kernel fixed-depth
    descent + clamped search, interpret mode on CPU) vs the clamped jnp
    masked-descent path."""
    import time as _time
    import numpy as np
    import jax
    import jax.numpy as jnp
    import repro  # noqa: F401
    from repro.core import rmrt

    rng = np.random.default_rng(0)
    keys = np.unique(np.sort(rng.lognormal(0, 1, n))
                     .astype(np.float32)).astype(np.float64)
    qs = jnp.asarray(rng.choice(keys, q))
    idx = rmrt.build_rmrt(jnp.asarray(keys), leaf_cap=4096, fanout=64,
                          kind="linear")
    rows = []
    for path, kw in (("kernel_rmrt_fused", dict(use_kernel=True)),
                     ("rmrt_jnp_clamped", dict())):
        jax.block_until_ready(rmrt.lookup(idx, qs, **kw))
        t0 = _time.time()
        jax.block_until_ready(rmrt.lookup(idx, qs, **kw))
        dt = _time.time() - t0
        rows.append({"name": path, "us_per_call": dt / q * 1e6,
                     "derived": f"{dt/q*1e9:.0f}ns/q n={n} "
                                f"depth={idx.depth} "
                                f"iters={idx.search_iters}"})
    return rows


SUITES = ["table2", "fig5", "fig6", "table3", "fig7", "updates", "sharded",
          "restack", "recover", "drift", "serve", "kernels", "rmrt"]

# --record routes each suite's rows into the matching committed trajectory
# (appended keyed by git sha + suite — never regenerated; see
# harness.append_bench).
_RECORD_TARGETS = {
    "fig7": "BENCH_updates.json", "updates": "BENCH_updates.json",
    "sharded": "BENCH_updates.json", "restack": "BENCH_updates.json",
    "recover": "BENCH_updates.json",
    "drift": "BENCH_updates.json",
    "serve": "BENCH_serve.json",
    "kernels": "BENCH_lookup.json", "rmrt": "BENCH_lookup.json",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help=f"comma list from {SUITES}")
    ap.add_argument("--n", type=int, default=None,
                    help="dataset size override (default 200k)")
    ap.add_argument("--record", action="store_true",
                    help="append the collected rows to the committed "
                         "BENCH_*.json trajectories (keyed by git sha + "
                         "suite)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else set(SUITES)

    by_suite: dict[str, list] = {}
    t_start = time.time()
    if "table2" in only:
        from . import table2_synth
        by_suite["table2"] = table2_synth.run()
    if "fig5" in only:
        from . import fig5_real
        by_suite["fig5"] = fig5_real.run(**({"n": args.n} if args.n else {}))
    if "fig6" in only:
        from . import fig6_skew
        by_suite["fig6"] = fig6_skew.run(**({"n": args.n} if args.n else {}))
    if "table3" in only:
        from . import table3_eps
        by_suite["table3"] = table3_eps.run(
            **({"n": args.n} if args.n else {}))
    if "fig7" in only:
        from . import fig7_updates
        by_suite["fig7"] = fig7_updates.run(
            **({"n": args.n} if args.n else {}))
    if "updates" in only:
        from . import bench_updates
        by_suite["updates"] = bench_updates.quick_rows(
            **({"n": args.n} if args.n else {}))
    if "sharded" in only:
        from . import bench_updates
        by_suite["sharded"] = bench_updates.sharded_quick_rows(
            **({"n": args.n} if args.n else {}))
    if "restack" in only:
        from . import bench_updates
        by_suite["restack"] = bench_updates.restack_quick_rows(
            **({"n": args.n} if args.n else {}))
    if "recover" in only:
        from . import bench_updates
        by_suite["recover"] = bench_updates.recover_quick_rows(
            **({"n": args.n} if args.n else {}))
    if "drift" in only:
        from . import bench_updates
        by_suite["drift"] = bench_updates.drift_quick_rows(
            **({"n": args.n} if args.n else {}))
    if "serve" in only:
        from . import bench_serve
        by_suite["serve"] = bench_serve.quick_rows(
            **({"n": args.n} if args.n else {}))
    if "kernels" in only:
        by_suite["kernels"] = kernel_rows(
            **({"n": args.n} if args.n else {}))
    if "rmrt" in only:
        by_suite["rmrt"] = rmrt_rows(**({"n": args.n} if args.n else {}))

    rows = [r for suite in SUITES for r in by_suite.get(suite, [])]
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.3f},\"{r['derived']}\"")
    print(f"# total {time.time()-t_start:.0f}s, {len(rows)} rows",
          file=sys.stderr)

    if args.record:
        from pathlib import Path
        from . import harness
        root = Path(__file__).resolve().parent.parent
        for suite, suite_rows in by_suite.items():
            target = _RECORD_TARGETS.get(suite)
            if target and suite_rows:
                harness.append_bench(root / target, f"run:{suite}",
                                     suite_rows)
            elif not target:
                print(f"# --record: suite {suite} has no trajectory "
                      f"target, skipped", file=sys.stderr)


if __name__ == "__main__":
    main()
