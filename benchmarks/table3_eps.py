"""Paper Table 3: build / lookup / insertion time under varying eps for
RMI-NN-MR and RMRT. Expected trends (paper): build rises with eps, lookup
falls with eps, insertion rises with eps (smaller Lemma 4.1 budgets)."""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

import repro  # noqa: F401
from repro.core import reuse, rmi, rmrt, synth, updates
from . import datasets


def run(n: int = 100_000, n_queries: int = 10_000,
        eps_list=(0.5, 0.6, 0.7, 0.9), insert_frac: float = 0.2):
    rng = np.random.default_rng(11)
    keys = jnp.asarray(datasets.amzn(n))
    q = jnp.asarray(rng.choice(np.asarray(keys), n_queries))
    ins = np.asarray(datasets.amzn(int(n * insert_frac), seed=99))
    rows = []
    for eps in eps_list:
        sp = synth.generate_pool(eps)
        mlp_pool = reuse.build_pool(sp, kind="mlp", train_steps=400)
        lin_pool = reuse.build_pool(sp, kind="linear")

        # RMI-NN-MR
        idx = rmi.build_rmi(keys, 512, kind="mlp", pool=mlp_pool,
                            train_steps=150)  # compile warmup
        t0 = time.time()
        idx = rmi.build_rmi(keys, 512, kind="mlp", pool=mlp_pool,
                            train_steps=150)
        jax.block_until_ready(idx.err_hi)
        bt = time.time() - t0
        rmi.lookup(idx, q).block_until_ready()
        t0 = time.time()
        rmi.lookup(idx, q).block_until_ready()
        lt = (time.time() - t0) / n_queries * 1e9

        dyn = updates.DynamicRMI.build(keys, pool=lin_pool, eps=eps,
                                       n_leaves=512, kind="linear")
        t0 = time.time()
        dyn.insert_batch(ins)
        it = (time.time() - t0) / ins.size * 1e9
        rows.append({
            "name": f"table3_eps{eps}_RMI-NN-MR",
            "us_per_call": lt / 1e3,
            "derived": f"build={bt:.2f}s lookup={lt:.0f}ns/q "
                       f"insert={it:.0f}ns/i rebuilds={dyn.rebuilds} "
                       f"reuse={idx.reuse_fraction:.2f}",
        })

        # RMRT
        t0 = time.time()
        tree = rmrt.build_rmrt(keys, leaf_cap=4096, fanout=64, kind="linear",
                               pool=lin_pool)
        jax.block_until_ready(tree.err_hi)
        bt2 = time.time() - t0
        rmrt.lookup(tree, q).block_until_ready()
        t0 = time.time()
        rmrt.lookup(tree, q).block_until_ready()
        lt2 = (time.time() - t0) / n_queries * 1e9
        rows.append({
            "name": f"table3_eps{eps}_RMRT",
            "us_per_call": lt2 / 1e3,
            "derived": f"build={bt2:.2f}s lookup={lt2:.0f}ns/q "
                       f"depth={tree.depth} reuse={tree.reuse_fraction:.2f}",
        })
    return rows
