"""Paper Fig. 6: build/lookup vs data skewness (alpha = 1,3,5,7,9) —
RMRT's adaptivity claim: its lookup time stays stable as skew grows."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

import repro  # noqa: F401
from . import datasets
from .harness import roster, timed_build, timed_lookup, verify

ROSTER_SUBSET = ("BTree", "RMI", "RMI-NN-MR", "PGM", "RS", "RMRT")


def run(n: int = datasets.DEFAULT_N, n_queries: int = 20_000,
        alphas=(1, 3, 5, 7, 9)):
    rng = np.random.default_rng(7)
    rows = []
    for alpha in alphas:
        keys = jnp.asarray(datasets.skew(alpha, n))
        q = jnp.asarray(rng.choice(np.asarray(keys), n_queries))
        for spec in roster():
            if spec.name not in ROSTER_SUBSET:
                continue
            idx, bt = timed_build(spec, keys)
            res, ns = timed_lookup(spec, idx, q)
            ok = verify(keys, q, res)
            rows.append({
                "name": f"fig6_a{alpha}_{spec.name}",
                "us_per_call": ns / 1e3,
                "derived": f"build={bt:.3f}s lookup={ns:.0f}ns/q correct={ok}",
            })
    return rows
