"""Paper Fig. 7: insertion time vs insertion ratio (a) and vs fanout /
branching parameter (b). Dynamic indices only (BTree absorbed into the
gapped-leaf comparison; RMI/RMI-NN/RS are static and excluded, as in the
paper).

PR 2: the sweeps now run on the two-tier (base + delta) device-resident
``DynamicRMI`` — inserts are vectorized route-sort-merges and Lemma 4.1
rebuilds are batched pool-reuse re-indexes — and each ratio row also times
find-under-churn through the fused lookup path.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

import repro  # noqa: F401
from repro.core import reuse, synth, updates
from . import datasets


def run(n: int = 100_000, eps: float = 0.9):
    rng = np.random.default_rng(13)
    keys = jnp.asarray(datasets.amzn(n))
    sp = synth.generate_pool(eps)
    pool = reuse.build_pool(sp, kind="linear")
    rows = []

    # (a) insertion ratio sweep
    for ratio in (0.1, 0.3, 0.5, 0.8, 1.0):
        ins = np.asarray(datasets.amzn(int(n * ratio), seed=1000 + int(ratio * 10)))
        dyn = updates.DynamicRMI.build(keys, pool=pool, eps=eps,
                                       n_leaves=512, kind="linear")
        t0 = time.time()
        dyn.insert_batch(ins)
        dt = time.time() - t0
        q = jnp.asarray(rng.choice(ins, 4096))
        jax.block_until_ready(dyn.find(q, use_kernel=False))   # warm
        t0 = time.time()
        jax.block_until_ready(dyn.find(q, use_kernel=False))
        dtf = time.time() - t0
        rows.append({
            "name": f"fig7a_ratio{ratio}",
            "us_per_call": dt / ins.size * 1e6,
            "derived": f"insert={dt/ins.size*1e9:.0f}ns/i "
                       f"find={dtf/4096*1e9:.0f}ns/q "
                       f"rebuilds={dyn.rebuilds} buffered={dyn.total_buffered}",
        })

    # (b) fanout sweep (number of leaves = insertion-budget granularity)
    ins = np.asarray(datasets.amzn(int(n * 0.5), seed=77))
    for n_leaves in (64, 256, 1024, 4096):
        dyn = updates.DynamicRMI.build(keys, pool=pool, eps=eps,
                                       n_leaves=n_leaves, kind="linear")
        t0 = time.time()
        dyn.insert_batch(ins)
        dt = time.time() - t0
        rows.append({
            "name": f"fig7b_leaves{n_leaves}",
            "us_per_call": dt / ins.size * 1e6,
            "derived": f"insert={dt/ins.size*1e9:.0f}ns/i "
                       f"rebuilds={dyn.rebuilds}",
        })
    return rows
