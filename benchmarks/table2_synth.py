"""Paper Table 2: number of synthetic datasets + pool pre-training time per
eps. Reproduces the enumeration exactly for eps in {0.5, 0.7, 0.8, 0.9}
(19 / 987 / 8,953 / 1,221; eps=0.6 noted in EXPERIMENTS.md) and reports the
batched pre-train time (the paper's GPU numbers: 2.1/8.8/63.5/839.5/109.1s —
our whole-pool-in-one-program times are the TPU-adaptation claim)."""
from __future__ import annotations

import time

import jax

import repro  # noqa: F401
from repro.core import reuse, synth

PAPER = {0.5: 19, 0.6: 95, 0.7: 987, 0.8: 8953, 0.9: 1221}


def run(quick: bool = True):
    rows = []
    eps_list = (0.5, 0.6, 0.7, 0.9) if quick else (0.5, 0.6, 0.7, 0.8, 0.9)
    for eps in eps_list:
        t0 = time.time()
        sp = synth.generate_pool(eps)
        t_gen = time.time() - t0
        t0 = time.time()
        pool = reuse.build_pool(sp, kind="mlp", train_steps=400)
        jax.block_until_ready(pool.err_hi)
        t_train = time.time() - t0
        rows.append({
            "name": f"table2_eps{eps}",
            "us_per_call": t_train * 1e6,
            "derived": (f"datasets={sp.size} paper={PAPER[eps]} "
                        f"match={sp.size == PAPER[eps]} gen={t_gen:.2f}s "
                        f"pretrain={t_train:.2f}s"),
        })
    return rows
