"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes and dtypes."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro  # noqa: F401  (x64 on)
from repro.kernels import ops, ref
from repro.core.reuse import pool_prefix_tables

pytestmark = pytest.mark.kernel

RNG = np.random.default_rng(1234)


@pytest.mark.parametrize("n", [100, 1_000, 4_097, 20_000])
@pytest.mark.parametrize("m", [12, 64, 130])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_hist_kernel(n, m, dtype):
    k = jnp.asarray((RNG.random(n) * 50 + 3).astype(dtype))
    got = ops.histogram(k, m, 3.0, 53.0)
    want = ref.hist_ref(k, m, 3.0, 53.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)
    assert abs(float(got.sum()) - 1.0) < 1e-5


@pytest.mark.parametrize("L,P,m", [(1, 1, 12), (5, 300, 64), (130, 70, 64),
                                   (64, 1221, 64)])
def test_ksdist_kernel(L, P, m):
    th = RNG.dirichlet(np.ones(m), L).astype(np.float32)
    ph = RNG.dirichlet(np.ones(m), P).astype(np.float32)
    pa, pps = pool_prefix_tables(jnp.asarray(ph))
    got = ops.ksdist_matrix(jnp.asarray(th), pa, pps)
    want = ref.ksdist_ref(jnp.asarray(th), pa, pps)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


@pytest.mark.parametrize("n,B", [(500, 4), (20_000, 50), (100_000, 513)])
def test_linfit_kernel(n, B):
    x = np.sort(RNG.random(n))
    buckets = jnp.asarray(np.minimum((x * B).astype(np.int32), B - 1))
    y = jnp.arange(n, dtype=jnp.float64)
    ab = ops.segment_linfit(jnp.asarray(x), y, buckets, B)
    from repro.core.rmi import segment_linear_fit
    p64 = segment_linear_fit(jnp.asarray(x), buckets, B)
    occupied = np.asarray(jax.ops.segment_sum(jnp.ones(n), buckets, B)) > 1
    np.testing.assert_allclose(np.asarray(ab[:, 0])[occupied],
                               np.asarray(p64.a)[occupied], rtol=5e-3)


def _rmi_tables(keys, n_leaves, kind):
    """Build an RMI over (f32-representable) keys; return its packed kernel
    tables + static meta."""
    from repro.core import rmi
    idx = rmi.build_rmi(jnp.asarray(keys, jnp.float64), n_leaves=n_leaves,
                        kind=kind, train_steps=60)
    root, mat, vec = idx.packed_tables()
    return idx, root, mat, vec


@pytest.mark.parametrize("S,Q", [(1_000, 128), (100_000, 5_000)])
@pytest.mark.parametrize("kind", ["linear", "mlp"])
def test_lookup_kernel(S, Q, kind):
    keys = np.unique(np.sort(RNG.lognormal(0, 1, S)).astype(np.float32))
    q = RNG.choice(keys, Q)
    idx, root, mat, vec = _rmi_tables(keys, 64, kind)
    got = ops.index_lookup(jnp.asarray(q), root, mat, vec, jnp.asarray(keys),
                           n_leaves=idx.n_leaves, root_kind=idx.root_kind,
                           leaf_kind=idx.leaf_kind, iters=idx.search_iters)
    truth = np.searchsorted(keys, q, side="left")
    np.testing.assert_array_equal(np.asarray(got), truth)
    # kernel must agree with its oracle exactly (pre-verification parity)
    from repro.kernels.lookup import lookup_pallas
    rk = lookup_pallas(jnp.asarray(q), root, mat, vec, jnp.asarray(keys),
                       n_leaves=idx.n_leaves, root_kind=idx.root_kind,
                       leaf_kind=idx.leaf_kind, iters=idx.search_iters)
    want = ref.lookup_ref(jnp.asarray(q), root, mat, vec, jnp.asarray(keys),
                          n_leaves=idx.n_leaves, root_kind=idx.root_kind,
                          leaf_kind=idx.leaf_kind, iters=idx.search_iters)
    np.testing.assert_array_equal(np.asarray(rk), np.asarray(want))


@pytest.mark.parametrize("S,Q,tile", [
    (5_000, 1_300, 512),      # Q not a multiple of TQ, S not of the tile
    (4_096, 4_096, 1024),     # exact multiples
    (70_001, 2_049, 4096),    # S spanning many tiles, ragged Q
    (300, 63, 128),           # S smaller than one tile
])
def test_lookup_kernel_edge_shapes(S, Q, tile):
    """Tiled kernel parity on ragged shapes, duplicate keys, and queries
    outside [kmin, kmax]."""
    base = np.sort(RNG.lognormal(0, 1, S)).astype(np.float32)
    keys = np.sort(np.concatenate([base, base[:: max(S // 64, 1)]]))  # dups
    inside = RNG.choice(keys, max(Q - 4, 1))
    outside = np.asarray([0.0, keys[0] / 2, keys[-1] * 2, 1e30], np.float32)
    q = np.concatenate([inside, outside])[:Q].astype(np.float32)
    idx, root, mat, vec = _rmi_tables(keys, 32, "linear")
    kw = dict(n_leaves=idx.n_leaves, root_kind=idx.root_kind,
              leaf_kind=idx.leaf_kind, iters=idx.search_iters, tile=tile)
    got = ops.index_lookup(jnp.asarray(q), root, mat, vec, jnp.asarray(keys),
                           **kw)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.searchsorted(keys, q, side="left"))
    from repro.kernels.lookup import lookup_pallas
    rk = lookup_pallas(jnp.asarray(q), root, mat, vec, jnp.asarray(keys),
                       **kw)
    want = ref.lookup_ref(jnp.asarray(q), root, mat, vec, jnp.asarray(keys),
                          **kw)
    np.testing.assert_array_equal(np.asarray(rk), np.asarray(want))


def test_lookup_kernel_guard_on_f32_unsafe_keys():
    """Keys that collide in f32 (kvcache-style packed ints > 2^24) must not
    auto-select the f32 kernel path; the jnp f64 path stays exact."""
    from repro.core import rmi
    keys = jnp.asarray([float((r << 22) | b) for r in range(8)
                        for b in range(128)], jnp.float64)
    idx = rmi.build_rmi(keys, n_leaves=16, kind="linear")
    assert not idx.f32_exact          # (7<<22)|127 etc. don't round-trip
    got = rmi.lookup(idx, keys)       # auto path: must stay f64-exact
    np.testing.assert_array_equal(np.asarray(got), np.arange(keys.shape[0]))
    with pytest.raises(ValueError):   # explicit override is rejected too
        rmi.lookup(idx, keys, use_kernel=True)
    # and an f32-clean key space is recognized as kernel-eligible
    clean = jnp.asarray(np.unique(RNG.random(4_000).astype(np.float32)),
                        jnp.float64)
    assert rmi.build_rmi(clean, n_leaves=16, kind="linear").f32_exact


def test_lookup_iters_clamped_by_error_window():
    """The serving search depth is bounded by the index's error window
    (paper §4), not by log2(n): near-linear data must search far fewer
    levels, and results stay exact."""
    from repro.core import rmi
    from repro.kernels.lookup import full_iters, search_iters
    n = 1 << 17
    keys = np.unique((np.arange(n) * 7.3
                      + RNG.random(n)).astype(np.float32))
    idx = rmi.build_rmi(jnp.asarray(keys, jnp.float64), n_leaves=512,
                        kind="linear")
    it = idx.search_iters
    assert it < full_iters(idx.n) - 3, (it, full_iters(idx.n))
    # depth covers the widest live window: 2^(it-1) >= max window
    elo = np.asarray(idx.err_lo)
    ehi = np.asarray(idx.err_hi)
    w = np.ceil(ehi) - np.floor(elo) + 3
    live = w < idx.n
    assert 2 ** (it - 1) >= w[live].max()
    assert it == search_iters(idx.err_lo, idx.err_hi, idx.n)
    q = RNG.choice(keys, 4_000)
    got = rmi.lookup(idx, jnp.asarray(q))                      # jnp, clamped
    np.testing.assert_array_equal(
        np.asarray(got), np.searchsorted(keys.astype(np.float64),
                                         q.astype(np.float64), side="left"))
    got_k = rmi.lookup(idx, jnp.asarray(q), use_kernel=True)   # fused kernel
    np.testing.assert_array_equal(np.asarray(got_k),
                                  np.searchsorted(keys, q, side="left"))


@pytest.mark.parametrize("B,Sq,H,dh", [(2, 128, 2, 64), (1, 384, 4, 128),
                                       (2, 100, 2, 64), (1, 256, 1, 128)])
@pytest.mark.parametrize("dtype", [np.float32, "bfloat16"])
def test_flash_attention_kernel(B, Sq, H, dh, dtype):
    """Pallas flash attention (interpret) vs the production jnp blockwise
    path (which the LM substrate uses and other tests validate)."""
    from repro.kernels.flash import flash_attention_pallas
    from repro.models.layers import flash_attention
    import ml_dtypes  # noqa: F401 — bf16 availability probe
    dt = jnp.bfloat16 if dtype == "bfloat16" else jnp.float32
    q = jnp.asarray(RNG.normal(0, 1, (B, Sq, H, dh)), dt)
    k = jnp.asarray(RNG.normal(0, 1, (B, Sq, H, dh)), dt)
    v = jnp.asarray(RNG.normal(0, 1, (B, Sq, H, dh)), dt)
    got = flash_attention_pallas(q, k, v, causal=True)
    want = flash_attention(q, k, v, q_offset=jnp.zeros((), jnp.int32))
    atol = 2e-2 if dtype == "bfloat16" else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


def test_flash_paths_match_dense_softmax_oracle():
    """Anchor both flash implementations (jnp blockwise AND the Pallas
    kernel) against a plain dense causal softmax — an oracle independent of
    the online-softmax machinery they share."""
    from repro.kernels.flash import flash_attention_pallas
    from repro.models.layers import flash_attention
    B, S, H, dh = 2, 160, 2, 64
    q = jnp.asarray(RNG.normal(0, 1, (B, S, H, dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (B, S, H, dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (B, S, H, dh)), jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(dh)
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, None], s, -jnp.inf)
    dense = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
    got_jnp = flash_attention(q, k, v, q_offset=jnp.zeros((), jnp.int32))
    got_pl = flash_attention_pallas(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got_jnp), np.asarray(dense),
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(got_pl), np.asarray(dense),
                               atol=2e-5)
