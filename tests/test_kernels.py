"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes and dtypes."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro  # noqa: F401  (x64 on)
from repro.kernels import ops, ref
from repro.core.reuse import pool_prefix_tables

RNG = np.random.default_rng(1234)


@pytest.mark.parametrize("n", [100, 1_000, 4_097, 20_000])
@pytest.mark.parametrize("m", [12, 64, 130])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_hist_kernel(n, m, dtype):
    k = jnp.asarray((RNG.random(n) * 50 + 3).astype(dtype))
    got = ops.histogram(k, m, 3.0, 53.0)
    want = ref.hist_ref(k, m, 3.0, 53.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)
    assert abs(float(got.sum()) - 1.0) < 1e-5


@pytest.mark.parametrize("L,P,m", [(1, 1, 12), (5, 300, 64), (130, 70, 64),
                                   (64, 1221, 64)])
def test_ksdist_kernel(L, P, m):
    th = RNG.dirichlet(np.ones(m), L).astype(np.float32)
    ph = RNG.dirichlet(np.ones(m), P).astype(np.float32)
    pa, pps = pool_prefix_tables(jnp.asarray(ph))
    got = ops.ksdist_matrix(jnp.asarray(th), pa, pps)
    want = ref.ksdist_ref(jnp.asarray(th), pa, pps)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


@pytest.mark.parametrize("n,B", [(500, 4), (20_000, 50), (100_000, 513)])
def test_linfit_kernel(n, B):
    x = np.sort(RNG.random(n))
    buckets = jnp.asarray(np.minimum((x * B).astype(np.int32), B - 1))
    y = jnp.arange(n, dtype=jnp.float64)
    ab = ops.segment_linfit(jnp.asarray(x), y, buckets, B)
    from repro.core.rmi import segment_linear_fit
    p64 = segment_linear_fit(jnp.asarray(x), buckets, B)
    occupied = np.asarray(jax.ops.segment_sum(jnp.ones(n), buckets, B)) > 1
    np.testing.assert_allclose(np.asarray(ab[:, 0])[occupied],
                               np.asarray(p64.a)[occupied], rtol=5e-3)


@pytest.mark.parametrize("S,Q", [(1_000, 128), (100_000, 5_000)])
@pytest.mark.parametrize("linear", [True, False])
def test_lookup_kernel(S, Q, linear):
    keys = np.sort(RNG.lognormal(0, 1, S)).astype(np.float32)
    keys = np.unique(keys)
    S = keys.size
    q = RNG.choice(keys, Q)
    A = np.polyfit(keys.astype(np.float64), np.arange(S), 1)
    resid = np.arange(S) - (A[0] * keys + A[1])
    w1 = np.zeros((Q, 4), np.float32)
    w1[:, 0] = A[0]
    b2 = np.full(Q, A[1], np.float32)
    elo = np.full(Q, resid.min() - 2, np.float32)
    ehi = np.full(Q, resid.max() + 2, np.float32)
    if linear:
        b1 = w2 = np.zeros((Q, 4), np.float32)
    else:  # random MLP: verified fallback must still give exact results
        b1 = RNG.normal(0, 1, (Q, 4)).astype(np.float32)
        w2 = RNG.normal(0, 1, (Q, 4)).astype(np.float32)
    got = ops.index_lookup(jnp.asarray(q), jnp.asarray(w1), jnp.asarray(b1),
                           jnp.asarray(w2), jnp.asarray(b2), jnp.asarray(elo),
                           jnp.asarray(ehi), jnp.asarray(keys), linear=linear)
    truth = np.searchsorted(keys, q, side="left")
    np.testing.assert_array_equal(np.asarray(got), truth)
    if linear:  # kernel must agree with its oracle exactly (no fallback path)
        want = ref.lookup_ref(jnp.asarray(q), jnp.asarray(w1), jnp.asarray(b1),
                              jnp.asarray(w2), jnp.asarray(b2),
                              jnp.asarray(elo), jnp.asarray(ehi),
                              jnp.asarray(keys), linear=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("B,Sq,H,dh", [(2, 128, 2, 64), (1, 384, 4, 128),
                                       (2, 100, 2, 64), (1, 256, 1, 128)])
@pytest.mark.parametrize("dtype", [np.float32, "bfloat16"])
def test_flash_attention_kernel(B, Sq, H, dh, dtype):
    """Pallas flash attention (interpret) vs the production jnp blockwise
    path (which the LM substrate uses and other tests validate)."""
    from repro.kernels.flash import flash_attention_pallas
    from repro.models.layers import flash_attention
    import ml_dtypes
    dt = jnp.bfloat16 if dtype == "bfloat16" else jnp.float32
    q = jnp.asarray(RNG.normal(0, 1, (B, Sq, H, dh)), dt)
    k = jnp.asarray(RNG.normal(0, 1, (B, Sq, H, dh)), dt)
    v = jnp.asarray(RNG.normal(0, 1, (B, Sq, H, dh)), dt)
    got = flash_attention_pallas(q, k, v, causal=True)
    want = flash_attention(q, k, v, q_offset=jnp.zeros((), jnp.int32))
    atol = 2e-2 if dtype == "bfloat16" else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


def test_flash_paths_match_dense_softmax_oracle():
    """Anchor both flash implementations (jnp blockwise AND the Pallas
    kernel) against a plain dense causal softmax — an oracle independent of
    the online-softmax machinery they share."""
    from repro.kernels.flash import flash_attention_pallas
    from repro.models.layers import flash_attention
    B, S, H, dh = 2, 160, 2, 64
    q = jnp.asarray(RNG.normal(0, 1, (B, S, H, dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (B, S, H, dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (B, S, H, dh)), jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(dh)
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, None], s, -jnp.inf)
    dense = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
    got_jnp = flash_attention(q, k, v, q_offset=jnp.zeros((), jnp.int32))
    got_pl = flash_attention_pallas(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got_jnp), np.asarray(dense),
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(got_pl), np.asarray(dense),
                               atol=2e-5)
