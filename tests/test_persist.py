"""Durability + recovery suite for core/persist.py.

Three layers:
  * single-process fault-injection tests (tests/faultinject.py drives the
    ``_write_bytes`` seam and damages committed snapshots at rest):
    surfaced async errors, per-file retry/backoff, torn-manifest and
    flipped-byte fallback, quarantined degraded serving, edge-case
    round-trips (empty / delta-only / all-tombstone / n==0 / pool);
  * multi-device subprocess scripts (conftest.run_mesh_script, like the
    other mesh suites): bit-exact kill/restore mid-churn on 1/2/4/8-device
    meshes on BOTH find paths, and elastic N->M restore (1<->2 quick,
    4->8 / 8->2 slow) asserting the no-full-rebuild counters;
  * a SIGKILL smoke: a churning process is killed for real mid-async-save,
    then a second process restores resharded 4->2 and must reproduce the
    exact finds recorded before the kill.
"""
import os
import signal
import subprocess
import sys
import tempfile

import numpy as np
import pytest

import repro  # noqa: F401
import faultinject as fi
from repro.core import persist

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from conftest import run_mesh_script  # noqa: E402


def f32keys(raw):
    return np.unique(np.sort(raw).astype(np.float32)).astype(np.float64)


def _mesh1():
    return jax.make_mesh((1,), ("data",))


def _small_index(seed=3, n=3000, churn=True, **kw):
    from repro.core import distributed
    rng = np.random.default_rng(seed)
    base = f32keys(rng.lognormal(0, 0.8, n) * 1e3)
    idx = distributed.ShardedDynamicIndex.build(
        jnp.asarray(base), _mesh1(), n_leaves=16, eps=0.7, **kw)
    if churn:
        fresh = np.setdiff1d(
            f32keys(rng.lognormal(0, 0.8, 4 * n) * 1e3), base)
        idx.insert_batch(fresh[:400])
        idx.delete_batch(rng.choice(base, 200, replace=False))
    return idx, rng


def _expect(idx, rng, extra=()):
    live = idx.live_keys()
    q = rng.permutation(np.concatenate(
        [rng.choice(live, 300), np.asarray(extra, np.float64)]))
    return q, np.searchsorted(live, q, "left"), np.searchsorted(live, q,
                                                                "right")


def _check(idx, q, lo, hi, use_kernel=False):
    f, r = idx.find(jnp.asarray(q), use_kernel=use_kernel)
    np.testing.assert_array_equal(np.asarray(r), lo)
    np.testing.assert_array_equal(np.asarray(f), hi > lo)


# ---------------------------------------------------------------------------
# Store-level fault injection.
# ---------------------------------------------------------------------------
def test_async_write_failure_surfaces():
    """A failed async write is re-raised from wait()/next save(), never
    swallowed (the old Checkpointer printed and moved on)."""
    with tempfile.TemporaryDirectory() as d:
        store = persist.SnapshotStore(d)
        with fi.FaultInjector(fail_always=True):
            store.save(1, {"a.npy": {"": np.arange(4.0)}})
            with pytest.raises(persist.SnapshotError):
                store.wait()
        # the error is consumed once; the store stays usable
        store.save(2, {"a.npy": {"": np.arange(4.0)}}, blocking=True)
        assert store.steps() == [2]


def test_transient_write_errors_retry_with_backoff():
    with tempfile.TemporaryDirectory() as d:
        store = persist.SnapshotStore(d, retries=3, backoff=0.001)
        with fi.FaultInjector(transient_errors=2) as inj:
            store.save(1, {"a.npy": {"": np.arange(4.0)}}, blocking=True)
            assert inj.raised == 2
        assert store.write_retries == 2
        assert store.steps() == [1]
        # retries exhausted -> the failure propagates
        with fi.FaultInjector(transient_errors=50):
            with pytest.raises(OSError):
                store.save(2, {"a.npy": {"": np.arange(4.0)}},
                           blocking=True)
        assert store.steps() == [1]


def test_kill_mid_write_commits_nothing():
    """A writer killed mid-shard leaves only a .tmp directory: the torn
    snapshot is invisible and restore falls back to the prior one."""
    idx, rng = _small_index()
    q, lo, hi = _expect(idx, rng)
    with tempfile.TemporaryDirectory() as d:
        store = persist.SnapshotStore(d)
        persist.snapshot_sharded(store, 1, idx, blocking=True)
        idx.insert_batch(np.asarray([1.5, 2.5]))
        with fi.FaultInjector(kill_after=1, partial=True):
            with pytest.raises(fi.WriteCrash):
                persist.snapshot_sharded(store, 2, idx, blocking=True)
        assert store.steps() == [1]
        assert any(s.endswith(".tmp") for s in os.listdir(d))
        idx2, rep = persist.restore_sharded(store, _mesh1())
        assert rep.step == 1
        _check(idx2, q, lo, hi)


def test_torn_manifest_falls_back():
    idx, rng = _small_index()
    q, lo, hi = _expect(idx, rng)
    with tempfile.TemporaryDirectory() as d:
        store = persist.SnapshotStore(d)
        persist.snapshot_sharded(store, 1, idx, blocking=True)
        idx.insert_batch(np.asarray([7.25]))
        persist.snapshot_sharded(store, 2, idx, blocking=True)
        fi.tear_manifest(store, 2)
        idx2, rep = persist.restore_sharded(store, _mesh1())
        assert rep.step == 1 and len(rep.skipped) == 1
        assert rep.skipped[0][0] == 2
        _check(idx2, q, lo, hi)
        with pytest.raises(persist.SnapshotCorruption):
            persist.restore_sharded(store, _mesh1(), on_corrupt="raise")


def test_flipped_byte_detected_fallback_and_quarantine():
    idx, rng = _small_index()
    q, lo, hi = _expect(idx, rng)
    with tempfile.TemporaryDirectory() as d:
        store = persist.SnapshotStore(d)
        persist.snapshot_sharded(store, 1, idx, blocking=True)
        idx.insert_batch(np.asarray([3.75]))
        persist.snapshot_sharded(store, 2, idx, blocking=True)
        fi.flip_byte(store, 2, "shard_00000.npz")
        # default: checksum catches it, the older snapshot serves
        idx2, rep = persist.restore_sharded(store, _mesh1())
        assert rep.step == 1
        _check(idx2, q, lo, hi)
        # explicit step + corruption -> raise, never silently accept
        with pytest.raises(persist.SnapshotCorruption):
            persist.restore_sharded(store, _mesh1(), step=2)
        # quarantine: newest snapshot serves degraded — the damaged shard
        # becomes a trivial empty shard answering found=False
        idx3, rep3 = persist.restore_sharded(store, _mesh1(),
                                             on_corrupt="quarantine")
        assert rep3.step == 2 and [s for s, _ in rep3.quarantined] == [0]
        assert idx3.quarantined == [0]
        f, r = idx3.find(jnp.asarray(q), use_kernel=False)
        assert not bool(np.asarray(f).any())
        np.testing.assert_array_equal(np.asarray(r), 0)
        # the quarantined range keeps accepting writes (re-feed path)
        idx3.insert_batch(q[:50])
        f, _ = idx3.find(jnp.asarray(q[:50]), use_kernel=False)
        assert bool(np.asarray(f).all())


def test_dropped_shard_file_falls_back():
    idx, rng = _small_index()
    q, lo, hi = _expect(idx, rng)
    with tempfile.TemporaryDirectory() as d:
        store = persist.SnapshotStore(d)
        persist.snapshot_sharded(store, 1, idx, blocking=True)
        idx.delete_batch(q[:20])
        persist.snapshot_sharded(store, 2, idx, blocking=True)
        fi.drop_file(store, 2, "shard_00000.npz")
        idx2, rep = persist.restore_sharded(store, _mesh1())
        assert rep.step == 1
        _check(idx2, q, lo, hi)


# ---------------------------------------------------------------------------
# Snapshot edge cases.
# ---------------------------------------------------------------------------
def _roundtrip(idx, probes):
    lv = idx.live_keys()
    lo = np.searchsorted(lv, probes, "left")
    hi = np.searchsorted(lv, probes, "right")
    with tempfile.TemporaryDirectory() as d:
        store = persist.SnapshotStore(d)
        persist.snapshot_sharded(store, 1, idx, blocking=True)
        idx2, _ = persist.restore_sharded(store, _mesh1())
    _check(idx2, probes, lo, hi)
    np.testing.assert_array_equal(idx2.live_keys(), lv)
    return idx2


def test_edge_empty_index_roundtrip():
    from repro.core import distributed
    idx = distributed.ShardedDynamicIndex.build(
        jnp.zeros((0,), jnp.float64), _mesh1(), n_leaves=8, eps=0.7)
    idx2 = _roundtrip(idx, np.asarray([0.0, 1.0, -3.5]))
    # a restored empty index accepts its first inserts
    idx2.insert_batch(np.asarray([4.0, 2.0, 8.0]))
    _check(idx2, np.asarray([2.0, 3.0, 8.0]), np.asarray([0, 1, 2]),
           np.asarray([1, 1, 3]))


def test_edge_delta_only_shard_roundtrip():
    from repro.core import distributed
    idx = distributed.ShardedDynamicIndex.build(
        jnp.zeros((0,), jnp.float64), _mesh1(), n_leaves=8, eps=0.7)
    keys = f32keys(np.random.default_rng(5).uniform(0, 100, 500))
    idx.insert_batch(keys)          # base tier still empty on any shard
    # rebuilds may have flushed some of the delta; force a delta-resident
    # remainder by inserting again
    idx.insert_batch(keys[:0])
    probes = np.concatenate([keys[::7], keys[::11] + 0.25])
    _roundtrip(idx, probes)


def test_edge_all_tombstone_roundtrip():
    idx, rng = _small_index(churn=False)
    keys = idx.live_keys()
    idx.delete_batch(keys)          # everything dead, storage still full
    assert idx.total_live == 0
    idx2 = _roundtrip(idx, keys[::5])
    f, r = idx2.find(jnp.asarray(keys[::5]), use_kernel=False)
    assert not bool(np.asarray(f).any())


def test_edge_pool_roundtrip():
    from repro.core import reuse, synth
    pool = reuse.build_pool(synth.generate_pool(0.9, limit=50),
                            kind="linear")
    idx, rng = _small_index(pool=pool)
    q, lo, hi = _expect(idx, rng)
    with tempfile.TemporaryDirectory() as d:
        store = persist.SnapshotStore(d)
        persist.snapshot_sharded(store, 1, idx, blocking=True)
        idx2, _ = persist.restore_sharded(store, _mesh1())
    assert idx2.pool is not None
    assert idx2.pool.trained_count == pool.trained_count
    _check(idx2, q, lo, hi)
    idx2.insert_batch(q[:100] + 0.125)      # pool-backed rebuilds still run


def test_bf16_and_f64_npy_viewcast_roundtrip():
    """bf16 leaves ride the uint16 view-cast codec and restore exactly,
    next to f64 leaves, through both the raw store and the Checkpointer."""
    import ml_dtypes
    from repro.train.checkpoint import Checkpointer
    rng = np.random.default_rng(0)
    bf = jnp.asarray(rng.normal(size=(33,)).astype(np.float32),
                     jnp.bfloat16)
    f64 = jnp.asarray(rng.normal(size=(17,)))
    tree = {"w": {"bf": bf, "f64": f64}}
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(3, tree, blocking=True)
        out = ck.restore(3, {"w": {"bf": jnp.zeros_like(bf),
                                   "f64": jnp.zeros_like(f64)}})
    assert out["w"]["bf"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(out["w"]["bf"]).view(np.uint16),
        np.asarray(bf).view(np.uint16))
    assert out["w"]["f64"].dtype == jnp.float64
    np.testing.assert_array_equal(np.asarray(out["w"]["f64"]),
                                  np.asarray(f64))
    with tempfile.TemporaryDirectory() as d:
        store = persist.SnapshotStore(d)
        store.save(1, {"x.npz": {"bf": np.asarray(bf), "f": np.asarray(f64)}},
                   blocking=True)
        got = store.load_file(1, "x.npz")
    assert got["bf"].dtype == ml_dtypes.bfloat16
    np.testing.assert_array_equal(got["bf"].view(np.uint16),
                                  np.asarray(bf).view(np.uint16))


def test_capacity_shrink_hysteresis():
    """Shedding most of a shard (the migration/reshard donor path keeps the
    +inf-padded capacity) strands storage; shrink_capacity steps both tiers
    down to the hysteresis class, answers stay exact, and an immediate
    small batch cannot climb back across."""
    from repro.core import updates
    rng = np.random.default_rng(11)
    keys = f32keys(rng.lognormal(0, 0.8, 30_000) * 1e3)
    d = updates.DynamicRMI.build(jnp.asarray(keys), eps=0.7, n_leaves=32,
                                 kind="linear")
    cap0 = d.index.keys.shape[0]
    d.shed_suffix(float(keys[999]))         # donor half of a migration
    assert d.index.keys.shape[0] == cap0, "shed must not reallocate"
    assert d.shrink_capacity() is True
    assert d.capacity_shrinks >= 1
    want = 2 * updates._capacity(d.base_n)  # hysteresis: 2x the tight class
    assert d.index.keys.shape[0] == want
    assert d.index.keys.shape[0] < cap0
    live = np.asarray(d.live_keys())
    q = rng.permutation(np.concatenate([rng.choice(live, 300),
                                        keys[-8:]]))
    f, r = d.find(jnp.asarray(q))
    np.testing.assert_array_equal(np.asarray(r),
                                  np.searchsorted(live, q, "left"))
    np.testing.assert_array_equal(
        np.asarray(f),
        np.searchsorted(live, q, "right") > np.searchsorted(live, q, "left"))
    # a <=128-key batch can never re-cross the class the shrink chose
    fresh = np.setdiff1d(
        f32keys(rng.lognormal(0, 0.8, 5_000) * 1e3), keys)[:128]
    d.insert_batch(fresh)
    assert not d.shrink_capacity(), "fresh headroom must not re-shrink"
    assert d.index.keys.shape[0] == want


# ---------------------------------------------------------------------------
# Elastic-controller integration: confirmed host loss -> restore resharded
# to the survivors.
# ---------------------------------------------------------------------------
def test_host_loss_triggers_restore_to_survivors():
    from repro.train.elastic import ElasticController
    t = [0.0]
    ctl = ElasticController(n_hosts=2, heartbeat_timeout=10.0,
                            clock=lambda: t[0])
    idx, rng = _small_index()
    q, lo, hi = _expect(idx, rng)
    with tempfile.TemporaryDirectory() as d:
        store = persist.SnapshotStore(d)
        persist.snapshot_sharded(store, 5, idx, blocking=True)
        t[0] = 20.0
        ctl.heartbeat(0, step_time=1.0)     # host 1 stays silent
        plan = ctl.plan()
        assert plan["action"] == "remesh" and plan["survivors"] == 1
        assert ctl.generation == 1
        # the launcher's response: restore the index resharded onto the
        # survivor mesh (1 host here — any width works, see the mesh
        # scripts for real N->M)
        mesh = jax.make_mesh((plan["survivors"],), ("data",))
        idx2, rep = persist.restore_sharded(store, mesh)
        assert rep.n_shards == plan["survivors"]
        _check(idx2, q, lo, hi)


# ---------------------------------------------------------------------------
# Multi-device: bit-exact kill/restore mid-churn (subprocess per mesh
# size), both find paths.
# ---------------------------------------------------------------------------
_SNAP_SCRIPT = r"""
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(ndev)d"
import numpy as np, jax, jax.numpy as jnp
import repro
from repro.core import distributed, persist

ndev = %(ndev)d

def f32keys(raw):
    return np.unique(np.sort(raw).astype(np.float32)).astype(np.float64)

rng = np.random.default_rng(13 + 7 * ndev)
base = f32keys(rng.lognormal(0, 0.8, 8_000) * 1e3)
fresh = np.setdiff1d(f32keys(rng.lognormal(0, 0.8, 60_000) * 1e3), base)
mesh = jax.make_mesh((ndev,), ("data",))
idx = distributed.ShardedDynamicIndex.build(
    jnp.asarray(base), mesh, n_leaves=32, eps=0.7)
idx.insert_batch(fresh[:900])
idx.delete_batch(rng.choice(base, 250, replace=False))

# expected answers are pinned to the snapshot instant
live = idx.live_keys()
q = rng.permutation(np.concatenate(
    [rng.choice(live, 500), fresh[-16:],
     np.asarray(idx.splits, np.float64) if idx.n_shards > 1
     else np.zeros(0)]))
lo = np.searchsorted(live, q, side="left")
hi = np.searchsorted(live, q, side="right")

with tempfile.TemporaryDirectory() as dd:
    store = persist.SnapshotStore(dd)
    persist.snapshot_sharded(store, 7, idx, blocking=False)
    # churn continues while the async writer runs: the snapshot must have
    # decoupled from every mutable buffer at the save() call
    idx.insert_batch(fresh[900:1400])
    idx.delete_batch(rng.choice(live, 200, replace=False))
    store.wait()

    # a later snapshot dies mid-write -> only step 7 is committed
    orig = persist._write_bytes
    calls = [0]
    def killer(path, data):
        if calls[0] >= 2:
            raise RuntimeError("simulated crash")
        calls[0] += 1
        orig(path, data)
    persist._write_bytes = killer
    try:
        persist.snapshot_sharded(store, 8, idx, blocking=True)
        raise SystemExit("crash injection did not fire")
    except RuntimeError:
        pass
    finally:
        persist._write_bytes = orig
    assert store.steps() == [7], store.steps()

    idx2, rep = persist.restore_sharded(store, mesh)
    assert rep.step == 7 and rep.n_shards_from == ndev

    # the recomputed device counter table is bit-identical to the saved one
    glob = store.load_file(7, "index.npz")
    np.testing.assert_array_equal(np.asarray(idx2._counts), glob["counts"])
    np.testing.assert_array_equal(np.asarray(idx2._muted), glob["muted"])

    for uk in (False, True):
        f, r = idx2.find(jnp.asarray(q), use_kernel=uk)
        np.testing.assert_array_equal(np.asarray(r), lo)
        np.testing.assert_array_equal(np.asarray(f), hi > lo)

    # the restored index keeps serving through fresh churn
    idx2.insert_batch(fresh[1400:1800])
    lv = idx2.live_keys()
    qq = rng.choice(lv, 300)
    f, r = idx2.find(jnp.asarray(qq), use_kernel=False)
    np.testing.assert_array_equal(np.asarray(r),
                                  np.searchsorted(lv, qq, "left"))
print("PERSIST_OK ndev=%(ndev)d")
"""


@pytest.mark.kernel
@pytest.mark.parametrize("ndev", [1, 2])
def test_snapshot_restore_bit_exact_small_mesh(ndev):
    run_mesh_script(_SNAP_SCRIPT % {"ndev": ndev}, f"PERSIST_OK ndev={ndev}")


@pytest.mark.kernel
@pytest.mark.slow
@pytest.mark.parametrize("ndev", [4, 8])
def test_snapshot_restore_bit_exact_large_mesh(ndev):
    run_mesh_script(_SNAP_SCRIPT % {"ndev": ndev}, f"PERSIST_OK ndev={ndev}")


# ---------------------------------------------------------------------------
# Elastic N->M restore (split and merge), no from-scratch rebuild.
# ---------------------------------------------------------------------------
_RESHARD_SCRIPT = r"""
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(ndev)d"
import numpy as np, jax, jax.numpy as jnp
import repro
from repro.core import distributed, persist

nfrom, nto = %(nfrom)d, %(nto)d

def f32keys(raw):
    return np.unique(np.sort(raw).astype(np.float32)).astype(np.float64)

rng = np.random.default_rng(29 + nfrom + 31 * nto)
base = f32keys(rng.lognormal(0, 0.8, 9_000) * 1e3)
fresh = np.setdiff1d(f32keys(rng.lognormal(0, 0.8, 60_000) * 1e3), base)
mesh_from = jax.make_mesh((nfrom,), ("data",),
                          devices=jax.devices()[:nfrom])
mesh_to = jax.make_mesh((nto,), ("data",), devices=jax.devices()[:nto])
idx = distributed.ShardedDynamicIndex.build(
    jnp.asarray(base), mesh_from, n_leaves=32, eps=0.7)
idx.insert_batch(fresh[:1200])
idx.delete_batch(rng.choice(base, 300, replace=False))
live = idx.live_keys()
q = rng.permutation(np.concatenate([rng.choice(live, 600), fresh[-16:]]))
lo = np.searchsorted(live, q, side="left")
hi = np.searchsorted(live, q, side="right")

with tempfile.TemporaryDirectory() as dd:
    store = persist.SnapshotStore(dd)
    persist.snapshot_sharded(store, 1, idx, blocking=True)
    idx2, rep = persist.restore_sharded(store, mesh_to)
    st = rep.reshard
    assert st is not None and st.n_from == nfrom and st.n_to == nto
    # the no-rebuild contract: every non-empty new shard is an anchor piece
    # cut out by shed (zero refits) plus delta-riding merges; only seam
    # leaves refit, nothing rebuilds from scratch
    assert st.full_rebuilds == 0, st
    assert st.pieces <= nfrom + nto - 1, st    # interval-overlap bound
    total_leaves = nto * 32
    assert st.leaf_refits < total_leaves, st
    for uk in (False, True):
        f, r = idx2.find(jnp.asarray(q), use_kernel=uk)
        np.testing.assert_array_equal(np.asarray(r), lo)
        np.testing.assert_array_equal(np.asarray(f), hi > lo)
    if nto >= 4 * nfrom:
        # a wide split strands the donor's pow2 capacity in every piece;
        # the first cold restack's shrink sweep must reclaim it (and the
        # finds above were answered post-shrink, so answers survived it)
        assert idx2.capacity_shrinks >= 1, idx2.capacity_shrinks
    # immediately serves fresh churn on the new width
    idx2.insert_batch(fresh[1200:1600])
    idx2.delete_batch(rng.choice(idx2.live_keys(), 150, replace=False))
    lv = idx2.live_keys()
    qq = rng.choice(lv, 300)
    f, r = idx2.find(jnp.asarray(qq), use_kernel=False)
    np.testing.assert_array_equal(np.asarray(r),
                                  np.searchsorted(lv, qq, "left"))
print("RESHARD_OK %(nfrom)d->%(nto)d")
"""


def _run_reshard(nfrom, nto):
    run_mesh_script(
        _RESHARD_SCRIPT % {"nfrom": nfrom, "nto": nto,
                           "ndev": max(nfrom, nto)},
        f"RESHARD_OK {nfrom}->{nto}")


@pytest.mark.kernel
@pytest.mark.parametrize("nfrom,nto", [(1, 2), (2, 1)])
def test_reshard_restore_small(nfrom, nto):
    _run_reshard(nfrom, nto)


@pytest.mark.kernel
@pytest.mark.slow
@pytest.mark.parametrize("nfrom,nto", [(4, 8), (8, 2), (1, 8)])
def test_reshard_restore_large(nfrom, nto):
    _run_reshard(nfrom, nto)


# ---------------------------------------------------------------------------
# SIGKILL smoke: a real kill -9 mid-async-save, then restore resharded
# 4->2 in a fresh process.
# ---------------------------------------------------------------------------
_KILL_SCRIPT = r"""
import os, signal
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, jax.numpy as jnp
import repro
from repro.core import distributed, persist

out = os.environ["PERSIST_SMOKE_DIR"]

def f32keys(raw):
    return np.unique(np.sort(raw).astype(np.float32)).astype(np.float64)

rng = np.random.default_rng(41)
base = f32keys(rng.lognormal(0, 0.8, 6_000) * 1e3)
fresh = np.setdiff1d(f32keys(rng.lognormal(0, 0.8, 40_000) * 1e3), base)
mesh = jax.make_mesh((4,), ("data",))
idx = distributed.ShardedDynamicIndex.build(
    jnp.asarray(base), mesh, n_leaves=32, eps=0.7)
idx.insert_batch(fresh[:700])
idx.delete_batch(rng.choice(base, 200, replace=False))

store = persist.SnapshotStore(out)
persist.snapshot_sharded(store, 1, idx, blocking=True)
live1 = idx.live_keys()

idx.insert_batch(fresh[700:1100])
live2 = idx.live_keys()
q = rng.permutation(np.concatenate([rng.choice(live2, 400), fresh[-16:]]))
# expected answers for BOTH possible surviving snapshots, written before
# the kill so the parent can check whichever one committed
np.savez(os.path.join(out, "expected.npz"), q=q,
         lo1=np.searchsorted(live1, q, "left"),
         hi1=np.searchsorted(live1, q, "right"),
         lo2=np.searchsorted(live2, q, "left"),
         hi2=np.searchsorted(live2, q, "right"))

persist.snapshot_sharded(store, 2, idx, blocking=False)   # async...
os.kill(os.getpid(), signal.SIGKILL)                      # ...and die
"""

_RESTORE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import numpy as np, jax, jax.numpy as jnp
import repro
from repro.core import distributed, persist

out = os.environ["PERSIST_SMOKE_DIR"]
exp = np.load(os.path.join(out, "expected.npz"))
store = persist.SnapshotStore(out)
mesh = jax.make_mesh((2,), ("data",))
idx, rep = persist.restore_sharded(store, mesh)
assert rep.n_shards_from == 4 and rep.n_shards == 2
assert rep.reshard is not None and rep.reshard.full_rebuilds == 0
tag = {1: ("lo1", "hi1"), 2: ("lo2", "hi2")}[rep.step]
lo, hi = exp[tag[0]], exp[tag[1]]
for uk in (False, True):
    f, r = idx.find(jnp.asarray(exp["q"]), use_kernel=uk)
    np.testing.assert_array_equal(np.asarray(r), lo)
    np.testing.assert_array_equal(np.asarray(f), hi > lo)
print("KILL_RESTORE_OK step=%d" % rep.step)
"""


@pytest.mark.kernel
def test_sigkill_restore_reshard_smoke():
    """Save under churn, SIGKILL the process for real, restore 4->2 in a
    fresh interpreter, and require bit-exact finds against answers the
    victim recorded before dying."""
    with tempfile.TemporaryDirectory() as d:
        env = dict(os.environ, PYTHONPATH="src", PERSIST_SMOKE_DIR=d)
        env.pop("XLA_FLAGS", None)
        proc = subprocess.run([sys.executable, "-c", _KILL_SCRIPT],
                              env=env, capture_output=True, text=True,
                              timeout=900)
        assert proc.returncode == -signal.SIGKILL, \
            (proc.returncode, proc.stderr[-2000:])
        # step 1 must have survived whatever the kill did to step 2
        store = persist.SnapshotStore(d)
        assert 1 in store.steps()
        proc = subprocess.run([sys.executable, "-c", _RESTORE_SCRIPT],
                              env=env, capture_output=True, text=True,
                              timeout=900)
        assert proc.returncode == 0, proc.stderr[-4000:]
        assert "KILL_RESTORE_OK" in proc.stdout, proc.stdout[-2000:]
