"""Multi-device integration: every arch takes a real train step AND a
seq-sharded decode step on a 4-device (1,2,2) mesh — catches FSDP
gather-axis and TP-psum bugs invisible on the (1,1,1) smoke mesh.

Runs in a subprocess because the parent pytest runs on 1 device (device
count locks at first jax init).
"""
import os
import subprocess
import sys

import jax
import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, jax.numpy as jnp
import repro
from repro.configs import list_archs
from repro.configs.reduced import reduced
from repro.models import model as M
from repro.serve import step as serve_step
from repro.train import optimizer
from repro.train.step import make_train_step

mesh = jax.make_mesh((1, 2, 2), ("pod", "data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*3)
import dataclasses
from repro.configs import get_arch
for arch in list_archs():
    cfg = reduced(arch)
    # enable real TP on the 2-wide model axis (and 2-way FSDP); xlstm stays
    # tp_shard=False by design (DESIGN.md §Arch-applicability)
    tp_shard = get_arch(arch).tp_shard
    cfg = dataclasses.replace(cfg, tp=2, tp_shard=tp_shard, n_heads=4,
                              n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads
                              else 4, vocab_size=256)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = optimizer.init(params)
    step, _ = make_train_step(cfg, mesh, lr=1e-3, donate=False,
                              microbatch=2)
    B, S = 4, 32
    if cfg.embed_input:
        inputs = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model),
                                   jnp.bfloat16)
    else:
        inputs = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                    cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    if cfg.rope == "mrope":
        pos = jnp.broadcast_to(pos[None], (3, B, S))
    p2, o2, _, m = step(params, opt, jnp.zeros(()), inputs, labels, pos)
    loss = float(m["loss"])
    assert np.isfinite(loss) and loss > 0, (arch, loss)
    # decode (batch-sharded on the 2-wide data axis)
    caches = M.init_cache(cfg, 4, 32, local=False)
    dec, _ = serve_step.make_decode_step(cfg, mesh, batch_sharded=True)
    tok = (jax.random.normal(jax.random.PRNGKey(3), (4, 1, cfg.d_model),
                             jnp.bfloat16) if cfg.embed_input
           else jnp.full((4, 1), 3, jnp.int32))
    dpos = (jnp.full((3, 4, 1), 8, jnp.int32) if cfg.rope == "mrope"
            else jnp.full((4, 1), 8, jnp.int32))
    nxt, _ = dec(p2, caches, tok, dpos, jnp.asarray(8, jnp.int32))
    assert np.all(np.asarray(nxt) >= 0), arch
    print(f"{arch}: loss={loss:.3f} decode ok", flush=True)
print("ALL_OK")
"""


@pytest.mark.slow
def test_all_archs_on_4dev_mesh():
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=2400)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "ALL_OK" in proc.stdout, proc.stdout[-2000:]


_NUMERIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses
import numpy as np, jax, jax.numpy as jnp
import repro
from repro.configs.reduced import reduced
from repro.models import model as M
from repro.train import optimizer
from repro.train.step import make_train_step

# qwen3 family with real TP(2) + FSDP(2) vs single-device: results must agree
cfg = dataclasses.replace(reduced("qwen3-4b"), tp=2, tp_shard=True,
                          n_heads=4, n_kv_heads=4, vocab_size=256)
mesh1 = jax.make_mesh((1, 1, 1), ("pod", "data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,)*3)
mesh4 = jax.make_mesh((1, 2, 2), ("pod", "data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,)*3)
params = M.init_params(cfg, jax.random.PRNGKey(0))
B, S = 4, 32
inputs = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
labels = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)

outs = []
for mesh in (mesh1, mesh4):
    step, _ = make_train_step(cfg, mesh, lr=1e-2, donate=False)
    p2, _, _, m = step(params, optimizer.init(params), jnp.zeros(()),
                       inputs, labels, pos)
    outs.append((float(m["loss"]), float(m["grad_norm"]),
                 [np.asarray(x, np.float32) for x in jax.tree.leaves(p2)]))

(l1, g1, t1), (l4, g4, t4) = outs
assert abs(l1 - l4) < 5e-3, (l1, l4)
assert abs(g1 - g4) / max(g1, 1e-9) < 5e-2, (g1, g4)
for a, b in zip(t1, t4):
    np.testing.assert_allclose(a, b, atol=5e-2)
print(f"NUMERIC_OK loss {l1:.4f}~{l4:.4f} gnorm {g1:.3f}~{g4:.3f}")
"""


@pytest.mark.slow
@pytest.mark.skipif(
    tuple(int(x) for x in jax.__version__.split(".")[:2]) < (0, 6),
    reason="needs the real pcast/vma machinery (jax >= 0.6): the 0.4.x "
           "compat shim runs shard_map with check_rep=False, which loses "
           "the replication typing this equivalence rests on (ROADMAP "
           "'True vma typing')")
def test_spmd_numeric_equivalence():
    """Loss/grad-norm/updated params agree between the (1,1,1) and (1,2,2)
    meshes — validates the manual-SPMD collective algebra (FSDP gathers,
    TP psums, grad sync) end to end."""
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _NUMERIC_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "NUMERIC_OK" in proc.stdout, proc.stdout[-2000:]
