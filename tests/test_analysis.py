"""tracelint analyzer suite (repro.analysis).

Every rule gets a positive fixture (the finding fires, with the right
rule id / file / line) and a negative fixture (the sanctioned spelling
stays clean).  Fixtures are written as miniature source trees under
``tmp_path/src`` so module names resolve exactly as in the repo
(``src/repro/serve/frontend.py`` -> ``repro.serve.frontend``), which is
what the hot-path call-graph roots key on.  The acceptance test seeds a
violation into a copy of the *real* ``serve/frontend.py`` by stripping
its sanctioned ``sync: ok`` pragmas and asserts the analyzer fails.

Pure-AST: no jax import, so this suite runs in milliseconds.
"""
import re
import textwrap
from pathlib import Path

from repro.analysis import Config, analyze
from repro.analysis.engine import main as cli_main

REPO = Path(__file__).resolve().parents[1]


def _write(tmp, rel, src):
    p = tmp / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(src))
    return p


def _run(tmp, config=None):
    return analyze([tmp / "src"], config, root=tmp)


def _bad(findings, rule=None):
    return [f for f in findings if f.suppressed is None
            and (rule is None or f.rule == rule)]


# -- hot-sync ---------------------------------------------------------------

_FRONTEND_FIXTURE = """\
    import numpy as np

    class BatchingFrontend:
        def _dispatch(self, batch):
            return self._stage(batch)

        def _stage(self, batch):
            n = batch.shape[0]
            pad = int(n)                # metadata: never flagged
            return np.asarray(batch.found), pad      # line 10: flagged

        def _resolve(self, inf):
            return int(inf.rank)        # line 13: flagged

    def cold_helper(x):
        return np.asarray(x)            # unreachable from roots: clean
    """


def test_hot_sync_positive_and_reachability(tmp_path):
    _write(tmp_path, "src/repro/serve/frontend.py", _FRONTEND_FIXTURE)
    bad = _bad(_run(tmp_path), "hot-sync")
    lines = sorted(f.line for f in bad)
    assert lines == [10, 13], bad
    assert all(str(f.path).endswith("serve/frontend.py") for f in bad)
    # the transitively-reached helper is attributed, the cold one is not
    assert any("_stage" in f.message for f in bad)
    assert not any("cold_helper" in f.message for f in bad)


def test_hot_sync_metadata_is_clean(tmp_path):
    _write(tmp_path, "src/repro/serve/frontend.py", """\
        class BatchingFrontend:
            def _dispatch(self, batch):
                n = batch.shape[0]
                caps = [int(n), int(batch.ndim), bool(n > 4)]
                return caps
        """)
    assert _bad(_run(tmp_path), "hot-sync") == []


def test_hot_sync_pragma_suppresses_with_reason(tmp_path):
    _write(tmp_path, "src/repro/serve/frontend.py", """\
        import numpy as np

        class BatchingFrontend:
            def _resolve(self, inf):
                # sync: ok(the one host sync per batch)
                found = np.asarray(inf.found)
                rank = np.asarray(inf.rank)  # tracelint: ok[hot-sync](rides it)
                return found, rank
        """)
    findings = _run(tmp_path)
    assert _bad(findings) == []
    reasons = {f.suppressed for f in findings if f.rule == "hot-sync"}
    assert reasons == {"the one host sync per batch", "rides it"}


# -- retrace ----------------------------------------------------------------

def test_retrace_branch_on_traced(tmp_path):
    _write(tmp_path, "src/repro/core/mod.py", """\
        import jax

        @jax.jit
        def f(x):
            if x > 0:
                return x
            return -x
        """)
    bad = _bad(_run(tmp_path), "retrace")
    assert len(bad) == 1 and bad[0].line == 5


def test_retrace_static_and_metadata_are_clean(tmp_path):
    _write(tmp_path, "src/repro/core/mod.py", """\
        import functools

        import jax

        @functools.partial(jax.jit, static_argnames=("flag",))
        def f(x, flag):
            n = x.shape[0]
            if flag and n > 4:          # static arg + shape metadata
                return x
            if x is None:               # identity: resolves at trace time
                return x
            return -x
        """)
    assert _bad(_run(tmp_path), "retrace") == []


def test_retrace_jit_of_lambda_and_jit_in_loop(tmp_path):
    _write(tmp_path, "src/repro/core/mod.py", """\
        import functools

        import jax

        g = jax.jit(lambda x: x + 1)

        def rebuild_every_call(fns, x):
            for fn in fns:
                x = jax.jit(fn)(x)
            return x

        @functools.lru_cache(maxsize=8)
        def jit_factory(fn):
            return jax.jit(fn)          # memoized: sanctioned
        """)
    bad = _bad(_run(tmp_path), "retrace")
    assert sorted(f.line for f in bad) == [5, 9]


# -- donation ---------------------------------------------------------------

_DONOR_FIXTURE = """\
    import functools

    import jax

    @functools.partial(jax.jit, donate_argnums=(0,))
    def upd(buf, x):
        return buf + x

    def bad(buf, x):
        out = upd(buf, x)
        return buf + out            # read of the deleted buffer

    def good(buf, x):
        buf = upd(buf, x)           # sanctioned same-statement rebind
        return buf
    """


def test_donation_read_after_donating_call(tmp_path):
    _write(tmp_path, "src/repro/core/mod.py", _DONOR_FIXTURE)
    bad = _bad(_run(tmp_path), "donation")
    assert len(bad) == 1 and bad[0].line == 10
    assert "'buf'" in bad[0].message


def test_donation_wrapper_propagates(tmp_path):
    # a thin wrapper forwarding its first arg into the donated slot is
    # itself donating; misuse at the *wrapper's* call site is flagged
    extra = textwrap.dedent("""\

        def wrapper(dst, x):
            return upd(dst, x)

        def bad_via_wrapper(dst, x):
            out = wrapper(dst, x)
            return dst + out
        """)
    _write(tmp_path, "src/repro/core/mod.py",
           textwrap.dedent(_DONOR_FIXTURE) + extra)
    bad = _bad(_run(tmp_path), "donation")
    assert {f.line for f in bad} == {10, 21}


# -- kernel -----------------------------------------------------------------

def _pallas_fixture(block):
    return f"""\
        import jax
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        def _kernel(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        def run(x):
            return pl.pallas_call(
                _kernel,
                in_specs=[pl.BlockSpec(({block}, {block}), lambda i: (0, 0))],
                out_specs=pl.BlockSpec(({block}, {block}), lambda i: (0, 0)),
            )(x)
        """


def test_kernel_vmem_budget(tmp_path):
    # 2048*2048*4B doubled-buffered in+out = 64 MiB >> 16 MiB default
    _write(tmp_path, "src/repro/kernels/mod.py", _pallas_fixture(2048))
    bad = _bad(_run(tmp_path), "kernel")
    assert len(bad) == 1 and "exceeds budget" in bad[0].message
    # the same site fits a raised budget
    cfg = Config(vmem_budget_bytes=128 * 1024 * 1024)
    assert _bad(_run(tmp_path, cfg), "kernel") == []


def test_kernel_small_blocks_are_clean(tmp_path):
    _write(tmp_path, "src/repro/kernels/mod.py", _pallas_fixture(128))
    assert _bad(_run(tmp_path), "kernel") == []


def test_kernel_banned_primitive_and_f64(tmp_path):
    _write(tmp_path, "src/repro/kernels/mod.py", """\
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        def _kernel(x_ref, o_ref):
            o_ref[...] = jnp.sort(x_ref[...])       # no TPU lowering
            tmp = x_ref[...].astype(jnp.float64)    # f64 in kernel

        def run(x):
            return pl.pallas_call(
                _kernel,
                in_specs=[pl.BlockSpec((8, 128), lambda i: (0, 0))],
            )(x)
        """)
    msgs = [f.message for f in _bad(_run(tmp_path), "kernel")]
    assert any("jnp.sort" in m for m in msgs)
    assert any("float64" in m for m in msgs)


# -- f32-cast ---------------------------------------------------------------

def test_f32_cast_of_keys_flagged(tmp_path):
    _write(tmp_path, "src/repro/core/mod.py", """\
        import jax.numpy as jnp

        def shrink(keys):
            return keys.astype(jnp.float32)
        """)
    bad = _bad(_run(tmp_path), "f32-cast")
    assert len(bad) == 1 and bad[0].line == 4


def test_f32_cast_guard_site_and_kernel_module_are_clean(tmp_path):
    _write(tmp_path, "src/repro/core/mod.py", """\
        import jax.numpy as jnp

        def checked(keys):
            kf = keys.astype(jnp.float32)
            return kf, _f32_exact(keys, kf)

        def mask(keys, q):
            return (keys == q).astype(jnp.float32)  # boolean mask, not keys
        """)
    # the kernel boundary package is sanctioned wholesale
    _write(tmp_path, "src/repro/kernels/mod.py", """\
        import jax.numpy as jnp

        def pack(keys):
            return keys.astype(jnp.float32)
        """)
    assert _bad(_run(tmp_path), "f32-cast") == []


# -- pragma grammar ---------------------------------------------------------

def test_pragma_requires_reason_and_known_rule(tmp_path):
    _write(tmp_path, "src/repro/core/mod.py", """\
        x = 1  # tracelint: ok[hot-sync]()
        y = 2  # tracelint: ok[no-such-rule](whatever)
        z = 3  # tracelint: ok
        w = 4  # sync: ok()
        """)
    bad = _bad(_run(tmp_path), "pragma")
    by_line = {f.line: f.message for f in bad}
    assert "no reason" in by_line[1]
    assert "unknown rule id" in by_line[2]
    assert "malformed pragma" in by_line[3]
    assert "no reason" in by_line[4]


def test_pragma_in_string_does_not_suppress(tmp_path):
    _write(tmp_path, "src/repro/serve/frontend.py", """\
        import numpy as np

        class BatchingFrontend:
            def _dispatch(self, batch):
                label = "sync: ok(not a comment)"
                return np.asarray(batch.found), label
        """)
    assert len(_bad(_run(tmp_path), "hot-sync")) == 1


def test_pragma_for_wrong_rule_does_not_suppress(tmp_path):
    _write(tmp_path, "src/repro/serve/frontend.py", """\
        import numpy as np

        class BatchingFrontend:
            def _dispatch(self, batch):
                # tracelint: ok[retrace](wrong rule id for this finding)
                return np.asarray(batch.found)
        """)
    assert len(_bad(_run(tmp_path), "hot-sync")) == 1


# -- acceptance: seeded violation in the real front-end ---------------------

def test_seeded_violation_in_real_frontend_fails(tmp_path):
    real = (REPO / "src/repro/serve/frontend.py").read_text()
    # strip the sanctioned per-batch sync pragmas: the resolve-site syncs
    # become unsuppressed hot-sync findings
    seeded = re.sub(r"#\s*sync:\s*ok\([^)]*\)", "# (pragma stripped)", real)
    assert seeded != real, "fixture drift: frontend.py lost its sync pragmas"
    _write(tmp_path, "src/repro/serve/frontend.py", seeded)
    bad = _bad(_run(tmp_path), "hot-sync")
    assert len(bad) >= 2
    assert any("_resolve" in f.message for f in bad)


def test_real_tree_is_clean():
    findings = analyze([REPO / "src", REPO / "benchmarks", REPO / "examples"],
                       root=REPO)
    assert _bad(findings) == []
    # every suppression carries a non-empty reason
    assert all(f.suppressed for f in findings if f.suppressed is not None)


# -- CLI --------------------------------------------------------------------

def test_cli_exit_codes(tmp_path, capsys, monkeypatch):
    # module names resolve relative to cwd (the repo-root invocation
    # contract: `python -m repro.analysis src benchmarks examples`)
    monkeypatch.chdir(tmp_path)
    _write(tmp_path, "src/repro/serve/frontend.py", _FRONTEND_FIXTURE)
    assert cli_main(["src"]) == 1
    out = capsys.readouterr().out
    assert "[hot-sync]" in out and "tracelint:" in out

    clean = tmp_path / "clean"
    _write(clean, "src/repro/core/mod.py", "X = 1\n")
    monkeypatch.chdir(clean)
    assert cli_main(["src"]) == 0

    assert cli_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    assert "hot-sync" in out and "kernel" in out
