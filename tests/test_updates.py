"""Two-tier (base + delta) dynamic-update subsystem tests: cross-leaf rank
accounting, tombstone semantics, pool-reuse rebuilds with measured bounds,
kernel-vs-oracle parity for the fused dynamic lookup, and the no-host-loop
guard on the jitted hot paths."""
import numpy as np
import jax.numpy as jnp
import pytest

import repro  # noqa: F401
from repro.core import reuse, rmi, synth
from repro.core import updates as updates_mod
from repro.core.updates import DynamicRMI
from repro.kernels import ref
from repro.kernels.lookup import dynamic_lookup_pallas

RNG = np.random.default_rng(7)


def _f32_keys(n, seed=0, lo=0.0, hi=1.0):
    rng = np.random.default_rng(seed)
    k = np.sort(rng.uniform(lo, hi, n))
    return np.unique(k.astype(np.float32)).astype(np.float64)


@pytest.fixture(scope="module")
def lin_pool():
    return reuse.build_pool(synth.generate_pool(0.9, limit=200),
                            kind="linear")


def _truth(d, q):
    live = d.live_keys()
    return np.isin(q, live), np.searchsorted(live, q, side="left")


def _assert_find_exact(d, q, use_kernel=False):
    tf, tr = _truth(d, np.asarray(q))
    f, r = d.find(jnp.asarray(q), use_kernel=use_kernel)
    np.testing.assert_array_equal(np.asarray(f), tf)
    np.testing.assert_array_equal(np.asarray(r), tr)


# ---------------------------------------------------------------------------
# Satellite: cross-leaf rank regression.
# ---------------------------------------------------------------------------
def test_rank_counts_deltas_in_earlier_leaves():
    """The seed composed base_pos + routed-leaf buffer rank only, dropping
    buffered inserts in earlier leaves; the two-tier rank must count every
    live delta key < q."""
    base = _f32_keys(20_000, seed=1)
    # eps=0.5 -> Lemma 4.1 budget == leaf size: no rebuilds, inserts stay
    # in the delta tier where the seed's bug lived.
    d = DynamicRMI.build(jnp.asarray(base), eps=0.5, n_leaves=16,
                         kind="linear")
    ins = _f32_keys(512, seed=2)                 # spread over all leaves
    ins = np.setdiff1d(ins, base)
    d.insert_batch(ins)
    assert d.rebuilds == 0 and d.total_buffered == ins.size
    # queries in the LAST leaf: rank must include earlier-leaf inserts
    q = np.concatenate([base[-50:], ins[-20:]])
    live = d.live_keys()
    _, r = d.find(jnp.asarray(q))
    np.testing.assert_array_equal(np.asarray(r), np.searchsorted(live, q))
    # and the strictest form: rank of the largest key counts everything
    _, r_top = d.find(jnp.asarray(live[-1:]))
    assert int(r_top[0]) == live.size - 1


# ---------------------------------------------------------------------------
# Satellite: rebuild refits the model and bounds stay measured/tight.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("reuse_on_rebuild", [None, True])
def test_rebuild_refits_model_and_bounds(lin_pool, reuse_on_rebuild):
    """The seed's _rebuild_leaf only reset counters — model and bounds went
    stale.  Post-rebuild, every leaf's error bounds must cover the measured
    residuals of its members over the *merged* base (and the delta entries
    of rebuilt leaves must actually be merged)."""
    base = _f32_keys(30_000, seed=3)
    d = DynamicRMI.build(jnp.asarray(base), pool=lin_pool, eps=0.9,
                         n_leaves=64, kind="linear",
                         reuse_on_rebuild=reuse_on_rebuild)
    ins = np.setdiff1d(_f32_keys(6_000, seed=4), base)
    d.insert_batch(ins)
    assert d.rebuilds > 0
    assert d.base_n > base.size          # delta actually merged into base
    idx = d.index
    buckets = updates_mod._routed_buckets(idx.root_kind, idx.root, idx.keys,
                                          idx.n_leaves, d.route_n)
    pred = rmi._leaf_predict_all(idx.leaf_kind, idx.leaves, idx.keys,
                                 buckets)
    lo, hi = rmi.segment_residual_bounds_sorted(pred, buckets, idx.n_leaves)
    elo, ehi = np.asarray(idx.err_lo), np.asarray(idx.err_hi)
    assert (np.asarray(lo) >= elo - 1e-6).all()
    assert (np.asarray(hi) <= ehi + 1e-6).all()
    # bounds are measured (tight), not the widen-only fallback: windows stay
    # far below the sentinel full-array width
    live_rows = np.asarray(
        rmi.leaf_stats_sorted(idx.keys, buckets, idx.n_leaves)[0]) > 0
    assert (ehi - elo)[live_rows].max() < d.base_n / 4
    _assert_find_exact(d, np.concatenate([base[:500], ins[:500]]))
    if reuse_on_rebuild:                 # Algorithm-1 reuse actually fired
        assert float(np.mean(np.asarray(idx.reused_mask))) > 0.0


# ---------------------------------------------------------------------------
# Satellite: delete of a key still sitting in the delta tier.
# ---------------------------------------------------------------------------
def test_delete_clears_buffered_insert():
    base = _f32_keys(10_000, seed=5)
    d = DynamicRMI.build(jnp.asarray(base), eps=0.5, n_leaves=16,
                         kind="linear")
    ins = np.setdiff1d(_f32_keys(200, seed=6), base)
    d.insert_batch(ins)
    victim = ins[37:38]
    f, _ = d.find(jnp.asarray(victim))
    assert bool(f[0])
    d.delete(victim[0])                  # still buffered in the delta tier
    f, _ = d.find(jnp.asarray(victim))
    assert not bool(f[0])                # seed left it live forever
    assert d.delta_live == ins.size - 1
    # rank excludes the tombstoned entry
    _assert_find_exact(d, np.concatenate([ins, base[:100]]))
    # delete of a base key, and of an absent key (no-op)
    d.delete_batch(np.concatenate([base[11:12], np.asarray([1e12])]))
    f, _ = d.find(jnp.asarray(base[11:12]))
    assert not bool(f[0])
    # re-insert after delete resurrects the key
    d.insert_batch(victim)
    f, _ = d.find(jnp.asarray(victim))
    assert bool(f[0])
    _assert_find_exact(d, np.concatenate([ins, base[:100]]))


def test_delete_only_workload_triggers_compaction():
    """ROADMAP churn item: a delete-only workload must not grow the delta
    tier's dead fraction without bound — compaction fires at the configured
    dead ratio, purges every tombstone, and leaves all live ranks (and the
    kernel path) invariant."""
    base = _f32_keys(8_192, seed=21)
    d = DynamicRMI.build(jnp.asarray(base), eps=0.5, n_leaves=16,
                         kind="linear", compact_dead_ratio=0.25)
    ins = np.setdiff1d(_f32_keys(3_000, seed=22, lo=0.1, hi=0.9), base)
    d.insert_batch(ins)
    assert d.delta_live == ins.size and d.delta_dead_count == 0

    probe = np.concatenate([ins, base[::64]])
    victims = ins[::3]                   # delete-only from here on
    survivors = np.setdiff1d(ins, victims)
    fired = 0
    for chunk in np.array_split(victims, 10):
        before = {}
        if fired == 0 and d.delta_dead_count > 0:
            # capture state right below the threshold to check invariance
            # across the *next* compaction
            f0, r0 = d.find(jnp.asarray(probe))
            before = {"f": np.asarray(f0), "r": np.asarray(r0),
                      "live": d.live_keys()}
        d.delete_batch(chunk)
        if d.delta_compactions > fired:
            fired = d.delta_compactions
            assert d.delta_dead_count == 0          # tombstones purged
            if before:
                # live keys and every rank unchanged by the compaction
                # (modulo the chunk that was just deleted)
                live = d.live_keys()
                np.testing.assert_array_equal(
                    live, np.setdiff1d(before["live"], chunk))
    assert d.delta_compactions >= 1      # the trigger actually fired
    # dead fraction stays bounded by the ratio after every batch
    tot = d.delta_live + d.delta_dead_count
    assert tot == 0 or d.delta_dead_count < 0.25 * tot + len(victims) // 10
    assert d.delta_live == survivors.size
    _assert_find_exact(d, probe)
    _assert_find_exact(d, probe, use_kernel=True)

    # disabling the trigger preserves the old behaviour (dead fraction
    # grows until the next insert/rebuild merge)
    d2 = DynamicRMI.build(jnp.asarray(base), eps=0.5, n_leaves=16,
                          kind="linear", compact_dead_ratio=None)
    d2.insert_batch(ins)
    d2.delete_batch(victims)
    assert d2.delta_compactions == 0
    assert d2.delta_dead_count == victims.size
    _assert_find_exact(d2, probe)


def test_delete_duplicate_runs():
    """Partially tombstoned duplicate runs: each delete retires one copy
    (tombstones form a prefix of the run), find stays True while any copy
    is live, and this holds across both tiers and the kernel path."""
    base = _f32_keys(4_096, seed=40)
    d = DynamicRMI.build(jnp.asarray(base), eps=0.5, n_leaves=8,
                         kind="linear")
    k = base[100:101]                    # one base copy
    d.insert_batch(np.repeat(k, 2))      # + two delta copies
    for expect_live in (2, 1, 0):
        d.delete(k[0])
        f, _ = d.find(jnp.asarray(k))
        fk, _ = d.find(jnp.asarray(k), use_kernel=True)
        assert bool(f[0]) == bool(fk[0]) == (expect_live > 0)
        assert d.live_keys().size == base.size + 2 - (3 - expect_live)
    d.delete(k[0])                       # absent now: no-op
    assert d.live_keys().size == base.size - 1
    _assert_find_exact(d, np.concatenate([k, base[:50]]))


# ---------------------------------------------------------------------------
# Satellite: kernel-vs-oracle parity suite for the two-tier lookup.
# ---------------------------------------------------------------------------
def _kernel_parity(d, q):
    """Raw kernel output must be bit-identical to the jnp oracle, and the
    full wrapped find must match the f64 path exactly."""
    idx = d.index
    root, mat, vec = idx.packed_tables()
    kw = dict(n_leaves=idx.n_leaves, route_n=d.route_n,
              root_kind=idx.root_kind, leaf_kind=idx.leaf_kind,
              iters=idx.search_iters)
    qj = jnp.asarray(q)
    pk, dk = dynamic_lookup_pallas(qj, root, mat, vec, idx.keys,
                                   d.delta_keys, **kw)
    pr, dr = ref.dynamic_lookup_ref(qj, root, mat, vec, idx.keys,
                                    d.delta_keys, **kw)
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
    np.testing.assert_array_equal(np.asarray(dk), np.asarray(dr))
    _assert_find_exact(d, q, use_kernel=True)
    _assert_find_exact(d, q, use_kernel=False)


@pytest.mark.kernel
def test_dynamic_kernel_parity_empty_delta():
    base = _f32_keys(8_192, seed=8)
    d = DynamicRMI.build(jnp.asarray(base), eps=0.9, n_leaves=32,
                         kind="linear")
    q = np.concatenate([RNG.choice(base, 500), _f32_keys(64, seed=9, hi=2.0)])
    _kernel_parity(d, q)


@pytest.mark.kernel
def test_dynamic_kernel_parity_delta_only_leaves():
    """Leaves with no base members but live delta entries (base has a hole
    in the key range; inserts land in it)."""
    lo = _f32_keys(4_000, seed=10, lo=0.0, hi=1.0)
    hi = _f32_keys(4_000, seed=11, lo=3.0, hi=4.0)
    base = np.concatenate([lo, hi])
    d = DynamicRMI.build(jnp.asarray(base), eps=0.5, n_leaves=64,
                         kind="linear")
    d.budget[:] = 1 << 30          # keep the hole-leaves delta-only (empty
    ins = _f32_keys(300, seed=12, lo=1.5, hi=2.5)  # leaves have 0 budget)
    d.insert_batch(ins)
    assert d.rebuilds == 0 and d.total_buffered == ins.size
    q = np.concatenate([ins, RNG.choice(base, 300),
                        _f32_keys(50, seed=13, lo=1.0, hi=3.0)])
    _kernel_parity(d, q)


@pytest.mark.kernel
def test_dynamic_kernel_parity_duplicates_across_tiers():
    base = _f32_keys(8_192, seed=14)
    d = DynamicRMI.build(jnp.asarray(base), eps=0.5, n_leaves=32,
                         kind="linear")
    dups = RNG.choice(base, 200, replace=False)       # re-insert base keys
    d.insert_batch(dups)
    q = np.concatenate([dups, RNG.choice(base, 300)])
    live = d.live_keys()
    assert live.size == base.size + dups.size         # multiset
    _kernel_parity(d, q)


@pytest.mark.kernel
def test_dynamic_kernel_parity_tombstoned_hits(lin_pool):
    base = _f32_keys(16_384, seed=15)
    d = DynamicRMI.build(jnp.asarray(base), pool=lin_pool, eps=0.9,
                         n_leaves=64, kind="linear")
    ins = np.setdiff1d(_f32_keys(3_000, seed=16), base)
    d.insert_batch(ins)                               # triggers rebuilds
    # tombstone a mix of base keys and still-buffered delta keys
    buffered = np.asarray(d.delta_keys)
    buffered = buffered[np.isfinite(buffered)]
    dels = np.concatenate([RNG.choice(base, 80, replace=False),
                           buffered[:20]])
    d.delete_batch(dels)
    q = np.concatenate([dels, RNG.choice(base, 300), RNG.choice(ins, 300)])
    _kernel_parity(d, q)


def test_post_rebuild_matches_fresh_build(lin_pool):
    """After Lemma 4.1 rebuilds, the dynamic index must answer exactly like
    a from-scratch build_rmi over the merged live keys."""
    base = _f32_keys(20_000, seed=17)
    d = DynamicRMI.build(jnp.asarray(base), pool=lin_pool, eps=0.9,
                         n_leaves=64, kind="linear")
    ins = np.setdiff1d(_f32_keys(4_000, seed=18), base)
    d.insert_batch(ins)
    assert d.rebuilds > 0
    live = d.live_keys()
    fresh = rmi.build_rmi(jnp.asarray(live), n_leaves=64, kind="linear",
                          pool=lin_pool)
    q = np.concatenate([RNG.choice(live, 1_000),
                        _f32_keys(100, seed=19, hi=2.0)])
    want = np.searchsorted(live, q, side="left")
    np.testing.assert_array_equal(
        np.asarray(rmi.lookup(fresh, jnp.asarray(q))), want)
    _, r = d.find(jnp.asarray(q))
    np.testing.assert_array_equal(np.asarray(r), want)
    _kernel_parity(d, q)


# ---------------------------------------------------------------------------
# Satellite: tier-1 guard — no per-key host loops on the jitted paths.
# ---------------------------------------------------------------------------
def test_insert_and_find_have_no_per_key_host_loops(monkeypatch):
    """insert_batch must be O(1) jit dispatches per batch (no np.insert /
    per-leaf Python loop) and find must be exactly one jitted call
    regardless of the query count."""
    base = _f32_keys(10_000, seed=20)
    d = DynamicRMI.build(jnp.asarray(base), eps=0.5, n_leaves=32,
                         kind="linear")

    def _boom(*a, **k):
        raise AssertionError("per-key host loop primitive called")
    monkeypatch.setattr(np, "insert", _boom)

    calls = {"find": 0, "merge": 0}
    orig_find = updates_mod._find_jit
    orig_fill = updates_mod._fill_delta_jit
    orig_clean = updates_mod._merge_delta_clean_jit
    monkeypatch.setattr(
        updates_mod, "_find_jit",
        lambda *a, **k: (calls.__setitem__("find", calls["find"] + 1),
                         orig_find(*a, **k))[1])
    monkeypatch.setattr(
        updates_mod, "_fill_delta_jit",
        lambda *a, **k: (calls.__setitem__("merge", calls["merge"] + 1),
                         orig_fill(*a, **k))[1])
    monkeypatch.setattr(
        updates_mod, "_merge_delta_clean_jit",
        lambda *a, **k: (calls.__setitem__("merge", calls["merge"] + 1),
                         orig_clean(*a, **k))[1])

    ins = np.setdiff1d(_f32_keys(2_000, seed=21), base)
    d.insert_batch(ins)                       # one merge, no np.insert
    assert calls["merge"] == 1
    for Q in (10, 10_000):                    # dispatch count is Q-invariant
        calls["find"] = 0
        q = RNG.choice(ins, Q)
        d.find(jnp.asarray(q))
        assert calls["find"] == 1
    # and the kernel path performs zero per-query host work: it is a single
    # jitted wrapper call (trace-counted via its module entry point)
    from repro.kernels import ops as kernel_ops
    kcalls = []
    orig_dyn = kernel_ops._dynamic_lookup_jit
    monkeypatch.setattr(kernel_ops, "_dynamic_lookup_jit",
                        lambda *a, **k: (kcalls.append(1),
                                         orig_dyn(*a, **k))[1])
    d.find(jnp.asarray(RNG.choice(ins, 5_000)), use_kernel=True)
    assert len(kcalls) == 1


# ---------------------------------------------------------------------------
# Serve/data integration rides the batched API.
# ---------------------------------------------------------------------------
def test_dynamic_page_table_batched_alloc_release():
    from repro.serve.kvcache import DynamicPageTable, PagedKVCache
    cache = PagedKVCache(n_pages=2048, page_size=16, n_kv_heads=2,
                         head_dim=8, n_layers=1)
    for r in range(4):
        cache.allocate_batch(r, range(64))
    t = DynamicPageTable.build(cache, eps=0.5, kind="linear")
    pages = t.allocate(4, range(32))
    f, pg = t.lookup(np.asarray([(4 << 22) | 7, (1 << 22) | 33],
                                np.float64))
    assert bool(f[0]) and bool(f[1])
    assert pg[0] == pages[7] and pg[1] == cache.table[(1, 33)]
    t.release(1)
    f, _ = t.lookup(np.asarray([(1 << 22) | 33], np.float64))
    assert not bool(f[0])
    # released pages are reusable and re-indexed through the batched API
    t.allocate(5, range(16))
    f, _ = t.lookup(np.asarray([(5 << 22) | 3], np.float64))
    assert bool(f[0])
    # empty allocation is a no-op (must not drain the free pool)
    free_before = len(cache.free)
    assert t.allocate(6, []).size == 0
    assert len(cache.free) == free_before
    # fully released table answers found=False without raising
    for r in (0, 2, 3, 4, 5):
        t.release(r)
    f, _ = t.lookup(np.asarray([(4 << 22) | 7], np.float64))
    assert not bool(f[0])


@pytest.mark.kernel
def test_dynamic_find_ref_parity():
    """ops.dynamic_find (seam-fixed kernel positions + tombstone algebra)
    must match ref.dynamic_find_ref (exact f32 searchsorted boundaries +
    the same algebra) bit-exactly on a churned index: valid kernel
    positions are pinned to the exact boundary by the seam verification."""
    from repro.kernels import ops as kernel_ops
    base = _f32_keys(12_288, seed=33)
    d = DynamicRMI.build(jnp.asarray(base), eps=0.6, n_leaves=32,
                         kind="linear")
    ins = np.setdiff1d(_f32_keys(2_000, seed=34), base)
    d.insert_batch(ins)
    d.delete_batch(np.concatenate([RNG.choice(base, 100, replace=False),
                                   ins[:40]]))
    q = jnp.asarray(np.concatenate(
        [RNG.choice(base, 400), RNG.choice(ins, 200),
         _f32_keys(64, seed=35, hi=2.0)]))
    idx = d.index
    root, mat, vec = idx.packed_tables()
    got_f, got_r = kernel_ops.dynamic_find(
        q, root, mat, vec, idx.keys, d.base_dead, d.base_psum,
        d.delta_keys, d.delta_dead, d.delta_psum, n_leaves=idx.n_leaves,
        route_n=d.route_n, root_kind=idx.root_kind, leaf_kind=idx.leaf_kind,
        iters=idx.search_iters)
    want_f, want_r = ref.dynamic_find_ref(
        q, idx.keys, d.base_dead, d.base_psum, d.delta_keys, d.delta_dead,
        d.delta_psum)
    np.testing.assert_array_equal(np.asarray(got_f), np.asarray(want_f))
    np.testing.assert_array_equal(np.asarray(got_r), np.asarray(want_r))


@pytest.mark.kernel
def test_dynamic_page_table_sharded():
    """DynamicPageTable rides the sharded dynamic index: a 1-device mesh in
    the default process exercises the full routed insert/delete/find path
    (multi-device meshes are covered by tests/test_sharded_dynamic.py)."""
    import jax
    from repro.serve.kvcache import DynamicPageTable, PagedKVCache
    cache = PagedKVCache(n_pages=1024, page_size=16, n_kv_heads=2,
                         head_dim=8, n_layers=1)
    for r in range(4):
        cache.allocate_batch(r, range(64))
    mesh = jax.make_mesh((1,), ("data",))
    t = DynamicPageTable.build(cache, mesh=mesh, eps=0.5, kind="linear")
    from repro.core.distributed import ShardedDynamicIndex
    assert isinstance(t.dyn, ShardedDynamicIndex)
    pages = t.allocate(4, range(32))
    f, pg = t.lookup(np.asarray([(4 << 22) | 7, (1 << 22) | 33],
                                np.float64))
    assert bool(f[0]) and bool(f[1])
    assert pg[0] == pages[7] and pg[1] == cache.table[(1, 33)]
    t.release(1)
    f, _ = t.lookup(np.asarray([(1 << 22) | 33], np.float64))
    assert not bool(f[0])
    t.allocate(5, range(16))
    f, _ = t.lookup(np.asarray([(5 << 22) | 3], np.float64))
    assert bool(f[0])


def test_empty_build_accepts_inserts():
    """An empty-built DynamicRMI (a sharded index's empty shard) serves
    found=False / rank 0, then absorbs inserts through the normal
    rebuild path."""
    d = DynamicRMI.build(jnp.asarray(np.zeros(0)), eps=0.5, n_leaves=16,
                         kind="linear")
    assert d.live_count == 0
    f, r = d.find(jnp.asarray([1.0, 100.0]))
    assert not np.asarray(f).any() and (np.asarray(r) == 0).all()
    ins = _f32_keys(300, seed=44)
    d.insert_batch(ins)
    _assert_find_exact(d, np.concatenate([ins[:100], [0.0, 2.0]]))
    d.delete_batch(ins[:10])
    _assert_find_exact(d, ins[:50])
    assert d.live_count == ins.size - 10


def _range_truth(d, lo, hi):
    live = d.live_keys()
    el = np.searchsorted(live, lo, side="left")
    return el, np.maximum(np.searchsorted(live, hi, side="right"), el)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_find_range_exact_under_churn(use_kernel):
    """Both find_range paths return (leftmost lo rank, rightmost hi rank)
    vs the flat live oracle across both tiers — duplicate runs included."""
    keys = _f32_keys(3000, seed=51, hi=1e6)
    d = DynamicRMI.build(jnp.asarray(keys), eps=0.7, n_leaves=64,
                         kind="linear")
    d.insert_batch(_f32_keys(500, seed=52, lo=1e5, hi=9e5))
    d.insert_batch(np.repeat(keys[100:110], 5))      # duplicate runs
    d.delete_batch(keys[400:460])
    live = d.live_keys()
    rng = np.random.default_rng(53)
    lo = rng.choice(live, 300)
    hi = (lo * (1 + rng.uniform(0, 0.05, 300))).astype(
        np.float32).astype(np.float64)
    lo[:10] = hi[:10] = np.repeat(keys[100:105], 2)  # run-point ranges
    el, eh = _range_truth(d, lo, hi)
    rl, rh = d.find_range(jnp.asarray(lo), jnp.asarray(hi),
                          use_kernel=use_kernel)
    np.testing.assert_array_equal(np.asarray(rl), el)
    np.testing.assert_array_equal(np.asarray(rh), eh)
    # gather_range materializes exactly live[rank_lo:rank_hi]
    for i, seg in zip(range(8), d.gather_range(rl[:8], rh[:8]), strict=True):
        np.testing.assert_array_equal(seg, live[el[i]:eh[i]])


@pytest.mark.parametrize("use_kernel", [False, True])
def test_find_range_degenerates(use_kernel):
    """Degenerate ranges come back empty (rank_lo == rank_hi) on both
    paths: lo > hi, fully out-of-range both sides, tombstoned lo == hi,
    and the n == 0 empty index."""
    keys = _f32_keys(1000, seed=61, hi=1e5)
    d = DynamicRMI.build(jnp.asarray(keys), eps=0.7, n_leaves=32,
                         kind="linear")
    d.delete_batch(keys[7:8])                        # tombstoned singleton
    live = d.live_keys()
    lo = np.asarray([keys[50], -1e9, live[-1] * 2, keys[7], keys[20]])
    hi = np.asarray([keys[10], -1e8, live[-1] * 4, keys[7], keys[20]])
    rl, rh = d.find_range(jnp.asarray(lo), jnp.asarray(hi),
                          use_kernel=use_kernel)
    rl, rh = np.asarray(rl), np.asarray(rh)
    el, eh = _range_truth(d, lo, hi)
    np.testing.assert_array_equal(rl, el)
    np.testing.assert_array_equal(rh, eh)
    assert (rl[:4] == rh[:4]).all()                  # all empty...
    assert rh[4] - rl[4] == 1                        # ...but live point hits
    assert all(s.size == 0 for s in d.gather_range(rl[:4], rh[:4]))

    empty = DynamicRMI.build(jnp.asarray(np.zeros(0)), eps=0.5,
                             n_leaves=16, kind="linear")
    rl, rh = empty.find_range(jnp.asarray([1.0]), jnp.asarray([2.0]),
                              use_kernel=use_kernel)
    assert int(rl[0]) == 0 and int(rh[0]) == 0


def test_indexed_dataset_locate_range(lin_pool):
    """Batch slicing through the dataset: ranges spanning shard boundaries
    stitch per-shard pieces in shard order and match the global oracle
    under churn; non-finite endpoints are rejected."""
    from repro.data.indexed_dataset import IndexedDataset
    ds = IndexedDataset.create(pool=lin_pool, eps=0.9, n_leaves=64)
    rng = np.random.default_rng(31)
    allk = _f32_keys(9000, seed=31, hi=3e5)
    chunks = np.array_split(allk, 3)
    for c in chunks:
        ds.add_shard(c)
    ds.delete_samples(1, rng.choice(chunks[1], 30, replace=False))
    glob = np.sort(np.concatenate(
        [ds.shards[s].dyn.live_keys() for s in range(3)]))
    lo = rng.choice(glob, 8)
    hi = (lo + rng.uniform(0, 1.5e5, 8)).astype(np.float32) \
        .astype(np.float64)
    lo = np.concatenate([lo, [4e5, -10.0, 100.0]])
    hi = np.concatenate([hi, [5e5, -5.0, 50.0]])     # oor-high / oor-low /
    res = ds.locate_range(lo, hi)                    # lo > hi
    for i, (a, b) in enumerate(zip(lo, hi, strict=True)):
        want = glob[(glob >= a) & (glob <= b)]
        got = np.concatenate([p for _, p in res[i]]) if res[i] \
            else np.zeros(0)
        np.testing.assert_array_equal(got, want, err_msg=f"range {i}")
        sids = [s for s, _ in res[i]]
        assert sids == sorted(sids)
    with pytest.raises(ValueError):
        ds.locate_range([np.inf], [1.0])
    with pytest.raises(ValueError):
        ds.locate_range([1.0, 2.0], [3.0])


def test_indexed_dataset_append_and_delete(lin_pool):
    from repro.data.indexed_dataset import IndexedDataset
    ds = IndexedDataset.create(pool=lin_pool, eps=0.9, n_leaves=64)
    rng = np.random.default_rng(23)
    for s in range(2):
        ds.add_shard(np.sort(rng.lognormal(0, 0.5, 20_000)) * 1e6 + s * 1e11)
    new = rng.lognormal(0, 0.5, 2_000) * 1e6 + 1e11
    ds.append_to_shard(1, new)
    q = rng.choice(new, 200)
    sid, off = ds.locate(q)
    assert (sid == 1).all()
    np.testing.assert_allclose(ds.shards[1].keys[off], q)
    ds.delete_samples(1, q[:50])
    sid, off = ds.locate(q[60:])
    np.testing.assert_allclose(ds.shards[1].keys[off], q[60:])
    # draining a shard completely must not crash boundary maintenance
    ds.delete_samples(1, ds.shards[1].keys)
    assert ds.shards[1].keys.size == 0


# ---------------------------------------------------------------------------
# Boundary-run shed primitives + delta flush (PR5: incremental migration).
# ---------------------------------------------------------------------------
def _churned_dyn(n=4000, seed=31, n_leaves=64, eps=0.7):
    """A DynamicRMI with live delta entries and tombstones in both tiers."""
    base = _f32_keys(n, seed=seed, lo=0.0, hi=1e6)
    extra = np.setdiff1d(_f32_keys(3 * n, seed=seed + 1, lo=0.0, hi=1e6),
                         base)
    d = DynamicRMI.build(jnp.asarray(base), eps=eps, n_leaves=n_leaves,
                         kind="linear")
    rng = np.random.default_rng(seed + 2)
    d.insert_batch(extra[:n // 4])
    live = np.sort(np.concatenate([base, extra[:n // 4]]))
    dels = rng.choice(live, n // 10, replace=False)
    d.delete_batch(dels)
    keep = np.ones(live.size, bool)
    keep[np.searchsorted(live, np.unique(dels))] = False
    return d, live[keep]


def _assert_find_matches(d, live, q):
    lo = np.searchsorted(live, q, side="left")
    hi = np.searchsorted(live, q, side="right")
    found, rank = d.find(jnp.asarray(q), use_kernel=False)
    np.testing.assert_array_equal(np.asarray(rank), lo)
    np.testing.assert_array_equal(np.asarray(found), hi > lo)


@pytest.mark.parametrize("frac", [0.3, 0.7])
def test_shed_suffix_truncates_both_tiers(frac):
    d, live = _churned_dyn()
    split = float(live[int(live.size * frac)])
    before_leaves = d.index.leaves
    d.shed_suffix(split)
    kept = live[live <= split]
    np.testing.assert_array_equal(d.live_keys(), kept)
    assert d.live_count == kept.size
    # survivor positions unchanged: models untouched, packed root cache too
    assert d.index.leaves is before_leaves
    rng = np.random.default_rng(9)
    q = np.concatenate([rng.choice(kept, 300), [split, kept[0], kept[-1]]])
    _assert_find_matches(d, kept, q)


@pytest.mark.parametrize("frac", [0.3, 0.7])
def test_shed_prefix_shifts_intercepts_exactly(frac):
    d, live = _churned_dyn(seed=47)
    split = float(live[int(live.size * frac)])
    iters_before = d.index.search_iters
    d.shed_prefix(split)
    kept = live[live > split]
    np.testing.assert_array_equal(d.live_keys(), kept)
    assert d.live_count == kept.size
    # the uniform shift is exact: bounds (hence the clamped depth) keep
    assert d.index.search_iters == iters_before
    rng = np.random.default_rng(9)
    q = np.concatenate([rng.choice(kept, 300), [split, kept[0], kept[-1]]])
    _assert_find_matches(d, kept, q)


def test_shed_roundtrip_donor_receiver():
    """A full donor/receiver hand-off: suffix-shed keys absorbed by an
    adjacent structure keep both sides exact (the sharded _migrate path,
    minus the mesh)."""
    d, live = _churned_dyn(seed=53)
    cut = live[int(live.size * 0.6)]
    moved = live[live > cut]
    recv_base = _f32_keys(500, seed=99, lo=2e6, hi=3e6)
    recv = DynamicRMI.build(jnp.asarray(recv_base), eps=0.7, n_leaves=32,
                            kind="linear")
    d.shed_suffix(float(cut))
    recv.insert_batch(moved)
    recv_live = np.sort(np.concatenate([recv_base, moved]))
    rng = np.random.default_rng(3)
    _assert_find_matches(d, live[live <= cut],
                         rng.choice(live[live <= cut], 200))
    _assert_find_matches(recv, recv_live, np.concatenate(
        [rng.choice(recv_live, 300), [moved[0], moved[-1], recv_base[0]]]))


def test_flush_delta_merges_and_localizes():
    d, live = _churned_dyn(seed=61)
    # a small batch after the bulk churn stays buffered (fresh budgets)
    extra = np.setdiff1d(_f32_keys(12_000, seed=62, lo=0.0, hi=1e6), live)
    d.insert_batch(extra[:200])
    live = np.sort(np.concatenate([live, extra[:200]]))
    assert d.delta_live > 0
    d.flush_delta()
    assert d.delta_live == 0 and d.delta_dead_count == 0
    np.testing.assert_array_equal(d.live_keys(), live)
    rng = np.random.default_rng(5)
    _assert_find_matches(d, live, rng.choice(live, 300))
    # headroom is restored for the flushed leaves (fresh Lemma 4.1 budgets)
    assert d.insertion_headroom > 0


def test_maintenance_stats_surface():
    from repro.serve.kvcache import DynamicPageTable, PagedKVCache
    cache = PagedKVCache(n_pages=64, page_size=8, n_kv_heads=1, head_dim=4,
                         n_layers=1)
    cache.allocate_batch(0, range(16))
    table = DynamicPageTable.build(cache, n_leaves=8)
    table.allocate(1, range(8))
    table.release(0)
    stats = table.maintenance_stats()
    assert stats["sharded"] is False
    assert stats["live"] == 8
    assert stats["rebuilds"] >= 0 and stats["buffered"] >= 0
