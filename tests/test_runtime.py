"""Runtime substrate tests: checkpointing (atomic commit, checksum verify,
reshard-on-restore), elastic controller (fake clock), optimizer algebra,
microbatch-equivalence of the train step."""
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro  # noqa: F401
from repro.configs.reduced import reduced
from repro.launch.mesh import make_smoke_mesh
from repro.models import model as M
from repro.train import optimizer
from repro.train.checkpoint import Checkpointer
from repro.train.elastic import ElasticController
from repro.train.step import make_train_step


def test_checkpoint_roundtrip_and_corruption():
    cfg = reduced("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(10, {"params": params}, blocking=True)
        assert ck.latest_step() == 10
        template = {"params": jax.tree.map(jnp.zeros_like, params)}
        restored = ck.restore(10, template)
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(restored["params"]),
                        strict=True):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # corrupt one shard -> checksum must catch it
        step_dir = os.path.join(d, "step_00000010")
        victim = next(f for f in os.listdir(step_dir) if f.endswith(".npy"))
        with open(os.path.join(step_dir, victim), "r+b") as f:
            f.seek(128)
            f.write(b"\xde\xad\xbe\xef")
        with pytest.raises(IOError):
            ck.restore(10, template)


def test_checkpoint_gc_and_async():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        x = {"w": jnp.arange(8.0)}
        for s in (1, 2, 3, 4):
            ck.save(s, x)
        ck.wait()
        steps = sorted(os.listdir(d))
        assert steps == ["step_00000003", "step_00000004"], steps


def test_elastic_controller_policies():
    t = [0.0]
    ctl = ElasticController(n_hosts=4, heartbeat_timeout=10.0,
                            clock=lambda: t[0])
    # normal heartbeats
    for h in range(4):
        for _ in range(6):
            ctl.heartbeat(h, step_time=1.0)
    assert ctl.plan()["action"] == "none"
    # one straggler: 3x median step time
    for _ in range(6):
        ctl.heartbeat(3, step_time=3.5)
    plan = ctl.plan()
    assert plan["action"] == "reassign_data" and plan["hosts"] == [3]
    # host 2 dies (misses heartbeats past the deadline)
    t[0] = 20.0
    for h in (0, 1, 3):
        ctl.heartbeat(h, step_time=1.0)
    t[0] = 29.0   # 2's last beat was t=0 (>timeout); others beat at t=20
    plan = ctl.plan()
    assert plan["action"] == "remesh" and plan["survivors"] == 3
    assert ctl.generation == 1


def test_elastic_rejoin_and_stale_stragglers():
    """A removed host that resumes heartbeats re-registers and surfaces a
    remesh (never a silent no-op); a timed-out host's stale step times
    drop out of the straggler computation."""
    t = [0.0]
    ctl = ElasticController(n_hosts=4, heartbeat_timeout=10.0,
                            clock=lambda: t[0])
    for h in range(4):
        for _ in range(6):
            ctl.heartbeat(h, step_time=1.0)
    # host 2 dies
    t[0] = 20.0
    for h in (0, 1, 3):
        ctl.heartbeat(h, step_time=1.0)
    plan = ctl.plan()
    assert plan["action"] == "remesh" and plan["survivors"] == 3
    assert ctl.generation == 1 and plan["rejoined"] == []
    # ...and comes back: the rejoin is a topology change like a loss
    ctl.heartbeat(2, step_time=1.0)
    plan = ctl.plan()
    assert plan["action"] == "remesh" and plan["survivors"] == 4
    assert plan["rejoined"] == [2]
    assert ctl.generation == 2
    assert ctl.plan()["action"] == "none"       # steady state again
    # a host that stops heartbeating while holding the worst step times
    # must not land in (or skew) the straggler set
    for _ in range(20):
        ctl.heartbeat(0, step_time=9.0)
    t[0] = 40.0
    for h in (1, 2, 3):
        ctl.heartbeat(h, step_time=1.0)
    assert ctl.stragglers() == []               # 0 is a loss, not a straggler
    assert ctl.dead_hosts() == [0]


def test_checkpoint_write_failure_surfaces_and_retries():
    """An async write failure re-raises from wait(); transient OSErrors are
    absorbed by the retry knob and counted."""
    import faultinject as fi
    x = {"w": jnp.arange(8.0)}
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        with fi.FaultInjector(fail_always=True):
            ck.save(1, x)
            with pytest.raises(IOError):
                ck.wait()
        assert ck.latest_step() is None
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, retries=2, backoff=0.001)
        with fi.FaultInjector(transient_errors=2):
            ck.save(1, x, blocking=True)
        assert ck.write_retries == 2
        assert ck.latest_step() == 1


def test_microbatch_equivalence():
    """grad-accumulated step == single-batch step (same loss, ~same params)."""
    cfg = reduced("qwen3-4b")
    mesh = make_smoke_mesh()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 4, 32
    inputs = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    outs = []
    for mb in (1, 2):
        step, _ = make_train_step(cfg, mesh, lr=1e-2, donate=False,
                                  microbatch=mb)
        p2, _, _, m = step(params, optimizer.init(params), jnp.zeros(()),
                           inputs, labels, pos)
        outs.append((float(m["loss"]), p2))
    assert abs(outs[0][0] - outs[1][0]) < 2e-2, (outs[0][0], outs[1][0])
    for a, b in zip(jax.tree.leaves(outs[0][1]), jax.tree.leaves(outs[1][1]),
                    strict=True):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=3e-2)


def test_checkpoint_reshard_restore():
    """Restore onto a mesh with shardings (smoke mesh: trivially resharded)."""
    cfg = reduced("yi-9b")
    mesh = make_smoke_mesh()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    specs = M.param_specs(cfg)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(5, {"params": params}, blocking=True)
        template = {"params": jax.tree.map(jnp.zeros_like, params)}
        restored = ck.restore(5, template, mesh=mesh,
                              specs={"params": specs})
        leaf = jax.tree.leaves(restored["params"])[0]
        assert leaf.sharding is not None
