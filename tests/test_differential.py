"""Property-based differential suite for the lookup kernels.

Three-way differential per generated case:

  rmrt_lookup_pallas (interpret)  ==  kernels.ref.rmrt_lookup_ref   bit-exact
  ops.rmrt_lookup (seam-fixed)    ==  np.searchsorted(keys, q)      exact

over random key distributions (uniform / lognormal / zipf / duplicate-
heavy), storage dtypes (f32 / f32-exact f64), tree shapes (leaf_cap,
fanout, key-tile size) and query mixes (members, midpoints, duplicates,
out-of-range, boundary keys).  The same harness generalizes over the
RMI (jnp + fused kernel paths), PGM, and RS builders.

The case generator is seeded numpy, so the full sweep (>= 200 generated
cases) runs without hypothesis; when hypothesis is importable the same
case body also runs under its shrinking explorer.  All keys/queries are
f32-exact by construction so the kernels' f32 left boundary coincides
with the f64 searchsorted truth.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro  # noqa: F401
from repro.core import pgm, radix_spline, rmi, rmrt
from repro.kernels import lookup as lookup_mod
from repro.kernels import ops, ref
from repro.kernels.lookup import lookup_pallas, rmrt_lookup_pallas

pytestmark = pytest.mark.kernel

# jit the raw kernel/oracle legs so repeated case configurations hit the
# trace cache (the ops wrapper is already jitted; eager pallas interpret
# re-traces every call).
_RMRT_STATICS = ("fanout", "depth", "kind", "iters", "tile")
_rmrt_kernel = jax.jit(rmrt_lookup_pallas, static_argnames=_RMRT_STATICS)
_rmrt_oracle = jax.jit(ref.rmrt_lookup_ref, static_argnames=_RMRT_STATICS)

N_SWEEP = 208            # rmrt differential cases (acceptance floor: 200)
N_BUILDERS = 12          # seeds for the RMI/PGM/RS builder harness
Q = 512                  # queries per case (fixed: one jit cache entry)
SIZES = (1024, 2048, 4096)
DISTS = ("uniform", "lognormal", "zipf", "dup-heavy")


def _gen_keys(rng, dist: str, size: int) -> np.ndarray:
    """Sorted, f32-exact f64 keys of exactly ``size`` entries (duplicates
    allowed — the dup-heavy distribution is built from a tiny value set)."""
    if dist == "uniform":
        raw = rng.uniform(0.001, 1e6, 2 * size)
    elif dist == "lognormal":
        raw = rng.lognormal(0, 1.2, 2 * size) * 1e3
    elif dist == "zipf":
        raw = rng.zipf(1.6, 2 * size).astype(np.float64) \
            + rng.random(2 * size)
    else:                                   # dup-heavy: ~size/64 uniques
        raw = rng.choice(rng.uniform(0.1, 1e5, max(size // 64, 4)), 2 * size)
    u = np.unique(raw.astype(np.float32)).astype(np.float64)
    if u.size >= size:
        return np.sort(rng.choice(u, size, replace=False))
    return np.sort(np.resize(u, size))      # cyclic tile -> duplicate runs


def _gen_queries(rng, keys: np.ndarray) -> np.ndarray:
    """Mixed query batch (exactly Q, f32-exact): members, midpoints of
    adjacent keys, repeated members, out-of-range, and both boundaries."""
    n_mem = Q - 128
    members = rng.choice(keys, n_mem)
    i = rng.integers(0, keys.size - 1, 96)
    mids = ((keys[i] + keys[i + 1]) / 2).astype(np.float32)
    oor = np.asarray([0.0, -keys[-1], keys[0] / 2, keys[-1] * 2,
                      keys[-1] * 16, 1e30], np.float32)
    edge = np.asarray([keys[0], keys[-1]], np.float32)
    rest = rng.choice(keys, 128 - mids.size - oor.size - edge.size)
    q = np.concatenate([members, mids.astype(np.float64),
                        oor.astype(np.float64), edge.astype(np.float64),
                        rest])
    return rng.permutation(q)[:Q]


def _case_params(seed: int):
    """Deterministic case configuration from the seed (shapes drawn from
    small sets so the jit cache is warm after the first few cases)."""
    rng = np.random.default_rng(seed)
    return dict(
        rng=rng,
        dist=DISTS[seed % len(DISTS)],
        size=SIZES[(seed // len(DISTS)) % len(SIZES)],
        leaf_cap=(128, 512)[seed % 2],
        fanout=(8, 16)[(seed // 2) % 2],
        tile=1024 if seed % 5 == 0 else None,   # exercise multi-tile merge
        f32_storage=seed % 3 == 0,              # feed f32 arrays to the ops
    )


def run_rmrt_case(seed: int) -> None:
    """One generated differential case: build an RMRT, assert
    kernel == oracle (bit-exact) and seam-fixed kernel == searchsorted."""
    p = _case_params(seed)
    keys = _gen_keys(p["rng"], p["dist"], p["size"])
    q = _gen_queries(p["rng"], keys)
    store = np.float32 if p["f32_storage"] else np.float64
    kj = jnp.asarray(keys.astype(store))
    qj = jnp.asarray(q.astype(store))

    idx = rmrt.build_rmrt(jnp.asarray(keys), leaf_cap=p["leaf_cap"],
                          fanout=p["fanout"], kind="linear")
    assert idx.f32_exact
    mat, vec = idx.packed_tables()
    kw = dict(fanout=idx.fanout, depth=idx.depth, kind=idx.kind,
              iters=idx.search_iters, tile=p["tile"])

    got = _rmrt_kernel(qj, mat, vec, kj, **kw)
    want = _rmrt_oracle(qj, mat, vec, kj, **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                  err_msg=f"kernel!=oracle seed={seed}")

    fixed = ops.rmrt_lookup(qj, mat, vec, kj, **kw)
    truth = np.searchsorted(keys, q, side="left")
    np.testing.assert_array_equal(np.asarray(fixed), truth,
                                  err_msg=f"kernel!=searchsorted seed={seed}")


def test_rmrt_differential_quick():
    """One full cycle of the case generator (every distribution x size
    combo) — the quick-tier slice of the sweep below."""
    for seed in range(len(DISTS) * len(SIZES) * 2):
        run_rmrt_case(seed)


@pytest.mark.slow
def test_rmrt_differential_sweep():
    """The full generated sweep: N_SWEEP cases across all distributions,
    dtypes, tree shapes, and query mixes (acceptance floor: >= 200)."""
    for seed in range(N_SWEEP):
        run_rmrt_case(seed)


@pytest.mark.parametrize("seed", [3, 16, 45, 77])
def test_rmrt_differential_mlp(seed):
    """MLP node models ride the same packed tables: kernel == oracle
    bit-exact, seam-fixed kernel == searchsorted (smaller case count —
    the per-level MLP training dominates the runtime)."""
    rng = np.random.default_rng(seed)
    keys = _gen_keys(rng, DISTS[seed % len(DISTS)], 2048)
    q = _gen_queries(rng, keys)
    idx = rmrt.build_rmrt(jnp.asarray(keys), leaf_cap=512, fanout=8,
                          kind="mlp", train_steps=25)
    mat, vec = idx.packed_tables()
    kw = dict(fanout=idx.fanout, depth=idx.depth, kind=idx.kind,
              iters=idx.search_iters)
    got = _rmrt_kernel(jnp.asarray(q), mat, vec, jnp.asarray(keys), **kw)
    want = _rmrt_oracle(jnp.asarray(q), mat, vec, jnp.asarray(keys), **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    fixed = ops.rmrt_lookup(jnp.asarray(q), mat, vec, jnp.asarray(keys),
                            **kw)
    np.testing.assert_array_equal(np.asarray(fixed),
                                  np.searchsorted(keys, q, side="left"))


# ---------------------------------------------------------------------------
# Range lookups: the fused two-endpoint kernel against its independent
# oracle (bit-exact) and the seam-fixed ops path against the flat live
# searchsorted truth — under churn, so both tiers and the live-rank
# algebra are exercised.  rank_lo is the leftmost rank of lo, rank_hi the
# rightmost rank of hi (duplicate runs included), clamped so degenerate
# ranges (lo > hi, out-of-range) come back empty.
# ---------------------------------------------------------------------------
_RANGE_STATICS = ("n_leaves", "route_n", "root_kind", "leaf_kind", "iters",
                  "tile")
_range_kernel = jax.jit(lookup_mod.dynamic_range_pallas,
                        static_argnames=_RANGE_STATICS + ("interpret",))
_range_oracle = jax.jit(ref.dynamic_range_ref,
                        static_argnames=_RANGE_STATICS)


def _gen_ranges(rng, keys: np.ndarray):
    """(lo, hi) endpoint batches (exactly Q pairs, f32-exact): member and
    midpoint endpoints, duplicate-run-spanning, degenerate lo > hi, and
    fully out-of-range on both sides."""
    lo = _gen_queries(rng, keys)
    span = rng.choice([0.0, 1.0, 16.0], Q) * np.abs(lo) * 0.01
    hi = (lo + span).astype(np.float32).astype(np.float64)
    flip = rng.random(Q) < 0.15                     # degenerate lo > hi
    lo2 = np.where(flip, hi + np.abs(lo) * 0.01, lo)
    return lo2.astype(np.float32).astype(np.float64), hi


def run_range_case(seed: int) -> None:
    """One generated range-differential case: churned DynamicRMI, assert
    range kernel == range oracle (bit-exact) and both find_range paths ==
    flat searchsorted truth over the live set."""
    from repro.core.updates import DynamicRMI

    p = _case_params(seed)
    keys = _gen_keys(p["rng"], p["dist"], p["size"])
    dyn = DynamicRMI.build(jnp.asarray(np.unique(keys)), n_leaves=64,
                           kind="linear")
    uniq = np.unique(keys)
    extra = _gen_keys(p["rng"], p["dist"], p["size"] // 4)
    dyn.insert_batch(jnp.asarray(np.setdiff1d(extra, keys)))
    dyn.delete_batch(jnp.asarray(                   # dup-heavy: few uniques
        p["rng"].choice(uniq, min(p["size"] // 8, uniq.size // 2),
                        replace=False)))
    live = np.asarray(dyn.live_keys())
    lo, hi = _gen_ranges(p["rng"], live)
    el = np.searchsorted(live, lo, side="left")
    eh = np.maximum(np.searchsorted(live, hi, side="right"), el)

    idx = dyn.index
    root, mat, vec = idx.packed_tables()
    kw = dict(n_leaves=idx.n_leaves, route_n=dyn.route_n,
              root_kind=idx.root_kind, leaf_kind=idx.leaf_kind,
              iters=idx.search_iters, tile=p["tile"])
    ql, qh = jnp.asarray(lo), jnp.asarray(hi)
    got = _range_kernel(ql, qh, root, mat, vec, idx.keys, dyn.delta_keys,
                        interpret=True, **kw)
    want = _range_oracle(ql, qh, root, mat, vec, idx.keys, dyn.delta_keys,
                         **kw)
    for g, w, leg in zip(got, want, ("blo", "bhi", "dlo", "dhi"),
                         strict=True):
        np.testing.assert_array_equal(
            np.asarray(g), np.asarray(w),
            err_msg=f"kernel!={leg}-oracle seed={seed}")

    for uk in (True, False):
        rl, rh = dyn.find_range(ql, qh, use_kernel=uk)
        np.testing.assert_array_equal(
            np.asarray(rl), el, err_msg=f"rank_lo seed={seed} uk={uk}")
        np.testing.assert_array_equal(
            np.asarray(rh), eh, err_msg=f"rank_hi seed={seed} uk={uk}")


def test_range_differential_quick():
    """One full cycle of the generator (every distribution x size combo,
    churned) — the quick-tier slice of the range sweep."""
    for seed in range(len(DISTS) * len(SIZES)):
        run_range_case(seed)


@pytest.mark.slow
def test_range_differential_sweep():
    """The full generated range sweep across distributions, tree shapes,
    and endpoint mixes."""
    for seed in range(N_SWEEP // 4):
        run_range_case(seed)


def _check_builder(name: str, keys: np.ndarray, q: np.ndarray) -> None:
    kj, qj = jnp.asarray(keys), jnp.asarray(q)
    truth = np.searchsorted(keys, q, side="left")
    if name == "rmi-jnp":
        idx = rmi.build_rmi(kj, n_leaves=64, kind="linear")
        got = rmi.lookup(idx, qj)
    elif name == "rmi-kernel":
        idx = rmi.build_rmi(kj, n_leaves=64, kind="linear")
        got = rmi.lookup(idx, qj, use_kernel=True)
        # the RMI kernel must also match ITS oracle bit-exactly
        root, mat, vec = idx.packed_tables()
        kw = dict(n_leaves=idx.n_leaves, root_kind=idx.root_kind,
                  leaf_kind=idx.leaf_kind, iters=idx.search_iters)
        rk = lookup_pallas(qj, root, mat, vec, kj, **kw)
        want = ref.lookup_ref(qj, root, mat, vec, kj, **kw)
        np.testing.assert_array_equal(np.asarray(rk), np.asarray(want))
    elif name == "pgm":
        got = pgm.lookup(pgm.build_pgm(kj, eps=32), qj)
    else:
        got = radix_spline.lookup(radix_spline.build_rs(kj, eps=16), qj)
    np.testing.assert_array_equal(np.asarray(got), truth, err_msg=name)


@pytest.mark.parametrize("builder", ["rmi-jnp", "rmi-kernel", "pgm", "rs"])
def test_builder_differential_sweep(builder):
    """The same generated-case harness over the other static index
    builders: every lookup path answers the brute-force truth exactly."""
    for seed in range(N_BUILDERS):
        p = _case_params(seed * 31 + 7)
        keys = _gen_keys(p["rng"], p["dist"], p["size"])
        q = _gen_queries(p["rng"], keys)
        _check_builder(builder, keys, q)


# ---------------------------------------------------------------------------
# Hypothesis wrapper: the same case body under the shrinking explorer when
# hypothesis is importable (the container image may not ship it; the seeded
# sweep above carries the coverage either way).
# ---------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2 ** 20))
    def test_rmrt_differential_hypothesis(seed):
        run_rmrt_case(seed)
