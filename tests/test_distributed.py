"""Distributed index service: Pallas-kernel vs jnp per-shard path parity
on 1/2/4/8-device CPU meshes, with ragged shard sizes and out-of-range /
shard-seam queries.

Each mesh size runs in a subprocess (device count locks at first jax
init, like tests/test_multidevice.py).  The kernel path runs the fused
lookup (in-kernel routing + clamped tiled search + sparse seam fix) per
shard inside ``shard_map``; the jnp path is the clamped ``verified_search``
— both must return identical global ranks, and those ranks must match the
brute-force searchsorted truth on the concatenated live keys.
"""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.kernel

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(ndev)d"
import numpy as np, jax, jax.numpy as jnp
import repro
from repro.core import distributed

ndev = %(ndev)d
rng = np.random.default_rng(11 + ndev)
# ragged: not a multiple of any tested mesh size (every shard non-empty)
n = 30_000 + 13
keys = np.unique(np.sort(rng.lognormal(0, 0.9, n) * 1e3)
                 .astype(np.float32)).astype(np.float64)
mesh = jax.make_mesh((ndev,), ("data",))
idx = distributed.build_sharded(jnp.asarray(keys), mesh, axis="data",
                                n_leaves=128)
assert idx.f32_exact
cap = idx.keys.shape[1]
valid = np.asarray(idx.valid)
assert (valid > 0).all() and valid.sum() == keys.size

Q = 2048
splits = np.asarray(idx.splits)
inside = rng.choice(keys, Q - 2 * splits.size - 8)
# seams: the split boundaries themselves and their f32 neighbours (the
# owning shard changes exactly here), plus out-of-range extremes
seam = np.concatenate([splits, np.nextafter(splits.astype(np.float32),
                                            np.float32(np.inf))
                       .astype(np.float64)]) if splits.size else np.zeros(0)
oor = np.asarray([0.0, -1e9, keys[0] / 2, keys[-1] * 2, 1e30,
                  keys[0], keys[-1], keys[-1] * 16], np.float32)
q = np.concatenate([inside, seam, oor.astype(np.float64)])[:Q]
q = rng.permutation(q)
qj = jnp.asarray(q)

fn_jnp = distributed.make_lookup_fn(idx, use_kernel=False)
fn_krn = distributed.make_lookup_fn(idx, use_kernel=True)
r_jnp = np.asarray(fn_jnp(qj))
r_krn = np.asarray(fn_krn(qj))
np.testing.assert_array_equal(r_jnp, r_krn)      # kernel == jnp, all meshes

# globalized shard ranks decode to the exact brute-force positions
shard, local = r_jnp // cap, r_jnp %% cap
glob = np.concatenate([[0], np.cumsum(valid)])[shard] + local
np.testing.assert_array_equal(glob, np.searchsorted(keys, q, side="left"))

# capacity-bucketed variant: answered slots must agree across paths
fk = distributed.make_lookup_fn(idx, capacity_factor=2.0, use_kernel=True)
fj = distributed.make_lookup_fn(idx, capacity_factor=2.0, use_kernel=False)
a, b = np.asarray(fk(qj)), np.asarray(fj(qj))
np.testing.assert_array_equal(a, b)
answered = a >= 0
assert answered.mean() > 0.5
np.testing.assert_array_equal(a[answered], r_jnp[answered])
print("DIST_OK ndev=%(ndev)d")
"""


def _run(ndev: int, timeout: int = 900):
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT % {"ndev": ndev}],
                          env=env, capture_output=True, text=True,
                          timeout=timeout)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert f"DIST_OK ndev={ndev}" in proc.stdout, proc.stdout[-2000:]


@pytest.mark.parametrize("ndev", [1, 2])
def test_distributed_kernel_parity_small_mesh(ndev):
    _run(ndev)


@pytest.mark.slow
@pytest.mark.parametrize("ndev", [4, 8])
def test_distributed_kernel_parity_large_mesh(ndev):
    _run(ndev)
