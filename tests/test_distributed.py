"""Distributed index service: Pallas-kernel vs jnp per-shard path parity
on 1/2/4/8-device CPU meshes, with ragged shard sizes and out-of-range /
shard-seam queries.

Each mesh size runs in a subprocess (device count locks at first jax
init, like tests/test_multidevice.py).  The kernel path runs the fused
lookup (in-kernel routing + clamped tiled search + sparse seam fix) per
shard inside ``shard_map``; the jnp path is the clamped ``verified_search``
— both must return identical global ranks, and those ranks must match the
brute-force searchsorted truth on the concatenated live keys.
"""
import pytest

from conftest import run_mesh_script

pytestmark = pytest.mark.kernel

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(ndev)d"
import numpy as np, jax, jax.numpy as jnp
import repro
from repro.core import distributed

ndev = %(ndev)d
rng = np.random.default_rng(11 + ndev)
# ragged: not a multiple of any tested mesh size (every shard non-empty)
n = 30_000 + 13
keys = np.unique(np.sort(rng.lognormal(0, 0.9, n) * 1e3)
                 .astype(np.float32)).astype(np.float64)
mesh = jax.make_mesh((ndev,), ("data",))
idx = distributed.build_sharded(jnp.asarray(keys), mesh, axis="data",
                                n_leaves=128)
assert idx.f32_exact
cap = idx.keys.shape[1]
valid = np.asarray(idx.valid)
assert (valid > 0).all() and valid.sum() == keys.size

Q = 2048
splits = np.asarray(idx.splits)
inside = rng.choice(keys, Q - 2 * splits.size - 8)
# seams: the split boundaries themselves and their f32 neighbours (the
# owning shard changes exactly here), plus out-of-range extremes
seam = np.concatenate([splits, np.nextafter(splits.astype(np.float32),
                                            np.float32(np.inf))
                       .astype(np.float64)]) if splits.size else np.zeros(0)
oor = np.asarray([0.0, -1e9, keys[0] / 2, keys[-1] * 2, 1e30,
                  keys[0], keys[-1], keys[-1] * 16], np.float32)
q = np.concatenate([inside, seam, oor.astype(np.float64)])[:Q]
q = rng.permutation(q)
qj = jnp.asarray(q)

fn_jnp = distributed.make_lookup_fn(idx, use_kernel=False)
fn_krn = distributed.make_lookup_fn(idx, use_kernel=True)
r_jnp = np.asarray(fn_jnp(qj))
r_krn = np.asarray(fn_krn(qj))
np.testing.assert_array_equal(r_jnp, r_krn)      # kernel == jnp, all meshes

# globalized shard ranks decode to the exact brute-force positions
shard, local = r_jnp // cap, r_jnp %% cap
glob = np.concatenate([[0], np.cumsum(valid)])[shard] + local
np.testing.assert_array_equal(glob, np.searchsorted(keys, q, side="left"))

# capacity-bucketed variant: answered slots must agree across paths
fk = distributed.make_lookup_fn(idx, capacity_factor=2.0, use_kernel=True)
fj = distributed.make_lookup_fn(idx, capacity_factor=2.0, use_kernel=False)
a, b = np.asarray(fk(qj)), np.asarray(fj(qj))
np.testing.assert_array_equal(a, b)
answered = a >= 0
assert answered.mean() > 0.5
np.testing.assert_array_equal(a[answered], r_jnp[answered])
print("DIST_OK ndev=%(ndev)d")
"""


def _run(ndev: int):
    run_mesh_script(_SCRIPT % {"ndev": ndev}, f"DIST_OK ndev={ndev}")


@pytest.mark.parametrize("ndev", [1, 2])
def test_distributed_kernel_parity_small_mesh(ndev):
    _run(ndev)


@pytest.mark.slow
@pytest.mark.parametrize("ndev", [4, 8])
def test_distributed_kernel_parity_large_mesh(ndev):
    _run(ndev)


_EDGE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(ndev)d"
import numpy as np, jax, jax.numpy as jnp
import repro
from repro.core import distributed

ndev = %(ndev)d
rng = np.random.default_rng(51 + ndev)
mesh = jax.make_mesh((ndev,), ("data",))

def decode_check(idx, keys, q):
    cap = idx.keys.shape[1]
    valid = np.asarray(idx.valid)
    assert valid.sum() == keys.size
    for uk in (False, True):
        fn = distributed.make_lookup_fn(idx, use_kernel=uk)
        r = np.asarray(fn(jnp.asarray(q)))
        shard, local = r // cap, r %% cap
        glob = np.concatenate([[0], np.cumsum(valid)])[shard] + local
        np.testing.assert_array_equal(
            glob, np.searchsorted(keys, q, side="left"),
            err_msg="use_kernel=%%s" %% uk)

# ---- empty shards: n < n_shards and n barely above it -----------------
for n in (3, ndev + 1):
    keys = np.unique(rng.uniform(1.0, 1e5, n).astype(np.float32)) \
        .astype(np.float64)
    idx = distributed.build_sharded(jnp.asarray(keys), mesh, n_leaves=16)
    splits = np.asarray(idx.splits)
    assert (np.diff(splits) >= 0).all(), "splits must stay monotone"
    q = np.concatenate([keys, [0.0, keys[0] / 2, keys[-1] * 2],
                        (keys[:-1] + keys[1:]) / 2])
    q = np.resize(q, -(-q.size // ndev) * ndev)
    decode_check(idx, keys, q)

# ---- seam duplicates: equal-key runs longer than a balanced shard -----
vals = np.unique(rng.uniform(0, 1e5, 29).astype(np.float32)) \
    .astype(np.float64)
keys = np.sort(rng.choice(vals, 16_000))
idx = distributed.build_sharded(jnp.asarray(keys), mesh, n_leaves=32)
valid = np.asarray(idx.valid)
splits = np.asarray(idx.splits)
starts = np.concatenate([[0], np.cumsum(valid)])
for s in range(ndev - 1):       # no run straddles a seam: strict inequality
    if valid[s + 1]:
        assert keys[starts[s + 1]] > splits[s], (s, keys[starts[s + 1]])
q = np.concatenate([vals, rng.choice(keys, 1000),
                    [keys[0] - 1.0, keys[-1] + 1.0]])
q = rng.permutation(np.resize(q, -(-q.size // ndev) * ndev))
decode_check(idx, keys, q)      # duplicated keys: global leftmost rank
print("EDGE_OK ndev=%(ndev)d")
"""


def _run_edge(ndev: int):
    run_mesh_script(_EDGE_SCRIPT % {"ndev": ndev}, f"EDGE_OK ndev={ndev}")


@pytest.mark.parametrize("ndev", [2])
def test_build_sharded_empty_shards_and_seam_duplicates(ndev):
    """Regression: build_sharded with empty shards (n < n_shards) and
    equal-key runs straddling naive equal-count boundaries — splits snap to
    run starts, stay monotone, and every query answers the global leftmost
    searchsorted rank on both lookup paths."""
    _run_edge(ndev)


@pytest.mark.slow
@pytest.mark.parametrize("ndev", [1, 4, 8])
def test_build_sharded_edge_meshes(ndev):
    _run_edge(ndev)


_SEAM_SPARSE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, jax.numpy as jnp
import repro
from repro.kernels import ops
from repro.core import distributed, rmi as rmi_mod

# Spy on both seam-verification layers: record every per-shard miss count
# so the test can pin that the +inf exchange pads (masked to a member key,
# 0.0 on an empty shard) never blow the sparse budget — the pre-PR4 bug
# demoted EVERY lookup to the dense full re-search whenever any shard was
# empty, because a batch of raw +inf pads always fails the left-boundary
# seam check.
kernel_bad, jnp_bad = [], []

orig_fix = ops._seam_fix
def spy_fix(r, kf, qf, seam_budget):
    n = kf.shape[0]
    rc = jnp.clip(r, 0, n - 1)
    valid = ((r == 0) | (kf[jnp.clip(r - 1, 0, n - 1)] < qf)) & \
            ((r == n) | (kf[rc] >= qf))
    jax.debug.callback(lambda nb: kernel_bad.append(int(nb)),
                       jnp.sum(~valid))
    return orig_fix(r, kf, qf, seam_budget)
ops._seam_fix = spy_fix

orig_vs = rmi_mod.verified_search
def spy_vs(keys, queries, lo, hi, iters=None):
    n = keys.shape[0]
    r = rmi_mod.bounded_search(keys, queries, lo, hi, iters=iters)
    rc = jnp.clip(r, 0, n - 1)
    valid = ((r == 0) | (keys[jnp.clip(r - 1, 0, n - 1)] < queries)) & \
            ((r == n) | (keys[rc] >= queries))
    jax.debug.callback(lambda nb: jnp_bad.append(int(nb)),
                       jnp.sum(~valid))
    return orig_vs(keys, queries, lo, hi, iters=iters)
rmi_mod.verified_search = spy_vs

mesh = jax.make_mesh((4,), ("data",))
rng = np.random.default_rng(0)

def decode(idx, r):
    cap = idx.keys.shape[1]
    valid = np.asarray(idx.valid)
    starts = np.concatenate([[0], np.cumsum(valid)])
    return starts[r // cap] + r % cap

# ---- n < n_shards: three empty shards, heavy out-of-range load ---------
keys = np.unique(rng.uniform(1.0, 1e5, 3).astype(np.float32)) \
    .astype(np.float64)
idx = distributed.build_sharded(jnp.asarray(keys), mesh, n_leaves=16)
B = 2048
q = rng.permutation(np.concatenate(
    [keys, rng.uniform(0.5, 2e5, B - keys.size - 2), [0.0, 1e30]]))
for uk in (False, True):
    kernel_bad.clear(); jnp_bad.clear()
    fn = distributed.make_lookup_fn(idx, use_kernel=uk)
    r = np.asarray(fn(jnp.asarray(q)))
    np.testing.assert_array_equal(decode(idx, r),
                                  np.searchsorted(keys, q, side="left"))
    bad = kernel_bad if uk else jnp_bad
    assert bad and max(bad) == 0, \
        "empty-shard pads must be seam-clean, got misses %r" % bad

# ---- duplicate-run data + an empty shard: the non-empty shards' real
# seam misses must stay sparse (within budget), not demote to dense ------
keys = np.sort(np.concatenate([np.full(900, 10.0), [20.0, 30.0]]))
idx = distributed.build_sharded(jnp.asarray(keys), mesh, n_leaves=16)
assert int(np.sum(np.asarray(idx.valid) == 0)) >= 1, "needs an empty shard"
q = jnp.asarray(rng.choice([5.0, 10.0, 15.0, 20.0, 25.0, 35.0], 2048))
kernel_bad.clear()
fn = distributed.make_lookup_fn(idx, use_kernel=True)
r = np.asarray(fn(q))
np.testing.assert_array_equal(
    decode(idx, r), np.searchsorted(keys, np.asarray(q), side="left"))
assert max(kernel_bad) > 0, "this workload must produce real seam misses"
assert max(kernel_bad) <= 1024, \
    "seam misses must stay within the sparse budget, got %r" % kernel_bad
print("SEAM_SPARSE_OK")
"""


def test_empty_shards_keep_sparse_seam_path():
    """Regression (PR4 pad-mask fix, pinned here): with empty shards in the
    mesh, exchange padding masked to a member key must produce zero seam
    misses on every shard — and real seam misses on non-empty shards must
    resolve through the sparse path, never the dense full re-search."""
    run_mesh_script(_SEAM_SPARSE_SCRIPT, "SEAM_SPARSE_OK")
