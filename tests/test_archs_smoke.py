"""Per-architecture smoke tests: REDUCED config of the same family, one
train step + prefill + decode steps on the (1,1,1) smoke mesh (same
manual-SPMD code path as production; collectives are no-ops), asserting
output shapes and finiteness."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro  # noqa: F401
from repro.configs import list_archs
from repro.configs.reduced import reduced
from repro.launch.mesh import make_smoke_mesh
from repro.models import model as M
from repro.serve import step as serve_step
from repro.train import grad_compress, optimizer
from repro.train.step import make_train_step

ARCHS = list_archs()


def _batch(cfg, B, S, key):
    k1, k2 = jax.random.split(key)
    if cfg.embed_input:
        inputs = jax.random.normal(k1, (B, S, cfg.d_model),
                                   jnp.bfloat16)
    else:
        inputs = jax.random.randint(k1, (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(k2, (B, S), 0, cfg.vocab_size)
    if cfg.rope == "mrope":
        pos = jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
    else:
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    return inputs, labels, pos


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = reduced(arch)
    mesh = make_smoke_mesh()
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    opt = optimizer.init(params)
    step, _ = make_train_step(cfg, mesh, lr=1e-3, donate=False)
    inputs, labels, pos = _batch(cfg, 2, 32, key)
    residual = jnp.zeros(())
    p2, o2, _, metrics = step(params, opt, residual, inputs, labels, pos)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0, loss
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = sum(float(jnp.abs(a.astype(jnp.float32) -
                              b.astype(jnp.float32)).sum())
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2),
                            strict=True))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode(arch):
    cfg = reduced(arch)
    mesh = make_smoke_mesh()
    key = jax.random.PRNGKey(1)
    params = M.init_params(cfg, key)
    B, S_pre, S_max = 2, 16, 32
    caches = M.init_cache(cfg, B, S_max)
    inputs, _, pos = _batch(cfg, B, S_pre, key)
    prefill, _ = make_prefill_cached(cfg, mesh)
    logits, caches = prefill(params, caches, inputs, pos)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    decode, _ = serve_step.make_decode_step(cfg, mesh)
    tok = (jax.random.normal(key, (B, 1, cfg.d_model), jnp.bfloat16)
           if cfg.embed_input else jnp.full((B, 1), 3, jnp.int32))
    dpos = (jnp.full((3, B, 1), S_pre, jnp.int32) if cfg.rope == "mrope"
            else jnp.full((B, 1), S_pre, jnp.int32))
    for i in range(2):
        nxt, caches = decode(params, caches, tok, dpos,
                             jnp.asarray(S_pre + i, jnp.int32))
        assert nxt.shape == (B,)
        assert np.all((np.asarray(nxt) >= 0) &
                      (np.asarray(nxt) < cfg.vocab_size))
        if not cfg.embed_input:
            tok = nxt[:, None]


_PREFILL_CACHE = {}


def make_prefill_cached(cfg, mesh):
    key = cfg.name
    if key not in _PREFILL_CACHE:
        _PREFILL_CACHE[key] = serve_step.make_prefill(cfg, mesh)
    return _PREFILL_CACHE[key]


def test_grad_compression_roundtrip():
    """int8 pod-psum with error feedback: single-pod sum == identity-ish."""
    mesh = make_smoke_mesh()
    from jax.sharding import PartitionSpec as P

    def f(g, r):
        return grad_compress.compressed_pod_psum({"w": g}, {"w": r})

    fn = jax.shard_map(f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
                       check_vma=True)
    g = jnp.asarray(np.random.default_rng(0).normal(0, 1, (64,)), jnp.float32)
    out, res = fn(g, jnp.zeros((64,), jnp.float32))
    np.testing.assert_allclose(np.asarray(out["w"] + res["w"]), np.asarray(g),
                               atol=1e-5)
