"""Batched serving front-end (repro.serve.frontend).

Covers the four contracts the module docstring promises:

  * **Coalescing**: the adaptive batcher holds a batch exactly until the
    oldest request has waited the latency budget (injectable clock — no
    wall-clock flakes) and cuts early at the key-count cap.
  * **Capacity-class padding + zero retraces**: after warming the classes
    a workload's batch sizes land in, serving any mix of batch sizes never
    retraces the stacked dispatch (``core.distributed.TRACE_COUNTS`` is the
    trace-time counter, same pattern as the update-path no-host-loop guard).
  * **Multi-tenant bit-exactness**: N tenants of different build sizes
    answered in one stacked dispatch match each tenant's own ``find``
    bit-for-bit — jnp AND kernel-interpret paths, 1/2/4-device meshes
    (subprocess per mesh size, like the other multi-device suites).
  * **Donated row scatters**: the restack/tenant-pack scatter really is
    in-place — donated input consumed (``is_deleted``) and, on CPU where
    jax exposes it, the output aliases the input buffer.
"""
import numpy as np
import pytest

from conftest import run_mesh_script

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

import repro  # noqa: E402,F401
from repro.core import distributed as dist_mod  # noqa: E402
from repro.kernels.lookup import capacity_class  # noqa: E402
from repro.serve.frontend import (  # noqa: E402
    AdaptiveBatcher, BatchingFrontend, Request, ServeConfig, TenantPack)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _req(n_keys: int, arrival: float, kind: str = "find") -> Request:
    return Request(0, kind, np.arange(1, n_keys + 1, dtype=np.float64),
                   arrival)


# ---------------------------------------------------------------- batcher --
def test_batcher_coalesces_until_deadline():
    """A batch waits exactly the latency budget from the *oldest* request:
    later arrivals never extend the deadline."""
    clk = FakeClock()
    b = AdaptiveBatcher(latency_budget_s=0.010, max_batch=1000, clock=clk)
    assert not b.ready() and b.deadline() is None

    b.offer(_req(4, arrival=0.0))
    assert b.deadline() == pytest.approx(0.010)
    clk.t = 0.004
    b.offer(_req(4, arrival=clk.t))          # younger request, same deadline
    assert b.deadline() == pytest.approx(0.010)
    clk.t = 0.0099
    assert not b.ready()
    clk.t = 0.010
    assert b.ready()
    batch = b.cut()
    assert [r.keys.size for r in batch] == [4, 4]
    assert len(b) == 0 and not b.ready()


def test_batcher_cuts_early_at_key_cap():
    clk = FakeClock()
    b = AdaptiveBatcher(latency_budget_s=10.0, max_batch=8, clock=clk)
    b.offer(_req(5, 0.0))
    assert not b.ready()                     # budget far away, under cap
    b.offer(_req(3, 0.0))
    assert b.ready()                         # 8 keys >= cap: cut now
    assert len(b.cut()) == 2


# ------------------------------------------------------- donated scatters --
def test_scatter_rows_donated_is_in_place():
    dst = jnp.arange(24, dtype=jnp.float64).reshape(4, 6)
    expect = np.asarray(dst).copy()
    expect[[1, 3]] = [[-1.0] * 6, [-2.0] * 6]
    ptr = None
    if jax.default_backend() == "cpu":
        ptr = dst.unsafe_buffer_pointer()
    out = dist_mod.scatter_rows_donated(
        dst, jnp.asarray([1, 3]),
        jnp.asarray([[-1.0] * 6, [-2.0] * 6], jnp.float64))
    np.testing.assert_array_equal(np.asarray(out), expect)
    assert dst.is_deleted(), "donated input must be consumed"
    if ptr is not None:
        assert out.unsafe_buffer_pointer() == ptr, \
            "donation accepted but output does not alias the input buffer"


# ----------------------------------------------- single-device end-to-end --
def _f32keys(raw):
    return np.unique(np.sort(raw).astype(np.float32)).astype(np.float64)


def _build_tenants(seed: int = 23):
    """Two tenants of different build sizes/leaf counts on the default
    1-device mesh (multi-device variants run in subprocesses below)."""
    rng = np.random.default_rng(seed)
    mesh = jax.make_mesh((1,), ("data",))
    tenants, live, fresh = [], [], []
    for i, (n, nl) in enumerate(((4000, 64), (900, 16))):
        pool = _f32keys(rng.lognormal(0, 0.8, n * 8) * 1e3 + i * 1e7)
        base = np.sort(rng.choice(pool, n, replace=False))
        tenants.append(dist_mod.ShardedDynamicIndex.build(
            jnp.asarray(base), mesh, n_leaves=nl, eps=0.7))
        live.append(base.copy())
        fresh.append(np.setdiff1d(pool, base))
    return tenants, live, fresh


def _check(fe, live, tid, q, tag):
    q = np.asarray(q, np.float64)
    found, rank = fe.lookup(tid, q)
    np.testing.assert_array_equal(
        rank, np.searchsorted(live[tid], q, side="left"), err_msg=tag)
    np.testing.assert_array_equal(
        found, np.searchsorted(live[tid], q, side="right") >
        np.searchsorted(live[tid], q, side="left"), err_msg=tag)


def test_frontend_serves_finds_and_interleaves_updates():
    tenants, live, fresh = _build_tenants()
    rng = np.random.default_rng(3)
    with BatchingFrontend(tenants,
                          config=ServeConfig(latency_budget_s=1e-3)) as fe:
        fe.warmup((1,))
        _check(fe, live, 0, rng.choice(live[0], 40), "t0 fresh")
        _check(fe, live, 1,
               np.concatenate([rng.choice(live[1], 20), fresh[1][-4:],
                               [0.0, 1e30]]), "t1 fresh+miss")
        # updates coalesce with finds and apply before the finds dispatch
        ins = fresh[1][:48]
        assert fe.submit_insert(1, ins).result(timeout=120.0) is None
        live[1] = np.sort(np.concatenate([live[1], ins]))
        dels = rng.choice(live[0], 32, replace=False)
        fe.submit_delete(0, dels).result(timeout=120.0)
        keep = np.ones(live[0].size, bool)
        keep[np.searchsorted(live[0], np.unique(dels))] = False
        live[0] = live[0][keep]
        _check(fe, live, 1, np.concatenate([ins[:16],
                                            rng.choice(live[1], 20)]),
               "t1 after insert")
        _check(fe, live, 0, np.concatenate([dels[:8],
                                            rng.choice(live[0], 20)]),
               "t0 after delete")
        assert fe.stats.updates == 48 + 32
        assert fe.pack.pack_rows >= 1, \
            "tenant updates must refresh via in-place row scatters"


def test_frontend_pads_to_capacity_classes():
    tenants, live, _ = _build_tenants()
    rng = np.random.default_rng(5)
    cfg = ServeConfig(latency_budget_s=1e-3, batch_floor=128)
    with BatchingFrontend(tenants, config=cfg) as fe:
        fe.warmup((1, 200))
        for sz in (1, 3, 127, 128, 129, 200):
            _check(fe, live, 0, rng.choice(live[0], sz), f"sz={sz}")
        assert fe.stats.qcaps <= {128, 256}, fe.stats.qcaps
        for c in fe.stats.qcaps:
            assert c == capacity_class(c, cfg.batch_floor)
        assert 0.0 < fe.stats.pad_fraction < 1.0


def test_zero_retraces_after_warmup():
    """The retrace guard: once warmup has traced the capacity classes a
    workload lands in, serving any batch-size mix must not trace again —
    batch-size variation changes pad contents, never shapes."""
    tenants, live, _ = _build_tenants()
    rng = np.random.default_rng(7)
    with BatchingFrontend(tenants,
                          config=ServeConfig(latency_budget_s=1e-3)) as fe:
        fe.warmup((1, 200))                 # classes {128, 256}
        before = dist_mod.TRACE_COUNTS["tenant_find"]
        for sz in (1, 2, 17, 64, 127, 128, 129, 199, 250, 256, 5):
            tid = int(rng.integers(2))
            _check(fe, live, tid, rng.choice(live[tid], sz), f"sz={sz}")
        delta = dist_mod.TRACE_COUNTS["tenant_find"] - before
        assert delta == 0, f"hot path retraced {delta}x after warmup"


def test_submit_validation():
    """EVERY request kind rejects non-finite keys up front: +inf is the
    delta-tier pad sentinel, so a non-finite insert would silently corrupt
    later merges and a non-finite range endpoint would walk the rank
    algebra into the capacity padding (regression: the guard used to cover
    only finds)."""
    tenants, _, _ = _build_tenants()
    fe = BatchingFrontend(tenants)
    with pytest.raises(RuntimeError):       # not started
        fe.submit_find(0, [1.0])
    with fe:
        with pytest.raises(ValueError):
            fe.submit_find(2, [1.0])        # unknown tenant
        for bad in (np.inf, -np.inf, np.nan):
            with pytest.raises(ValueError):
                fe.submit_find(0, [bad])
            with pytest.raises(ValueError):
                fe.submit_insert(0, [1.0, bad])
            with pytest.raises(ValueError):
                fe.submit_delete(0, [bad])
            with pytest.raises(ValueError):
                fe.submit_range(0, [bad], [1.0])
            with pytest.raises(ValueError):
                fe.submit_range(0, [1.0], [bad])
        with pytest.raises(ValueError):     # endpoint arrays must pair up
            fe.submit_range(0, [1.0, 2.0], [3.0])
        with pytest.raises(RuntimeError):
            fe.start()                      # double start


def _check_range(fe, live, tid, lo, hi, tag):
    lo = np.asarray(lo, np.float64)
    hi = np.asarray(hi, np.float64)
    rl, rh = fe.scan(tid, lo, hi)
    el = np.searchsorted(live[tid], lo, side="left")
    eh = np.maximum(np.searchsorted(live[tid], hi, side="right"), el)
    np.testing.assert_array_equal(rl, el, err_msg=tag)
    np.testing.assert_array_equal(rh, eh, err_msg=tag)


def test_frontend_serves_ranges():
    """Range requests ride the same coalesced dispatch as finds: answers
    match the flat searchsorted oracle, ranges interleave with finds and
    updates, and degenerate ranges come back empty (rank_lo == rank_hi)."""
    tenants, live, fresh = _build_tenants()
    rng = np.random.default_rng(13)
    with BatchingFrontend(tenants,
                          config=ServeConfig(latency_budget_s=1e-3)) as fe:
        fe.warmup((1, 64))
        for tid in (0, 1):
            lo = rng.choice(live[tid], 9)
            hi = (lo * (1 + rng.uniform(0, 0.02, 9))).astype(
                np.float32).astype(np.float64)
            _check_range(fe, live, tid, lo, hi, f"t{tid} fresh")
        # ranges coalesce with point finds in one batch
        rreq = fe.submit_range(0, live[0][:4], live[0][8:12])
        freq = fe.submit_find(1, rng.choice(live[1], 6))
        rl, rh = rreq.result(timeout=120.0)
        np.testing.assert_array_equal(
            rl, np.searchsorted(live[0], live[0][:4], side="left"))
        np.testing.assert_array_equal(
            rh, np.searchsorted(live[0], live[0][8:12], side="right"))
        assert freq.result(timeout=120.0)[0].all()
        # churn between range batches: answers track the live set
        ins = fresh[1][:32]
        fe.submit_insert(1, ins).result(timeout=120.0)
        live[1] = np.sort(np.concatenate([live[1], ins]))
        _check_range(fe, live, 1, ins[:8],
                     (ins[:8] * 1.01).astype(np.float32).astype(np.float64),
                     "after insert")
        # degenerates: lo > hi, fully out-of-range low/high
        span = live[0][-1] - live[0][0]
        for lo, hi in (([live[0][5]], [live[0][2]]),
                       ([live[0][0] - span], [live[0][0] - span / 2]),
                       ([live[0][-1] * 2], [live[0][-1] * 4])):
            rl, rh = fe.scan(0, lo, hi)
            assert np.array_equal(rl, rh), (lo, hi, rl, rh)
        _check_range(fe, live, 0, [live[0][0]], [live[0][-1]], "full span")
        assert fe.stats.ranges > 0


def test_zero_range_retraces_after_warmup():
    """Range batches get their own capacity classes; once warmup traced
    them, serving any mix of range batch sizes never retraces."""
    tenants, live, _ = _build_tenants()
    rng = np.random.default_rng(17)
    with BatchingFrontend(tenants,
                          config=ServeConfig(latency_budget_s=1e-3)) as fe:
        fe.warmup((1, 200))                 # classes {128, 256}
        before = dist_mod.TRACE_COUNTS["tenant_range"]
        for sz in (1, 2, 17, 127, 128, 129, 200, 256):
            tid = int(rng.integers(2))
            lo = rng.choice(live[tid], sz)
            hi = (lo * 1.001).astype(np.float32).astype(np.float64)
            _check_range(fe, live, tid, lo, hi, f"sz={sz}")
        delta = dist_mod.TRACE_COUNTS["tenant_range"] - before
        assert delta == 0, f"range path retraced {delta}x after warmup"


def test_tenant_pack_bit_exact_single_device():
    """One stacked dispatch over tenants of different build sizes matches
    each tenant's own find bit-for-bit — jnp and kernel-interpret paths."""
    tenants, live, fresh = _build_tenants()
    rng = np.random.default_rng(11)
    qcap = 256
    qmat = np.stack([
        rng.permutation(np.concatenate(
            [rng.choice(live[t], qcap - 12), fresh[t][-8:],
             [0.0, 1e30, live[t][0] / 2, live[t][-1] * 2]]))
        for t in range(2)])
    for uk in (False, True):
        pack = TenantPack(tenants, use_kernel=uk,
                          interpret=True if uk else None)
        f, r = pack.find(jnp.asarray(qmat))
        f, r = np.asarray(f), np.asarray(r)
        for t, idx in enumerate(tenants):
            ft, rt = idx.find(jnp.asarray(qmat[t]), use_kernel=uk)
            np.testing.assert_array_equal(
                f[t], np.asarray(ft), err_msg=f"found t={t} uk={uk}")
            np.testing.assert_array_equal(
                r[t], np.asarray(rt), err_msg=f"rank t={t} uk={uk}")
            lo = np.searchsorted(live[t], qmat[t], side="left")
            np.testing.assert_array_equal(r[t], lo,
                                          err_msg=f"oracle t={t} uk={uk}")


# --------------------------------------------------------- multi-device ---
_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(ndev)d"
import numpy as np, jax, jax.numpy as jnp
import repro
from repro.core import distributed
from repro.serve.frontend import BatchingFrontend, ServeConfig, TenantPack

ndev = %(ndev)d
rng = np.random.default_rng(41 + ndev)

def f32keys(raw):
    return np.unique(np.sort(raw).astype(np.float32)).astype(np.float64)

mesh = jax.make_mesh((ndev,), ("data",))
tenants, live, fresh = [], [], []
for i, (n, nl) in enumerate(((6000, 64), (1400, 16))):
    pool = f32keys(rng.lognormal(0, 0.8, n * 8) * 1e3 + i * 1e7)
    base = np.sort(rng.choice(pool, n, replace=False))
    tenants.append(distributed.ShardedDynamicIndex.build(
        jnp.asarray(base), mesh, n_leaves=nl, eps=0.7))
    live.append(base.copy())
    fresh.append(np.setdiff1d(pool, base))

# ---- stacked dispatch bit-exact vs per-tenant find, both paths ---------
qcap = 256 * max(ndev // 2, 1)
qmat = np.stack([
    rng.permutation(np.concatenate(
        [rng.choice(live[t], qcap - 12 - (tenants[t].n_shards - 1)),
         fresh[t][-8:],
         np.asarray(tenants[t].splits, np.float64)
         if tenants[t].n_shards > 1 else np.zeros(0),
         [0.0, 1e30, live[t][0] / 2, live[t][-1] * 2]]))[:qcap]
    for t in range(2)])
for uk in (False, True):
    pack = TenantPack(tenants, use_kernel=uk,
                      interpret=True if uk else None)
    f, r = pack.find(jnp.asarray(qmat))
    f, r = np.asarray(f), np.asarray(r)
    for t, idx in enumerate(tenants):
        ft, rt = idx.find(jnp.asarray(qmat[t]), use_kernel=uk)
        np.testing.assert_array_equal(f[t], np.asarray(ft),
                                      err_msg="found t=%%d uk=%%s" %% (t, uk))
        np.testing.assert_array_equal(r[t], np.asarray(rt),
                                      err_msg="rank t=%%d uk=%%s" %% (t, uk))
        np.testing.assert_array_equal(
            r[t], np.searchsorted(live[t], qmat[t], side="left"),
            err_msg="oracle t=%%d uk=%%s" %% (t, uk))

# ---- frontend end-to-end: zero retraces, then interleaved churn --------
def check(fe, tid, q, tag):
    q = np.asarray(q, np.float64)
    found, rank = fe.lookup(tid, q)
    np.testing.assert_array_equal(
        rank, np.searchsorted(live[tid], q, side="left"), err_msg=tag)
    np.testing.assert_array_equal(
        found, np.searchsorted(live[tid], q, side="right") >
        np.searchsorted(live[tid], q, side="left"), err_msg=tag)

with BatchingFrontend(tenants,
                      config=ServeConfig(latency_budget_s=1e-3)) as fe:
    fe.warmup((1, 200))
    before = distributed.TRACE_COUNTS["tenant_find"]
    for sz in (1, 17, 128, 129, 250):
        tid = int(rng.integers(2))
        check(fe, tid, rng.choice(live[tid], sz), "sz=%%d" %% sz)
    delta = distributed.TRACE_COUNTS["tenant_find"] - before
    assert delta == 0, "hot path retraced %%d times after warmup" %% delta

    ins = fresh[1][:64]
    fe.submit_insert(1, ins).result(timeout=300.0)
    live[1] = np.sort(np.concatenate([live[1], ins]))
    dels = rng.choice(live[0], 48, replace=False)
    fe.submit_delete(0, dels).result(timeout=300.0)
    keep = np.ones(live[0].size, bool)
    keep[np.searchsorted(live[0], np.unique(dels))] = False
    live[0] = live[0][keep]
    check(fe, 1, np.concatenate([ins[:16], rng.choice(live[1], 32)]),
          "after insert")
    check(fe, 0, np.concatenate([dels[:8], rng.choice(live[0], 32)]),
          "after delete")
    assert fe.pack.pack_rows >= 1

    # ---- range requests: oracle-exact on the mesh, zero retraces -------
    rbefore = distributed.TRACE_COUNTS["tenant_range"]
    for sz in (1, 9, 130):
        tid = int(rng.integers(2))
        lo = np.sort(rng.choice(live[tid], sz))
        hi = (lo * (1 + rng.uniform(0, 0.02, sz))).astype(
            np.float32).astype(np.float64)
        rl, rh = fe.scan(tid, lo, hi)
        el = np.searchsorted(live[tid], lo, side="left")
        eh = np.maximum(np.searchsorted(live[tid], hi, side="right"), el)
        np.testing.assert_array_equal(rl, el, err_msg="range sz=%%d" %% sz)
        np.testing.assert_array_equal(rh, eh, err_msg="range sz=%%d" %% sz)
    rl, rh = fe.scan(0, [live[0][7]], [live[0][3]])     # degenerate lo > hi
    assert rl[0] == rh[0]
    rdelta = distributed.TRACE_COUNTS["tenant_range"] - rbefore
    assert rdelta == 0, "range path retraced %%d times" %% rdelta
print("SERVE_OK ndev=%(ndev)d")
"""


def _run(ndev: int):
    run_mesh_script(_SCRIPT % {"ndev": ndev}, f"SERVE_OK ndev={ndev}")


def test_serve_mesh_2dev():
    _run(2)


@pytest.mark.slow
def test_serve_mesh_4dev():
    _run(4)
