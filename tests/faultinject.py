"""Deterministic fault injection for the persist layer (not a test module
— the recovery suites in test_persist.py / test_runtime.py drive it).

Two fault surfaces:

* **Write-time** (:class:`FaultInjector`): a context manager that swaps
  ``repro.core.persist._write_bytes`` — the single seam every snapshot byte
  passes through — for an injecting wrapper.  It can kill the writer
  mid-snapshot after N successful file writes (optionally leaving a
  half-written file, like a real crash), raise a bounded number of
  transient ``OSError``s (exercising the per-file retry/backoff path), or
  fail every write.

* **At-rest** (:func:`tear_manifest` / :func:`flip_byte` /
  :func:`drop_file`): damage a *committed* snapshot the way disks and
  operators do — truncate the manifest mid-JSON, flip bytes inside a shard
  file, delete a shard file — to exercise checksum detection, latest-
  complete fallback, and quarantined degraded serving.
"""
from __future__ import annotations

import os

from repro.core import persist


class WriteCrash(RuntimeError):
    """Simulated hard death of the writing process mid-snapshot (not an
    OSError on purpose: it must bypass the transient-retry path, like a
    SIGKILL would)."""


class FaultInjector:
    """Monkeypatch ``persist._write_bytes`` inside a ``with`` block.

    kill_after=N      raise WriteCrash instead of performing the (N+1)-th
                      file write; with partial=True, first flush half the
                      bytes (a torn file a crash can leave behind)
    transient_errors=N  raise OSError for the first N write calls, then
                      write normally (the retry path must absorb these)
    fail_always=True  every write raises OSError (surfaced-error path)
    """

    def __init__(self, kill_after: int | None = None, partial: bool = False,
                 transient_errors: int = 0, fail_always: bool = False):
        self.kill_after = kill_after
        self.partial = partial
        self.transient_errors = transient_errors
        self.fail_always = fail_always
        self.writes = 0         # successful file writes
        self.raised = 0         # injected failures

    def __enter__(self):
        self._orig = persist._write_bytes

        def inject(path: str, data: bytes) -> None:
            if self.fail_always:
                self.raised += 1
                raise OSError(f"injected permanent failure on {path}")
            if self.raised < self.transient_errors:
                self.raised += 1
                raise OSError(f"injected transient failure on {path}")
            if self.kill_after is not None and \
                    self.writes >= self.kill_after:
                if self.partial:
                    self._orig(path, data[:max(len(data) // 2, 1)])
                self.raised += 1
                raise WriteCrash(f"killed before writing {path}")
            self._orig(path, data)
            self.writes += 1

        persist._write_bytes = inject
        return self

    def __exit__(self, *exc):
        persist._write_bytes = self._orig
        return False


def step_dir(store: persist.SnapshotStore, step: int) -> str:
    return os.path.join(store.directory, persist._STEP_FMT.format(step))


def tear_manifest(store: persist.SnapshotStore, step: int) -> None:
    """Truncate a committed snapshot's manifest mid-JSON."""
    path = os.path.join(step_dir(store, step), "manifest.json")
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(max(size // 2, 1))


def flip_byte(store: persist.SnapshotStore, step: int, fname: str,
              offset: int = 128) -> None:
    """Flip one byte inside a committed snapshot file."""
    path = os.path.join(step_dir(store, step), fname)
    offset = min(offset, os.path.getsize(path) - 1)
    with open(path, "r+b") as f:
        f.seek(offset)
        b = f.read(1)
        f.seek(offset)
        f.write(bytes([b[0] ^ 0xFF]))


def drop_file(store: persist.SnapshotStore, step: int, fname: str) -> None:
    """Delete a file out of a committed snapshot."""
    os.remove(os.path.join(step_dir(store, step), fname))
