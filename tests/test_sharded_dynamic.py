"""Sharded dynamic index: interleaved insert/delete/find churn differential
against a flat sorted-array oracle on 1/2/4/8-device CPU meshes.

Each mesh size runs in a subprocess (device count locks at first jax init,
like tests/test_distributed.py).  Every round of churn asserts — for BOTH
the kernel-interpret and jnp per-shard paths — that ``find``'s (found, rank)
matches the brute-force multiset truth on the concatenated live keys
bit-exactly, including seam/split queries, out-of-range extremes, duplicate
keys, a delete-all-of-one-shard drain, and a rebalance-triggering skewed
ingest (keys are f32-exact throughout so the kernel's f32 boundary
coincides with the f64 truth).  ``find_range`` rides every churn round
(seam-spanning, point, and degenerate ranges vs the flat oracle), and a
dedicated regression pins the rightmost-rank semantics for duplicate runs
at shard seams.
"""
import pytest

from conftest import run_mesh_script

pytestmark = pytest.mark.kernel

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(ndev)d"
import numpy as np, jax, jax.numpy as jnp
import repro
from repro.core import distributed

ndev = %(ndev)d
rng = np.random.default_rng(29 + ndev)

def f32keys(raw):
    return np.unique(np.sort(raw).astype(np.float32)).astype(np.float64)

base = f32keys(rng.lognormal(0, 0.8, 16_000) * 1e3)
fresh = np.setdiff1d(f32keys(rng.lognormal(0, 0.8, 80_000) * 1e3), base)
mesh = jax.make_mesh((ndev,), ("data",))
idx = distributed.ShardedDynamicIndex.build(jnp.asarray(base), mesh,
                                            n_leaves=64, eps=0.7)
live = base.copy()

def check(q, tag):
    q = np.asarray(q, np.float64)
    lo = np.searchsorted(live, q, side="left")
    hi = np.searchsorted(live, q, side="right")
    for uk in (False, True):
        f, r = idx.find(jnp.asarray(q), use_kernel=uk)
        np.testing.assert_array_equal(
            np.asarray(r), lo, err_msg="rank %%s uk=%%s" %% (tag, uk))
        np.testing.assert_array_equal(
            np.asarray(f), hi > lo, err_msg="found %%s uk=%%s" %% (tag, uk))

def check_range(tag, n=129):
    # find_range rides every churn round: rank_lo leftmost / rank_hi
    # rightmost vs the flat live oracle, seam endpoints included.
    if live.size == 0:
        return
    lo = rng.choice(live, n)
    if idx.n_shards > 1:                 # seam-spanning + seam endpoints
        seams = np.asarray(idx.splits, np.float64)
        lo[:seams.size] = seams
    hi = (lo * (1 + rng.uniform(0, 0.02, n))).astype(
        np.float32).astype(np.float64)
    hi[-8:] = lo[-8:]                    # point ranges (lo == hi)
    lo[-4:], hi[-4:] = hi[-4:], lo[-4:]  # degenerate lo > hi
    el = np.searchsorted(live, lo, side="left")
    eh = np.maximum(np.searchsorted(live, hi, side="right"), el)
    for uk in (False, True):
        rl, rh = idx.find_range(jnp.asarray(lo), jnp.asarray(hi),
                                use_kernel=uk)
        np.testing.assert_array_equal(
            np.asarray(rl), el, err_msg="range lo %%s uk=%%s" %% (tag, uk))
        np.testing.assert_array_equal(
            np.asarray(rh), eh, err_msg="range hi %%s uk=%%s" %% (tag, uk))

def queries(n=701):                      # odd n: exercises the Q padding
    mem = rng.choice(live, n - 32) if live.size else np.zeros(n - 32)
    seams = np.asarray(idx.splits, np.float64) if idx.n_shards > 1 \
        else np.zeros(0)
    oor = np.asarray([0.0, -1e9, 1e30, live[0] / 2 if live.size else 1.0,
                      (live[-1] * 2) if live.size else 2.0], np.float32)
    miss = rng.choice(fresh, 27)
    return rng.permutation(np.concatenate(
        [mem, seams, oor.astype(np.float64), miss]))[:n]

def oracle_delete(live, batch):
    # DynamicRMI semantics: duplicates within one batch collapse to one
    # removal; each unique key retires its leftmost live occurrence.
    for k in np.unique(batch):
        i = np.searchsorted(live, k, side="left")
        if i < live.size and live[i] == k:
            live = np.delete(live, i)
    return live

check(queries(), "fresh")
check_range("fresh")

# ---- interleaved churn: inserts (incl. duplicates of live keys), deletes
# (incl. misses), find after every round --------------------------------
ptr = 0
for rnd in range(4):
    ins = fresh[ptr:ptr + 1500]; ptr += 1500
    dups = rng.choice(live, 64)          # multiset: duplicate inserts
    batch = np.concatenate([ins, dups])
    idx.insert_batch(batch)
    live = np.sort(np.concatenate([live, batch]))
    dels = np.concatenate([rng.choice(live, 400, replace=False),
                           fresh[-8:]])  # misses are no-ops
    idx.delete_batch(dels)
    live = oracle_delete(live, dels)
    check(queries(), "round %%d" %% rnd)
    check_range("round %%d" %% rnd)

# ---- delete-all-of-one-shard drain ------------------------------------
if idx.n_shards > 1:
    for _ in range(64):                  # duplicates need repeated batches
        in0 = live[live <= idx.splits[0]]
        if in0.size == 0:
            break
        batch = np.unique(in0)
        idx.delete_batch(batch)
        live = oracle_delete(live, batch)
    check(queries(), "drain")
    check_range("drain")

# ---- rebalance-triggering skewed ingest -------------------------------
span_hi = float(idx.splits[0]) if idx.n_shards > 1 else float(live[0])
hot = np.setdiff1d(f32keys(rng.uniform(live[0] / 4, max(span_hi, live[0]),
                                       30_000)), live)
idx.insert_batch(hot)
live = np.sort(np.concatenate([live, hot]))
if idx.n_shards > 1:
    assert idx.rebalances >= 1, "skewed ingest must trigger a rebalance"
check(queries(), "skew")
check_range("skew")
assert idx.total_live == live.size
print("SHARDED_DYN_OK ndev=%(ndev)d")
"""


def _run(ndev: int):
    run_mesh_script(_SCRIPT % {"ndev": ndev}, f"SHARDED_DYN_OK ndev={ndev}")


@pytest.mark.parametrize("ndev", [1, 2])
def test_sharded_dynamic_churn_small_mesh(ndev):
    _run(ndev)


@pytest.mark.slow
@pytest.mark.parametrize("ndev", [4, 8])
def test_sharded_dynamic_churn_large_mesh(ndev):
    _run(ndev)


_EMPTY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
import repro
from repro.core import distributed

mesh = jax.make_mesh((8,), ("data",))
base = np.asarray([1.0, 2.0, 5.0, 9.0, 12.0])   # n < n_shards: empty shards
idx = distributed.ShardedDynamicIndex.build(jnp.asarray(base), mesh,
                                            n_leaves=16, eps=0.7)
live = base.copy()

def check(q):
    q = np.asarray(q, np.float64)
    lo = np.searchsorted(live, q, side="left")
    hi = np.searchsorted(live, q, side="right")
    for uk in (False, True):
        f, r = idx.find(jnp.asarray(q), use_kernel=uk)
        np.testing.assert_array_equal(np.asarray(r), lo)
        np.testing.assert_array_equal(np.asarray(f), hi > lo)

check([0.5, 1.0, 2.0, 3.0, 9.0, 12.0, 100.0])
# inserts routed into gaps and past the end (trailing empty shards)
ins = np.asarray([0.25, 3.5, 20.0, 21.0, 22.0])
idx.insert_batch(ins)
live = np.sort(np.concatenate([live, ins]))
check(np.concatenate([live, [0.0, 50.0, 2.5]]))
print("EMPTY_OK")
"""


def test_sharded_dynamic_empty_shards():
    """n < n_shards: empty shards build, serve, and absorb inserts."""
    run_mesh_script(_EMPTY_SCRIPT, "EMPTY_OK")


_DEAD_HOT_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import numpy as np, jax, jax.numpy as jnp
import repro
from repro.core import distributed

rng = np.random.default_rng(9)
base = np.unique(np.sort(rng.uniform(0, 1e6, 6000)).astype(np.float32)) \
    .astype(np.float64)
idx = distributed.ShardedDynamicIndex.build(
    jnp.asarray(base), jax.make_mesh((2,), ("data",)), n_leaves=32, eps=0.7)
live = base.copy()
# Uniform deletes keep live counts balanced while every shard's dead
# fraction climbs: migration can't help, so the trigger must resolve via
# an in-place shard rebuild (tombstones purged) instead of re-firing a
# fruitless migration on every batch.
for _ in range(8):
    dels = rng.choice(live, 500, replace=False)
    idx.delete_batch(dels)
    for k in np.unique(dels):
        live = np.delete(live, np.searchsorted(live, k))
assert idx.rebalances >= 1, "dead-hot trigger never resolved"
assert max(d.dead_fraction for d in idx.shards) < 0.5
q = np.concatenate([rng.choice(live, 500), rng.choice(base, 200)])
lo = np.searchsorted(live, q, side="left")
hi = np.searchsorted(live, q, side="right")
for uk in (False, True):
    f, r = idx.find(jnp.asarray(q), use_kernel=uk)
    np.testing.assert_array_equal(np.asarray(r), lo)
    np.testing.assert_array_equal(np.asarray(f), hi > lo)
print("DEAD_HOT_OK")
"""


def test_sharded_dynamic_dead_hot_rebuilds_in_place():
    """A delete-heavy workload with balanced shards must clear the dead
    ratio via an in-place rebuild, keeping finds exact afterwards."""
    run_mesh_script(_DEAD_HOT_SCRIPT, "DEAD_HOT_OK")


_SEAM_DUP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(ndev)d"
import numpy as np, jax, jax.numpy as jnp
import repro
from repro.core import distributed

ndev = %(ndev)d
rng = np.random.default_rng(67)
base = np.unique(rng.uniform(0, 1e6, 4000).astype(np.float32)) \
    .astype(np.float64)
mesh = jax.make_mesh((ndev,), ("data",))
idx = distributed.ShardedDynamicIndex.build(jnp.asarray(base), mesh,
                                            n_leaves=64, eps=0.7)
live = base.copy()

# Grow a duplicate run on each seam key itself: splits snap to run starts,
# so after these inserts every split value heads a run that ends exactly at
# its shard boundary (splits[r-1] < run key <= splits[r] routes the whole
# run, and any hi endpoint equal to it, to shard r).
splits = np.asarray(idx.splits, np.float64)
dups = np.repeat(splits, 9)
idx.insert_batch(dups)
live = np.sort(np.concatenate([live, dups]))

# hi == seam-run key: the rightmost rank must count EVERY duplicate in the
# run (an off-by-run answer here means the hi endpoint was routed to the
# shard past the seam, or the local search used the leftmost bound).
lo = np.concatenate([splits, np.repeat(live[0], splits.size), live[:2]])
hi = np.concatenate([splits, splits, live[:2]])
el = np.searchsorted(live, lo, side="left")
eh = np.maximum(np.searchsorted(live, hi, side="right"), el)
for uk in (False, True):
    rl, rh = idx.find_range(jnp.asarray(lo), jnp.asarray(hi), use_kernel=uk)
    np.testing.assert_array_equal(np.asarray(rl), el,
                                  err_msg="seam-dup lo uk=%%s" %% uk)
    np.testing.assert_array_equal(np.asarray(rh), eh,
                                  err_msg="seam-dup hi uk=%%s" %% uk)
    # each seam run is 1 original + 9 duplicates wide
    w = np.asarray(rh - rl)[:splits.size]
    np.testing.assert_array_equal(w, np.full(splits.size, 10),
                                  err_msg="seam run width uk=%%s" %% uk)
print("SEAM_DUP_OK ndev=%(ndev)d")
"""


@pytest.mark.parametrize("ndev", [2])
def test_sharded_range_seam_duplicates_small_mesh(ndev):
    """Regression: a range's hi endpoint equal to a duplicate-run key at a
    shard seam must return the RIGHTMOST global rank — counting the whole
    run on the seam-owning shard, not the leftmost bound and not the next
    shard's zero."""
    run_mesh_script(_SEAM_DUP_SCRIPT % {"ndev": ndev},
                    f"SEAM_DUP_OK ndev={ndev}")


@pytest.mark.slow
@pytest.mark.parametrize("ndev", [4])
def test_sharded_range_seam_duplicates_large_mesh(ndev):
    run_mesh_script(_SEAM_DUP_SCRIPT % {"ndev": ndev},
                    f"SEAM_DUP_OK ndev={ndev}")
