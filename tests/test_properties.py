"""Property-based tests (hypothesis) for the paper's invariants:

  Eq. 3      dist_h >= exact KS distance (the reuse decision is conservative)
  Lemma 3.2  affine folding is exact (linear AND our MLP extension)
  Thm 3.3    error-bound algebra + soundness with measured bounds
  Lemma 4.1  insertion budget keeps the worst-case CDF drift within sim-eps
  + search/bucketing invariants the system relies on.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import repro  # noqa: F401
from repro.core import adapt, bounds, cdf, models
from repro.core.rmi import bounded_search

SET = settings(max_examples=40, deadline=None)


def sorted_keys(draw, min_size=5, max_size=300):
    xs = draw(st.lists(st.floats(0.001, 1e9, allow_nan=False,
                                 allow_infinity=False),
                       min_size=min_size, max_size=max_size, unique=True))
    return np.sort(np.asarray(xs, np.float64))


@st.composite
def two_datasets(draw):
    return sorted_keys(draw), sorted_keys(draw)


@SET
@given(two_datasets(), st.integers(4, 128))
def test_eq3_hist_distance_upper_bounds_ks(ds, m):
    """Algorithm 2 over ANY bin count m upper-bounds the exact KS distance
    (both datasets normalized to [0,1] like the production path)."""
    a, b = ds
    an = (a - a.min()) / max(a.max() - a.min(), 1e-300)
    bn = (b - b.min()) / max(b.max() - b.min(), 1e-300)
    ha = cdf.histogram_sorted(jnp.asarray(an), m, jnp.float64(0), jnp.float64(1))
    hb = cdf.histogram_sorted(jnp.asarray(bn), m, jnp.float64(0), jnp.float64(1))
    d_h = float(cdf.hist_distance(ha, hb))
    d_ks = float(cdf.ks_distance(jnp.asarray(an), jnp.asarray(bn)))
    assert d_h >= d_ks - 1e-9, (d_h, d_ks)


@SET
@given(two_datasets())
def test_ks_distance_metric_properties(ds):
    a, b = ds
    da = jnp.asarray(a)
    db = jnp.asarray(b)
    assert abs(float(cdf.ks_distance(da, da))) < 1e-12
    d1, d2 = float(cdf.ks_distance(da, db)), float(cdf.ks_distance(db, da))
    assert abs(d1 - d2) < 1e-12
    assert -1e-12 <= d1 <= 1.0 + 1e-12


@SET
@given(st.integers(0, 2 ** 31), st.floats(1.0, 100.0), st.floats(0.0, 1e6),
       st.floats(1.0, 1e3), st.floats(0.0, 1e6), st.floats(1.0, 1e3))
def test_lemma32_linear_fold_exact(seed, a, xs, xw, ys, yw):
    """Folded linear model == T_out(M(T_in(x))) pointwise."""
    rng = np.random.default_rng(seed)
    p = models.LinearParams(a=jnp.float64(a), b=jnp.float64(rng.normal()))
    src = adapt.DomainSpec(jnp.float64(xs), jnp.float64(xs + xw),
                           jnp.float64(ys), jnp.float64(ys + yw))
    tgt = adapt.DomainSpec(jnp.float64(xs * 2 + 1), jnp.float64(xs * 2 + 1 + xw * 3),
                           jnp.float64(0.0), jnp.float64(999.0))
    folded = adapt.adapt_linear(p, src, tgt)
    (a1, b1), (a2, b2) = adapt.affine_coeffs(src, tgt)
    x = jnp.asarray(rng.uniform(float(tgt.x_start), float(tgt.x_end), 50))
    direct = a2 * (models.linear_predict(p, a1 * x + b1)) + b2
    np.testing.assert_allclose(np.asarray(models.linear_predict(folded, x)),
                               np.asarray(direct), rtol=1e-9, atol=1e-6)


@SET
@given(st.integers(0, 2 ** 31))
def test_lemma32_mlp_fold_exact(seed):
    """Our MLP extension of Lemma 3.2 is exact too."""
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed % (2 ** 31))
    p = models.mlp_init(key)
    src = adapt.DomainSpec(jnp.float64(0.0), jnp.float64(1.0),
                           jnp.float64(0.0), jnp.float64(99.0))
    tgt = adapt.DomainSpec(jnp.float64(5.0), jnp.float64(125.0),
                           jnp.float64(0.0), jnp.float64(4999.0))
    folded = adapt.adapt_mlp(p, src, tgt)
    (a1, b1), (a2, b2) = adapt.affine_coeffs(src, tgt)
    x = jnp.asarray(rng.uniform(5.0, 125.0, 64))
    direct = a2 * models.mlp_predict(p, a1 * x + b1) + b2
    np.testing.assert_allclose(np.asarray(models.mlp_predict(folded, x)),
                               np.asarray(direct), rtol=1e-7, atol=1e-5)


@SET
@given(st.floats(0.0, 0.5), st.floats(0.5, 0.999), st.integers(10, 10 ** 7))
def test_lemma41_budget_bounds_cdf_drift(gap, eps, n):
    """Inserting <= budget points (all at one spot — worst case) keeps the
    CDF drift n_i/(n_i+n) within the slack sim - eps."""
    sim = min(eps + gap, 1.0)
    budget = float(bounds.insertion_budget(jnp.float64(sim),
                                           jnp.float64(eps), jnp.float64(n)))
    n_i = int(budget)
    drift = n_i / (n_i + n)
    assert drift <= (sim - eps) + 1e-9
    # one more insert may exceed the slack (budget is tight up to flooring)
    if sim - eps > 1e-6 and budget > 0:
        n_over = int(budget) + max(int(0.01 * n), 2)
        assert n_over / (n_over + n) > (sim - eps) - 1.0 / n - 1e-9


@SET
@given(st.integers(0, 2 ** 31), st.integers(2, 400))
def test_thm33_bounds_sound_with_exact_distance(seed, ns):
    """Reusing a model across datasets with exact-KS distance `dist`, the
    Thm 3.3 window (widened by the CDF quantization term 1) contains every
    true position."""
    rng = np.random.default_rng(seed)
    src_keys = jnp.asarray(np.sort(rng.random(ns)))
    tgt_keys = jnp.asarray(np.sort(rng.random(ns) ** 1.2))
    pos_s = jnp.arange(ns, dtype=jnp.float64)
    p = models.linear_fit(src_keys, pos_s)
    elo, ehi = models.linear_err_bounds(p, src_keys, pos_s)
    src = adapt.domain_of(src_keys)
    tgt = adapt.domain_of(tgt_keys)
    folded = adapt.adapt_linear(p, src, tgt)
    dist = cdf.ks_distance(
        (src_keys - src_keys[0]) / (src_keys[-1] - src_keys[0] + 1e-300),
        (tgt_keys - tgt_keys[0]) / (tgt_keys[-1] - tgt_keys[0] + 1e-300))
    s_dy = (tgt.y_end - tgt.y_start) / (src.y_end - src.y_start)
    lo, hi = bounds.reuse_err_bounds(elo, ehi, dist, jnp.float64(ns), s_dy)
    pred = models.linear_predict(folded, tgt_keys)
    resid = jnp.arange(ns, dtype=jnp.float64) - pred
    # +-1 slack: empirical CDFs quantize at 1/n (finite-sample edge term)
    assert float(resid.min()) >= float(lo) - 1.0 - 1e-6
    assert float(resid.max()) <= float(hi) + 1.0 + 1e-6


@SET
@given(st.integers(0, 2 ** 31), st.integers(2, 500), st.integers(1, 50))
def test_bounded_search_matches_searchsorted(seed, n, nq):
    rng = np.random.default_rng(seed)
    keys = jnp.asarray(np.sort(rng.normal(0, 100, n)))
    q = jnp.asarray(rng.normal(0, 120, nq))
    truth = jnp.searchsorted(keys, q, side="left")
    got = bounded_search(keys, q, jnp.zeros(nq, jnp.int32),
                         jnp.full(nq, n, jnp.int32))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(truth))


@SET
@given(st.integers(0, 2 ** 31), st.integers(1, 300), st.integers(2, 64))
def test_histograms_consistent(seed, n, m):
    """Sorted O(m log n) histogram == streaming O(n) histogram; sums to 1."""
    rng = np.random.default_rng(seed)
    keys = np.sort(rng.random(n))
    lo, hi = jnp.float64(0.0), jnp.float64(1.0)
    h1 = cdf.histogram_sorted(jnp.asarray(keys), m, lo, hi)
    h2 = cdf.histogram_stream(jnp.asarray(keys), m, lo, hi)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-12)
    assert abs(float(h1.sum()) - 1.0) < 1e-9
