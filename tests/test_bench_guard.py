"""Bench-trajectory guard: the checker passes the committed BENCH files and
actually catches the violations it exists for (schema drift, duplicate
(sha, suite) keys, mutated history)."""
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT))

from benchmarks import check_bench  # noqa: E402


def _updates_doc():
    return {
        "meta": {"queries": 1},
        "rows": [{"op": "insert", "impl": "x", "n_keys": 1,
                  "ns_per_op": 1.0, "detail": ""}],
        "trajectory": [
            {"sha": "abc1234", "suite": "updates", "mode": "interpret/CPU",
             "date": "2026-07-30",
             "rows": [{"op": "insert", "impl": "x", "n_keys": 1,
                       "ns_per_op": 1.0, "detail": ""}]},
        ],
    }


def _serve_doc():
    row = {"workload": "point", "tenants": 2, "offered_qps": 500.0,
           "achieved_qps": 480.0, "p50_ms": 4.1, "p99_ms": 9.9,
           "p999_ms": 15.0, "detail": "reqs=500"}
    return {
        "meta": {"duration_s": 1.0},
        "rows": [dict(row)],
        "trajectory": [
            {"sha": "abc1234", "suite": "serve", "mode": "interpret/CPU",
             "date": "2026-08-08", "rows": [dict(row)]},
        ],
    }


def _write(tmp_path, doc, name="BENCH_updates.json"):
    p = tmp_path / name
    p.write_text(json.dumps(doc))
    return p


def test_committed_files_pass():
    assert check_bench.main([]) == 0


def test_clean_doc_passes(tmp_path):
    p = _write(tmp_path, _updates_doc())
    assert check_bench.check_file(p) == []


def test_schema_violations_caught(tmp_path):
    doc = _updates_doc()
    del doc["rows"][0]["ns_per_op"]
    assert check_bench.check_schema(Path("BENCH_updates.json"), doc)

    doc = _updates_doc()
    doc["trajectory"][0].pop("sha")
    assert check_bench.check_schema(Path("BENCH_updates.json"), doc)

    doc = _updates_doc()
    doc["trajectory"][0]["date"] = "today"
    assert check_bench.check_schema(Path("BENCH_updates.json"), doc)


def test_serve_doc_passes(tmp_path):
    p = _write(tmp_path, _serve_doc(), name="BENCH_serve.json")
    assert check_bench.check_file(p) == []


def test_serve_schema_violations_caught(tmp_path):
    doc = _serve_doc()
    del doc["rows"][0]["p999_ms"]
    errs = check_bench.check_schema(Path("BENCH_serve.json"), doc)
    assert any("p999_ms" in e for e in errs)

    doc = _serve_doc()
    del doc["trajectory"][0]["rows"][0]["achieved_qps"]
    errs = check_bench.check_schema(Path("BENCH_serve.json"), doc)
    assert any("achieved_qps" in e for e in errs)


def test_duplicate_trajectory_key_caught(tmp_path):
    doc = _updates_doc()
    doc["trajectory"].append(json.loads(json.dumps(doc["trajectory"][0])))
    errs = check_bench.check_schema(Path("BENCH_updates.json"), doc)
    assert any("duplicate trajectory key" in e for e in errs)


def test_append_flow_preserves_history(tmp_path):
    """The real append flow, run twice against a scratch copy, must leave
    meta/rows/pre-existing entries intact and replace the re-run key."""
    p = _write(tmp_path, _updates_doc())
    assert check_bench.check_append_immutable(p) == []
    # the scratch-append self-test must not touch the input file itself
    assert json.loads(p.read_text()) == _updates_doc()


def test_mutated_history_is_detected(tmp_path, monkeypatch):
    """If append_bench ever started rewriting historical entries, the guard
    must fail — simulate a broken appender that drops old entries."""
    from benchmarks import harness

    def broken_append(path, suite, rows, mode="interpret/CPU", note=""):
        data = json.loads(Path(path).read_text())
        data["trajectory"] = [{"sha": "zzz", "suite": suite, "mode": mode,
                               "date": "2026-07-30", "rows": rows}]
        Path(path).write_text(json.dumps(data))
        return data

    monkeypatch.setattr(harness, "append_bench", broken_append)
    p = _write(tmp_path, _updates_doc())
    errs = check_bench.check_append_immutable(p)
    assert any("pre-existing trajectory" in e for e in errs)


def _lookup_doc():
    base = {"variant": "DynamicRMI", "n_keys": 1000, "path": "jnp",
            "ns_per_query": 9.5}
    range_row = dict(base, mix="point")
    return {
        "meta": {"queries": 1},
        "rows": [dict(base)],
        "trajectory": [
            {"sha": "abc1234", "suite": "lookup", "mode": "interpret/CPU",
             "date": "2026-08-08", "rows": [dict(base)]},
            {"sha": "abc1234", "suite": "lookup-range",
             "mode": "interpret/CPU", "date": "2026-08-08",
             "rows": [dict(range_row)]},
        ],
    }


def test_lookup_doc_passes(tmp_path):
    p = _write(tmp_path, _lookup_doc(), name="BENCH_lookup.json")
    assert check_bench.check_file(p) == []


def test_malformed_row_rejected():
    """A non-object row — the shape a half-written append leaves behind —
    fails both in the baseline and inside a trajectory entry."""
    doc = _lookup_doc()
    doc["rows"].append(["variant", "DynamicRMI"])
    errs = check_bench.check_schema(Path("BENCH_lookup.json"), doc)
    assert any("rows[1] is not an object" in e for e in errs)

    doc = _lookup_doc()
    doc["trajectory"][0]["rows"][0] = 42
    errs = check_bench.check_schema(Path("BENCH_lookup.json"), doc)
    assert any("trajectory[0].rows[0]" in e for e in errs)


def test_suite_specific_column_required():
    """lookup-range trajectory rows carry the YCSB mix column on top of
    the file's baseline schema (_SUITE_ROW_KEYS); dropping it fails even
    though the row satisfies the plain BENCH_lookup.json schema."""
    doc = _lookup_doc()
    del doc["trajectory"][1]["rows"][0]["mix"]
    errs = check_bench.check_schema(Path("BENCH_lookup.json"), doc)
    assert any("trajectory[1].rows[0] missing columns ['mix']" in e
               for e in errs)
    # the plain lookup suite does not require mix
    doc = _lookup_doc()
    errs = check_bench.check_schema(Path("BENCH_lookup.json"), doc)
    assert errs == []
