"""HloCost accountant: exactness on controlled programs (the reason this
exists: XLA cost_analysis counts while bodies once)."""
import jax
import jax.numpy as jnp

import repro  # noqa: F401
from repro.launch.hlo_cost import HloCost


def test_scan_trip_counts():
    def body(c, _):
        return c @ c, None

    def f(x):
        y, _ = jax.lax.scan(body, x, None, length=8)
        return y

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = jax.jit(f).lower(x).compile()
    got = HloCost(c.as_text()).summary()["flops"]
    want = 8 * 2 * 256 ** 3
    assert abs(got - want) / want < 0.01, (got, want)
    # and confirm XLA's own number misses the trip count
    xla = c.cost_analysis()
    if isinstance(xla, list):   # jax 0.4.x returns [dict], newer a dict
        xla = xla[0]
    assert xla["flops"] < want / 4


def test_nested_scan():
    def inner(c, _):
        return c @ c, None

    def outer(c, _):
        y, _ = jax.lax.scan(inner, c, None, length=3)
        return y, None

    def f(x):
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = jax.jit(f).lower(x).compile()
    got = HloCost(c.as_text()).summary()["flops"]
    want = 15 * 2 * 128 ** 3
    assert abs(got - want) / want < 0.02, (got, want)


def test_dot_flops_plain():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((64, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 32), jnp.float32)
    c = jax.jit(f).lower(a, b).compile()
    got = HloCost(c.as_text()).summary()["flops"]
    want = 2 * 64 * 512 * 32
    assert abs(got - want) / want < 0.01, (got, want)


def test_collectives_counted_with_trips():
    devs = jax.device_count()
    if devs < 2:
        import pytest
        pytest.skip("needs >1 device")
    mesh = jax.make_mesh((devs,), ("d",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    from jax.sharding import PartitionSpec as P

    def step(x, _):
        return jax.lax.psum(x, "d"), None

    def f(x):
        y, _ = jax.lax.scan(step, x, None, length=4)
        return y

    sm = jax.shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                       check_vma=True)
    x = jax.ShapeDtypeStruct((1024,), jnp.float32)
    c = jax.jit(sm).lower(x).compile()
    s = HloCost(c.as_text()).summary()
    n = devs
    want = 4 * 2 * 1024 * 4 * (n - 1) / n      # 4 trips, ring all-reduce
    got = s["collective_bytes"]
    assert abs(got - want) / want < 0.05, (got, want)
