"""Per-shard slice cache: after any interleaved churn sequence, the
incrementally maintained stacked device state must be bit-exact against a
cold full restack, on 1/2/4/8-device meshes (subprocess per mesh size, like
the other multi-device suites).

Also pins the O(touched) accounting contract: a batch routed to one shard
(no rebalance, no capacity-class crossing) rewrites exactly one slice row
and never triggers a full restack.
"""
import pytest

from conftest import run_mesh_script

pytestmark = pytest.mark.kernel

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(ndev)d"
import numpy as np, jax, jax.numpy as jnp
import repro
from repro.core import distributed

ndev = %(ndev)d

def f32keys(raw):
    return np.unique(np.sort(raw).astype(np.float32)).astype(np.float64)

def assert_stack_equal(warm, cold, tag):
    assert warm.keys() == cold.keys(), tag
    for k in warm:
        a, b = warm[k], cold[k]
        if k == "leaf_kind":
            assert a == b, (tag, k)
        elif k in ("bcap", "dcap", "iters"):
            assert a == b, (tag, k, a, b)
        elif k == "packed":
            assert (a is None) == (b is None), (tag, k)
            if a is not None:
                for x, y in zip(a, b, strict=True):
                    np.testing.assert_array_equal(
                        np.asarray(x), np.asarray(y),
                        err_msg="%%s %%s" %% (tag, k))
        elif k in ("root", "leaves"):
            for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b), strict=True):
                np.testing.assert_array_equal(
                    np.asarray(x), np.asarray(y),
                    err_msg="%%s %%s" %% (tag, k))
        else:
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg="%%s %%s" %% (tag, k))

def check_vs_cold(idx, tag):
    # warm: whatever the incremental path maintained; cold: force a full
    # re-assembly of the same logical state and compare every array.
    warm = dict(idx._stacked())
    idx._packed_stack(idx._stack)
    warm = dict(idx._stack)
    idx._stack = None
    idx._dirty.clear()
    cold = idx._stacked()
    idx._packed_stack(cold)
    assert_stack_equal(warm, cold, tag)

mesh = jax.make_mesh((ndev,), ("data",))
for seed in (3, 11):
    rng = np.random.default_rng(seed + 97 * ndev)
    base = f32keys(rng.lognormal(0, 0.8, 8_000) * 1e3)
    fresh = np.setdiff1d(f32keys(rng.lognormal(0, 0.8, 60_000) * 1e3), base)
    idx = distributed.ShardedDynamicIndex.build(
        jnp.asarray(base), mesh, n_leaves=32, eps=0.7)
    live = base.copy()
    ptr = 0
    for rnd in range(4):
        ins = fresh[ptr:ptr + 900]; ptr += 900
        idx.insert_batch(ins)
        live = np.sort(np.concatenate([live, ins]))
        dels = rng.choice(live, 250, replace=False)
        idx.delete_batch(dels)
        keep = np.ones(live.size, bool)
        keep[np.searchsorted(live, np.unique(dels))] = False
        live = live[keep]
        check_vs_cold(idx, "seed %%d round %%d" %% (seed, rnd))
        q = rng.permutation(np.concatenate(
            [rng.choice(live, 400), fresh[-16:],
             np.asarray(idx.splits, np.float64) if idx.n_shards > 1
             else np.zeros(0)]))
        lo = np.searchsorted(live, q, side="left")
        hi = np.searchsorted(live, q, side="right")
        for uk in (False, True):
            f, r = idx.find(jnp.asarray(q), use_kernel=uk)
            np.testing.assert_array_equal(np.asarray(r), lo)
            np.testing.assert_array_equal(np.asarray(f), hi > lo)

# ---- O(touched) accounting: one quiet batch into one shard ------------
rng = np.random.default_rng(5)
base = f32keys(rng.lognormal(0, 0.8, 8_000) * 1e3)
idx = distributed.ShardedDynamicIndex.build(
    jnp.asarray(base), mesh, n_leaves=32, eps=0.7, rebalance_ratio=None)
jax.block_until_ready(idx.find(jnp.asarray(base[:64]), use_kernel=False)[1])
# prime shard 0's delta capacity so the measured batch cannot cross a
# power of two (which would legitimately force a full restack)
span0 = float(idx.splits[0]) if idx.n_shards > 1 else float(base[-1])
pool = np.setdiff1d(f32keys(rng.uniform(base[0] / 2, span0, 9_000)), base)
idx.insert_batch(pool[:2_000])
jax.block_until_ready(idx.find(jnp.asarray(base[:64]), use_kernel=False)[1])
caps = (idx._bcaps.copy(), idx._dcaps.copy())
rows0, full0 = idx.restack_rows, idx.restack_full
idx.insert_batch(pool[2_000:2_128])         # one shard, no capacity change
jax.block_until_ready(idx.find(jnp.asarray(base[:64]), use_kernel=False)[1])
assert np.array_equal(caps[0], idx._bcaps), "base capacity must not move"
assert np.array_equal(caps[1], idx._dcaps), "delta capacity must not move"
assert idx.restack_full == full0, "quiet batch must not full-restack"
assert idx.restack_rows - rows0 == 1, \
    "one touched shard must rewrite exactly one row, got %%d" %% (
        idx.restack_rows - rows0)
print("RESTACK_OK ndev=%(ndev)d")
"""


def _run(ndev: int):
    run_mesh_script(_SCRIPT % {"ndev": ndev}, f"RESTACK_OK ndev={ndev}")


@pytest.mark.parametrize("ndev", [1, 2])
def test_restack_cache_bit_exact_small_mesh(ndev):
    _run(ndev)


@pytest.mark.slow
@pytest.mark.parametrize("ndev", [4, 8])
def test_restack_cache_bit_exact_large_mesh(ndev):
    _run(ndev)
