"""End-to-end behaviour tests for the paper's system: agile reuse builds
correct indices, the full roster answers lookups exactly, the distributed
service and data pipeline resolve addresses, and a short LM training run
learns (loss decreases)."""
import numpy as np
import jax.numpy as jnp
import pytest

import repro  # noqa: F401
from repro.core import btree, pgm, radix_spline, reuse, rmi, rmrt, synth
from repro.core.updates import DynamicRMI

RNG = np.random.default_rng(0)


@pytest.fixture(scope="module")
def pools():
    sp = synth.generate_pool(0.9, limit=400, seed=0)
    return (reuse.build_pool(sp, kind="linear"),
            reuse.build_pool(sp, kind="mlp", train_steps=300))


@pytest.fixture(scope="module")
def keys():
    return jnp.asarray(np.sort(RNG.lognormal(0, 0.8, 120_000) * 1e9))


def _truth(keys, q):
    return jnp.searchsorted(keys, q, side="left")


def test_algorithm1_reuse_or_train(pools, keys):
    lin_pool, _ = pools
    m = lin_pool.reuse_or_train(keys, enqueue=False)
    pred = m.predict(keys)
    r = jnp.arange(keys.shape[0]) - pred
    assert float(r.min()) >= float(m.err_lo) - 1e-6
    assert float(r.max()) <= float(m.err_hi) + 1e-6


def test_full_roster_exact_lookups(pools, keys):
    lin_pool, mlp_pool = pools
    q = jnp.asarray(RNG.choice(np.asarray(keys), 5_000))
    qn = jnp.asarray(np.sort(RNG.lognormal(0, 0.8, 1_000) * 1e9))
    truth, truth_n = _truth(keys, q), _truth(keys, qn)
    cases = {
        "btree": btree.build_btree(keys),
        "rmi": rmi.build_rmi(keys, 256, kind="linear"),
        "rmi-mr": rmi.build_rmi(keys, 256, kind="linear", pool=lin_pool),
        "rmi-nn-mr": rmi.build_rmi(keys, 256, kind="mlp", pool=mlp_pool,
                                   train_steps=100),
        "pgm": pgm.build_pgm(keys, eps=64),
        "rs": radix_spline.build_rs(keys, eps=32),
        "rmrt": rmrt.build_rmrt(keys, leaf_cap=2048, fanout=32,
                                kind="linear", pool=lin_pool),
    }
    looks = {"btree": btree.lookup, "pgm": pgm.lookup,
             "rs": radix_spline.lookup, "rmrt": rmrt.lookup}
    for name, idx in cases.items():
        look = looks.get(name, rmi.lookup)
        np.testing.assert_array_equal(np.asarray(look(idx, q)),
                                      np.asarray(truth), err_msg=name)
        np.testing.assert_array_equal(np.asarray(look(idx, qn)),
                                      np.asarray(truth_n), err_msg=name)


def test_paper_bounds_mode(pools, keys):
    """Theorem 3.3 windows (paper-faithful mode) still give exact lookups
    through the verified search."""
    lin_pool, _ = pools
    idx = rmi.build_rmi(keys, 256, kind="linear", pool=lin_pool,
                        paper_bounds=True)
    q = jnp.asarray(RNG.choice(np.asarray(keys), 3_000))
    np.testing.assert_array_equal(np.asarray(rmi.lookup(idx, q)),
                                  np.asarray(_truth(keys, q)))


def test_dynamic_index_inserts(pools, keys):
    lin_pool, _ = pools
    d = DynamicRMI.build(keys, pool=lin_pool, eps=0.9, n_leaves=128,
                         kind="linear")
    ins = RNG.lognormal(0, 0.8, 20_000) * 1e9
    d.insert_batch(ins)
    f, _ = d.find(jnp.asarray(RNG.choice(ins, 500)))
    assert bool(jnp.all(f))
    f2, _ = d.find(jnp.asarray(RNG.choice(np.asarray(keys), 500)))
    assert bool(jnp.all(f2))
    assert d.rebuilds > 0          # Lemma 4.1 budgets actually trigger


def test_indexed_dataset_pipeline(pools):
    from repro.data.indexed_dataset import IndexedDataset
    lin_pool, _ = pools
    ds = IndexedDataset.create(pool=lin_pool, eps=0.9, n_leaves=64)
    for s in range(3):
        ds.add_shard(np.sort(RNG.lognormal(0, 0.5, 30_000)) * 1e6
                     + s * 1e11)
    q = RNG.choice(ds.shards[1].keys, 300)
    sid, off = ds.locate(q)
    assert (sid == 1).all()
    np.testing.assert_allclose(ds.shards[1].keys[off], q)


def test_lm_training_learns():
    """~1M-param reduced LM: 30 steps must reduce loss."""
    from repro.launch.train import train
    losses = train("qwen3-4b", steps=30, batch=4, seq=64, lr=3e-3,
                   reduced=True, ckpt_dir=None, d_model=64, log_every=100)
    assert losses[-1] < losses[0] * 0.9, (losses[0], losses[-1])
