"""Shared test fixtures/helpers.

``run_mesh_script`` is the forced-host-device-count subprocess harness used
by every multi-device suite (the XLA host device count locks at the first
jax init in a process, so any test needing an n>1 CPU mesh re-execs the
script in a fresh interpreter; the script itself sets XLA_FLAGS before
importing jax).
"""
import os
import subprocess
import sys

import pytest


@pytest.fixture(scope="module", autouse=True)
def _clear_jax_caches_per_module():
    """Bound in-process compiled-executable accumulation.

    The full tier-1 suite compiles hundreds of XLA:CPU programs in one
    interpreter; past a threshold the accumulated LLVM JIT state can
    segfault a later ``backend_compile`` (deterministic on a 1-core host
    once the range differential/serve suites landed — the crashing
    program itself compiles fine in isolation). Dropping the compile
    caches at module boundaries keeps the live-executable footprint at
    single-module scale. The per-test zero-retrace guards
    (``TRACE_COUNTS``) are unaffected: they only assert deltas within a
    single test function, and recompiles across modules are expected.
    """
    yield
    import jax

    jax.clear_caches()


def run_mesh_script(script: str, marker: str, timeout: int = 900) -> None:
    """Run ``script`` with `python -c` (PYTHONPATH=src, inherited XLA_FLAGS
    stripped so the script's own forced device count wins) and assert it
    exits 0 with ``marker`` on stdout."""
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert marker in proc.stdout, proc.stdout[-2000:]
