"""Drift monitoring + bound-checked pool hot-swap tests (``core.drift``,
the ``DynamicRMI``/``ShardedDynamicIndex`` wiring, and the ``repro.api``
facade).

Contract under test (core.drift module docstring):

  * the per-shard drift score is the binned two-sample KS statistic over
    the build-time reference histogram — ~0 at stationarity, monotone
    under a sustained distribution shift;
  * the drifted latch has hysteresis: set above ``thresh_hi``, cleared
    below ``thresh_lo``, HELD inside the band (no flapping);
  * a hot-swap commits per leaf only when the on-device Lemma 4.1 bound
    check passes; rejected leaves fall back to the ordinary refit path,
    and either way ``find``/``find_range`` stay bit-exact against the
    refit-only twin (checked on 1/2/4-device meshes through the serve
    front-end, whose TRACE_COUNTS guard pins zero retraces across swap
    commits);
  * drift state survives snapshot/restore.
"""
import numpy as np
import pytest

from conftest import run_mesh_script

import jax.numpy as jnp  # noqa: E402

import repro  # noqa: E402,F401
from repro.api import Index  # noqa: E402
from repro.core import drift as drift_mod  # noqa: E402
from repro.core import reuse, synth  # noqa: E402
from repro.core.updates import DynamicRMI  # noqa: E402


def _f32e(a) -> np.ndarray:
    """f32-exact f64 keys (the kernel-path precondition every suite uses)."""
    return np.asarray(a, np.float64).astype(np.float32).astype(np.float64)


@pytest.fixture(scope="module")
def pool():
    sp = synth.generate_pool(0.65, ns=256, seed=1)
    return reuse.build_pool(sp, kind="linear", m_sim=64)


@pytest.fixture(scope="module")
def base_keys():
    rng = np.random.default_rng(7)
    return np.unique(np.sort(_f32e(rng.lognormal(0.0, 0.5, 40_000))))


def _shifted(rng, n=3000):
    return np.sort(_f32e(rng.lognormal(1.5, 0.4, n)))


def _stationary(rng, n=3000):
    return np.sort(_f32e(rng.lognormal(0.0, 0.5, n)))


# ---------------------------------------------------------------------------
# Detector unit tests
# ---------------------------------------------------------------------------
def test_ks_score_monotone_under_shift(base_keys):
    st = drift_mod.init_drift(jnp.asarray(base_keys), m=64,
                              thresh_hi=0.08, thresh_lo=0.04)
    rng = np.random.default_rng(1)
    for _ in range(3):
        st = drift_mod.update_drift(st, jnp.asarray(_stationary(rng)))
    stationary = float(st.score)
    assert stationary < 0.04, "stationary ingest must not look like drift"
    assert not bool(st.drifted)
    scores = []
    for _ in range(5):
        st = drift_mod.update_drift(st, jnp.asarray(_shifted(rng)))
        scores.append(float(st.score))
    assert all(b > a for a, b in zip(scores, scores[1:])), \
        f"KS score must grow monotonically under sustained shift: {scores}"
    assert scores[0] > stationary
    assert bool(st.drifted), f"latch must set past thresh_hi: {scores}"
    assert st.updates == 8


def test_hysteresis_latch_does_not_flap():
    rng = np.random.default_rng(2)
    ref = np.unique(np.sort(_f32e(rng.lognormal(0.0, 0.5, 10_000))))
    st = drift_mod.init_drift(jnp.asarray(ref), m=64,
                              thresh_hi=0.08, thresh_lo=0.04)
    while not bool(st.drifted):
        st = drift_mod.update_drift(st, jnp.asarray(_shifted(rng, 2000)))
    # Stationary traffic now dilutes the accumulated shift: the score
    # decays through the (thresh_lo, thresh_hi) band, where the latch
    # must HOLD — it clears only below thresh_lo.
    in_band_steps = 0
    for _ in range(200):
        st = drift_mod.update_drift(st, jnp.asarray(_stationary(rng, 2000)))
        s = float(st.score)
        if s >= st.thresh_lo:
            assert bool(st.drifted), \
                f"latch flapped inside the hysteresis band at score {s}"
            if s < st.thresh_hi:
                in_band_steps += 1
        else:
            assert not bool(st.drifted), \
                f"latch must clear below thresh_lo, score {s}"
            break
    assert in_band_steps > 0, "decay never traversed the hysteresis band"
    # rebaseline resets score and latch
    st = drift_mod.rebaseline(st)
    assert float(st.score) == 0.0 and not bool(st.drifted)
    assert st.rebaselines == 1


# ---------------------------------------------------------------------------
# Swap commit / fallback on the single-host backend
# ---------------------------------------------------------------------------
def test_swap_vs_refit_bit_exact_single_host(pool, base_keys):
    kw = dict(pool=pool, eps=0.65, n_leaves=64)
    d_swap = DynamicRMI.build(jnp.asarray(base_keys), drift_bins=64,
                              drift_hi=0.08, drift_lo=0.04,
                              swap_on_drift=True, **kw)
    d_refit = DynamicRMI.build(jnp.asarray(base_keys), **kw)
    rng = np.random.default_rng(3)
    for _ in range(4):
        b = _shifted(rng)
        d_swap.insert_batch(b)
        d_refit.insert_batch(b)
    # shifted ingest latches the detector; the maintenance pass then runs
    # the bound-checked hot-swap over every pressured leaf
    assert bool(d_swap.drift.drifted), float(d_swap.drift.score)
    d_swap.maybe_swap()
    assert d_swap.swaps_committed > 0, "shifted ingest must commit swaps"
    live = d_swap.live_keys()
    assert np.array_equal(live, d_refit.live_keys())
    q = np.concatenate([live[::53], _f32e(live[::101] * (1 + 1e-3))])
    f1, r1 = d_swap.find(q, path="jnp")
    f2, r2 = d_refit.find(q, path="jnp")
    assert np.array_equal(np.asarray(f1), np.asarray(f2))
    assert np.array_equal(np.asarray(r1), np.asarray(r2))
    assert np.all(live[np.asarray(r1)[:live[::53].size]] == live[::53])
    lo = live[::201]
    hi = _f32e(lo * 1.02)
    rl1, rh1 = d_swap.find_range(lo, hi)
    rl2, rh2 = d_refit.find_range(lo, hi)
    assert np.array_equal(np.asarray(rl1), np.asarray(rl2))
    assert np.array_equal(np.asarray(rh1), np.asarray(rh2))


def test_bound_violation_rejects_and_falls_back(pool, base_keys):
    d = DynamicRMI.build(jnp.asarray(base_keys), pool=pool, eps=0.65,
                         n_leaves=64, drift_bins=64, drift_hi=0.08,
                         drift_lo=0.04, swap_on_drift=True)
    # Pressure far beyond any Lemma 4.1 budget: the on-device bound check
    # (new_budget >= n_inserts) must reject every candidate, leaving the
    # fitted state untouched so the refit path handles the leaves.
    before = np.asarray(d.index.err_lo)
    ids = np.asarray([5, 9, 21])
    d.n_inserts[ids] = 10_000_000
    assert d.maybe_swap(ids) == 0
    assert d.swap_rejects >= ids.size
    assert d.swaps_committed == 0
    assert np.array_equal(np.asarray(d.index.err_lo), before)
    # the refit fallback clears the pressure and keeps answers exact
    rb0 = d.rebuilds
    d._rebuild_leaves(ids)
    assert d.rebuilds > rb0
    assert np.all(d.n_inserts[ids] == 0)
    live = d.live_keys()
    q = live[::97]
    f, r = d.find(q, path="jnp")
    assert bool(np.all(np.asarray(f)))
    assert np.all(live[np.asarray(r)] == q)


def test_maintenance_swap_gated_on_latch(pool, base_keys):
    d = DynamicRMI.build(jnp.asarray(base_keys), pool=pool, eps=0.65,
                         n_leaves=64, drift_bins=64, drift_hi=0.08,
                         drift_lo=0.04)
    rng = np.random.default_rng(4)
    d.insert_batch(_stationary(rng, 500))
    # stationary: latch unset, the maintenance-style call must be a no-op
    assert not bool(d.drift.drifted)
    assert d.maybe_swap() == 0
    assert d.swaps_committed == 0


# ---------------------------------------------------------------------------
# Snapshot / restore round-trip (facade verbs)
# ---------------------------------------------------------------------------
def test_snapshot_restore_drift_roundtrip(pool, base_keys, tmp_path):
    ix = Index.build(jnp.asarray(base_keys), pool=pool, eps=0.65,
                     n_leaves=64, drift_bins=64, drift_hi=0.08,
                     drift_lo=0.04, swap_on_drift=True)
    rng = np.random.default_rng(5)
    for _ in range(3):
        ix.insert(_shifted(rng))
    ix.snapshot(str(tmp_path), 11)
    ix2 = Index.restore(str(tmp_path))
    d1, d2 = ix.backend, ix2.backend
    assert d2.drift is not None
    assert float(d2.drift.score) == float(d1.drift.score)
    assert bool(d2.drift.drifted) == bool(d1.drift.drifted)
    assert np.array_equal(np.asarray(d2.drift.ref), np.asarray(d1.drift.ref))
    assert np.array_equal(np.asarray(d2.drift.acc), np.asarray(d1.drift.acc))
    assert (d2.drift.updates, d2.drift.rebaselines) == \
        (d1.drift.updates, d1.drift.rebaselines)
    assert d2.swap_on_drift
    assert d2.swaps_committed == d1.swaps_committed
    assert d2.swap_rejects == d1.swap_rejects
    assert np.array_equal(ix2.drift_scores(), ix.drift_scores())
    live = ix.live_keys()
    q = live[::61]
    f1, r1 = ix.find(q, path="jnp")
    f2, r2 = ix2.find(q, path="jnp")
    assert np.array_equal(np.asarray(f1), np.asarray(f2))
    assert np.array_equal(np.asarray(r1), np.asarray(r2))
    # the restored monitor keeps accumulating (not a frozen copy)
    ix2.insert(_shifted(rng, 500))
    assert ix2.backend.drift.updates == d1.drift.updates + 1


# ---------------------------------------------------------------------------
# Sharded swap-vs-refit bit-exactness + serve-path zero-retrace guard,
# on 1/2/4-device meshes (fresh interpreter per device count).
# ---------------------------------------------------------------------------
_SCRIPT = r"""
import os, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(ndev)d"
import numpy as np, jax, jax.numpy as jnp
import repro
from repro.api import Index
from repro.core import distributed, reuse, synth
from repro.serve.frontend import BatchingFrontend, Request, ServeConfig

ndev = %(ndev)d
mesh = jax.make_mesh((ndev,), ("data",))
rng = np.random.default_rng(0)
f32e = lambda a: np.asarray(a, np.float64).astype(np.float32).astype(np.float64)
keys = np.unique(np.sort(f32e(rng.lognormal(0.0, 0.5, 40_000))))
sp = synth.generate_pool(0.65, ns=256, seed=1)
pool = reuse.build_pool(sp, kind="linear", m_sim=64)
kw = dict(mesh=mesh, pool=pool, eps=0.65, n_leaves=64)
ix = Index.build(jnp.asarray(keys), drift_bins=64, drift_hi=0.06,
                 drift_lo=0.03, swap_on_drift=True, **kw)
ref = Index.build(jnp.asarray(keys), **kw)      # refit-only twin

def committed():
    return sum(s.swaps_committed for s in ix.backend.shards)

fe = BatchingFrontend([ix.backend],
                      config=ServeConfig(latency_budget_s=1e-3))
fe.start()
fe.warmup((1, 128))
# --- ingest phase: shifted traffic latches the detector; delta growth
# crosses capacity classes here, so retraces are legitimate and unmeasured
for step in range(4):
    b = np.sort(f32e(rng.lognormal(1.5, 0.4, 3000)))
    fe.submit(Request(0, "insert", b)).result(timeout=600.0)
    ref.insert(b)
    fe.submit(Request(0, "find", rng.choice(b, 64))).result(timeout=600.0)
latched = int(ix.drift_scores()[:, 1].sum())
assert latched > 0, "shifted ingest must latch at least one shard"

# --- settle: let in-flight idle maintenance finish, drain every deferred
# repair (sweep refits may change the clamped search depth — a legitimate
# retrace, so it must happen BEFORE the measured window), then zero the
# pressure accounting so the window's pressure is exactly the batch below
# (ingest residue would otherwise make the refit fallback nondeterministic)
time.sleep(0.3)
ix.maybe_swap()
for s in ix.backend.shards:
    s.n_inserts[:] = 0.0

# --- warm the final shapes once (find class 128, range class 128)
live = ix.live_keys()
q = live[:: max(live.size // 120, 1)][:120]
lo = q[:100]
hi = f32e(lo * 1.02)
fe.submit(Request(0, "find", q)).result(timeout=600.0)
fe.submit(Request(0, "range", np.stack([lo, hi]))).result(timeout=600.0)

# --- measured window: pressure crafted to be at-risk but never over-
# budget.  Midpoints between consecutive base keys, routed per leaf via
# the shard's own (frozen) root, ~1/3 of each leaf's Lemma-4.1 budget on
# the smallest-budget leaves first, capped to the delta tier's current
# capacity-class headroom.  The idle maintenance pass can then only
# hot-swap (commit gate: refreshed budget covers the pressure), never
# refit, and no array shape changes: zero retraces, deterministically.
# Snapshots are taken BEFORE the insert — the dispatcher's idle
# _maintain may commit at any point after it, and all commits count.
from repro.core import rmi as rmi_mod
hot = ix.backend.shards[-1]
bk = np.asarray(hot.index.keys[: hot.base_n])
lv = np.asarray(rmi_mod.root_buckets(
    hot.index.root_kind, hot.index.root, jnp.asarray(bk),
    hot.index.n_leaves, hot.route_n))
head = hot.delta_keys.shape[0] - hot.delta_live - 64
parts = []
for leaf in np.argsort(hot.budget):
    m = int(0.3 * hot.budget[leaf]) + 2
    ks = bk[lv == leaf]
    if ks.size < m + 1 or m > head:
        continue
    p = np.linspace(0, ks.size - 2, m).astype(int)
    parts.append((ks[p] + ks[p + 1]) * 0.5)
    head -= m
    if head < 16:
        break
b = np.unique(f32e(np.concatenate(parts)))
assert b.size > 0, "no pressure batch fits the delta headroom"
before_f = distributed.TRACE_COUNTS["tenant_find"]
before_r = distributed.TRACE_COUNTS["tenant_range"]
swaps0 = committed()
ix.insert(b)                    # direct: same capacity classes throughout
ref.insert(b)
time.sleep(0.3)                 # idle window: dispatcher runs _maintain()
ix.maybe_swap()                 # same pass, deterministic
in_window = committed() - swaps0
assert in_window > 0, "no bound-held swap committed inside the window"

live = ix.live_keys()
assert np.array_equal(live, ref.live_keys())
q = live[:: max(live.size // 120, 1)][:120]
f1, r1 = fe.submit(Request(0, "find", q)).result(timeout=600.0)
f2, r2 = ref.find(q, path="jnp")
assert np.array_equal(np.asarray(f1), np.asarray(f2))
assert np.array_equal(np.asarray(r1), np.asarray(r2))
assert bool(np.all(np.asarray(f1)))
lo = q[:100]
hi = f32e(lo * 1.02)
rl1, rh1 = fe.submit(Request(0, "range",
                             np.stack([lo, hi]))).result(timeout=600.0)
rl2, rh2 = ref.find_range(lo, hi)
assert np.array_equal(np.asarray(rl1), np.asarray(rl2))
assert np.array_equal(np.asarray(rh1), np.asarray(rh2))
d_find = distributed.TRACE_COUNTS["tenant_find"] - before_f
d_range = distributed.TRACE_COUNTS["tenant_range"] - before_r
fe.stop()
assert d_find == 0 and d_range == 0, \
    ("retrace across swap commit", d_find, d_range)
print(f"DRIFT_OK ndev={ndev} swaps={committed()} latched={latched} "
      f"in_window={in_window} retraces=0")
"""


@pytest.mark.parametrize(
    "ndev", [1, 2, pytest.param(4, marks=pytest.mark.slow)])
def test_sharded_swap_bit_exact_zero_retrace(ndev):
    run_mesh_script(_SCRIPT % {"ndev": ndev}, f"DRIFT_OK ndev={ndev}")
