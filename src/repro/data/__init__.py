"""Data pipeline with learned-index integration (the paper's technique as a
first-class framework feature — DESIGN.md §3)."""
