"""IndexedDataset: sample-ID -> (shard, offset) resolution served by the
paper's agile-reuse learned index.

This is where "A Lazy Approach for Efficient Index Learning" plugs into the
training framework: streaming corpora arrive as shards of (sorted) sample
keys (document ids, hash keys); resolving a sample key to its storage
location is a learned-index lookup. New shards are indexed by *reusing*
pool models (build cost ~histogram + selection instead of training), and
in-place ingestion uses Lemma 4.1 to decide when a leaf model must be
rebuilt — exactly the paper's update path, embedded in a data pipeline.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.core import reuse as reuse_mod
from repro.core import synth
from repro.core.updates import DynamicRMI


@dataclass
class ShardInfo:
    shard_id: int
    keys: np.ndarray              # sorted *live* sample keys
    dyn: DynamicRMI               # two-tier dynamic index over the shard
    reuse_fraction: float

    @property
    def index(self):              # the underlying RMIIndex (base tier)
        return self.dyn.index


@dataclass
class IndexedDataset:
    """Sharded corpus with one learned index per shard + routing table."""
    pool: reuse_mod.ModelPool
    eps: float = 0.9
    n_leaves: int = 256
    shards: list = field(default_factory=list)
    boundaries: list = field(default_factory=list)   # max key per shard

    @classmethod
    def create(cls, eps: float = 0.9, kind: str = "linear",
               pool: reuse_mod.ModelPool | None = None, **kw):
        if pool is None:
            pool = reuse_mod.build_pool(synth.generate_pool(eps), kind=kind)
        return cls(pool=pool, eps=eps, **kw)

    # -- ingest ------------------------------------------------------------
    def add_shard(self, keys: np.ndarray) -> ShardInfo:
        """Index a new shard via agile model reuse (the paper's build path);
        the shard is served by a DynamicRMI so later appends/deletes ride
        the batched §4 update path instead of re-indexing."""
        keys = np.sort(np.asarray(keys, np.float64))
        dyn = DynamicRMI.build(jnp.asarray(keys), pool=self.pool,
                               eps=self.eps, n_leaves=self.n_leaves,
                               kind=self.pool.kind)
        info = ShardInfo(shard_id=len(self.shards), keys=keys, dyn=dyn,
                         reuse_fraction=dyn.index.reuse_fraction)
        self.shards.append(info)
        self.boundaries.append(keys[-1])
        return info

    def append_to_shard(self, shard_id: int, keys: np.ndarray) -> None:
        """Streaming ingest into an existing shard: one batched insert
        (vectorized route-sort-merge; Lemma 4.1 decides which leaf models
        rebuild) — the paper's in-place ingestion path.  Appended keys must
        stay below the next shard's boundary: shard routing is a
        searchsorted over the (sorted) boundary list, so an overreaching
        append would silently misroute every later query."""
        keys = np.asarray(keys, np.float64)
        if shard_id + 1 < len(self.boundaries) and keys.size and \
                keys.max() >= self.boundaries[shard_id + 1]:
            raise ValueError(
                f"append_to_shard({shard_id}): keys reach into shard "
                f"{shard_id + 1}'s range (>= {self.boundaries[shard_id + 1]})")
        info = self.shards[shard_id]
        info.dyn.insert_batch(keys)
        info.keys = info.dyn.live_keys()
        if info.keys.size:
            self.boundaries[shard_id] = info.keys[-1]

    def delete_samples(self, shard_id: int, keys: np.ndarray) -> None:
        """Batched tombstone delete of sample keys from a shard.  A fully
        drained shard keeps its old routing boundary (it simply answers
        found=False)."""
        info = self.shards[shard_id]
        info.dyn.delete_batch(np.asarray(keys, np.float64))
        info.keys = info.dyn.live_keys()
        if info.keys.size:
            self.boundaries[shard_id] = info.keys[-1]

    # -- resolve -------------------------------------------------------------
    def locate(self, sample_keys: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """(shard_id, offset) per key — the pipeline's address resolution.
        Offsets come from the dynamic find's two-tier live rank, so they
        stay exact under appended (delta-tier) and tombstoned samples."""
        q = np.asarray(sample_keys, np.float64)
        shard_of = np.searchsorted(np.asarray(self.boundaries), q, side="left")
        shard_of = np.clip(shard_of, 0, len(self.shards) - 1)
        offsets = np.empty(q.shape, np.int64)
        for sid in np.unique(shard_of):
            mask = shard_of == sid
            _, rank = self.shards[sid].dyn.find(jnp.asarray(q[mask]))
            offsets[mask] = np.asarray(rank)
        return shard_of, offsets

    def locate_range(self, lo_keys: np.ndarray, hi_keys: np.ndarray
                     ) -> list[tuple[int, np.ndarray]]:
        """Batch slicing: resolve inclusive key ranges ``[lo, hi]`` to
        their live sample keys — the pipeline's "fetch every sample in a
        key window" primitive (contiguous corpus slices, time windows).
        Each range runs through the owning shards' ``find_range`` (batched
        per shard), and a range spanning shard boundaries stitches the
        per-shard slices in shard order.  Returns, per input range, a list
        of (shard_id, keys) pieces; tombstoned samples are excluded and
        degenerate ranges (lo > hi, fully out-of-range) come back empty.
        """
        lo = np.asarray(lo_keys, np.float64)
        hi = np.asarray(hi_keys, np.float64)
        if lo.shape != hi.shape:
            raise ValueError("locate_range endpoint arrays must pair up")
        if not (np.all(np.isfinite(lo)) and np.all(np.isfinite(hi))):
            raise ValueError("range endpoints must be finite")
        bounds = np.asarray(self.boundaries)
        ns = len(self.shards)
        # A range touches every shard from lo's owner through hi's owner.
        s_lo = np.clip(np.searchsorted(bounds, lo, side="left"), 0, ns - 1)
        s_hi = np.clip(np.searchsorted(bounds, hi, side="left"), 0, ns - 1)
        s_hi = np.maximum(s_hi, s_lo)
        # One batched find_range per touched shard; a spanning range clamps
        # its endpoints to the shard's live span (interior shards are taken
        # whole — clamping to member keys keeps every endpoint finite, so
        # the +inf capacity padding never enters the rank algebra).
        pieces: list[dict] = [dict() for _ in range(lo.shape[0])]
        for sid in range(ns):
            rid = np.flatnonzero((s_lo <= sid) & (sid <= s_hi))
            if rid.size == 0:
                continue
            dyn = self.shards[sid].dyn
            live = dyn.live_keys()
            if live.size == 0:
                continue
            ql = np.where(s_lo[rid] == sid, lo[rid], live[0])
            qh = np.where(s_hi[rid] == sid, hi[rid], live[-1])
            rl, rh = dyn.find_range(jnp.asarray(ql), jnp.asarray(qh))
            for r, a, b in zip(rid, np.asarray(rl), np.asarray(rh), strict=True):
                pieces[r][sid] = live[int(a):int(b)]
        return [[(sid, piece[sid]) for sid in sorted(piece)
                 if piece[sid].size] for piece in pieces]

    @property
    def mean_reuse(self) -> float:
        return float(np.mean([s.reuse_fraction for s in self.shards])) \
            if self.shards else 0.0


def synthetic_token_stream(key: int, vocab: int, batch: int, seq: int):
    """Deterministic synthetic LM batches (zipf-ish unigram) — the loader
    used by examples/train_lm.py on CPU."""
    rng = np.random.default_rng(key)
    ranks = np.arange(1, vocab + 1)
    probs = 1.0 / ranks ** 1.1
    probs /= probs.sum()
    while True:
        toks = rng.choice(vocab, size=(batch, seq + 1), p=probs)
        yield toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32)
