"""IndexedDataset: sample-ID -> (shard, offset) resolution served by the
paper's agile-reuse learned index.

This is where "A Lazy Approach for Efficient Index Learning" plugs into the
training framework: streaming corpora arrive as shards of (sorted) sample
keys (document ids, hash keys); resolving a sample key to its storage
location is a learned-index lookup. New shards are indexed by *reusing*
pool models (build cost ~histogram + selection instead of training), and
in-place ingestion uses Lemma 4.1 to decide when a leaf model must be
rebuilt — exactly the paper's update path, embedded in a data pipeline.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.core import reuse as reuse_mod
from repro.core import rmi as rmi_mod
from repro.core import synth
from repro.core.updates import DynamicRMI


@dataclass
class ShardInfo:
    shard_id: int
    keys: np.ndarray              # sorted sample keys
    index: object                 # RMIIndex
    reuse_fraction: float


@dataclass
class IndexedDataset:
    """Sharded corpus with one learned index per shard + routing table."""
    pool: reuse_mod.ModelPool
    eps: float = 0.9
    n_leaves: int = 256
    shards: list = field(default_factory=list)
    boundaries: list = field(default_factory=list)   # max key per shard

    @classmethod
    def create(cls, eps: float = 0.9, kind: str = "linear",
               pool: reuse_mod.ModelPool | None = None, **kw):
        if pool is None:
            pool = reuse_mod.build_pool(synth.generate_pool(eps), kind=kind)
        return cls(pool=pool, eps=eps, **kw)

    # -- ingest ------------------------------------------------------------
    def add_shard(self, keys: np.ndarray) -> ShardInfo:
        """Index a new shard via agile model reuse (the paper's build path)."""
        keys = np.sort(np.asarray(keys, np.float64))
        idx = rmi_mod.build_rmi(jnp.asarray(keys), n_leaves=self.n_leaves,
                                kind=self.pool.kind, pool=self.pool)
        info = ShardInfo(shard_id=len(self.shards), keys=keys, index=idx,
                         reuse_fraction=idx.reuse_fraction)
        self.shards.append(info)
        self.boundaries.append(keys[-1])
        return info

    # -- resolve -------------------------------------------------------------
    def locate(self, sample_keys: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """(shard_id, offset) per key — the pipeline's address resolution."""
        q = np.asarray(sample_keys, np.float64)
        shard_of = np.searchsorted(np.asarray(self.boundaries), q, side="left")
        shard_of = np.clip(shard_of, 0, len(self.shards) - 1)
        offsets = np.empty(q.shape, np.int64)
        for sid in np.unique(shard_of):
            mask = shard_of == sid
            offsets[mask] = np.asarray(
                rmi_mod.lookup(self.shards[sid].index, jnp.asarray(q[mask])))
        return shard_of, offsets

    @property
    def mean_reuse(self) -> float:
        return float(np.mean([s.reuse_fraction for s in self.shards])) \
            if self.shards else 0.0


def synthetic_token_stream(key: int, vocab: int, batch: int, seq: int):
    """Deterministic synthetic LM batches (zipf-ish unigram) — the loader
    used by examples/train_lm.py on CPU."""
    rng = np.random.default_rng(key)
    ranks = np.arange(1, vocab + 1)
    probs = 1.0 / ranks ** 1.1
    probs /= probs.sum()
    while True:
        toks = rng.choice(vocab, size=(batch, seq + 1), p=probs)
        yield toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32)
