"""End-to-end training driver.

Runs REAL steps on the available devices (reduced configs on CPU; the full
mesh path is exercised by dryrun.py). Wires together: config -> data
pipeline -> shard_map train step -> checkpointing -> elastic controller.

  PYTHONPATH=src python -m repro.launch.train --arch granite-moe-1b-a400m \
      --reduced --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro  # noqa: F401
from repro.configs import get_arch
from repro.configs.reduced import reduce_cfg
from repro.data.indexed_dataset import synthetic_token_stream
from repro.launch.mesh import make_smoke_mesh
from repro.models import model as M
from repro.train import optimizer
from repro.train.checkpoint import Checkpointer
from repro.train.elastic import ElasticController
from repro.train.step import make_train_step


def train(arch: str, *, steps: int, batch: int, seq: int, lr: float,
          reduced: bool, ckpt_dir: str | None, ckpt_every: int = 50,
          d_model: int = 128, n_layers: int | None = None,
          log_every: int = 10, seed: int = 0):
    cfg = get_arch(arch)
    if reduced:
        cfg = reduce_cfg(cfg, d_model=d_model, n_layers=n_layers,
                         vocab=2048)
    mesh = make_smoke_mesh()
    key = jax.random.PRNGKey(seed)
    params = M.init_params(cfg, key)
    opt = optimizer.init(params)
    residual = jnp.zeros(())
    step_fn, _ = make_train_step(cfg, mesh, lr=lr, donate=False)
    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
    elastic = ElasticController(n_hosts=1)
    stream = synthetic_token_stream(seed, cfg.vocab_size, batch, seq)

    n_params = cfg.param_count()
    print(f"[train] {cfg.name}: {n_params/1e6:.1f}M params "
          f"({cfg.param_count(active_only=True)/1e6:.1f}M active), "
          f"batch={batch} seq={seq}")
    losses = []
    for step in range(steps):
        toks, labels = next(stream)
        if cfg.embed_input:
            rngl = np.random.default_rng(step)
            inputs = jnp.asarray(
                rngl.normal(0, 1, (batch, seq, cfg.d_model)), jnp.bfloat16)
        else:
            inputs = jnp.asarray(toks)
        pos = jnp.broadcast_to(jnp.arange(seq)[None], (batch, seq)).astype(jnp.int32)
        if cfg.rope == "mrope":
            pos = jnp.broadcast_to(pos[None], (3, batch, seq))
        t0 = time.time()
        params, opt, residual, metrics = step_fn(
            params, opt, residual, inputs, jnp.asarray(labels), pos)
        dt = time.time() - t0
        elastic.heartbeat(0, dt)
        losses.append(float(metrics["loss"]))
        if step % log_every == 0:
            print(f"step {step:4d} loss={losses[-1]:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
        if ckpt and step and step % ckpt_every == 0:
            ckpt.save(step, {"params": params, "opt": opt})
    if ckpt:
        ckpt.save(steps, {"params": params, "opt": opt}, blocking=True)
        ckpt.wait()
    print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-1b-a400m")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--n-layers", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    train(args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
          lr=args.lr, reduced=args.reduced, ckpt_dir=args.ckpt_dir,
          d_model=args.d_model, n_layers=args.n_layers)


if __name__ == "__main__":
    main()
