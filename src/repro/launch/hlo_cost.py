"""Trip-count-aware cost accounting over compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop *bodies once* (verified
in tests/test_hlo_cost.py), which silently undercounts every lax.scan — the
superblock stack, microbatch accumulation, flash-attention KV loop, loss
chunking. This module re-derives per-chip FLOPs / HBM traffic / collective
link traffic by walking the post-partition HLO with loop multipliers taken
from the ``known_trip_count`` backend annotations.

Model:
  * dot: 2 * numel(result) * prod(lhs contracting dims)   (exact)
  * elementwise/reduce inside fusions: numel(result) per op (minor term)
  * HBM bytes: at fusion/instruction granularity — result + operand buffer
    bytes (post-fusion buffers are what actually hits HBM)
  * collectives: ring model per-chip traffic (see parse ratios below),
    multiplied by enclosing loop trip counts
"""
from __future__ import annotations

import re
from collections import defaultdict

_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
          "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1,
          "pred": 1, "c64": 8, "c128": 16}

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+"
                     r"([\w\-]+)\(")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|s32|s16|s8|u64|u32|u16|u8|"
                       r"pred|c64|c128)\[([0-9,]*)\]")
_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition)=%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_TRIP_RE = re.compile(r'trip_count\\?":\{\\?"n\\?":\\?"(\d+)')
_OPND_RE = re.compile(r"\(([^)]*)\)")
_GROUP_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "exponential",
    "log", "rsqrt", "sqrt", "tanh", "negate", "abs", "power", "compare",
    "select", "and", "or", "xor", "convert", "floor", "ceil", "sign",
    "logistic", "remainder", "clamp", "expm1", "log1p",
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes_numel(type_str: str):
    """Total (bytes, numel) over all array shapes in a (possibly tuple)
    type string."""
    b = n = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        numel = 1
        for d in dims.split(","):
            if d:
                numel *= int(d)
        n += numel
        b += numel * _BYTES[dt]
    return b, n


class HloCost:
    def __init__(self, text: str):
        self.comps: dict[str, list[str]] = {}
        self.shapes: dict[str, str] = {}
        self._parse(text)
        self._memo: dict[str, dict] = {}

    def _parse(self, text: str):
        cur = None
        for line in text.splitlines():
            s = line.strip()
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{", s)
            if m and not s.startswith("%param"):
                cur = m.group(1)
                self.comps[cur] = []
                if s.startswith("ENTRY") or line.startswith("ENTRY"):
                    self.entry = cur
                continue
            if s == "}" or s.startswith("}"):
                continue
            if cur is None:
                continue
            self.comps[cur].append(s)
            dm = _DEF_RE.match(s)
            if dm:
                self.shapes[dm.group(1)] = dm.group(2)

    # ------------------------------------------------------------------
    def cost(self, comp: str | None = None) -> dict:
        comp = comp or self.entry
        if comp in self._memo:
            return self._memo[comp]
        total = {"flops": 0.0, "bytes": 0.0,
                 "coll": defaultdict(float), "coll_counts": defaultdict(float),
                 "bytes_by_op": defaultdict(float)}
        for line in self.comps.get(comp, []):
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            name, rtype, op = dm.groups()
            rbytes, rnumel = _shape_bytes_numel(rtype)

            if op == "while":
                body = _BODY_RE.search(line)
                tm = _TRIP_RE.search(line)
                trips = int(tm.group(1)) if tm else 1
                for cname in _CALL_RE.findall(line):
                    sub = self.cost(cname)
                    mult = trips if cname == (body.group(1) if body else "") \
                        else trips + 1
                    total["flops"] += sub["flops"] * mult
                    total["bytes"] += sub["bytes"] * mult
                    for k, v in sub["coll"].items():
                        total["coll"][k] += v * mult
                    for k, v in sub["coll_counts"].items():
                        total["coll_counts"][k] += v * mult
                    for k, v in sub["bytes_by_op"].items():
                        total["bytes_by_op"][k] += v * mult
                continue

            if op in ("fusion", "call", "conditional", "map"):
                for cname in _CALL_RE.findall(line):
                    sub = self.cost(cname)
                    for k in ("flops",):
                        total[k] += sub[k]
                    for k, v in sub["coll"].items():
                        total["coll"][k] += v
                    for k, v in sub["coll_counts"].items():
                        total["coll_counts"][k] += v
                    for k, v in sub["bytes_by_op"].items():
                        total["bytes_by_op"][k] += v
                # In-place dynamic-update-slice fusions (scan residual
                # stacking): XLA aliases input/output, so the true traffic
                # is the UPDATE region, not the whole carried buffer —
                # billing full size overcounts sequence-scan archs ~50x.
                eff = self._fusion_effective_bytes(line, op, rbytes)
                # fusions that internally slice a large buffer (stacked scan
                # params) only *read* the slice: cap per-operand traffic at
                # the effective result size
                b = eff + self._operand_bytes(line, cap=max(eff, 1))
                total["bytes"] += b
                total["bytes_by_op"]["fusion"] += b
                continue

            if op == "dot":
                k_contract = self._dot_contract(line)
                total["flops"] += 2.0 * rnumel * k_contract
                b = rbytes + self._operand_bytes(line)
                total["bytes"] += b
                total["bytes_by_op"]["dot"] += b
                continue

            base = op.split(".")[0]
            if any(base.startswith(c) for c in _COLLECTIVES):
                kind = next(c for c in _COLLECTIVES if base.startswith(c))
                if base.endswith("-done"):
                    continue
                n = self._group_size(line)
                if kind == "all-gather":
                    traffic = rbytes * (n - 1) / n
                elif kind == "reduce-scatter":
                    traffic = rbytes * (n - 1)
                elif kind == "all-reduce":
                    traffic = 2 * rbytes * (n - 1) / n
                    total["flops"] += rnumel  # reduction adds
                elif kind == "all-to-all":
                    traffic = rbytes * (n - 1) / n
                else:
                    traffic = rbytes
                total["coll"][kind] += traffic
                total["coll_counts"][kind] += 1
                total["bytes"] += rbytes
                continue

            if base in _ELEMENTWISE:
                total["flops"] += rnumel
                b = rbytes + self._operand_bytes(line)
                total["bytes"] += b
                total["bytes_by_op"]["elementwise"] += b
            elif base in ("reduce", "reduce-window"):
                total["flops"] += self._operand_numel(line)
                b = rbytes + self._operand_bytes(line)
                total["bytes"] += b
                total["bytes_by_op"]["reduce"] += b
            elif base in ("slice", "dynamic-slice", "gather"):
                # read only what the result needs
                b = 2 * rbytes
                total["bytes"] += b
                total["bytes_by_op"][base] += b
            elif base in ("broadcast", "iota", "reshape"):
                # never materialized on TPU (fused into consumers / bitcast)
                pass
            elif base == "dynamic-update-slice":
                # in-place post-optimization: touch 2x the update region
                ops_ = self._operand_names(line)
                upd = self.shapes.get(ops_[1]) if len(ops_) > 1 else None
                ub = _shape_bytes_numel(upd)[0] if upd else rbytes
                total["bytes"] += 2 * ub
                total["bytes_by_op"][base] += 2 * ub
            elif base in ("copy", "transpose", "concatenate",
                          "pad", "scatter", "reverse", "sort"):
                b = rbytes + self._operand_bytes(line)
                total["bytes"] += b
                total["bytes_by_op"][base] += b
            # get-tuple-element / tuple / parameter / constant / bitcast: free
        self._memo[comp] = total
        return total

    # ------------------------------------------------------------------
    def _operand_names(self, line: str):
        m = _OPND_RE.search(line[line.index("("):] if "(" in line else line)
        if not m:
            return []
        return re.findall(r"%([\w.\-]+)", m.group(1))

    def _fusion_effective_bytes(self, line: str, op: str,
                                rbytes: float) -> float:
        """Result-side traffic of a fusion: elements produced by an
        in-place dynamic-update-slice root are billed at their UPDATE size
        (input/output aliasing); everything else at full size."""
        m = re.search(r"calls=%([\w.\-]+)", line)
        if not m:
            return rbytes
        comp = m.group(1)
        roots = [ln for ln in self.comps.get(comp, [])
                 if ln.startswith("ROOT")]
        if not roots:
            return rbytes
        root = roots[0]
        rm = _DEF_RE.match(root)
        if not rm:
            return rbytes
        rop = rm.group(3)
        # look through elementwise wrappers (convert(DUS(...)) roots fuse
        # into the in-place update on TPU)
        hops = 0
        while rop in ("convert", "bitcast", "copy") and hops < 3:
            prods = self._operand_names(root)
            if not prods:
                break
            producer = next((ln for ln in self.comps.get(comp, [])
                             if f"%{prods[0]} =" in ln or
                             ln.lstrip("ROOT %").startswith(prods[0] + " ")),
                            None)
            if producer is None:
                break
            pm = _DEF_RE.match(producer)
            if not pm:
                break
            root, rop = producer, pm.group(3)
            hops += 1
        if rop == "dynamic-update-slice":
            ops_ = self._operand_names(root)
            upd = self.shapes.get(ops_[1]) if len(ops_) > 1 else None
            return _shape_bytes_numel(upd)[0] if upd else rbytes
        if rop == "tuple":
            # per element: DUS-produced -> update size; else element size
            total = 0.0
            for nm in self._operand_names(root):
                t = self.shapes.get(nm, "")
                producer = next((ln for ln in self.comps.get(comp, [])
                                 if ln.lstrip("ROOT %").startswith(nm + " ")
                                 or f"%{nm} =" in ln), None)
                if producer and " dynamic-update-slice(" in producer:
                    o2 = self._operand_names(producer)
                    upd = self.shapes.get(o2[1]) if len(o2) > 1 else None
                    total += _shape_bytes_numel(upd)[0] if upd else \
                        _shape_bytes_numel(t)[0]
                else:
                    total += _shape_bytes_numel(t)[0]
            return total or rbytes
        return rbytes

    def _operand_bytes(self, line: str, cap: float | None = None) -> float:
        b = 0
        for n in self._operand_names(line):
            t = self.shapes.get(n)
            if t:
                ob = _shape_bytes_numel(t)[0]
                b += min(ob, cap) if cap else ob
        return b

    def _operand_numel(self, line: str) -> float:
        n_ = 0
        for n in self._operand_names(line):
            t = self.shapes.get(n)
            if t:
                n_ += _shape_bytes_numel(t)[1]
        return n_

    def _dot_contract(self, line: str) -> float:
        ops = self._operand_names(line)
        if not ops:
            return 1.0
        lhs_t = self.shapes.get(ops[0])
        if lhs_t is None:
            return 1.0
        m = _SHAPE_RE.search(lhs_t)
        if not m:
            return 1.0
        dims = [int(d) for d in m.group(2).split(",") if d]
        cm = re.search(r"lhs_contracting_dims=\{([0-9,]+)\}", line)
        if not cm:
            return 1.0
        k = 1.0
        for ci in cm.group(1).split(","):
            ci = int(ci)
            if ci < len(dims):
                k *= dims[ci]
        return k

    def _group_size(self, line: str) -> int:
        gm = _GROUP_RE.search(line)
        if gm:
            return max(len(gm.group(1).split(",")), 2)
        return 2

    # ------------------------------------------------------------------
    def summary(self) -> dict:
        c = self.cost()
        return {
            "flops": c["flops"],
            "bytes": c["bytes"],
            "collective_bytes_by_kind": dict(c["coll"]),
            "collective_counts": dict(c["coll_counts"]),
            "collective_bytes": float(sum(c["coll"].values())),
        }
