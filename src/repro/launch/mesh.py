"""Production mesh construction.

IMPORTANT: functions, not module-level constants — importing this module
never touches jax device state. The dry-run entry point sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* any jax
import (see dryrun.py lines 1-2); smoke tests and benchmarks see 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod(s): 16x16 = 256 chips per pod; 2 pods for multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_smoke_mesh():
    """(1,1,1) mesh on a single device: the same manual-SPMD code paths run
    with every collective a no-op — used by CPU smoke/integration tests."""
    return jax.make_mesh((1, 1, 1), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)


def make_mesh_for(devices: int, *, model_parallel: int = 16):
    """Elasticity helper: best (pod, data, model) factorization for an
    arbitrary surviving-device count (see train/elastic.py)."""
    model = min(model_parallel, devices)
    while devices % model:
        model -= 1
    rest = devices // model
    pod = 2 if rest % 2 == 0 and rest >= 32 else 1
    data = rest // pod
    return jax.make_mesh((pod, data, model), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
