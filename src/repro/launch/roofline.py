"""Roofline aggregation: reads experiments/dryrun/*.json (written by
dryrun.py / sweep.py) and emits the EXPERIMENTS.md §Roofline table.

Per (arch x shape x mesh):
  compute_s    = HLO_FLOPs_per_chip / 197e12        (bf16 peak, v5e)
  memory_s     = HLO_bytes_per_chip / 819e9         (HBM BW)
  collective_s = per-chip link traffic / 50e9       (ICI, ring model)
  dominant     = argmax of the three
  MODEL_FLOPS  = 6*N*D (train) | 2*N*D (prefill) | 2*N_active*B (decode)
  useful       = MODEL_FLOPS / (HLO_FLOPs_per_chip * chips)

  PYTHONPATH=src python -m repro.launch.roofline --dir experiments/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import SHAPES, get_arch

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_PER_CHIP = 16e9          # v5e


def model_flops(arch: str, shape_name: str) -> float:
    if arch == "index_service":
        return 0.0
    cfg = get_arch(arch)
    n_active = cfg.param_count(active_only=True)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch      # decode: 1 token/seq


def load(dir_: str) -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        if os.path.basename(path).startswith("_"):
            continue
        with open(path) as f:
            r = json.load(f)
        mf = model_flops(r["arch"], r.get("shape", "train_4k")) \
            if r["arch"] != "index_service" else 0.0
        hlo_total = r["hlo_flops_per_chip"] * r["chips"]
        r["model_flops"] = mf
        r["useful_ratio"] = mf / hlo_total if hlo_total else 0.0
        rr = r["roofline"]
        bound = max(rr["compute_s"], rr["memory_s"], rr["collective_s"])
        # roofline fraction: how much of the bound step time is the ideal
        # compute time (1.0 = perfectly compute-bound at peak)
        r["roofline_fraction"] = rr["compute_s"] / bound if bound else 0.0
        r["hbm_ok"] = r["memory"]["peak_bytes_est"] <= HBM_PER_CHIP
        rows.append(r)
    return rows


def fmt_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute_s | memory_s | collective_s | "
           "dominant | useful | roofline_frac | HBM GB/chip | fits |")
    sep = "|" + "---|" * 11
    out = [hdr, sep]
    for r in rows:
        rr = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rr['compute_s']:.3e} | {rr['memory_s']:.3e} "
            f"| {rr['collective_s']:.3e} | {rr['dominant'][:-2]} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} "
            f"| {r['memory']['peak_bytes_est']/1e9:.2f} "
            f"| {'Y' if r['hbm_ok'] else 'NO'} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    rows = load(args.dir)
    print(fmt_table(rows))
    # pick hillclimb candidates
    single = [r for r in rows if r["mesh"] == "single"
              and r["arch"] != "index_service"]
    if single:
        worst = min(single, key=lambda r: r["roofline_fraction"])
        coll = max(single, key=lambda r: r["roofline"]["collective_s"] /
                   max(sum(r["roofline"][k] for k in
                           ("compute_s", "memory_s", "collective_s")), 1e-30))
        print(f"\nworst roofline fraction: {worst['arch']} {worst['shape']} "
              f"({worst['roofline_fraction']:.2f})")
        print(f"most collective-bound:   {coll['arch']} {coll['shape']}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
