"""Launch entry points: mesh construction, dry-run, roofline, train/serve."""
