"""Dry-run sweep driver: every (arch x shape x mesh) cell as a subprocess
(XLA_FLAGS isolation + per-cell timeout + crash containment), resumable —
existing result JSONs are skipped.

  PYTHONPATH=src python -m repro.launch.sweep --out experiments/dryrun
  PYTHONPATH=src python -m repro.launch.sweep --mesh multi --timeout 1800
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.configs import SHAPES, get_arch, list_archs


def cells(meshes=("single", "multi")):
    out = []
    for arch in list_archs():
        cfg = get_arch(arch)
        for shape in SHAPES.values():
            if shape.name == "long_500k" and not cfg.subquadratic:
                continue  # pure full-attention archs skip (DESIGN.md)
            for mesh in meshes:
                out.append((arch, shape.name, mesh))
    for mesh in meshes:
        out.append(("index_service", "lookup_64k", mesh))
    return out


def run(out_dir: str, meshes, timeout: int, only_arch=None, jobs=1):
    todo = []
    for arch, shape, mesh in cells(meshes):
        if only_arch and arch != only_arch:
            continue
        path = os.path.join(out_dir, f"{arch}__{shape}__{mesh}.json")
        if os.path.exists(path):
            continue
        todo.append((arch, shape, mesh, path))
    print(f"[sweep] {len(todo)} cells to run")
    results = []
    for i, (arch, shape, mesh, _path) in enumerate(todo):
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--mesh", mesh, "--out", out_dir]
        t0 = time.time()
        try:
            proc = subprocess.run(cmd, capture_output=True, text=True,
                                  timeout=timeout,
                                  env=dict(os.environ, PYTHONPATH="src"))
            ok = proc.returncode == 0
            err = proc.stderr.strip().splitlines()[-1] if (proc.stderr and
                                                           not ok) else ""
        except subprocess.TimeoutExpired:
            ok, err = False, f"timeout>{timeout}s"
        dt = time.time() - t0
        status = "ok" if ok else f"FAIL ({err[:120]})"
        print(f"[{i+1}/{len(todo)}] {arch} {shape} {mesh}: {status} "
              f"({dt:.0f}s)", flush=True)
        results.append({"arch": arch, "shape": shape, "mesh": mesh,
                        "ok": ok, "seconds": round(dt, 1), "error": err})
        with open(os.path.join(out_dir, "_sweep_log.json"), "w") as f:
            json.dump(results, f, indent=1)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--arch", default=None)
    ap.add_argument("--timeout", type=int, default=2400)
    args = ap.parse_args()
    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    os.makedirs(args.out, exist_ok=True)
    run(args.out, meshes, args.timeout, only_arch=args.arch)


if __name__ == "__main__":
    main()
