"""Serving driver: batched request loop (prefill + decode) on the local
mesh, with paged-KV bookkeeping and the learned page table.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
      --requests 8 --new-tokens 24
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

import repro  # noqa: F401
from repro.configs import get_arch
from repro.configs.reduced import reduce_cfg
from repro.launch.mesh import make_smoke_mesh
from repro.models import model as M
from repro.serve import step as serve_step
from repro.serve.kvcache import PagedKVCache, learned_page_table


def serve(arch: str, *, reduced: bool, requests: int, prompt_len: int,
          new_tokens: int, d_model: int = 128, seed: int = 0):
    cfg = get_arch(arch)
    if reduced:
        cfg = reduce_cfg(cfg, d_model=d_model, vocab=2048)
    mesh = make_smoke_mesh()
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    prefill, _ = serve_step.make_prefill(cfg, mesh)
    decode, _ = serve_step.make_decode_step(cfg, mesh)

    S_max = prompt_len + new_tokens
    rng = np.random.default_rng(seed)
    B = requests
    if cfg.embed_input:
        prompts = jnp.asarray(
            rng.normal(0, 1, (B, prompt_len, cfg.d_model)), jnp.bfloat16)
    else:
        prompts = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, prompt_len)), jnp.int32)
    pos = jnp.broadcast_to(jnp.arange(prompt_len)[None],
                           (B, prompt_len)).astype(jnp.int32)
    if cfg.rope == "mrope":
        pos = jnp.broadcast_to(pos[None], (3, B, prompt_len))

    caches = M.init_cache(cfg, B, S_max)
    t0 = time.time()
    logits, caches = prefill(params, caches, prompts, pos)
    t_pre = time.time() - t0
    tok = jnp.argmax(logits[:, :cfg.vocab_size], -1).astype(jnp.int32)[:, None]

    # paged-KV bookkeeping (control plane) alongside the decode loop
    page = 16
    pkv = PagedKVCache(n_pages=B * (S_max // page + 1), page_size=page,
                       n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
                       n_layers=1)
    for r in range(B):
        for blk in range(S_max // page + 1):
            pkv.allocate(r, blk)

    out = [np.asarray(tok[:, 0])]
    t0 = time.time()
    for i in range(new_tokens):
        dpos = jnp.full((B, 1), prompt_len + i, jnp.int32)
        if cfg.rope == "mrope":
            dpos = jnp.broadcast_to(dpos[None], (3, B, 1))
        if cfg.embed_input:
            tok_in = jnp.asarray(rng.normal(0, 1, (B, 1, cfg.d_model)),
                                 jnp.bfloat16)
        else:
            tok_in = tok
        nxt, caches = decode(params, caches, tok_in, dpos,
                             jnp.asarray(prompt_len + i, jnp.int32))
        tok = nxt[:, None]
        out.append(np.asarray(nxt))
    dt = time.time() - t0
    lookup, keys, pages = learned_page_table(pkv.table)
    q = keys[:: max(len(keys) // 16, 1)]
    assert bool(jnp.all(lookup(q) == pages[jnp.searchsorted(keys, q)]))
    print(f"[serve] {cfg.name}: prefill {t_pre:.2f}s, "
          f"{B * new_tokens / max(dt, 1e-9):.1f} tok/s decode, "
          f"learned page table exact over {len(pkv.table)} pages")
    return np.stack(out, 1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()
    serve(args.arch, reduced=args.reduced, requests=args.requests,
          prompt_len=args.prompt_len, new_tokens=args.new_tokens)


if __name__ == "__main__":
    main()
