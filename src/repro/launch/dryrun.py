import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile one (arch x shape x mesh) cell with
ShapeDtypeStruct stand-ins (no allocation), print/record memory analysis,
cost analysis and the parsed collective schedule.

The two lines above MUST precede any jax import (jax locks the device count
on first init); smoke tests and benches never import this module, so they
see 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k \
      --mesh single --out experiments/dryrun/
  PYTHONPATH=src python -m repro.launch.dryrun --arch index_service --mesh multi
"""
import argparse
import json
import re
import sys
import time

import jax
import jax.numpy as jnp

import repro  # noqa: F401  (x64 for the index core)
from repro.configs import SHAPES, get_arch, list_archs
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.serve import step as serve_step
from repro.train import optimizer
from repro.train.step import batch_shapes, make_train_step

# v5e hardware constants (DESIGN.md §7)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link (~per-direction)


def _sharded_sds(tree_shapes, tree_specs, mesh):
    def f(s, spec):
        return jax.ShapeDtypeStruct(
            s.shape, s.dtype,
            sharding=jax.sharding.NamedSharding(mesh, spec))
    return jax.tree.map(f, tree_shapes, tree_specs)


_COLL_RE = re.compile(
    r"(\w+(?:\.\d+)?)\s*=\s*(\w+\[[^\]]*\](?:[^ ]*)?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(", )
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|s32|s8|u64|u32|u8|pred)\[([0-9,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")

_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
          "s8": 1, "u64": 8, "u32": 4, "u8": 1, "pred": 1}


def parse_collectives(hlo_text: str) -> dict:
    """Sum per-chip link traffic by collective kind from post-partition HLO.

    Ring-model per-chip traffic from the op's *result* shape R and group
    size n:  all-gather (n-1)/n * R;  reduce-scatter (n-1) * R (result is
    1/n of the input);  all-reduce 2(n-1)/n * R;  all-to-all (n-1)/n * R;
    collective-permute R.
    """
    totals = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
              "all-to-all": 0.0, "collective-permute": 0.0}
    counts = dict.fromkeys(totals, 0)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(3)
        if m.group(4):  # -start of a start/done pair; done has no shape
            pass
        sm = _SHAPE_RE.search(m.group(2))
        if not sm:
            continue
        dt, dims = sm.group(1), sm.group(2)
        numel = 1
        for d in dims.split(","):
            if d:
                numel *= int(d)
        nbytes = numel * _BYTES[dt]
        gm = _GROUP_RE.search(line)
        n = len(gm.group(1).split(",")) if gm else 2
        if kind == "all-gather":
            traffic = nbytes * (n - 1) / n
        elif kind == "reduce-scatter":
            traffic = nbytes * (n - 1)
        elif kind == "all-reduce":
            traffic = 2 * nbytes * (n - 1) / n
        elif kind == "all-to-all":
            traffic = nbytes * (n - 1) / n
        else:
            traffic = nbytes
        totals[kind] += traffic
        counts[kind] += 1
    return {"bytes_by_kind": totals, "counts": counts,
            "total_bytes": sum(totals.values())}


def lower_cell(arch: str, shape_name: str, mesh, *, compress_pod=False,
               microbatch: int | None = None, psum_bf16: bool = False,
               replicate_weights: bool = False):
    """Returns (lowered, meta) for one cell."""
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    meta = {"arch": arch, "shape": shape_name, "kind": shape.kind,
            "psum_bf16": psum_bf16, "replicate_weights": replicate_weights}

    if shape.kind == "train":
        from repro.train.step import auto_microbatch
        if microbatch is None:
            microbatch = auto_microbatch(cfg, shape, mesh)
        meta["microbatch"] = microbatch
        fn, in_specs = make_train_step(
            cfg, mesh, compress_pod=compress_pod, microbatch=microbatch,
            psum_dtype=jnp.bfloat16 if psum_bf16 else None)
        p = M.param_shapes(cfg)
        o = optimizer.init_shapes(p)
        b = batch_shapes(cfg, shape)
        if compress_pod:
            from repro.train.grad_compress import init_residual
            res = init_residual(p, shapes_only=True)
        else:
            res = jax.ShapeDtypeStruct((), jnp.float32)
        from jax.sharding import PartitionSpec as P
        args = (_sharded_sds(p, in_specs[0], mesh),
                _sharded_sds(o, in_specs[1], mesh),
                _sharded_sds(res, in_specs[2], mesh),
                _sharded_sds(b["inputs"], in_specs[3], mesh),
                _sharded_sds(b["labels"], in_specs[4], mesh),
                _sharded_sds(b["pos"], in_specs[5], mesh))
        lowered = fn.lower(*args)
    elif shape.kind == "prefill":
        fn, in_specs = serve_step.make_prefill(cfg, mesh)
        p = M.param_shapes(cfg)
        sh = serve_step.serve_shapes(cfg, shape, mesh)
        B, S = shape.global_batch, shape.seq_len
        tok = jax.ShapeDtypeStruct(
            (B, S, cfg.d_model) if cfg.embed_input else (B, S),
            jnp.bfloat16 if cfg.embed_input else jnp.int32)
        pos = jax.ShapeDtypeStruct(
            (3, B, S) if cfg.rope == "mrope" else (B, S), jnp.int32)
        args = (_sharded_sds(p, in_specs[0], mesh),
                _sharded_sds(sh["caches"], in_specs[1], mesh),
                _sharded_sds(tok, in_specs[2], mesh),
                _sharded_sds(pos, in_specs[3], mesh))
        lowered = fn.lower(*args)
    else:  # decode
        sh = serve_step.serve_shapes(cfg, shape, mesh)
        fn, in_specs = serve_step.make_decode_step(
            cfg, mesh, batch_sharded=sh["batch_sharded"],
            seq_shard=sh["seq_shard"], replicate_weights=replicate_weights)
        p = M.param_shapes(cfg)
        from jax.sharding import PartitionSpec as P
        args = (_sharded_sds(p, in_specs[0], mesh),
                _sharded_sds(sh["caches"], in_specs[1], mesh),
                _sharded_sds(sh["tokens"], in_specs[2], mesh),
                _sharded_sds(sh["pos"], in_specs[3], mesh),
                _sharded_sds(sh["cache_len"], P(), mesh))
        meta["batch_sharded"] = sh["batch_sharded"]
        meta["seq_shard"] = sh["seq_shard"]
        lowered = fn.lower(*args)
    return lowered, meta


def lower_index_service(mesh, capacity_factor=None):
    """Dry-run cell for the paper's distributed index service itself."""
    import numpy as np
    from repro.core import distributed
    n = 1 << 20
    keys = jnp.asarray(np.linspace(0.0, 1.0, n))
    idx = distributed.build_sharded(keys, mesh, axis="data", n_leaves=256)
    fn = distributed.make_lookup_fn(idx, capacity_factor=capacity_factor)
    q = jax.ShapeDtypeStruct((1 << 16,), jnp.float64)
    return fn.lower(q), {"arch": "index_service", "shape": "lookup_64k",
                         "kind": "index",
                         "capacity_factor": capacity_factor}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str | None = None, compress_pod: bool = False,
             microbatch: int | None = None, tag: str = "",
             psum_bf16: bool = False, replicate_weights: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = 512 if multi_pod else 256
    t0 = time.time()
    if arch == "index_service":
        lowered, meta = lower_index_service(
            mesh, capacity_factor=2.0 if tag == "cap2" else None)
    else:
        lowered, meta = lower_cell(arch, shape_name, mesh,
                                   compress_pod=compress_pod,
                                   microbatch=microbatch,
                                   psum_bf16=psum_bf16,
                                   replicate_weights=replicate_weights)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):     # jax 0.4.x returns [dict], newer a dict
        cost = cost[0]
    hlo_text = compiled.as_text()
    coll = parse_collectives(hlo_text)

    # Trip-count-aware accounting (xla cost_analysis counts loop bodies
    # once — see launch/hlo_cost.py; raw values kept for reference).
    from repro.launch.hlo_cost import HloCost
    acc = HloCost(hlo_text).summary()
    flops = acc["flops"]
    bytes_acc = acc["bytes"]
    coll = {"bytes_by_kind": acc["collective_bytes_by_kind"],
            "counts": acc["collective_counts"],
            "total_bytes": acc["collective_bytes"],
            "once_counted": coll}
    result = dict(
        meta,
        mesh="multi" if multi_pod else "single",
        chips=chips,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        hlo_flops_per_chip=flops,
        hlo_bytes_per_chip=bytes_acc,
        xla_cost_analysis={"flops": float(cost.get("flops", 0.0)),
                           "bytes": float(cost.get("bytes accessed", 0.0))},
        collective=coll,
        memory={
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes_est": mem.argument_size_in_bytes
            + mem.output_size_in_bytes + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        roofline={
            "compute_s": flops / PEAK_FLOPS,
            "memory_s": bytes_acc / HBM_BW,
            "collective_s": coll["total_bytes"] / ICI_BW,
        },
    )
    r = result["roofline"]
    result["roofline"]["dominant"] = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: r[k])
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        fname = f"{arch}__{shape_name}__{result['mesh']}{suffix}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help=f"one of {list_archs()} or index_service")
    ap.add_argument("--shape", default="train_4k",
                    choices=[*SHAPES, "lookup_64k"])
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--out", default=None)
    ap.add_argument("--compress-pod", action="store_true")
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--psum-bf16", action="store_true")
    ap.add_argument("--replicate-weights", action="store_true")
    args = ap.parse_args()
    res = run_cell(args.arch, args.shape, args.mesh == "multi", args.out,
                   compress_pod=args.compress_pod,
                   microbatch=args.microbatch, tag=args.tag,
                   psum_bf16=args.psum_bf16,
                   replicate_weights=args.replicate_weights)
    json.dump(res, sys.stdout, indent=1)
    print()


if __name__ == "__main__":
    main()
