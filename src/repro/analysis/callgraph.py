"""Project call graph with repo-idiom name resolution.

Qualified names are ``module:Outer.inner`` — class methods as
``module:Class.method``, nested defs as ``module:outer.inner``.  A call is
resolved to zero or more defs via, in order:

* local/module-level function names and ``from x import y`` aliases
  (relative imports resolved against the importing module's package),
* module-alias attributes (``import repro.core.distributed as dist_mod``
  makes ``dist_mod.scatter_rows_donated`` precise),
* ``self.method()`` -> the enclosing class,
* ``self.attr.method()`` through attribute types inferred from
  ``self.attr = ClassName(...)`` assignments anywhere in the class,
* ``Var.method()`` through ``var = ClassName(...)`` local assignments,
* a capped unique-method-name fallback: an ``obj.m()`` whose receiver we
  can't type links to *every* def of ``m`` in the project, provided there
  are at most ``config.name_fallback_cap`` of them.  This deliberately
  over-approximates (soundness for the hot-sync rule beats precision);
  generic names past the cap are dropped instead of spraying edges.

Calling a class name reaches its ``__init__``.  A def nested inside
another def (or a lambda) is reachable whenever its parent is — closures
on the dispatch path run on the dispatch path.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field


@dataclass
class FuncInfo:
    qual: str                   # "module:Class.method"
    module: str
    name: str                   # bare name ("method")
    node: ast.AST               # FunctionDef / AsyncFunctionDef
    file: object                # FileModel
    cls: str | None = None      # enclosing class name, if a method
    parent: str | None = None   # enclosing def's qual, if nested
    calls: list = field(default_factory=list)   # resolved callee quals


@dataclass
class ClassInfo:
    qual: str                   # "module:Class"
    module: str
    name: str
    methods: dict = field(default_factory=dict)       # name -> func qual
    attr_types: dict = field(default_factory=dict)    # attr -> class qual


def _abs_module(file, level: int, mod: str | None) -> str:
    """Resolve a relative import against the importing file's package."""
    if level == 0:
        return mod or ""
    parts = file.module.split(".") if file.module else []
    if file.path.name != "__init__.py" and parts:
        parts = parts[:-1]                   # the module's package
    parts = parts[: len(parts) - (level - 1)] if level > 1 else parts
    return ".".join(parts + mod.split(".")) if mod else ".".join(parts)


class _ModuleIndex:
    """Per-file name tables: imports and top-level defs."""

    def __init__(self, file):
        self.file = file
        self.mod_alias: dict[str, str] = {}     # local name -> dotted module
        self.from_imports: dict[str, tuple] = {}  # local -> (module, attr)
        for node in ast.walk(file.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.mod_alias[a.asname or a.name.split(".")[0]] = \
                        a.name if a.asname else a.name.split(".")[0]
            elif isinstance(node, ast.ImportFrom):
                base = _abs_module(file, node.level, node.module)
                for a in node.names:
                    self.from_imports[a.asname or a.name] = (base, a.name)


class CallGraph:
    def __init__(self, project):
        self.project = project
        self.funcs: dict[str, FuncInfo] = {}
        self.classes: dict[str, ClassInfo] = {}
        self.by_name: dict[str, list] = {}      # bare name -> [func quals]
        self.indexes: dict[str, _ModuleIndex] = {}
        for f in project.files:
            self.indexes[f.module] = _ModuleIndex(f)
            self._collect(f)
        self._infer_attr_types()
        for fi in self.funcs.values():
            fi.calls = self._resolve_calls(fi)

    # -- collection ---------------------------------------------------------

    def _collect(self, f):
        def visit(node, prefix, cls, parent):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, ast.ClassDef):
                    cq = f"{f.module}:{prefix}{child.name}"
                    self.classes[cq] = ClassInfo(qual=cq, module=f.module,
                                                 name=child.name)
                    visit(child, f"{prefix}{child.name}.", cq, parent)
                elif isinstance(child, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                    q = f"{f.module}:{prefix}{child.name}"
                    fi = FuncInfo(qual=q, module=f.module, name=child.name,
                                  node=child, file=f,
                                  cls=cls.split(":")[1] if cls else None,
                                  parent=parent)
                    self.funcs[q] = fi
                    self.by_name.setdefault(child.name, []).append(q)
                    if cls:
                        self.classes[cls].methods[child.name] = q
                    visit(child, f"{prefix}{child.name}.", None, q)
                else:
                    visit(child, prefix, cls, parent)
        visit(f.tree, "", None, None)

    def _class_qual_from_call(self, idx, call) -> str | None:
        """``ClassName(...)`` / ``mod.ClassName(...)`` -> class qual."""
        fn = call.func if isinstance(call, ast.Call) else call
        if isinstance(fn, ast.Name):
            q = f"{idx.file.module}:{fn.id}"
            if q in self.classes:
                return q
            if fn.id in idx.from_imports:
                mod, attr = idx.from_imports[fn.id]
                if f"{mod}:{attr}" in self.classes:
                    return f"{mod}:{attr}"
        elif isinstance(fn, ast.Attribute) and isinstance(fn.value, ast.Name):
            mod = idx.mod_alias.get(fn.value.id)
            if mod and f"{mod}:{fn.attr}" in self.classes:
                return f"{mod}:{fn.attr}"
        return None

    def _infer_attr_types(self):
        for fi in self.funcs.values():
            if fi.cls is None:
                continue
            ci = self.classes.get(f"{fi.module}:{fi.cls}")
            if ci is None:
                continue
            idx = self.indexes[fi.module]
            for node in ast.walk(fi.node):
                if not (isinstance(node, ast.Assign)
                        and len(node.targets) == 1):
                    continue
                t = node.targets[0]
                if (isinstance(t, ast.Attribute)
                        and isinstance(t.value, ast.Name)
                        and t.value.id == "self"
                        and isinstance(node.value, ast.Call)):
                    cq = self._class_qual_from_call(idx, node.value)
                    if cq:
                        ci.attr_types.setdefault(t.attr, cq)

    # -- resolution ---------------------------------------------------------

    def _method_of(self, class_qual: str, name: str) -> list:
        ci = self.classes.get(class_qual)
        if ci and name in ci.methods:
            return [ci.methods[name]]
        return []

    def _resolve_one(self, fi, idx, fn) -> list:
        """Resolve a call's func expression to candidate def quals."""
        if isinstance(fn, ast.Name):
            q = f"{fi.module}:{fn.id}"
            if q in self.funcs:
                return [q]
            if q in self.classes:
                return self._method_of(q, "__init__")
            if fn.id in idx.from_imports:
                mod, attr = idx.from_imports[fn.id]
                tq = f"{mod}:{attr}"
                if tq in self.funcs:
                    return [tq]
                if tq in self.classes:
                    return self._method_of(tq, "__init__")
            return []
        if not isinstance(fn, ast.Attribute):
            return []
        recv, meth = fn.value, fn.attr
        if isinstance(recv, ast.Name):
            # module alias:  dist_mod.scatter_rows_donated(...)
            mod = idx.mod_alias.get(recv.id)
            if mod is None and recv.id in idx.from_imports:
                m, a = idx.from_imports[recv.id]
                if f"{m}.{a}" in self.project.by_module:
                    mod = f"{m}.{a}"        # `from repro.core import x`
            if mod is not None:
                tq = f"{mod}:{meth}"
                if tq in self.funcs:
                    return [tq]
                if tq in self.classes:
                    return self._method_of(tq, "__init__")
                if mod in self.project.by_module:
                    return []       # known module, unknown attr: external
            if recv.id == "self" and fi.cls is not None:
                got = self._method_of(f"{fi.module}:{fi.cls}", meth)
                if got:
                    return got
        elif (isinstance(recv, ast.Attribute)
              and isinstance(recv.value, ast.Name)
              and recv.value.id == "self" and fi.cls is not None):
            # self.attr.method() through inferred attribute types
            ci = self.classes.get(f"{fi.module}:{fi.cls}")
            if ci and recv.attr in ci.attr_types:
                got = self._method_of(ci.attr_types[recv.attr], meth)
                if got:
                    return got
        # capped bare-name fallback
        cands = self.by_name.get(meth, [])
        if 0 < len(cands) <= self.project.config.name_fallback_cap:
            return list(cands)
        return []

    def _resolve_calls(self, fi) -> list:
        idx = self.indexes[fi.module]
        out: list[str] = []
        for node in ast.walk(fi.node):
            if isinstance(node, ast.Call):
                out.extend(self._resolve_one(fi, idx, node.func))
        # local-var typing:  pack = TenantPack(...); pack.find(...) is
        # already covered by the __init__ edge + bare-name fallback.
        # nested defs / closures run when the parent runs
        for q, other in self.funcs.items():
            if other.parent == fi.qual:
                out.append(q)
        return sorted(set(out) - {fi.qual})

    # -- queries ------------------------------------------------------------

    def reachable(self, roots) -> set:
        """BFS closure of def quals from the given root quals."""
        seen: set[str] = set()
        frontier = [r for r in roots if r in self.funcs]
        while frontier:
            q = frontier.pop()
            if q in seen:
                continue
            seen.add(q)
            frontier.extend(self.funcs[q].calls)
        return seen
