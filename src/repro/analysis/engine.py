"""tracelint engine: project model, pragma scanning, rule driver, CLI.

The engine owns everything rule-agnostic: walking the analyzed roots into
a :class:`Project` of parsed files (with module names resolved the way the
repo imports them — ``src/repro/...`` -> ``repro...``,
``benchmarks/x.py`` -> ``benchmarks.x``), scanning comments for
suppression pragmas (tokenize-based, so strings that merely *contain* a
pragma spelling do not suppress), matching findings against pragmas, and
rendering/exiting.  Rules live in ``repro.analysis.rules`` and receive the
whole project, so cross-module facts (call-graph reachability, jit
wrappers defined in one module and called from another) are first-class.
"""
from __future__ import annotations

import argparse
import ast
import io
import re
import tokenize
from dataclasses import dataclass, field, replace
from pathlib import Path

# -- pragmas ----------------------------------------------------------------
# Grammar (see package docstring): the general form names a rule id in
# brackets and a reason in parens; the "sync" spelling aliases hot-sync.
_PRAGMA_RE = re.compile(r"tracelint:\s*ok\[([A-Za-z0-9_-]+)\]\(([^)]*)\)")
_SYNC_RE = re.compile(r"sync:\s*ok\(([^)]*)\)")
# Malformed spellings that were clearly *meant* as pragmas must fail
# loud, not silently un-suppress: either marker word followed by the
# approval token but missing its [rule]/(reason) payload.
_NEAR_PRAGMA_RE = re.compile(r"(tracelint|sync):\s*ok")

PRAGMA_RULE = "pragma"          # rule id for pragma-grammar violations


@dataclass(frozen=True)
class Finding:
    rule: str
    path: Path                  # as given (relative to the analysis root)
    line: int
    message: str
    suppressed: str | None = None    # the pragma reason, when suppressed

    def render(self) -> str:
        tag = f" (suppressed: {self.suppressed})" if self.suppressed else ""
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}{tag}"


@dataclass
class Config:
    """Analyzer knobs (defaults encode this repo's contracts)."""
    # Call-graph roots of the serving hot path ("module:Qual.name").
    hot_roots: tuple = (
        "repro.serve.frontend:BatchingFrontend._dispatch",
        "repro.serve.frontend:BatchingFrontend._resolve",
        "repro.serve.frontend:TenantPack.find",
        "repro.serve.frontend:TenantPack.find_range",
    )
    # Per-grid-step VMEM budget for pallas_call sites (one TPU core).
    vmem_budget_bytes: int = 16 * 1024 * 1024
    # In/out blocks are double-buffered by the Pallas pipeline.
    vmem_pipeline_factor: int = 2
    # Identifiers that mark an expression as key-valued for f32-cast checks.
    key_name_re: str = (r"(^|_)(k|kf|kn|kp|q|qf|ql|qh|qm|rq|dk|dkp|key|keys|"
                        r"queries|splits|q_lo|q_hi|lo_keys|hi_keys)(_|$)|key")
    # Module prefixes where f32 key casts are sanctioned (the kernel
    # boundary: every wrapper sits behind the f32_exact gate).
    f32_cast_ok_modules: tuple = ("repro.kernels",)
    # Primitives that must not appear inside a Pallas kernel body.
    kernel_banned: tuple = (
        "jnp.sort", "jnp.argsort", "jnp.unique", "jnp.nonzero",
        "jnp.searchsorted", "jnp.median", "jnp.percentile",
        "jax.lax.sort", "jax.lax.while_loop", "lax.sort", "lax.while_loop",
    )
    # Ambiguous-method-call fallback: an `obj.m()` call with an unknown
    # receiver type links to every def of `m` when there are at most this
    # many (past it the name is too generic to mean anything).
    name_fallback_cap: int = 6


@dataclass
class FileModel:
    path: Path                  # absolute
    rel: Path                   # relative to analysis root (for display)
    module: str                 # dotted import name ("repro.core.updates")
    tree: ast.Module
    source: str
    # line -> {rule_id: reason} suppression pragmas on that line
    pragmas: dict = field(default_factory=dict)
    pragma_errors: list = field(default_factory=list)   # (line, message)


@dataclass
class Project:
    root: Path
    files: list
    config: Config

    def __post_init__(self):
        self.by_module = {f.module: f for f in self.files}
        self._callgraph = None

    @property
    def callgraph(self):
        """Lazily built project call graph (rules share one instance)."""
        if self._callgraph is None:
            from .callgraph import CallGraph
            self._callgraph = CallGraph(self)
        return self._callgraph


def _scan_pragmas(source: str) -> tuple[dict, list]:
    """Comment-token pragma scan -> ({line: {rule: reason}}, errors)."""
    pragmas: dict[int, dict] = {}
    errors: list[tuple[int, str]] = []
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        comments = [(t.start[0], t.string) for t in tokens
                    if t.type == tokenize.COMMENT]
    except tokenize.TokenError:
        comments = []
    for line, text in comments:
        matched = False
        for m in _PRAGMA_RE.finditer(text):
            matched = True
            rule, reason = m.group(1), m.group(2).strip()
            if not reason:
                errors.append((line, f"pragma ok[{rule}] carries no reason"))
            else:
                pragmas.setdefault(line, {})[rule] = reason
        for m in _SYNC_RE.finditer(text):
            matched = True
            reason = m.group(1).strip()
            if not reason:
                errors.append((line, "sync: ok() carries no reason"))
            else:
                pragmas.setdefault(line, {})["hot-sync"] = reason
        if not matched and _NEAR_PRAGMA_RE.search(text):
            errors.append(
                (line, "malformed pragma: want 'tracelint: ok[rule](reason)'"
                       " or 'sync: ok(reason)'"))
    return pragmas, errors


def _module_name(rel: Path) -> str:
    """Dotted import name matching how the repo imports the file
    (``src`` is the PYTHONPATH root; benchmarks/examples import as-is)."""
    parts = list(rel.with_suffix("").parts)
    if parts and parts[0] == "src":
        parts = parts[1:]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def load_project(paths: list, config: Config | None = None,
                 root: Path | None = None) -> Project:
    """Parse every ``.py`` under the given files/directories."""
    config = config or Config()
    root = (root or Path.cwd()).resolve()
    seen: set[Path] = set()
    files: list[FileModel] = []
    queue: list[Path] = []
    for p in paths:
        p = Path(p).resolve()
        queue += sorted(p.rglob("*.py")) if p.is_dir() else [p]
    for path in queue:
        if path in seen:
            continue
        seen.add(path)
        source = path.read_text()
        try:
            tree = ast.parse(source)
        except SyntaxError as exc:
            raise SystemExit(f"tracelint: cannot parse {path}: {exc}") \
                from exc
        try:
            rel = path.relative_to(root)
        except ValueError:
            rel = path
        pragmas, errors = _scan_pragmas(source)
        files.append(FileModel(path=path, rel=rel, module=_module_name(rel),
                               tree=tree, source=source, pragmas=pragmas,
                               pragma_errors=errors))
    return Project(root=root, files=files, config=config)


def _apply_pragmas(f: FileModel, findings: list) -> list:
    """Mark findings suppressed by a pragma on any line of the flagged
    statement or the line directly above it."""
    out = []
    for fd in findings:
        span = getattr(fd, "_span", (fd.line, fd.line))
        reason = None
        for line in range(span[0] - 1, span[1] + 1):
            got = f.pragmas.get(line, {}).get(fd.rule)
            if got is not None:
                reason = got
                break
        out.append(replace(fd, suppressed=reason) if reason else fd)
    return out


def finding(rule: str, f: FileModel, node: ast.AST, message: str) -> Finding:
    """Build a Finding anchored to ``node`` (records the statement span so
    trailing pragmas on any physical line of the statement match)."""
    fd = Finding(rule=rule, path=f.rel, line=getattr(node, "lineno", 1),
                 message=message)
    object.__setattr__(fd, "_span", (getattr(node, "lineno", 1),
                                     getattr(node, "end_lineno",
                                             getattr(node, "lineno", 1))))
    return fd


def analyze(paths: list, config: Config | None = None,
            root: Path | None = None) -> list:
    """Run every rule over the project; returns all findings (suppressed
    ones carry their pragma reason).  Pragma-grammar violations are
    findings of rule ``pragma`` and are never suppressible."""
    from .rules import KNOWN_RULE_IDS, RULES
    project = load_project(paths, config, root)
    findings: list[Finding] = []
    per_file: dict[str, list] = {f.module: [] for f in project.files}
    for rule in RULES:
        for fd in rule.check(project):
            key = str(fd.path)
            bucket = next((f for f in project.files if str(f.rel) == key),
                          None)
            if bucket is not None:
                per_file.setdefault(bucket.module, []).append(fd)
            else:
                findings.append(fd)
    for f in project.files:
        findings.extend(_apply_pragmas(f, per_file.get(f.module, [])))
        for line, msg in f.pragma_errors:
            findings.append(Finding(rule=PRAGMA_RULE, path=f.rel, line=line,
                                    message=msg))
        for line, by_rule in f.pragmas.items():
            for rid in by_rule:
                if rid not in KNOWN_RULE_IDS:
                    findings.append(Finding(
                        rule=PRAGMA_RULE, path=f.rel, line=line,
                        message=f"pragma names unknown rule id {rid!r}"))
    findings.sort(key=lambda fd: (str(fd.path), fd.line, fd.rule))
    return findings


def main(argv: list | None = None) -> int:
    from .rules import RULES
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="tracelint: repo-native trace-safety/host-sync/donation/"
                    "kernel-budget static analysis (package docstring has "
                    "the rule and pragma reference)")
    ap.add_argument("paths", nargs="*", default=[],
                    help="files or directories to analyze")
    ap.add_argument("--vmem-budget", type=int, default=None, metavar="BYTES",
                    help="per-grid-step Pallas VMEM budget (default 16 MiB)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print rule ids and one-line docs, then exit")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="suppress the per-rule summary and suppressed "
                         "findings; print only violations")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in RULES:
            print(f"{rule.id:12s} {rule.doc}")
        return 0
    if not args.paths:
        ap.error("no paths given (try: src benchmarks examples)")

    config = Config()
    if args.vmem_budget is not None:
        config.vmem_budget_bytes = args.vmem_budget
    findings = analyze(args.paths, config)
    bad = [fd for fd in findings if fd.suppressed is None]
    ok = [fd for fd in findings if fd.suppressed is not None]
    for fd in bad:
        print(fd.render())
    if not args.quiet:
        for fd in ok:
            print(fd.render())
        counts: dict[str, int] = {}
        for fd in bad:
            counts[fd.rule] = counts.get(fd.rule, 0) + 1
        summary = ", ".join(f"{k}={v}" for k, v in sorted(counts.items())) \
            or "none"
        print(f"tracelint: {len(bad)} finding(s) [{summary}], "
              f"{len(ok)} suppressed")
    return 1 if bad else 0
