"""Rule ``hot-sync``: host synchronization in the serving hot path.

The hot path is the call-graph closure of ``Config.hot_roots`` (the
front-end dispatch/resolve roots).  Within it, any construct that forces
a device->host transfer or a stream drain is flagged: numpy
materialization (``np.asarray``/``np.array``/``np.copy``),
``jax.device_get``, ``block_until_ready`` (function or method),
``.item()``/``.tolist()``, and scalar coercions ``int()``/``float()``/
``bool()`` of non-metadata expressions.  The contract allows exactly one
such sync per served batch — annotated ``# sync: ok(reason)`` at the
resolve site; host-side numpy *mirrors* that never hold device buffers
are likewise annotated where the analyzer cannot prove it.
"""
from __future__ import annotations

import ast

from ..engine import finding
from .common import (Rule, dotted, is_metadata_expr, own_body_nodes,
                     scalar_env)

_SYNC_METHODS = {"item", "tolist", "block_until_ready"}
_NUMPY_FUNCS = {"asarray", "array", "copy", "ascontiguousarray"}
_JAX_SYNC = {"jax.device_get", "jax.block_until_ready"}
_COERCIONS = {"int", "float", "bool"}


def _numpy_aliases(idx) -> set:
    out = set()
    for alias, mod in idx.mod_alias.items():
        if mod == "numpy" or mod.startswith("numpy."):
            out.add(alias)
    return out


def _scan(fi, idx, f):
    np_names = _numpy_aliases(idx)
    env = scalar_env(fi.node)
    where = f"in hot-path function {fi.qual.split(':')[1]}"
    for node in own_body_nodes(fi.node):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        name = dotted(fn)
        if isinstance(fn, ast.Attribute):
            root = name.split(".")[0] if name else None
            if root in np_names and fn.attr in _NUMPY_FUNCS:
                yield finding(
                    "hot-sync", f, node,
                    f"np.{fn.attr}() materializes a device value on host "
                    f"{where}")
                continue
            if name in _JAX_SYNC:
                yield finding("hot-sync", f, node, f"{name}() {where}")
                continue
            if fn.attr in _SYNC_METHODS \
                    and not is_metadata_expr(fn.value, env):
                # method form on a possibly-device value:
                # x.item() / x.tolist() / x.block_until_ready()
                yield finding(
                    "hot-sync", f, node,
                    f".{fn.attr}() forces a host sync {where}")
                continue
        elif isinstance(fn, ast.Name) and fn.id in _COERCIONS:
            if node.args and not all(is_metadata_expr(a, env)
                                     for a in node.args):
                yield finding(
                    "hot-sync", f, node,
                    f"{fn.id}() of a non-metadata value syncs if it holds "
                    f"a device array {where}")


def check(project):
    cg = project.callgraph
    reach = cg.reachable(project.config.hot_roots)
    for qual in sorted(reach):
        fi = cg.funcs[qual]
        if fi.module.startswith("repro.analysis"):
            continue
        yield from _scan(fi, cg.indexes[fi.module], fi.file)


RULE = Rule(
    id="hot-sync",
    doc="host sync (np.asarray/.item()/int()/block_until_ready) reachable "
        "from the serve dispatch/resolve roots",
    check=check,
)
