"""Rule ``retrace``: recompile hazards inside jit-traced code.

Traced contexts are collected per module: ``@jax.jit``-decorated defs
(directly or via ``functools.partial(jax.jit, ...)``), defs passed to a
``jax.jit(f)`` / ``jax.shard_map(f, ...)`` call anywhere in the module
(the lru_cache'd jit-factory idiom), and defs nested inside either.
Arguments bound by ``static_argnums``/``static_argnames`` are exempt.

Inside a traced body the non-static parameters are *traced*; taint
propagates through plain assignments, but shape/dtype metadata of a
traced value is static (``n = q.shape[0]`` then branching on ``n`` is
fine — that is exactly the capacity-class padding idiom).  Flagged:

* python control flow (``if``/``while``/ternary/``assert``) on a traced
  value — a concretization error or a retrace per distinct value,
* host materialization of traced values (``int``/``float``/``bool``,
  ``.item()``/``.tolist()``, ``np.*``),
* per-call jit construction: ``jax.jit(<lambda>)`` anywhere, or a
  ``jax.jit(...)`` call inside a loop body when the enclosing def is not
  an ``lru_cache``/``cache``-memoized factory — each call builds a fresh
  trace cache, so every invocation retraces.
"""
from __future__ import annotations

import ast

from ..engine import finding
from .common import Rule, dotted, is_metadata_expr

_JIT_NAMES = {"jax.jit", "jit"}
_WRAP_NAMES = {"jax.jit", "jit", "jax.shard_map", "shard_map",
               "jax.experimental.shard_map.shard_map"}
_MEMO_NAMES = {"functools.lru_cache", "lru_cache", "functools.cache",
               "cache"}


def _static_params(call_kw) -> tuple:
    """(static_argnums tuple, static_argnames tuple) from jit keywords."""
    nums, names = (), ()
    for kw in call_kw:
        if kw.arg == "static_argnums":
            got = _const_tuple(kw.value)
            nums = tuple(v for v in got if isinstance(v, int))
        elif kw.arg == "static_argnames":
            got = _const_tuple(kw.value)
            names = tuple(v for v in got if isinstance(v, str))
    return nums, names


def _const_tuple(node) -> tuple:
    if isinstance(node, ast.Constant):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        return tuple(e.value for e in node.elts
                     if isinstance(e, ast.Constant))
    return ()


def _jit_decoration(dec) -> tuple | None:
    """None if not a jit decorator, else (static_argnums, static_argnames).

    Handles ``@jax.jit``, ``@jax.jit(...)``, and
    ``@functools.partial(jax.jit, ...)``.
    """
    if dotted(dec) in _JIT_NAMES:
        return (), ()
    if isinstance(dec, ast.Call):
        name = dotted(dec.func)
        if name in _JIT_NAMES:
            return _static_params(dec.keywords)
        if name in {"functools.partial", "partial"} and dec.args \
                and dotted(dec.args[0]) in _JIT_NAMES:
            return _static_params(dec.keywords)
    return None


def _traced_defs(file):
    """Yield (def node, static names set) for every traced def in file."""
    # defs wrapped by name at a jit/shard_map call site anywhere in module
    wrapped: dict[str, tuple] = {}
    for node in ast.walk(file.tree):
        if isinstance(node, ast.Call) and dotted(node.func) in _WRAP_NAMES \
                and node.args and isinstance(node.args[0], ast.Name):
            nums, names = _static_params(node.keywords)
            wrapped[node.args[0].id] = (nums, names)

    def emit(fn, nums, names):
        argnames = [a.arg for a in fn.args.posonlyargs + fn.args.args]
        static = {n for n in names}
        static.update(argnames[i] for i in nums if i < len(argnames))
        yield fn, static
        for inner in ast.walk(fn):
            if inner is not fn and isinstance(
                    inner, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield inner, static

    seen = set()
    for node in ast.walk(file.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        spec = None
        for dec in node.decorator_list:
            spec = _jit_decoration(dec)
            if spec is not None:
                break
        if spec is None and node.name in wrapped:
            spec = wrapped[node.name]
        if spec is None:
            continue
        for fn, static in emit(node, *spec):
            if id(fn) not in seen:
                seen.add(id(fn))
                yield fn, static


class _Taint:
    """Forward taint over a traced body: non-static params are traced;
    assignment spreads taint unless the RHS is pure metadata."""

    def __init__(self, fn, static):
        args = fn.args
        names = [a.arg for a in args.posonlyargs + args.args
                 + args.kwonlyargs]
        self.tainted = {n for n in names if n not in static}
        self.tainted -= {"self"}

    def references(self, node) -> bool:
        """Does ``node`` read a traced value outside metadata context?"""
        if is_metadata_expr(node):
            return False
        if isinstance(node, ast.Compare) and all(
                isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            # identity checks (`mask is None`) resolve at trace time
            return False
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            # .shape/.dtype of traced is static; other attrs propagate
            return not is_metadata_expr(node) \
                and self.references(node.value)
        for child in ast.iter_child_nodes(node):
            if self.references(child):
                return True
        return False

    def assign(self, stmt):
        if isinstance(stmt, ast.Assign):
            src = stmt.value
            for t in stmt.targets:
                for name in ast.walk(t):
                    if isinstance(name, ast.Name):
                        if self.references(src):
                            self.tainted.add(name.id)
                        else:
                            self.tainted.discard(name.id)
        elif isinstance(stmt, ast.AugAssign) and \
                isinstance(stmt.target, ast.Name):
            if self.references(stmt.value):
                self.tainted.add(stmt.target.id)


def _scan_traced(fn, static, f):
    taint = _Taint(fn, static)
    for node in ast.walk(fn):
        taint.assign(node) if isinstance(
            node, (ast.Assign, ast.AugAssign)) else None
        if isinstance(node, (ast.If, ast.While)) and \
                taint.references(node.test):
            yield finding(
                "retrace", f, node,
                f"python branch on traced value inside jit body "
                f"{fn.name!r} — concretizes the tracer (use lax.cond/"
                f"jnp.where, or mark the arg static)")
        elif isinstance(node, ast.IfExp) and taint.references(node.test):
            yield finding(
                "retrace", f, node,
                f"ternary on traced value inside jit body {fn.name!r}")
        elif isinstance(node, ast.Assert) and taint.references(node.test):
            yield finding(
                "retrace", f, node,
                f"assert on traced value inside jit body {fn.name!r}")
        elif isinstance(node, ast.Call):
            name = dotted(node.func)
            if isinstance(node.func, ast.Name) \
                    and node.func.id in {"int", "float", "bool"} \
                    and any(taint.references(a) for a in node.args):
                yield finding(
                    "retrace", f, node,
                    f"{node.func.id}() concretizes a traced value inside "
                    f"jit body {fn.name!r}")
            elif isinstance(node.func, ast.Attribute) \
                    and node.func.attr in {"item", "tolist"} \
                    and taint.references(node.func.value):
                yield finding(
                    "retrace", f, node,
                    f".{node.func.attr}() on traced value inside jit "
                    f"body {fn.name!r}")
            elif name and name.split(".")[0] in {"np", "numpy"} \
                    and any(taint.references(a) for a in node.args):
                yield finding(
                    "retrace", f, node,
                    f"host numpy call on traced value inside jit body "
                    f"{fn.name!r}")


def _scan_jit_construction(file):
    """jax.jit(<lambda>) anywhere; jax.jit(...) built inside a loop of a
    non-memoized def."""
    memo_defs = set()
    for node in ast.walk(file.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                target = dec.func if isinstance(dec, ast.Call) else dec
                if dotted(target) in _MEMO_NAMES:
                    memo_defs.add(id(node))

    def walk(node, in_loop, in_memo):
        for child in ast.iter_child_nodes(node):
            child_loop = in_loop or isinstance(child, (ast.For, ast.While))
            child_memo = in_memo or id(child) in memo_defs
            if isinstance(child, ast.Call) \
                    and dotted(child.func) in _JIT_NAMES and child.args:
                if isinstance(child.args[0], ast.Lambda):
                    yield child, "jax.jit(lambda ...) builds a fresh " \
                        "trace cache per call site evaluation"
                elif child_loop and not child_memo:
                    yield child, "jax.jit(...) constructed inside a " \
                        "loop — every iteration retraces (hoist it, or " \
                        "memoize the factory with functools.lru_cache)"
            yield from walk(child, child_loop, child_memo)

    yield from walk(file.tree, False, False)


def check(project):
    for f in project.files:
        if f.module.startswith("repro.analysis"):
            continue
        for fn, static in _traced_defs(f):
            yield from _scan_traced(fn, static, f)
        for node, msg in _scan_jit_construction(f):
            yield finding("retrace", f, node, msg)


RULE = Rule(
    id="retrace",
    doc="retrace hazards in jit bodies: python branches/coercions on "
        "traced values, per-call jit construction",
    check=check,
)
