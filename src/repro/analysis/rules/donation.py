"""Rule ``donation``: no def-use of a donated buffer after the call.

Donating callables are collected project-wide: any def jitted with
``donate_argnums`` (decorator or ``jax.jit(f, donate_argnums=...)``
site), plus thin wrappers that forward one of their own positional
parameters into a donated slot of another donating callable
(``scatter_rows_donated(dst, ...) -> _row_scatter_jit(dst, ...)``),
propagated to a fixpoint.

At each call site of a donating callable, the argument in a donated slot
is consumed by XLA — its buffer is deleted.  The sanctioned idiom rebinds
the result over the source in the same statement (``x = f(x, ...)``,
``self._st[k] = f(self._st[k], ...)``); any *read* of the donated
expression in a later statement of the same function is flagged.
"""
from __future__ import annotations

import ast

from ..engine import finding
from .common import Rule, dotted, own_body_nodes

_JIT_NAMES = {"jax.jit", "jit"}


def _donated_nums(keywords) -> tuple:
    for kw in keywords:
        if kw.arg == "donate_argnums":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                return (v.value,)
            if isinstance(v, (ast.Tuple, ast.List)):
                return tuple(e.value for e in v.elts
                             if isinstance(e, ast.Constant)
                             and isinstance(e.value, int))
    return ()


def _collect_donors(project) -> dict:
    """qual -> set of donated positional indices."""
    cg = project.callgraph
    donors: dict[str, set] = {}
    for f in project.files:
        # decorator form: @functools.partial(jax.jit, donate_argnums=(0,))
        for node in ast.walk(f.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    if not isinstance(dec, ast.Call):
                        continue
                    name = dotted(dec.func)
                    is_jit = name in _JIT_NAMES or (
                        name in {"functools.partial", "partial"}
                        and dec.args and dotted(dec.args[0]) in _JIT_NAMES)
                    if is_jit:
                        nums = _donated_nums(dec.keywords)
                        if nums:
                            for q, fi in cg.funcs.items():
                                if fi.node is node:
                                    donors.setdefault(q, set()).update(nums)
            # call-site form: jax.jit(f, donate_argnums=...)
            elif isinstance(node, ast.Call) \
                    and dotted(node.func) in _JIT_NAMES \
                    and node.args and isinstance(node.args[0], ast.Name):
                nums = _donated_nums(node.keywords)
                if nums:
                    q = f"{f.module}:{node.args[0].id}"
                    if q in cg.funcs:
                        donors.setdefault(q, set()).update(nums)
    # wrapper propagation to fixpoint: f(p0..) calling donor(p0 in slot)
    for _ in range(5):
        grew = False
        for q, fi in cg.funcs.items():
            params = [a.arg for a in fi.node.args.posonlyargs
                      + fi.node.args.args]
            if params and params[0] == "self":
                params = params[1:]
            idx = cg.indexes[fi.module]
            for node in own_body_nodes(fi.node):
                if not isinstance(node, ast.Call):
                    continue
                for callee in cg._resolve_one(fi, idx, node.func):
                    nums = donors.get(callee)
                    if not nums:
                        continue
                    for n in nums:
                        if n < len(node.args) and isinstance(
                                node.args[n], ast.Name):
                            try:
                                slot = params.index(node.args[n].id)
                            except ValueError:
                                continue
                            cur = donors.setdefault(q, set())
                            if slot not in cur:
                                cur.add(slot)
                                grew = True
        if not grew:
            break
    return donors


def _stmt_list(fn):
    """All statement lists in a def (body/orelse/finally blocks)."""
    out = []
    for node in ast.walk(fn):
        for attr in ("body", "orelse", "finalbody"):
            block = getattr(node, attr, None)
            if isinstance(block, list) and block \
                    and isinstance(block[0], ast.stmt):
                out.append(block)
    return out


def _reads_after(fn, expr_src: str, after_line: int):
    """First read of ``expr_src`` (by unparse identity) after
    ``after_line``, stopping at a rebind of it.  ``x.is_deleted()`` is
    not a read — it is the sanctioned no-copy assertion on the consumed
    handle."""
    guard_nodes = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Attribute) and node.attr == "is_deleted":
            for sub in ast.walk(node.value):
                guard_nodes.add(id(sub))
    events = []     # (line, kind) kind in {read, write}
    for node in ast.walk(fn):
        line = getattr(node, "lineno", None)
        if line is None or line <= after_line:
            continue
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if _unparse(t) == expr_src:
                    events.append((line, "write"))
        elif isinstance(node, (ast.Name, ast.Attribute, ast.Subscript)):
            if isinstance(getattr(node, "ctx", None), ast.Load) \
                    and id(node) not in guard_nodes \
                    and _unparse(node) == expr_src:
                events.append((line, "read"))
    events.sort()
    for line, kind in events:
        if kind == "write":
            return None
        return line
    return None


def _unparse(node) -> str:
    try:
        return ast.unparse(node)
    except Exception:       # pragma: no cover - defensive
        return ""


def check(project):
    cg = project.callgraph
    donors = _collect_donors(project)
    if not donors:
        return
    for fi in cg.funcs.values():
        if fi.module.startswith("repro.analysis"):
            continue
        idx = cg.indexes[fi.module]
        for node in own_body_nodes(fi.node):
            if not isinstance(node, ast.Call):
                continue
            callees = cg._resolve_one(fi, idx, node.func)
            nums = set()
            for c in callees:
                nums |= donors.get(c, set())
            if not nums:
                continue
            for n in sorted(nums):
                if n >= len(node.args):
                    continue
                arg = node.args[n]
                if not isinstance(arg, (ast.Name, ast.Attribute,
                                        ast.Subscript)):
                    continue        # fresh temporary: nothing to misuse
                if isinstance(arg, ast.Name) and _lambda_local(
                        fi.node, node, arg.id):
                    continue        # bound by the enclosing lambda: its
                    # single-expression body has no later statements
                src = _unparse(arg)
                # sanctioned: same-statement rebind  x = f(x, ...)
                stmt = _enclosing_assign(fi.node, node)
                if stmt is not None and any(
                        _unparse(t) == src for t in stmt.targets):
                    continue
                line = _reads_after(fi.node, src,
                                    getattr(node, "end_lineno", node.lineno))
                if line is not None:
                    callee = callees[0].split(":")[1] if callees else "?"
                    yield finding(
                        "donation", fi.file, node,
                        f"{src!r} is donated to {callee}() (arg {n}) but "
                        f"read again at line {line} — the buffer is "
                        f"deleted after donation")


def _lambda_local(fn, call, name: str) -> bool:
    """True when ``call`` sits inside a lambda that binds ``name``."""
    for node in ast.walk(fn):
        if not isinstance(node, ast.Lambda):
            continue
        params = {a.arg for a in node.args.posonlyargs + node.args.args
                  + node.args.kwonlyargs}
        if node.args.vararg:
            params.add(node.args.vararg.arg)
        if name not in params:
            continue
        for sub in ast.walk(node):
            if sub is call:
                return True
    return False


def _enclosing_assign(fn, call):
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            for sub in ast.walk(node.value):
                if sub is call:
                    return node
    return None


RULE = Rule(
    id="donation",
    doc="donated buffer (donate_argnums) read after the donating call",
    check=check,
)
