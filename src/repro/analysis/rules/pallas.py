"""Rule ``kernel``: Pallas kernel constraints at every pallas_call site.

Two checks per ``pl.pallas_call``:

* **VMEM budget** — per grid step, the blocks the pipeline keeps resident
  are every in/out BlockSpec block (double-buffered, so x2) plus VMEM
  scratch.  Block dims are evaluated against module constants and
  single-assignment locals of the enclosing wrapper (so
  ``tile = min(TILE_MAX, _pow2ceil(S))`` bounds to ``TILE_MAX``);
  BlockSpec dtypes are unknown statically and assumed 4 bytes, scratch
  dtypes are read from the ``pltpu.VMEM((...), dtype)`` literal.  Dims
  that cannot be bounded are skipped, making the estimate a *lower*
  bound — exceeding the budget is definitely real.

* **kernel body** — the kernel callable (resolved through the local
  ``kern = functools.partial(_kernel, ...)`` idiom and followed into
  same-module helper functions) must not reference f64
  (``jnp.float64``/``np.float64``/``astype(...float64)``), host numpy, or
  the banned primitives (``sort``/``argsort``/``unique``/``nonzero``/
  ``searchsorted``/``median``/``percentile``/``while_loop``) — none of
  which lower to TPU Pallas.
"""
from __future__ import annotations

import ast

from ..engine import finding
from .common import Rule, dotted, eval_int, local_env, module_constants

_DTYPE_BYTES = {
    "float64": 8, "int64": 8, "uint64": 8, "complex64": 8,
    "float32": 4, "int32": 4, "uint32": 4,
    "bfloat16": 2, "float16": 2, "int16": 2, "uint16": 2,
    "int8": 1, "uint8": 1, "bool_": 1, "bool": 1,
}

_BANNED_ATTRS = {"sort", "argsort", "unique", "nonzero", "searchsorted",
                 "median", "percentile", "while_loop"}
_ARRAY_MODULES = {"jnp", "np", "numpy", "lax", "jax"}


def _dtype_bytes(node) -> int:
    name = dotted(node) or (node.value if isinstance(node, ast.Constant)
                            and isinstance(node.value, str) else "")
    if name:
        return _DTYPE_BYTES.get(str(name).split(".")[-1], 4)
    return 4


def _block_shape(spec_call):
    """BlockSpec((d0, d1), index_map) -> list of dim AST nodes."""
    shape = None
    if spec_call.args:
        shape = spec_call.args[0]
    for kw in spec_call.keywords:
        if kw.arg == "block_shape":
            shape = kw.value
    if isinstance(shape, (ast.Tuple, ast.List)):
        return list(shape.elts)
    return None


def _iter_specs(node):
    """Flatten a BlockSpec | [BlockSpec, ...] keyword value."""
    if isinstance(node, (ast.Tuple, ast.List)):
        for e in node.elts:
            yield from _iter_specs(e)
    elif isinstance(node, ast.Call) and \
            (dotted(node.func) or "").endswith("BlockSpec"):
        yield node


def _block_bytes(dims, env, width) -> int:
    total = width
    for d in dims:
        val = eval_int(d, env)
        if val is not None and val > 0:
            total *= val
    return total


def _vmem_estimate(call, env, cfg) -> tuple:
    """(bytes, description) lower-bound VMEM footprint per grid step."""
    total = 0
    parts = []
    for kw in call.keywords:
        if kw.arg in {"in_specs", "out_specs"}:
            for spec in _iter_specs(kw.value):
                dims = _block_shape(spec)
                if dims is None:
                    continue
                b = _block_bytes(dims, env, 4) * cfg.vmem_pipeline_factor
                total += b
                parts.append(f"{kw.arg}:{b}")
        elif kw.arg == "scratch_shapes":
            items = kw.value.elts \
                if isinstance(kw.value, (ast.Tuple, ast.List)) else []
            for item in items:
                if not (isinstance(item, ast.Call)
                        and (dotted(item.func) or "").endswith("VMEM")):
                    continue
                shape = item.args[0] if item.args else None
                if not isinstance(shape, (ast.Tuple, ast.List)):
                    continue
                width = _dtype_bytes(item.args[1]) \
                    if len(item.args) > 1 else 4
                b = _block_bytes(list(shape.elts), env, width)
                total += b
                parts.append(f"scratch:{b}")
    return total, " + ".join(parts)


def _module_defs(file) -> dict:
    out = {}
    for node in file.tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out[node.name] = node
    return out


def _resolve_kernel(call, env, defs):
    """pallas_call's first arg -> kernel def node (through partial)."""
    if not call.args:
        return None
    target = call.args[0]
    for _ in range(4):
        if isinstance(target, ast.Name):
            if target.id in defs:
                return defs[target.id]
            target = env.get(target.id)
        elif isinstance(target, ast.Call) and \
                dotted(target.func) in {"functools.partial", "partial"} \
                and target.args:
            target = target.args[0]
        else:
            return None
    return None


def _scan_body(kernel, defs, f, site_line):
    """Yield findings from the kernel body and same-module helpers."""
    visited = set()
    queue = [kernel]
    while queue:
        fn = queue.pop()
        if fn.name in visited:
            continue
        visited.add(fn.name)
        for node in ast.walk(fn):
            name = dotted(node) if isinstance(
                node, (ast.Attribute, ast.Name)) else None
            if isinstance(node, ast.Attribute) and name:
                root, leaf = name.split(".")[0], name.split(".")[-1]
                if "float64" in name or leaf == "float64":
                    yield finding(
                        "kernel", f, node,
                        f"f64 reference {name!r} in kernel body "
                        f"{fn.name!r} (pallas_call at line {site_line})")
                elif root in {"np", "numpy"}:
                    yield finding(
                        "kernel", f, node,
                        f"host numpy {name!r} in kernel body {fn.name!r} "
                        f"(pallas_call at line {site_line})")
                elif leaf in _BANNED_ATTRS and root in _ARRAY_MODULES:
                    yield finding(
                        "kernel", f, node,
                        f"{name!r} does not lower to TPU Pallas — banned "
                        f"in kernel body {fn.name!r} (pallas_call at "
                        f"line {site_line})")
            elif isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "astype":
                for a in node.args:
                    # dotted jnp.float64 args hit the Attribute check
                    # above; this catches the string-dtype spelling.
                    if isinstance(a, ast.Constant) and a.value == "float64":
                        yield finding(
                            "kernel", f, node,
                            f"astype(float64) in kernel body {fn.name!r} "
                            f"(pallas_call at line {site_line})")
            elif isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Name) \
                    and node.func.id in defs:
                queue.append(defs[node.func.id])


def _sites(tree, consts):
    """Yield (env, pallas_call) with the env of the innermost enclosing
    def (module constants at top level) — each site exactly once."""
    env_cache: dict[int, dict] = {}

    def env_for(owner):
        if owner is None:
            return consts
        if id(owner) not in env_cache:
            env_cache[id(owner)] = local_env(owner, consts)
        return env_cache[id(owner)]

    def visit(node, owner):
        for child in ast.iter_child_nodes(node):
            nxt = child if isinstance(
                child, (ast.FunctionDef, ast.AsyncFunctionDef)) else owner
            if isinstance(child, ast.Call) and (
                    dotted(child.func) or "").endswith("pallas_call"):
                yield env_for(owner), child
            yield from visit(child, nxt)

    yield from visit(tree, None)


def check(project):
    cfg = project.config
    for f in project.files:
        if f.module.startswith("repro.analysis"):
            continue
        consts = module_constants(f.tree)
        defs = _module_defs(f)
        for env, call in _sites(f.tree, consts):
            est, desc = _vmem_estimate(call, env, cfg)
            if est > cfg.vmem_budget_bytes:
                yield finding(
                    "kernel", f, call,
                    f"pallas_call VMEM lower bound {est} bytes ({desc}) "
                    f"exceeds budget {cfg.vmem_budget_bytes}")
            kernel = _resolve_kernel(call, env, defs)
            if kernel is not None:
                yield from _scan_body(kernel, defs, f, call.lineno)


RULE = Rule(
    id="kernel",
    doc="Pallas VMEM budget and banned-primitive/f64 checks at "
        "pl.pallas_call sites",
    check=check,
)
