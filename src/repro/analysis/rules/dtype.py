"""Rule ``f32-cast``: dtype exactness for key arrays.

The index's correctness story depends on keys staying f64 until the
``f32_exact`` gate proves the f32 roundtrip lossless; an f32 cast of a
key-like array anywhere else silently merges f32-colliding keys.
Flagged spellings: ``X.astype(jnp.float32 | np.float32 | "float32")``,
``jnp.float32(X)`` / ``np.float32(X)``, and
``jnp.asarray/array(X, dtype=float32)`` where ``X`` mentions a key-like
identifier (``Config.key_name_re``).  Exempt contexts: modules under
``Config.f32_cast_ok_modules`` (the kernel boundary — every wrapper sits
behind the gate) and functions that themselves implement an f32-exactness
guard (their body references ``f32_exact``/``_f32_exact``/``_delta_f32``).
"""
from __future__ import annotations

import ast
import re

from ..engine import finding
from .common import Rule, dotted

_F32_NAMES = {"float32", "f32"}
_GUARD_RE = re.compile(r"\b(_?f32_exact|_delta_f32|_keys_f32_exact)\b")


def _is_f32_dtype(node) -> bool:
    name = dotted(node)
    if name and name.split(".")[-1] in _F32_NAMES:
        return True
    return isinstance(node, ast.Constant) and node.value == "float32"


def _mentions_key(node, key_re) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and key_re.search(sub.id):
            return True
        if isinstance(sub, ast.Attribute) and key_re.search(sub.attr):
            return True
    return False


def _guarded(fn_src: str) -> bool:
    return bool(_GUARD_RE.search(fn_src))


def _guard_map(tree) -> dict:
    """id(node) -> True when the node sits inside a def whose body
    references an f32-exactness guard."""
    guards: dict[int, bool] = {}

    def mark(node, guarded):
        for child in ast.iter_child_nodes(node):
            g = guarded
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                g = guarded or _guarded(ast.unparse(child))
            guards[id(child)] = g
            mark(child, g)

    mark(tree, False)
    return guards


def check(project):
    key_re = re.compile(project.config.key_name_re)
    ok_prefixes = project.config.f32_cast_ok_modules
    for f in project.files:
        if f.module.startswith("repro.analysis"):
            continue
        if any(f.module == p or f.module.startswith(p + ".")
               for p in ok_prefixes):
            continue
        # map each node to its innermost def's guardedness
        guards = _guard_map(f.tree)
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.Call):
                continue
            hit = None
            fn = node.func
            name = dotted(fn)
            if isinstance(fn, ast.Attribute) and fn.attr == "astype" \
                    and node.args and _is_f32_dtype(node.args[0]) \
                    and not isinstance(fn.value, ast.Compare) \
                    and _mentions_key(fn.value, key_re):
                # (a Compare receiver is a boolean mask, not keys)
                hit = fn.value
            elif name and name.split(".")[-1] == "float32" and node.args \
                    and _mentions_key(node.args[0], key_re):
                hit = node.args[0]
            elif name and name.split(".")[-1] in {"asarray", "array"} \
                    and node.args and _mentions_key(node.args[0], key_re):
                for kw in node.keywords:
                    if kw.arg == "dtype" and _is_f32_dtype(kw.value):
                        hit = node.args[0]
            if hit is None or guards.get(id(node), False):
                continue
            yield finding(
                "f32-cast", f, node,
                f"f32 cast of key-like value {ast.unparse(hit)!r} outside "
                f"the f32_exact guard/kernel boundary — f32-colliding f64 "
                f"keys would silently merge")


RULE = Rule(
    id="f32-cast",
    doc="f32 cast of key arrays outside approved f32_exact guard sites",
    check=check,
)
