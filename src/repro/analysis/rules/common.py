"""Shared rule plumbing: the Rule record and small AST utilities."""
from __future__ import annotations

import ast
from dataclasses import dataclass


@dataclass(frozen=True)
class Rule:
    id: str
    doc: str
    check: object               # callable(project) -> iterable[Finding]


def dotted(node: ast.AST) -> str | None:
    """``jnp.lax.sort``-style dotted name for Name/Attribute chains."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def own_body_nodes(func: ast.AST):
    """Walk a def's subtree, excluding nested def subtrees (those are
    separate call-graph nodes and would double-report)."""
    stack = list(ast.iter_child_nodes(func))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            stack.extend(ast.iter_child_nodes(node))


_META_ATTRS = {"shape", "ndim", "size", "dtype", "itemsize", "nbytes"}
_META_FUNCS = {"len", "min", "max", "abs", "round", "sorted", "sum",
               "range", "int", "float", "bool", "str"}
_HOST_REDUCTIONS = {"max", "min", "sum", "any", "all", "mean", "item",
                    "tolist", "astype", "copy", "bit_length", "argmax",
                    "argmin", "nonzero"}
_SCALAR_ANNOTATIONS = {"int", "float", "bool", "str"}


def scalar_env(fn: ast.AST) -> dict:
    """Host-value environment for :func:`is_metadata_expr`: parameters
    annotated with a scalar type map to True; every other name maps to
    the list of expressions assigned to it in the body (a name is then
    host-valued iff *all* of them are)."""
    env: dict = {}
    args = fn.args
    for a in args.posonlyargs + args.args + args.kwonlyargs:
        if isinstance(a.annotation, ast.Name) \
                and a.annotation.id in _SCALAR_ANNOTATIONS:
            env[a.arg] = True
    assigns: dict[str, list] = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                for name in ast.walk(t):
                    if isinstance(name, ast.Name):
                        assigns.setdefault(name.id, []).append(node.value)
        elif isinstance(node, ast.AugAssign) \
                and isinstance(node.target, ast.Name):
            assigns.setdefault(node.target.id, []).append(node.value)
        elif isinstance(node, ast.For):
            for name in ast.walk(node.target):
                if isinstance(name, ast.Name):
                    assigns.setdefault(name.id, []).append(node.iter)
    for name, exprs in assigns.items():
        env.setdefault(name, exprs)
    return env


def is_metadata_expr(node: ast.AST, env: dict | None = None,
                     _stack: frozenset = frozenset()) -> bool:
    """True when evaluating ``node`` can never force a device->host sync:
    python constants, scalar-annotated parameters, ``len()``/``math.*``
    arithmetic, ``.shape``/``.ndim``/``.size``/``.dtype`` metadata, host
    numpy results (``np.*`` values already live on host — the *call* that
    made them is judged separately), and reductions/arithmetic over any
    of those.  A bare untracked Name is *not* metadata — it may hold a
    device array.  Self-referential assignments resolve optimistically."""
    env = env or {}

    def rec(n, stack=_stack):
        return is_metadata_expr(n, env, stack)

    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.Name):
        if node.id in _stack:
            return True
        got = env.get(node.id)
        if got is True:
            return True
        if isinstance(got, list):
            stack = _stack | {node.id}
            return all(rec(e, stack) for e in got)
        return False
    if isinstance(node, ast.Attribute):
        return node.attr in _META_ATTRS or rec(node.value)
    if isinstance(node, ast.Subscript):
        return rec(node.value)
    if isinstance(node, ast.Call):
        fn = node.func
        if isinstance(fn, ast.Name) and fn.id in _META_FUNCS:
            return all(rec(a) for a in node.args)
        name = dotted(fn)
        if name and name.split(".")[0] == "math":
            return all(rec(a) for a in node.args)
        if name and name.split(".")[0] in {"np", "numpy"}:
            return True
        if isinstance(fn, ast.Attribute) and fn.attr in _HOST_REDUCTIONS:
            return rec(fn.value)
        return False
    if isinstance(node, ast.BinOp):
        return rec(node.left) and rec(node.right)
    if isinstance(node, (ast.UnaryOp, ast.Starred)):
        return rec(node.operand if isinstance(node, ast.UnaryOp)
                   else node.value)
    if isinstance(node, ast.BoolOp):
        return all(rec(v) for v in node.values)
    if isinstance(node, ast.Compare):
        return rec(node.left) and all(rec(c) for c in node.comparators)
    if isinstance(node, ast.IfExp):
        return rec(node.test) and rec(node.body) and rec(node.orelse)
    if isinstance(node, (ast.Tuple, ast.List)):
        return all(rec(e) for e in node.elts)
    return False


def module_constants(tree: ast.Module) -> dict:
    """Top-level ``NAME = <int expr>`` bindings, evaluated where possible
    (handles the ``TQ = 1024`` / ``TILE_MAX = 1 << 18`` idiom)."""
    out: dict[str, int] = {}
    for node in tree.body:
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)):
            val = eval_int(node.value, out)
            if val is not None:
                out[node.targets[0].id] = val
    return out


def eval_int(node: ast.AST, env: dict, depth: int = 0) -> int | None:
    """Best-effort integer evaluation over constants, module/local names
    in ``env``, arithmetic, and ``min``/``max``.  ``min(KNOWN, unknown)``
    yields KNOWN as an *upper bound* (that is the conservative direction
    for a VMEM budget check); unknowns elsewhere yield None."""
    if depth > 16:
        return None
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return node.value
    if isinstance(node, ast.Name):
        got = env.get(node.id)
        if isinstance(got, int):
            return got
        if isinstance(got, ast.AST):
            return eval_int(got, env, depth + 1)
        return None
    if isinstance(node, ast.BinOp):
        lhs = eval_int(node.left, env, depth + 1)
        rhs = eval_int(node.right, env, depth + 1)
        if lhs is None or rhs is None:
            return None
        try:
            if isinstance(node.op, ast.Add):
                return lhs + rhs
            if isinstance(node.op, ast.Sub):
                return lhs - rhs
            if isinstance(node.op, ast.Mult):
                return lhs * rhs
            if isinstance(node.op, ast.FloorDiv):
                return lhs // rhs
            if isinstance(node.op, ast.Mod):
                return lhs % rhs
            if isinstance(node.op, ast.LShift):
                return lhs << rhs
            if isinstance(node.op, ast.RShift):
                return lhs >> rhs
            if isinstance(node.op, ast.Pow):
                return lhs ** rhs
            if isinstance(node.op, ast.BitOr):
                return lhs | rhs
            if isinstance(node.op, ast.BitAnd):
                return lhs & rhs
        except (ValueError, ZeroDivisionError, OverflowError):
            return None
        return None
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        val = eval_int(node.operand, env, depth + 1)
        return -val if val is not None else None
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in {"min", "max"}:
        vals = [eval_int(a, env, depth + 1) for a in node.args]
        known = [v for v in vals if v is not None]
        if not known:
            return None
        if node.func.id == "min":
            # min(KNOWN, unknown) <= KNOWN: a valid upper bound.
            return min(known)
        return max(known) if len(known) == len(vals) else None
    return None


def local_env(func: ast.AST, consts: dict) -> dict:
    """Single-assignment local names layered over module constants, so
    ``tile = min(TILE_MAX, _pow2ceil(S))`` inside a wrapper resolves to an
    upper bound at the pallas_call site."""
    env = dict(consts)
    counts: dict[str, int] = {}
    exprs: dict[str, ast.AST] = {}
    for node in ast.walk(func):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)):
            name = node.targets[0].id
            counts[name] = counts.get(name, 0) + 1
            exprs[name] = node.value
    for name, expr in exprs.items():
        if counts[name] == 1 and name not in env:
            env[name] = expr
    return env
