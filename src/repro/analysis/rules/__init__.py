"""Rule registry: each rule module exposes a ``RULE`` record
(``id``, one-line ``doc``, ``check(project)``); the engine iterates
``RULES`` and owns suppression/rendering."""
from . import donation, dtype, hostsync, pallas, retrace

RULES = [
    hostsync.RULE,
    retrace.RULE,
    donation.RULE,
    pallas.RULE,
    dtype.RULE,
]

KNOWN_RULE_IDS = {r.id for r in RULES}

__all__ = ["RULES", "KNOWN_RULE_IDS"]
