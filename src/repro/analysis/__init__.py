"""tracelint — repo-native static analysis for the lazy-index serving stack.

The load-bearing invariants of this repo (zero retraces after warmup,
honored buffer donation, one host sync per served batch, f32-exactness on
kernel paths, VMEM-bounded Pallas kernels) are enforced at *runtime* by
guards like ``core.distributed.TRACE_COUNTS`` and
``scatter_rows_donated``'s ``is_deleted()`` assert — which means a
violation only surfaces when a test happens to exercise it.  This package
checks the same contracts at *analysis* time, over the AST, so a hot-path
sync or a donated-buffer reuse fails CI before any workload hits it.

Usage::

    PYTHONPATH=src python -m repro.analysis src benchmarks examples
    PYTHONPATH=src python -m repro.analysis --list-rules
    PYTHONPATH=src python -m repro.analysis --vmem-budget 8388608 src

Exit status is non-zero iff any *unsuppressed* finding (or malformed
pragma) remains.  Findings print as ``path:line: [rule-id] message``.

Rules (one module each under ``repro.analysis.rules``):

``hot-sync``
    Host synchronization inside the serving hot path.  The hot path is
    every function reachable — over the project call graph — from the
    front-end's dispatch/resolve roots (``BatchingFrontend._dispatch``,
    ``BatchingFrontend._resolve``, ``TenantPack.find``/``find_range``).
    Flagged constructs: ``np.asarray``/``np.array``/``np.copy`` on device
    values, ``jax.device_get``, ``jax.block_until_ready`` /
    ``.block_until_ready()``, ``.item()``/``.tolist()``, and
    ``int()``/``float()``/``bool()`` of non-trivial expressions
    (``.shape``/``len()`` metadata access is exempt — it never syncs).

    **The hot-path sync-point contract**: a served batch performs exactly
    ONE host sync, at result resolution (``BatchingFrontend._resolve``
    materializing the batch's device arrays after dispatch).  Everything
    else on the dispatch path must stay asynchronous; host-side *numpy
    mirrors* (counters and capacity metadata maintained O(touched) by the
    mutation paths) are read freely but must be annotated where the
    analyzer cannot see they never touch device buffers.

``retrace``
    Retrace hazards inside jit-traced code: python branches
    (``if``/``while``/ternary/``assert``) on traced arguments, host
    materialization (``numpy`` calls, ``int()``/``float()``/``bool()``,
    ``.item()``) of traced arguments, shapes computed from traced values,
    and per-call ``jax.jit`` construction (jit of a lambda, or jit built
    inside a loop) whose fresh trace cache retraces on every call.
    Traced contexts are ``@jax.jit``-decorated functions (directly or via
    ``functools.partial``), functions wrapped by ``jax.jit(f)`` /
    ``jax.shard_map(f)`` call sites, and defs nested inside those bodies.
    Arguments named by ``static_argnums``/``static_argnames`` are exempt.

``donation``
    Donation discipline: for every callable jitted with
    ``donate_argnums`` (and every thin wrapper that forwards its own
    parameter into a donated slot, e.g.
    ``core.distributed.scatter_rows_donated``), a caller must not read
    the donated buffer after the call — XLA consumed it.  The
    ``x = f(x, ...)`` same-statement rebind is recognized as the idiom.

``kernel``
    Pallas kernel constraints at every ``pl.pallas_call`` site: the
    per-grid-step VMEM footprint — BlockSpec block shapes x dtype width,
    doubled for the pipeline's double buffering, plus scratch — must fit
    the configurable budget (default 16 MiB, a TPU core's VMEM); kernel
    bodies (resolved through ``functools.partial`` and followed into
    same-module helpers) must not touch f64 or host numpy, nor the
    disallowed primitives (``sort``/``argsort``/``unique``/``nonzero``/
    ``searchsorted``/``while_loop`` — none of them lower to TPU Pallas).
    Dimensions the evaluator cannot bound are skipped (the budget check
    is then a lower bound) — it still bounds ``min(CONST, ...)`` shapes
    like the key-tile clamp.

``f32-cast``
    dtype exactness: casting *key-like* arrays (names matching the key
    regex: keys/queries/q_lo/q_hi/splits/...) to f32 is only legal inside
    ``repro.kernels`` (every kernel wrapper sits behind the ``f32_exact``
    path-selection gate) or inside functions that themselves implement an
    ``f32_exact`` guard.  Anywhere else an f32 key cast silently merges
    f32-colliding f64 keys.

Pragma grammar (inline suppression — there is **no** baseline file; every
suppression is an annotation at the offending line and MUST carry a
non-empty reason)::

    # tracelint: ok[<rule-id>](<reason>)     — suppress <rule-id> here
    # sync: ok(<reason>)                     — alias for ok[hot-sync]

A pragma suppresses findings of that rule on any line of the statement it
annotates (trailing comment) or on the statement directly below it (own
line).  A pragma with an empty reason, an unknown rule id, or a malformed
spelling is itself reported (rule id ``pragma``) and cannot be
suppressed.  The one sanctioned hot-path sync (see contract above) is
annotated ``# sync: ok(the one host sync per batch: ...)`` at its site in
``serve/frontend.py``.
"""
from .engine import Config, Finding, Project, analyze, main

__all__ = ["Config", "Finding", "Project", "analyze", "main"]
