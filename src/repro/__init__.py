"""repro — production JAX framework reproducing
"A Lazy Approach for Efficient Index Learning" (Liu, Kulik, Ma, Qi; CS.DB 2021).

Layers:
  repro.core     — the paper: agile model reuse, RMI/RMRT, bounds, baselines.
  repro.kernels  — Pallas TPU kernels for the index hot paths.
  repro.models   — LM substrate (10 assigned architectures).
  repro.train    — distributed training runtime (shard_map manual SPMD).
  repro.serve    — serving runtime (paged KV cache, decode loop).
  repro.data     — data pipeline with learned-index integration.
  repro.launch   — mesh/dry-run/roofline/launcher entry points.

The index core operates on 64-bit keys (SOSD-style u64); we enable x64 here.
All LM code pins bf16/f32 dtypes explicitly so this never leaks into it.
"""
import jax

jax.config.update("jax_enable_x64", True)

# Installs the jax 0.4.x compat shims (jax.shard_map, AxisType, pcast, ...)
# as an import side effect; must run before any repro module traces.
from .models import sharding as _jax_compat  # noqa: E402,F401

__version__ = "1.0.0"
