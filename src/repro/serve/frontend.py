"""Async batched serving front-end for sharded dynamic indexes.

Pipeline (the latency-budget / capacity-class contract)::

    submit() -> request queue -> AdaptiveBatcher -> TenantPack.find -> scatter
                                     |                    |
                          coalesce up to the        one stacked shard_map
                          latency budget (or        dispatch over every
                          the batch-size cap)       tenant, padded to pow2
                                                    capacity classes

* **Coalescing**: requests wait at most ``ServeConfig.latency_budget_s``
  measured from the *oldest* queued request; a batch also cuts early when
  the queued key count reaches ``max_batch``.  Batching trades that bounded
  queueing delay for one dispatch amortized over every caller in the
  window.
* **Capacity-class padding**: the live batch pads to
  ``kernels.lookup.capacity_class`` widths (pow2, 128 floor), so the jitted
  stacked dispatch sees only pow2 query shapes — after warmup the hot path
  **never retraces**; batch-size variation changes pad contents, not
  shapes.  (``core.distributed.TRACE_COUNTS`` exposes the trace counter
  the guard tests pin.)
* **Multi-tenant stacked dispatch**: N independent ``ShardedDynamicIndex``
  tenants answer in one ``shard_map`` program
  (``core.distributed._tenant_stacked_find_fn``).  Tenants of different
  build sizes share the single trace: tiers pad to cross-tenant max
  capacity classes, leaf tables pad to the widest tenant with the last
  live leaf replicated (``lookup.pad_packed_leaves``), and per-tenant
  routing rescales ride the data — the traced ``route_n`` scalars on the
  jnp path, the ``pack_root(route_scale=...)`` fold on the kernel path.
* **Double-buffered dispatch**: up to ``pipeline_depth`` batches stay in
  flight; while batch k executes on device, the loop coalesces, stages
  (``jax.device_put``) and dispatches batch k+1, so the device never
  idles between batches.  Results resolve (one host sync per batch) and
  scatter back to each caller's future.
* **Find/update interleaving**: insert/delete requests coalesce into the
  same batches; they apply *before* the batch's finds dispatch (finds
  observe every update coalesced with them).  Mutations ride the PR 5
  dirty-row slice cache twice over — each tenant restacks only its dirty
  shard rows, and the tenant stack rewrites only the mutated tenants'
  rows (donated row scatters, true in-place writes).
* **Range requests** (``submit_range``): the ``"range"`` kind answers
  inclusive key ranges ``[lo, hi]`` with global live ranks
  ``(rank_lo, rank_hi)`` — leftmost rank of ``lo``, rightmost rank of
  ``hi`` under duplicates, tombstones excluded, ``rank_hi`` clamped so
  degenerate ranges come back empty.  Ranges coalesce into the same
  batches as point finds but dispatch through their own stacked program
  (``core.distributed._tenant_stacked_range_fn``) on a [lo block | hi
  block] query row with its own capacity class.  Both endpoints of every
  pair count toward the ``max_batch`` early-cut, so one scan-heavy caller
  can't starve the coalescer.
* **Typed requests**: every submission surface funnels through
  ``submit(Request(tenant, kind, payload))`` — the ``submit_*`` methods
  are thin constructors.  Payload validation (the kind filter, the
  finiteness rejection that protects the +inf-padded delta tier, range
  endpoint pairing) lives in exactly one place: the :class:`Request`
  constructor.
* **Idle-window drift maintenance**: when the queue drains after a batch,
  the dispatcher thread gives each tenant one pool hot-swap pass
  (``ShardedDynamicIndex.maybe_swap``) — drift-latched shards try the
  Lemma 4.1 bound-checked leaf swaps and ride the dirty-row slice cache
  back into the stacked state, so adaptation happens *between* batches
  with zero retraces and no refit stalls on the serving path.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..core import distributed as dist_mod
from ..core.paths import resolve_path
from ..kernels.lookup import capacity_class, pad_packed_leaves

Array = jax.Array


@dataclass
class ServeConfig:
    """Front-end knobs (see module docstring for the contract)."""
    latency_budget_s: float = 2e-3    # max coalesce wait from oldest request
    max_batch: int = 4096             # early-cut key-count cap per batch
    batch_floor: int = 128            # capacity-class floor for query rows
    pipeline_depth: int = 2           # batches in flight (double-buffered)


REQUEST_KINDS = ("find", "range", "insert", "delete")


class Request:
    """One typed serving request — and the future its caller waits on.

    Validation lives HERE, in exactly one place, for every submission
    surface (``frontend.submit`` and the thin ``submit_*`` wrappers):

      * ``kind`` must be one of ``find | range | insert | delete`` — an
        unrecognized kind would fall through the dispatcher's kind
        filters and leave its caller waiting forever;
      * keys coerce to f64 and must be **finite**: a NaN/±inf insert or
        delete would poison the sorted delta tier (+inf is the delta pad
        sentinel, so a +inf insert silently corrupts every later merge),
        and a non-finite find/range key would walk the rank algebra into
        the exchange's +inf capacity padding;
      * a range's payload is the (2, n) ``[lo; hi]`` endpoint stack —
        endpoint arrays must pair up.
    """
    __slots__ = ("tenant", "kind", "keys", "arrival", "done_at", "found",
                 "rank", "rank_lo", "rank_hi", "error", "_event")

    def __init__(self, tenant: int, kind: str, keys,
                 arrival: float | None = None):
        if kind not in REQUEST_KINDS:
            raise ValueError(
                f"kind must be one of {REQUEST_KINDS}, got {kind!r}")
        keys = np.asarray(keys, np.float64)
        if kind == "range":
            if keys.ndim != 2 or keys.shape[0] != 2:
                raise ValueError(
                    "range payload must be the (2, n) [lo; hi] endpoint "
                    "stack: endpoint arrays must pair up")
        else:
            keys = np.atleast_1d(keys)
            if keys.ndim != 1:
                raise ValueError(f"{kind} payload must be a key vector, "
                                 f"got shape {keys.shape}")
        if not np.all(np.isfinite(keys)):
            raise ValueError(f"{kind} keys must be finite")
        self.tenant = int(tenant)
        self.kind = kind          # one of REQUEST_KINDS
        self.keys = keys          # (n,) keys; ranges carry (2, n) endpoints
        self.arrival = arrival    # stamped by submit() when None
        self.done_at = None               # completion time (frontend clock)
        self.found = None
        self.rank = None
        self.rank_lo = None
        self.rank_hi = None
        self.error = None
        self._event = threading.Event()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None):
        """Block until served.  Finds return ``(found, rank)`` numpy
        arrays, ranges return ``(rank_lo, rank_hi)``; updates return
        ``None`` once applied."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"request not served within {timeout}s")
        if self.error is not None:
            raise self.error
        if self.kind == "find":
            return self.found, self.rank
        if self.kind == "range":
            return self.rank_lo, self.rank_hi
        return None


class AdaptiveBatcher:
    """Pure coalescing policy — no threads, injectable clock, so the
    deadline semantics are unit-testable without wall-clock flakes.

    A batch becomes ready when the *oldest* pending request has waited the
    latency budget, or the queued key count reaches ``max_batch``.
    """

    def __init__(self, latency_budget_s: float, max_batch: int,
                 clock=time.monotonic):
        self.latency_budget_s = float(latency_budget_s)
        self.max_batch = int(max_batch)
        self.clock = clock
        self._pending: list[Request] = []
        self._n_keys = 0

    def __len__(self) -> int:
        return len(self._pending)

    def offer(self, req: Request) -> None:
        self._pending.append(req)
        self._n_keys += req.keys.size

    def deadline(self) -> float | None:
        """Absolute time the current batch must cut at (None when empty)."""
        if not self._pending:
            return None
        return self._pending[0].arrival + self.latency_budget_s

    def ready(self, now: float | None = None) -> bool:
        if not self._pending:
            return False
        if self._n_keys >= self.max_batch:
            return True
        return (self.clock() if now is None else now) >= self.deadline()

    def cut(self) -> list[Request]:
        batch, self._pending, self._n_keys = self._pending, [], 0
        return batch


class TenantPack:
    """N tenants' stacked per-shard state, padded to cross-tenant max
    capacity classes and maintained incrementally: ``find`` refreshes only
    the rows of tenants whose own slice cache changed (donated row
    scatters), and re-assembles cold only when a cross-tenant capacity
    class crosses a pow2."""

    def __init__(self, tenants: list, *, path: str = "auto",
                 use_kernel: bool | None = None,
                 interpret: bool | None = None):
        if not tenants:
            raise ValueError("TenantPack needs at least one tenant")
        mesh, axis = tenants[0].mesh, tenants[0].axis
        kinds = {t.shards[0].index.leaf_kind for t in tenants}
        if any(t.mesh is not mesh or t.axis != axis for t in tenants):
            raise ValueError("tenants must share one mesh and axis")
        if len(kinds) != 1:
            raise ValueError(f"tenants must share one leaf kind: {kinds}")
        use_kernel = resolve_path(
            path, f32_exact=lambda: all(t.f32_exact for t in tenants),
            use_kernel=use_kernel, what="tenant key space")
        self.tenants = tenants
        self.mesh, self.axis = mesh, axis
        self.use_kernel = bool(use_kernel)
        self.interpret = interpret if interpret is None else bool(interpret)
        self.leaf_kind = kinds.pop()
        self.n_leaves = max(t.n_leaves for t in tenants)
        # Common packed lane count: tenants re-pad to the widest tenant's
        # 128-multiple (pack_leaves layout).
        self._lp = -(-self.n_leaves // 128) * 128
        self._st: dict | None = None
        self._geom = None
        self._fps: list | None = None     # per-tenant identity fingerprints
        self.pack_full = 0                # cold tenant-stack assemblies
        self.pack_rows = 0                # tenant rows rewritten in place

    @property
    def n_tenants(self) -> int:
        return len(self.tenants)

    @property
    def n_shards(self) -> int:
        return self.tenants[0].n_shards

    # -- assembly ----------------------------------------------------------
    @staticmethod
    def _fingerprint(st: dict) -> tuple:
        """Identity snapshot of one tenant's stacked arrays.  Holding the
        refs keeps ids stable; comparison is pure ``is`` checks, so a
        tenant whose slice cache was untouched costs O(1) per batch."""
        leaves = jax.tree.leaves((st["root"], st["leaves"], st["packed"]))
        return tuple(st[k] for k in
                     dist_mod.ShardedDynamicIndex._ROW_KEYS) + \
            (st["offs"], st["splits"], st["iters"]) + tuple(leaves)

    def _tenant_row(self, t, st: dict, bcap: int, dcap: int) -> dict:
        """One tenant's (S, ...) slice set padded to the cross-tenant
        geometry — the unit of incremental tenant restacking."""
        L, lt = self.n_leaves, t.n_leaves
        padv = lambda a, c, v: jnp.pad(
            a, ((0, 0), (0, c - a.shape[1])), constant_values=v)
        pade = lambda a, c: jnp.pad(
            a, ((0, 0), (0, c - a.shape[1])) + ((0, 0),) * (a.ndim - 2),
            mode="edge")
        row = dict(
            splits=st["splits"],
            offs=st["offs"],
            # Per-tenant routing rescale as data: the stacked trace routes
            # with static n_leaves = max_t L_t, so a tenant built at L_t
            # scales its frozen per-shard route_n by L / L_t (overshoot
            # past L_t - 1 lands on the replicated last leaf below).
            route_n=st["route_n"] * (jnp.float64(L) / jnp.float64(lt)),
            base=padv(st["base"], bcap, jnp.inf),
            bdead=padv(st["bdead"], bcap, False),
            bpsum=pade(st["bpsum"], bcap + 1),
            dk=padv(st["dk"], dcap, jnp.inf),
            ddead=padv(st["ddead"], dcap, False),
            dpsum=pade(st["dpsum"], dcap + 1),
            root=st["root"],
            leaves=jax.tree.map(lambda a: pade(a, L), st["leaves"]),
            err_lo=pade(st["err_lo"], L),
            err_hi=pade(st["err_hi"], L))
        if self.use_kernel:
            kroot, kmat, kvec = t._packed_stack(st)
            kmat, kvec = pad_packed_leaves(kmat, kvec, lt, self._lp)
            row["kroot"], row["kmat"], row["kvec"] = kroot, kmat, kvec
        return row

    _STACK_KEYS = ("splits", "offs", "route_n", "base", "bdead", "bpsum",
                   "dk", "ddead", "dpsum", "err_lo", "err_hi")

    def _refresh(self) -> dict:
        sts = [t._stacked() for t in self.tenants]
        if self.use_kernel:
            for t, st in zip(self.tenants, sts, strict=True):
                t._packed_stack(st)
        bcap = max(st["bcap"] for st in sts)
        dcap = max(st["dcap"] for st in sts)
        fps = [self._fingerprint(st) for st in sts]
        geom = (bcap, dcap)
        if self._st is None or geom != self._geom:
            rows = [self._tenant_row(t, st, bcap, dcap)
                    for t, st in zip(self.tenants, sts, strict=True)]
            stack = lambda k: jnp.stack([r[k] for r in rows])
            self._st = {k: stack(k) for k in self._STACK_KEYS}
            tmap = lambda k: jax.tree.map(lambda *a: jnp.stack(a),
                                          *[r[k] for r in rows])
            self._st["root"] = tmap("root")
            self._st["leaves"] = tmap("leaves")
            if self.use_kernel:
                for k in ("kroot", "kmat", "kvec"):
                    self._st[k] = stack(k)
            self._geom = geom
            self.pack_full += 1
        else:
            stale = [i for i, fp in enumerate(fps)
                     if not all(a is b
                                for a, b in zip(fp, self._fps[i],
                                                strict=False))
                     or len(fp) != len(self._fps[i])]
            for i in stale:
                row = self._tenant_row(self.tenants[i], sts[i], bcap, dcap)
                idx = jnp.asarray([i])
                for k in self._STACK_KEYS + (
                        ("kroot", "kmat", "kvec") if self.use_kernel
                        else ()):
                    self._st[k] = dist_mod.scatter_rows_donated(
                        self._st[k], idx, row[k][None])
                scat = lambda dst, r, idx=idx: \
                    dist_mod.scatter_rows_donated(dst, idx, r[None])
                self._st["root"] = jax.tree.map(scat, self._st["root"],
                                                row["root"])
                self._st["leaves"] = jax.tree.map(scat, self._st["leaves"],
                                                  row["leaves"])
                self.pack_rows += 1
        self._fps = fps
        self._st["iters"] = max(st["iters"] for st in sts)
        return self._st

    # -- dispatch ----------------------------------------------------------
    def find(self, qmat) -> tuple[Array, Array]:
        """One stacked dispatch: ``qmat`` is (n_tenants, qcap) f64 with
        finite pads (qcap a multiple of the shard count; callers pad to
        ``capacity_class`` widths to stay on the warm trace).  Returns
        (found, rank) as (n_tenants, qcap) device arrays — asynchronous,
        so callers can overlap the next batch's staging."""
        st = self._refresh()
        qmat = jnp.asarray(qmat, jnp.float64)
        T, qcap = qmat.shape
        if T != self.n_tenants or qcap % self.n_shards:
            raise ValueError(f"bad query matrix {qmat.shape}: want "
                             f"({self.n_tenants}, k*{self.n_shards})")
        fn = dist_mod._tenant_stacked_find_fn(
            self.mesh, self.axis, n_tenants=self.n_tenants,
            n_leaves=self.n_leaves, leaf_kind=self.leaf_kind,
            iters=st["iters"], use_kernel=self.use_kernel,
            interpret=self.interpret)
        tables = (st["kroot"], st["kmat"], st["kvec"]) if self.use_kernel \
            else (st["root"], st["leaves"], st["err_lo"], st["err_hi"])
        return fn(st["splits"], st["offs"], st["route_n"], st["base"],
                  st["bdead"], st["bpsum"], st["dk"], st["ddead"],
                  st["dpsum"], tables, qmat)

    def find_range(self, rmat) -> tuple[Array, Array]:
        """One stacked range dispatch: ``rmat`` is (n_tenants, 2 * rcap)
        f64 laid out [lo endpoints | hi endpoints] per row (rcap a multiple
        of the shard count, finite pads).  Returns (rank_lo, rank_hi) as
        (n_tenants, rcap) device arrays with rank_hi clamped to rank_lo —
        same asynchrony contract as :meth:`find`."""
        st = self._refresh()
        rmat = jnp.asarray(rmat, jnp.float64)
        T, w = rmat.shape
        if T != self.n_tenants or w % (2 * self.n_shards):
            raise ValueError(f"bad range matrix {rmat.shape}: want "
                             f"({self.n_tenants}, 2*k*{self.n_shards})")
        fn = dist_mod._tenant_stacked_range_fn(
            self.mesh, self.axis, n_tenants=self.n_tenants,
            n_leaves=self.n_leaves, leaf_kind=self.leaf_kind,
            iters=st["iters"], use_kernel=self.use_kernel,
            interpret=self.interpret)
        tables = (st["kroot"], st["kmat"], st["kvec"]) if self.use_kernel \
            else (st["root"], st["leaves"], st["err_lo"], st["err_hi"])
        rl, rr = fn(st["splits"], st["offs"], st["route_n"], st["base"],
                    st["bdead"], st["bpsum"], st["dk"], st["ddead"],
                    st["dpsum"], tables, rmat)
        rcap = w // 2
        rank_lo = rl[:, :rcap]
        return rank_lo, jnp.maximum(rr[:, rcap:], rank_lo)


@dataclass
class FrontendStats:
    batches: int = 0              # stacked dispatches
    queries: int = 0              # live find keys served
    ranges: int = 0               # live range pairs served
    updates: int = 0              # insert/delete keys applied
    swaps: int = 0                # drift-maintenance pool hot-swaps
    padded_slots: int = 0         # pad lanes dispatched (wasted work)
    qcaps: set = field(default_factory=set)   # capacity classes seen

    @property
    def pad_fraction(self) -> float:
        tot = self.queries + 2 * self.ranges + self.padded_slots
        return self.padded_slots / tot if tot else 0.0


class _InFlight:
    __slots__ = ("found", "rank", "plan", "rank_lo", "rank_hi", "rplan")

    def __init__(self, found, rank, plan, rank_lo=None, rank_hi=None,
                 rplan=()):
        self.found, self.rank, self.plan = found, rank, plan
        self.rank_lo, self.rank_hi, self.rplan = rank_lo, rank_hi, rplan


class BatchingFrontend:
    """The serving loop: a dispatcher thread drains the request queue
    through the batcher into stacked dispatches (module docstring).  Use
    as a context manager, or ``start()``/``stop()`` explicitly."""

    def __init__(self, tenants: list, *, path: str = "auto",
                 use_kernel: bool | None = None,
                 interpret: bool | None = None,
                 config: ServeConfig | None = None, clock=time.monotonic):
        self.config = config or ServeConfig()
        self.pack = TenantPack(tenants, path=path, use_kernel=use_kernel,
                               interpret=interpret)
        self.stats = FrontendStats()
        self.clock = clock
        self.batcher = AdaptiveBatcher(self.config.latency_budget_s,
                                       self.config.max_batch, clock)
        self._cond = threading.Condition()
        self._inflight: deque[_InFlight] = deque()
        self._stop = False
        self._thread: threading.Thread | None = None

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "BatchingFrontend":
        if self._thread is not None:
            raise RuntimeError("frontend already started")
        self._stop = False
        self._thread = threading.Thread(target=self._loop,
                                        name="serve-frontend", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        with self._cond:
            self._stop = True
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    __enter__ = start

    def __exit__(self, *exc) -> None:
        self.stop()

    def warmup(self, batch_sizes=(1,)) -> None:
        """Trace the stacked find AND range dispatches for each capacity
        class the given live batch sizes land in (plus the floor), so
        steady-state serving never pays a trace.  Call before opening the
        queue to traffic."""
        for n in {capacity_class(int(n), self.config.batch_floor)
                  for n in batch_sizes} | {self.config.batch_floor}:
            qcap = max(n, self.pack.n_shards)
            found, rank = self.pack.find(
                jnp.zeros((self.pack.n_tenants, qcap), jnp.float64))
            rlo, rhi = self.pack.find_range(
                jnp.zeros((self.pack.n_tenants, 2 * qcap), jnp.float64))
            jax.block_until_ready((found, rank, rlo, rhi))

    # -- submission --------------------------------------------------------
    def submit(self, request: Request) -> Request:
        """THE submission verb: enqueue one constructed :class:`Request`.
        Payload validation (finiteness, kind filter, range pairing)
        already ran on the Request constructor — this only checks the
        frontend-level facts (started, known tenant), stamps the arrival
        clock, and offers the request to the coalescer.  The ``submit_*``
        convenience wrappers below all funnel through here."""
        if self._thread is None:
            raise RuntimeError("frontend not started")
        if not 0 <= request.tenant < self.pack.n_tenants:
            raise ValueError(f"unknown tenant {request.tenant}")
        if request.arrival is None:
            request.arrival = self.clock()
        with self._cond:
            self.batcher.offer(request)
            self._cond.notify_all()
        return request

    def submit_find(self, tenant: int, keys) -> Request:
        return self.submit(Request(tenant, "find", keys))

    def submit_range(self, tenant: int, lo_keys, hi_keys) -> Request:
        """Inclusive key ranges ``[lo, hi]`` -> ``(rank_lo, rank_hi)``
        global live ranks (module docstring).  Both endpoint arrays count
        toward the batch key cap."""
        lo = np.atleast_1d(np.asarray(lo_keys, np.float64))
        hi = np.atleast_1d(np.asarray(hi_keys, np.float64))
        if lo.shape != hi.shape:
            raise ValueError(
                "range payload must be the (2, n) [lo; hi] endpoint "
                "stack: endpoint arrays must pair up")
        return self.submit(Request(tenant, "range", np.stack([lo, hi])))

    def submit_insert(self, tenant: int, keys) -> Request:
        return self.submit(Request(tenant, "insert", keys))

    def submit_delete(self, tenant: int, keys) -> Request:
        return self.submit(Request(tenant, "delete", keys))

    def lookup(self, tenant: int, keys, timeout: float | None = 60.0):
        """Synchronous convenience: submit one find and wait."""
        return self.submit_find(tenant, keys).result(timeout)

    def scan(self, tenant: int, lo_keys, hi_keys,
             timeout: float | None = 60.0):
        """Synchronous convenience: submit one range request and wait."""
        return self.submit_range(tenant, lo_keys, hi_keys).result(timeout)

    # -- the serving loop --------------------------------------------------
    def _collect(self) -> list | None:
        """Block for the next batch: wait for a first request, then
        coalesce until the batcher's deadline (or size cap).  Returns None
        on shutdown with nothing pending."""
        with self._cond:
            while not len(self.batcher):
                if self._stop:
                    return None
                self._cond.wait(timeout=0.05)
            while not self._stop and not self.batcher.ready():
                dl = self.batcher.deadline()
                self._cond.wait(timeout=max(dl - self.clock(), 0.0))
            return self.batcher.cut()

    def _apply_updates(self, batch: list) -> None:
        """Mutations coalesced into this batch apply before its finds
        dispatch — each tenant's dirty-row slice cache (and the tenant
        stack above it) then refreshes O(touched) at assembly."""
        for req in batch:
            if req.kind in ("find", "range"):
                continue
            try:
                tenant = self.pack.tenants[req.tenant]
                if req.kind == "insert":
                    tenant.insert_batch(req.keys)
                else:
                    tenant.delete_batch(req.keys)
                self.stats.updates += req.keys.size
            except Exception as e:          # broad: fail the caller
                req.error = e
            req.done_at = self.clock()
            req._event.set()

    def _dispatch(self, batch: list) -> _InFlight | None:
        finds = [r for r in batch if r.kind == "find"]
        rngs = [r for r in batch if r.kind == "range"]
        if not finds and not rngs:
            return None
        found = rank = rlo = rhi = None
        plan, rplan = [], []            # (req, tenant, start, stop)
        self.stats.batches += 1
        if finds:
            counts = [0] * self.pack.n_tenants
            for r in finds:
                t = r.tenant
                plan.append((r, t, counts[t], counts[t] + r.keys.size))
                counts[t] += r.keys.size
            qcap = capacity_class(max(counts), self.config.batch_floor)
            qcap = max(qcap, self.pack.n_shards)
            qmat = np.zeros((self.pack.n_tenants, qcap), np.float64)
            for r, t, a, b in plan:
                qmat[t, a:b] = r.keys
            live = sum(counts)
            self.stats.queries += live
            self.stats.padded_slots += qmat.size - live
            self.stats.qcaps.add(qcap)
            # Stage host->device explicitly, then dispatch asynchronously:
            # with pipeline_depth > 1 this batch's transfer and compute
            # overlap the previous batch's compute and the next batch's
            # coalescing.
            found, rank = self.pack.find(jax.device_put(qmat))
        if rngs:
            # Ranges ride their own [lo block | hi block] matrix with an
            # independent capacity class (range traffic is usually far
            # sparser than point traffic — padding one to the other's
            # width would double the wasted lanes).
            rcounts = [0] * self.pack.n_tenants
            for r in rngs:
                t = r.tenant
                n = r.keys.shape[1]
                rplan.append((r, t, rcounts[t], rcounts[t] + n))
                rcounts[t] += n
            rcap = capacity_class(max(rcounts), self.config.batch_floor)
            rcap = max(rcap, self.pack.n_shards)
            rmat = np.zeros((self.pack.n_tenants, 2 * rcap), np.float64)
            for r, t, a, b in rplan:
                rmat[t, a:b] = r.keys[0]
                rmat[t, rcap + a:rcap + b] = r.keys[1]
            rlive = sum(rcounts)
            self.stats.ranges += rlive
            self.stats.padded_slots += rmat.size - 2 * rlive
            self.stats.qcaps.add(rcap)
            rlo, rhi = self.pack.find_range(jax.device_put(rmat))
        return _InFlight(found, rank, plan, rlo, rhi, rplan)

    def _resolve(self, inf: _InFlight) -> None:
        now = self.clock()
        if inf.plan:
            # sync: ok(the one host sync per batch: point results resolve)
            found = np.asarray(inf.found)
            rank = np.asarray(inf.rank)  # sync: ok(rides the found sync)
            for req, t, a, b in inf.plan:
                req.found = found[t, a:b]
                req.rank = rank[t, a:b]
                req.done_at = now
                req._event.set()
        if inf.rplan:
            # sync: ok(range leg of the same batch resolution point)
            rlo = np.asarray(inf.rank_lo)
            rhi = np.asarray(inf.rank_hi)  # sync: ok(rides the rlo sync)
            for req, t, a, b in inf.rplan:
                req.rank_lo = rlo[t, a:b]
                req.rank_hi = rhi[t, a:b]
                req.done_at = now
                req._event.set()

    def _fail(self, batch: list, err: Exception) -> None:
        for req in batch:
            if not req._event.is_set():
                req.error = err
                req.done_at = self.clock()
                req._event.set()

    def _maintain(self) -> None:
        """Idle-window drift maintenance, run on the dispatcher thread
        between batches when the queue has drained: one pool hot-swap pass
        per tenant (``ShardedDynamicIndex.maybe_swap`` — per-leaf Lemma
        4.1 bound-checked commits on the drift-latched shards, riding the
        dirty-row slice cache).  Swaps rewrite stacked row *contents*,
        never shapes or search depths, so the warm find/range traces
        survive — the serve TRACE_COUNTS guard pins zero retraces across
        swap commits.  The same pass also runs the deferred-refit sweep:
        in swap mode the insert path never does structural work, so
        budget-exhausted leaves a swap could not absorb take their O(n)
        merge + refit HERE, in the idle window, off the serving path
        (refits may legitimately retrace — they change base shapes and
        can widen the clamped search depth).  Tenants without drift
        monitoring short-circuit on a host flag; the per-pass cost for
        monitored tenants is the one drift-table sync inside
        ``maybe_swap``."""
        for t in self.pack.tenants:
            swap = getattr(t, "maybe_swap", None)
            if swap is not None:
                self.stats.swaps += swap()

    def _loop(self) -> None:
        while True:
            batch = self._collect()
            if batch is None:
                break
            try:
                self._apply_updates(batch)
                inf = self._dispatch(batch)
            except Exception as e:          # broad: fail the batch
                self._fail(batch, e)
                continue
            if inf is not None:
                self._inflight.append(inf)
            while len(self._inflight) >= self.config.pipeline_depth or \
                    (self._inflight and not len(self.batcher)):
                self._resolve(self._inflight.popleft())
            if not len(self.batcher):
                self._maintain()
        while self._inflight:
            self._resolve(self._inflight.popleft())
