"""Paged KV cache with a learned page table.

vLLM-style paging: the logical KV sequence of each request is scattered
over fixed-size physical pages; a page table maps (request, logical_block)
-> physical page. The default table is a dense int32 array; the *learned*
mode replaces the dense table for the (sorted) global block-key space with
the paper's lookup path — (request_id << 32 | logical_block) keys indexed by
an agile-reuse RMI, exercising repro.kernels.lookup as the serving hot path.

This module manages the page pool on the host (allocation is control-plane)
while gather/scatter of KV pages is jitted data-plane work.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class PagedKVCache:
    n_pages: int
    page_size: int
    n_kv_heads: int
    head_dim: int
    n_layers: int
    dtype: object = jnp.bfloat16
    kv: jax.Array = None                 # (L, 2, n_pages, page, H, dh)
    free: list = None
    table: dict = field(default_factory=dict)   # (req, block) -> page

    def __post_init__(self):
        if self.kv is None:
            self.kv = jnp.zeros((self.n_layers, 2, self.n_pages,
                                 self.page_size, self.n_kv_heads,
                                 self.head_dim), self.dtype)
        if self.free is None:
            self.free = list(range(self.n_pages))

    # -- control plane -----------------------------------------------------
    def allocate(self, req: int, logical_block: int) -> int:
        if not self.free:
            raise MemoryError("KV page pool exhausted")
        page = self.free.pop()
        self.table[(req, logical_block)] = page
        return page

    def release(self, req: int) -> None:
        for key in [k for k in self.table if k[0] == req]:
            self.free.append(self.table.pop(key))

    def pages_for(self, req: int, n_blocks: int) -> np.ndarray:
        return np.asarray([self.table[(req, b)] for b in range(n_blocks)],
                          np.int32)

    # -- data plane ----------------------------------------------------------
    def write(self, layer: int, req_pages: np.ndarray, pos_in_page: int,
              k: jax.Array, v: jax.Array) -> None:
        """Append one token's K/V for a batch of requests (pages gathered
        per request)."""
        pages = jnp.asarray(req_pages)
        self.kv = self.kv.at[layer, 0, pages, pos_in_page].set(k)
        self.kv = self.kv.at[layer, 1, pages, pos_in_page].set(v)

    def gather(self, layer: int, pages: np.ndarray) -> tuple:
        """(k, v) of shape (n_blocks, page, H, dh) for one request."""
        p = jnp.asarray(pages)
        return self.kv[layer, 0, p], self.kv[layer, 1, p]


def learned_page_table(table: dict, *, use_kernel: bool | None = None):
    """Build a learned index over the page table's flat key space.

    Returns (lookup_fn, keys, pages): lookup_fn(query_keys) -> page ids via
    the paper's RMI lookup path with the error-window-clamped search depth.
    The packed (req << 22 | block) keys exceed 2^24 once req > 3 and then do
    not round-trip through f32, so the f32 Pallas kernel path is only legal
    for small tables — ``use_kernel=True`` is rejected when the key space is
    not f32-exact (the kernel's f32 seam verification cannot detect f32 key
    collisions). Used by benchmarks to compare dense vs learned table lookup
    at scale."""
    from repro.core import rmi as rmi_mod
    items = sorted(table.items())
    keys = jnp.asarray([float((r << 22) | b) for (r, b), _ in items])
    pages = jnp.asarray([p for _, p in items], jnp.int32)
    idx = rmi_mod.build_rmi(keys, n_leaves=max(len(items) // 64, 1),
                            kind="linear")
    if use_kernel and not idx.f32_exact:
        raise ValueError(
            "learned_page_table: key space is not f32-exact; the Pallas "
            "kernel path would resolve colliding keys to wrong page ids")

    def lookup(query_keys: jax.Array) -> jax.Array:
        pos = rmi_mod.lookup(idx, query_keys, use_kernel=use_kernel)
        return pages[jnp.clip(pos, 0, pages.shape[0] - 1)]

    return lookup, keys, pages
