"""Paged KV cache with a learned page table.

vLLM-style paging: the logical KV sequence of each request is scattered
over fixed-size physical pages; a page table maps (request, logical_block)
-> physical page. The default table is a dense int32 array; the *learned*
mode replaces the dense table for the (sorted) global block-key space with
the paper's lookup path — (request_id << 32 | logical_block) keys indexed by
an agile-reuse RMI, exercising repro.kernels.lookup as the serving hot path.

This module manages the page pool on the host (allocation is control-plane)
while gather/scatter of KV pages is jitted data-plane work.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

# Packed block-key layout: key = (request_id << _BLOCK_BITS) | logical_block.
# Every packer/unpacker below must use this constant — a divergent shift
# silently aliases (req, block) pairs across requests.
_BLOCK_BITS = 22


@dataclass
class PagedKVCache:
    n_pages: int
    page_size: int
    n_kv_heads: int
    head_dim: int
    n_layers: int
    dtype: object = jnp.bfloat16
    kv: jax.Array = None                 # (L, 2, n_pages, page, H, dh)
    free: list = None
    table: dict = field(default_factory=dict)   # (req, block) -> page

    def __post_init__(self):
        if self.kv is None:
            self.kv = jnp.zeros((self.n_layers, 2, self.n_pages,
                                 self.page_size, self.n_kv_heads,
                                 self.head_dim), self.dtype)
        if self.free is None:
            self.free = list(range(self.n_pages))

    # -- control plane -----------------------------------------------------
    def allocate(self, req: int, logical_block: int) -> int:
        if not self.free:
            raise MemoryError("KV page pool exhausted")
        page = self.free.pop()
        self.table[(req, logical_block)] = page
        return page

    def allocate_batch(self, req: int, logical_blocks) -> np.ndarray:
        """Batched allocation (the serving hot path allocates a request's
        prefill blocks at once): pops len(blocks) pages in one slice so a
        learned table mirror sees one insert batch, not per-block calls."""
        blocks = list(logical_blocks)
        if not blocks:
            return np.empty((0,), np.int32)
        if len(self.free) < len(blocks):
            raise MemoryError("KV page pool exhausted")
        pages = self.free[-len(blocks):][::-1]
        del self.free[-len(blocks):]
        self.table.update(((req, b), p) for b, p in zip(blocks, pages, strict=True))
        return np.asarray(pages, np.int32)

    def release(self, req: int) -> None:
        for key in [k for k in self.table if k[0] == req]:
            self.free.append(self.table.pop(key))

    def pages_for(self, req: int, n_blocks: int) -> np.ndarray:
        return np.asarray([self.table[(req, b)] for b in range(n_blocks)],
                          np.int32)

    # -- data plane ----------------------------------------------------------
    def write(self, layer: int, req_pages: np.ndarray, pos_in_page: int,
              k: jax.Array, v: jax.Array) -> None:
        """Append one token's K/V for a batch of requests (pages gathered
        per request)."""
        pages = jnp.asarray(req_pages)
        self.kv = self.kv.at[layer, 0, pages, pos_in_page].set(k)
        self.kv = self.kv.at[layer, 1, pages, pos_in_page].set(v)

    def gather(self, layer: int, pages: np.ndarray) -> tuple:
        """(k, v) of shape (n_blocks, page, H, dh) for one request."""
        p = jnp.asarray(pages)
        return self.kv[layer, 0, p], self.kv[layer, 1, p]


def learned_page_table(table: dict, *, path: str = "auto",
                       use_kernel: bool | None = None):
    """Build a learned index over the page table's flat key space.

    Returns (lookup_fn, keys, pages): lookup_fn(query_keys) -> page ids via
    the paper's RMI lookup path with the error-window-clamped search depth.
    The packed (req << 22 | block) keys exceed 2^24 once req > 3 and then do
    not round-trip through f32, so the f32 Pallas kernel path is only legal
    for small tables — ``path="kernel"`` is rejected when the key space is
    not f32-exact (the kernel's f32 seam verification cannot detect f32 key
    collisions; ``use_kernel=`` is the deprecated bool shim, see
    ``core.paths``). Used by benchmarks to compare dense vs learned table
    lookup at scale."""
    from repro.core import rmi as rmi_mod
    from repro.core.paths import resolve_path
    items = sorted(table.items())
    keys = jnp.asarray([float((r << _BLOCK_BITS) | b) for (r, b), _ in items])
    pages = jnp.asarray([p for _, p in items], jnp.int32)
    idx = rmi_mod.build_rmi(keys, n_leaves=max(len(items) // 64, 1),
                            kind="linear")
    kernel = resolve_path(path, f32_exact=lambda: idx.f32_exact,
                          use_kernel=use_kernel,
                          what="page-table key space")

    def lookup(query_keys: jax.Array) -> jax.Array:
        pos = rmi_mod.lookup(idx, query_keys,
                             path="kernel" if kernel else "jnp")
        return pages[jnp.clip(pos, 0, pages.shape[0] - 1)]

    return lookup, keys, pages


def _pack_keys(req: int, blocks) -> np.ndarray:
    return np.asarray([(req << _BLOCK_BITS) | int(b) for b in blocks],
                      np.float64)


@dataclass
class DynamicPageTable:
    """Learned page table served by the two-tier dynamic index: block
    allocation/release mutate the index through the *batched* insert/delete
    API of ``core.updates.DynamicRMI`` instead of rebuilding a static RMI,
    so the serving control plane exercises the paper's §4 update path.

    The aligned ``_pages`` array is ordered by live key, which is exactly
    what ``DynamicRMI.find``'s rank indexes — a page lookup is one fused
    find (base window search + delta probe + tombstone mask) plus a gather.
    """
    cache: PagedKVCache
    dyn: object = None                   # DynamicRMI or ShardedDynamicIndex
    _keys: np.ndarray = None             # sorted live block keys
    _pages: np.ndarray = None            # aligned physical page ids

    @classmethod
    def build(cls, cache: PagedKVCache, mesh=None, axis: str = "data",
              **rmi_kwargs):
        """Bootstrap over the cache's current (non-empty) table; subsequent
        allocations ride the delta tier until Lemma 4.1 triggers merges.

        With ``mesh`` given, the table rides the *sharded* dynamic index
        (``core.distributed.ShardedDynamicIndex``): same batched
        insert/delete/find surface, but block keys range-partition across
        the mesh axis and lookups dispatch per shard under shard_map —
        the serving control plane at multi-host scale."""
        items = sorted(cache.table.items())
        if not items:
            raise ValueError("DynamicPageTable.build needs a primed cache")
        keys = np.asarray([float((r << _BLOCK_BITS) | b)
                           for (r, b), _ in items])
        pages = np.asarray([p for _, p in items], np.int32)
        rmi_kwargs.setdefault("n_leaves", max(len(items) // 64, 1))
        if mesh is not None:
            from repro.core.distributed import ShardedDynamicIndex
            dyn = ShardedDynamicIndex.build(jnp.asarray(keys), mesh,
                                            axis=axis, **rmi_kwargs)
        else:
            from repro.core.updates import DynamicRMI
            dyn = DynamicRMI.build(jnp.asarray(keys), **rmi_kwargs)
        return cls(cache=cache, dyn=dyn, _keys=keys, _pages=pages)

    def allocate(self, req: int, logical_blocks) -> np.ndarray:
        """Allocate pages for a request's blocks: one pool pop, one batched
        index insert, one vectorized merge of the page mapping."""
        pages = self.cache.allocate_batch(req, logical_blocks)
        kn = _pack_keys(req, logical_blocks)
        order = np.argsort(kn)
        kn, pages_sorted = kn[order], pages[order]
        self.dyn.insert_batch(kn)
        pos = np.searchsorted(self._keys, kn)
        self._keys = np.insert(self._keys, pos, kn)
        self._pages = np.insert(self._pages, pos, pages_sorted)
        return pages

    def release(self, req: int) -> None:
        """Release a request: one batched tombstone delete over its keys."""
        blocks = [b for (r, b) in self.cache.table if r == req]
        self.cache.release(req)
        if not blocks:
            return
        kn = _pack_keys(req, sorted(blocks))
        self.dyn.delete_batch(kn)
        live = (self._keys.astype(np.int64) >> _BLOCK_BITS) != req
        self._keys = self._keys[live]
        self._pages = self._pages[live]

    def lookup(self, query_keys) -> tuple[np.ndarray, np.ndarray]:
        """(found, page) per flat block key via the fused dynamic find."""
        found, rank = self.dyn.find(jnp.asarray(query_keys, jnp.float64))
        found = np.asarray(found)
        if self._pages.size == 0:       # everything released
            return found, np.zeros(found.shape, np.int32)
        rank = np.clip(np.asarray(rank), 0, self._pages.size - 1)
        return found, self._pages[rank]

    def maintenance_stats(self) -> dict:
        """Index-maintenance counters for the serving control plane (what a
        scheduler watches to size allocation batches): rebuild/compaction
        counts on a single-host table, plus — when the table rides the
        sharded index — rebalances split by kind and the slice-cache
        restack accounting (``restack_rows`` grows O(touched shards) per
        allocate/release, ``restack_full`` only on capacity-class
        changes)."""
        d = self.dyn
        if hasattr(d, "shards"):        # ShardedDynamicIndex
            return dict(
                sharded=True,
                live=int(d.total_live),
                rebalances=int(d.rebalances),
                migrations_incremental=int(d.migrations_incremental),
                migrations_full=int(d.migrations_full),
                restack_full=int(d.restack_full),
                restack_rows=int(d.restack_rows),
                rebuilds=int(sum(s.rebuilds for s in d.shards)),
            )
        return dict(
            sharded=False,
            live=int(d.live_count),
            rebuilds=int(d.rebuilds),
            delta_compactions=int(d.delta_compactions),
            buffered=int(d.total_buffered),
        )
