"""shard_map serving steps.

prefill: full-sequence forward into fresh caches, returns last-token logits.
decode:  one-token step against the caches (the shape cells ``decode_32k``
         and ``long_500k`` lower THIS function, not train_step).

Sharding variants:
  batch-sharded (decode_32k): batch over (pod, data), KV heads over model.
  seq-sharded   (long_500k, global_batch=1): batch replicated, cache time
                axis sharded over data, flash-decoding psum combine.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import model as M
from repro.models.sharding import (TP, batch_axes_for, set_batch_axes,
                                   set_fsdp_gather, set_mesh_axes,
                                   unvary)

F32 = jnp.float32


def unvary_to_specs(tree, specs):
    """Align each output leaf's varying-axes to exactly the axes named in
    its out_spec (numeric identity, see sharding.unvary)."""
    def axes_of(sp):
        out = []
        for e in sp:
            if e is None:
                continue
            out += list(e) if isinstance(e, tuple) else [e]
        return tuple(out)
    return jax.tree.map(
        lambda x, sp: unvary(x, keep=axes_of(sp)), tree, specs,
        is_leaf=lambda x: isinstance(x, jax.Array) or hasattr(x, "shape"))


def _cache_specs(cfg, mesh, *, batch_sharded: bool, seq_shard: bool) -> dict:
    tp = TP if cfg.tp_shard else None
    b_ax = batch_axes_for(mesh) if batch_sharded else None
    seq_ax = "data" if seq_shard else None
    out = {}
    for i in range(cfg.sb):
        kind = cfg.pattern[i]
        if kind == "attn":
            # heads dim is TP-sharded both for kv_sharded archs (padded kv
            # heads) and kv-replicated ones (tp one-head slots)
            kv_tp = tp if (cfg.kv_sharded or cfg.tp_shard) else None
            kv = P(None, b_ax, seq_ax, kv_tp, None)
            out[f"pos{i}"] = {"k": kv, "v": kv}
        elif kind == "mamba":
            out[f"pos{i}"] = {"conv": P(None, b_ax, None, tp),
                              "h": P(None, b_ax, tp, None)}
        elif kind == "mlstm":
            out[f"pos{i}"] = {"c": P(None, b_ax, None, None, None),
                              "n": P(None, b_ax, None, None),
                              "m": P(None, b_ax, None)}
        elif kind == "slstm":
            z = P(None, b_ax, None, None)
            out[f"pos{i}"] = {k: z for k in ("h", "c", "n", "m")}
    return out


def serve_shapes(cfg, shape, mesh) -> dict:
    """ShapeDtypeStructs for the decode cell (GLOBAL shapes)."""
    B, S = shape.global_batch, shape.seq_len
    n_batch_shards = 1
    for a in batch_axes_for(mesh):
        n_batch_shards *= mesh.shape[a]
    batch_sharded = B >= n_batch_shards
    seq_shard = not batch_sharded
    if cfg.embed_input:
        tok = jax.ShapeDtypeStruct((B, 1, cfg.d_model), jnp.bfloat16)
    else:
        tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((3, B, 1) if cfg.rope == "mrope" else (B, 1),
                               jnp.int32)
    # per-shard cache shapes -> global: multiply sharded dims back up.
    # init_cache builds LOCAL shapes given batch_local; for lowering we want
    # GLOBAL arrays, so pass global batch and the full seq.
    caches = M.init_cache(cfg, B, S, seq_shard=1, shapes_only=True,
                          local=False)
    return {"tokens": tok, "pos": pos, "caches": caches,
            "cache_len": jax.ShapeDtypeStruct((), jnp.int32),
            "batch_sharded": batch_sharded, "seq_shard": seq_shard}


def _strip_fsdp(specs):
    """Serve-replicated weights: drop the data-axis shard from param specs
    (weights fully resident per chip; no per-step gather)."""
    return jax.tree.map(
        lambda sp: P(*(None if e == "data" else e for e in sp)), specs,
        is_leaf=lambda x: isinstance(x, P))


def make_decode_step(cfg, mesh, *, batch_sharded: bool = True,
                     seq_shard: bool = False,
                     replicate_weights: bool = False):
    """Returns (fn, in_specs). fn(params, caches, tokens, pos, cache_len)
    -> (next_token_ids (B,), new_caches). ``replicate_weights`` trades
    params-HBM for eliminating every per-step weight all_gather (small
    archs; EXPERIMENTS.md §Perf)."""
    p_specs = M.param_specs(cfg)
    if replicate_weights:
        p_specs = _strip_fsdp(p_specs)
    c_specs = _cache_specs(cfg, mesh, batch_sharded=batch_sharded,
                           seq_shard=seq_shard)
    b_ax = batch_axes_for(mesh) if batch_sharded else None
    tok_spec = P(b_ax, None, None) if cfg.embed_input else P(b_ax, None)
    pos_spec = P(None, b_ax, None) if cfg.rope == "mrope" else P(b_ax, None)
    mesh_b_axes = batch_axes_for(mesh)

    def step_fn(params, caches, tokens, pos, cache_len):
        set_batch_axes(mesh_b_axes)
        set_mesh_axes(mesh.axis_names)
        set_fsdp_gather(not replicate_weights)
        x, new_caches = M.forward(params, cfg, tokens, pos=pos,
                                  caches=caches, mode="decode",
                                  cache_len=cache_len, seq_sharded=seq_shard)
        logits = M.lm_logits(params, cfg, x, cfg.tp_shard)   # (B,1,V_l)
        logits = logits[:, 0, :]
        if cfg.tp_shard:
            logits = jax.lax.all_gather(logits, TP, axis=1, tiled=True)
        nxt = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1).astype(jnp.int32)
        b_keep = (mesh_b_axes if batch_sharded else ())
        return (unvary(nxt, keep=b_keep),
                unvary_to_specs(new_caches, c_specs))

    in_specs = (p_specs, c_specs, tok_spec, pos_spec, P())
    out_specs = (P(b_ax), c_specs)
    fn = jax.shard_map(step_fn, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=True)
    return jax.jit(fn, donate_argnums=(1,)), in_specs


def make_prefill(cfg, mesh, *, batch_sharded: bool = True):
    """Full-sequence prefill: returns last-position logits + filled caches.
    Lowered by the ``prefill_32k`` cells."""
    p_specs = M.param_specs(cfg)
    c_specs = _cache_specs(cfg, mesh, batch_sharded=batch_sharded,
                           seq_shard=False)
    b_ax = batch_axes_for(mesh) if batch_sharded else None
    tok_spec = P(b_ax, None, None) if cfg.embed_input else P(b_ax, None)
    pos_spec = P(None, b_ax, None) if cfg.rope == "mrope" else P(b_ax, None)
    mesh_b_axes = batch_axes_for(mesh)

    def prefill_fn(params, caches, tokens, pos):
        set_batch_axes(mesh_b_axes)
        set_mesh_axes(mesh.axis_names)
        set_fsdp_gather(True)
        x, new_caches = M.forward(params, cfg, tokens, pos=pos,
                                  caches=caches, mode="prefill")
        last = x[:, -1:, :]
        logits = M.lm_logits(params, cfg, last, cfg.tp_shard)[:, 0, :]
        b_keep = (mesh_b_axes if batch_sharded else ()) + ((TP,) if cfg.tp_shard else ())
        return (unvary(logits, keep=b_keep),
                unvary_to_specs(new_caches, c_specs))

    in_specs = (p_specs, c_specs, tok_spec, pos_spec)
    out_specs = (P(b_ax, TP if cfg.tp_shard else None), c_specs)
    fn = jax.shard_map(prefill_fn, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=True)
    return jax.jit(fn, donate_argnums=(1,)), in_specs
