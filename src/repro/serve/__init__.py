"""Serving runtime.

Two serving surfaces live here:

* ``serve.frontend`` — the async batched index front-end: request queue ->
  adaptive batcher -> one stacked multi-tenant ``shard_map`` dispatch ->
  response scatter.  The batcher coalesces requests up to a configurable
  latency budget (measured from the oldest queued request, with an early
  cut at the batch-size cap) and pads the live batch to the pow2
  ``kernels.lookup.capacity_class`` widths, so after warmup the jitted
  dispatch sees only pow2 query shapes and the hot path never retraces —
  batch-size variation changes pad *contents*, not shapes.  Dispatches are
  double-buffered (up to ``ServeConfig.pipeline_depth`` batches in flight:
  batch k+1 stages and dispatches while batch k computes), and
  insert/delete requests interleave with finds in the same batches, riding
  the dirty-row slice cache so mutations cost O(touched shards + touched
  tenants).
* ``serve.step`` — LM prefill + decode steps, paged KV cache with the
  learned page-table option.
"""
