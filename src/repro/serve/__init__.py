"""Serving runtime: prefill + decode steps, paged KV cache with learned
page-table option."""
