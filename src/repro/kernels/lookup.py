"""Pallas TPU kernel: fused learned-index lookup — the serving hot path.

One kernel per query tile fuses the three stages the paper executes per
query (leaf-model predict -> error-bound window -> bounded binary search):

  1. tiny-MLP / linear predict (T-wide vectorized, 4-neuron MXU-free math),
  2. window clamp from the leaf's error bounds,
  3. branchless fixed-iteration binary search against the key array resident
     in VMEM (dynamic vectorized gather within VMEM).

Memory layout: the per-device key shard is a single VMEM block (f32; up to
~3M keys in 12 MiB of a 16 MiB v5e VMEM). Indexes larger than one shard are
split by the distributed layer (core.distributed) across chips, which is the
production topology anyway. Leaf-model params arrive pre-gathered per query
(an XLA gather feeding the kernel), so the kernel itself is gather-free on
its parameter side.

Semantics match core.rmi.bounded_search: left boundary, clamped window; the
seam-fallback verification stays in the ops wrapper (XLA), keeping the
kernel single-pass.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TQ = 1024      # queries per grid step
H = 4          # paper's hidden width


def _lookup_kernel(q_ref, w1_ref, b1_ref, w2_ref, b2_ref, elo_ref, ehi_ref,
                   keys_ref, out_ref, *, n_keys: int, iters: int,
                   linear: bool):
    q = q_ref[...].reshape(TQ)
    elo = elo_ref[...].reshape(TQ)
    ehi = ehi_ref[...].reshape(TQ)

    if linear:
        a = w1_ref[...].reshape(TQ, H)[:, 0]
        c = b2_ref[...].reshape(TQ)
        pred = a * q + c
    else:
        w1 = w1_ref[...].reshape(TQ, H)
        b1 = b1_ref[...].reshape(TQ, H)
        w2 = w2_ref[...].reshape(TQ, H)
        c = b2_ref[...].reshape(TQ)
        h = jnp.maximum(q[:, None] * w1 + b1, 0.0)
        pred = jnp.sum(h * w2, axis=1) + c

    lo = jnp.clip(jnp.floor(pred + elo), 0, n_keys - 1).astype(jnp.int32)
    hi = jnp.clip(jnp.ceil(pred + ehi) + 1.0, 1, n_keys).astype(jnp.int32)

    keys = keys_ref[...].reshape(-1)            # full VMEM-resident shard

    def body(_, lh):
        lo, hi = lh
        active = hi - lo > 0
        mid = (lo + hi) // 2
        kv = jnp.take(keys, jnp.clip(mid, 0, n_keys - 1))
        below = kv < q
        nlo = jnp.where(below, mid + 1, lo)
        nhi = jnp.where(below, hi, mid)
        return (jnp.where(active, nlo, lo), jnp.where(active, nhi, hi))

    lo, _ = jax.lax.fori_loop(0, iters, body, (lo, hi))
    out_ref[...] = lo.reshape(out_ref.shape)


def lookup_pallas(queries, w1, b1, w2, b2, err_lo, err_hi, keys, *,
                  linear: bool = False, interpret: bool = True):
    """Positions (left boundary) of ``queries`` in ``keys``.

    queries/err_lo/err_hi: (Q,) f32, per-query (pre-gathered leaf bounds);
    w1/b1/w2: (Q, H) f32 (ignored-except-w1 row 0 when linear); b2: (Q,) f32;
    keys: (S,) f32 sorted.
    """
    Q = queries.shape[0]
    S = keys.shape[0]
    q_pad = -(-Q // TQ) * TQ
    s_pad = -(-S // 128) * 128
    iters = math.ceil(math.log2(max(S, 2))) + 1

    pad1 = lambda a: jnp.pad(a.astype(jnp.float32), (0, q_pad - Q)) \
        .reshape(-1, 8, TQ // 8)
    pad2 = lambda a: jnp.pad(a.astype(jnp.float32),
                             ((0, q_pad - Q), (0, 0))).reshape(-1, TQ, H)
    kp = jnp.pad(keys.astype(jnp.float32), (0, s_pad - S),
                 constant_values=jnp.inf).reshape(1, 8, s_pad // 8)

    kern = functools.partial(_lookup_kernel, n_keys=S, iters=iters,
                             linear=linear)
    out = pl.pallas_call(
        kern,
        grid=(q_pad // TQ,),
        in_specs=[
            pl.BlockSpec((1, 8, TQ // 8), lambda i: (i, 0, 0)),   # q
            pl.BlockSpec((1, TQ, H), lambda i: (i, 0, 0)),        # w1
            pl.BlockSpec((1, TQ, H), lambda i: (i, 0, 0)),        # b1
            pl.BlockSpec((1, TQ, H), lambda i: (i, 0, 0)),        # w2
            pl.BlockSpec((1, 8, TQ // 8), lambda i: (i, 0, 0)),   # b2
            pl.BlockSpec((1, 8, TQ // 8), lambda i: (i, 0, 0)),   # elo
            pl.BlockSpec((1, 8, TQ // 8), lambda i: (i, 0, 0)),   # ehi
            pl.BlockSpec((1, 8, s_pad // 8), lambda i: (0, 0, 0)),  # keys
        ],
        out_specs=pl.BlockSpec((1, 8, TQ // 8), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((q_pad // TQ, 8, TQ // 8), jnp.int32),
        interpret=interpret,
    )(pad1(queries), pad2(w1), pad2(b1), pad2(w2), pad1(b2), pad1(err_lo),
      pad1(err_hi), kp)
    return out.reshape(-1)[:Q]
