"""Pallas TPU kernel: fused learned-index lookup — the serving hot path.

One kernel fuses all four stages the paper executes per query
(root routing -> leaf predict -> error-bound window -> bounded search):

  1. in-kernel root routing: the root model (linear or tiny MLP) runs on the
     query tile and buckets each query into its leaf,
  2. gather-free leaf-param fetch: the compact per-leaf tables — (H, L) model
     params and (L,) bounds, a few hundred KB even at 16k leaves — stay
     resident in VMEM and are indexed per query *inside* the kernel. The old
     path materialized (Q, H)x3 pre-gathered parameter arrays in XLA before
     the kernel; at serving batch sizes Q >> L that gather traffic dominated,
  3. window clamp from the leaf's error bounds,
  4. branchless binary search with a *static iteration count derived from the
     index's error window* (paper §4: the reuse bound caps the search range),
     not from log2(n_keys) — 3-6x fewer iterations for tight-epsilon indexes.

Memory layout: queries are tiled TQ at a time (grid dim 0) and the key shard
is BlockSpec-tiled TILE keys at a time (grid dim 1, innermost), so the VMEM
working set is TQ + TILE + tables regardless of shard size — shards beyond
the old ~3M-key single-block cap are servable. Each (i, j) grid step searches
query tile i's windows restricted to key tile j and min-merges the candidate
into the revisited output block; left-boundary results compose across tiles
because positions increase with j. Queries whose window misses tile j
contribute nothing.

Leaf tables are packed lane-major — (3H, Lp) params, (8, Lp) scalars, leaves
on the 128-lane axis — so per-query fetch is a VMEM dynamic gather along
lanes, the same primitive as the key probe.

RMRT node-table packing (``pack_rmrt``): the flat level-synchronous node
arrays of ``core.rmrt.RMRTIndex`` pack into the same lane-major layout,
nodes on the 128-lane axis padded to Np = 128-multiple:

  mat (3H, Np) f32   rows [0, H)   w1 (linear models ride in w1[:, 0])
                     rows [H, 2H)  b1
                     rows [2H, 3H) w2
  vec (8, Np)  f32   row 0 b2 / b          row 4 y_end
                     row 1 err_lo          row 5 child_base (int, f32-exact:
                     row 2 err_hi                 node count << 2^24)
                     row 3 y_start         row 6 is_leaf (0.0 / 1.0)

so the fixed-depth masked descent (``_rmrt_route_window``) is a per-level
VMEM lane gather + predict + re-bucket, entirely in-kernel — no XLA
pre-routing pass.  Internal nodes carry err rows of 0 and leaves carry
child_base -1; neither is ever consumed on the other branch of the
``is_leaf`` select.  Padded lanes are unreachable (descent starts at node 0
and child ids stay < num_nodes).

Semantics match core.rmi.bounded_search on the same window/iters; the seam
verification (sparse re-check of the rare misses) stays in the ops wrapper,
keeping the kernel single-pass.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TQ = 1024          # queries per grid step
TILE_MAX = 1 << 18  # keys per VMEM tile (1 MiB f32)
H = 4              # paper's hidden width
ROOT_ROWS = 8      # packed root block: rows [w1, b1, w2, b2|meta] x 128 lanes


def search_iters(err_lo, err_hi, n_keys: int) -> int:
    """Static binary-search depth for an index with the given leaf bounds.

    The paper's §4 bound: a lookup only ever searches a window of
    ceil(err_hi) - floor(err_lo) positions (+3 for the clamp/rounding slack),
    so the branchless search needs ceil(log2(max window)) + 1 iterations, not
    ceil(log2(n_keys)) + 1. Sentinel windows (empty leaves carry a sound
    full-array window) are excluded — queries routed there are caught by the
    seam verification and re-searched at full depth.
    """
    from ..core.bounds import clamped_depth, window_widths
    return clamped_depth(window_widths(err_lo, err_hi), n_keys)


def full_iters(n_keys: int) -> int:
    """Unclamped depth: the classic ceil(log2(n)) + 1."""
    return int(math.ceil(math.log2(max(n_keys, 2)))) + 1


def pack_root(root_kind: str, params, route_scale: float = 1.0) -> jax.Array:
    """(ROOT_ROWS, 128) f32 block holding the root model.

    linear: [0,0]=a, [3,0]=b.   mlp: rows 0/1/2 = w1/b1/w2 (H lanes), [3,0]=b2.

    ``route_scale`` folds a routing rescale into the packed model (the
    *output* layer for the MLP), so callers whose frozen routing scale
    differs per table — the sharded dynamic path stacks shards with
    different ``route_n`` under one statically-traced kernel — can pack
    scale = kernel_route_n / shard_route_n and trace a single kernel with
    ``route_n = kernel_route_n``.  The same fold generalizes per *tenant*
    (serve.frontend): a tenant built with ``L_t`` leaves packs
    scale = L_t / tenant_route_n, its leaf tables re-pad to the widest
    tenant's lane count (:func:`pad_packed_leaves`), and one kernel traced
    with static ``n_leaves = route_n = max_t L_t`` serves every tenant —
    routing overshoot past ``L_t - 1`` lands on a replicated last leaf,
    i.e. the same window the tenant's own clip would have produced.
    Routing runs in f32 either way and every final position is
    seam-verified, so the fold never changes results.
    """
    s = jnp.float64(route_scale)
    blk = jnp.zeros((ROOT_ROWS, 128), jnp.float32)
    if root_kind == "linear":
        blk = blk.at[0, 0].set((params.a * s).astype(jnp.float32))
        blk = blk.at[3, 0].set((params.b * s).astype(jnp.float32))
    else:
        blk = blk.at[0, :H].set(params.w1.astype(jnp.float32))
        blk = blk.at[1, :H].set(params.b1.astype(jnp.float32))
        blk = blk.at[2, :H].set((params.w2 * s).astype(jnp.float32))
        blk = blk.at[3, 0].set((params.b2 * s).astype(jnp.float32))
    return blk


def pack_leaves(w1, b1, w2, b2, err_lo, err_hi):
    """Lane-major leaf tables: (3H, Lp) params + (8, Lp) scalars, Lp = 128-pad.

    w1/b1/w2: (L, H); b2/err_lo/err_hi: (L,). Padded lanes are never gathered
    (buckets are clipped to L-1).
    """
    L = w1.shape[0]
    lp = -(-L // 128) * 128
    padT = lambda a: jnp.pad(a.astype(jnp.float32).T, ((0, 0), (0, lp - L)))
    mat = jnp.concatenate([padT(w1), padT(b1), padT(w2)], axis=0)  # (3H, Lp)
    vec = jnp.zeros((8, lp), jnp.float32)
    for row, a in ((0, b2), (1, err_lo), (2, err_hi)):
        vec = vec.at[row, :L].set(a.astype(jnp.float32))
    return mat, vec


def pad_packed_leaves(mat, vec, n_live: int, lp_to: int):
    """Re-pad packed lane-major leaf tables (``pack_leaves`` layout, lane
    count on the last axis) to a wider lane count, replicating the last
    *live* leaf into every lane past ``n_live - 1``.

    This is the per-tenant half of the ``route_scale`` fold: a tenant with
    ``L_t = n_live`` leaves stacked under a kernel traced with a wider
    static ``n_leaves`` can see routing buckets in ``[L_t, n_leaves - 1]``
    (its packed scale maps predictions past the end there, where its own
    trace would have clipped to ``L_t - 1``).  Replicated lanes carry the
    last leaf's params *and* error bounds, so an overshot bucket yields the
    exact window the tenant's own clip produces — downstream search and
    seam verification then match bit-for-bit.  Leading axes (e.g. a shard
    stack) broadcast through.
    """
    lane = jnp.minimum(jnp.arange(lp_to), max(n_live - 1, 0))
    return mat[..., lane], vec[..., lane]


def _route_window(root, mat, vec, q, *, n_keys: int, n_leaves: int, lp: int,
                  route_n: int, root_kind: str, leaf_kind: str):
    """Stages 1-3 on a query tile (pure jnp on values — shared by the static
    and dynamic kernel bodies): in-kernel root routing (scaled by
    ``route_n``, the build-time key count the routing is frozen at),
    gather-free leaf fetch from the VMEM-resident tables, error-bound
    window clamped to the *current* key count ``n_keys``."""
    # ---- stage 1: in-kernel root routing --------------------------------
    if root_kind == "linear":
        rpred = root[0, 0] * q + root[3, 0]
    else:
        h = jnp.maximum(q[:, None] * root[0, :H] + root[1, :H], 0.0)
        rpred = jnp.sum(h * root[2, :H], axis=1) + root[3, 0]
    b = jnp.clip((rpred * (n_leaves / route_n)).astype(jnp.int32),
                 0, n_leaves - 1)

    # ---- stage 2: gather-free leaf fetch (VMEM-resident tables) ---------
    row = lambda flat, r: jnp.take(flat, b + r * lp)       # (TQ,) per row
    if leaf_kind == "linear":
        pred = row(mat, 0) * q + row(vec, 0)
    else:
        pred = row(vec, 0)
        for k in range(H):
            hk = jnp.maximum(q * row(mat, k) + row(mat, H + k), 0.0)
            pred = pred + hk * row(mat, 2 * H + k)

    # ---- stage 3: error-bound window ------------------------------------
    lo = jnp.clip(jnp.floor(pred + row(vec, 1)), 0, n_keys - 1
                  ).astype(jnp.int32)
    hi = jnp.clip(jnp.ceil(pred + row(vec, 2)) + 1.0, 1, n_keys
                  ).astype(jnp.int32)
    return lo, hi


def _tile_search_merge(keys_ref, q, lo_ref, hi_ref, out_ref, j, *,
                       n_keys: int, tile: int, tile_iters: int,
                       right: bool = False):
    """Stage 4, shared by every lookup kernel: window-clamped branchless
    search of query tile ``q`` restricted to key tile ``j``, min-merged into
    the revisited output block (left boundaries compose across tiles because
    positions increase with j).

    ``right=True`` searches the *right* boundary (first position with
    key > q — the rightmost-rank side of a range endpoint).  The min-merge
    composes identically: the first tile containing a key > q yields the
    winning candidate, tiles whose clipped window is entirely <= q converge
    to l == thi (invalid candidate), and +inf capacity padding compares > q
    for every finite query, so pads never shift a right boundary either."""
    lo = lo_ref[...].reshape(TQ)
    hi = hi_ref[...].reshape(TQ)
    base = j * tile
    tlo = jnp.clip(lo - base, 0, tile)
    thi = jnp.clip(hi - base, 0, tile)
    keys = keys_ref[...].reshape(tile)

    def body(_, lh):
        l, h2 = lh
        active = h2 - l > 0
        mid = (l + h2) // 2
        kv = jnp.take(keys, jnp.clip(mid, 0, tile - 1))
        below = kv <= q if right else kv < q
        nl = jnp.where(below, mid + 1, l)
        nh = jnp.where(below, h2, mid)
        return (jnp.where(active, nl, l), jnp.where(active, nh, h2))

    l, _ = jax.lax.fori_loop(0, tile_iters, body, (tlo, thi))
    cand = jnp.where(l < thi, base + l, n_keys)

    cur = out_ref[...].reshape(TQ)
    out_ref[...] = jnp.minimum(cur, cand).reshape(out_ref.shape)


def _lookup_kernel(root_ref, mat_ref, vec_ref, q_ref, keys_ref, out_ref,
                   lo_ref, hi_ref, *,
                   n_keys: int, n_leaves: int, lp: int, tile: int,
                   tile_iters: int, root_kind: str, leaf_kind: str):
    j = pl.program_id(1)
    q = q_ref[...].reshape(TQ)

    # Stages 1-3 depend only on the query tile: run them once per query tile
    # (j == 0) and stash the window in VMEM scratch for the key-tile sweep.
    @pl.when(j == 0)
    def _():
        lo, hi = _route_window(
            root_ref[...].reshape(ROOT_ROWS, 128),
            mat_ref[...].reshape(3 * H * lp), vec_ref[...].reshape(8 * lp),
            q, n_keys=n_keys, n_leaves=n_leaves, lp=lp, route_n=n_keys,
            root_kind=root_kind, leaf_kind=leaf_kind)
        lo_ref[...] = lo.reshape(lo_ref.shape)
        hi_ref[...] = hi.reshape(hi_ref.shape)
        out_ref[...] = hi.reshape(out_ref.shape)

    _tile_search_merge(keys_ref, q, lo_ref, hi_ref, out_ref, j,
                       n_keys=n_keys, tile=tile, tile_iters=tile_iters)


def _pow2ceil(v: int) -> int:
    return 1 << max(int(v) - 1, 1).bit_length()


def capacity_class(n: int, floor: int = 128) -> int:
    """Pow2 capacity bucket shared by tier storage and the sharded slice
    cache: a tier of ``n`` finite entries is stored +inf-padded to this
    capacity, so array shapes — and with them every jit specialization,
    packed-table layout, and stacked per-shard slice — change only when the
    entry count crosses a power of two.  The 128 floor is one kernel lane
    tile."""
    return max(_pow2ceil(max(int(n), 1)), floor)


def pad_capacity(keys: jax.Array, cap: int) -> jax.Array:
    """+inf-pad a sorted tier (or tier slice) to its capacity class — pads
    sort past every live key, route to the dump bucket, and never win a
    left-boundary search."""
    return jnp.pad(keys, (0, cap - keys.shape[0]), constant_values=jnp.inf)


def lookup_pallas(queries, root, mat, vec, keys, *, n_leaves: int,
                  root_kind: str = "linear", leaf_kind: str = "linear",
                  iters: int | None = None, tile: int | None = None,
                  interpret: bool = True):
    """Positions (left boundary, window-clamped) of ``queries`` in ``keys``.

    queries: (Q,); root: pack_root block; mat/vec: pack_leaves tables;
    keys: (S,) sorted. ``iters`` is the static window search depth
    (see search_iters); ``tile`` the key-tile size (multiple of 128).
    """
    Q = queries.shape[0]
    S = keys.shape[0]
    lp = mat.shape[1]
    q_pad = -(-Q // TQ) * TQ
    if tile is None:
        tile = min(TILE_MAX, _pow2ceil(max(S, 128)))
    assert tile % 128 == 0, "key tile must be a multiple of 128 lanes"
    s_pad = -(-S // tile) * tile
    nk = s_pad // tile
    if iters is None:
        iters = full_iters(S)
    tile_iters = min(iters, full_iters(tile))

    pad1 = lambda a: jnp.pad(a.astype(jnp.float32), (0, q_pad - Q)) \
        .reshape(-1, 8, TQ // 8)
    kp = jnp.pad(keys.astype(jnp.float32), (0, s_pad - S),
                 constant_values=jnp.inf).reshape(nk, 8, tile // 8)

    kern = functools.partial(
        _lookup_kernel, n_keys=S, n_leaves=n_leaves, lp=lp, tile=tile,
        tile_iters=tile_iters, root_kind=root_kind, leaf_kind=leaf_kind)
    out = pl.pallas_call(
        kern,
        grid=(q_pad // TQ, nk),
        in_specs=[
            pl.BlockSpec((ROOT_ROWS, 128), lambda i, j: (0, 0)),      # root
            pl.BlockSpec((3 * H, lp), lambda i, j: (0, 0)),           # mat
            pl.BlockSpec((8, lp), lambda i, j: (0, 0)),               # vec
            pl.BlockSpec((1, 8, TQ // 8), lambda i, j: (i, 0, 0)),    # q
            pl.BlockSpec((1, 8, tile // 8), lambda i, j: (j, 0, 0)),  # keys
        ],
        out_specs=pl.BlockSpec((1, 8, TQ // 8), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((q_pad // TQ, 8, TQ // 8), jnp.int32),
        scratch_shapes=[pltpu.VMEM((8, TQ // 8), jnp.int32),   # lo window
                        pltpu.VMEM((8, TQ // 8), jnp.int32)],  # hi window
        interpret=interpret,
    )(root, mat, vec, pad1(queries), kp)
    return out.reshape(-1)[:Q]


# ---------------------------------------------------------------------------
# Two-tier (base + delta) dynamic lookup: the update subsystem's serving
# kernel.  One kernel fuses the static kernel's four stages over the base
# tier with a full-depth probe of the sorted delta tier (the device-resident
# insert buffer, VMEM-sized by the Lemma 4.1 rebuild policy), so a find
# under churn is a single kernel call.  The tombstone mask and two-tier rank
# arithmetic are O(Q) gathers in the jitted ops wrapper
# (``ops.dynamic_index_lookup``) — the kernel owns everything logarithmic.
# ---------------------------------------------------------------------------
def _full_probe(dk, q, *, nd: int, d_iters: int, right: bool = False):
    """Full-depth branchless search of the VMEM-resident delta tier (pure
    jnp on values — shared by the dynamic point and range kernel bodies).
    The tier is sorted ascending and +inf padded, so both boundaries of a
    finite query always land within the live prefix (``kv <= q`` is False
    at every +inf pad)."""
    dl = jnp.zeros((TQ,), jnp.int32)
    dh = jnp.full((TQ,), nd, jnp.int32)

    def dbody(_, lh):
        l, h2 = lh
        active = h2 - l > 0
        mid = (l + h2) // 2
        kv = jnp.take(dk, jnp.clip(mid, 0, nd - 1))
        below = kv <= q if right else kv < q
        nl = jnp.where(below, mid + 1, l)
        nh = jnp.where(below, h2, mid)
        return (jnp.where(active, nl, l), jnp.where(active, nh, h2))

    dl, _ = jax.lax.fori_loop(0, d_iters, dbody, (dl, dh))
    return dl


def _dynamic_lookup_kernel(root_ref, mat_ref, vec_ref, q_ref, dkeys_ref,
                           keys_ref, out_ref, dout_ref, lo_ref, hi_ref, *,
                           n_keys: int, n_leaves: int, lp: int, tile: int,
                           tile_iters: int, nd: int, d_iters: int,
                           route_n: int, root_kind: str, leaf_kind: str):
    j = pl.program_id(1)
    q = q_ref[...].reshape(TQ)

    @pl.when(j == 0)
    def _():
        lo, hi = _route_window(
            root_ref[...].reshape(ROOT_ROWS, 128),
            mat_ref[...].reshape(3 * H * lp), vec_ref[...].reshape(8 * lp),
            q, n_keys=n_keys, n_leaves=n_leaves, lp=lp, route_n=route_n,
            root_kind=root_kind, leaf_kind=leaf_kind)
        lo_ref[...] = lo.reshape(lo_ref.shape)
        hi_ref[...] = hi.reshape(hi_ref.shape)
        out_ref[...] = hi.reshape(out_ref.shape)

        # ---- delta probe: full-depth search of the VMEM-resident tier ---
        dl = _full_probe(dkeys_ref[...].reshape(nd), q, nd=nd,
                         d_iters=d_iters)
        dout_ref[...] = dl.reshape(dout_ref.shape)

    # ---- base tier: window-clamped search within key tile j -------------
    _tile_search_merge(keys_ref, q, lo_ref, hi_ref, out_ref, j,
                       n_keys=n_keys, tile=tile, tile_iters=tile_iters)


def pad_delta(delta_keys, dtype=jnp.float32):
    """+inf-pad the delta tier to a 128-lane multiple (floor 128)."""
    nd = delta_keys.shape[0]
    ndp = max(-(-max(nd, 1) // 128) * 128, 128)
    return jnp.pad(delta_keys.astype(dtype), (0, ndp - nd),
                   constant_values=jnp.inf)


def dynamic_lookup_pallas(queries, root, mat, vec, keys, delta_keys, *,
                          n_leaves: int, route_n: int | None = None,
                          root_kind: str = "linear",
                          leaf_kind: str = "linear",
                          iters: int | None = None, tile: int | None = None,
                          interpret: bool = True):
    """(base_pos, delta_pos) of ``queries`` against the two tiers.

    base_pos is the window-clamped left boundary in ``keys`` (identical
    semantics to :func:`lookup_pallas`); delta_pos is the full-depth left
    boundary in the sorted, +inf-padded ``delta_keys``.  ``route_n`` is the
    frozen routing scale of the dynamic index (defaults to the current key
    count, i.e. static-index behaviour).
    """
    Q = queries.shape[0]
    S = keys.shape[0]
    lp = mat.shape[1]
    q_pad = -(-Q // TQ) * TQ
    if route_n is None:
        route_n = S
    if tile is None:
        tile = min(TILE_MAX, _pow2ceil(max(S, 128)))
    assert tile % 128 == 0, "key tile must be a multiple of 128 lanes"
    s_pad = -(-S // tile) * tile
    nk = s_pad // tile
    if iters is None:
        iters = full_iters(S)
    tile_iters = min(iters, full_iters(tile))

    dkp = pad_delta(delta_keys)
    nd = dkp.shape[0]
    d_iters = full_iters(nd)

    pad1 = lambda a: jnp.pad(a.astype(jnp.float32), (0, q_pad - Q)) \
        .reshape(-1, 8, TQ // 8)
    kp = jnp.pad(keys.astype(jnp.float32), (0, s_pad - S),
                 constant_values=jnp.inf).reshape(nk, 8, tile // 8)

    kern = functools.partial(
        _dynamic_lookup_kernel, n_keys=S, n_leaves=n_leaves, lp=lp, tile=tile,
        tile_iters=tile_iters, nd=nd, d_iters=d_iters, route_n=route_n,
        root_kind=root_kind, leaf_kind=leaf_kind)
    out, dout = pl.pallas_call(
        kern,
        grid=(q_pad // TQ, nk),
        in_specs=[
            pl.BlockSpec((ROOT_ROWS, 128), lambda i, j: (0, 0)),      # root
            pl.BlockSpec((3 * H, lp), lambda i, j: (0, 0)),           # mat
            pl.BlockSpec((8, lp), lambda i, j: (0, 0)),               # vec
            pl.BlockSpec((1, 8, TQ // 8), lambda i, j: (i, 0, 0)),    # q
            pl.BlockSpec((1, 8, nd // 8), lambda i, j: (0, 0, 0)),    # delta
            pl.BlockSpec((1, 8, tile // 8), lambda i, j: (j, 0, 0)),  # keys
        ],
        out_specs=[
            pl.BlockSpec((1, 8, TQ // 8), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, 8, TQ // 8), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q_pad // TQ, 8, TQ // 8), jnp.int32),
            jax.ShapeDtypeStruct((q_pad // TQ, 8, TQ // 8), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((8, TQ // 8), jnp.int32),   # lo window
                        pltpu.VMEM((8, TQ // 8), jnp.int32)],  # hi window
        interpret=interpret,
    )(root, mat, vec, pad1(queries), dkp.reshape(1, 8, nd // 8), kp)
    return out.reshape(-1)[:Q], dout.reshape(-1)[:Q]


# ---------------------------------------------------------------------------
# Fused range kernel: both endpoints of [lo, hi] routed in ONE tile pass.
# The lo endpoint uses the point path's left-bound search; the hi endpoint
# runs the mirrored right-bound search (first position with key > q, i.e.
# the rightmost rank under duplicate keys — see _tile_search_merge's
# ``right`` flag for why the min-merge composes identically).  Each key tile
# is streamed through VMEM once and searched twice, so a range lookup costs
# one kernel invocation and the same HBM traffic as a single point lookup.
# Both candidates are window-clamped and seam-verified by the ops wrapper
# (``ops.range_lookup``) exactly like the point path.
# ---------------------------------------------------------------------------
def _dynamic_range_kernel(root_ref, mat_ref, vec_ref, qlo_ref, qhi_ref,
                          dkeys_ref, keys_ref,
                          blo_ref, bhi_ref, dlo_ref, dhi_ref,
                          llo_ref, lhi_ref, rlo_ref, rhi_ref, *,
                          n_keys: int, n_leaves: int, lp: int, tile: int,
                          tile_iters: int, nd: int, d_iters: int,
                          route_n: int, root_kind: str, leaf_kind: str):
    j = pl.program_id(1)
    ql = qlo_ref[...].reshape(TQ)
    qh = qhi_ref[...].reshape(TQ)

    @pl.when(j == 0)
    def _():
        root = root_ref[...].reshape(ROOT_ROWS, 128)
        mat = mat_ref[...].reshape(3 * H * lp)
        vec = vec_ref[...].reshape(8 * lp)
        lo, hi = _route_window(
            root, mat, vec, ql, n_keys=n_keys, n_leaves=n_leaves, lp=lp,
            route_n=route_n, root_kind=root_kind, leaf_kind=leaf_kind)
        llo_ref[...] = lo.reshape(llo_ref.shape)
        lhi_ref[...] = hi.reshape(lhi_ref.shape)
        blo_ref[...] = hi.reshape(blo_ref.shape)
        lo, hi = _route_window(
            root, mat, vec, qh, n_keys=n_keys, n_leaves=n_leaves, lp=lp,
            route_n=route_n, root_kind=root_kind, leaf_kind=leaf_kind)
        rlo_ref[...] = lo.reshape(rlo_ref.shape)
        rhi_ref[...] = hi.reshape(rhi_ref.shape)
        bhi_ref[...] = hi.reshape(bhi_ref.shape)

        # ---- delta probes: left bound of lo, right bound of hi ----------
        dk = dkeys_ref[...].reshape(nd)
        dlo_ref[...] = _full_probe(dk, ql, nd=nd, d_iters=d_iters) \
            .reshape(dlo_ref.shape)
        dhi_ref[...] = _full_probe(dk, qh, nd=nd, d_iters=d_iters,
                                   right=True).reshape(dhi_ref.shape)

    # ---- base tier: both endpoints searched within key tile j -----------
    _tile_search_merge(keys_ref, ql, llo_ref, lhi_ref, blo_ref, j,
                       n_keys=n_keys, tile=tile, tile_iters=tile_iters)
    _tile_search_merge(keys_ref, qh, rlo_ref, rhi_ref, bhi_ref, j,
                       n_keys=n_keys, tile=tile, tile_iters=tile_iters,
                       right=True)


def dynamic_range_pallas(q_lo, q_hi, root, mat, vec, keys, delta_keys, *,
                         n_leaves: int, route_n: int | None = None,
                         root_kind: str = "linear",
                         leaf_kind: str = "linear",
                         iters: int | None = None, tile: int | None = None,
                         interpret: bool = True):
    """(base_lo, base_hi, delta_lo, delta_hi) of range endpoint pairs.

    base_lo/delta_lo are the left boundaries of ``q_lo`` (leftmost rank
    under duplicates — identical semantics to the point path); base_hi/
    delta_hi are the *right* boundaries of ``q_hi`` (first position whose
    key compares > q_hi, i.e. rightmost rank).  Both endpoints ride the
    same grid pass, so each key tile is fetched from HBM exactly once.
    """
    Q = q_lo.shape[0]
    assert q_hi.shape[0] == Q, "endpoint arrays must pair up"
    S = keys.shape[0]
    lp = mat.shape[1]
    q_pad = -(-Q // TQ) * TQ
    if route_n is None:
        route_n = S
    if tile is None:
        tile = min(TILE_MAX, _pow2ceil(max(S, 128)))
    assert tile % 128 == 0, "key tile must be a multiple of 128 lanes"
    s_pad = -(-S // tile) * tile
    nk = s_pad // tile
    if iters is None:
        iters = full_iters(S)
    tile_iters = min(iters, full_iters(tile))

    dkp = pad_delta(delta_keys)
    nd = dkp.shape[0]
    d_iters = full_iters(nd)

    pad1 = lambda a: jnp.pad(a.astype(jnp.float32), (0, q_pad - Q)) \
        .reshape(-1, 8, TQ // 8)
    kp = jnp.pad(keys.astype(jnp.float32), (0, s_pad - S),
                 constant_values=jnp.inf).reshape(nk, 8, tile // 8)

    kern = functools.partial(
        _dynamic_range_kernel, n_keys=S, n_leaves=n_leaves, lp=lp, tile=tile,
        tile_iters=tile_iters, nd=nd, d_iters=d_iters, route_n=route_n,
        root_kind=root_kind, leaf_kind=leaf_kind)
    qspec = pl.BlockSpec((1, 8, TQ // 8), lambda i, j: (i, 0, 0))
    blo, bhi, dlo, dhi = pl.pallas_call(
        kern,
        grid=(q_pad // TQ, nk),
        in_specs=[
            pl.BlockSpec((ROOT_ROWS, 128), lambda i, j: (0, 0)),      # root
            pl.BlockSpec((3 * H, lp), lambda i, j: (0, 0)),           # mat
            pl.BlockSpec((8, lp), lambda i, j: (0, 0)),               # vec
            qspec,                                                    # q_lo
            qspec,                                                    # q_hi
            pl.BlockSpec((1, 8, nd // 8), lambda i, j: (0, 0, 0)),    # delta
            pl.BlockSpec((1, 8, tile // 8), lambda i, j: (j, 0, 0)),  # keys
        ],
        out_specs=[qspec, qspec, qspec, qspec],
        out_shape=[
            jax.ShapeDtypeStruct((q_pad // TQ, 8, TQ // 8), jnp.int32)
            for _ in range(4)
        ],
        scratch_shapes=[pltpu.VMEM((8, TQ // 8), jnp.int32)   # lo window x2,
                        for _ in range(4)],                   # hi window x2
        interpret=interpret,
    )(root, mat, vec, pad1(q_lo), pad1(q_hi), dkp.reshape(1, 8, nd // 8), kp)
    flat = lambda a: a.reshape(-1)[:Q]
    return flat(blo), flat(bhi), flat(dlo), flat(dhi)


# ---------------------------------------------------------------------------
# RMRT: in-kernel fixed-depth masked descent over the flat node tables (see
# the module docstring for the pack_rmrt layout), then the same clamped
# tiled search as the static kernel.  Replaces the XLA masked-descent loop
# that used to pre-route queries before the kernel.
# ---------------------------------------------------------------------------
def pack_rmrt(kind: str, params, is_leaf, child_base, y_start, y_end,
              err_lo, err_hi):
    """Lane-major RMRT node tables: (3H, Np) params + (8, Np) scalars.

    ``params`` are the stacked per-node models (LinearParams or MLPParams,
    leading dim = num_nodes); linear models ride in w1[:, 0] / b2 exactly
    like the RMI leaf tables.  Row layout documented in the module
    docstring.  ``child_base`` must stay f32-exact (node count << 2^24).
    """
    N = int(is_leaf.shape[0])
    if N >= 1 << 24:        # raise (not assert): must survive python -O
        raise ValueError(
            f"RMRT node count {N} exceeds f32 integer resolution (2^24): "
            "child_base pointers in the packed f32 tables would be rounded "
            "silently — raise leaf_cap or shard the tree")
    if kind == "linear":
        w1 = jnp.zeros((N, H), jnp.float32).at[:, 0].set(
            params.a.astype(jnp.float32))
        zeros = jnp.zeros((N, H), jnp.float32)
        b1, w2, b2 = zeros, zeros, params.b
    else:
        w1, b1, w2, b2 = params.w1, params.b1, params.w2, params.b2
    npad = -(-N // 128) * 128
    padT = lambda a: jnp.pad(a.astype(jnp.float32).T, ((0, 0), (0, npad - N)))
    mat = jnp.concatenate([padT(w1), padT(b1), padT(w2)], axis=0)
    vec = jnp.zeros((8, npad), jnp.float32)
    for r, a in ((0, b2), (1, err_lo), (2, err_hi), (3, y_start),
                 (4, y_end), (5, child_base), (6, is_leaf)):
        vec = vec.at[r, :N].set(a.astype(jnp.float32))
    return mat, vec


def _rmrt_route_window(mat, vec, q, *, n_keys: int, npad: int, fanout: int,
                       depth: int, kind: str):
    """Stages 1-3 of the RMRT lookup (pure jnp on values — shared by the
    kernel body; the oracle in ``kernels.ref`` reimplements it): depth-D
    masked descent over the VMEM-resident node tables, then the leaf's
    error-bound window clamped to ``n_keys``."""
    row = lambda flat, r, idx: jnp.take(flat, idx + r * npad)

    def predict(node):
        if kind == "linear":
            return row(mat, 0, node) * q + row(vec, 0, node)
        pred = row(vec, 0, node)
        for k in range(H):
            hk = jnp.maximum(q * row(mat, k, node) + row(mat, H + k, node),
                             0.0)
            pred = pred + hk * row(mat, 2 * H + k, node)
        return pred

    def body(_, node):
        pred = predict(node)
        ys = row(vec, 3, node)
        span = row(vec, 4, node) - ys
        child = jnp.clip(((pred - ys) * fanout / span).astype(jnp.int32),
                         0, fanout - 1)
        nxt = row(vec, 5, node).astype(jnp.int32) + child
        return jnp.where(row(vec, 6, node) > 0.5, node, nxt)

    node = jax.lax.fori_loop(0, depth, body,
                             jnp.zeros(q.shape, jnp.int32))
    pred = predict(node)
    lo = jnp.clip(jnp.floor(pred + row(vec, 1, node)), 0, n_keys - 1
                  ).astype(jnp.int32)
    hi = jnp.clip(jnp.ceil(pred + row(vec, 2, node)) + 1.0, 1, n_keys
                  ).astype(jnp.int32)
    return lo, hi


def _rmrt_lookup_kernel(mat_ref, vec_ref, q_ref, keys_ref, out_ref,
                        lo_ref, hi_ref, *,
                        n_keys: int, npad: int, fanout: int, depth: int,
                        tile: int, tile_iters: int, kind: str):
    j = pl.program_id(1)
    q = q_ref[...].reshape(TQ)

    @pl.when(j == 0)
    def _():
        lo, hi = _rmrt_route_window(
            mat_ref[...].reshape(3 * H * npad), vec_ref[...].reshape(8 * npad),
            q, n_keys=n_keys, npad=npad, fanout=fanout, depth=depth,
            kind=kind)
        lo_ref[...] = lo.reshape(lo_ref.shape)
        hi_ref[...] = hi.reshape(hi_ref.shape)
        out_ref[...] = hi.reshape(out_ref.shape)

    _tile_search_merge(keys_ref, q, lo_ref, hi_ref, out_ref, j,
                       n_keys=n_keys, tile=tile, tile_iters=tile_iters)


def rmrt_lookup_pallas(queries, mat, vec, keys, *, fanout: int, depth: int,
                       kind: str = "linear", iters: int | None = None,
                       tile: int | None = None, interpret: bool = True):
    """Positions (left boundary, window-clamped) of ``queries`` in ``keys``
    under the RMRT: the whole depth-``depth`` descent runs in-kernel over
    the packed node tables (``pack_rmrt``), then the error-window-clamped
    tiled search — one kernel, no XLA pre-routing.
    """
    Q = queries.shape[0]
    S = keys.shape[0]
    npad = mat.shape[1]
    q_pad = -(-Q // TQ) * TQ
    if tile is None:
        tile = min(TILE_MAX, _pow2ceil(max(S, 128)))
    assert tile % 128 == 0, "key tile must be a multiple of 128 lanes"
    s_pad = -(-S // tile) * tile
    nk = s_pad // tile
    if iters is None:
        iters = full_iters(S)
    tile_iters = min(iters, full_iters(tile))

    pad1 = lambda a: jnp.pad(a.astype(jnp.float32), (0, q_pad - Q)) \
        .reshape(-1, 8, TQ // 8)
    kp = jnp.pad(keys.astype(jnp.float32), (0, s_pad - S),
                 constant_values=jnp.inf).reshape(nk, 8, tile // 8)

    kern = functools.partial(
        _rmrt_lookup_kernel, n_keys=S, npad=npad, fanout=fanout, depth=depth,
        tile=tile, tile_iters=tile_iters, kind=kind)
    out = pl.pallas_call(
        kern,
        grid=(q_pad // TQ, nk),
        in_specs=[
            pl.BlockSpec((3 * H, npad), lambda i, j: (0, 0)),         # mat
            pl.BlockSpec((8, npad), lambda i, j: (0, 0)),             # vec
            pl.BlockSpec((1, 8, TQ // 8), lambda i, j: (i, 0, 0)),    # q
            pl.BlockSpec((1, 8, tile // 8), lambda i, j: (j, 0, 0)),  # keys
        ],
        out_specs=pl.BlockSpec((1, 8, TQ // 8), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((q_pad // TQ, 8, TQ // 8), jnp.int32),
        scratch_shapes=[pltpu.VMEM((8, TQ // 8), jnp.int32),   # lo window
                        pltpu.VMEM((8, TQ // 8), jnp.int32)],  # hi window
        interpret=interpret,
    )(mat, vec, pad1(queries), kp)
    return out.reshape(-1)[:Q]
