"""Pure-jnp oracles for every Pallas kernel (same dtypes/semantics).

Each function mirrors its kernel's contract exactly (f32 math where the
kernel computes in f32) so tests can assert_allclose across shape/dtype
sweeps in interpret mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hist_ref(keys: jax.Array, m: int, lo, hi) -> jax.Array:
    """Oracle for hist.hist_pallas (f32, right-closed bins)."""
    k = keys.astype(jnp.float32)
    x = (k - jnp.float32(lo)) / (jnp.float32(hi) - jnp.float32(lo))
    b = jnp.clip(jnp.ceil(x * m).astype(jnp.int32) - 1, 0, m - 1)
    counts = jnp.zeros((m,), jnp.float32).at[b].add(1.0)
    return counts / jnp.float32(keys.shape[0])


def ksdist_ref(tgt_hists: jax.Array, pool_a: jax.Array,
               pool_ps: jax.Array) -> jax.Array:
    """Oracle for ksdist.ksdist_pallas: (L, P) Algorithm-2 distances."""
    ht = tgt_hists.astype(jnp.float32)
    pt = jnp.concatenate(
        [jnp.zeros((ht.shape[0], 1), jnp.float32), jnp.cumsum(ht, 1)[:, :-1]], 1)
    up = jnp.max(pool_a[None, :, :] - pt[:, None, :], axis=2)
    dn = jnp.max((ht + pt)[:, None, :] - pool_ps[None, :, :], axis=2)
    return jnp.maximum(up, dn)


def linfit_sums_ref(x: jax.Array, y: jax.Array, buckets: jax.Array,
                    n_buckets: int) -> jax.Array:
    """Oracle for linfit.linfit_sums_pallas: (n_buckets, 5) moment sums."""
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    seg = lambda v: jax.ops.segment_sum(v, buckets, n_buckets)
    return jnp.stack([seg(jnp.ones_like(x)), seg(x), seg(y), seg(x * y),
                      seg(x * x)], axis=1)


def lookup_ref(queries, root, mat, vec, keys, *, n_leaves: int,
               root_kind: str = "linear", leaf_kind: str = "linear",
               iters: int | None = None, tile: int | None = None,
               route_n: int | None = None) -> jax.Array:
    """Oracle for lookup.lookup_pallas: same packed-table contract, same f32
    arithmetic, same per-key-tile clamped search and min-merge — bit-identical
    in interpret mode (including the deliberate non-convergence of queries
    whose window exceeds the static depth; the ops wrapper's verification owns
    those).  ``route_n`` is the frozen routing scale of the dynamic kernel
    (defaults to the key count — static-index behaviour)."""
    from . import lookup as _lk

    q = queries.astype(jnp.float32)
    kf = keys.astype(jnp.float32)
    S = kf.shape[0]
    lp = mat.shape[1]
    if route_n is None:
        route_n = S
    if tile is None:
        tile = min(_lk.TILE_MAX, _lk._pow2ceil(max(S, 128)))
    if iters is None:
        iters = _lk.full_iters(S)
    tile_iters = min(iters, _lk.full_iters(tile))
    nk = -(-S // tile)
    kp = jnp.pad(kf, (0, nk * tile - S), constant_values=jnp.inf)

    lo, hi = _route_window_ref(q, root, mat, vec, n_leaves=n_leaves,
                               route_n=route_n, root_kind=root_kind,
                               leaf_kind=leaf_kind, S=S, lp=lp)
    return _tiled_window_search(q, kp, lo, hi, S=S, tile=tile,
                                tile_iters=tile_iters)


def _route_window_ref(q, root, mat, vec, *, n_leaves: int, route_n: int,
                      root_kind: str, leaf_kind: str, S: int, lp: int):
    """The kernels' stages 1-3, mirrored: root routing -> leaf predict ->
    error-bound window clamped to [0, S].  Same f32 op ordering as
    ``lookup._route_window``."""
    from . import lookup as _lk

    if root_kind == "linear":
        rpred = root[0, 0] * q + root[3, 0]
    else:
        h = jnp.maximum(q[:, None] * root[0, :_lk.H] + root[1, :_lk.H], 0.0)
        rpred = jnp.sum(h * root[2, :_lk.H], axis=1) + root[3, 0]
    b = jnp.clip((rpred * (n_leaves / route_n)).astype(jnp.int32),
                 0, n_leaves - 1)

    matf = mat.reshape(-1)
    vecf = vec.reshape(-1)
    row = lambda flat, r: jnp.take(flat, b + r * lp)
    if leaf_kind == "linear":
        pred = row(matf, 0) * q + row(vecf, 0)
    else:
        pred = row(vecf, 0)
        for k in range(_lk.H):
            hk = jnp.maximum(q * row(matf, k) + row(matf, _lk.H + k), 0.0)
            pred = pred + hk * row(matf, 2 * _lk.H + k)

    lo = jnp.clip(jnp.floor(pred + row(vecf, 1)), 0, S - 1).astype(jnp.int32)
    hi = jnp.clip(jnp.ceil(pred + row(vecf, 2)) + 1.0, 1, S).astype(jnp.int32)
    return lo, hi


def _tiled_window_search(q, kp, lo, hi, *, S: int, tile: int,
                         tile_iters: int, right: bool = False):
    """The kernels' stage 4, mirrored: per-key-tile clamped branchless
    search with min-merge across tiles.  ``kp`` is the +inf-padded f32 key
    array (length a ``tile`` multiple).  ``right=True`` mirrors the range
    kernel's right-boundary search (first position with key > q)."""
    nk = kp.shape[0] // tile
    out = hi
    for j in range(nk):
        base = j * tile
        tlo = jnp.clip(lo - base, 0, tile)
        thi = jnp.clip(hi - base, 0, tile)
        ktile = jax.lax.dynamic_slice_in_dim(kp, base, tile)

        def body(_, lh, ktile=ktile):
            l, h2 = lh
            active = h2 - l > 0
            mid = (l + h2) // 2
            kv = jnp.take(ktile, jnp.clip(mid, 0, tile - 1))
            below = kv <= q if right else kv < q
            nl = jnp.where(below, mid + 1, l)
            nh = jnp.where(below, h2, mid)
            return (jnp.where(active, nl, l), jnp.where(active, nh, h2))

        l, _ = jax.lax.fori_loop(0, tile_iters, body, (tlo, thi))
        out = jnp.minimum(out, jnp.where(l < thi, base + l, S))
    return out


def rmrt_lookup_ref(queries, mat, vec, keys, *, fanout: int, depth: int,
                    kind: str = "linear", iters: int | None = None,
                    tile: int | None = None) -> jax.Array:
    """Oracle for lookup.rmrt_lookup_pallas: same packed node-table contract
    (pack_rmrt layout), same f32 arithmetic — the depth-D masked descent is
    reimplemented here (independent of the kernel body) with identical op
    ordering, then the shared tiled clamped search.  Bit-identical in
    interpret mode."""
    from . import lookup as _lk

    q = queries.astype(jnp.float32)
    kf = keys.astype(jnp.float32)
    S = kf.shape[0]
    npad = mat.shape[1]
    if tile is None:
        tile = min(_lk.TILE_MAX, _lk._pow2ceil(max(S, 128)))
    if iters is None:
        iters = _lk.full_iters(S)
    tile_iters = min(iters, _lk.full_iters(tile))
    nk = -(-S // tile)
    kp = jnp.pad(kf, (0, nk * tile - S), constant_values=jnp.inf)

    matf = mat.reshape(-1)
    vecf = vec.reshape(-1)
    row = lambda flat, r, idx: jnp.take(flat, idx + r * npad)

    def predict(node):
        if kind == "linear":
            return row(matf, 0, node) * q + row(vecf, 0, node)
        pred = row(vecf, 0, node)
        for k in range(_lk.H):
            hk = jnp.maximum(q * row(matf, k, node)
                             + row(matf, _lk.H + k, node), 0.0)
            pred = pred + hk * row(matf, 2 * _lk.H + k, node)
        return pred

    node = jnp.zeros(q.shape, jnp.int32)
    for _ in range(depth):
        pred = predict(node)
        ys = row(vecf, 3, node)
        span = row(vecf, 4, node) - ys
        child = jnp.clip(((pred - ys) * fanout / span).astype(jnp.int32),
                         0, fanout - 1)
        nxt = row(vecf, 5, node).astype(jnp.int32) + child
        node = jnp.where(row(vecf, 6, node) > 0.5, node, nxt)

    pred = predict(node)
    lo = jnp.clip(jnp.floor(pred + row(vecf, 1, node)), 0, S - 1
                  ).astype(jnp.int32)
    hi = jnp.clip(jnp.ceil(pred + row(vecf, 2, node)) + 1.0, 1, S
                  ).astype(jnp.int32)
    return _tiled_window_search(q, kp, lo, hi, S=S, tile=tile,
                                tile_iters=tile_iters)


def dynamic_lookup_ref(queries, root, mat, vec, keys, delta_keys, *,
                       n_leaves: int, route_n: int | None = None,
                       root_kind: str = "linear", leaf_kind: str = "linear",
                       iters: int | None = None,
                       tile: int | None = None) -> tuple:
    """Oracle for lookup.dynamic_lookup_pallas: (base_pos, delta_pos).
    The base tier is exactly :func:`lookup_ref` with the frozen ``route_n``
    routing scale (one oracle — no drift between the static and dynamic
    base-search semantics); the delta probe mirrors the kernel's full-depth
    search of the +inf-padded tier.  Bit-identical in interpret mode."""
    from . import lookup as _lk

    out = lookup_ref(queries, root, mat, vec, keys, n_leaves=n_leaves,
                     root_kind=root_kind, leaf_kind=leaf_kind, iters=iters,
                     tile=tile, route_n=route_n)

    q = queries.astype(jnp.float32)
    dk = _lk.pad_delta(delta_keys)
    nd = dk.shape[0]
    dl = jnp.zeros(q.shape, jnp.int32)
    dh = jnp.full(q.shape, nd, jnp.int32)

    def dbody(_, lh):
        l, h2 = lh
        active = h2 - l > 0
        mid = (l + h2) // 2
        kv = jnp.take(dk, jnp.clip(mid, 0, nd - 1))
        below = kv < q
        nl = jnp.where(below, mid + 1, l)
        nh = jnp.where(below, h2, mid)
        return (jnp.where(active, nl, l), jnp.where(active, nh, h2))

    dl, _ = jax.lax.fori_loop(0, _lk.full_iters(nd), dbody, (dl, dh))
    return out, dl


def dynamic_range_ref(q_lo, q_hi, root, mat, vec, keys, delta_keys, *,
                      n_leaves: int, route_n: int | None = None,
                      root_kind: str = "linear", leaf_kind: str = "linear",
                      iters: int | None = None,
                      tile: int | None = None) -> tuple:
    """Oracle for lookup.dynamic_range_pallas: (base_lo, base_hi, delta_lo,
    delta_hi).  Left boundary of ``q_lo`` and right boundary of ``q_hi``
    against both tiers, with the same routing/window/tiled-search f32 op
    ordering as the fused kernel — bit-identical in interpret mode."""
    from . import lookup as _lk

    ql = q_lo.astype(jnp.float32)
    qh = q_hi.astype(jnp.float32)
    kf = keys.astype(jnp.float32)
    S = kf.shape[0]
    lp = mat.shape[1]
    if route_n is None:
        route_n = S
    if tile is None:
        tile = min(_lk.TILE_MAX, _lk._pow2ceil(max(S, 128)))
    if iters is None:
        iters = _lk.full_iters(S)
    tile_iters = min(iters, _lk.full_iters(tile))
    nk = -(-S // tile)
    kp = jnp.pad(kf, (0, nk * tile - S), constant_values=jnp.inf)

    win = lambda q: _route_window_ref(
        q, root, mat, vec, n_leaves=n_leaves, route_n=route_n,
        root_kind=root_kind, leaf_kind=leaf_kind, S=S, lp=lp)
    lo, hi = win(ql)
    blo = _tiled_window_search(ql, kp, lo, hi, S=S, tile=tile,
                               tile_iters=tile_iters)
    lo, hi = win(qh)
    bhi = _tiled_window_search(qh, kp, lo, hi, S=S, tile=tile,
                               tile_iters=tile_iters, right=True)

    dk = _lk.pad_delta(delta_keys)
    nd = dk.shape[0]

    def probe(q, right):
        dl = jnp.zeros(q.shape, jnp.int32)
        dh = jnp.full(q.shape, nd, jnp.int32)

        def dbody(_, lh):
            l, h2 = lh
            active = h2 - l > 0
            mid = (l + h2) // 2
            kv = jnp.take(dk, jnp.clip(mid, 0, nd - 1))
            below = kv <= q if right else kv < q
            nl = jnp.where(below, mid + 1, l)
            nh = jnp.where(below, h2, mid)
            return (jnp.where(active, nl, l), jnp.where(active, nh, h2))

        dl, _ = jax.lax.fori_loop(0, _lk.full_iters(nd), dbody, (dl, dh))
        return dl

    return blo, bhi, probe(ql, False), probe(qh, True)


def dynamic_range_find_ref(q_lo, q_hi, keys, base_psum, delta_keys,
                           delta_psum) -> tuple:
    """Oracle for ops.range_lookup's (rank_lo, rank_hi): exact searchsorted
    boundaries (side='left' for lo, side='right' for hi) composed through
    the two-tier live-rank algebra, with rank_hi clamped to rank_lo so
    degenerate ranges (lo > hi, tombstoned singletons, fully out-of-range)
    collapse to an empty [rank_lo, rank_lo) window."""
    from . import lookup as _lk

    kf = keys.astype(jnp.float32)
    qlf = q_lo.astype(jnp.float32)
    qhf = q_hi.astype(jnp.float32)
    blo = jnp.searchsorted(kf, qlf, side="left").astype(jnp.int32)
    bhi = jnp.searchsorted(kf, qhf, side="right").astype(jnp.int32)
    df = _lk.pad_delta(delta_keys)
    nd = df.shape[0]
    dlo = jnp.searchsorted(df, qlf, side="left").astype(jnp.int32)
    dhi = jnp.searchsorted(df, qhf, side="right").astype(jnp.int32)
    dpsum = jnp.pad(delta_psum, (0, nd + 1 - delta_psum.shape[0]),
                    mode="edge")
    rank_lo = (blo - base_psum[blo]) + (dlo - dpsum[dlo])
    rank_hi = (bhi - base_psum[bhi]) + (dhi - dpsum[dhi])
    return rank_lo, jnp.maximum(rank_hi, rank_lo)


def dynamic_find_ref(queries, keys, base_dead, base_psum, delta_keys,
                     delta_dead, delta_psum) -> tuple:
    """Oracle for ops.dynamic_find's (found, rank): the same f32 tombstone /
    two-tier live-rank algebra as ``ops._dynamic_lookup_jit``, with exact
    searchsorted boundaries in place of the kernel positions — the seam
    verification pins every valid kernel position to exactly this boundary,
    so ops.dynamic_find must match bit-for-bit on f32-exact tiers.  Model
    tables don't enter: routing only picks the (seam-verified) window."""
    from . import lookup as _lk

    kf = keys.astype(jnp.float32)
    qf = queries.astype(jnp.float32)
    pos = jnp.searchsorted(kf, qf, side="left").astype(jnp.int32)
    bhi = jnp.searchsorted(kf, qf, side="right").astype(jnp.int32)
    base_hit = (bhi - pos) > (base_psum[bhi] - base_psum[pos])
    df = _lk.pad_delta(delta_keys)
    nd = df.shape[0]
    dpos = jnp.searchsorted(df, qf, side="left").astype(jnp.int32)
    dhi = jnp.searchsorted(df, qf, side="right").astype(jnp.int32)
    dpsum = jnp.pad(delta_psum, (0, nd + 1 - delta_psum.shape[0]),
                    mode="edge")
    delta_hit = (dhi - dpos) > (dpsum[dhi] - dpsum[dpos])
    rank = (pos - base_psum[pos]) + (dpos - dpsum[dpos])
    return base_hit | delta_hit, rank
