"""Pure-jnp oracles for every Pallas kernel (same dtypes/semantics).

Each function mirrors its kernel's contract exactly (f32 math where the
kernel computes in f32) so tests can assert_allclose across shape/dtype
sweeps in interpret mode.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def hist_ref(keys: jax.Array, m: int, lo, hi) -> jax.Array:
    """Oracle for hist.hist_pallas (f32, right-closed bins)."""
    k = keys.astype(jnp.float32)
    x = (k - jnp.float32(lo)) / (jnp.float32(hi) - jnp.float32(lo))
    b = jnp.clip(jnp.ceil(x * m).astype(jnp.int32) - 1, 0, m - 1)
    counts = jnp.zeros((m,), jnp.float32).at[b].add(1.0)
    return counts / jnp.float32(keys.shape[0])


def ksdist_ref(tgt_hists: jax.Array, pool_a: jax.Array,
               pool_ps: jax.Array) -> jax.Array:
    """Oracle for ksdist.ksdist_pallas: (L, P) Algorithm-2 distances."""
    ht = tgt_hists.astype(jnp.float32)
    pt = jnp.concatenate(
        [jnp.zeros((ht.shape[0], 1), jnp.float32), jnp.cumsum(ht, 1)[:, :-1]], 1)
    up = jnp.max(pool_a[None, :, :] - pt[:, None, :], axis=2)
    dn = jnp.max((ht + pt)[:, None, :] - pool_ps[None, :, :], axis=2)
    return jnp.maximum(up, dn)


def linfit_sums_ref(x: jax.Array, y: jax.Array, buckets: jax.Array,
                    n_buckets: int) -> jax.Array:
    """Oracle for linfit.linfit_sums_pallas: (n_buckets, 5) moment sums."""
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    seg = lambda v: jax.ops.segment_sum(v, buckets, n_buckets)
    return jnp.stack([seg(jnp.ones_like(x)), seg(x), seg(y), seg(x * y),
                      seg(x * x)], axis=1)


def lookup_ref(queries, w1, b1, w2, b2, err_lo, err_hi, keys,
               linear: bool = False) -> jax.Array:
    """Oracle for lookup.lookup_pallas (f32 predict + bounded search)."""
    q = queries.astype(jnp.float32)
    keys = keys.astype(jnp.float32)
    n = keys.shape[0]
    if linear:
        pred = w1[:, 0].astype(jnp.float32) * q + b2.astype(jnp.float32)
    else:
        h = jnp.maximum(q[:, None] * w1.astype(jnp.float32)
                        + b1.astype(jnp.float32), 0.0)
        pred = jnp.sum(h * w2.astype(jnp.float32), 1) + b2.astype(jnp.float32)
    lo = jnp.clip(jnp.floor(pred + err_lo.astype(jnp.float32)), 0, n - 1
                  ).astype(jnp.int32)
    hi = jnp.clip(jnp.ceil(pred + err_hi.astype(jnp.float32)) + 1.0, 1, n
                  ).astype(jnp.int32)
    iters = math.ceil(math.log2(max(n, 2))) + 1

    def body(_, lh):
        lo, hi = lh
        active = hi - lo > 0
        mid = (lo + hi) // 2
        kv = keys[jnp.clip(mid, 0, n - 1)]
        below = kv < q
        nlo = jnp.where(below, mid + 1, lo)
        nhi = jnp.where(below, hi, mid)
        return (jnp.where(active, nlo, lo), jnp.where(active, nhi, hi))

    lo, _ = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return lo
