"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernel bodies execute in Python via the Pallas interpreter, which is the
validation mode) and to False on real TPU backends.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import hist as _hist
from . import ksdist as _ksdist
from . import linfit as _linfit
from . import lookup as _lookup


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("m", "interpret"))
def histogram(keys: jax.Array, m: int, lo, hi, interpret: bool | None = None):
    """Streaming m-bin relative-frequency histogram (unsorted keys)."""
    interpret = _default_interpret() if interpret is None else interpret
    return _hist.hist_pallas(keys, m, lo, hi, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ksdist_matrix(tgt_hists, pool_a, pool_ps, interpret: bool | None = None):
    """(L, P) Algorithm-2 distance matrix (targets x pool)."""
    interpret = _default_interpret() if interpret is None else interpret
    return _ksdist.ksdist_pallas(tgt_hists, pool_a, pool_ps,
                                 interpret=interpret)


@functools.partial(jax.jit, static_argnames=("n_buckets", "interpret"))
def segment_linfit(x, y, buckets, n_buckets: int,
                   interpret: bool | None = None):
    """Per-bucket least-squares (slope, intercept): (n_buckets, 2) f64.

    Two kernel passes for f32 moment stability: pass 1 accumulates
    (count, Sum x, Sum y) -> per-bucket means; inputs are then centered *per
    bucket* in f64 (within-bucket dynamic range is tiny, so the f32 kernel
    moments of pass 2 are exact enough) and pass 2 accumulates the centered
    cross moments. Global standardization alone cancels catastrophically
    when buckets are narrow slices of the key range.
    """
    interpret = _default_interpret() if interpret is None else interpret
    x64 = x.astype(jnp.float64)
    y64 = y.astype(jnp.float64)
    # pass 1 on globally standardized coords (safe for means)
    mu_x, sd_x = jnp.mean(x64), jnp.maximum(jnp.std(x64), 1e-30)
    mu_y, sd_y = jnp.mean(y64), jnp.maximum(jnp.std(y64), 1e-30)
    xs = ((x64 - mu_x) / sd_x).astype(jnp.float32)
    ys = ((y64 - mu_y) / sd_y).astype(jnp.float32)
    s1 = _linfit.linfit_sums_pallas(xs, ys, buckets, n_buckets,
                                    interpret=interpret)
    n = s1[:, 0].astype(jnp.float64)
    nn = jnp.maximum(n, 1.0)
    bmu_x = s1[:, 1].astype(jnp.float64) / nn       # in standardized coords
    bmu_y = s1[:, 2].astype(jnp.float64) / nn
    # pass 2: per-bucket centered
    xc = (((x64 - mu_x) / sd_x) - bmu_x[buckets]).astype(jnp.float32)
    yc = (((y64 - mu_y) / sd_y) - bmu_y[buckets]).astype(jnp.float32)
    s2 = _linfit.linfit_sums_pallas(xc, yc, buckets, n_buckets,
                                    interpret=interpret)
    sxy = s2[:, 3].astype(jnp.float64)
    sxx = s2[:, 4].astype(jnp.float64)
    a_s = jnp.where(sxx > 1e-20, sxy / sxx, 0.0)
    # map back: y = a x + b in raw coordinates
    a = a_s * sd_y / sd_x
    b = (bmu_y * sd_y + mu_y) - a * (bmu_x * sd_x + mu_x)
    return jnp.stack([a, jnp.where(n > 0, b, 0.0)], 1)


def index_lookup(queries, root, mat, vec, keys, *, n_leaves: int,
                 root_kind: str = "linear", leaf_kind: str = "linear",
                 iters: int | None = None, tile: int | None = None,
                 interpret: bool | None = None, seam_budget: int = 1024):
    """Fused serving lookup (route -> predict -> window -> clamped search)
    with the XLA-side sparse seam verification.

    ``root``/``mat``/``vec`` are the packed tables from
    lookup.pack_root / lookup.pack_leaves. ``iters`` is the static
    error-window search depth; when None it is derived host-side from the
    (concrete) bound rows of ``vec`` via lookup.search_iters.
    """
    interpret = _default_interpret() if interpret is None else interpret
    if iters is None:
        if isinstance(vec, jax.core.Tracer):
            # under an outer jit/vmap the bounds aren't concrete; fall back
            # to the sound full depth (callers wanting the clamped depth
            # pass iters=index.search_iters, which is static)
            iters = _lookup.full_iters(keys.shape[0])
        else:
            import numpy as np
            L = min(n_leaves, vec.shape[1])
            vec_np = np.asarray(vec)          # concrete at call time
            iters = _lookup.search_iters(vec_np[1, :L], vec_np[2, :L],
                                         keys.shape[0])
    return _index_lookup_jit(queries, root, mat, vec, keys,
                             n_leaves=n_leaves, root_kind=root_kind,
                             leaf_kind=leaf_kind, iters=iters, tile=tile,
                             interpret=interpret, seam_budget=seam_budget)


def _seam_fix(r, kf, qf, seam_budget: int, right: bool = False):
    """Seam verification in f32 space (kernel semantics). Misses are rare —
    boundary queries outside their leaf's window, or queries routed to a
    sentinel (empty-leaf) window deeper than the clamped search depth — so
    the fallback re-searches only the invalid positions (compacted to a
    static ``seam_budget``); the dense full-Q re-search runs only if the
    miss count exceeds the budget.  ``right=True`` checks the mirrored
    right-boundary invariant (kf[r-1] <= q < kf[r]) for the range kernel's
    hi endpoints, with a side='right' searchsorted fallback."""
    n = kf.shape[0]
    rc = jnp.clip(r, 0, n - 1)
    side = "right" if right else "left"
    prev = kf[jnp.clip(r - 1, 0, n - 1)]
    if right:
        valid = ((r == 0) | (prev <= qf)) & ((r == n) | (kf[rc] > qf))
    else:
        valid = ((r == 0) | (prev < qf)) & ((r == n) | (kf[rc] >= qf))
    n_bad = jnp.sum(~valid)
    budget = min(seam_budget, qf.shape[0])

    def _sparse(_):
        idx = jnp.nonzero(~valid, size=budget, fill_value=0)[0]
        sub = jnp.searchsorted(kf, qf[idx], side=side).astype(r.dtype)
        return r.at[idx].set(jnp.where(valid[idx], r[idx], sub))

    def _dense(_):
        full = jnp.searchsorted(kf, qf, side=side).astype(r.dtype)
        return jnp.where(valid, r, full)

    def _fix(_):
        return jax.lax.cond(n_bad <= budget, _sparse, _dense, None)

    return jax.lax.cond(n_bad == 0, lambda _: r, _fix, None)


@functools.partial(jax.jit, static_argnames=(
    "n_leaves", "root_kind", "leaf_kind", "iters", "tile", "interpret",
    "seam_budget"))
def _index_lookup_jit(queries, root, mat, vec, keys, *, n_leaves, root_kind,
                      leaf_kind, iters, tile, interpret, seam_budget):
    r = _lookup.lookup_pallas(queries, root, mat, vec, keys,
                              n_leaves=n_leaves, root_kind=root_kind,
                              leaf_kind=leaf_kind, iters=iters, tile=tile,
                              interpret=interpret)
    return _seam_fix(r, keys.astype(jnp.float32),
                     queries.astype(jnp.float32), seam_budget)


def rmrt_lookup(queries, mat, vec, keys, *, fanout: int, depth: int,
                kind: str = "linear", iters: int | None = None,
                tile: int | None = None, interpret: bool | None = None,
                seam_budget: int = 1024):
    """Fused RMRT serving lookup: in-kernel fixed-depth descent over the
    packed node tables (lookup.pack_rmrt) + error-window-clamped tiled
    search, with the same XLA-side sparse seam verification as
    :func:`index_lookup`.

    ``iters`` is the static error-window search depth; when None it is
    derived host-side from the (concrete) bound rows of ``vec`` — internal
    nodes carry zero-width rows and sentinel (empty-leaf) windows are
    excluded by the live mask, exactly like the RMI path.
    """
    interpret = _default_interpret() if interpret is None else interpret
    if iters is None:
        if isinstance(vec, jax.core.Tracer):
            iters = _lookup.full_iters(keys.shape[0])
        else:
            import numpy as np
            vec_np = np.asarray(vec)
            iters = _lookup.search_iters(vec_np[1], vec_np[2],
                                         keys.shape[0])
    return _rmrt_lookup_jit(queries, mat, vec, keys, fanout=fanout,
                            depth=depth, kind=kind, iters=iters, tile=tile,
                            interpret=interpret, seam_budget=seam_budget)


@functools.partial(jax.jit, static_argnames=(
    "fanout", "depth", "kind", "iters", "tile", "interpret", "seam_budget"))
def _rmrt_lookup_jit(queries, mat, vec, keys, *, fanout, depth, kind, iters,
                     tile, interpret, seam_budget):
    r = _lookup.rmrt_lookup_pallas(queries, mat, vec, keys, fanout=fanout,
                                   depth=depth, kind=kind, iters=iters,
                                   tile=tile, interpret=interpret)
    return _seam_fix(r, keys.astype(jnp.float32),
                     queries.astype(jnp.float32), seam_budget)


def dynamic_index_lookup(queries, root, mat, vec, keys, base_dead, base_psum,
                         delta_keys, delta_dead, delta_psum, *, n_leaves: int,
                         route_n: int, root_kind: str = "linear",
                         leaf_kind: str = "linear", iters: int | None = None,
                         tile: int | None = None,
                         interpret: bool | None = None,
                         seam_budget: int = 1024):
    """Fused two-tier serving find for the dynamic index: one Pallas kernel
    (base window search + delta probe), then O(Q) jitted gathers for the
    tombstone mask and the two-tier live rank.  Zero per-query host Python.

    ``keys``/``delta_keys`` are the sorted base/delta tiers (delta +inf
    padded to its storage capacity); ``*_dead`` the tombstone bitmaps and
    ``*_psum`` their exclusive prefix sums (length n+1).  ``route_n`` is the
    frozen routing scale of ``core.updates.DynamicRMI``.  Returns
    (found, rank, base_pos, delta_pos): ``found`` is True iff a live copy of
    the query exists in either tier; ``rank`` counts live keys < q across
    both tiers.
    """
    interpret = _default_interpret() if interpret is None else interpret
    if iters is None:
        if isinstance(vec, jax.core.Tracer):
            iters = _lookup.full_iters(keys.shape[0])
        else:
            import numpy as np
            L = min(n_leaves, vec.shape[1])
            # tracelint: ok[hot-sync](iters=None convenience path only; serve callers pass iters)
            vec_np = np.asarray(vec)
            iters = _lookup.search_iters(vec_np[1, :L], vec_np[2, :L],
                                         keys.shape[0])
    return _dynamic_lookup_jit(queries, root, mat, vec, keys, base_dead,
                               base_psum, delta_keys, delta_dead, delta_psum,
                               n_leaves=n_leaves, route_n=route_n,
                               root_kind=root_kind, leaf_kind=leaf_kind,
                               iters=iters, tile=tile, interpret=interpret,
                               seam_budget=seam_budget)


def dynamic_find(queries, root, mat, vec, keys, base_dead, base_psum,
                 delta_keys, delta_dead, delta_psum, **kw):
    """The two-tier serving answer alone: (found, rank) of
    :func:`dynamic_index_lookup`, without the positional diagnostics.
    Shared by ``core.updates.DynamicRMI.find`` and the per-shard dispatch of
    ``core.distributed.ShardedDynamicIndex`` (which packs per-shard routing
    scales into the root block — ``lookup.pack_root(route_scale=...)`` — and
    traces this once with a uniform static ``route_n``)."""
    found, rank, _, _ = dynamic_index_lookup(
        queries, root, mat, vec, keys, base_dead, base_psum, delta_keys,
        delta_dead, delta_psum, **kw)
    return found, rank


@functools.partial(jax.jit, static_argnames=(
    "n_leaves", "route_n", "root_kind", "leaf_kind", "iters", "tile",
    "interpret", "seam_budget"))
def _dynamic_lookup_jit(queries, root, mat, vec, keys, base_dead, base_psum,
                        delta_keys, delta_dead, delta_psum, *, n_leaves,
                        route_n, root_kind, leaf_kind, iters, tile, interpret,
                        seam_budget):
    pos, dpos = _lookup.dynamic_lookup_pallas(
        queries, root, mat, vec, keys, delta_keys, n_leaves=n_leaves,
        route_n=route_n, root_kind=root_kind, leaf_kind=leaf_kind,
        iters=iters, tile=tile, interpret=interpret)
    kf = keys.astype(jnp.float32)
    qf = queries.astype(jnp.float32)
    # Base tier: seam-verify the window-clamped positions, then tombstone
    # mask.  The delta probe ran at full depth over the whole (VMEM-sized)
    # tier, so its boundary is already exact — no seam pass.  A hit is any
    # *live* entry in the equal-key run [left, right): count live slots via
    # the tombstone prefix sums (robust to partially tombstoned duplicate
    # runs); the right boundaries are one O(Q log n) searchsorted each.
    pos = _seam_fix(pos, kf, qf, seam_budget)
    bhi = jnp.searchsorted(kf, qf, side="right").astype(pos.dtype)
    base_hit = (bhi - pos) > (base_psum[bhi] - base_psum[pos])
    df = _lookup.pad_delta(delta_keys)
    nd = df.shape[0]
    dhi = jnp.searchsorted(df, qf, side="right").astype(dpos.dtype)
    dpsum = jnp.pad(delta_psum, (0, nd + 1 - delta_psum.shape[0]),
                    mode="edge")
    delta_hit = (dhi - dpos) > (dpsum[dhi] - dpsum[dpos])
    # Live rank across both tiers: positions minus tombstones left of them.
    rank = (pos - base_psum[pos]) + (dpos - dpsum[dpos])
    return base_hit | delta_hit, rank, pos, dpos


def range_lookup(q_lo, q_hi, root, mat, vec, keys, base_dead, base_psum,
                 delta_keys, delta_dead, delta_psum, *, n_leaves: int,
                 route_n: int, root_kind: str = "linear",
                 leaf_kind: str = "linear", iters: int | None = None,
                 tile: int | None = None, interpret: bool | None = None,
                 seam_budget: int = 1024):
    """Fused two-tier range answer: (rank_lo, rank_hi) live ranks of the
    inclusive key range ``[q_lo, q_hi]`` — rank_lo counts live keys < q_lo
    (leftmost rank under duplicates), rank_hi counts live keys <= q_hi
    (rightmost rank), so the range holds exactly rank_hi - rank_lo live
    entries.  One Pallas pass routes BOTH endpoints (lookup.
    dynamic_range_pallas), each boundary is seam-verified with its own
    side, and rank_hi is clamped to rank_lo so degenerate inputs (lo > hi,
    a tombstoned singleton, a fully out-of-range window) return an empty
    range instead of a negative width.
    """
    interpret = _default_interpret() if interpret is None else interpret
    if iters is None:
        if isinstance(vec, jax.core.Tracer):
            iters = _lookup.full_iters(keys.shape[0])
        else:
            import numpy as np
            L = min(n_leaves, vec.shape[1])
            # tracelint: ok[hot-sync](iters=None convenience path only; serve callers pass iters)
            vec_np = np.asarray(vec)
            iters = _lookup.search_iters(vec_np[1, :L], vec_np[2, :L],
                                         keys.shape[0])
    return _range_lookup_jit(q_lo, q_hi, root, mat, vec, keys, base_psum,
                             delta_keys, delta_psum, n_leaves=n_leaves,
                             route_n=route_n, root_kind=root_kind,
                             leaf_kind=leaf_kind, iters=iters, tile=tile,
                             interpret=interpret, seam_budget=seam_budget)


@functools.partial(jax.jit, static_argnames=(
    "n_leaves", "route_n", "root_kind", "leaf_kind", "iters", "tile",
    "interpret", "seam_budget"))
def _range_lookup_jit(q_lo, q_hi, root, mat, vec, keys, base_psum,
                      delta_keys, delta_psum, *, n_leaves, route_n,
                      root_kind, leaf_kind, iters, tile, interpret,
                      seam_budget):
    blo, bhi, dlo, dhi = _lookup.dynamic_range_pallas(
        q_lo, q_hi, root, mat, vec, keys, delta_keys, n_leaves=n_leaves,
        route_n=route_n, root_kind=root_kind, leaf_kind=leaf_kind,
        iters=iters, tile=tile, interpret=interpret)
    kf = keys.astype(jnp.float32)
    qlf = q_lo.astype(jnp.float32)
    qhf = q_hi.astype(jnp.float32)
    # Seam-verify each base boundary with its own side; the delta probes ran
    # at full depth over the VMEM-sized tier so they are already exact.
    blo = _seam_fix(blo, kf, qlf, seam_budget)
    bhi = _seam_fix(bhi, kf, qhf, seam_budget, right=True)
    nd = _lookup.pad_delta(delta_keys).shape[0]
    dpsum = jnp.pad(delta_psum, (0, nd + 1 - delta_psum.shape[0]),
                    mode="edge")
    rank_lo = (blo - base_psum[blo]) + (dlo - dpsum[dlo])
    rank_hi = (bhi - base_psum[bhi]) + (dhi - dpsum[dhi])
    return rank_lo, jnp.maximum(rank_hi, rank_lo)
