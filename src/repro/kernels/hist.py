"""Pallas TPU kernel: streaming relative-frequency histogram.

Used on the ingest/update path where keys arrive unsorted (the sorted path
uses the O(m log n) searchsorted trick in core.cdf). TPU adaptation: binning
is a one-hot compare + a (1, T) x (T, m) matmul so the accumulation runs on
the MXU; the m-bin accumulator lives in VMEM across grid steps (same output
block for every step, initialized at step 0).

Tiling: keys are streamed HBM->VMEM in (8, 128) f32 tiles; the histogram is
one (1, m_pad) f32 block (m_pad = m rounded up to a lane multiple of 128).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_R, TILE_C = 8, 128
TILE = TILE_R * TILE_C


def _hist_kernel(prm_ref, keys_ref, out_ref, *, m: int, n_valid: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    lo, inv_span = prm_ref[0, 0], prm_ref[0, 1]
    k = keys_ref[...].reshape(TILE)                       # (TILE,) f32
    gidx = step * TILE + jax.lax.broadcasted_iota(jnp.int32, (TILE, 1), 0)[:, 0]
    valid = gidx < n_valid
    x = (k - lo) * inv_span
    # right-closed bins: bin = ceil(x*m) - 1, clipped
    b = jnp.clip(jnp.ceil(x * m).astype(jnp.int32) - 1, 0, m - 1)
    m_pad = out_ref.shape[1]
    onehot = (b[:, None] == jax.lax.broadcasted_iota(jnp.int32, (TILE, m_pad), 1))
    onehot = jnp.where(valid[:, None], onehot.astype(jnp.float32), 0.0)
    ones = jnp.ones((1, TILE), jnp.float32)
    out_ref[...] += jnp.dot(ones, onehot,                  # (1, m_pad) on MXU
                            preferred_element_type=jnp.float32)


def hist_pallas(keys: jax.Array, m: int, lo, hi, *,
                interpret: bool = True) -> jax.Array:
    """Relative-frequency m-bin histogram of ``keys`` (any 1-D float array).

    Returns float32 (m,) frequencies summing to 1.
    """
    n = keys.shape[0]
    n_pad = -(-n // TILE) * TILE
    m_pad = -(-m // 128) * 128
    kp = jnp.pad(keys.astype(jnp.float32), (0, n_pad - n))
    kp = kp.reshape(n_pad // TILE, TILE_R, TILE_C)
    lo32 = jnp.asarray(lo, jnp.float32)
    span = jnp.maximum(jnp.asarray(hi, jnp.float32) - lo32, 1e-30)
    prm = jnp.zeros((1, 128), jnp.float32).at[0, 0].set(lo32) \
        .at[0, 1].set(1.0 / span)

    def kern(prm_ref, keys_ref, out_ref):
        _hist_kernel(prm_ref, keys_ref, out_ref, m=m, n_valid=n)

    counts = pl.pallas_call(
        kern,
        grid=(n_pad // TILE,),
        in_specs=[pl.BlockSpec((1, 128), lambda i: (0, 0)),
                  pl.BlockSpec((1, TILE_R, TILE_C), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, m_pad), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, m_pad), jnp.float32),
        interpret=interpret,
    )(prm, kp)
    return counts[0, :m] / jnp.float32(n)
