"""Pallas TPU kernel: blockwise causal flash attention (forward).

This is the top §Perf lever identified by the roofline loop
(EXPERIMENTS.md): the jnp blockwise attention materializes the f32
(B, H, Sq, kv_block) score/exp tensors in HBM several times per block —
the dominant memory term of most train/prefill cells. This kernel keeps
the whole online-softmax update in VMEM: per grid cell it loads a
(BQ, dh) query tile and one (BK, dh) KV tile, runs QK^T -> masked exp ->
accumulate on the MXU/VPU, and only the (BQ, dh) output ever returns to
HBM.

Grid: (batch*heads, Sq/BQ, Skv/BK) with the KV dim innermost; m/l/acc
live in VMEM scratch across the KV iterations of one (bh, q) cell.

Validated in interpret mode against the production jnp path
(models.layers.flash_attention) — which is itself the oracle used by the
LM substrate — over shape sweeps in tests/test_kernels.py. On this CPU
container the kernel cannot be lowered for real (no TPU), so the dry-run
cells keep the jnp path; the expected effect on the memory term is
quantified in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BQ, BK = 128, 128
NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, sq: int, skv: int, causal: bool, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale            # (BQ, dh)
    k = k_ref[0].astype(jnp.float32)                    # (BK, dh)
    v = v_ref[0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)   # (BQ, BK)
    q_pos = qi * BQ + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 0)
    k_pos = ki * BK + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 1)
    mask = k_pos < skv
    if causal:
        mask &= k_pos <= q_pos
    s = jnp.where(mask, s, NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True,
                           interpret: bool = True) -> jax.Array:
    """q: (B, Sq, H, dh); k/v: (B, Skv, H, dh) (GQA pre-broadcast by caller).

    Returns (B, Sq, H, dh) in q's dtype. Padding to (BQ, BK) multiples is
    handled here; padded KV positions are masked inside the kernel.
    """
    B, Sq, H, dh = q.shape
    Skv = k.shape[1]
    scale = 1.0 / float(dh) ** 0.5
    sq_pad = -(-Sq // BQ) * BQ
    sk_pad = -(-Skv // BK) * BK

    def prep(x, s_pad):
        x = jnp.pad(x, ((0, 0), (0, s_pad - x.shape[1]), (0, 0), (0, 0)))
        return x.transpose(0, 2, 1, 3).reshape(B * H, s_pad, dh)

    qp, kp, vp = prep(q, sq_pad), prep(k, sk_pad), prep(v, sk_pad)

    kern = functools.partial(_flash_kernel, sq=Sq, skv=Skv, causal=causal,
                             scale=scale)
    out = pl.pallas_call(
        kern,
        grid=(B * H, sq_pad // BQ, sk_pad // BK),
        in_specs=[
            pl.BlockSpec((1, BQ, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, BK, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, BK, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, BQ, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, sq_pad, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((BQ, 1), jnp.float32),    # running max m
            pltpu.VMEM((BQ, 1), jnp.float32),    # running denom l
            pltpu.VMEM((BQ, dh), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qp, kp, vp)
    out = out.reshape(B, H, sq_pad, dh).transpose(0, 2, 1, 3)
    return out[:, :Sq]
