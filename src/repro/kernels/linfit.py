"""Pallas TPU kernel: batched per-segment linear least squares (RMI/RMRT
leaf fitting).

Accumulates, for every leaf bucket b, the moment sums
    S[b] = [count, Sum x, Sum y, Sum xy, Sum x^2]
as a (8, B) accumulator (stat rows padded 5->8 for sublane alignment) via an
MXU matmul per tile:  feats(8, T) @ onehot(T, TB)  ->  (8, TB).

The closed-form solve (a = (n Sxy - Sx Sy) / (n Sxx - Sx^2), b = ...) is a
tiny elementwise epilogue done by the ops wrapper. Keys are pre-centered /
scaled per segment *range block* by the wrapper to keep f32 moments stable
(raw SOSD keys are u64-scale; x'^2 sums overflow f32 otherwise).

Grid: (bucket_tiles, key_tiles) with key tiles innermost so each (8, TB)
output block accumulates over its full key stream before moving on.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 1024     # keys per grid step
TB = 512        # buckets per grid step


def _linfit_kernel(x_ref, y_ref, b_ref, out_ref, *, n_valid: int):
    jb, step = pl.program_id(0), pl.program_id(1)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].reshape(TILE)
    y = y_ref[...].reshape(TILE)
    b = b_ref[...].reshape(TILE)
    gidx = step * TILE + jax.lax.broadcasted_iota(jnp.int32, (TILE, 1), 0)[:, 0]
    valid = (gidx < n_valid).astype(jnp.float32)

    local = b - jb * TB                                     # bucket in tile?
    onehot = (local[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (TILE, TB), 1))
    onehot = onehot.astype(jnp.float32) * valid[:, None]    # (TILE, TB)

    feats = jnp.stack([jnp.ones_like(x), x, y, x * y, x * x,
                       jnp.zeros_like(x), jnp.zeros_like(x),
                       jnp.zeros_like(x)])                  # (8, TILE)
    out_ref[...] += jnp.dot(feats, onehot,                  # (8, TB) on MXU
                            preferred_element_type=jnp.float32)


def linfit_sums_pallas(x: jax.Array, y: jax.Array, buckets: jax.Array,
                       n_buckets: int, *, interpret: bool = True) -> jax.Array:
    """Per-bucket moment sums (n_buckets, 5) float32.

    x, y: (N,) f32 (pre-scaled); buckets: (N,) int32.
    """
    n = x.shape[0]
    n_pad = -(-n // TILE) * TILE
    b_pad = -(-n_buckets // TB) * TB
    xp = jnp.pad(x.astype(jnp.float32), (0, n_pad - n)).reshape(-1, 8, TILE // 8)
    yp = jnp.pad(y.astype(jnp.float32), (0, n_pad - n)).reshape(-1, 8, TILE // 8)
    bp = jnp.pad(buckets.astype(jnp.int32), (0, n_pad - n),
                 constant_values=-1).reshape(-1, 8, TILE // 8)

    def kern(x_ref, y_ref, b_ref, out_ref):
        _linfit_kernel(x_ref, y_ref, b_ref, out_ref, n_valid=n)

    out = pl.pallas_call(
        kern,
        grid=(b_pad // TB, n_pad // TILE),
        in_specs=[
            pl.BlockSpec((1, 8, TILE // 8), lambda j, i: (i, 0, 0)),
            pl.BlockSpec((1, 8, TILE // 8), lambda j, i: (i, 0, 0)),
            pl.BlockSpec((1, 8, TILE // 8), lambda j, i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((8, TB), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((8, b_pad), jnp.float32),
        interpret=interpret,
    )(xp, yp, bp)
    return out[:5, :n_buckets].T                            # (n_buckets, 5)
