"""Pallas TPU kernel: batched Algorithm-2 histogram distance (the agile-
reuse selection core).

Computes the (L, P) distance matrix between L target histograms (RMI leaves
/ RMRT level nodes) and a pool of P pre-trained synthetic histograms:

    d[l, p] = max( max_m (A_S[p,m] - P_T[l,m]),
                   max_m (A_T[l,m] - P_S[p,m]) )

where A = H + P(prefix) tables are precomputed per side (core.reuse.
pool_prefix_tables). TPU adaptation of the paper's sequential priority-queue
scan: one grid cell processes a (TL, TP) tile of the matrix with both
operand tiles resident in VMEM; the m-dim broadcast stays on-chip
(TL*TP*m f32 = 1 MiB at 64x64x64), so HBM traffic is O(L*m + P*m + L*P)
instead of the O(L*P*m) a naive XLA broadcast materializes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TL, TP = 64, 64


def _ksdist_kernel(tgt_a_ref, tgt_pt_ref, pool_a_ref, pool_ps_ref, out_ref):
    ta = tgt_a_ref[...]       # (TL, m)  = H_T + P_T
    tp = tgt_pt_ref[...]      # (TL, m)  = P_T
    pa = pool_a_ref[...]      # (TP, m)  = H_S + P_S
    pp = pool_ps_ref[...]     # (TP, m)  = P_S
    up = jnp.max(pa[None, :, :] - tp[:, None, :], axis=2)     # (TL, TP)
    dn = jnp.max(ta[:, None, :] - pp[None, :, :], axis=2)     # (TL, TP)
    out_ref[...] = jnp.maximum(up, dn)


def ksdist_pallas(tgt_hists: jax.Array, pool_a: jax.Array, pool_ps: jax.Array,
                  *, interpret: bool = True) -> jax.Array:
    """(L, P) Algorithm-2 distances. All inputs f32; m is padded to a lane
    multiple inside (padding bins carry zero mass so prefix tables are flat
    there and do not perturb the max)."""
    L, m = tgt_hists.shape
    P = pool_a.shape[0]
    m_pad = -(-m // 128) * 128
    L_pad = -(-L // TL) * TL
    P_pad = -(-P // TP) * TP

    ht = tgt_hists.astype(jnp.float32)
    pt = jnp.concatenate(
        [jnp.zeros((L, 1), jnp.float32), jnp.cumsum(ht, 1)[:, :-1]], 1)
    ta = ht + pt

    def pad2(a, rows, col_fill, row_fill):
        a = jnp.pad(a, ((0, 0), (0, m_pad - a.shape[1])),
                    constant_values=col_fill)
        return jnp.pad(a, ((0, rows - a.shape[0]), (0, 0)),
                       constant_values=row_fill)

    # Column padding must be neutral under the max: A-side columns get -10
    # (never the max), prefix-side columns +10 (subtracted, never the max).
    # Pool *row* padding gets A_S = +2 so padded pool entries report
    # distance > 1 and are never eligible; target padding rows are sliced
    # off afterwards.
    ta_p = pad2(ta, L_pad, -10.0, 0.0)
    tp_p = pad2(pt, L_pad, +10.0, 0.0)
    pa_p = pad2(pool_a.astype(jnp.float32), P_pad, -10.0, +2.0)
    pp_p = pad2(pool_ps.astype(jnp.float32), P_pad, +10.0, 0.0)

    out = pl.pallas_call(
        _ksdist_kernel,
        grid=(L_pad // TL, P_pad // TP),
        in_specs=[
            pl.BlockSpec((TL, m_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((TL, m_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((TP, m_pad), lambda i, j: (j, 0)),
            pl.BlockSpec((TP, m_pad), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((TL, TP), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((L_pad, P_pad), jnp.float32),
        interpret=interpret,
    )(ta_p, tp_p, pa_p, pp_p)
    return out[:L, :P]
