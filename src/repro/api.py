"""``repro.api`` — the one facade over every dynamic index backend.

``Index.build(keys, *, mesh=None, pool=None)`` returns a single object
with the canonical verb set, dispatching to the single-host
``core.updates.DynamicRMI`` (``mesh=None``) or the range-partitioned
``core.distributed.ShardedDynamicIndex`` (``mesh`` given).  Verb-to-
backend mapping (also documented in ``core.drift``):

  =============  ====================================================
  verb           backend call
  =============  ====================================================
  find           ``backend.find(q, path=...)`` -> (found, rank)
  find_range     ``backend.find_range(lo, hi, path=...)``
  insert         ``backend.insert_batch(keys)``
  delete         ``backend.delete_batch(keys)``
  gather         ``backend.live_keys()[ranks]``
  gather_range   ``backend.gather_range(rank_lo, rank_hi)``
  snapshot       ``persist.snapshot_dynamic`` | ``persist.snapshot_sharded``
  restore        ``persist.restore_dynamic`` | ``persist.restore_sharded``
  =============  ====================================================

Drift-adaptive serving rides the same facade: pass ``drift_bins=`` (plus
``drift_hi``/``drift_lo`` thresholds and ``swap_on_drift=True``) to
``build`` and the backend maintains a per-shard KS drift score online;
``maybe_swap()`` runs one bound-checked pool hot-swap pass and
``drift_scores()`` exposes the ``(n_shards, 2)`` [score, latch] table.

The per-backend entry points (``DynamicRMI.build``,
``ShardedDynamicIndex.build``) remain importable and supported — the
facade adds no state of its own, so mixing levels is safe — but new code
should go through :class:`Index`.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass

import numpy as np

from .core import persist as persist_mod
from .core.distributed import ShardedDynamicIndex
from .core.updates import DynamicRMI

__all__ = ["Index", "build_index"]


def _as_store(src) -> persist_mod.SnapshotStore:
    if isinstance(src, persist_mod.SnapshotStore):
        return src
    return persist_mod.SnapshotStore(str(src))


@dataclass
class Index:
    """One dynamic learned index (module docstring: verb table).  Thin by
    design: every verb forwards to the backend, so anything true of
    ``DynamicRMI`` / ``ShardedDynamicIndex`` (rank semantics, path
    selection, drift lifecycle) is true here verbatim."""
    backend: object                     # DynamicRMI | ShardedDynamicIndex

    # -- construction ------------------------------------------------------
    @classmethod
    def build(cls, keys, *, mesh=None, axis: str = "data", pool=None,
              **kwargs) -> "Index":
        """Build over sorted ``keys``.  ``mesh=None`` -> single-host
        ``DynamicRMI``; a ``jax.sharding.Mesh`` -> ``ShardedDynamicIndex``
        range-partitioned over ``mesh.shape[axis]`` shards.  ``pool`` is
        the pre-trained ``reuse.ModelPool`` consulted by Algorithm 1 on
        rebuilds and drift hot-swaps; remaining kwargs forward to the
        backend ``build`` (``n_leaves``, ``eps``, ``drift_bins``,
        ``swap_on_drift``, ...)."""
        if mesh is None:
            return cls(DynamicRMI.build(keys, pool=pool, **kwargs))
        return cls(ShardedDynamicIndex.build(keys, mesh, axis=axis,
                                             pool=pool, **kwargs))

    @property
    def sharded(self) -> bool:
        return isinstance(self.backend, ShardedDynamicIndex)

    # -- queries -----------------------------------------------------------
    def find(self, queries, *, path: str = "auto"):
        """(found, rank) device arrays per query — rank is the leftmost
        live rank, indexing :meth:`gather`'s key order."""
        return self.backend.find(queries, path=path)

    def find_range(self, q_lo, q_hi, *, path: str = "auto"):
        """(rank_lo, rank_hi) live ranks of the inclusive ranges
        ``[q_lo[i], q_hi[i]]`` (degenerate ranges come back empty)."""
        return self.backend.find_range(q_lo, q_hi, path=path)

    # -- mutation ----------------------------------------------------------
    def insert(self, keys) -> None:
        self.backend.insert_batch(np.atleast_1d(np.asarray(keys)))

    def delete(self, keys) -> None:
        self.backend.delete_batch(np.atleast_1d(np.asarray(keys)))

    # -- materialization ---------------------------------------------------
    def gather(self, ranks) -> np.ndarray:
        """Keys at the given live ranks (what :meth:`find` returned)."""
        return self.backend.live_keys()[np.asarray(ranks, np.int64)]

    def gather_range(self, rank_lo, rank_hi) -> list[np.ndarray]:
        """Materialize :meth:`find_range` spans as per-range sorted live
        key arrays."""
        return self.backend.gather_range(rank_lo, rank_hi)

    def live_keys(self) -> np.ndarray:
        return self.backend.live_keys()

    @property
    def live_count(self) -> int:
        return int(self.backend.total_live if self.sharded
                   else self.backend.live_count)

    # -- drift maintenance -------------------------------------------------
    def maybe_swap(self) -> int:
        """One drift-maintenance pass: bound-checked pool hot-swaps on the
        drift-latched shards (no-op without ``drift_bins``).  Returns the
        number of leaves swapped."""
        return self.backend.maybe_swap()

    def drift_scores(self) -> np.ndarray:
        """(n_shards, 2) [KS score, drifted latch] rows (single-host:
        one row).  All-zero when drift monitoring is off."""
        if self.sharded:
            return self.backend.drift_scores()
        from .core import drift as drift_mod
        return np.asarray(drift_mod.state_row(self.backend.drift))[None]

    # -- durability --------------------------------------------------------
    def snapshot(self, store, step: int = 0, *, blocking: bool = True,
                 include_pool: bool = True) -> None:
        """Write one checksummed, atomically-committed snapshot into
        ``store`` (a ``persist.SnapshotStore`` or a directory path).
        Drift-monitor state rides the snapshot."""
        st = _as_store(store)
        if self.sharded:
            persist_mod.snapshot_sharded(st, step, self.backend,
                                         blocking=blocking,
                                         include_pool=include_pool)
        else:
            persist_mod.snapshot_dynamic(st, step, self.backend,
                                         blocking=blocking,
                                         include_pool=include_pool)

    @classmethod
    def restore(cls, store, *, mesh=None, axis: str = "data",
                step: int | None = None) -> "Index":
        """Restore from the newest verifiable snapshot in ``store`` (or
        exactly ``step``).  ``mesh=None`` restores the single-host
        backend; a mesh restores (and reshards onto) the sharded one."""
        st = _as_store(store)
        if mesh is None:
            backend, _ = persist_mod.restore_dynamic(st, step=step)
        else:
            backend, _ = persist_mod.restore_sharded(st, mesh, axis,
                                                     step=step)
        return cls(backend)


def build_index(keys, **kwargs) -> Index:
    """Deprecated alias of :meth:`Index.build`."""
    warnings.warn("build_index() is deprecated; use Index.build()",
                  DeprecationWarning, stacklevel=2)
    return Index.build(keys, **kwargs)
