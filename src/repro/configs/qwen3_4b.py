"""qwen3-4b [dense] — hf:Qwen/Qwen3 family.

36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936; qk_norm; decoupled
head_dim=128 (projections 2560 -> 4096).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-4b",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=9728, vocab_size=151936, qk_norm=True,
    family="dense",
)
