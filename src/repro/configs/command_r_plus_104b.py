"""command-r-plus-104b [dense] — hf:CohereForAI (unverified tier).

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000, no biases.
Cohere-style PARALLEL blocks: attention and FFN read the same normed input
and their partial outputs share a single TP psum (also halves the per-layer
collective payload — EXPERIMENTS.md §Perf P9).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-plus-104b",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, head_dim=128,
    d_ff=33792, vocab_size=256000, parallel_block=True,
    family="dense",
)
