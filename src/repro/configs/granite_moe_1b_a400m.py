"""granite-moe-1b-a400m [moe] — hf:ibm-granite/granite-3.0-1b-a400m-base.

24L d_model=1024 16H (GQA kv=8) d_ff=512/expert vocab=49155, MoE 32e top-8.
"""
from .base import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155,
    moe=MoECfg(n_experts=32, top_k=8, d_expert=512),
    family="moe",
)
