"""Architecture configs: one module per assigned architecture + registry."""
from .base import ArchConfig, MoECfg, SHAPES, ShapeCfg, get_arch, list_archs

__all__ = ["ArchConfig", "MoECfg", "SHAPES", "ShapeCfg", "get_arch",
           "list_archs"]
