"""yi-9b [dense] — arXiv:2403.04652 (llama-arch GQA).

48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="yi-9b",
    n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=11008, vocab_size=64000,
    family="dense",
)
