"""Architecture + shape configuration.

Every assigned architecture is a frozen ArchConfig; ``pattern`` assigns a
block kind per layer ("attn" | "mamba" | "mlstm" | "slstm"), grouped into
superblocks of length ``sb`` for scan-over-layers (compile time stays
O(superblock), not O(n_layers)).

TP-16 alignment: head counts are padded up to a multiple of 16 where needed
(``n_heads_padded``), KV heads are replicated/padded to 16 slots when fewer
(``kv_sharded``/``n_kv_padded``), vocab is padded to a multiple of 16
(``vocab_padded``), expert counts padded to a multiple of 16
(``n_experts_padded``). All padding is zero-weight and is accounted in the
roofline's useful-FLOPs ratio (EXPERIMENTS.md).
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass


@dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0            # always-on shared experts (qwen2-moe)
    every: int = 1               # every k-th layer is MoE (jamba: 2)
    offset: int = 0              # first MoE layer index within the pattern


@dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class ArchConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    pattern: tuple = ()          # per-layer kinds; default all-attn
    sb: int = 0                  # superblock length (0 -> auto)
    moe: MoECfg | None = None
    qk_norm: bool = False
    qkv_bias: bool = False
    rope: str = "rope"           # "rope" | "mrope" | "none"
    rope_theta: float = 1e4
    mrope_sections: tuple = (16, 24, 24)
    embed_input: bool = False    # modality frontend stub feeds embeddings
    norm_eps: float = 1e-6
    # ssm (jamba mamba blocks)
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    # xlstm
    xl_heads: int = 4
    parallel_block: bool = False  # attn+FFN from same input, one TP psum
    tp: int = 16                 # tensor-parallel width the padding targets
    tp_shard: bool = True        # False: replicate weights across model axis
    family: str = "dense"        # dense|moe|hybrid|vlm|audio|ssm
    subquadratic: bool = False   # eligible for long_500k

    # ---- derived ---------------------------------------------------------
    def __post_init__(self):
        if not self.pattern:
            object.__setattr__(self, "pattern", ("attn",) * self.n_layers)
        assert len(self.pattern) == self.n_layers
        if self.sb == 0:
            object.__setattr__(self, "sb", self._auto_sb())
        assert self.n_layers % self.sb == 0
        # superblocks must be identical so params can stack
        p = self.pattern
        for s in range(0, self.n_layers, self.sb):
            assert p[s:s + self.sb] == p[:self.sb], "pattern not periodic"

    def _auto_sb(self) -> int:
        p = self.pattern
        for sb in range(1, self.n_layers + 1):
            if self.n_layers % sb == 0 and all(
                    p[s:s + sb] == p[:sb]
                    for s in range(0, self.n_layers, sb)):
                return sb
        return self.n_layers

    @property
    def n_sb(self) -> int:
        return self.n_layers // self.sb

    @property
    def n_heads_padded(self) -> int:
        if not self.tp_shard:
            return self.n_heads
        return -(-self.n_heads // self.tp) * self.tp

    @property
    def kv_sharded(self) -> bool:
        """KV projections are TP-sharded when there are >= tp KV heads;
        otherwise the (small) KV projection is replicated across TP and each
        rank slices its q-head group's KV head — keeps GQA weight tying
        exact under training (no duplicated weight copies)."""
        return self.tp_shard and self.n_kv_heads >= self.tp

    @property
    def n_kv_padded(self) -> int:
        if self.kv_sharded:
            return -(-self.n_kv_heads // self.tp) * self.tp
        return self.n_kv_heads

    @property
    def vocab_padded(self) -> int:
        t = self.tp if self.tp_shard else 1
        step = t * 8
        return -(-self.vocab_size // step) * step

    @property
    def n_experts_padded(self) -> int:
        if self.moe is None:
            return 0
        if not self.tp_shard:
            return self.moe.n_experts
        return -(-self.moe.n_experts // self.tp) * self.tp

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(self.d_model // 16, 1)

    def moe_at(self, pos: int) -> bool:
        """Is layer position `pos` a MoE layer? (jamba: every 2nd, offset 1)"""
        if self.moe is None or self.d_ff == 0:
            return False
        return (pos % self.moe.every) == (self.moe.offset % self.moe.every)

    # parameter count (true, unpadded) for MODEL_FLOPS
    def param_count(self, active_only: bool = False) -> int:
        d, dh = self.d_model, self.head_dim
        total = 0 if self.embed_input else self.vocab_size * d
        total += self.vocab_size * d        # lm head
        for i, kind in enumerate(self.pattern):
            if kind == "attn":
                total += d * (self.n_heads * dh) + 2 * d * (self.n_kv_heads * dh)
                total += (self.n_heads * dh) * d
                total += 2 * d               # norms
            elif kind == "mamba":
                di, ds, dtr = self.d_inner, self.d_state, self.dt_rank
                total += d * 2 * di + di * self.d_conv + \
                    di * (dtr + 2 * ds) + dtr * di + di * ds + di + di * d + d
            elif kind in ("mlstm", "slstm"):
                total += 4 * d * d + d * self.expand * d * 2 + 2 * d
            # ffn / moe
            if kind in ("attn", "mamba") and self.d_ff > 0:
                if self.moe is not None and self.moe_at(i):
                    e = self.moe.n_experts
                    k = self.moe.top_k if active_only else e
                    total += 3 * d * self.moe.d_expert * k
                    total += 3 * d * self.moe.d_expert * self.moe.n_shared
                    total += d * e           # router
                else:
                    total += 3 * d * self.d_ff
        return total


_REGISTRY = [
    "granite_moe_1b_a400m", "qwen2_moe_a2_7b", "jamba_v0_1_52b",
    "qwen1_5_4b", "command_r_plus_104b", "yi_9b", "qwen3_4b",
    "qwen2_vl_72b", "musicgen_large", "xlstm_125m",
]


def list_archs() -> list[str]:
    return list(_REGISTRY)


def get_arch(name: str) -> ArchConfig:
    mod = name.replace("-", "_").replace(".", "_")
    if mod not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {_REGISTRY}")
    return importlib.import_module(f"repro.configs.{mod}").CONFIG
