"""xlstm-125m [ssm] — arXiv:2405.04517 (unverified tier).

12L d_model=768 4 heads vocab=50304, alternating mLSTM/sLSTM blocks
(superblock = 2), no separate FFN (d_ff=0; block-internal up/down
projections, expand=2). Model is too small for 16-way tensor parallel:
weights are replicated across the model axis (tp_shard=False), only
FSDP/DP shard it — recorded in DESIGN.md §Arch-applicability.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, head_dim=192,
    d_ff=0, vocab_size=50304,
    pattern=("mlstm", "slstm") * 6, sb=2,
    xl_heads=4, expand=2, tp_shard=False, rope="none",
    family="ssm", subquadratic=True,
)
