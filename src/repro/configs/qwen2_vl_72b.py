"""qwen2-vl-72b [vlm] — arXiv:2409.12191.

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064, M-RoPE (temporal/
height/width sections 16/24/24 over head_dim/2), dynamic-resolution vision
frontend is a STUB: input_specs supplies precomputed 3-D position ids (and
patch embeddings arrive as ordinary token positions).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=29568, vocab_size=152064, rope="mrope", mrope_sections=(16, 24, 24),
    family="vlm",
)
