"""jamba-v0.1-52b [hybrid] — arXiv:2403.19887.

32L d_model=4096 32H (GQA kv=8) d_ff=14336/expert vocab=65536, MoE 16e top-2.
Mamba:attention 7:1 interleave (attention at offset 4 of each 8-layer
period), MoE every 2nd layer (offset 1). Superblock = 8 layers.
"""
from .base import ArchConfig, MoECfg

_PERIOD = ("mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba",
           "mamba")

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=65536,
    pattern=_PERIOD * 4, sb=8,
    moe=MoECfg(n_experts=16, top_k=2, d_expert=14336, every=2, offset=1),
    family="hybrid", subquadratic=True,
)
