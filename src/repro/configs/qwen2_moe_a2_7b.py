"""qwen2-moe-a2.7b [moe] — hf:Qwen/Qwen1.5-MoE-A2.7B.

24L d_model=2048 16H (GQA kv=16) d_ff=1408/expert vocab=151936,
60 routed experts top-4 + 4 shared experts. QKV bias (qwen1.5 lineage).
"""
from .base import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=151936, qkv_bias=True,
    moe=MoECfg(n_experts=60, top_k=4, d_expert=1408, n_shared=4),
    family="moe",
)
