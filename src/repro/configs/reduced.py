"""Reduced-size variants of every assigned architecture for CPU smoke tests:
same family/pattern/features, tiny dims, tp=1 (smoke mesh is (1,1,1))."""
from __future__ import annotations

import dataclasses

from .base import ArchConfig, MoECfg, get_arch


def reduce_cfg(cfg: ArchConfig, *, n_layers: int | None = None,
               d_model: int = 64, vocab: int = 256) -> ArchConfig:
    nl = n_layers or cfg.sb
    nl = max(nl, cfg.sb)
    nl = (nl // cfg.sb) * cfg.sb
    moe = None
    if cfg.moe is not None:
        moe = MoECfg(n_experts=8, top_k=min(cfg.moe.top_k, 2), d_expert=32,
                     n_shared=min(cfg.moe.n_shared, 1), every=cfg.moe.every,
                     offset=cfg.moe.offset)
    return dataclasses.replace(
        cfg,
        n_layers=nl, pattern=cfg.pattern[:nl], sb=cfg.sb,
        d_model=d_model,
        n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 2) if
        cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        d_ff=96 if cfg.d_ff else 0,
        vocab_size=vocab,
        moe=moe,
        tp=1, tp_shard=False,
        d_state=8, d_conv=4, expand=2,
        xl_heads=2,
    )


def reduced(name: str, **kw) -> ArchConfig:
    return reduce_cfg(get_arch(name), **kw)
