"""musicgen-large [audio] — arXiv:2306.05284.

48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048, decoder-only over EnCodec
tokens. Modality frontend is a STUB: input_specs provides precomputed frame
embeddings (B, S, d_model) — the four-codebook sum lives in the frontend.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=2048, embed_input=True, rope="none",
    family="audio",
)
