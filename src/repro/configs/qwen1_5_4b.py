"""qwen1.5-4b [dense] — hf:Qwen/Qwen1.5 family.

40L d_model=2560 20H (GQA kv=20) d_ff=6912 vocab=151936, QKV bias.
20 heads pad to 32 for TP-16 (zero-weight heads; counted as padding waste).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-4b",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20, head_dim=128,
    d_ff=6912, vocab_size=151936, qkv_bias=True,
    family="dense",
)
