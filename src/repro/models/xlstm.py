"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, sequential), manual-SPMD but — per the
xlstm-125m config — weights replicated across TP (tp_shard=False; the model
is far too small for 16-way tensor parallel, see DESIGN.md).

mLSTM train/prefill uses the stabilized *parallel* form through the shared
blockwise-attention machinery (exponential-gate bias terms F_q - F_k + i_k
via flash_attention's bias_qk hook, unnormalized-softmax semantics
approximated by its running max/denominator); decode is the O(1) recurrent
update of (C, n, m). sLSTM is inherently sequential: lax.scan over time.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import flash_attention, rms_norm
from .sharding import fsdp_gather, scan_aligned

Array = jax.Array
F32 = jnp.float32
BF16 = jnp.bfloat16


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
class MLSTMParams(NamedTuple):
    ln: Array        # (d,)
    w_qkv: Array     # (d, 3*NH*dh)
    w_if: Array      # (d, 2*NH)  input/forget gate projections
    b_if: Array      # (2*NH,)
    w_o: Array       # (d, NH*dh) output gate
    w_up: Array      # (d, 2*ef*d)  pre-up-projection (expand factor)
    w_down: Array    # (ef*d, d)
    ln_inner: Array  # (NH*dh,)


class MLSTMState(NamedTuple):
    c: Array         # (B, NH, dh, dh) f32
    n: Array         # (B, NH, dh) f32
    m: Array         # (B, NH) f32


def mlstm_block(p: MLSTMParams, x: Array, cfg, *, state: MLSTMState | None,
                tp_shard: bool) -> tuple:
    B, S, d = x.shape
    NH = cfg.xl_heads
    h = rms_norm(x, p.ln, cfg.norm_eps)

    # up-projection (expand 2x) with gate, xLSTM block style
    wu = fsdp_gather(p.w_up)
    up = jnp.einsum("bsd,de->bse", h, wu, preferred_element_type=F32)
    u, gate = jnp.split(up, 2, axis=-1)
    ef_d = u.shape[-1]
    dh = ef_d // NH

    # q, k, v straight from the up-projected stream
    q, k, v = jnp.split(_qkv(p, u, d), 3, axis=-1)
    q = q.reshape(B, S, NH, dh)
    k = k.reshape(B, S, NH, dh) / jnp.sqrt(dh).astype(F32)
    v = v.reshape(B, S, NH, dh)

    gif = jnp.einsum("bsd,dg->bsg", h, fsdp_gather(p.w_if),
                     preferred_element_type=F32) + p.b_if
    ig, fg = gif[..., :NH], gif[..., NH:]               # (B, S, NH)
    logf = jax.nn.log_sigmoid(fg)

    if S == 1 and state is not None:
        mn = jnp.maximum(logf[:, 0] + state.m, ig[:, 0])        # (B, NH)
        fw = jnp.exp(logf[:, 0] + state.m - mn)
        iw = jnp.exp(ig[:, 0] - mn)
        kt, vt, qt = k[:, 0], v[:, 0], q[:, 0]                  # (B,NH,dh)
        c = fw[..., None, None] * state.c + \
            iw[..., None, None] * jnp.einsum("bhk,bhv->bhkv",
                                             kt.astype(F32), vt.astype(F32))
        n = fw[..., None] * state.n + iw[..., None] * kt.astype(F32)
        num = jnp.einsum("bhk,bhkv->bhv", qt.astype(F32), c)
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", qt.astype(F32), n))
        out_h = num / jnp.maximum(den, jnp.exp(-mn))[..., None]
        new_state = MLSTMState(c=c, n=n, m=mn)
        o = out_h.reshape(B, 1, NH * dh)
    else:
        # parallel form: blockwise attention with gate bias terms
        F_cum = jnp.cumsum(logf, axis=1)                        # (B, S, NH)
        bias_q = F_cum                                           # F_t
        bias_k = ig - F_cum                                      # i_s - F_s
        o = flash_attention(q.astype(BF16), k.astype(BF16), v.astype(BF16),
                            q_offset=jnp.zeros((), jnp.int32),
                            bias_qk=(bias_q, bias_k))
        o = o.reshape(B, S, NH * dh)
        if state is not None:
            # prefill: materialize the final recurrent state so decode can
            # continue.  C_S = sum_s exp(F_S - F_s + i_s - m) k_s v_s^T
            wlog = (F_cum[:, -1:, :] - F_cum + ig)              # (B,S,NH)
            m_fin = wlog.max(1)                                 # (B,NH)
            wts = jnp.exp(wlog - m_fin[:, None, :])
            c = jnp.einsum("bsh,bshk,bshv->bhkv", wts, k.astype(F32),
                           v.astype(F32))
            n = jnp.einsum("bsh,bshk->bhk", wts, k.astype(F32))
            new_state = MLSTMState(c=c, n=n, m=m_fin)
        else:
            new_state = None

    o = rms_norm(o, p.ln_inner, cfg.norm_eps)
    og = jnp.einsum("bsd,de->bse", h, fsdp_gather(p.w_o),
                    preferred_element_type=F32)
    o = o * jax.nn.sigmoid(og)
    y = o.astype(F32) * jax.nn.silu(gate)
    wd = fsdp_gather(p.w_down, axis=1)   # (ef, d): FSDP on d
    out = jnp.einsum("bse,ed->bsd", y.astype(BF16), wd,
                     preferred_element_type=F32)
    return out.astype(x.dtype), new_state


def _qkv(p: MLSTMParams, u: Array, d: int) -> Array:
    wqkv = fsdp_gather(p.w_qkv)
    return jnp.einsum("bse,ef->bsf", u.astype(BF16), wqkv,
                      preferred_element_type=F32)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
class SLSTMParams(NamedTuple):
    ln: Array        # (d,)
    w_x: Array       # (d, 4*NH*dh)  gates i,f,z,o from input
    r_h: Array       # (NH, dh, 4*dh) block-diagonal recurrent weights
    b: Array         # (4*NH*dh,)
    w_up: Array      # (d_head_total -> ffn) (d, ff)
    w_down: Array    # (ff, d)
    ln_ff: Array     # (d,)


class SLSTMState(NamedTuple):
    h: Array         # (B, NH, dh) f32
    c: Array
    n: Array
    m: Array         # (B, NH, dh)


def slstm_block(p: SLSTMParams, x: Array, cfg, *, state: SLSTMState | None,
                tp_shard: bool) -> tuple:
    B, S, d = x.shape
    NH = cfg.xl_heads
    dh = d // NH
    xin = rms_norm(x, p.ln, cfg.norm_eps)
    wx = fsdp_gather(p.w_x)
    gx = jnp.einsum("bsd,dg->bsg", xin, wx,
                    preferred_element_type=F32) + p.b    # (B,S,4*NH*dh)
    gx = gx.reshape(B, S, NH, 4 * dh)

    if state is None:
        z = jnp.zeros((B, NH, dh), F32)
        st = SLSTMState(h=z, c=z, n=z + 1e-6, m=z)
    else:
        st = state

    def step(st, gxt):
        rec = jnp.einsum("bhd,hdg->bhg", st.h, p.r_h.astype(F32))
        g = gxt + rec                                    # (B, NH, 4*dh)
        gi, gf, gz, go = jnp.split(g, 4, axis=-1)
        mn = jnp.maximum(gf + st.m, gi)                  # exp-gate stabilizer
        i_ = jnp.exp(gi - mn)
        f_ = jnp.exp(gf + st.m - mn)
        c = f_ * st.c + i_ * jnp.tanh(gz)
        n = f_ * st.n + i_
        h = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1e-6)
        return SLSTMState(h=h, c=c, n=n, m=mn), h

    if S == 1:
        new_st, h = step(st, gx[:, 0])
        hs = h[:, None]
    else:
        new_st, hs = scan_aligned(step, st,
                                  gx.transpose(1, 0, 2, 3))
        hs = hs.transpose(1, 0, 2, 3)                    # (B,S,NH,dh)
    hs = hs.reshape(B, S, d)

    # small gated FFN (proj factor 4/3-ish via cfg-independent 2x here)
    hf = rms_norm(hs.astype(x.dtype), p.ln_ff, cfg.norm_eps)
    wu = fsdp_gather(p.w_up)
    wd = fsdp_gather(p.w_down, axis=1)   # (ef, d): FSDP on d
    ff = jnp.einsum("bsd,df->bsf", hf, wu, preferred_element_type=F32)
    ff = jax.nn.silu(ff).astype(BF16)
    out = jnp.einsum("bsf,fd->bsd", ff, wd, preferred_element_type=F32)
    return (hs.astype(F32) + out).astype(x.dtype), \
        (new_st if state is not None or S == 1 else None)
