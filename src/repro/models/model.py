"""Unified block-pattern decoder covering all 10 assigned architectures.

One parameter *factory* (`build_tree`) is the single source of truth for
shapes, shardings and initializers: it is instantiated three ways —
  init_params(cfg, key)   -> real arrays (smoke tests / examples)
  param_specs(cfg)        -> PartitionSpec tree (shard_map in_specs)
  param_shapes(cfg)       -> ShapeDtypeStructs (dry-run lowering, no alloc)

Forward modes:
  "train"    full sequence, loss-ready hidden states
  "prefill"  full sequence + KV/SSM caches out, last-position logits
  "decode"   single token step against caches

The layer stack is an lax.scan over stacked superblocks (params stacked on
axis 0), with the superblock body optionally jax.checkpoint'd (train remat).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import layers, ssm, xlstm
from .sharding import (FSDP, TP, batch_axes, fsdp_gather, psum_forced,
                       scan_aligned, tp_psum)

Array = jax.Array
F32 = jnp.float32
BF16 = jnp.bfloat16


# ---------------------------------------------------------------------------
# parameter factory
# ---------------------------------------------------------------------------
class Leaf(NamedTuple):
    shape: tuple
    spec: tuple          # PartitionSpec entries (pre-stacking)
    fan_in: int          # for init scaling (0 -> zeros, -1 -> ones)


def _block_leaves(cfg, kind: str, pos: int) -> dict:
    d, dh = cfg.d_model, cfg.head_dim
    tp = TP if cfg.tp_shard else None
    out: dict[str, Any] = {}
    if kind == "attn":
        Hp = cfg.n_heads_padded
        KV = cfg.n_kv_padded
        kv_spec = tp if cfg.kv_sharded else None
        out["core"] = layers.AttnParams(
            ln=Leaf((d,), (None,), -1),
            wq=Leaf((d, Hp * dh), (FSDP, tp), d),
            wk=Leaf((d, KV * dh), (FSDP, kv_spec), d),
            wv=Leaf((d, KV * dh), (FSDP, kv_spec), d),
            wo=Leaf((Hp * dh, d), (tp, FSDP), Hp * dh),
            bq=Leaf((Hp * dh,), (tp,), 0) if cfg.qkv_bias else None,
            bk=Leaf((KV * dh,), (kv_spec,), 0) if cfg.qkv_bias else None,
            bv=Leaf((KV * dh,), (kv_spec,), 0) if cfg.qkv_bias else None,
            qn=Leaf((dh,), (None,), -1) if cfg.qk_norm else None,
            kn=Leaf((dh,), (None,), -1) if cfg.qk_norm else None,
        )
    elif kind == "mamba":
        di, ds, dtr, K = cfg.d_inner, cfg.d_state, cfg.dt_rank, cfg.d_conv
        out["core"] = ssm.MambaParams(
            ln=Leaf((d,), (None,), -1),
            in_proj=Leaf((d, 2 * di), (FSDP, tp), d),
            conv_w=Leaf((K, di), (None, tp), K),
            conv_b=Leaf((di,), (tp,), 0),
            x_proj=Leaf((di, dtr + 2 * ds), (tp, None), di),
            dt_w=Leaf((dtr, di), (None, tp), dtr),
            dt_b=Leaf((di,), (tp,), 0),
            a_log=Leaf((di, ds), (tp, None), -1),
            d_skip=Leaf((di,), (tp,), -1),
            out_proj=Leaf((di, d), (tp, FSDP), di),
        )
    elif kind == "mlstm":
        NH = cfg.xl_heads
        ef = cfg.expand * d
        out["core"] = xlstm.MLSTMParams(
            ln=Leaf((d,), (None,), -1),
            w_qkv=Leaf((ef, 3 * ef), (FSDP, tp), ef),
            w_if=Leaf((d, 2 * NH), (FSDP, None), d),
            b_if=Leaf((2 * NH,), (None,), 0),
            w_o=Leaf((d, ef), (FSDP, tp), d),
            w_up=Leaf((d, 2 * ef), (FSDP, tp), d),
            w_down=Leaf((ef, d), (tp, FSDP), ef),
            ln_inner=Leaf((ef,), (None,), -1),
        )
    elif kind == "slstm":
        NH = cfg.xl_heads
        dh_s = d // NH
        out["core"] = xlstm.SLSTMParams(
            ln=Leaf((d,), (None,), -1),
            w_x=Leaf((d, 4 * NH * dh_s), (FSDP, tp), d),
            r_h=Leaf((NH, dh_s, 4 * dh_s), (None, None, None), dh_s),
            b=Leaf((4 * NH * dh_s,), (None,), 0),
            w_up=Leaf((d, cfg.expand * d), (FSDP, tp), d),
            w_down=Leaf((cfg.expand * d, d), (tp, FSDP), cfg.expand * d),
            ln_ff=Leaf((d,), (None,), -1),
        )
    else:
        raise ValueError(kind)

    # FFN stage (attn/mamba layers only; xlstm blocks carry their own)
    if kind in ("attn", "mamba") and cfg.d_ff > 0:
        tpn = tp
        if cfg.moe_at(pos):
            mc = cfg.moe
            fe = mc.d_expert
            out["ffn"] = layers.MoEParams(
                ln=Leaf((d,), (None,), -1),
                router=Leaf((d, mc.n_experts), (FSDP, None), d),
                w_gate=Leaf((cfg.n_experts_padded, d, fe), (tpn, FSDP, None), d),
                w_up=Leaf((cfg.n_experts_padded, d, fe), (tpn, FSDP, None), d),
                w_down=Leaf((cfg.n_experts_padded, fe, d), (tpn, None, FSDP), fe),
                sh_gate=(Leaf((d, mc.n_shared * fe), (FSDP, tpn), d)
                         if mc.n_shared else None),
                sh_up=(Leaf((d, mc.n_shared * fe), (FSDP, tpn), d)
                       if mc.n_shared else None),
                sh_down=(Leaf((mc.n_shared * fe, d), (tpn, FSDP),
                              mc.n_shared * fe) if mc.n_shared else None),
            )
        else:
            out["ffn"] = layers.MLPParams(
                ln=Leaf((d,), (None,), -1),
                w_gate=Leaf((d, cfg.d_ff), (FSDP, tpn), d),
                w_up=Leaf((d, cfg.d_ff), (FSDP, tpn), d),
                w_down=Leaf((cfg.d_ff, d), (tpn, FSDP), cfg.d_ff),
            )
    else:
        out["ffn"] = None
    return out


def build_tree(cfg) -> dict:
    """Leaf-description tree (pre-stacking; superblock leaves get an n_sb
    stacking axis added by the instantiators)."""
    d = cfg.d_model
    tp = TP if cfg.tp_shard else None
    tree: dict[str, Any] = {}
    if not cfg.embed_input:
        tree["embed"] = Leaf((cfg.vocab_padded, d), (tp, FSDP), d)
    tree["sb"] = {f"pos{i}": _block_leaves(cfg, cfg.pattern[i], i)
                  for i in range(cfg.sb)}
    tree["final_ln"] = Leaf((d,), (None,), -1)
    tree["lm_head"] = Leaf((d, cfg.vocab_padded), (FSDP, tp), d)
    return tree


def _is_leaf(x):
    return isinstance(x, Leaf)


def _instantiate(cfg, fn: Callable[[Leaf, bool], Any]) -> dict:
    """fn(leaf, stacked) -> instantiated leaf."""
    tree = build_tree(cfg)
    out = {k: jax.tree.map(lambda l: fn(l, False), v, is_leaf=_is_leaf)
           for k, v in tree.items() if k != "sb"}
    out["sb"] = jax.tree.map(lambda l: fn(l, True), tree["sb"],
                             is_leaf=_is_leaf)
    return out


def param_specs(cfg) -> dict:
    def f(l: Leaf, stacked: bool):
        spec = ((None,) if stacked else ()) + l.spec
        return P(*spec)
    return _instantiate(cfg, f)


def param_shapes(cfg, dtype=BF16) -> dict:
    def f(l: Leaf, stacked: bool):
        shape = ((cfg.n_sb,) if stacked else ()) + l.shape
        return jax.ShapeDtypeStruct(shape, dtype)
    return _instantiate(cfg, f)


def init_params(cfg, key: Array, dtype=BF16) -> dict:
    leaves = jax.tree.leaves(build_tree(cfg), is_leaf=_is_leaf)
    keys = iter(jax.random.split(key, len(leaves) + 1))

    def f(l: Leaf, stacked: bool):
        shape = ((cfg.n_sb,) if stacked else ()) + l.shape
        if l.fan_in == 0:
            return jnp.zeros(shape, dtype)
        if l.fan_in == -1:
            return jnp.ones(shape, dtype)
        w = jax.random.normal(next(keys), shape, F32) / jnp.sqrt(l.fan_in)
        return w.astype(dtype)
    return _instantiate(cfg, f)


def param_sync_axes(cfg) -> dict:
    """Per-leaf comma-joined mesh axes the leaf is *replicated* over
    (gradients need an explicit psum over exactly these; layers.sync_grad).
    Strings, not tuples, so the tree zips with the param tree under
    jax.tree.map (tuples would be traversed as pytree nodes)."""
    def f(l: Leaf, stacked: bool):
        present = {a for a in l.spec if a}
        return ",".join(a for a in ("pod", "data", "model")
                        if a not in present)
    return _instantiate(cfg, f)


# ---------------------------------------------------------------------------
# caches / recurrent state
# ---------------------------------------------------------------------------
def init_cache(cfg, batch: int, max_seq: int, *, seq_shard: int = 1,
               shapes_only: bool = False, local: bool = True) -> dict:
    """Decode-state tree, stacked over superblocks. ``seq_shard`` > 1 splits
    the KV time axis across the data axis (long_500k flash-decode).
    ``local=False`` builds GLOBAL shapes (dry-run lowering: the TP-sharded
    dims carry the full padded extent; shard_map splits them)."""
    mk = (jax.ShapeDtypeStruct if shapes_only
          else (lambda s, d: jnp.zeros(s, d)))
    tp = cfg.tp if (cfg.tp_shard and local) else 1
    out = {}
    for i in range(cfg.sb):
        kind = cfg.pattern[i]
        n_sb = cfg.n_sb
        if kind == "attn":
            if cfg.kv_sharded:
                KVl = cfg.n_kv_padded // tp
            elif cfg.tp_shard:
                # replicated-KV GQA: each rank stores its group's one head
                KVl = 1 if local else cfg.tp
            else:
                KVl = cfg.n_kv_heads
            s_local = max_seq // seq_shard
            out[f"pos{i}"] = {
                "k": mk((n_sb, batch, s_local, KVl, cfg.head_dim), BF16),
                "v": mk((n_sb, batch, s_local, KVl, cfg.head_dim), BF16),
            }
        elif kind == "mamba":
            di_l = cfg.d_inner // tp
            out[f"pos{i}"] = {
                "conv": mk((n_sb, batch, cfg.d_conv - 1, di_l), BF16),
                "h": mk((n_sb, batch, di_l, cfg.d_state), F32),
            }
        elif kind == "mlstm":
            NH = cfg.xl_heads
            dh = cfg.expand * cfg.d_model // NH
            out[f"pos{i}"] = {
                "c": mk((n_sb, batch, NH, dh, dh), F32),
                "n": mk((n_sb, batch, NH, dh), F32),
                "m": mk((n_sb, batch, NH), F32),
            }
        elif kind == "slstm":
            NH = cfg.xl_heads
            dh = cfg.d_model // NH
            z = (n_sb, batch, NH, dh)
            out[f"pos{i}"] = {k: mk(z, F32) for k in ("h", "c", "n", "m")}
    return out


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def embed_tokens(params, cfg, tokens: Array, tp_shard: bool) -> Array:
    """Vocab-sharded embedding lookup: local-range take + psum over TP."""
    w = fsdp_gather(params["embed"], axis=1)            # (V_l, d)
    V_l = w.shape[0]
    base = (jax.lax.axis_index(TP) * V_l) if tp_shard else 0
    local = tokens - base
    ok = (local >= 0) & (local < V_l)
    x = jnp.take(w, jnp.clip(local, 0, V_l - 1), axis=0)
    x = jnp.where(ok[..., None], x, 0)
    if tp_shard:
        x = tp_psum(x.astype(F32)).astype(BF16)
    return x


def _run_block(cfg, pos_idx: int, kind: str, blk_params, x, *, pos, cache,
               tp_shard):
    new_cache = None
    if kind == "attn" and cfg.parallel_block and \
            isinstance(blk_params.get("ffn"), layers.MLPParams):
        # Cohere-style parallel block: attn and FFN partials share one psum
        o, new_cache = layers.attention_block(
            blk_params["core"], x, cfg, pos=pos, cache=cache,
            tp_shard=tp_shard, reduce=False)
        m = layers.mlp_block(blk_params["ffn"], x, cfg, tp_shard=tp_shard,
                             reduce=False)
        comb = o + m
        if tp_shard:
            comb = layers.tp_psum(comb)
        return x + comb.astype(x.dtype), new_cache
    if kind == "attn":
        o, new_cache = layers.attention_block(
            blk_params["core"], x, cfg, pos=pos, cache=cache,
            tp_shard=tp_shard)
        x = x + o
    elif kind == "mamba":
        st = ssm.MambaState(**cache) if cache is not None else None
        o, nst = ssm.mamba_block(blk_params["core"], x, cfg, state=st,
                                 tp_shard=tp_shard)
        x = x + o
        if nst is not None:
            new_cache = nst._asdict()
    elif kind == "mlstm":
        st = xlstm.MLSTMState(**cache) if cache is not None else None
        o, nst = xlstm.mlstm_block(blk_params["core"], x, cfg, state=st,
                                   tp_shard=tp_shard)
        x = x + o
        if nst is not None and cache is not None:
            new_cache = nst._asdict()
    elif kind == "slstm":
        st = xlstm.SLSTMState(**cache) if cache is not None else None
        o, nst = xlstm.slstm_block(blk_params["core"], x, cfg, state=st,
                                   tp_shard=tp_shard)
        x = o  # slstm block returns residual-included
        if nst is not None and cache is not None:
            new_cache = nst._asdict()
    if blk_params.get("ffn") is not None:
        if isinstance(blk_params["ffn"], layers.MoEParams):
            x = x + layers.moe_block(blk_params["ffn"], x, cfg,
                                     tp_shard=tp_shard)
        else:
            x = x + layers.mlp_block(blk_params["ffn"], x, cfg,
                                     tp_shard=tp_shard)
    return x, new_cache


def forward(params, cfg, inputs: Array, *, pos, caches=None,
            mode: str = "train", remat: bool = True, cache_len=None,
            seq_sharded: bool = False):
    """inputs: token ids (B, S) or embeddings (B, S, d) for embed_input
    archs. pos: (B, S) positions (or (3, B, S) for mrope). ``cache_len``:
    scalar filled-prefix length of the caches (decode/prefill-continue).
    Returns (hidden (B,S,d), new_caches)."""
    tp_shard = cfg.tp_shard
    if cfg.embed_input:
        x = inputs.astype(BF16)
    else:
        x = embed_tokens(params, cfg, inputs, tp_shard)

    decode = mode == "decode"

    def superblock(x, sb_args):
        p_sb, cache_sb = sb_args
        new_caches = {}
        for i in range(cfg.sb):
            kind = cfg.pattern[i]
            c = cache_sb.get(f"pos{i}") if cache_sb is not None else None
            if c is not None and kind == "attn":
                c = dict(c, length=cache_len, seq_sharded=seq_sharded)
            x, nc = _run_block(cfg, i, kind, p_sb[f"pos{i}"], x,
                               pos=pos, cache=c, tp_shard=tp_shard)
            if nc is not None:
                nc.pop("length", None)
                new_caches[f"pos{i}"] = nc
        return x, (new_caches if new_caches else None)

    if decode:
        if cache_len is None:
            cache_len = pos.reshape(-1)[0]
        cache_len = jnp.asarray(cache_len, jnp.int32)
        if cfg.rope != "mrope":
            pos = jnp.broadcast_to(cache_len, inputs.shape[:2])
    elif caches is not None:           # prefill into fresh caches
        cache_len = jnp.zeros((), jnp.int32)

    body = superblock
    if remat and mode == "train":
        body = jax.checkpoint(superblock, prevent_cse=False)

    if caches is None:
        x, _ = scan_aligned(lambda c, p: body(c, (p, None)), x, params["sb"])
        new_caches = None
    else:
        x, new_caches = scan_aligned(lambda c, a: body(c, a), x,
                                     (params["sb"], caches))
    return x, new_caches


def lm_logits(params, cfg, x: Array, tp_shard: bool) -> Array:
    """(B, S, V_local) logits (TP-sharded on vocab)."""
    h = layers.rms_norm(x, params["final_ln"], cfg.norm_eps)
    w = fsdp_gather(params["lm_head"])                   # (d, V_l)
    return jnp.einsum("bsd,dv->bsv", h, w, preferred_element_type=F32)


def lm_loss(params, cfg, x: Array, labels: Array, tp_shard: bool,
            seq_chunk: int = 512) -> Array:
    """Mean cross-entropy with vocab TP-sharded; seq-chunked so the full
    (B, S, V) logits tensor never materializes."""
    B, S, d = x.shape
    h = layers.rms_norm(x, params["final_ln"], cfg.norm_eps)
    w = fsdp_gather(params["lm_head"])                   # (d, V_l)
    V_l = w.shape[1]
    base = (jax.lax.axis_index(TP) * V_l) if tp_shard else 0
    ch = min(seq_chunk, S)
    nch = -(-S // ch)
    pad = nch * ch - S
    hp = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    hp = hp.reshape(B, nch, ch, d).transpose(1, 0, 2, 3)
    lp = lp.reshape(B, nch, ch).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_loss(carry, args):
        # rematerialized: without this the backward saves each chunk's full
        # (B, ch, V_local) f32 logits/exp residuals — 13 GB/chip on the
        # vocab-unsharded xlstm cell (EXPERIMENTS.md §Perf P6)
        hc, lc = args
        logits = jnp.einsum("bsd,dv->bsv", hc, w,
                            preferred_element_type=F32)
        # stability offset only; exact under stop_gradient (cancels in lse).
        # stop_gradient BEFORE pmax: pmax has no differentiation rule, and
        # with a symbolic-zero tangent it is never asked for one.
        mx = jax.lax.stop_gradient(logits.max(-1))
        if tp_shard:
            mx = jax.lax.pmax(mx, TP)
        lse = jnp.exp(logits - mx[..., None]).sum(-1)
        if tp_shard:
            lse = tp_psum(lse)
        lse = jnp.log(lse) + mx
        loc = lc - base
        ok = (loc >= 0) & (loc < V_l)
        true_logit = jnp.take_along_axis(
            logits, jnp.clip(loc, 0, V_l - 1)[..., None], -1)[..., 0]
        true_logit = jnp.where(ok, true_logit, 0.0)
        if tp_shard:
            true_logit = tp_psum(true_logit)
        valid = (lc >= 0).astype(F32)
        nll = (lse - true_logit) * valid
        return (carry[0] + nll.sum(), carry[1] + valid.sum()), None

    (tot, cnt), _ = scan_aligned(
        chunk_loss, (jnp.zeros((), F32), jnp.zeros((), F32)), (hp, lp))
    # aggregate across the batch-sharded axes
    tot = psum_forced(tot, batch_axes())
    cnt = psum_forced(cnt, batch_axes())
    return tot / jnp.maximum(cnt, 1.0)
