"""Sharding contract for the manual-SPMD model code.

Mesh axes (DESIGN.md §4):
  pod    — pure data parallel across pods (gradient psum)
  data   — batch shard + FSDP/ZeRO-3 parameter shard
  model  — tensor parallel (heads / d_ff / vocab / experts)

Model code always runs under shard_map with all three axes bound; a
single-device smoke test uses a (1,1,1) mesh so the same collectives become
no-ops. Conventions:
  * every weight leaf carries FSDP on the axis named by its spec; the
    gather helper materializes the full weight just-in-time (backward
    auto-transposes to psum_scatter => ZeRO gradient reduction for free)
  * activations are replicated across `model` between blocks; each block
    ends in exactly one psum over `model`
  * the batch dim is sharded over ("pod", "data")
"""
from __future__ import annotations

import enum
import inspect

import jax
import jax.numpy as jnp

POD, FSDP, TP = "pod", "data", "model"


# ---------------------------------------------------------------------------
# jax 0.4.x compat shim.
#
# The pinned jax (0.4.37) predates several APIs this codebase targets:
#   * jax.shard_map            (only jax.experimental.shard_map, check_rep)
#   * jax.sharding.AxisType / jax.make_mesh(axis_types=...)  (explicit meshes)
#   * jax.typeof(...).vma + jax.lax.pcast  (varying-manual-axes typing)
#   * jax.lax.axis_size
#
# Installed once at import (repro/__init__ imports this module). On the old
# API there is no vma type system, so pcast degrades to identity and
# shard_map runs with check_rep=False — the collectives in this codebase are
# all explicit, so forward/backward semantics are unchanged; only the static
# replication checking is lost.
# ---------------------------------------------------------------------------
class _PlainAval:
    vma: frozenset = frozenset()


def _install_jax_compat() -> None:
    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _esm

        def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kw):
            return _esm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                        check_rep=False)

        jax.shard_map = shard_map
    if not hasattr(jax.sharding, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType
    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        _make_mesh = jax.make_mesh

        def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
            return _make_mesh(axis_shapes, axis_names, **kw)

        jax.make_mesh = make_mesh
    if not hasattr(jax, "typeof"):
        jax.typeof = lambda v: _PlainAval
    if not hasattr(jax.lax, "pcast"):
        jax.lax.pcast = lambda x, axes, to=None: x
    if not hasattr(jax.lax, "axis_size"):
        jax.lax.axis_size = lambda name: jax.lax.psum(
            jnp.ones((), jnp.int32), name)


_install_jax_compat()

# Batch-carrying axes. The production single-pod mesh is (data, model) with
# no pod axis, so this is configured per step-factory (set_batch_axes runs
# again inside each step_fn, i.e. at trace time, making the psums correct
# for whichever mesh the enclosing shard_map binds).
_BATCH_AXES = ("pod", "data")


def set_batch_axes(axes) -> None:
    global _BATCH_AXES
    _BATCH_AXES = tuple(axes)


def batch_axes() -> tuple:
    return _BATCH_AXES


def batch_axes_for(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


_FSDP_GATHER_ON = True     # serve-replicated mode turns JIT gathers off
_PSUM_DTYPE = None         # hillclimb lever: bf16 block-output psums


def set_fsdp_gather(on: bool) -> None:
    """serve-replicated mode: weights arrive full per chip (no data-axis
    shard), so the JIT gather must become identity. Trace-time global,
    set inside each step_fn like set_batch_axes."""
    global _FSDP_GATHER_ON
    _FSDP_GATHER_ON = on


def set_psum_dtype(dtype) -> None:
    """Cast block outputs to `dtype` (e.g. bf16) before the TP psum —
    halves the dominant all-reduce payload (EXPERIMENTS.md §Perf)."""
    global _PSUM_DTYPE
    _PSUM_DTYPE = dtype


_MESH_AXES = ("pod", "data", "model")


def set_mesh_axes(axes) -> None:
    """Trace-time: the axis names bound by the enclosing shard_map (set by
    every step factory, like set_batch_axes)."""
    global _MESH_AXES
    _MESH_AXES = tuple(axes)


def pvary_all(x):
    """Mark a value as varying over every bound mesh axis it is not varying
    on yet — vma alignment for scan carries under check_vma=True
    (numerically a no-op)."""
    def one(v):
        vma = jax.typeof(v).vma
        missing = tuple(a for a in _MESH_AXES if a not in vma)
        return jax.lax.pcast(v, missing, to="varying") if missing else v
    return jax.tree.map(one, x)


def scan_aligned(body, init, xs, length=None):
    """lax.scan whose initial carry is pcast to the body's NATURAL output
    vma (found by abstract evaluation). Over-varying the carry (e.g. a
    blanket pvary over all axes) is numerically a no-op forward but poisons
    the backward: implicit invariant->varying promotions inside the body
    transpose to psums, silently scaling gradients by axis sizes
    (tests/test_multidevice.py::test_spmd_numeric_equivalence guards this).
    """
    x0 = None if xs is None else jax.tree.map(lambda a: a[0], xs)

    def align(c, av):
        want = getattr(av, "vma", None) or frozenset()
        have = jax.typeof(c).vma or frozenset()
        missing = tuple(a for a in want if a not in have)
        return jax.lax.pcast(c, missing, to="varying") if missing else c

    for _ in range(2):  # vma grows monotonically; 2 rounds reach fixpoint
        out_sh = jax.eval_shape(lambda c, x: body(c, x)[0], init, x0)
        init = jax.tree.map(align, init, out_sh)
    return jax.lax.scan(body, init, xs, length=length)


def psum_forced(x, axes):
    """psum over `axes`, first marking x varying on any of them it is typed
    invariant on. For genuinely-replicated values this MULTIPLIES by the
    axis size — callers use it only where the value is either truly varying
    or the axis is degenerate (size 1 / weighted out, e.g. grad-norm
    accounting with repl_w)."""
    def one(v):
        missing = tuple(a for a in axes if a not in jax.typeof(v).vma)
        v = jax.lax.pcast(v, missing, to="varying") if missing else v
        return jax.lax.psum(v, axes)
    return jax.tree.map(one, x)


def unvary(x, keep=()):
    """Re-mark a value as replicated over every axis it is typed varying on
    (except `keep`). Implemented as pmax — the numeric identity for values
    that are already replicated — so shard_map out_specs like P() type-check
    under check_vma=True."""
    def one(v):
        axes = tuple(a for a in jax.typeof(v).vma if a not in keep)
        return jax.lax.pmax(v, axes) if axes else v
    return jax.tree.map(one, x)


def fsdp_gather(w: jax.Array, axis: int = 0) -> jax.Array:
    """Materialize the FSDP-sharded dim of a weight (ZeRO-3 just-in-time
    gather). Transpose under grad = psum_scatter over `data`."""
    if not _FSDP_GATHER_ON:
        return w
    return jax.lax.all_gather(w, FSDP, axis=axis, tiled=True)


def tp_psum(x: jax.Array) -> jax.Array:
    if _PSUM_DTYPE is not None:
        return jax.lax.psum(x.astype(_PSUM_DTYPE), TP)
    return jax.lax.psum(x, TP)


def tp_index() -> jax.Array:
    return jax.lax.axis_index(TP)


def axis_size(name: str) -> int:
    return jax.lax.axis_size(name)


def dp_psum(x: jax.Array) -> jax.Array:
    """Reduction over every batch-carrying axis (loss/metric aggregation)."""
    return jax.lax.psum(x, batch_axes())


def pod_psum(x: jax.Array) -> jax.Array:
    return jax.lax.psum(x, POD)
