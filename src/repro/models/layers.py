"""Shared transformer layers, written manual-SPMD (axis names bound by
shard_map; see models/sharding.py for the contract).

Numerics: params/activations bf16, accumulations f32
(preferred_element_type), norms/softmax in f32.

Gradient correctness under manual SPMD: we rely on shard_map's
check_vma=True varying-manual-axes system — psum transposes are inserted
exactly where replication demands them, so replicated-parameter gradients
arrive globally summed with NO manual sync (validated by
tests/test_multidevice.py::test_spmd_numeric_equivalence; a manual
sync_grad double-counts). The one obligation on this code is vma hygiene:
scan carries must be pcast to the body's natural vma
(sharding.scan_aligned) — over-varying a carry silently scales gradients
by mesh-axis sizes. ``sync_grad`` is kept only as a reference utility.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .sharding import FSDP, TP, fsdp_gather, scan_aligned, tp_psum

Array = jax.Array
F32 = jnp.float32
BF16 = jnp.bfloat16


# ---------------------------------------------------------------------------
# grad sync for replicated params (manual-SPMD correctness)
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def sync_grad(x: Array, axes: tuple) -> Array:
    return x


def _sync_fwd(x, axes):
    return x, None


def _sync_bwd(axes, _, g):
    return (jax.lax.psum(g, axes) if axes else g,)


sync_grad.defvjp(_sync_fwd, _sync_bwd)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rms_norm(x: Array, scale: Array, eps: float) -> Array:
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=F32) / head_dim))


def apply_rope(x: Array, pos: Array, theta: float) -> Array:
    """x: (B, S, H, dh); pos: (B, S) int32. Half-split (NeoX) rotation."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    ang = pos[..., None].astype(F32) * freqs            # (B, S, dh/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_mrope(x: Array, pos3: Array, theta: float, sections: tuple) -> Array:
    """M-RoPE (qwen2-vl): pos3 (3, B, S) = (t, h, w) ids; the dh/2 frequency
    slots are split into `sections` groups, each rotated by its own id."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    # section id per frequency slot
    sec = jnp.repeat(jnp.arange(3), jnp.asarray(sections),
                     total_repeat_length=dh // 2)       # (dh/2,)
    pos = pos3.astype(F32)[sec, :, :]                   # (dh/2, B, S)
    ang = jnp.moveaxis(pos, 0, -1) * freqs              # (B, S, dh/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# blockwise (flash-style) attention — O(block) memory, causal, GQA
# ---------------------------------------------------------------------------
def flash_attention(q: Array, k: Array, v: Array, *, q_offset: Array,
                    kv_valid: Array | None = None, kv_block: int = 1024,
                    bias_qk: tuple | None = None,
                    return_partial: bool = False) -> Array:
    """q: (B, Sq, H, dh); k/v: (B, Skv, Hkv, dh) with H % Hkv == 0.

    Online-softmax over kv blocks (lax.scan -> one compiled block body).
    Causal mask uses global positions (q_offset for decode); ``kv_valid``
    masks an under-filled cache. ``bias_qk`` optionally supplies additive
    (per-query, per-key) head-wise bias terms (Fq, Fk+i) for the mLSTM
    reuse of this machinery.
    """
    B, Sq, H, dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = 1.0 / jnp.sqrt(dh).astype(F32)
    kv_block = min(kv_block, -(-Skv // 128) * 128)
    nb = -(-Skv // kv_block)
    pad = nb * kv_block - Skv
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    if bias_qk is not None:
        bias_qk = (bias_qk[0],
                   jnp.pad(bias_qk[1], ((0, 0), (0, pad), (0, 0)),
                           constant_values=0.0))
    if kv_valid is None and pad:
        kv_valid = jnp.asarray(Skv, jnp.int32)   # mask tail padding

    qf = q.astype(F32) * scale
    q_pos = q_offset + jnp.arange(Sq)

    def body(carry, start):
        # each block is dynamic-sliced from the (padded) KV — scanning over
        # a transposed copy instead moves the WHOLE cache through HBM every
        # decode step (EXPERIMENTS.md §Perf P10)
        m, l, acc = carry
        kb = jax.lax.dynamic_slice_in_dim(kp, start, kv_block, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(vp, start, kv_block, axis=1)
        kb = jnp.repeat(kb, G, axis=2)                  # GQA broadcast
        vb = jnp.repeat(vb, G, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kb.astype(F32))
        kv_pos = start + jnp.arange(kv_block)
        mask = kv_pos[None, :] <= q_pos[:, None]        # causal
        if kv_valid is not None:
            mask &= (kv_pos < kv_valid)[None, :]
        if bias_qk is not None:
            fq, fk = bias_qk                            # (B,Sq,H), (B,Skv,H)
            fkb = jax.lax.dynamic_slice_in_dim(fk, start, kv_block, 1)
            s = s + fq.transpose(0, 2, 1)[:, :, :, None] \
                  + fkb.transpose(0, 2, 1)[:, :, None, :]
        s = jnp.where(mask[None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(-1))
        # guard: fully-masked rows keep m finite
        m_new = jnp.maximum(m_new, -1e30)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vb.astype(F32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), -1e30, F32)
    l0 = jnp.zeros((B, H, Sq), F32)
    a0 = jnp.zeros((B, H, Sq, dh), F32)
    starts = jnp.arange(nb) * kv_block
    (m, l, acc), _ = scan_aligned(body, (m0, l0, a0), starts)
    if return_partial:
        return m, l, acc                                # combine across shards
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)    # (B, Sq, H, dh)


# ---------------------------------------------------------------------------
# attention block (GQA + optional qk_norm / bias / rope kinds)
# ---------------------------------------------------------------------------
class AttnParams(NamedTuple):
    ln: Array          # (d,)
    wq: Array          # (d, Hl*dh)   [global (d, Hp*dh), TP on cols]
    wk: Array          # (d, KVl*dh)
    wv: Array          # (d, KVl*dh)
    wo: Array          # (Hl*dh, d)   [TP on rows]
    bq: Array          # (Hl*dh,) or ()
    bk: Array
    bv: Array
    qn: Array          # (dh,) qk_norm scales (or ())
    kn: Array


def attention_block(p: AttnParams, x: Array, cfg, *, pos, cache=None,
                    layer_slot: int = 0, tp_shard: bool,
                    reduce: bool = True) -> tuple:
    """x: (B, S, d) replicated over TP. Returns (out, new_cache_slot).

    cache: None (train/prefill w/o cache) or dict with k/v (B, Smax, KV, dh)
    local slices + `length` (filled prefix). One tp_psum at the output.
    """
    B, S, d = x.shape
    dh = cfg.head_dim
    h = rms_norm(x, p.ln, cfg.norm_eps)
    wq = fsdp_gather(p.wq)
    wk = fsdp_gather(p.wk)
    wv = fsdp_gather(p.wv)

    q = jnp.einsum("bsd,dh->bsh", h, wq,
                   preferred_element_type=F32).astype(BF16)
    k = jnp.einsum("bsd,dh->bsh", h, wk,
                   preferred_element_type=F32).astype(BF16)
    v = jnp.einsum("bsd,dh->bsh", h, wv,
                   preferred_element_type=F32).astype(BF16)
    if cfg.qkv_bias:
        q, k, v = q + p.bq, k + p.bk, v + p.bv
    Hl = q.shape[-1] // dh
    q = q.reshape(B, S, Hl, dh)
    k = k.reshape(B, S, -1, dh)
    v = v.reshape(B, S, -1, dh)
    if tp_shard and not cfg.kv_sharded:
        # replicated-KV GQA: every rank computed all n_kv heads; slice the
        # single KV head serving this rank's contiguous q-head block.
        first_q = jax.lax.axis_index(TP) * Hl
        g = (first_q * cfg.n_kv_heads) // cfg.n_heads_padded
        k = jax.lax.dynamic_slice_in_dim(k, g, 1, axis=2)
        v = jax.lax.dynamic_slice_in_dim(v, g, 1, axis=2)

    if cfg.qk_norm:
        q = rms_norm(q, p.qn, cfg.norm_eps)
        k = rms_norm(k, p.kn, cfg.norm_eps)
    if cfg.rope == "rope":
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    elif cfg.rope == "mrope":
        q = apply_mrope(q, pos, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, pos, cfg.rope_theta, cfg.mrope_sections)

    new_cache = None
    if cache is None:
        o = flash_attention(q, k, v, q_offset=jnp.zeros((), jnp.int32))
    elif cache.get("seq_sharded", False):
        # long-context decode: cache time axis sharded over `data`; each
        # rank computes a partial softmax over its chunk, combined with one
        # psum (flash-decoding). The new token's K/V is written by the rank
        # owning global position `length`.
        S_l = cache["k"].shape[1]
        base = jax.lax.axis_index(FSDP) * S_l
        off = cache["length"] - base
        mine = (off >= 0) & (off < S_l)
        offc = jnp.clip(off, 0, S_l - 1)
        kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, offc, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, offc, 1)
        kc = jnp.where(mine, kc, cache["k"])
        vc = jnp.where(mine, vc, cache["v"])
        m, l, acc = flash_attention(q, kc, vc, q_offset=cache["length"] - base,
                                    return_partial=True)
        m_g = jax.lax.pmax(m, FSDP)
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, FSDP)
        acc_g = jax.lax.psum(acc * corr[..., None], FSDP)
        o = (acc_g / jnp.maximum(l_g, 1e-30)[..., None]) \
            .transpose(0, 2, 1, 3).astype(q.dtype)
        new_cache = {"k": kc, "v": vc}
    else:
        # decode: append to cache at position `length`, attend over prefix
        kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, cache["length"], 1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, cache["length"], 1)
        o = flash_attention(q, kc, vc, q_offset=cache["length"],
                            kv_valid=cache["length"] + S)
        new_cache = {"k": kc, "v": vc}

    o = o.reshape(B, S, Hl * dh)
    wo = fsdp_gather(p.wo, axis=1)
    out = jnp.einsum("bsh,hd->bsd", o, wo, preferred_element_type=F32)
    if tp_shard and reduce:
        out = tp_psum(out)
    return (out.astype(x.dtype) if reduce else out), new_cache


# ---------------------------------------------------------------------------
# dense MLP (SwiGLU)
# ---------------------------------------------------------------------------
class MLPParams(NamedTuple):
    ln: Array
    w_gate: Array      # (d, f_l)
    w_up: Array        # (d, f_l)
    w_down: Array      # (f_l, d)


def mlp_block(p: MLPParams, x: Array, cfg, *, tp_shard: bool,
              reduce: bool = True, pre_normed: Array | None = None) -> Array:
    h = rms_norm(x, p.ln, cfg.norm_eps) if pre_normed is None else pre_normed
    wg = fsdp_gather(p.w_gate)
    wu = fsdp_gather(p.w_up)
    wd = fsdp_gather(p.w_down, axis=1)
    g = jnp.einsum("bsd,df->bsf", h, wg, preferred_element_type=F32)
    u = jnp.einsum("bsd,df->bsf", h, wu, preferred_element_type=F32)
    a = (jax.nn.silu(g) * u).astype(BF16)
    out = jnp.einsum("bsf,fd->bsd", a, wd, preferred_element_type=F32)
    if tp_shard and reduce:
        out = tp_psum(out)
    return out.astype(x.dtype) if reduce else out


# ---------------------------------------------------------------------------
# MoE block — expert parallelism as tensor parallelism (DESIGN.md §4)
# ---------------------------------------------------------------------------
class MoEParams(NamedTuple):
    ln: Array
    router: Array      # (d, E) replicated over TP
    w_gate: Array      # (E_l, d, fe)
    w_up: Array        # (E_l, d, fe)
    w_down: Array      # (E_l, fe, d)
    sh_gate: Array     # (d, n_shared*fe / tp) or ()
    sh_up: Array
    sh_down: Array


def moe_block(p: MoEParams, x: Array, cfg, *, tp_shard: bool,
              capacity_factor: float = 1.25) -> Array:
    """Activations are replicated over TP after attention, so each model
    rank owns E/tp experts and dispatches its *local* experts for ALL its
    data-shard tokens — no all_to_all; the block ends in the same single
    psum as a dense TP MLP. Capacity-bucketed (dropped tokens pass through
    the residual, standard top-k capacity semantics).
    """
    mc = cfg.moe
    B, S, d = x.shape
    T = B * S
    E = mc.n_experts
    E_pad = cfg.n_experts_padded
    tp = cfg.tp if tp_shard else 1
    E_l = E_pad // tp
    C = max(int(T * mc.top_k * capacity_factor / E), 4)

    h = rms_norm(x, p.ln, cfg.norm_eps).reshape(T, d)
    router = fsdp_gather(p.router)   # replicated over TP; grad-sync by spec
    logits = jnp.einsum("td,de->te", h, router,
                        preferred_element_type=F32)    # (T, E)
    gates, top_e = jax.lax.top_k(logits, mc.top_k)     # (T, k)
    gates = jax.nn.softmax(gates, axis=-1)

    flat_e = top_e.reshape(-1)                          # (T*k,)
    flat_w = gates.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), mc.top_k)
    # position of each assignment within its expert (global cumcount)
    onehot = jax.nn.one_hot(flat_e, E, dtype=F32)       # (T*k, E)
    pos_in_e = (jnp.cumsum(onehot, axis=0) - onehot)    # exclusive
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], 1)[:, 0].astype(jnp.int32)

    base = (jax.lax.axis_index(TP) if tp_shard else 0) * E_l
    local = (flat_e >= base) & (flat_e < base + E_l) & (pos < C)
    e_loc = jnp.clip(flat_e - base, 0, E_l - 1)
    slot = jnp.where(local, e_loc * C + pos, E_l * C)   # overflow -> dropped

    hx = h.astype(BF16)
    buf = jnp.zeros((E_l * C + 1, d), BF16).at[slot].set(hx[flat_t])
    buf = buf[:E_l * C].reshape(E_l, C, d)

    wg = fsdp_gather(p.w_gate, axis=1)
    wu = fsdp_gather(p.w_up, axis=1)
    wd = fsdp_gather(p.w_down, axis=2)   # (E_l, fe, d): FSDP on d
    g = jnp.einsum("ecd,edf->ecf", buf, wg, preferred_element_type=F32)
    u = jnp.einsum("ecd,edf->ecf", buf, wu, preferred_element_type=F32)
    y = jnp.einsum("ecf,efd->ecd", (jax.nn.silu(g) * u).astype(BF16), wd,
                   preferred_element_type=F32)          # (E_l, C, d)

    y_flat = jnp.concatenate([y.reshape(E_l * C, d),
                              jnp.zeros((1, d), F32)])
    contrib = y_flat[slot] * flat_w[:, None]
    out = jnp.zeros((T, d), F32).at[flat_t].add(
        jnp.where(local[:, None], contrib, 0.0))

    if mc.n_shared:
        sg = fsdp_gather(p.sh_gate)
        su = fsdp_gather(p.sh_up)
        sd = fsdp_gather(p.sh_down, axis=1)
        g2 = jnp.einsum("td,df->tf", h, sg, preferred_element_type=F32)
        u2 = jnp.einsum("td,df->tf", h, su, preferred_element_type=F32)
        out = out + jnp.einsum("tf,fd->td", (jax.nn.silu(g2) * u2).astype(BF16),
                               sd, preferred_element_type=F32)
    if tp_shard:
        out = tp_psum(out)
    return out.reshape(B, S, d).astype(x.dtype)
