"""Mamba block (jamba's SSM layers), manual-SPMD.

Sharding: d_inner is TP-sharded (jamba: 8192/16 = 512 per rank); the
selective-scan state (B, d_inner_l, d_state) is rank-local; x_proj is the
block's only TP reduction (row-sharded matmul -> psum) besides out_proj.

Sequence handling: training/prefill runs the selective scan chunked over
time via lax.scan (compiled body = one chunk; recurrence carried across
chunks). Within a chunk the recurrence is materialized step-by-step — a
chunk-parallel (associative-scan) variant is a recorded hillclimb candidate
in EXPERIMENTS.md §Perf. Decode is the O(1) single-step recurrence.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .sharding import fsdp_gather, scan_aligned, tp_psum

Array = jax.Array
F32 = jnp.float32
BF16 = jnp.bfloat16


class MambaParams(NamedTuple):
    ln: Array          # (d,)
    in_proj: Array     # (d, 2*di_l)
    conv_w: Array      # (d_conv, di_l)
    conv_b: Array      # (di_l,)
    x_proj: Array      # (di_l, dt_rank + 2*d_state)
    dt_w: Array        # (dt_rank, di_l)
    dt_b: Array        # (di_l,)
    a_log: Array       # (di_l, d_state)
    d_skip: Array      # (di_l,)
    out_proj: Array    # (di_l, d)


class MambaState(NamedTuple):
    conv: Array        # (B, d_conv-1, di_l) trailing inputs
    h: Array           # (B, di_l, d_state) f32


def _ssm_scan(x, dt, b_in, c_in, a, d_skip, h0, chunk: int):
    """Selective scan: h_t = exp(dt_t a) h_{t-1} + dt_t b_t x_t;
    y_t = c_t . h_t + D x_t.  Shapes: x/dt (B,S,di), b/c (B,S,ds),
    a (di,ds), h0 (B,di,ds). Chunked lax.scan; returns (y, h_final)."""
    B, S, di = x.shape
    ds = a.shape[1]
    nc = S // chunk

    @jax.checkpoint
    def chunk_body(h, args):
        # rematerialized per chunk: without this the backward saves the
        # per-timestep (B, di, ds) recurrence residuals for the WHOLE
        # sequence (jamba train_4k: >100 GB/chip; EXPERIMENTS.md §Perf)
        xc, dtc, bc, cc = args      # (B, L, ...)

        def step(h, t_args):
            xt, dtt, bt, ct = t_args           # (B,di),(B,di),(B,ds),(B,ds)
            decay = jnp.exp(dtt[..., None] * a)            # (B,di,ds)
            h = decay * h + (dtt * xt)[..., None] * bt[:, None, :]
            y = jnp.einsum("bds,bs->bd", h, ct) + d_skip * xt
            return h, y

        h, yc = scan_aligned(step, h,
                             (xc.transpose(1, 0, 2), dtc.transpose(1, 0, 2),
                              bc.transpose(1, 0, 2), cc.transpose(1, 0, 2)))
        return h, yc.transpose(1, 0, 2)

    xr = x.reshape(B, nc, chunk, di).transpose(1, 0, 2, 3)
    dtr = dt.reshape(B, nc, chunk, di).transpose(1, 0, 2, 3)
    br = b_in.reshape(B, nc, chunk, ds).transpose(1, 0, 2, 3)
    cr = c_in.reshape(B, nc, chunk, ds).transpose(1, 0, 2, 3)
    h, y = scan_aligned(chunk_body, h0, (xr, dtr, br, cr))
    return y.transpose(1, 0, 2, 3).reshape(B, S, di), h


def mamba_block(p: MambaParams, x: Array, cfg, *, state: MambaState | None,
                tp_shard: bool, chunk: int = 256) -> tuple:
    """x: (B, S, d) replicated over TP -> (out, new_state)."""
    B, S, d = x.shape
    from .layers import rms_norm
    h = rms_norm(x, p.ln, cfg.norm_eps)

    w_in = fsdp_gather(p.in_proj)
    xz = jnp.einsum("bsd,de->bse", h, w_in, preferred_element_type=F32)
    di_l = xz.shape[-1] // 2
    xs, z = xz[..., :di_l], xz[..., di_l:]

    # depthwise causal conv over time (d_conv taps)
    K = cfg.d_conv
    if state is None:
        pad = jnp.zeros((B, K - 1, di_l), xs.dtype)
        new_conv = xs[:, S - (K - 1):, :] if S >= K - 1 else None
    else:
        pad = state.conv.astype(xs.dtype)
        new_conv = jnp.concatenate([pad, xs], 1)[:, -(K - 1):, :]
    xp = jnp.concatenate([pad, xs], axis=1)             # (B, S+K-1, di_l)
    xc = sum(xp[:, i:i + S, :] * p.conv_w[i] for i in range(K)) + p.conv_b
    xc = jax.nn.silu(xc)

    # x_proj: row-sharded over TP -> psum for the small (dt, B, C) features
    feats = jnp.einsum("bsd,de->bse", xc.astype(BF16), p.x_proj,
                       preferred_element_type=F32)
    if tp_shard:
        feats = tp_psum(feats)
    dtr = cfg.dt_rank
    dt_in = feats[..., :dtr]
    b_in = feats[..., dtr:dtr + cfg.d_state]
    c_in = feats[..., dtr + cfg.d_state:]

    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_in.astype(BF16), p.dt_w,
                   preferred_element_type=F32) + p.dt_b)
    a = -jnp.exp(p.a_log.astype(F32))                   # (di_l, ds)

    h0 = state.h if state is not None else jnp.zeros((B, di_l, cfg.d_state), F32)
    if S == 1:  # decode fast path
        decay = jnp.exp(dt[:, 0, :, None] * a)
        hn = decay * h0 + (dt[:, 0] * xc[:, 0].astype(F32))[..., None] \
            * b_in[:, 0, None, :]
        y = jnp.einsum("bds,bs->bd", hn, c_in[:, 0]) + p.d_skip * xc[:, 0]
        y = y[:, None, :]
    else:
        ch = min(chunk, S)
        assert S % ch == 0
        y, hn = _ssm_scan(xc.astype(F32), dt, b_in, c_in, a, p.d_skip, h0, ch)

    y = y * jax.nn.silu(z)
    w_out = fsdp_gather(p.out_proj, axis=1)
    out = jnp.einsum("bse,ed->bsd", y.astype(BF16), w_out,
                     preferred_element_type=F32)
    if tp_shard:
        out = tp_psum(out)
    new_state = MambaState(
        conv=(new_conv if new_conv is not None else
              jnp.zeros((B, K - 1, di_l), xs.dtype)).astype(BF16),
        h=hn) if state is not None or S == 1 else None
    return out.astype(x.dtype), new_state
