"""LM substrate: the 10 assigned architectures as one unified block-pattern
decoder, written manual-SPMD (every collective explicit, axis names bound by
shard_map). See DESIGN.md §4 for the sharding contract."""
