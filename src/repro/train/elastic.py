"""Elastic scaling + straggler mitigation (simulated; tested with a fake
clock in tests/test_runtime.py).

At 1000+ nodes, failures are routine. The controller below implements the
policy layer the launcher uses:
  * heartbeat registry with a deadline — hosts that miss it are `suspect`,
  * straggler mitigation: a step that exceeds `straggler_factor` x the
    trailing-median step time marks the slowest host and (policy) either
    reassigns its data shard or triggers a re-mesh,
  * re-mesh: on confirmed loss, pick the best (pod, data, model)
    factorization of the survivors (launch.mesh.make_mesh_for), restore the
    latest checkpoint *resharded* to the new mesh, and resume — parameters
    are FSDP-sharded so any device count that preserves divisibility works.
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field


@dataclass
class HostState:
    last_heartbeat: float
    step_times: list = field(default_factory=list)


@dataclass
class ElasticController:
    n_hosts: int
    heartbeat_timeout: float = 60.0
    straggler_factor: float = 2.0
    clock: callable = time.monotonic
    hosts: dict = None
    generation: int = 0            # bumps on every re-mesh

    def __post_init__(self):
        now = self.clock()
        self.hosts = {h: HostState(now) for h in range(self.n_hosts)}

    # -- signals -----------------------------------------------------------
    def heartbeat(self, host: int, step_time: float | None = None):
        st = self.hosts.get(host)
        if st is None:
            return
        st.last_heartbeat = self.clock()
        if step_time is not None:
            st.step_times.append(step_time)
            st.step_times = st.step_times[-32:]

    # -- queries -------------------------------------------------------------
    def dead_hosts(self) -> list:
        now = self.clock()
        return [h for h, st in self.hosts.items()
                if now - st.last_heartbeat > self.heartbeat_timeout]

    def stragglers(self) -> list:
        meds = {h: statistics.median(st.step_times)
                for h, st in self.hosts.items() if len(st.step_times) >= 4}
        if len(meds) < 2:
            return []
        global_med = statistics.median(meds.values())
        return [h for h, m in meds.items()
                if m > self.straggler_factor * global_med]

    # -- actions -------------------------------------------------------------
    def plan(self) -> dict:
        """Returns the action the launcher should take this round."""
        dead = self.dead_hosts()
        if dead:
            survivors = [h for h in self.hosts if h not in dead]
            for h in dead:
                del self.hosts[h]
            self.generation += 1
            return {"action": "remesh", "survivors": len(survivors),
                    "generation": self.generation}
        slow = self.stragglers()
        if slow:
            return {"action": "reassign_data", "hosts": slow}
        return {"action": "none"}
