"""Elastic scaling + straggler mitigation (simulated; tested with a fake
clock in tests/test_runtime.py).

At 1000+ nodes, failures are routine. The controller below implements the
policy layer the launcher uses:
  * heartbeat registry with a deadline — hosts that miss it are `suspect`,
  * straggler mitigation: a step that exceeds `straggler_factor` x the
    trailing-median step time marks the slowest host and (policy) either
    reassigns its data shard or triggers a re-mesh,
  * re-mesh: on confirmed loss, pick the best (pod, data, model)
    factorization of the survivors (launch.mesh.make_mesh_for), restore the
    latest checkpoint *resharded* to the new mesh, and resume — parameters
    are FSDP-sharded so any device count that preserves divisibility works.
    For the serving-side index the same plan drives
    ``core.persist.restore_sharded`` onto the survivor mesh (elastic N->M
    reshard, no rebuild).
  * rejoin: a host that resumes heartbeating after removal re-registers —
    that is a topology change like a loss, so the next ``plan()`` bumps the
    generation and reports ``action: "remesh"`` upward (never a silent
    no-op).
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field


@dataclass
class HostState:
    last_heartbeat: float
    step_times: list = field(default_factory=list)


@dataclass
class ElasticController:
    n_hosts: int
    heartbeat_timeout: float = 60.0
    straggler_factor: float = 2.0
    clock: callable = time.monotonic
    hosts: dict = None
    generation: int = 0            # bumps on every re-mesh
    _rejoined: set = field(default_factory=set)   # since the last plan()

    def __post_init__(self):
        now = self.clock()
        self.hosts = {h: HostState(now) for h in range(self.n_hosts)}

    # -- signals -----------------------------------------------------------
    def heartbeat(self, host: int, step_time: float | None = None):
        st = self.hosts.get(host)
        if st is None:
            # A removed (or brand-new) host resuming heartbeats rejoins the
            # registry; the topology change surfaces from the next plan().
            st = self.hosts[host] = HostState(self.clock())
            self._rejoined.add(host)
        st.last_heartbeat = self.clock()
        if step_time is not None:
            st.step_times.append(step_time)
            st.step_times = st.step_times[-32:]

    # -- queries -------------------------------------------------------------
    def dead_hosts(self) -> list:
        now = self.clock()
        return [h for h, st in self.hosts.items()
                if now - st.last_heartbeat > self.heartbeat_timeout]

    def stragglers(self) -> list:
        """Hosts whose median step time exceeds ``straggler_factor`` x the
        fleet median — computed over *live* hosts only: a host past the
        heartbeat deadline is a loss for ``plan()`` to handle, and its stale
        step times must not skew (or land it in) the straggler set."""
        now = self.clock()
        meds = {h: statistics.median(st.step_times)
                for h, st in self.hosts.items()
                if len(st.step_times) >= 4
                and now - st.last_heartbeat <= self.heartbeat_timeout}
        if len(meds) < 2:
            return []
        global_med = statistics.median(meds.values())
        return [h for h, m in meds.items()
                if m > self.straggler_factor * global_med]

    # -- actions -------------------------------------------------------------
    def plan(self) -> dict:
        """Returns the action the launcher should take this round."""
        dead = self.dead_hosts()
        rejoined = sorted(self._rejoined - set(dead))
        self._rejoined.clear()
        if dead or rejoined:
            for h in dead:
                del self.hosts[h]
            self.generation += 1
            return {"action": "remesh", "survivors": len(self.hosts),
                    "generation": self.generation, "rejoined": rejoined}
        slow = self.stragglers()
        if slow:
            return {"action": "reassign_data", "hosts": slow}
        return {"action": "none"}
