"""The shard_map training step (manual SPMD — DESIGN.md §4).

Collective inventory per step (all explicit in this file or the layers):
  all_gather(data)        FSDP weight materialization (per superblock)
  psum_scatter(data)      its transpose: gradient reduce-scatter (ZeRO)
  psum(model)             one per block output + loss softmax terms
  psum(pod)               gradient DP sync (optionally int8-compressed)
  psum(pod,data)          scalar loss/metric aggregation
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import model as M
from repro.models.sharding import (batch_axes_for, scan_aligned,
                                   set_batch_axes, set_fsdp_gather,
                                   set_mesh_axes, set_psum_dtype,
                                   unvary)
from . import grad_compress, optimizer

F32 = jnp.float32


def batch_specs(cfg, mesh) -> dict:
    b_ax = batch_axes_for(mesh)
    pos_spec = P(None, b_ax, None) if cfg.rope == "mrope" \
        else P(b_ax, None)
    tok = P(b_ax, None, None) if cfg.embed_input \
        else P(b_ax, None)
    return {"inputs": tok, "labels": P(b_ax, None), "pos": pos_spec}


def batch_shapes(cfg, shape, dtype_tokens=jnp.int32) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if cfg.embed_input:
        inputs = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
    else:
        inputs = jax.ShapeDtypeStruct((B, S), dtype_tokens)
    pos = jax.ShapeDtypeStruct((3, B, S) if cfg.rope == "mrope" else (B, S),
                               jnp.int32)
    return {"inputs": inputs, "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "pos": pos}


def auto_microbatch(cfg, shape, mesh, *, budget_bytes: float = 2.5e9) -> int:
    """Microbatch count so the rematerialization checkpoint residuals
    (one saved x per superblock per microbatch-step) fit the budget:
        saved = B_local/nmb * S * d_model * 2B * n_sb  <=  budget."""
    n_batch = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n_batch *= mesh.shape[a]
    b_local = max(shape.global_batch // n_batch, 1)
    # hybrid/ssm archs save wider residuals (d_inner streams, chunk scans)
    width = cfg.d_model * (3 if "mamba" in cfg.pattern else 1)
    saved = b_local * shape.seq_len * width * 2 * cfg.n_sb
    nmb = 1
    while saved / nmb > budget_bytes and nmb < b_local:
        nmb *= 2
    return nmb


def make_train_step(cfg, mesh, *, lr: float = 3e-4, compress_pod: bool = False,
                    remat: bool = True, donate: bool = True,
                    microbatch: int = 1, psum_dtype=None):
    """Returns (step_fn, in_specs_dict). step_fn(params, opt, residual,
    batch) -> (params, opt, residual, metrics).

    ``microbatch`` > 1 enables gradient accumulation: the local batch is
    split into that many slices scanned sequentially, with f32 grad
    accumulators (bytes ~= params/chip * 4) — this is what bounds the
    activation footprint of the big train cells (EXPERIMENTS.md §Perf)."""
    p_specs = M.param_specs(cfg)
    has_pod = "pod" in mesh.axis_names

    def parse(s: str) -> tuple:
        axes = tuple(a for a in s.split(",") if a)
        return axes if has_pod else tuple(a for a in axes if a != "pod")

    sync_axes = M.param_sync_axes(cfg)
    # replication weight for exact global grad-norm (data/model only)
    repl_w = jax.tree.map(
        lambda s: 1.0 / float(jnp.prod(jnp.asarray(
            [mesh.shape[a] for a in parse(s) if a in ("data", "model")]
            or [1.0]))), sync_axes)

    bspecs = batch_specs(cfg, mesh)
    b_axes = batch_axes_for(mesh)

    def step_fn(params, opt, residual, inputs, labels, pos):
        set_batch_axes(b_axes)   # trace-time: bind loss psums to this mesh
        set_mesh_axes(mesh.axis_names)
        set_fsdp_gather(True)
        set_psum_dtype(psum_dtype)
        # NOTE: no manual grad-sync. Under shard_map with check_vma=True,
        # JAX's varying-manual-axes system transposes psums correctly, so
        # replicated-parameter gradients arrive globally summed already
        # (verified in tests/test_multidevice.py::test_spmd_numeric_...).
        params_s = params

        def loss_fn(p, inp, lab, po):
            x, _ = M.forward(p, cfg, inp, pos=po, mode="train",
                             remat=remat)
            return M.lm_loss(p, cfg, x, lab, cfg.tp_shard)

        if microbatch == 1:
            loss, grads = jax.value_and_grad(loss_fn)(
                params_s, inputs, labels, pos)
        else:
            nmb = microbatch
            B = inputs.shape[0]
            assert B % nmb == 0, (B, nmb)
            split0 = lambda a: a.reshape((nmb, B // nmb) + a.shape[1:])
            mb_in = split0(inputs)
            mb_lab = split0(labels)
            if cfg.rope == "mrope":   # pos is (3, B, S): batch on axis 1
                mb_pos = pos.reshape((3, nmb, B // nmb) + pos.shape[2:]) \
                    .transpose(1, 0, 2, 3)
            else:
                mb_pos = split0(pos)

            def mb_body(carry, mb):
                acc, lsum = carry
                inp, lab, po = mb
                l, g = jax.value_and_grad(loss_fn)(params_s, inp, lab, po)
                acc = jax.tree.map(lambda a, gi: a + gi.astype(F32), acc, g)
                return (acc, lsum + l), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
            (grads, lsum), _ = scan_aligned(
                mb_body, (zeros, jnp.zeros((), F32)),
                (mb_in, mb_lab, mb_pos))
            grads = jax.tree.map(lambda g: g / nmb, grads)
            loss = lsum / nmb

        if has_pod:
            if compress_pod:
                grads, residual = grad_compress.compressed_pod_psum(
                    grads, residual)
            else:
                grads = jax.tree.map(lambda g: jax.lax.psum(g, "pod"), grads)

        gnorm = optimizer.global_grad_norm(grads, repl_w)
        scale = jnp.minimum(1.0, 1.0 / jnp.maximum(gnorm, 1e-12))
        new_params, new_opt = optimizer.update(params, grads, opt, lr=lr,
                                               scale=scale)
        metrics = {"loss": unvary(loss), "grad_norm": unvary(gnorm)}
        return new_params, new_opt, residual, metrics

    # residual spec: mirrors params when compressing, dummy scalar otherwise
    res_spec = p_specs if compress_pod else P()
    in_specs = (p_specs, optimizer.state_specs(p_specs), res_spec,
                bspecs["inputs"], bspecs["labels"], bspecs["pos"])
    out_specs = (p_specs, optimizer.state_specs(p_specs), res_spec,
                 {"loss": P(), "grad_norm": P()})

    fn = jax.shard_map(step_fn, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=True)
    if donate:
        return jax.jit(fn, donate_argnums=(0, 1, 2)), in_specs
    return jax.jit(fn), in_specs
