"""Pod-axis gradient compression with error feedback.

The pod axis is the slowest link (DCI between pods), so its gradient psum is
the multi-pod step's collective bottleneck. Optional int8 compression with
per-leaf scale + error-feedback residual keeps the cross-pod traffic at 1/4
of bf16 while preserving convergence (residual re-injected next step).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def init_residual(params_or_shapes, shapes_only: bool = False):
    if shapes_only:
        return jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
            params_or_shapes)
    return jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params_or_shapes)


def compressed_pod_psum(grads, residual, axis: str = "pod"):
    """int8-quantized psum over the pod axis with error feedback.

    Returns (synced_grads, new_residual).
    """
    def one(g, r):
        g = g.astype(F32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        # scales differ per pod: sync the max scale first (cheap scalar psum)
        scale = jax.lax.pmax(scale, axis)
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        new_r = g - q.astype(F32) * scale
        summed = jax.lax.psum(q.astype(jnp.int32), axis)
        return summed.astype(F32) * scale, new_r

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r, strict=True)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]))
