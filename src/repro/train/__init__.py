"""Distributed training runtime: sharded AdamW, the shard_map train step,
gradient compression, checkpointing, elasticity."""
