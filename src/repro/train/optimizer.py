"""AdamW with fp32 master weights, fully sharded (ZeRO): every optimizer
leaf carries the same PartitionSpec as its parameter, so per-chip optimizer
memory is params/(data*model) * 12 bytes.

The update runs on *already-reduced* gradients: FSDP leaves arrive
reduce-scattered over `data` (the transpose of the forward all_gather) and
replicated leaves arrive post-sync_grad — so the update itself is purely
local arithmetic on the shard.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


class AdamWState(NamedTuple):
    mu: dict
    nu: dict
    master: dict
    step: jax.Array


def init(params: dict) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
    master = jax.tree.map(lambda p: p.astype(F32), params)
    return AdamWState(mu=zeros, nu=jax.tree.map(jnp.copy, zeros),
                      master=master, step=jnp.zeros((), jnp.int32))


def init_shapes(params_shapes: dict) -> AdamWState:
    """ShapeDtypeStruct mirror for dry-run lowering."""
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, F32)
    return AdamWState(mu=jax.tree.map(f32, params_shapes),
                      nu=jax.tree.map(f32, params_shapes),
                      master=jax.tree.map(f32, params_shapes),
                      step=jax.ShapeDtypeStruct((), jnp.int32))


def state_specs(param_specs: dict):
    from jax.sharding import PartitionSpec as P
    return AdamWState(mu=param_specs, nu=param_specs, master=param_specs,
                      step=P())


def global_grad_norm(grads: dict, repl_weight: dict) -> jax.Array:
    """Global L2 norm of sharded grads. ``repl_weight`` down-weights leaves
    replicated across (data, model) so the cross-rank psum counts each
    element exactly once."""
    from repro.models.sharding import psum_forced
    sq = sum(w * jnp.sum(g.astype(F32) ** 2)
             for g, w in zip(jax.tree.leaves(grads),
                             jax.tree.leaves(repl_weight),
                             strict=True))
    return jnp.sqrt(psum_forced(sq, ("data", "model")))


def update(params: dict, grads: dict, st: AdamWState, *, lr: float,
           scale: jax.Array | float = 1.0,
           b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
           weight_decay: float = 0.1, dtype=jnp.bfloat16):
    """Returns (new_params, new_state). ``scale`` is the (clip) multiplier
    computed by the caller from global_grad_norm."""

    step = st.step + 1
    c1 = 1.0 - b1 ** step.astype(F32)
    c2 = 1.0 - b2 ** step.astype(F32)

    def upd(g, mu, nu, m):
        g = g.astype(F32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        m = m - lr * ((mu / c1) / (jnp.sqrt(nu / c2) + eps) +
                      weight_decay * m)
        return mu, nu, m

    flat_g, tdef = jax.tree.flatten(grads)
    flat_mu = tdef.flatten_up_to(st.mu)
    flat_nu = tdef.flatten_up_to(st.nu)
    flat_m = tdef.flatten_up_to(st.master)
    new_mu, new_nu, new_m = [], [], []
    for g, mu, nu, m in zip(flat_g, flat_mu, flat_nu, flat_m, strict=True):
        a, b, c = upd(g, mu, nu, m)
        new_mu.append(a)
        new_nu.append(b)
        new_m.append(c)
    new_params = jax.tree.unflatten(tdef, [m.astype(dtype) for m in new_m])
    new_state = AdamWState(mu=jax.tree.unflatten(tdef, new_mu),
                           nu=jax.tree.unflatten(tdef, new_nu),
                           master=jax.tree.unflatten(tdef, new_m), step=step)
    return new_params, new_state
