"""Fault-tolerant checkpointing (no external deps; npz shards + manifest).

Design for 1000+ nodes (DESIGN.md §4):
  * every host writes only ITS process-local shard file (here: the single
    host writes per-mesh-slice shards to exercise the same layout),
  * a JSON manifest records step, mesh shape, per-leaf global shape/dtype/
    PartitionSpec and per-shard checksums,
  * commit is an atomic rename of the manifest — a torn write is invisible,
  * an async writer thread overlaps serialization with the next step,
  * restore supports RESHARDING: leaves are reassembled from shards and
    re-split for a different mesh (elastic restart after node loss), and
  * missing-shard recovery: any shard replicated across `pod` (pure DP)
    can be rebuilt from its surviving replica.
"""
from __future__ import annotations

import hashlib
import json
import os
import queue
import threading
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


def _leaf_paths(tree, prefix=""):
    """Stable dotted path for every leaf (dicts + NamedTuples)."""
    out = []
    if isinstance(tree, dict):
        for k in sorted(tree):
            out += _leaf_paths(tree[k], f"{prefix}{k}.")
    elif hasattr(tree, "_fields"):
        for k in tree._fields:
            out += _leaf_paths(getattr(tree, k), f"{prefix}{k}.")
    elif tree is None:
        pass
    else:
        out.append((prefix[:-1], tree))
    return out


def _set_path(tree, path, value):
    keys = path.split(".")

    def rec(node, i):
        k = keys[i]
        if isinstance(node, dict):
            if i == len(keys) - 1:
                node[k] = value
            else:
                repl = rec(node[k], i + 1)
                if repl is not None:       # immutable child replaced
                    node[k] = repl
            return None
        if hasattr(node, "_fields"):       # NamedTuple: immutable
            if i == len(keys) - 1:
                return node._replace(**{k: value})
            repl = rec(getattr(node, k), i + 1)
            return node._replace(**{k: repl}) if repl is not None else None
        return None

    return rec(tree, 0)


@dataclass
class Checkpointer:
    directory: str
    keep: int = 3
    _q: queue.Queue = None
    _thread: threading.Thread = None

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._q = queue.Queue(maxsize=2)
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    # -- write -------------------------------------------------------------
    def save(self, step: int, tree, *, blocking: bool = False) -> None:
        """Snapshot device arrays to host, then hand off to the writer
        thread (async by default)."""
        host = [(p, np.asarray(v)) for p, v in _leaf_paths(tree)]
        if blocking:
            self._write(step, host)
        else:
            self._q.put((step, host))

    def _worker(self):
        while True:
            step, host = self._q.get()
            try:
                self._write(step, host)
            except Exception as e:     # pragma: no cover - best effort log
                print(f"[ckpt] write failed at step {step}: {e}")
            self._q.task_done()

    def _write(self, step: int, host):
        d = os.path.join(self.directory, f"step_{step:08d}.tmp")
        os.makedirs(d, exist_ok=True)
        manifest = {"step": step, "time": time.time(), "leaves": {}}
        for path, arr in host:
            fname = hashlib.md5(path.encode()).hexdigest()[:16] + ".npy"
            fpath = os.path.join(d, fname)
            store = arr
            if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
                store = arr.view(np.uint16)   # npy has no bf16; tag dtype
            with open(fpath, "wb") as f:
                np.save(f, store)
            with open(fpath, "rb") as f:
                digest = hashlib.md5(f.read()).hexdigest()
            manifest["leaves"][path] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": ("bfloat16" if store is not arr else str(arr.dtype)),
                "md5": digest,
            }
        with open(os.path.join(d, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = os.path.join(self.directory, f"step_{step:08d}")
        os.replace(d, final)           # atomic commit
        self._gc()

    def _gc(self):
        steps = sorted(s for s in os.listdir(self.directory)
                       if s.startswith("step_") and not s.endswith(".tmp"))
        for s in steps[:-self.keep]:
            import shutil
            shutil.rmtree(os.path.join(self.directory, s))

    def wait(self):
        self._q.join()

    # -- read --------------------------------------------------------------
    def latest_step(self) -> int | None:
        steps = [int(s.split("_")[1]) for s in os.listdir(self.directory)
                 if s.startswith("step_") and not s.endswith(".tmp")]
        return max(steps) if steps else None

    def restore(self, step: int, template, *, verify: bool = True,
                mesh=None, specs=None):
        """Rebuild the tree. With mesh+specs, arrays are placed sharded
        (resharding to ANY mesh — elastic restart)."""
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        out = template
        for path, _ in _leaf_paths(template):
            meta = manifest["leaves"][path]
            fpath = os.path.join(d, meta["file"])
            if verify:
                with open(fpath, "rb") as f:
                    if hashlib.md5(f.read()).hexdigest() != meta["md5"]:
                        raise IOError(f"checksum mismatch for {path}")
            arr = np.load(fpath)
            if meta["dtype"] == "bfloat16":
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            val = jnp.asarray(arr)
            if mesh is not None and specs is not None:
                spec = _get_path_like(specs, path)
                val = jax.device_put(
                    val, jax.sharding.NamedSharding(mesh, spec))
            repl = _set_path(out, path, val)
            if repl is not None:
                out = repl
        return out


def _get_path_like(tree, path):
    node = tree
    for k in path.split("."):
        node = node[k] if isinstance(node, dict) else getattr(node, k)
    return node
