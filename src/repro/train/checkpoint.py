"""Fault-tolerant checkpointing (no external deps; npy leaves + manifest).

A thin tree-checkpoint adapter over ``core.persist.SnapshotStore`` — the
generic store owns the durability mechanics (atomic rename commit,
checksummed manifest with a schema version, async writer whose failures are
*surfaced*, retry-with-backoff on transient ``OSError``s, keep-N gc); this
module maps a params/opt-state tree onto it:

  * every leaf writes as its own ``.npy`` file (named by the md5 of its
    dotted path, recorded in the manifest meta) so a 1000-node layout where
    each host writes only its local shard files needs no format change,
  * bf16 leaves ride the store's uint16 view-cast codec and restore exactly,
  * restore supports RESHARDING: with ``mesh`` + ``specs``, leaves are
    placed via ``jax.device_put(NamedSharding(mesh, spec))`` onto ANY mesh
    (elastic restart after node loss).

A failed async write is recorded and re-raised from ``wait()`` or the next
``save()`` — it can never be mistaken for durability.  All verification
failures raise :class:`core.persist.SnapshotCorruption` (an ``IOError``).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..core.persist import SnapshotStore, set_tree_path, tree_paths


def _leaf_fname(path: str) -> str:
    return hashlib.md5(path.encode()).hexdigest()[:16] + ".npy"


@dataclass
class Checkpointer:
    directory: str
    keep: int = 3
    retries: int = 0                    # transient-OSError attempts per write
    backoff: float = 0.05               # base of the exponential backoff
    _store: SnapshotStore = field(init=False)

    def __post_init__(self):
        self._store = SnapshotStore(self.directory, keep=self.keep,
                                    retries=self.retries,
                                    backoff=self.backoff, kind="tree")

    # -- write -------------------------------------------------------------
    def save(self, step: int, tree, *, blocking: bool = False) -> None:
        """Snapshot device arrays to host, then hand off to the store
        (async by default; a prior async failure re-raises here).  Leaves
        materialize *now* so donated buffers can be reused immediately."""
        files, leaves = {}, {}
        for path, leaf in tree_paths(tree):
            fname = _leaf_fname(path)
            files[fname] = {"": np.asarray(leaf)}
            leaves[path] = fname
        self._store.save(step, files, {"leaves": leaves}, blocking=blocking)

    def wait(self) -> None:
        """Block until queued snapshots are durable; re-raise any writer
        failure."""
        self._store.wait()

    @property
    def write_retries(self) -> int:
        return self._store.write_retries

    # -- read --------------------------------------------------------------
    def latest_step(self) -> int | None:
        return self._store.latest_step()

    def restore(self, step: int, template, *, verify: bool = True,
                mesh=None, specs=None):
        """Rebuild the tree. With mesh+specs, arrays are placed sharded
        (resharding to ANY mesh — elastic restart)."""
        manifest = self._store.read_manifest(step)
        leaves = manifest["meta"]["leaves"]
        out = template
        for path, _ in tree_paths(template):
            arr = self._store.load_file(step, leaves[path], manifest,
                                        verify=verify)[""]
            val = jnp.asarray(arr)
            if mesh is not None and specs is not None:
                spec = _get_path_like(specs, path)
                val = jax.device_put(
                    val, jax.sharding.NamedSharding(mesh, spec))
            repl = set_tree_path(out, path, val)
            if repl is not None:
                out = repl
        return out


def _get_path_like(tree, path):
    node = tree
    for k in path.split("."):
        node = node[k] if isinstance(node, dict) else getattr(node, k)
    return node
