"""Unified execution-path selection for every lookup surface.

Historically each lookup entry point (``rmi.lookup``, ``rmrt.lookup``,
``DynamicRMI.find/find_range``, ``ShardedDynamicIndex.find/find_range``,
``distributed.make_lookup_fn``, the serve-front-end ``TenantPack``) carried
its own ``use_kernel: bool | None`` tri-state plus a copy of the implicit
f32-exactness fallback.  This module is now the single owner of that
policy, exposed as a three-value enum:

  ``path="auto"``    Pallas kernel on TPU backends when the key space is
                     exactly f32-representable, jnp otherwise (the
                     historical ``use_kernel=None`` behavior).
  ``path="kernel"``  force the fused Pallas kernel; raises ``ValueError``
                     when the key space is not f32-exact (the kernel
                     searches and seam-verifies in f32, so f32-colliding
                     f64 keys would resolve to wrong positions silently).
  ``path="jnp"``     force the jnp oracle path (never touches exactness —
                     the f64 fallback works for any key space).

``use_kernel=`` is kept as a deprecated shim on every public entry point:
``True`` maps to ``path="kernel"``, ``False`` to ``path="jnp"`` (``None``
defers to ``path``), with a ``DeprecationWarning``.
"""
from __future__ import annotations

import warnings
from typing import Callable

import jax

PATHS = ("auto", "kernel", "jnp")


def resolve_path(path: str = "auto", *,
                 f32_exact: bool | Callable[[], bool],
                 use_kernel: bool | None = None,
                 what: str = "key space") -> bool:
    """Resolve the ``path`` enum (or the deprecated ``use_kernel`` kwarg)
    to a concrete use-the-kernel decision.

    ``f32_exact`` may be a bool or a zero-arg callable — the callable is
    only invoked when the decision actually needs exactness (``"auto"`` /
    ``"kernel"``), so ``path="jnp"`` never pays the device round-trip of
    computing it.  ``what`` names the key space in the error message so
    sharded/tenant surfaces keep their specific wording.
    """
    if use_kernel is not None:
        warnings.warn(
            "use_kernel= is deprecated; pass path='kernel'|'jnp'|'auto' "
            "instead", DeprecationWarning, stacklevel=3)
        if path != "auto":
            raise ValueError(
                "pass either path= or the deprecated use_kernel=, not both")
        path = "kernel" if use_kernel else "jnp"
    if path not in PATHS:
        raise ValueError(f"path must be one of {PATHS}, got {path!r}")
    if path == "jnp":
        return False
    exact = f32_exact() if callable(f32_exact) else bool(f32_exact)
    if path == "kernel":
        if not exact:
            raise ValueError(
                f"path='kernel' on a {what} that is not f32-exact: the "
                "kernel's f32 search and seam verification cannot "
                "distinguish f32-colliding f64 keys, so wrong positions "
                "would be returned silently")
        return True
    return jax.default_backend() == "tpu" and exact
