"""Recursive Model Reuse Tree (RMRT, paper §3).

A node holding more than N keys trains a model that partitions its keys into
B children (agile model reuse applied whenever a model is needed); recursion
stops when a partition holds <= N keys, which is then indexed by a (reused or
fresh) leaf model. The tree is unbalanced by construction — dense regions get
more levels — which is the paper's answer to skew.

TPU adaptation: the tree is built *level-synchronously* — every node of a
level is processed by the same batched machinery as the RMI layer (segment
fits, batched histograms, one fused pool selection for all nodes), and the
tree is stored as flat arrays (child_base/is_leaf/bounds per node) because
TPUs do not chase pointers. Descent is a fixed-depth masked loop.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import models
from .adapt import DomainSpec, adapt_linear, adapt_mlp
from .bounds import reuse_err_bounds
from .paths import resolve_path
from .reuse import ModelPool, select_from_pool_batch
from .rmi import (leaf_histograms, leaf_stats, segment_linear_fit,
                  segment_residual_bounds, verified_search,
                  _batched_leaf_mlp, _leaf_predict_all)

Array = jax.Array


@dataclass
class RMRTIndex:
    keys: Array              # (n,) sorted
    kind: str                # leaf/internal model kind: "linear" | "mlp"
    params: models.LinearParams | models.MLPParams   # stacked (num_nodes, ...)
    is_leaf: Array           # (num_nodes,) bool
    child_base: Array        # (num_nodes,) int32 — flat index of first child
    y_start: Array           # (num_nodes,) f64 — position range for bucketing
    y_end: Array             # (num_nodes,)
    err_lo: Array            # (num_nodes,) leaf bounds (0 for internal)
    err_hi: Array
    node_sim: Array          # (num_nodes,) build-time similarity (Lemma 4.1)
    reused_mask: Array       # (num_nodes,) bool
    fanout: int
    leaf_cap: int
    depth: int
    _iters: int | None = None        # cached error-window search depth
    _packed: tuple | None = None     # (mat, vec) kernel node tables
    _f32_exact: bool | None = None   # keys round-trip through f32

    @property
    def n(self) -> int:
        return int(self.keys.shape[0])

    @property
    def search_iters(self) -> int:
        """Static search depth bounded by the widest live leaf window (§4)."""
        if self._iters is None:
            from ..kernels.lookup import search_iters
            self._iters = search_iters(self.err_lo, self.err_hi, self.n)
        return self._iters

    @property
    def num_nodes(self) -> int:
        return int(self.is_leaf.shape[0])

    @property
    def reuse_fraction(self) -> float:
        return float(jnp.mean(self.reused_mask.astype(jnp.float64)))

    @property
    def f32_exact(self) -> bool:
        """True when every key round-trips through f32 — the precondition
        for the Pallas kernel path (same guard as RMIIndex.f32_exact)."""
        if self._f32_exact is None:
            k32 = self.keys.astype(jnp.float32).astype(jnp.float64)
            self._f32_exact = bool(jnp.all(k32 == self.keys))
        return self._f32_exact

    def packed_tables(self) -> tuple:
        """(mat, vec) VMEM-layout node tables for the fused RMRT kernel."""
        if self._packed is None:
            from ..kernels import lookup as _lk
            self._packed = _lk.pack_rmrt(
                self.kind, self.params, self.is_leaf, self.child_base,
                self.y_start, self.y_end, self.err_lo, self.err_hi)
        return self._packed


def _fit_level(keys, slots, n_slots, kind, pool, train_steps, seed,
               paper_bounds):
    """Fit (reuse-or-train) one model per occupied slot; returns params,
    measured/theorem bounds, sim, reused mask — all (n_slots,) stacked."""
    count, kmin, kmax, pmin, pmax = leaf_stats(keys, slots, n_slots)
    found = jnp.zeros((n_slots,), bool)
    if pool is not None:
        if pool.sel_a is None:
            pool._refresh_tables()
        hists = leaf_histograms(keys, slots, n_slots, pool.m, kmin, kmax)
        sel = select_from_pool_batch(pool.sel_a, pool.sel_ps, hists,
                                     jnp.float32(pool.eps))
        found = sel.found & (count > 1)
        src = jax.tree.map(lambda a: a[sel.index], pool.domains)
        tgt = DomainSpec(x_start=kmin,
                         x_end=jnp.where(kmax > kmin, kmax, kmin + 1.0),
                         y_start=pmin, y_end=jnp.maximum(pmax, pmin + 1.0))
        pp = jax.tree.map(lambda a: a[sel.index], pool.params)
        adapt = adapt_linear if pool.kind == "linear" else adapt_mlp
        adapted = jax.vmap(adapt)(pp, src, tgt)
        s_dy = (tgt.y_end - tgt.y_start) / (src.y_end - src.y_start)
        thm_lo, thm_hi = reuse_err_bounds(pool.err_lo[sel.index],
                                          pool.err_hi[sel.index],
                                          sel.dist, count, s_dy)

    if kind == "linear":
        fresh = segment_linear_fit(keys, slots, n_slots)
    else:
        fresh = _batched_leaf_mlp(keys, slots, n_slots, count, kmin, kmax,
                                  pmin, train_steps, seed,
                                  skip_mask=found if pool is not None else None)

    if pool is not None and pool.kind == kind:
        merge = lambda a, f: jnp.where(
            jnp.expand_dims(found, tuple(range(1, a.ndim))), a, f)
        params = jax.tree.map(merge, adapted, fresh)
    else:
        params = fresh
        found = jnp.zeros((n_slots,), bool)

    pred = _leaf_predict_all(kind, params, keys, slots)
    lo, hi = segment_residual_bounds(pred, slots, n_slots)
    if pool is not None and paper_bounds:
        lo = jnp.where(found, thm_lo, lo)
        hi = jnp.where(found, thm_hi, hi)
    # Empty slots are reachable by out-of-distribution queries: give them a
    # sound full-array window (plain binary search fallback).
    n = keys.shape[0]
    lo = jnp.where(count > 0, lo, -float(n))
    hi = jnp.where(count > 0, hi, float(n))
    sim = jnp.where(found, 1.0 - sel.dist, 1.0) if pool is not None \
        else jnp.ones((n_slots,), jnp.float64)
    return params, lo, hi, sim, found, count, pmin, pmax


def build_rmrt(
    keys: Array,
    leaf_cap: int = 4096,            # paper's N (1e6 at 200M-key scale)
    fanout: int = 64,                # paper's B
    kind: str = "linear",
    pool: Optional[ModelPool] = None,
    paper_bounds: bool = False,
    train_steps: int = 200,
    max_depth: int = 12,
    seed: int = 0,
) -> RMRTIndex:
    keys = jnp.asarray(keys, jnp.float64)
    n = keys.shape[0]

    # Flat node storage, appended level by level. Keys that already settled
    # into a finished leaf are "parked" in a dummy tail slot at deeper levels
    # (fitted results for the dummy are trimmed before appending).
    all_params, all_leaf, all_cbase = [], [], []
    all_ylo, all_yhi, all_elo, all_ehi, all_sim, all_reused = [], [], [], [], [], []

    slots = jnp.zeros((n,), jnp.int32)        # key -> node slot in this level
    n_slots, has_dummy = 1, False
    level_base = 0                            # flat index of level's first node
    depth = 0

    for level in range(max_depth):
        depth = level + 1
        params, lo, hi, sim, found, count, pmin, pmax = _fit_level(
            keys, slots, n_slots, kind, pool, train_steps, seed + level,
            paper_bounds)
        real = n_slots - (1 if has_dummy else 0)
        count_np = np.asarray(count)[:real]
        leaf_mask = (count_np <= leaf_cap) | (level == max_depth - 1)
        internal = np.where(~leaf_mask)[0]

        # child_base: the next level is laid out as fanout-sized groups in
        # the order of `internal`.
        next_base = level_base + real
        cbase = np.full((real,), -1, np.int64)
        cbase[internal] = next_base + np.arange(internal.size) * fanout

        trim = lambda a, real=real: a[:real]
        all_params.append(jax.tree.map(trim, params))
        all_leaf.append(jnp.asarray(leaf_mask))
        all_cbase.append(jnp.asarray(cbase, jnp.int32))
        all_ylo.append(trim(pmin))
        all_yhi.append(trim(jnp.maximum(pmax, pmin) + 1.0))
        all_elo.append(jnp.where(jnp.asarray(leaf_mask), trim(lo), 0.0))
        all_ehi.append(jnp.where(jnp.asarray(leaf_mask), trim(hi), 0.0))
        all_sim.append(trim(sim))
        all_reused.append(trim(found))

        if internal.size == 0:
            break

        # Route keys of internal nodes to their child slot; park the rest.
        pred = _leaf_predict_all(kind, params, keys, slots)
        span = (jnp.maximum(pmax, pmin) + 1.0 - pmin)[slots]
        child = jnp.clip(((pred - pmin[slots]) * fanout / span).astype(jnp.int32),
                         0, fanout - 1)
        slot_remap = np.full((n_slots,), -1, np.int64)  # dummy stays -1
        slot_remap[internal] = np.arange(internal.size)
        new_slots = jnp.asarray(slot_remap, jnp.int32)[slots] * fanout + child
        # Pad the internal count to a power of two: stabilizes traced shapes
        # across levels/builds (jit-cache friendly). Padding slots are empty
        # and become sound empty leaves (full-window fallback).
        pad = 1 << max(int(internal.size) - 1, 0).bit_length()
        n_next = pad * fanout
        slots = jnp.where(new_slots >= 0, new_slots, n_next)
        n_slots, has_dummy = n_next + 1, True
        level_base = next_base

    cat = jnp.concatenate
    params = jax.tree.map(lambda *ps: cat(ps), *all_params)
    return RMRTIndex(
        keys=keys, kind=kind, params=params,
        is_leaf=cat(all_leaf), child_base=cat(all_cbase),
        y_start=cat(all_ylo), y_end=cat(all_yhi),
        err_lo=cat(all_elo), err_hi=cat(all_ehi),
        node_sim=cat(all_sim), reused_mask=cat(all_reused),
        fanout=fanout, leaf_cap=leaf_cap, depth=depth)


# ---------------------------------------------------------------------------
# Lookup.
# ---------------------------------------------------------------------------
def lookup(index: RMRTIndex, queries: Array, *, path: str = "auto",
           use_kernel: bool | None = None,
           clamp_iters: bool = True) -> Array:
    """Serving lookup.  ``path="kernel"`` is the fused Pallas kernel —
    descent AND clamped search in one kernel; the masked-descent jnp path
    below is the CPU fast path, the kernel's f64 reference, and the f64
    fallback.  Same path-selection semantics as ``rmi.lookup``
    (``core.paths.resolve_path``; ``use_kernel`` is the deprecated shim)."""
    if resolve_path(path, f32_exact=lambda: index.f32_exact,
                    use_kernel=use_kernel):
        from ..kernels import ops as kernel_ops
        from ..kernels.lookup import full_iters
        iters = index.search_iters if clamp_iters else full_iters(index.n)
        mat, vec = index.packed_tables()
        return kernel_ops.rmrt_lookup(
            jnp.asarray(queries, jnp.float64), mat, vec, index.keys,
            fanout=index.fanout, depth=index.depth, kind=index.kind,
            iters=iters)
    return _rmrt_lookup(index.kind, index.params, index.is_leaf,
                        index.child_base, index.y_start, index.y_end,
                        index.err_lo, index.err_hi, index.keys,
                        jnp.asarray(queries, jnp.float64), index.fanout,
                        index.depth,
                        index.search_iters if clamp_iters else None)


def _predict_one(kind, params, node, q):
    p = jax.tree.map(lambda a: a[node], params)
    if kind == "linear":
        return models.linear_predict(p, q)
    h = jax.nn.relu(q[..., None] * p.w1 + p.b1)
    return jnp.sum(h * p.w2, -1) + p.b2


@functools.partial(jax.jit,
                   static_argnames=("kind", "fanout", "depth", "iters"))
def _rmrt_lookup(kind, params, is_leaf, child_base, y_start, y_end,
                 err_lo, err_hi, keys, queries, fanout: int, depth: int,
                 iters: int | None = None):
    """Masked fixed-depth descent (vectorized over queries), then the same
    bounded branchless binary search as RMI."""
    n = keys.shape[0]
    node = jnp.zeros(queries.shape, jnp.int32)

    def body(_, node):
        pred = _predict_one(kind, params, node, queries)
        span = y_end[node] - y_start[node]
        child = jnp.clip(((pred - y_start[node]) * fanout / span)
                         .astype(jnp.int32), 0, fanout - 1)
        nxt = child_base[node] + child
        return jnp.where(is_leaf[node], node, nxt)

    node = jax.lax.fori_loop(0, depth, body, node)
    pred = _predict_one(kind, params, node, queries)
    lo = jnp.clip(jnp.floor(pred + err_lo[node]), 0, n - 1).astype(jnp.int32)
    hi = jnp.clip(jnp.ceil(pred + err_hi[node]) + 1, 1, n).astype(jnp.int32)
    return verified_search(keys, queries, lo, hi, iters=iters)
