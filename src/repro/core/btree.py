"""Array-based static B+tree baseline (paper competitor #1, STX-like).

Implicit layout: level l holds the separator keys of its nodes contiguously;
a lookup descends with one fanout-wide scan per level (branchless,
vectorized over queries). Build is a single bottom-up pass — this is why the
paper finds BTree build time unbeatable, which we reproduce.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclass
class BTreeIndex:
    keys: Array               # (n,) sorted leaf level
    levels: list              # list of (n_l,) separator arrays, root last
    fanout: int

    @property
    def n(self) -> int:
        return int(self.keys.shape[0])

    @property
    def height(self) -> int:
        return len(self.levels)


def build_btree(keys: Array, fanout: int = 16) -> BTreeIndex:
    """Bottom-up bulk load: level l+1 = every fanout-th key of level l."""
    keys = jnp.asarray(keys, jnp.float64)
    levels = []
    cur = keys
    while cur.shape[0] > fanout:
        cur = cur[fanout - 1::fanout]        # max key of each node
        levels.append(cur)
    return BTreeIndex(keys=keys, levels=levels, fanout=fanout)


def lookup(index: BTreeIndex, queries: Array) -> Array:
    """Left-boundary rank of each query (same semantics as rmi.lookup)."""
    queries = jnp.asarray(queries, jnp.float64)
    # Descend: at each level, narrow [lo, lo+fanout) by one scan.
    return _btree_lookup(index.keys, tuple(index.levels), index.fanout, queries)


@functools.partial(jax.jit, static_argnames=("fanout",))
def _btree_lookup(keys, levels: tuple, fanout: int, queries):
    n = keys.shape[0]
    # start from the root level: position among root separators
    node = jnp.zeros(queries.shape, jnp.int32)
    for lvl in reversed(levels):
        m = lvl.shape[0]
        # children of `node` cover separators [node*fanout, (node+1)*fanout)
        base = node * fanout
        offs = jnp.arange(fanout)
        cand = jnp.clip(base[:, None] + offs[None, :], 0, m - 1)
        below = (lvl[cand] < queries[:, None]) & ((base[:, None] + offs) < m)
        node = base + below.sum(1).astype(jnp.int32)
    base = node * fanout
    offs = jnp.arange(fanout)
    cand = jnp.clip(base[:, None] + offs[None, :], 0, n - 1)
    below = (keys[cand] < queries[:, None]) & ((base[:, None] + offs) < n)
    return jnp.clip(base + below.sum(1).astype(jnp.int32), 0, n)
