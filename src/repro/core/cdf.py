"""CDF machinery: exact KS distance, relative-frequency histograms, and the
paper's Algorithm 2 histogram-based distance upper bound.

Definitions (paper §3):
  sim(D_S, D_T)  = 1 - sup_x |cdf_S(x) - cdf_T(x)|          (Def. 3.1)
  dist(D_S, D_T) = 1 - sim(D_S, D_T)   (two-sample Kolmogorov-Smirnov statistic)
  dist_h(D_S, D_T) >= dist(D_S, D_T)                        (Eq. 3, Algorithm 2)

All functions are jit-compatible and operate on float64 keys (x64 enabled in
``repro.__init__``) so 64-bit integer keys survive normalization.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# Exact two-sample KS distance (Def. 3.1).
# ---------------------------------------------------------------------------
@jax.jit
def ks_distance(sorted_a: Array, sorted_b: Array) -> Array:
    """Exact ``sup_x |cdf_A(x) - cdf_B(x)|`` for two *sorted* 1-D key arrays.

    Right-continuous empirical CDFs jump only at sample points, so the sup is
    attained at a point of the union of the two samples; evaluating both CDFs
    at every union point is exact. O((n+m) log(n+m)) via searchsorted.
    """
    union = jnp.concatenate([sorted_a, sorted_b])
    fa = jnp.searchsorted(sorted_a, union, side="right").astype(jnp.float64) \
        / sorted_a.shape[0]
    fb = jnp.searchsorted(sorted_b, union, side="right").astype(jnp.float64) \
        / sorted_b.shape[0]
    return jnp.max(jnp.abs(fa - fb))


def ks_similarity(sorted_a: Array, sorted_b: Array) -> Array:
    """sim(D_S, D_T) per Def. 3.1."""
    return 1.0 - ks_distance(sorted_a, sorted_b)


# ---------------------------------------------------------------------------
# Relative-frequency histograms.
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("m",))
def histogram_sorted(sorted_keys: Array, m: int, lo: Array, hi: Array) -> Array:
    """m-bin relative-frequency histogram of a *sorted* key array.

    This is the paper's O(m log n) construction: locate the m-1 interior bin
    edges with binary search instead of scanning all n keys. Bins follow the
    paper's right-closed convention ( (i/m, (i+1)/m] after normalization ),
    with the first bin additionally absorbing keys == lo.
    """
    n = sorted_keys.shape[0]
    edges = lo + (hi - lo) * (jnp.arange(1, m + 1, dtype=sorted_keys.dtype) / m)
    cum = jnp.searchsorted(sorted_keys, edges, side="right")
    counts = jnp.diff(jnp.concatenate([jnp.zeros((1,), cum.dtype), cum]))
    # Clip anything above hi into the last bin (defensive; callers pass
    # lo/hi = data range so cum[-1] == n already).
    counts = counts.at[-1].add(n - cum[-1])
    return counts.astype(jnp.float64) / n


@functools.partial(jax.jit, static_argnames=("m",))
def histogram_stream(keys: Array, m: int, lo: Array, hi: Array) -> Array:
    """m-bin relative-frequency histogram of an *unsorted* key array (O(n)).

    jnp reference for the Pallas streaming kernel in ``repro.kernels.hist``
    (used on the update/ingest path where keys arrive unsorted).
    """
    n = keys.shape[0]
    scaled = (keys - lo) / jnp.maximum(hi - lo, jnp.finfo(keys.dtype).tiny)
    # Right-closed bins: key in ((i)/m, (i+1)/m]  ->  bin = ceil(x*m) - 1.
    idx = jnp.clip(jnp.ceil(scaled * m).astype(jnp.int32) - 1, 0, m - 1)
    counts = jnp.zeros((m,), jnp.float64).at[idx].add(1.0)
    return counts / n


# ---------------------------------------------------------------------------
# Algorithm 2: histogram-based distance upper bound.
# ---------------------------------------------------------------------------
@jax.jit
def hist_distance(hs: Array, ht: Array) -> Array:
    """Algorithm 2. ``dist_h(D_S, D_T)`` from two m-bin histograms.

    Guarantees dist_h >= dist (Eq. 3): within bin i, cdf_S is at most the
    *inclusive* prefix sum P_S + H_S[i] while cdf_T is at least the
    *exclusive* prefix sum P_T, and symmetrically. Vectorized form of the
    paper's loop: both branches evaluated for every bin, single max-reduce.
    """
    ps = jnp.concatenate([jnp.zeros((1,), hs.dtype), jnp.cumsum(hs)[:-1]])
    pt = jnp.concatenate([jnp.zeros((1,), ht.dtype), jnp.cumsum(ht)[:-1]])
    up = hs + ps - pt     # bounds cdf_S(x) - cdf_T(x) from above, per bin
    dn = ht + pt - ps     # bounds cdf_T(x) - cdf_S(x) from above, per bin
    return jnp.maximum(jnp.max(up), jnp.max(dn))


@jax.jit
def hist_distance_pool(pool_hists: Array, ht: Array) -> Array:
    """Batched Algorithm 2: distance of one target histogram against a whole
    pool ``(P, m)`` of pre-computed synthetic histograms in one shot.

    TPU-native replacement for the paper's sequential priority-queue scan —
    the selection over the result is done by the caller (see reuse.py). A
    fused Pallas version lives in ``repro.kernels.ksdist``.
    """
    return jax.vmap(lambda hs: hist_distance(hs, ht))(pool_hists)


def normalize_keys(keys: Array) -> tuple[Array, Array, Array]:
    """Map keys to [0, 1]; returns (normalized, lo, hi). Constant datasets map
    to 0.5 to stay well-defined."""
    lo, hi = keys.min(), keys.max()
    span = jnp.maximum(hi - lo, jnp.finfo(jnp.float64).tiny)
    return (keys - lo) / span, lo, hi
