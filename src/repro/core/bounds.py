"""Error-bound algebra: Theorem 3.3 (reuse error bounds) and Lemma 4.1
(insertion budget before rebuild)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


@jax.jit
def reuse_err_bounds(err_lo: Array, err_hi: Array, dist: Array, n_t: Array,
                     s_dy: Array) -> tuple[Array, Array]:
    """Theorem 3.3: bounds of a reused model on the target dataset.

        err_lo' = -dist * n_T + err_lo * S_dy
        err_hi' = +dist * n_T + err_hi * S_dy

    ``dist`` may be the exact KS distance or the Algorithm-2 upper bound
    dist_h (>= dist, so the result stays a sound bound — Eq. 3).
    """
    return (-dist * n_t + err_lo * s_dy, dist * n_t + err_hi * s_dy)


@jax.jit
def insertion_budget(sim: Array, eps: Array, n: Array) -> Array:
    """Lemma 4.1: max #inserts before a rebuild is required:

        n_i <= (sim - eps) / (1 + eps - sim) * n

    ``sim`` is the build-time similarity between the dataset and whatever the
    model was trained on (1.0 if freshly trained). Negative budgets clamp to 0
    (a model reused right at the threshold must rebuild on first insert).
    """
    return jnp.maximum(jnp.floor((sim - eps) / (1.0 + eps - sim) * n), 0.0)


def widen_for_inserts(err_lo: Array, err_hi: Array, n_inserts: Array):
    """§4: a sibling leaf whose CDF is untouched by i inserts only needs its
    bounds widened by i (positions after the insertion point shift by <= i)."""
    return err_lo - n_inserts, err_hi + n_inserts
