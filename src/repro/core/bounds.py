"""Error-bound algebra: Theorem 3.3 (reuse error bounds) and Lemma 4.1
(insertion budget before rebuild)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


@jax.jit
def reuse_err_bounds(err_lo: Array, err_hi: Array, dist: Array, n_t: Array,
                     s_dy: Array) -> tuple[Array, Array]:
    """Theorem 3.3: bounds of a reused model on the target dataset.

        err_lo' = -dist * n_T + err_lo * S_dy
        err_hi' = +dist * n_T + err_hi * S_dy

    ``dist`` may be the exact KS distance or the Algorithm-2 upper bound
    dist_h (>= dist, so the result stays a sound bound — Eq. 3).
    """
    return (-dist * n_t + err_lo * s_dy, dist * n_t + err_hi * s_dy)


@jax.jit
def insertion_budget(sim: Array, eps: Array, n: Array) -> Array:
    """Lemma 4.1: max #inserts before a rebuild is required:

        n_i <= (sim - eps) / (1 + eps - sim) * n

    ``sim`` is the build-time similarity between the dataset and whatever the
    model was trained on (1.0 if freshly trained). Negative budgets clamp to 0
    (a model reused right at the threshold must rebuild on first insert).
    """
    return jnp.maximum(jnp.floor((sim - eps) / (1.0 + eps - sim) * n), 0.0)


def widen_for_inserts(err_lo: Array, err_hi: Array, n_inserts: Array):
    """§4: a sibling leaf whose CDF is untouched by i inserts only needs its
    bounds widened by i (positions after the insertion point shift by <= i)."""
    return err_lo - n_inserts, err_hi + n_inserts


def insertion_headroom(budget, n_inserts) -> float:
    """Aggregate Lemma 4.1 headroom: sum over leaves of the remaining
    insertion budget max(budget_l - inserts_l, 0).

    The sharded rebalancer compares a migrated boundary run against the
    *receiving* shard's headroom: a run within the headroom rides the delta
    tier (at worst triggering localized leaf rebuilds), while a run that
    overflows it would churn most of the shard's leaves anyway, so the
    receiver falls back to one full rebuild.  Host numpy — this feeds a
    host-side policy decision, not traced code."""
    import numpy as np
    b = np.asarray(budget, np.float64)
    i = np.asarray(n_inserts, np.float64)
    return float(np.maximum(b - i, 0.0).sum())


# ---------------------------------------------------------------------------
# Search-window accounting (ROADMAP "Update path x clamped depth"): the
# serving search depth is a function of per-leaf window *widths*, so the
# dynamic-update path maintains a host-side width vector and recomputes the
# depth incrementally on every leaf merge instead of invalidating the cached
# depth and re-deriving it from the device bound arrays.
# ---------------------------------------------------------------------------
def window_widths(err_lo, err_hi):
    """Per-leaf search-window widths: ceil(err_hi) - floor(err_lo) + 3
    (the +3 is the clamp/rounding slack of the lookup's window math).
    Host numpy — this feeds static jit parameters, not traced code."""
    import numpy as np
    # tracelint: ok[hot-sync](update-path bounds ingest feeding static jit params)
    elo = np.asarray(err_lo, np.float64)
    # tracelint: ok[hot-sync](second leg of the same bounds ingest)
    ehi = np.asarray(err_hi, np.float64)
    return np.ceil(ehi) - np.floor(elo) + 3.0


def clamped_depth(widths, n_keys: int) -> int:
    """Static branchless-search depth covering the widest *live* window
    (sentinel full-array windows on empty leaves are excluded; queries routed
    there are caught by seam verification and re-searched at full depth)."""
    import math
    import numpy as np
    # tracelint: ok[hot-sync](widths is the host-side np width mirror)
    w = np.asarray(widths, np.float64)
    live = w < n_keys
    wmax = float(w[live].max()) if live.any() else float(max(n_keys, 2))
    wmax = min(max(wmax, 2.0), float(max(n_keys, 2)))
    return int(math.ceil(math.log2(wmax))) + 1
