"""RadixSpline baseline (paper competitor #5): a single-pass error-bounded
greedy spline + a radix table over key prefixes.

Build is one pass (GreedySplineCorridor, host NumPy) — the paper's RS builds
fastest among learned indices but pays lookup cost / size, which our
benchmarks reproduce. Lookup: radix bucket -> binary search spline points ->
linear interpolation -> eps-bounded search (jitted, vectorized).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .rmi import bounded_search, verified_search

Array = jax.Array


def _greedy_spline(keys: np.ndarray, eps: int) -> np.ndarray:
    """GreedySplineCorridor (Neumann/Michel; as in RadixSpline): indices of
    spline knots such that chord interpolation between consecutive knots is
    within +-eps of the true rank.

    Invariant: the cone [lo, hi] from the current knot (xb, yb) contains
    every slope that passes within +-eps of all points seen since the knot.
    A point whose exact slope lies inside the cone may safely *end* the
    segment (the chord hits it exactly and stays within the corridor); when
    it falls outside, the previous point becomes a knot."""
    n = keys.size
    pts = [0]
    lo_s, hi_s = -np.inf, np.inf
    xb, yb = keys[0], 0
    prev = 0
    for i in range(1, n):
        x = keys[i]
        if x == xb:
            continue
        s = (i - yb) / (x - xb)
        if s < lo_s or s > hi_s:
            # knot at the last in-corridor point, restart cone from it
            pts.append(prev)
            xb, yb = keys[prev], prev
            lo_s, hi_s = -np.inf, np.inf
            if x == xb:
                continue
        dx = x - xb
        lo_s = max(lo_s, (i - eps - yb) / dx)
        hi_s = min(hi_s, (i + eps - yb) / dx)
        prev = i
    pts.append(n - 1)
    return np.unique(np.asarray(pts, np.int64))


@dataclass
class RSIndex:
    keys: Array
    eps: int
    spline_x: Array      # (S,) spline point keys
    spline_y: Array      # (S,) their ranks
    radix_bits: int
    radix_table: Array   # (2**bits + 1,) first spline point per radix bucket
    key_min: float
    key_max: float

    @property
    def n(self) -> int:
        return int(self.keys.shape[0])

    @property
    def size_bytes(self) -> int:
        return int(self.spline_x.size * 16 + self.radix_table.size * 4)


def build_rs(keys: Array, eps: int = 32, radix_bits: int = 12) -> RSIndex:
    keys_np = np.asarray(keys, np.float64)
    pts = _greedy_spline(keys_np, eps)
    sx, sy = keys_np[pts], pts.astype(np.float64)
    kmin, kmax = float(keys_np[0]), float(keys_np[-1])
    span = max(kmax - kmin, np.finfo(np.float64).tiny)
    # radix table over the leading bits of the normalized key
    buckets = ((sx - kmin) / span * ((1 << radix_bits) - 1)).astype(np.int64)
    table = np.searchsorted(buckets, np.arange((1 << radix_bits) + 1))
    return RSIndex(keys=jnp.asarray(keys_np), eps=eps,
                   spline_x=jnp.asarray(sx), spline_y=jnp.asarray(sy),
                   radix_bits=radix_bits,
                   radix_table=jnp.asarray(table, jnp.int32),
                   key_min=kmin, key_max=kmax)


def lookup(index: RSIndex, queries: Array) -> Array:
    return _rs_lookup(index.keys, index.spline_x, index.spline_y,
                      index.radix_table, index.radix_bits, index.eps,
                      index.key_min, index.key_max,
                      jnp.asarray(queries, jnp.float64))


@functools.partial(jax.jit,
                   static_argnames=("radix_bits", "eps", "kmin", "kmax"))
def _rs_lookup(keys, sx, sy, table, radix_bits: int, eps: int,
               kmin: float, kmax: float, queries):
    n = keys.shape[0]
    S = sx.shape[0]
    span = max(kmax - kmin, np.finfo(np.float64).tiny)
    b = jnp.clip(((queries - kmin) / span * ((1 << radix_bits) - 1))
                 .astype(jnp.int32), 0, (1 << radix_bits) - 1)
    lo = table[b]
    hi = jnp.minimum(table[b + 1] + 1, S)
    # right spline point: first spline key >= q, within [lo, hi)
    r = bounded_search(sx, queries, lo, hi)
    r = jnp.clip(r, 1, S - 1)
    x0, x1 = sx[r - 1], sx[r]
    y0, y1 = sy[r - 1], sy[r]
    t = jnp.where(x1 > x0, (queries - x0) / (x1 - x0), 0.0)
    pred = y0 + t * (y1 - y0)
    plo = jnp.clip(pred.astype(jnp.int32) - eps, 0, n - 1)
    phi = jnp.clip(pred.astype(jnp.int32) + eps + 2, 1, n)
    # +-eps window -> clamped search depth (the radix-table spline search
    # above keeps full depth: bucket occupancy is not statically bounded)
    from ..kernels.lookup import full_iters
    return verified_search(keys, queries, plo, phi,
                           iters=full_iters(2 * eps + 2))
