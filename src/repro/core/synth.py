"""Synthetic dataset generation (paper §3, "Synthetic dataset generation").

The CDF space [0,1]^2 is discretized by the reuse threshold eps: any CDF is
within 1-eps of some grid polyline. The paper limits per-bin probability mass
to {0, (1-eps)/2, (1-eps)} over m = ceil(2/(1-eps)) bins (m=12 at eps=0.9,
matching Table 2), enumerates all such histograms, and samples ns=100 keys
per histogram.

Enumeration: with q = 1-eps, choose i bins of mass q and j bins of mass q/2
with i*q + j*q/2 = 1, i.e. 2i + j = round(2/q). This reproduces Table 2
exactly for eps in {0.5, 0.8, 0.9(m=12)}: 19, 8,953 and 1,221 datasets.
For eps in {0.6, 0.7} the paper reports 95 / 987, which no integral
(i, j) assignment reproduces (2/q = 5 and 6.67); we additionally emit
"remainder" histograms (one extra bin carrying the leftover mass < q/2) so
every mass vector still sums to exactly 1. The discrepancy is recorded in
EXPERIMENTS.md; the default eps=0.9 configuration is exact.
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass

import numpy as np

__all__ = [
    "num_bins",
    "enumerate_histograms",
    "datasets_from_histograms",
    "SyntheticPool",
    "generate_pool",
]


def num_bins(eps: float) -> int:
    """m = ceil(2/(1-eps)); the paper overrides m=12 for eps=0.9 (Table 2)."""
    if abs(eps - 0.9) < 1e-12:
        return 12
    return math.ceil(2.0 / (1.0 - eps) - 1e-9)  # fp-tolerant ceil


def enumerate_histograms(eps: float, m: int | None = None) -> np.ndarray:
    """All m-bin histograms with bin mass in {0, q/2, q}, q = 1-eps, summing
    to 1 (plus remainder-completion histograms when 2/q is fractional).

    Returns (P, m) float64 array of relative frequencies.
    """
    q = 1.0 - eps
    m = num_bins(eps) if m is None else m
    two_over_q = 2.0 / q
    out: list[np.ndarray] = []

    units = int(round(two_over_q))
    exact = abs(two_over_q - units) < 1e-9
    # i bins of mass q (2 half-units), j bins of mass q/2 (1 half-unit).
    for i in range(0, min(m, units // 2) + 1):
        rem_units = (units if exact else int(two_over_q)) - 2 * i
        if rem_units < 0:
            break
        j = rem_units
        leftover = 1.0 - i * q - j * (q / 2.0) if not exact else 0.0
        n_extra = 1 if (not exact and leftover > 1e-12) else 0
        if i + j + n_extra > m:
            continue
        for full_bins in itertools.combinations(range(m), i):
            rest = [b for b in range(m) if b not in full_bins]
            for half_bins in itertools.combinations(rest, j):
                if n_extra:
                    used = set(full_bins) | set(half_bins)
                    for extra in (b for b in range(m) if b not in used):
                        h = np.zeros(m)
                        h[list(full_bins)] = q
                        h[list(half_bins)] = q / 2.0
                        h[extra] = leftover
                        out.append(h)
                else:
                    h = np.zeros(m)
                    h[list(full_bins)] = q
                    h[list(half_bins)] = q / 2.0
                    out.append(h)
    if not out:
        raise ValueError(f"no histograms for eps={eps}, m={m}")
    hists = np.stack(out)
    np.testing.assert_allclose(hists.sum(1), 1.0, atol=1e-9)
    return hists


def datasets_from_histograms(
    hists: np.ndarray, ns: int = 100, seed: int = 0
) -> np.ndarray:
    """Sample one sorted ns-key dataset in [0,1] per histogram (paper: random
    key values per bin, data range [0,1], ns=100). Returns (P, ns) float64.

    Bin counts are largest-remainder rounded so each dataset has exactly ns
    keys; keys are uniform within their bin and sorted.
    """
    rng = np.random.default_rng(seed)
    P, m = hists.shape
    counts = np.floor(hists * ns).astype(np.int64)
    # Largest-remainder method to hit exactly ns per dataset.
    short = ns - counts.sum(1)
    rema = hists * ns - counts
    order = np.argsort(-rema, axis=1)
    for p in range(P):
        for k in range(short[p]):
            counts[p, order[p, k]] += 1
    data = np.empty((P, ns))
    width = 1.0 / m
    for p in range(P):
        vals = []
        for b in range(m):
            c = counts[p, b]
            if c:
                vals.append(b * width + width * rng.random(c))
        data[p] = np.sort(np.concatenate(vals))
    return data


@dataclass(frozen=True)
class SyntheticPool:
    """The raw synthetic corpus: histograms + sampled sorted datasets."""
    eps: float
    m: int
    hists: np.ndarray      # (P, m) relative frequencies
    datasets: np.ndarray   # (P, ns) sorted keys in [0,1]

    @property
    def size(self) -> int:
        return self.hists.shape[0]


def generate_pool(eps: float, ns: int = 100, seed: int = 0,
                  m: int | None = None, limit: int | None = None) -> SyntheticPool:
    """Generate the full synthetic corpus for a reuse threshold eps.

    ``limit`` truncates the corpus (deterministic shuffle first) — useful in
    unit tests; production uses the full enumeration.
    """
    hists = enumerate_histograms(eps, m=m)
    if limit is not None and hists.shape[0] > limit:
        perm = np.random.default_rng(seed + 1).permutation(hists.shape[0])[:limit]
        hists = hists[np.sort(perm)]
    data = datasets_from_histograms(hists, ns=ns, seed=seed)
    return SyntheticPool(eps=eps, m=num_bins(eps) if m is None else m,
                         hists=hists, datasets=data)
