"""The paper's contribution: agile model reuse for learned indices.

Public surface:
  synth.generate_pool(eps)            — synthetic corpus (Table 2 enumeration)
  reuse.build_pool(corpus, kind)      — batched pool pre-training (Q_MP)
  pool.reuse_or_train(keys)           — Algorithm 1 for one dataset
  rmi.build_rmi / rmi.lookup          — RMI, RMI-MR, RMI-NN, RMI-NN-MR
  rmrt.build_rmrt / rmrt.lookup       — the paper's RMRT
  updates.DynamicRMI                  — §4 insert handling (Lemma 4.1)
  drift                               — online KS drift monitoring +
                                        bound-checked pool hot-swaps
  paths.resolve_path                  — the path="auto"|"kernel"|"jnp"
                                        execution-path policy
  distributed.build_sharded           — multi-host sharded index service
  distributed.ShardedDynamicIndex     — sharded two-tier dynamic serving
                                        (per-shard delta tiers, routed
                                        updates, split rebalancing)
  btree / pgm / radix_spline          — baselines from the paper's roster

The unified front door over the dynamic backends is ``repro.api.Index``.
"""
from . import (adapt, bounds, btree, cdf, distributed, drift, models, paths,
               pgm, radix_spline, reuse, rmi, rmrt, synth, updates)

__all__ = ["adapt", "bounds", "btree", "cdf", "distributed", "drift",
           "models", "paths", "pgm", "radix_spline", "reuse", "rmi", "rmrt",
           "synth", "updates"]
