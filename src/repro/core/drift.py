"""Online drift monitoring + bound-checked hot-swap reuse (paper Alg. 1/2
turned into a *serving-time* feature — the top open ROADMAP item).

Drift-score lifecycle
---------------------
A :class:`DriftState` rides on every ``DynamicRMI`` (one per shard in the
sharded index).  It is a pair of raw-count histograms over the build-time
key domain ``[lo, hi]`` at resolution ``m``:

  ``ref``   the accepted baseline — the build-time CDF (``core.cdf``
            histogram of the base tier), later *re-baselined* when a
            ``flush_delta`` merges every buffered insert into the base:
            ``ref += acc; acc = 0; score = 0``.  Partial per-leaf
            rebuilds do NOT rebaseline — the global score keeps tracking
            the workload shift until an explicit flush accepts it.
  ``acc``   every key inserted since the last rebaseline (accumulated at
            ``insert_batch`` time in one scatter-add jit — no host sync;
            deletes are not subtracted, a documented approximation).

The drift score is the binned two-sample KS statistic (max CDF gap at
the bin edges) between the normalized baseline and the normalized
*mixture* ``ref + acc`` — the distance between the distribution the
models were fitted on and the distribution the index currently stores.
(Algorithm-2's ``hist_distance`` is deliberately NOT used for the score:
its within-bin slack keeps it an upper bound for pool-selection
soundness, at the price of a distribution-dependent floor — its
self-distance is the max bin mass — which a threshold latch cannot
tolerate.  The slack-bearing distance still governs pool *selection*
inside the swap pass.)  Keys outside ``[lo, hi]`` clip into the edge
bins, so domain-shifting workloads register immediately.  The score is
zero at stationarity, monotone in both the shift magnitude and the
drifted mass fraction, and lives on device (reading it is a
maintenance-path sync).

Threshold / hysteresis contract
-------------------------------
``drifted`` is a latch, not a comparison: it sets when ``score``
crosses ``thresh_hi`` from below and clears only when ``score`` falls
under ``thresh_lo`` (< thresh_hi) — or on rebaseline, which resets the
score outright.  Scores inside the ``[thresh_lo, thresh_hi]`` band keep
the previous value, so a score oscillating around either threshold
cannot flap the latch, and maintenance never alternates swap/refit
decisions on noise.

Swap-commit semantics
---------------------
When the latch is set and a leaf exhausts its Lemma 4.1 insert budget,
``DynamicRMI.maybe_swap`` tries an Algorithm-1 pool swap *instead of* the
refit storm: one fused jit computes the touched leaves' current key
histograms (base + delta tiers, searchsorted range counts), selects pool
models (``select_from_pool_batch``), adapts them (Lemma 3.2 affine
folds), measures post-swap residual bounds over the base tier, and
derives fresh Lemma 4.1 budgets — then commits each leaf's swap with a
*masked row write* iff, on device:

  * the pool had an eligible model (``dist <= 1 - eps``),
  * the fresh Lemma 4.1 budget covers every insert already buffered on
    the leaf (the budget-exhaustion trigger falls silent — the swap buys
    the headroom a refit would have bought, without the refit's merge +
    retrain cost), and
  * the new error window fits under the current clamped-depth width cap
    (table contents change, shapes and search depth do not — zero
    retraces).

Leaves whose bound check fails fall back to the ordinary
``_rebuild_leaves`` refit.  A committed swap replaces leaf params, error
bounds, sim, and budget in place; the delta tier is untouched (models
index only the base tier), so the swap is O(touched leaves), not O(n).

Facade verb-to-backend mapping (``repro.api``)
----------------------------------------------
``Index.build(keys, mesh=None, pool=None)`` wraps ``DynamicRMI``
(``mesh=None``) or ``ShardedDynamicIndex`` (mesh given).  Verbs map as:

  ============== ============================ ===========================
  verb            DynamicRMI backend           ShardedDynamicIndex backend
  ============== ============================ ===========================
  find            ``find``                     ``find``
  find_range      ``find_range``               ``find_range``
  insert          ``insert_batch``             ``insert``
  delete          ``delete_batch``             ``delete``
  gather          ``live_keys()[ranks]``       ``live_keys()[ranks]``
  gather_range    ``gather_range``             ``gather_range``
  snapshot        ``persist.snapshot_dynamic`` ``persist.snapshot_sharded``
  restore         ``persist.restore_dynamic``  ``persist.restore_sharded``
  ============== ============================ ===========================

Drift state survives snapshot/restore/reshard (``core.persist`` carries
``ref``/``acc``/``score``/``drifted`` plus the scalar config per shard).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
import numpy as np

from . import rmi as rmi_mod
from .adapt import DomainSpec, adapt_linear, adapt_mlp
from .bounds import insertion_budget
from .reuse import select_from_pool_batch

Array = jax.Array


# ---------------------------------------------------------------------------
# Drift state.
# ---------------------------------------------------------------------------
@dataclass
class DriftState:
    """Per-index (per-shard) online drift monitor — see the module
    docstring for the lifecycle and hysteresis contract."""
    m: int                  # histogram resolution (static)
    lo: float               # build-time key domain (host scalars; keys
    hi: float               # outside clip into the edge bins)
    thresh_hi: float        # latch sets when score crosses this
    thresh_lo: float        # latch clears when score falls under this
    ref: Array              # (m,) f64 raw counts — accepted baseline
    acc: Array              # (m,) f64 raw counts since last rebaseline
    score: Array            # () f64 — Algorithm-2 distance, on device
    drifted: Array          # () bool — the hysteresis latch, on device
    updates: int = 0        # batches accumulated (host counter)
    rebaselines: int = 0    # merge events absorbed (host counter)


@functools.partial(jax.jit, static_argnames=("m",))
def _raw_hist_jit(keys: Array, lo, hi, *, m: int) -> Array:
    """Raw-count histogram with ``cdf.histogram_stream``'s right-closed
    binning; non-finite entries (capacity padding) drop out."""
    span = jnp.maximum(hi - lo, jnp.finfo(jnp.float64).tiny)
    b = jnp.clip(jnp.ceil((keys - lo) / span * m).astype(jnp.int32) - 1,
                 0, m - 1)
    idx = jnp.where(jnp.isfinite(keys), b, m)
    return jnp.zeros((m,), jnp.float64).at[idx].add(1.0, mode="drop")


@functools.partial(jax.jit, static_argnames=("m",))
def _accumulate_jit(ref: Array, acc: Array, batch: Array, drifted: Array,
                    lo, hi, thr_hi, thr_lo, *, m: int):
    """Fold one insert batch into ``acc`` and refresh (score, latch) —
    all on device, nothing for the caller to sync."""
    acc = acc + _raw_hist_jit(batch, lo, hi, m=m)
    ref_n = ref / jnp.maximum(ref.sum(), 1.0)
    cur = ref + acc
    cur_n = cur / jnp.maximum(cur.sum(), 1.0)
    # Binned two-sample KS statistic: max CDF gap at the bin edges.  NOT
    # Algorithm-2's hist_distance — that adds within-bin slack to stay an
    # upper bound for pool-selection soundness (Eq. 3), which gives it a
    # distribution-dependent floor (its self-distance is the max bin
    # mass).  A threshold latch needs a score that is zero at
    # stationarity and monotone in the shift, which the tight KS gap is.
    score = jnp.max(jnp.abs(jnp.cumsum(ref_n) - jnp.cumsum(cur_n)))
    drifted = jnp.where(score > thr_hi, True,
                        jnp.where(score < thr_lo, False, drifted))
    return acc, score, drifted


@jax.jit
def _rebase_jit(ref: Array, acc: Array):
    return (ref + acc, jnp.zeros_like(acc), jnp.zeros((), jnp.float64),
            jnp.zeros((), bool))


def init_drift(sorted_keys, m: int = 64, thresh_hi: float = 0.15,
               thresh_lo: float = 0.05) -> DriftState:
    """Baseline a monitor on the build-time key array (build path — the
    one-time domain sync is fine there)."""
    if thresh_lo >= thresh_hi:
        raise ValueError("hysteresis needs thresh_lo < thresh_hi, got "
                         f"[{thresh_lo}, {thresh_hi}]")
    keys = jnp.asarray(sorted_keys, jnp.float64)
    if keys.shape[0] == 0:
        lo, hi = 0.0, 1.0
        ref = jnp.zeros((m,), jnp.float64)
    else:
        lo, hi = float(keys[0]), float(keys[-1])
        if hi <= lo:
            hi = lo + 1.0
        ref = _raw_hist_jit(keys, jnp.float64(lo), jnp.float64(hi), m=m)
    return DriftState(m=m, lo=lo, hi=hi, thresh_hi=thresh_hi,
                      thresh_lo=thresh_lo, ref=ref,
                      acc=jnp.zeros((m,), jnp.float64),
                      score=jnp.zeros((), jnp.float64),
                      drifted=jnp.zeros((), bool))


def update_drift(state: DriftState, batch: Array) -> DriftState:
    """Accumulate one insert batch (device-resident, no host sync)."""
    acc, score, drifted = _accumulate_jit(
        state.ref, state.acc, batch, state.drifted,
        jnp.float64(state.lo), jnp.float64(state.hi),
        jnp.float64(state.thresh_hi), jnp.float64(state.thresh_lo),
        m=state.m)
    return replace(state, acc=acc, score=score, drifted=drifted,
                   updates=state.updates + 1)


def rebaseline(state: DriftState) -> DriftState:
    """Absorb ``acc`` into the baseline after a merge event (rebuild /
    flush): the models were just refitted on the merged data, so the
    stored distribution IS the new reference and the latch clears."""
    ref, acc, score, drifted = _rebase_jit(state.ref, state.acc)
    return replace(state, ref=ref, acc=acc, score=score, drifted=drifted,
                   rebaselines=state.rebaselines + 1)


def state_row(state: DriftState | None) -> Array:
    """(2,) device row [score, drifted] for the sharded drift table —
    the ``(n_shards, k)`` counter-table pattern of ``core.distributed``."""
    if state is None:
        return jnp.zeros((2,), jnp.float64)
    return jnp.stack([state.score, state.drifted.astype(jnp.float64)])


# ---------------------------------------------------------------------------
# The fused swap pass.
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("leaf_kind", "m", "n_leaves"))
def swap_leaves_jit(base_keys: Array, buckets: Array, dk: Array,
                    dleaf: Array, rid_p: Array, leaves, err_lo: Array,
                    err_hi: Array, leaf_sim: Array, reused_mask: Array,
                    sel_a: Array, sel_ps: Array, p_params, p_domains,
                    n_ins: Array, win_cap, eps, *,
                    leaf_kind: str, m: int, n_leaves: int):
    """One fused Algorithm-1 swap attempt for the (pow2-padded) leaf rows
    ``rid_p``: current-distribution histograms -> pool selection ->
    Lemma 3.2 adaptation -> measured bounds over the base tier -> Lemma
    4.1 budgets -> masked row commit.  Requires a monotone (linear) root:
    every per-leaf range is a searchsorted run over the sorted tiers.

    Returns the *committed* full tables plus per-row diagnostics
    ``(leaves, err_lo, err_hi, sim, reused, commit, budget, width,
    dist)`` — rows whose bound check fails keep their old values, and the
    caller refits those leaves instead.  Padding rows repeat a real leaf
    id and scatter identical values (harmless, keeps the jit cache keyed
    on pow2 row counts only).
    """
    n = base_keys.shape[0]
    nd = dk.shape[0]
    rid = rid_p.astype(jnp.int32)
    bs = jnp.searchsorted(buckets, rid, side="left").astype(jnp.int32)
    be = jnp.searchsorted(buckets, rid, side="right").astype(jnp.int32)
    # Delta runs: under the monotone root the routed-leaf table is
    # non-decreasing over the sorted tier; -1 pads map past every leaf.
    dl = jnp.where(dleaf >= 0, dleaf, n_leaves)
    ds = jnp.searchsorted(dl, rid, side="left").astype(jnp.int32)
    de = jnp.searchsorted(dl, rid, side="right").astype(jnp.int32)
    bcnt = (be - bs).astype(jnp.float64)
    dcnt = (de - ds).astype(jnp.float64)

    # Combined key span across both tiers (the leaf's *current* data).
    bk_lo = jnp.where(bcnt > 0, base_keys[jnp.clip(bs, 0, n - 1)], jnp.inf)
    bk_hi = jnp.where(bcnt > 0, base_keys[jnp.clip(be - 1, 0, n - 1)],
                      -jnp.inf)
    dk_lo = jnp.where(dcnt > 0, dk[jnp.clip(ds, 0, nd - 1)], jnp.inf)
    dk_hi = jnp.where(dcnt > 0, dk[jnp.clip(de - 1, 0, nd - 1)], -jnp.inf)
    empty = (bcnt + dcnt) == 0
    kmin = jnp.where(empty, 0.0, jnp.minimum(bk_lo, dk_lo))
    kmax = jnp.where(empty, 1.0, jnp.maximum(bk_hi, dk_hi))
    span = jnp.maximum(kmax - kmin, jnp.finfo(jnp.float64).tiny)

    # Per-row combined histograms: searchsorted range counts at the bin
    # edges over each sorted tier (cost ~ R*m, not n) — the incremental
    # KS-distance input, same right-closed binning as cdf/leaf_histograms.
    frac = jnp.arange(1, m, dtype=jnp.float64) / m
    edges = (kmin[:, None] + span[:, None] * frac[None, :]).reshape(-1)

    def range_counts(tier, s, e):
        pos = jnp.searchsorted(tier, edges, side="right") \
            .reshape(rid.shape[0], m - 1).astype(jnp.int32)
        pos = jnp.clip(pos, s[:, None], e[:, None])
        bounds = jnp.concatenate([s[:, None], pos, e[:, None]], 1)
        return (bounds[:, 1:] - bounds[:, :-1]).astype(jnp.float64)

    counts = range_counts(base_keys, bs, be) + range_counts(dk, ds, de)
    hists = counts / jnp.maximum(counts.sum(1, keepdims=True), 1.0)

    sel = select_from_pool_batch(sel_a, sel_ps, hists,
                                 eps.astype(jnp.float32))

    # Lemma 3.2 adaptation onto (combined key span -> base position span):
    # the swapped model indexes the base tier only (the delta tier is
    # probed by plain searchsorted), so bounds are measured on base keys.
    pmin = bs.astype(jnp.float64)
    pmax = jnp.maximum((be - 1).astype(jnp.float64), pmin)
    tgt = DomainSpec(x_start=kmin,
                     x_end=jnp.where(kmax > kmin, kmax, kmin + 1.0),
                     y_start=pmin, y_end=jnp.maximum(pmax, pmin + 1.0))
    src = jax.tree.map(lambda a: a[sel.index], p_domains)
    pp = jax.tree.map(lambda a: a[sel.index], p_params)
    adapt = adapt_linear if leaf_kind == "linear" else adapt_mlp
    cand_rows = jax.vmap(adapt)(pp, src, tgt)

    # Measured residual bounds of the candidate tree over the base tier
    # (capacity pads route to the dump bucket and drop out of the scan).
    cand = jax.tree.map(lambda full, new: full.at[rid].set(new),
                        leaves, cand_rows)
    pred = rmi_mod._leaf_predict_all(leaf_kind, cand, base_keys, buckets)
    lo_all, hi_all = rmi_mod.segment_residual_bounds_sorted(pred, buckets,
                                                            n_leaves)
    nlo, nhi = lo_all[rid], hi_all[rid]
    new_w = jnp.ceil(nhi) - jnp.floor(nlo) + 3.0   # bounds.window_widths
    sim = 1.0 - sel.dist
    new_budget = insertion_budget(sim, eps, bcnt)

    # The on-device commit gate (module docstring "Swap-commit semantics").
    commit = (sel.found & (bcnt > 1.0)
              & (new_budget >= n_ins) & (new_w <= win_cap))

    keep = lambda new, old: jnp.where(
        jnp.expand_dims(commit, tuple(range(1, new.ndim))), new, old)
    out_leaves = jax.tree.map(
        lambda full, new: full.at[rid].set(keep(new, full[rid])),
        leaves, cand_rows)
    out_lo = err_lo.at[rid].set(jnp.where(commit, nlo, err_lo[rid]))
    out_hi = err_hi.at[rid].set(jnp.where(commit, nhi, err_hi[rid]))
    out_sim = leaf_sim.at[rid].set(jnp.where(commit, sim, leaf_sim[rid]))
    out_reused = reused_mask.at[rid].set(commit | reused_mask[rid])
    return (out_leaves, out_lo, out_hi, out_sim, out_reused, commit,
            new_budget, new_w, sel.dist)
