"""Model adaptation (paper §3 "Model adaptation", Lemma 3.2).

A pool model M_S was trained on keys in [xs_s, xs_e] predicting positions in
[ys_s, ys_e]. To reuse it on D_T with key range [xt_s, xt_e] and position
range [yt_s, yt_e]:

    T_in(x)  = a1*x + b1,  a1 = S_dx = (xs_e - xs_s)/(xt_e - xt_s),
                           b1 = xs_s - xt_s * S_dx
    T_out(y) = a2*y + b2,  a2 = S_dy = (yt_e - yt_s)/(ys_e - ys_s),
                           b2 = yt_s - ys_s * S_dy

Lemma 3.2: for a linear model both maps fold into (a', b') with zero extra
prediction cost. We implement the analogous exact fold for the 1x4 MLP (the
paper's "similar results can be derived for other models"): the input affine
folds into the first layer, the output affine into the last.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .models import LinearParams, MLPParams

Array = jax.Array


class DomainSpec(NamedTuple):
    """Key/position ranges of a dataset, as used by T_in / T_out."""
    x_start: Array
    x_end: Array
    y_start: Array
    y_end: Array


def affine_coeffs(src: DomainSpec, tgt: DomainSpec):
    """Returns ((a1, b1), (a2, b2)) for T_in / T_out."""
    s_dx = (src.x_end - src.x_start) / (tgt.x_end - tgt.x_start)
    s_dy = (tgt.y_end - tgt.y_start) / (src.y_end - src.y_start)
    a1, b1 = s_dx, src.x_start - tgt.x_start * s_dx
    a2, b2 = s_dy, tgt.y_start - src.y_start * s_dy
    return (a1, b1), (a2, b2)


@jax.jit
def adapt_linear(p: LinearParams, src: DomainSpec, tgt: DomainSpec) -> LinearParams:
    """Lemma 3.2 fold: a' = a*S_dx*S_dy,
    b' = (-a*xt_s*S_dx + a*xs_s + b - ys_s)*S_dy + yt_s."""
    (a1, b1), (a2, b2) = affine_coeffs(src, tgt)
    return LinearParams(a=p.a * a1 * a2, b=(p.a * b1 + p.b) * a2 + b2)


@jax.jit
def adapt_mlp(p: MLPParams, src: DomainSpec, tgt: DomainSpec) -> MLPParams:
    """Exact MLP fold: first layer absorbs T_in, last layer absorbs T_out.

        h  = relu(w1*(a1*x + b1) + c1) = relu((w1*a1)*x + (w1*b1 + c1))
        y' = a2*(w2·h + c2) + b2      = (a2*w2)·h + (a2*c2 + b2)
    """
    (a1, b1), (a2, b2) = affine_coeffs(src, tgt)
    return MLPParams(
        w1=p.w1 * a1,
        b1=p.w1 * b1 + p.b1,
        w2=p.w2 * a2,
        b2=p.b2 * a2 + b2,
    )


def domain_of(sorted_keys: Array) -> DomainSpec:
    """DomainSpec of a sorted dataset with positions 0..n-1."""
    n = sorted_keys.shape[0]
    return DomainSpec(
        x_start=sorted_keys[0],
        x_end=sorted_keys[-1],
        y_start=jnp.zeros((), jnp.float64),
        y_end=jnp.asarray(n - 1, jnp.float64),
    )
