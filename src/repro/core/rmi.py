"""Two-layer RMI (Kraska et al. 2018) with optional agile model reuse
(paper §3 "Learned indices with agile model reuse", Fig. 3).

Variants (matching the paper's experiment roster):
  RMI        root + leaves linear, fresh fits          build_rmi(kind="linear")
  RMI-NN     root linear, leaves 1x4 MLP, fresh        build_rmi(kind="mlp")
  RMI-MR     linear leaves, pool reuse                 build_rmi(..., pool=linear_pool)
  RMI-NN-MR  MLP leaves, pool reuse                    build_rmi(..., pool=mlp_pool)

TPU adaptation: every per-leaf operation is batched across ALL leaves —
segment closed-form fits, per-leaf similarity histograms, pool selection,
affine adaptation, residual bounds — so a build is a handful of jit calls
regardless of leaf count, instead of the paper's per-leaf Python loop.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import models
from .adapt import DomainSpec, adapt_linear, adapt_mlp
from .bounds import reuse_err_bounds
from .paths import resolve_path
from .reuse import ModelPool, PoolSelection, select_from_pool_batch

Array = jax.Array


# ---------------------------------------------------------------------------
# Batched per-leaf machinery (shared with RMRT).
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("n_leaves",))
def leaf_stats(keys: Array, buckets: Array, n_leaves: int):
    """Per-leaf (count, key_min, key_max, pos_min, pos_max) via segment ops."""
    n = keys.shape[0]
    pos = jnp.arange(n, dtype=jnp.float64)
    ones = jnp.ones((n,), jnp.float64)
    count = jax.ops.segment_sum(ones, buckets, n_leaves)
    kmin = jax.ops.segment_min(keys, buckets, n_leaves)
    kmax = jax.ops.segment_max(keys, buckets, n_leaves)
    pmin = jax.ops.segment_min(pos, buckets, n_leaves)
    pmax = jax.ops.segment_max(pos, buckets, n_leaves)
    empty = count == 0
    kmin = jnp.where(empty, 0.0, kmin)
    kmax = jnp.where(empty, 1.0, kmax)
    pmin = jnp.where(empty, 0.0, pmin)
    pmax = jnp.where(empty, 0.0, pmax)
    return count, kmin, kmax, pmin, pmax


@functools.partial(jax.jit, static_argnames=("n_leaves", "m"))
def leaf_histograms(keys: Array, buckets: Array, n_leaves: int, m: int,
                    kmin: Array, kmax: Array) -> Array:
    """(n_leaves, m) leaf-normalized similarity histograms, one bincount."""
    span = jnp.maximum(kmax - kmin, jnp.finfo(jnp.float64).tiny)
    x = (keys - kmin[buckets]) / span[buckets]
    b = jnp.clip(jnp.ceil(x * m).astype(jnp.int32) - 1, 0, m - 1)
    flat = buckets * m + b
    counts = jnp.zeros((n_leaves * m,), jnp.float64).at[flat].add(1.0)
    counts = counts.reshape(n_leaves, m)
    tot = jnp.maximum(counts.sum(1, keepdims=True), 1.0)
    return counts / tot


@functools.partial(jax.jit, static_argnames=("n_leaves",))
def segment_linear_fit(keys: Array, buckets: Array, n_leaves: int):
    """Closed-form least-squares (pos on key) per leaf, all leaves at once.
    jnp oracle for the Pallas kernel in ``repro.kernels.linfit``."""
    n = keys.shape[0]
    x = keys.astype(jnp.float64)
    y = jnp.arange(n, dtype=jnp.float64)
    seg = lambda v: jax.ops.segment_sum(v, buckets, n_leaves)
    cnt, sx, sy = seg(jnp.ones_like(x)), seg(x), seg(y)
    sxx, sxy = seg(x * x), seg(x * y)
    denom = cnt * sxx - sx * sx
    a = jnp.where(jnp.abs(denom) > 1e-30, (cnt * sxy - sx * sy) / denom, 0.0)
    b = jnp.where(cnt > 0, (sy - a * sx) / jnp.maximum(cnt, 1.0), 0.0)
    return models.LinearParams(a=a, b=b)


@functools.partial(jax.jit, static_argnames=("n_leaves",))
def segment_residual_bounds(pred: Array, buckets: Array, n_leaves: int):
    """Per-leaf (min, max) of (true position - prediction), batched."""
    n = pred.shape[0]
    r = jnp.arange(n, dtype=jnp.float64) - pred
    lo = jax.ops.segment_min(r, buckets, n_leaves)
    hi = jax.ops.segment_max(r, buckets, n_leaves)
    cnt = jax.ops.segment_sum(jnp.ones((n,)), buckets, n_leaves)
    lo = jnp.where(cnt > 0, lo, 0.0)
    hi = jnp.where(cnt > 0, hi, 0.0)
    return lo, hi


# ---------------------------------------------------------------------------
# Sorted-bucket fast paths.  XLA's CPU scatters make jax.ops.segment_* cost
# ~20ms per op at 10^5 keys — far too slow for the dynamic-update rebuild
# path, which runs these per insert batch.  With a *monotone* (linear) root
# the bucket array over sorted keys is itself sorted, so every per-leaf
# reduction has a scatter-free form: boundaries via searchsorted, sums via
# cumulative-sum differences, min/max via a segmented associative scan.
# Out-of-range buckets (the dynamic index's +inf capacity padding routes to
# the dump bucket ``n_leaves``) sort to the tail and drop out naturally.
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("n_leaves",))
def leaf_stats_sorted(keys: Array, buckets: Array, n_leaves: int):
    """:func:`leaf_stats` for non-decreasing ``buckets`` (no scatters)."""
    n = keys.shape[0]
    lid = jnp.arange(n_leaves)
    start = jnp.searchsorted(buckets, lid, side="left")
    end = jnp.searchsorted(buckets, lid, side="right")
    count = (end - start).astype(jnp.float64)
    empty = count == 0
    s = jnp.clip(start, 0, n - 1)
    e = jnp.clip(end - 1, 0, n - 1)
    kmin = jnp.where(empty, 0.0, keys[s])
    kmax = jnp.where(empty, 1.0, keys[e])
    pmin = jnp.where(empty, 0.0, start.astype(jnp.float64))
    pmax = jnp.where(empty, 0.0, e.astype(jnp.float64))
    return count, kmin, kmax, pmin, pmax


def _segsum(v: Array, start: Array, end: Array) -> Array:
    """Per-leaf sums of ``v`` over contiguous [start, end) ranges via one
    cumulative sum (exclusive prefix, diff at the boundaries)."""
    c = jnp.concatenate([jnp.zeros((1,), v.dtype), jnp.cumsum(v)])
    return c[end] - c[start]


@functools.partial(jax.jit, static_argnames=("m",))
def leaf_histograms_ranges(keys: Array, buckets: Array, rid: Array, m: int,
                           kmin: Array, kmax: Array) -> Array:
    """:func:`leaf_histograms` for a compacted subset of leaves (rows
    ``rid``), non-decreasing ``buckets``: per-leaf bin populations via
    searchsorted at the bin edges — cost scales with R*m, not n.  Same
    right-closed binning as the scatter version."""
    start = jnp.searchsorted(buckets, rid, side="left")
    end = jnp.searchsorted(buckets, rid, side="right")
    span = jnp.maximum(kmax - kmin, jnp.finfo(jnp.float64).tiny)
    frac = jnp.arange(1, m, dtype=jnp.float64) / m
    edges = kmin[:, None] + span[:, None] * frac[None, :]
    pos = jnp.searchsorted(keys, edges.reshape(-1), side="right") \
        .reshape(rid.shape[0], m - 1)
    pos = jnp.clip(pos, start[:, None], end[:, None])
    bounds = jnp.concatenate([start[:, None], pos, end[:, None]], 1)
    counts = (bounds[:, 1:] - bounds[:, :-1]).astype(jnp.float64)
    tot = jnp.maximum(counts.sum(1, keepdims=True), 1.0)
    return counts / tot


@functools.partial(jax.jit, static_argnames=("n_leaves",))
def segment_linear_fit_sorted(keys: Array, buckets: Array, n_leaves: int):
    """:func:`segment_linear_fit` for non-decreasing ``buckets``: two-pass
    cumsum-diff moments (pass 1 per-leaf means, pass 2 centered products —
    the same centering the Pallas linfit wrapper uses for stability).
    Non-finite keys (capacity padding) contribute zero to every moment."""
    n = keys.shape[0]
    lid = jnp.arange(n_leaves)
    start = jnp.searchsorted(buckets, lid, side="left")
    end = jnp.searchsorted(buckets, lid, side="right")
    finite = jnp.isfinite(keys)
    x = jnp.where(finite, keys.astype(jnp.float64), 0.0)
    y = jnp.arange(n, dtype=jnp.float64)
    cnt = (end - start).astype(jnp.float64)
    nn = jnp.maximum(cnt, 1.0)
    mx = _segsum(x, start, end) / nn
    # y is consecutive positions: its per-leaf mean is closed-form.
    my = (start + end - 1).astype(jnp.float64) / 2.0
    bc = jnp.clip(buckets, 0, n_leaves - 1)
    xc = jnp.where(finite, x - mx[bc], 0.0)
    yc = jnp.where(finite, y - my[bc], 0.0)
    sxy = _segsum(xc * yc, start, end)
    sxx = _segsum(xc * xc, start, end)
    a = jnp.where(jnp.abs(sxx) > 1e-30, sxy / sxx, 0.0)
    b = jnp.where(cnt > 0, my - a * mx, 0.0)
    return models.LinearParams(a=a, b=b)


@functools.partial(jax.jit, static_argnames=("n_leaves",))
def segment_residual_bounds_sorted(pred: Array, buckets: Array,
                                   n_leaves: int):
    """:func:`segment_residual_bounds` for non-decreasing ``buckets``:
    segmented min/max via one associative scan each (flag-reset combine),
    gathered at each leaf's last element."""
    n = pred.shape[0]
    r = jnp.arange(n, dtype=jnp.float64) - pred
    first = jnp.concatenate(
        [jnp.ones((1,), bool), buckets[1:] != buckets[:-1]])

    def combine(a, b):
        mn = jnp.where(b[2], b[0], jnp.minimum(a[0], b[0]))
        mx = jnp.where(b[2], b[1], jnp.maximum(a[1], b[1]))
        return mn, mx, a[2] | b[2]

    run_min, run_max, _ = jax.lax.associative_scan(combine, (r, r, first))
    lid = jnp.arange(n_leaves)
    end = jnp.searchsorted(buckets, lid, side="right")
    empty = jnp.searchsorted(buckets, lid, side="left") == end
    e = jnp.clip(end - 1, 0, n - 1)
    return (jnp.where(empty, 0.0, run_min[e]),
            jnp.where(empty, 0.0, run_max[e]))


# ---------------------------------------------------------------------------
# The index structure.
# ---------------------------------------------------------------------------
@dataclass
class RMIIndex:
    keys: Array                      # (n,) sorted
    root_kind: str                   # "linear" | "mlp"
    root: models.LinearParams | models.MLPParams
    leaf_kind: str
    leaves: models.LinearParams | models.MLPParams   # stacked (B, ...)
    err_lo: Array                    # (B,)
    err_hi: Array                    # (B,)
    n_leaves: int
    # provenance / reuse accounting (build-time diagnostics)
    reused_mask: Array               # (B,) bool
    leaf_sim: Array                  # (B,) build-time similarity (Lemma 4.1 input)
    # lazily-derived serving state (host-side caches, not build outputs)
    _iters: int | None = None        # error-window search depth
    _packed: tuple | None = None     # (root, mat, vec) kernel tables
    _f32_exact: bool | None = None   # keys round-trip through f32

    @property
    def n(self) -> int:
        return int(self.keys.shape[0])

    @property
    def reuse_fraction(self) -> float:
        return float(jnp.mean(self.reused_mask.astype(jnp.float64)))

    @property
    def search_iters(self) -> int:
        """Static per-query search depth bounded by the error window (§4)."""
        if self._iters is None:
            from ..kernels.lookup import search_iters
            self._iters = search_iters(self.err_lo, self.err_hi, self.n)
        return self._iters

    @property
    def f32_exact(self) -> bool:
        """True when every key round-trips through f32 — the precondition
        for the Pallas kernel path, which searches (and seam-verifies) in
        f32: distinct f64 keys that collide in f32 would resolve to wrong
        positions undetectably."""
        if self._f32_exact is None:
            k32 = self.keys.astype(jnp.float32).astype(jnp.float64)
            self._f32_exact = bool(jnp.all(k32 == self.keys))
        return self._f32_exact

    def packed_tables(self) -> tuple:
        """(root, mat, vec) VMEM-layout tables for the fused Pallas kernel."""
        if self._packed is None:
            from ..kernels import lookup as _lk
            root = _lk.pack_root(self.root_kind, self.root)
            w1, b1, w2, b2 = _leaf_table_arrays(self.leaf_kind, self.leaves,
                                                self.n_leaves)
            mat, vec = _lk.pack_leaves(w1, b1, w2, b2, self.err_lo,
                                       self.err_hi)
            self._packed = (root, mat, vec)
        return self._packed


def _leaf_table_arrays(kind: str, leaves, n_leaves: int):
    """Uniform (L, H)/(L,) leaf tables for either leaf kind (linear models
    ride in w1[:, 0] / b2, mirroring the kernel's linear fast path)."""
    if kind == "linear":
        w1 = jnp.zeros((n_leaves, models.HIDDEN),
                       jnp.float32).at[:, 0].set(leaves.a.astype(jnp.float32))
        zeros = jnp.zeros((n_leaves, models.HIDDEN), jnp.float32)
        return w1, zeros, zeros, leaves.b
    return leaves.w1, leaves.b1, leaves.w2, leaves.b2


def _root_predict(kind, params, keys):
    return (models.linear_predict if kind == "linear"
            else models.mlp_predict)(params, keys)


@functools.partial(jax.jit, static_argnames=("kind", "n_leaves", "n"))
def root_buckets(kind: str, params, keys: Array, n_leaves: int, n: int) -> Array:
    pred = _root_predict(kind, params, keys)
    return jnp.clip((pred * n_leaves / n).astype(jnp.int32), 0, n_leaves - 1)


class LeafFit(NamedTuple):
    """Batched per-leaf fit result (all leaves; see :func:`fit_leaves`)."""
    leaves: Any          # stacked params, (L, ...) per field
    reused: Array        # (L,) bool — Algorithm 1 pool hit
    err_lo: Array        # (L,) sound bounds (sentinel window on empty leaves)
    err_hi: Array        # (L,)
    sim: Array           # (L,) build-time similarity (Lemma 4.1 input)
    count: Array         # (L,) member counts


def fit_leaves(
    keys: Array,
    buckets: Array,
    n_leaves: int,
    kind: str = "linear",
    pool: Optional[ModelPool] = None,
    paper_bounds: bool = False,
    train_steps: int = 300,
    seed: int = 0,
    refit_mask=None,
    sorted_buckets: bool = False,
) -> LeafFit:
    """Fit every leaf of an RMI layer in a handful of batched jit calls:
    Algorithm-1 pool reuse first (batched selection + affine adaptation),
    fresh fits on the misses, residual bounds in one batched predict.

    Shared by :func:`build_rmi` (all leaves) and the dynamic-update rebuild
    path (``core.updates.DynamicRMI._rebuild_leaves``), which passes
    ``refit_mask`` to restrict *training* cost to the leaves being rebuilt —
    rows outside the mask are still populated (one cheap segment fit) but
    callers keep their existing models for them. A ``pool`` whose kind does
    not match ``kind`` is ignored (cross-kind params cannot be merged).
    ``sorted_buckets`` (sound only for a monotone root, i.e. linear) selects
    the scatter-free segment reductions above.
    """
    stats = leaf_stats_sorted if sorted_buckets else leaf_stats
    count, kmin, kmax, pmin, pmax = stats(keys, buckets, n_leaves)
    if pool is not None and pool.kind != kind:
        pool = None
    if pool is not None:
        if pool.sel_a is None:
            pool._refresh_tables()
        if refit_mask is not None and sorted_buckets:
            # Rebuild path: Algorithm-1 selection only for the leaves being
            # re-indexed — histograms via per-range searchsorted and a
            # compacted (pow2-padded) selection batch, scattered back.
            import numpy as np
            rid = np.flatnonzero(np.asarray(refit_mask))
            rp = 1 << max(int(rid.size) - 1, 0).bit_length()
            rid_p = jnp.asarray(np.concatenate(
                [rid, np.full(rp - rid.size, rid[0] if rid.size else 0)])
                .astype(np.int32))
            sel = _select_compact_jit(keys, buckets, rid_p, kmin, kmax,
                                      pool.sel_a, pool.sel_ps,
                                      jnp.float32(pool.eps), m=pool.m,
                                      n_leaves=n_leaves)
        else:
            hists = leaf_histograms(keys, buckets, n_leaves, pool.m, kmin,
                                    kmax)
            sel = select_from_pool_batch(pool.sel_a, pool.sel_ps, hists,
                                         jnp.float32(pool.eps))
        found = sel.found & (count > 1)
        if refit_mask is not None:
            found = found & refit_mask
    else:
        found = jnp.zeros((n_leaves,), bool)

    # ---- fresh fits for missing leaves (batched over all leaves) ---------
    if kind == "linear":
        fit_fn = segment_linear_fit_sorted if sorted_buckets \
            else segment_linear_fit
        fresh = fit_fn(keys, buckets, n_leaves)
    else:
        skip = None
        if pool is not None or refit_mask is not None:
            skip = found if refit_mask is None else found | ~refit_mask
        fresh = _batched_leaf_mlp(keys, buckets, n_leaves, count, kmin, kmax,
                                  pmin, train_steps, seed, skip_mask=skip)

    # ---- merge reused + fresh, derive bounds (one fused jit) --------------
    if pool is not None:
        leaves, err_lo, err_hi, sim = _pool_merge_measure_jit(
            keys, buckets, fresh, found, sel.index, sel.dist, pool.params,
            pool.domains, pool.err_lo, pool.err_hi, count, kmin, kmax, pmin,
            pmax, kind=kind, n_leaves=n_leaves, paper_bounds=paper_bounds,
            sorted_buckets=sorted_buckets)
    else:
        leaves = fresh
        err_lo, err_hi = _measure_bounds_jit(
            keys, buckets, fresh, count, kind=kind, n_leaves=n_leaves,
            sorted_buckets=sorted_buckets)
        sim = jnp.ones((n_leaves,), jnp.float64)
    return LeafFit(leaves=leaves, reused=found, err_lo=err_lo, err_hi=err_hi,
                   sim=sim, count=count)


@functools.partial(jax.jit, static_argnames=("m", "n_leaves"))
def _select_compact_jit(keys, buckets, rid_p, kmin, kmax, sel_a, sel_ps,
                        eps, *, m: int, n_leaves: int):
    """Compacted Algorithm-1 selection (rebuild path): range histograms +
    fused selection for the padded leaf-row batch, scattered back to full
    (L,) selection arrays — one dispatch.  Padding rows repeat a real leaf
    id, so they scatter an identical value onto that row (harmless) and the
    true row count never enters the jit cache key."""
    hist_c = leaf_histograms_ranges(keys, buckets, rid_p, m,
                                    kmin[rid_p], kmax[rid_p])
    sel_c = select_from_pool_batch(sel_a, sel_ps, hist_c, eps)
    return PoolSelection(
        found=jnp.zeros((n_leaves,), bool).at[rid_p].set(sel_c.found),
        index=jnp.zeros((n_leaves,), jnp.int32).at[rid_p].set(sel_c.index),
        dist=jnp.zeros((n_leaves,), jnp.float64).at[rid_p].set(sel_c.dist))


def _sentinel_bounds(err_lo, err_hi, count, n: int):
    """Empty leaves are reachable by out-of-distribution queries: give them
    a sound full-array window (plain binary search fallback)."""
    return (jnp.where(count > 0, err_lo, -float(n)),
            jnp.where(count > 0, err_hi, float(n)))


@functools.partial(jax.jit, static_argnames=("kind", "n_leaves",
                                             "sorted_buckets"))
def _measure_bounds_jit(keys, buckets, leaves, count, *, kind: str,
                        n_leaves: int, sorted_buckets: bool):
    pred = _leaf_predict_all(kind, leaves, keys, buckets)
    bounds_fn = segment_residual_bounds_sorted if sorted_buckets \
        else segment_residual_bounds
    meas_lo, meas_hi = bounds_fn(pred, buckets, n_leaves)
    return _sentinel_bounds(meas_lo, meas_hi, count, keys.shape[0])


@functools.partial(jax.jit, static_argnames=("kind", "n_leaves",
                                             "paper_bounds",
                                             "sorted_buckets"))
def _pool_merge_measure_jit(keys, buckets, fresh, found, sel_index, sel_dist,
                            p_params, p_domains, p_errlo, p_errhi, count,
                            kmin, kmax, pmin, pmax, *, kind: str,
                            n_leaves: int, paper_bounds: bool,
                            sorted_buckets: bool):
    """Adapt the selected pool models (Lemma 3.2 folds), merge with the
    fresh fits, measure residual bounds — the whole tail of fit_leaves in
    one jit (it used to be ~100 eager dispatches on the rebuild path)."""
    src = jax.tree.map(lambda a: a[sel_index], p_domains)
    tgt = DomainSpec(x_start=kmin, x_end=jnp.where(kmax > kmin, kmax, kmin + 1.0),
                     y_start=pmin, y_end=jnp.maximum(pmax, pmin + 1.0))
    pool_params = jax.tree.map(lambda a: a[sel_index], p_params)
    adapt = adapt_linear if kind == "linear" else adapt_mlp
    adapted = jax.vmap(adapt)(pool_params, src, tgt)
    merge = lambda a, f: jnp.where(
        jnp.expand_dims(found, tuple(range(1, a.ndim))), a, f)
    leaves = jax.tree.map(merge, adapted, fresh)

    pred = _leaf_predict_all(kind, leaves, keys, buckets)
    bounds_fn = segment_residual_bounds_sorted if sorted_buckets \
        else segment_residual_bounds
    meas_lo, meas_hi = bounds_fn(pred, buckets, n_leaves)
    if paper_bounds:
        s_dy = (tgt.y_end - tgt.y_start) / (src.y_end - src.y_start)
        thm_lo, thm_hi = reuse_err_bounds(p_errlo[sel_index],
                                          p_errhi[sel_index],
                                          sel_dist, count, s_dy)
        err_lo = jnp.where(found, thm_lo, meas_lo)
        err_hi = jnp.where(found, thm_hi, meas_hi)
    else:
        err_lo, err_hi = meas_lo, meas_hi
    err_lo, err_hi = _sentinel_bounds(err_lo, err_hi, count, keys.shape[0])
    sim = jnp.where(found, 1.0 - sel_dist, 1.0)
    return leaves, err_lo, err_hi, sim


def build_rmi(
    keys: Array,
    n_leaves: int = 1024,
    kind: str = "linear",
    root_kind: str = "linear",
    pool: Optional[ModelPool] = None,
    paper_bounds: bool = False,
    train_steps: int = 300,
    root_subsample: int = 1 << 16,
    seed: int = 0,
) -> RMIIndex:
    """Build a two-layer RMI over a sorted key array.

    With ``pool`` given, every leaf first attempts agile model reuse
    (batched Algorithm 1 across all leaves); only missing leaves are trained.
    ``paper_bounds`` selects Theorem 3.3 bounds verbatim; the default also
    measures residuals (sound and tighter; one batched predict).
    """
    keys = jnp.asarray(keys, jnp.float64)
    n = keys.shape[0]
    if n == 0:
        # Empty partition: sharded builds produce empty shards when n is
        # smaller than the shard count or when equal-count boundaries snap
        # to duplicate-run edges (core.distributed.shard_bounds).  Return a
        # trivial index with zero models and one-slot error windows: every
        # key slot a consumer pads in is +inf, so any finite query resolves
        # to position 0 and seam verification never fires.  Shapes match a
        # real build exactly, so per-shard stacking stays uniform.
        if root_kind != "linear":
            raise ValueError("build_rmi on an empty key array requires a "
                             "linear root (nothing to train an MLP root on)")
        zero = jnp.zeros((), jnp.float64)
        if kind == "linear":
            leaves = models.LinearParams(a=jnp.zeros((n_leaves,), jnp.float64),
                                         b=jnp.zeros((n_leaves,), jnp.float64))
        else:
            leaves = jax.tree.map(
                lambda a: jnp.zeros((n_leaves,) + a.shape, jnp.float64),
                models.mlp_init(jax.random.PRNGKey(0)))
        ones = jnp.ones((n_leaves,), jnp.float64)
        return RMIIndex(keys=keys, root_kind=root_kind,
                        root=models.LinearParams(a=zero, b=zero),
                        leaf_kind=kind, leaves=leaves,
                        err_lo=-ones, err_hi=ones, n_leaves=n_leaves,
                        reused_mask=jnp.zeros((n_leaves,), bool),
                        leaf_sim=ones)
    pos = jnp.arange(n, dtype=jnp.float64)

    # ---- root -----------------------------------------------------------
    if root_kind == "linear":
        root = models.linear_fit(keys, pos)
    else:
        stride = max(1, n // root_subsample)
        sub, subpos = keys[::stride], pos[::stride]
        norm = (sub - keys[0]) / (keys[-1] - keys[0])
        p = models.mlp_train(jax.random.PRNGKey(seed), norm, subpos,
                             steps=train_steps)
        span = keys[-1] - keys[0]
        root = models.MLPParams(w1=p.w1 / span, b1=p.b1 - p.w1 * keys[0] / span,
                                w2=p.w2, b2=p.b2)
    buckets = root_buckets(root_kind, root, keys, n_leaves, n)

    fit = fit_leaves(keys, buckets, n_leaves, kind=kind, pool=pool,
                     paper_bounds=paper_bounds, train_steps=train_steps,
                     seed=seed, sorted_buckets=root_kind == "linear")
    return RMIIndex(keys=keys, root_kind=root_kind, root=root, leaf_kind=kind,
                    leaves=fit.leaves, err_lo=fit.err_lo, err_hi=fit.err_hi,
                    n_leaves=n_leaves, reused_mask=fit.reused,
                    leaf_sim=fit.sim)


def _batched_leaf_mlp(keys, buckets, n_leaves, count, kmin, kmax, pmin,
                      train_steps: int, seed: int, skip_mask=None):
    """Train leaf MLPs, batched. With ``skip_mask`` (reused leaves), only the
    *missing* leaves are compacted into the training batch — this is where
    agile reuse actually saves build time. Host wrapper: padding capacity and
    compaction are data-dependent, so they are materialized here and passed
    static to the jitted trainer (sizes rounded to powers of two to keep the
    jit cache small)."""
    import numpy as np

    def _pow2(v):
        return 1 << max(int(v) - 1, 1).bit_length()

    if skip_mask is None:
        miss = np.arange(n_leaves)
    else:
        miss = np.where(~np.asarray(skip_mask))[0]
    zero = jax.tree.map(
        lambda a: jnp.zeros((n_leaves,) + a.shape, jnp.float64),
        models.mlp_init(jax.random.PRNGKey(0)))
    if miss.size == 0:
        return zero
    K = _pow2(miss.size)
    # Dense leaves are *subsampled* to TRAIN_CAP points for training — a
    # 13-parameter model doesn't need 30k points, and error bounds are
    # measured on the full data afterwards, so correctness is unaffected.
    # This bounds the padded batch at (K, TRAIN_CAP) regardless of skew.
    TRAIN_CAP = 1024
    cap = min(_pow2(max(int(jnp.max(count[miss])), 2)), TRAIN_CAP)
    # Remap buckets: missing leaf -> compact slot; others -> dump slot K.
    slot_of = np.full((n_leaves,), K, np.int32)
    slot_of[miss] = np.arange(miss.size, dtype=np.int32)
    take = lambda a: jnp.concatenate(
        [a[miss], jnp.zeros((K + 1 - miss.size,), a.dtype)])
    p = _padded_leaf_mlp_train(
        keys, jnp.asarray(slot_of)[buckets], K + 1, cap,
        take(kmin), take(jnp.where(kmax > kmin, kmax, kmin + 1.0)),
        take(pmin), take(count), train_steps, seed)
    scat = lambda z, t: z.at[jnp.asarray(miss)].set(t[:miss.size])
    return jax.tree.map(scat, zero, p)


@functools.partial(jax.jit,
                   static_argnames=("n_leaves", "cap", "train_steps", "seed"))
def _padded_leaf_mlp_train(keys, buckets, n_leaves: int, cap: int,
                           kmin, kmax, pmin, count, train_steps: int,
                           seed: int):
    n = keys.shape[0]
    pos = jnp.arange(n, dtype=jnp.float64)
    # Exact within-leaf rank (cumcount) — correct even for non-monotone MLP
    # roots where a leaf's members are not a contiguous key range.
    order = jnp.argsort(buckets, stable=True)
    sb = buckets[order]
    run_start = jnp.searchsorted(sb, jnp.arange(n_leaves))
    offs_sorted = jnp.arange(n, dtype=jnp.int32) - run_start[sb].astype(jnp.int32)
    offs = jnp.zeros((n,), jnp.int32).at[order].set(offs_sorted)
    # Decimate leaves bigger than cap: slot = offs * cap / count (collisions
    # overwrite — still ~cap near-uniformly spaced training points).
    cnt_b = jnp.maximum(count[buckets], 1.0)
    slot = jnp.where(cnt_b > cap,
                     (offs.astype(jnp.float64) * cap / cnt_b).astype(jnp.int32),
                     offs)
    flat = buckets * cap + jnp.clip(slot, 0, cap - 1)
    span = jnp.where(kmax > kmin, kmax - kmin, 1.0)  # single-key leaf guard
    xn = (keys - kmin[buckets]) / span[buckets]              # leaf-normalized
    X = jnp.zeros((n_leaves * cap,), jnp.float64).at[flat].set(xn)
    Y = jnp.zeros((n_leaves * cap,), jnp.float64).at[flat].set(pos)
    M = jnp.zeros((n_leaves * cap,), jnp.float64).at[flat].set(1.0)
    X, Y, M = (v.reshape(n_leaves, cap) for v in (X, Y, M))
    rng = jax.random.split(jax.random.PRNGKey(seed), n_leaves)
    p = jax.vmap(lambda k, x, y, m: models.mlp_train(
        k, x, y, steps=train_steps, mask=m))(rng, X, Y, M)
    # Fold leaf normalization so leaves consume raw keys like pool models do.
    return models.MLPParams(
        w1=p.w1 / span[:, None],
        b1=p.b1 - p.w1 * (kmin / span)[:, None],
        w2=p.w2, b2=p.b2)


@functools.partial(jax.jit, static_argnames=("kind",))
def _leaf_predict_all(kind: str, leaves, keys: Array, buckets: Array) -> Array:
    """Predict every key with its own leaf's model (gather params, elementwise)."""
    p = jax.tree.map(lambda a: a[buckets], leaves)
    if kind == "linear":
        return models.linear_predict(p, keys)
    h = jax.nn.relu(keys[:, None] * p.w1 + p.b1)
    return jnp.sum(h * p.w2, -1) + p.b2


# ---------------------------------------------------------------------------
# Lookup: root -> leaf -> bounded branchless binary search.
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("root_kind", "leaf_kind",
                                             "n_leaves", "n", "iters"))
def rmi_lookup(root_kind: str, root, leaf_kind: str, leaves, err_lo, err_hi,
               keys: Array, queries: Array, n_leaves: int, n: int,
               iters: int | None = None) -> Array:
    """Positions of ``queries`` in ``keys`` (first index with key >= query).

    jnp oracle for the Pallas serving kernel (``repro.kernels.lookup``):
    predict, clamp the window to the leaf's error bounds, then a fixed-
    iteration branchless binary search inside the window. ``iters`` clamps
    the search depth to the index's error window (RMIIndex.search_iters);
    None falls back to the classic ceil(log2 n) + 1.
    """
    b = root_buckets(root_kind, root, queries, n_leaves, n)
    p = jax.tree.map(lambda a: a[b], leaves)
    if leaf_kind == "linear":
        pred = models.linear_predict(p, queries)
    else:
        h = jax.nn.relu(queries[:, None] * p.w1 + p.b1)
        pred = jnp.sum(h * p.w2, -1) + p.b2
    lo = jnp.clip(jnp.floor(pred + err_lo[b]), 0, n - 1).astype(jnp.int32)
    hi = jnp.clip(jnp.ceil(pred + err_hi[b]) + 1, 1, n).astype(jnp.int32)
    return verified_search(keys, queries, lo, hi, iters=iters)


@functools.partial(jax.jit, static_argnames=("iters",))
def verified_search(keys: Array, queries: Array, lo: Array, hi: Array,
                    iters: int | None = None) -> Array:
    """Bounded search + seam verification. Error bounds are measured on the
    indexed keys, so *member* lookups always land; a non-member query routed
    near a leaf boundary can fall outside its leaf's window (and with a
    clamped ``iters`` a query in a sentinel full-array window cannot converge
    in depth). Verify the left-boundary invariant and re-search the full
    array at full depth for the (rare) violations — total lookups stay sound
    for any query distribution."""
    n = keys.shape[0]
    r = bounded_search(keys, queries, lo, hi, iters=iters)
    rc = jnp.clip(r, 0, n - 1)
    valid = ((r == 0) | (keys[jnp.clip(r - 1, 0, n - 1)] < queries)) & \
            ((r == n) | (keys[rc] >= queries))

    def _fallback(_):
        full = bounded_search(keys, queries, jnp.zeros_like(lo),
                              jnp.full_like(hi, n))
        return jnp.where(valid, r, full)

    return jax.lax.cond(jnp.all(valid), lambda _: r, _fallback, None)


@functools.partial(jax.jit, static_argnames=("iters",))
def bounded_search(keys: Array, queries: Array, lo: Array, hi: Array,
                   iters: int | None = None) -> Array:
    """Branchless binary search of each query in keys[lo:hi] (left boundary:
    first position with keys[p] >= q). Fixed iteration count so it vectorizes
    with no data-dependent control flow; ``iters`` defaults to the full
    ceil(log2 n) + 1 and can be clamped to the caller's window bound."""
    n = keys.shape[0]
    if iters is None:
        import math as _math
        iters = _math.ceil(_math.log2(max(n, 2))) + 1

    def body(_, lh):
        lo, hi = lh
        active = hi - lo > 0
        mid = (lo + hi) // 2
        below = keys[jnp.clip(mid, 0, n - 1)] < queries
        new_lo = jnp.where(below, mid + 1, lo)
        new_hi = jnp.where(below, hi, mid)
        return (jnp.where(active, new_lo, lo), jnp.where(active, new_hi, hi))

    lo, _ = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return lo


def lookup(index: RMIIndex, queries: Array, *, path: str = "auto",
           use_kernel: bool | None = None,
           clamp_iters: bool = True) -> Array:
    """Serving lookup. ``path`` selects the execution path (see
    ``core.paths.resolve_path``): ``"kernel"`` is the fused Pallas kernel,
    ``"jnp"`` the CPU fast path / kernel oracle / f64 fallback, and
    ``"auto"`` picks the kernel on TPU backends when the key space is
    exactly f32-representable. Note the kernel path's left boundary is
    defined in f32 key space even for f32-exact keys: a non-member f64
    query within one f32 ulp of a key rounds onto it and returns that
    key's position, where the f64 jnp path returns the position after it.
    ``clamp_iters`` bounds the search depth by the index's error window
    instead of log2(n). ``use_kernel`` is the deprecated bool shim."""
    iters = index.search_iters if clamp_iters else None
    if resolve_path(path, f32_exact=lambda: index.f32_exact,
                    use_kernel=use_kernel):
        from ..kernels import ops as kernel_ops
        from ..kernels.lookup import full_iters
        root, mat, vec = index.packed_tables()
        return kernel_ops.index_lookup(
            jnp.asarray(queries, jnp.float64), root, mat, vec, index.keys,
            n_leaves=index.n_leaves, root_kind=index.root_kind,
            leaf_kind=index.leaf_kind,
            iters=iters if iters is not None else full_iters(index.n))
    return rmi_lookup(index.root_kind, index.root, index.leaf_kind,
                      index.leaves, index.err_lo, index.err_hi, index.keys,
                      jnp.asarray(queries, jnp.float64), index.n_leaves,
                      index.n, iters=iters)
