"""Two-layer RMI (Kraska et al. 2018) with optional agile model reuse
(paper §3 "Learned indices with agile model reuse", Fig. 3).

Variants (matching the paper's experiment roster):
  RMI        root + leaves linear, fresh fits          build_rmi(kind="linear")
  RMI-NN     root linear, leaves 1x4 MLP, fresh        build_rmi(kind="mlp")
  RMI-MR     linear leaves, pool reuse                 build_rmi(..., pool=linear_pool)
  RMI-NN-MR  MLP leaves, pool reuse                    build_rmi(..., pool=mlp_pool)

TPU adaptation: every per-leaf operation is batched across ALL leaves —
segment closed-form fits, per-leaf similarity histograms, pool selection,
affine adaptation, residual bounds — so a build is a handful of jit calls
regardless of leaf count, instead of the paper's per-leaf Python loop.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from . import models
from .adapt import DomainSpec, adapt_linear, adapt_mlp
from .bounds import reuse_err_bounds
from .reuse import ModelPool, select_from_pool_batch

Array = jax.Array


# ---------------------------------------------------------------------------
# Batched per-leaf machinery (shared with RMRT).
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("n_leaves",))
def leaf_stats(keys: Array, buckets: Array, n_leaves: int):
    """Per-leaf (count, key_min, key_max, pos_min, pos_max) via segment ops."""
    n = keys.shape[0]
    pos = jnp.arange(n, dtype=jnp.float64)
    ones = jnp.ones((n,), jnp.float64)
    count = jax.ops.segment_sum(ones, buckets, n_leaves)
    kmin = jax.ops.segment_min(keys, buckets, n_leaves)
    kmax = jax.ops.segment_max(keys, buckets, n_leaves)
    pmin = jax.ops.segment_min(pos, buckets, n_leaves)
    pmax = jax.ops.segment_max(pos, buckets, n_leaves)
    empty = count == 0
    kmin = jnp.where(empty, 0.0, kmin)
    kmax = jnp.where(empty, 1.0, kmax)
    pmin = jnp.where(empty, 0.0, pmin)
    pmax = jnp.where(empty, 0.0, pmax)
    return count, kmin, kmax, pmin, pmax


@functools.partial(jax.jit, static_argnames=("n_leaves", "m"))
def leaf_histograms(keys: Array, buckets: Array, n_leaves: int, m: int,
                    kmin: Array, kmax: Array) -> Array:
    """(n_leaves, m) leaf-normalized similarity histograms, one bincount."""
    span = jnp.maximum(kmax - kmin, jnp.finfo(jnp.float64).tiny)
    x = (keys - kmin[buckets]) / span[buckets]
    b = jnp.clip(jnp.ceil(x * m).astype(jnp.int32) - 1, 0, m - 1)
    flat = buckets * m + b
    counts = jnp.zeros((n_leaves * m,), jnp.float64).at[flat].add(1.0)
    counts = counts.reshape(n_leaves, m)
    tot = jnp.maximum(counts.sum(1, keepdims=True), 1.0)
    return counts / tot


@functools.partial(jax.jit, static_argnames=("n_leaves",))
def segment_linear_fit(keys: Array, buckets: Array, n_leaves: int):
    """Closed-form least-squares (pos on key) per leaf, all leaves at once.
    jnp oracle for the Pallas kernel in ``repro.kernels.linfit``."""
    n = keys.shape[0]
    x = keys.astype(jnp.float64)
    y = jnp.arange(n, dtype=jnp.float64)
    seg = lambda v: jax.ops.segment_sum(v, buckets, n_leaves)
    cnt, sx, sy = seg(jnp.ones_like(x)), seg(x), seg(y)
    sxx, sxy = seg(x * x), seg(x * y)
    denom = cnt * sxx - sx * sx
    a = jnp.where(jnp.abs(denom) > 1e-30, (cnt * sxy - sx * sy) / denom, 0.0)
    b = jnp.where(cnt > 0, (sy - a * sx) / jnp.maximum(cnt, 1.0), 0.0)
    return models.LinearParams(a=a, b=b)


@functools.partial(jax.jit, static_argnames=("n_leaves",))
def segment_residual_bounds(pred: Array, buckets: Array, n_leaves: int):
    """Per-leaf (min, max) of (true position - prediction), batched."""
    n = pred.shape[0]
    r = jnp.arange(n, dtype=jnp.float64) - pred
    lo = jax.ops.segment_min(r, buckets, n_leaves)
    hi = jax.ops.segment_max(r, buckets, n_leaves)
    cnt = jax.ops.segment_sum(jnp.ones((n,)), buckets, n_leaves)
    lo = jnp.where(cnt > 0, lo, 0.0)
    hi = jnp.where(cnt > 0, hi, 0.0)
    return lo, hi


# ---------------------------------------------------------------------------
# The index structure.
# ---------------------------------------------------------------------------
@dataclass
class RMIIndex:
    keys: Array                      # (n,) sorted
    root_kind: str                   # "linear" | "mlp"
    root: models.LinearParams | models.MLPParams
    leaf_kind: str
    leaves: models.LinearParams | models.MLPParams   # stacked (B, ...)
    err_lo: Array                    # (B,)
    err_hi: Array                    # (B,)
    n_leaves: int
    # provenance / reuse accounting (build-time diagnostics)
    reused_mask: Array               # (B,) bool
    leaf_sim: Array                  # (B,) build-time similarity (Lemma 4.1 input)
    # lazily-derived serving state (host-side caches, not build outputs)
    _iters: int | None = None        # error-window search depth
    _packed: tuple | None = None     # (root, mat, vec) kernel tables
    _f32_exact: bool | None = None   # keys round-trip through f32

    @property
    def n(self) -> int:
        return int(self.keys.shape[0])

    @property
    def reuse_fraction(self) -> float:
        return float(jnp.mean(self.reused_mask.astype(jnp.float64)))

    @property
    def search_iters(self) -> int:
        """Static per-query search depth bounded by the error window (§4)."""
        if self._iters is None:
            from ..kernels.lookup import search_iters
            self._iters = search_iters(self.err_lo, self.err_hi, self.n)
        return self._iters

    @property
    def f32_exact(self) -> bool:
        """True when every key round-trips through f32 — the precondition
        for the Pallas kernel path, which searches (and seam-verifies) in
        f32: distinct f64 keys that collide in f32 would resolve to wrong
        positions undetectably."""
        if self._f32_exact is None:
            k32 = self.keys.astype(jnp.float32).astype(jnp.float64)
            self._f32_exact = bool(jnp.all(k32 == self.keys))
        return self._f32_exact

    def packed_tables(self) -> tuple:
        """(root, mat, vec) VMEM-layout tables for the fused Pallas kernel."""
        if self._packed is None:
            from ..kernels import lookup as _lk
            root = _lk.pack_root(self.root_kind, self.root)
            w1, b1, w2, b2 = _leaf_table_arrays(self.leaf_kind, self.leaves,
                                                self.n_leaves)
            mat, vec = _lk.pack_leaves(w1, b1, w2, b2, self.err_lo,
                                       self.err_hi)
            self._packed = (root, mat, vec)
        return self._packed


def _leaf_table_arrays(kind: str, leaves, n_leaves: int):
    """Uniform (L, H)/(L,) leaf tables for either leaf kind (linear models
    ride in w1[:, 0] / b2, mirroring the kernel's linear fast path)."""
    if kind == "linear":
        w1 = jnp.zeros((n_leaves, models.HIDDEN),
                       jnp.float32).at[:, 0].set(leaves.a.astype(jnp.float32))
        zeros = jnp.zeros((n_leaves, models.HIDDEN), jnp.float32)
        return w1, zeros, zeros, leaves.b
    return leaves.w1, leaves.b1, leaves.w2, leaves.b2


def _root_predict(kind, params, keys):
    return (models.linear_predict if kind == "linear"
            else models.mlp_predict)(params, keys)


@functools.partial(jax.jit, static_argnames=("kind", "n_leaves", "n"))
def root_buckets(kind: str, params, keys: Array, n_leaves: int, n: int) -> Array:
    pred = _root_predict(kind, params, keys)
    return jnp.clip((pred * n_leaves / n).astype(jnp.int32), 0, n_leaves - 1)


def build_rmi(
    keys: Array,
    n_leaves: int = 1024,
    kind: str = "linear",
    root_kind: str = "linear",
    pool: Optional[ModelPool] = None,
    paper_bounds: bool = False,
    train_steps: int = 300,
    root_subsample: int = 1 << 16,
    seed: int = 0,
) -> RMIIndex:
    """Build a two-layer RMI over a sorted key array.

    With ``pool`` given, every leaf first attempts agile model reuse
    (batched Algorithm 1 across all leaves); only missing leaves are trained.
    ``paper_bounds`` selects Theorem 3.3 bounds verbatim; the default also
    measures residuals (sound and tighter; one batched predict).
    """
    keys = jnp.asarray(keys, jnp.float64)
    n = keys.shape[0]
    pos = jnp.arange(n, dtype=jnp.float64)

    # ---- root -----------------------------------------------------------
    if root_kind == "linear":
        root = models.linear_fit(keys, pos)
    else:
        stride = max(1, n // root_subsample)
        sub, subpos = keys[::stride], pos[::stride]
        norm = (sub - keys[0]) / (keys[-1] - keys[0])
        p = models.mlp_train(jax.random.PRNGKey(seed), norm, subpos,
                             steps=train_steps)
        span = keys[-1] - keys[0]
        root = models.MLPParams(w1=p.w1 / span, b1=p.b1 - p.w1 * keys[0] / span,
                                w2=p.w2, b2=p.b2)
    buckets = root_buckets(root_kind, root, keys, n_leaves, n)

    # ---- per-leaf stats + reuse selection --------------------------------
    count, kmin, kmax, pmin, pmax = leaf_stats(keys, buckets, n_leaves)
    if pool is not None:
        if pool.sel_a is None:
            pool._refresh_tables()
        hists = leaf_histograms(keys, buckets, n_leaves, pool.m, kmin, kmax)
        sel = select_from_pool_batch(pool.sel_a, pool.sel_ps, hists,
                                     jnp.float32(pool.eps))
        found = sel.found & (count > 1)
        src = jax.tree.map(lambda a: a[sel.index], pool.domains)
        tgt = DomainSpec(x_start=kmin, x_end=jnp.where(kmax > kmin, kmax, kmin + 1.0),
                         y_start=pmin, y_end=jnp.maximum(pmax, pmin + 1.0))
        pool_params = jax.tree.map(lambda a: a[sel.index], pool.params)
        adapt = adapt_linear if pool.kind == "linear" else adapt_mlp
        adapted = jax.vmap(adapt)(pool_params, src, tgt)
        s_dy = (tgt.y_end - tgt.y_start) / (src.y_end - src.y_start)
        thm_lo, thm_hi = reuse_err_bounds(pool.err_lo[sel.index],
                                          pool.err_hi[sel.index],
                                          sel.dist, count, s_dy)
    else:
        found = jnp.zeros((n_leaves,), bool)

    # ---- fresh fits for missing leaves (batched over all leaves) ---------
    if kind == "linear":
        fresh = segment_linear_fit(keys, buckets, n_leaves)
    else:
        fresh = _batched_leaf_mlp(keys, buckets, n_leaves, count, kmin, kmax,
                                  pmin, train_steps, seed,
                                  skip_mask=found if pool is not None else None)

    # ---- merge reused + fresh, derive bounds ------------------------------
    if pool is not None and pool.kind == kind:
        merge = lambda a, f: jnp.where(
            jnp.expand_dims(found, tuple(range(1, a.ndim))), a, f)
        leaves = jax.tree.map(merge, adapted, fresh)
    else:
        leaves = fresh
        found = jnp.zeros((n_leaves,), bool)

    pred = _leaf_predict_all(kind, leaves, keys, buckets)
    meas_lo, meas_hi = segment_residual_bounds(pred, buckets, n_leaves)
    if pool is not None and paper_bounds:
        err_lo = jnp.where(found, thm_lo, meas_lo)
        err_hi = jnp.where(found, thm_hi, meas_hi)
    else:
        err_lo, err_hi = meas_lo, meas_hi
    # Empty leaves are reachable by out-of-distribution queries: give them a
    # sound full-array window (plain binary search fallback).
    err_lo = jnp.where(count > 0, err_lo, -float(n))
    err_hi = jnp.where(count > 0, err_hi, float(n))

    leaf_sim = jnp.where(found, 1.0 - sel.dist, 1.0) if pool is not None \
        else jnp.ones((n_leaves,), jnp.float64)

    return RMIIndex(keys=keys, root_kind=root_kind, root=root, leaf_kind=kind,
                    leaves=leaves, err_lo=err_lo, err_hi=err_hi,
                    n_leaves=n_leaves, reused_mask=found, leaf_sim=leaf_sim)


def _batched_leaf_mlp(keys, buckets, n_leaves, count, kmin, kmax, pmin,
                      train_steps: int, seed: int, skip_mask=None):
    """Train leaf MLPs, batched. With ``skip_mask`` (reused leaves), only the
    *missing* leaves are compacted into the training batch — this is where
    agile reuse actually saves build time. Host wrapper: padding capacity and
    compaction are data-dependent, so they are materialized here and passed
    static to the jitted trainer (sizes rounded to powers of two to keep the
    jit cache small)."""
    import numpy as np

    def _pow2(v):
        return 1 << max(int(v) - 1, 1).bit_length()

    if skip_mask is None:
        miss = np.arange(n_leaves)
    else:
        miss = np.where(~np.asarray(skip_mask))[0]
    zero = jax.tree.map(
        lambda a: jnp.zeros((n_leaves,) + a.shape, jnp.float64),
        models.mlp_init(jax.random.PRNGKey(0)))
    if miss.size == 0:
        return zero
    K = _pow2(miss.size)
    # Dense leaves are *subsampled* to TRAIN_CAP points for training — a
    # 13-parameter model doesn't need 30k points, and error bounds are
    # measured on the full data afterwards, so correctness is unaffected.
    # This bounds the padded batch at (K, TRAIN_CAP) regardless of skew.
    TRAIN_CAP = 1024
    cap = min(_pow2(max(int(jnp.max(count[miss])), 2)), TRAIN_CAP)
    # Remap buckets: missing leaf -> compact slot; others -> dump slot K.
    slot_of = np.full((n_leaves,), K, np.int32)
    slot_of[miss] = np.arange(miss.size, dtype=np.int32)
    take = lambda a: jnp.concatenate(
        [a[miss], jnp.zeros((K + 1 - miss.size,), a.dtype)])
    p = _padded_leaf_mlp_train(
        keys, jnp.asarray(slot_of)[buckets], K + 1, cap,
        take(kmin), take(jnp.where(kmax > kmin, kmax, kmin + 1.0)),
        take(pmin), take(count), train_steps, seed)
    scat = lambda z, t: z.at[jnp.asarray(miss)].set(t[:miss.size])
    return jax.tree.map(scat, zero, p)


@functools.partial(jax.jit,
                   static_argnames=("n_leaves", "cap", "train_steps", "seed"))
def _padded_leaf_mlp_train(keys, buckets, n_leaves: int, cap: int,
                           kmin, kmax, pmin, count, train_steps: int,
                           seed: int):
    n = keys.shape[0]
    pos = jnp.arange(n, dtype=jnp.float64)
    # Exact within-leaf rank (cumcount) — correct even for non-monotone MLP
    # roots where a leaf's members are not a contiguous key range.
    order = jnp.argsort(buckets, stable=True)
    sb = buckets[order]
    run_start = jnp.searchsorted(sb, jnp.arange(n_leaves))
    offs_sorted = jnp.arange(n, dtype=jnp.int32) - run_start[sb].astype(jnp.int32)
    offs = jnp.zeros((n,), jnp.int32).at[order].set(offs_sorted)
    # Decimate leaves bigger than cap: slot = offs * cap / count (collisions
    # overwrite — still ~cap near-uniformly spaced training points).
    cnt_b = jnp.maximum(count[buckets], 1.0)
    slot = jnp.where(cnt_b > cap,
                     (offs.astype(jnp.float64) * cap / cnt_b).astype(jnp.int32),
                     offs)
    flat = buckets * cap + jnp.clip(slot, 0, cap - 1)
    span = jnp.where(kmax > kmin, kmax - kmin, 1.0)  # single-key leaf guard
    xn = (keys - kmin[buckets]) / span[buckets]              # leaf-normalized
    X = jnp.zeros((n_leaves * cap,), jnp.float64).at[flat].set(xn)
    Y = jnp.zeros((n_leaves * cap,), jnp.float64).at[flat].set(pos)
    M = jnp.zeros((n_leaves * cap,), jnp.float64).at[flat].set(1.0)
    X, Y, M = (v.reshape(n_leaves, cap) for v in (X, Y, M))
    rng = jax.random.split(jax.random.PRNGKey(seed), n_leaves)
    p = jax.vmap(lambda k, x, y, m: models.mlp_train(
        k, x, y, steps=train_steps, mask=m))(rng, X, Y, M)
    # Fold leaf normalization so leaves consume raw keys like pool models do.
    return models.MLPParams(
        w1=p.w1 / span[:, None],
        b1=p.b1 - p.w1 * (kmin / span)[:, None],
        w2=p.w2, b2=p.b2)


@functools.partial(jax.jit, static_argnames=("kind",))
def _leaf_predict_all(kind: str, leaves, keys: Array, buckets: Array) -> Array:
    """Predict every key with its own leaf's model (gather params, elementwise)."""
    p = jax.tree.map(lambda a: a[buckets], leaves)
    if kind == "linear":
        return models.linear_predict(p, keys)
    h = jax.nn.relu(keys[:, None] * p.w1 + p.b1)
    return jnp.sum(h * p.w2, -1) + p.b2


# ---------------------------------------------------------------------------
# Lookup: root -> leaf -> bounded branchless binary search.
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("root_kind", "leaf_kind",
                                             "n_leaves", "n", "iters"))
def rmi_lookup(root_kind: str, root, leaf_kind: str, leaves, err_lo, err_hi,
               keys: Array, queries: Array, n_leaves: int, n: int,
               iters: int | None = None) -> Array:
    """Positions of ``queries`` in ``keys`` (first index with key >= query).

    jnp oracle for the Pallas serving kernel (``repro.kernels.lookup``):
    predict, clamp the window to the leaf's error bounds, then a fixed-
    iteration branchless binary search inside the window. ``iters`` clamps
    the search depth to the index's error window (RMIIndex.search_iters);
    None falls back to the classic ceil(log2 n) + 1.
    """
    b = root_buckets(root_kind, root, queries, n_leaves, n)
    p = jax.tree.map(lambda a: a[b], leaves)
    if leaf_kind == "linear":
        pred = models.linear_predict(p, queries)
    else:
        h = jax.nn.relu(queries[:, None] * p.w1 + p.b1)
        pred = jnp.sum(h * p.w2, -1) + p.b2
    lo = jnp.clip(jnp.floor(pred + err_lo[b]), 0, n - 1).astype(jnp.int32)
    hi = jnp.clip(jnp.ceil(pred + err_hi[b]) + 1, 1, n).astype(jnp.int32)
    return verified_search(keys, queries, lo, hi, iters=iters)


@functools.partial(jax.jit, static_argnames=("iters",))
def verified_search(keys: Array, queries: Array, lo: Array, hi: Array,
                    iters: int | None = None) -> Array:
    """Bounded search + seam verification. Error bounds are measured on the
    indexed keys, so *member* lookups always land; a non-member query routed
    near a leaf boundary can fall outside its leaf's window (and with a
    clamped ``iters`` a query in a sentinel full-array window cannot converge
    in depth). Verify the left-boundary invariant and re-search the full
    array at full depth for the (rare) violations — total lookups stay sound
    for any query distribution."""
    n = keys.shape[0]
    r = bounded_search(keys, queries, lo, hi, iters=iters)
    rc = jnp.clip(r, 0, n - 1)
    valid = ((r == 0) | (keys[jnp.clip(r - 1, 0, n - 1)] < queries)) & \
            ((r == n) | (keys[rc] >= queries))

    def _fallback(_):
        full = bounded_search(keys, queries, jnp.zeros_like(lo),
                              jnp.full_like(hi, n))
        return jnp.where(valid, r, full)

    return jax.lax.cond(jnp.all(valid), lambda _: r, _fallback, None)


@functools.partial(jax.jit, static_argnames=("iters",))
def bounded_search(keys: Array, queries: Array, lo: Array, hi: Array,
                   iters: int | None = None) -> Array:
    """Branchless binary search of each query in keys[lo:hi] (left boundary:
    first position with keys[p] >= q). Fixed iteration count so it vectorizes
    with no data-dependent control flow; ``iters`` defaults to the full
    ceil(log2 n) + 1 and can be clamped to the caller's window bound."""
    n = keys.shape[0]
    if iters is None:
        import math as _math
        iters = _math.ceil(_math.log2(max(n, 2))) + 1

    def body(_, lh):
        lo, hi = lh
        active = hi - lo > 0
        mid = (lo + hi) // 2
        below = keys[jnp.clip(mid, 0, n - 1)] < queries
        new_lo = jnp.where(below, mid + 1, lo)
        new_hi = jnp.where(below, hi, mid)
        return (jnp.where(active, new_lo, lo), jnp.where(active, new_hi, hi))

    lo, _ = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return lo


def lookup(index: RMIIndex, queries: Array, *, use_kernel: bool | None = None,
           clamp_iters: bool = True) -> Array:
    """Serving lookup. ``use_kernel`` selects the fused Pallas kernel
    (default: on TPU backends, and only when the key space is exactly
    f32-representable — the kernel searches and seam-verifies in f32, so
    f32-colliding f64 keys would resolve wrongly; the jnp path is the CPU
    fast path, the kernel's oracle, and the f64 fallback). Note the kernel
    path's left boundary is defined in f32 key space even for f32-exact
    keys: a non-member f64 query within one f32 ulp of a key rounds onto it
    and returns that key's position, where the f64 jnp path returns the
    position after it. ``clamp_iters`` bounds the search depth by the
    index's error window instead of log2(n)."""
    iters = index.search_iters if clamp_iters else None
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu" and index.f32_exact
    elif use_kernel and not index.f32_exact:
        raise ValueError(
            "use_kernel=True on a key space that is not f32-exact: the "
            "kernel's f32 seam verification cannot detect f32 key "
            "collisions, so wrong positions would be returned silently")
    if use_kernel:
        from ..kernels import ops as kernel_ops
        from ..kernels.lookup import full_iters
        root, mat, vec = index.packed_tables()
        return kernel_ops.index_lookup(
            jnp.asarray(queries, jnp.float64), root, mat, vec, index.keys,
            n_leaves=index.n_leaves, root_kind=index.root_kind,
            leaf_kind=index.leaf_kind,
            iters=iters if iters is not None else full_iters(index.n))
    return rmi_lookup(index.root_kind, index.root, index.leaf_kind,
                      index.leaves, index.err_lo, index.err_hi, index.keys,
                      jnp.asarray(queries, jnp.float64), index.n_leaves,
                      index.n, iters=iters)
