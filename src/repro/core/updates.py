"""Update handling (paper §4), two-tier and device-resident.

Architecture (PR 2; replaces the per-leaf host Python buffers of the seed):
the *base* tier is the immutable sorted key array served by the RMI, and all
inserts live in a single sorted device-resident *delta* tier with a routed-
leaf table, so the update path rides the same vectorized/jit machinery as
the lookup path:

  insert_batch   one route-sort-merge on device: root-route the batch
                 (vectorized), merge it into the sorted delta tier (argsort
                 gather, tombstoned entries purged in the same pass), bump
                 per-leaf Lemma 4.1 counters with one bincount.
  delete_batch   tombstones as *bitmaps* aligned to each tier (plus exclusive
                 prefix sums for rank arithmetic), marked by one vectorized
                 scatter — a delete of a key still sitting in the delta tier
                 marks the buffered entry itself (the seed's query-value
                 tombstone set left it live forever).
  find           (found, rank) in one fused pass: base window search + delta
                 probe + tombstone mask.  ``rank`` counts *live* keys < q
                 across BOTH tiers (the seed composed base_pos with only the
                 routed leaf's buffer, dropping buffered inserts in earlier
                 leaves).  On TPU (or ``use_kernel=True``) the whole pass is
                 one Pallas kernel call (``kernels.ops.dynamic_index_lookup``);
                 the jnp path here is its f64 oracle and the CPU fast path.
  rebuild        Lemma 4.1 budget exhaustion triggers a *batched* leaf
                 rebuild: the affected leaves' delta entries merge into the
                 base in one sorted merge, and the leaves are re-indexed via
                 pool selection (Algorithm 1 reuse first, refit on miss —
                 ``rmi.fit_leaves``).  Untouched leaves are position-shifted
                 exactly (monotone linear root) or bound-widened (MLP root),
                 and the clamped search depth is recomputed *incrementally*
                 from a maintained per-leaf window-width vector (ROADMAP
                 "Update path x clamped depth") instead of being invalidated.

  maybe_swap     drift-adaptive maintenance (PR 10; ``core.drift``): an
                 online binned KS score over inserted keys vs the build-time
                 CDF drives a ``drift_hi``/``drift_lo`` hysteresis latch.
                 When latched, at-risk leaves (pressure past a quarter of
                 their Lemma 4.1 budget) take an Algorithm-1 pool hot-swap in
                 ONE fused jit — select, adapt, bound-check, commit — where a
                 commit requires the refreshed budget to cover the buffered
                 inserts and the new window to fit the current width cap, so
                 the clamped search depth (and every jit keyed on it) is
                 untouched: zero retraces across commits.  A committed swap
                 starts a fresh budget epoch (``n_inserts`` resets — the
                 bound check paid for the buffered inserts).  In swap mode
                 (``swap_on_drift=True``) the insert path defers ALL
                 structural repair here: budget-exhausted leaves wait for the
                 idle-window maintenance pass, which sweeps them with the
                 ordinary refit when a swap cannot absorb them.

Routing is frozen at build time (``route_n``): the root model plus the
build-time key count define a pure key->leaf hash, so base merges never
remap existing keys between leaves and insert-time routing always matches
find-time routing.

Semantics notes: duplicate keys across tiers are counted as a multiset by
``rank``; ``delete`` removes one (the leftmost live) occurrence of a key.
The delta tier is stored at power-of-two capacity with +inf padding so its
shape — and therefore the jit cache — only changes on capacity doubling.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp
import numpy as np

from . import drift as drift_mod
from . import models
from . import rmi as rmi_mod
from .bounds import (clamped_depth, insertion_budget, insertion_headroom,
                     window_widths)
from .paths import resolve_path
from .reuse import ModelPool

Array = jax.Array

_MIN_CAP = 128      # delta-tier floor: one kernel lane tile
_COMPACT_RATIO = 0.25   # default delta-tier dead fraction before compaction


def _pow2ceil(v: int) -> int:
    return 1 << max(int(v) - 1, 1).bit_length()


def _capacity(n: int) -> int:
    """Tier capacity bucket (kernels.lookup.capacity_class with the
    _MIN_CAP floor): shapes only change on pow2 crossings."""
    from ..kernels.lookup import capacity_class
    return capacity_class(n, floor=_MIN_CAP)


# ---------------------------------------------------------------------------
# Jitted tier primitives (module-level so tests can count dispatches).
# ---------------------------------------------------------------------------
def _compact_sorted(keys: Array, keep: Array, payloads: tuple,
                    fills: tuple) -> tuple:
    """Drop ``~keep`` entries from a sorted array, backfilling +inf /
    ``fills``: target slot of a kept entry is its index minus the dropped
    count before it (one cumsum + scatter — order, hence sortedness, is
    preserved with no sort)."""
    cap = keys.shape[0]
    tgt = jnp.arange(cap) - jnp.cumsum(~keep) + (~keep)   # exclusive cumsum
    tgt = jnp.where(keep, tgt, cap)
    ck = jnp.full((cap,), jnp.inf, keys.dtype).at[tgt].set(keys, mode="drop")
    cp = tuple(jnp.full((cap,), f, p.dtype).at[tgt].set(p, mode="drop")
               for p, f in zip(payloads, fills, strict=True))
    return ck, cp


def _merge_sorted(ak: Array, bk: Array, cap_out: int, a_payloads: tuple,
                  b_payloads: tuple, fills: tuple) -> tuple:
    """Gather-merge of two sorted, +inf-padded arrays (with payloads).

    XLA's CPU sort and scatters are far too slow for the update hot path;
    since both inputs are sorted, the merged position of every ``bk`` entry
    is one searchsorted (ties: ``ak``'s equal run first), and each *output*
    slot then resolves to a pure gather: slot i holds ``bk[bl]`` if the i-th
    merged element is from ``bk`` (bl = #b-positions < i, membership via a
    second searchsorted over the sorted position list), else ``ak[i - bl]``.
    Output re-padded/truncated to ``cap_out`` (callers guarantee every
    finite entry fits).
    """
    na, nb = ak.shape[0], bk.shape[0]
    if nb == 0:                      # drop-only call: resize ak alone
        pad = max(cap_out - na, 0)
        ext = lambda x, f: jnp.concatenate(
            [x, jnp.full((pad,), f, x.dtype)])[:cap_out]
        return ext(ak, jnp.inf), tuple(
            ext(pa, f) for pa, f in zip(a_payloads, fills, strict=True))
    # One small-side searchsorted (nb queries; XLA's searchsorted costs
    # ~O(queries), so keep the big side out of the query slot), then the
    # per-slot source map comes from a bincount + cumsum over the output:
    # ind[i] = 1 iff slot i holds a b element, bl[i] = #b slots before i.
    posb = jnp.arange(nb) + jnp.searchsorted(ak, bk, side="right")  # sorted
    i = jnp.arange(cap_out)
    ind = jnp.bincount(posb, length=cap_out)          # oob posb drop (trunc)
    cum = jnp.cumsum(ind)
    from_b = ind > 0
    bl = cum - ind                                    # exclusive
    ai = jnp.clip(i - bl, 0, na - 1)
    bi = jnp.clip(bl, 0, nb - 1)
    in_range = i < na + nb
    out = jnp.where(in_range & from_b, bk[bi],
                    jnp.where(in_range, ak[ai], jnp.inf))
    outp = tuple(
        jnp.where(in_range & from_b, pb[bi],
                  jnp.where(in_range, pa[ai], f))
        for pa, pb, f in zip(a_payloads, b_payloads, fills, strict=True))
    return out, outp


@functools.partial(jax.jit, static_argnames=("cap_out",))
def _merge_delta_jit(dk: Array, dleaf: Array, ddead: Array,
                     new_k: Array, new_leaf: Array, cap_out: int):
    """Sorted merge of a routed (pre-sorted) batch into the delta tier.

    Tombstoned entries are purged by the compaction pass, so the returned
    tier is all-live: callers reset the dead bitmap/prefix sum to zeros.
    Sort-free: one cumsum compaction + one searchsorted gather-merge.
    """
    ck, (cl,) = _compact_sorted(dk, jnp.isfinite(dk) & ~ddead, (dleaf,),
                                (jnp.int32(-1),))
    allk, (alll,) = _merge_sorted(
        ck, new_k.astype(jnp.float64), cap_out, (cl,),
        (new_leaf.astype(jnp.int32),), (jnp.int32(-1),))
    return allk, alll


@functools.partial(jax.jit, static_argnames=("cap_out",))
def _merge_delta_clean_jit(dk: Array, dleaf: Array, new_k: Array,
                           new_leaf: Array, cap_out: int):
    """:func:`_merge_delta_jit` fast path for a tier with no tombstones
    (the common case, tracked host-side): skips the compaction scatter."""
    allk, (alll,) = _merge_sorted(
        dk, new_k.astype(jnp.float64), cap_out, (dleaf,),
        (new_leaf.astype(jnp.int32),), (jnp.int32(-1),))
    return allk, alll


@functools.partial(jax.jit, static_argnames=("cap_out",))
def _fill_delta_jit(new_k: Array, new_leaf: Array, cap_out: int):
    """Insert into an *empty* delta tier: the sorted batch plus padding."""
    pad = cap_out - new_k.shape[0]
    return (jnp.concatenate([new_k.astype(jnp.float64),
                             jnp.full((pad,), jnp.inf, jnp.float64)]),
            jnp.concatenate([new_leaf.astype(jnp.int32),
                             jnp.full((pad,), -1, jnp.int32)]))


@functools.partial(jax.jit, static_argnames=("n_leaves",))
def _batch_counts_sorted(lv: Array, n_leaves: int) -> Array:
    """Per-leaf counts of a routed batch under a monotone root (``lv`` is
    non-decreasing): searchsorted run lengths, no bincount scatter."""
    lid = jnp.arange(n_leaves)
    return jnp.searchsorted(lv, lid, side="right") - \
        jnp.searchsorted(lv, lid, side="left")


@jax.jit
def _moved_counts_sorted(dleaf: Array, rmask: Array) -> Array:
    """Per-leaf live delta counts restricted to ``rmask`` leaves, for a
    tombstone-free tier under a *monotone* root (leaf ids non-decreasing
    over the sorted keys): searchsorted run lengths, no bincount scatter."""
    L = rmask.shape[0]
    arr = jnp.where(dleaf >= 0, dleaf, L)
    lid = jnp.arange(L)
    cnt = jnp.searchsorted(arr, lid, side="right") - \
        jnp.searchsorted(arr, lid, side="left")
    return jnp.where(rmask, cnt, 0)


@jax.jit
def _psum(dead: Array) -> Array:
    """Exclusive prefix sum of a tombstone bitmap, length n + 1 (so a gather
    at position n yields the total dead count)."""
    return jnp.concatenate([jnp.zeros((1,), jnp.int32),
                            jnp.cumsum(dead.astype(jnp.int32))])


@jax.jit
def _delete_jit(base_keys: Array, base_dead: Array, dk: Array, ddead: Array,
                q: Array):
    """Mark one live occurrence of each query dead: delta tier first (the
    most recent insert), base on a delta miss.  Absent keys are no-ops.

    Duplicates: within an equal-key run tombstones always form a *prefix*
    (this function only ever kills the first live slot, and the order-
    preserving merges keep runs intact), so the first live slot of a run is
    ``run_lo + #dead-in-run`` — repeated deletes of a duplicated key retire
    one copy each.  Duplicate keys within a single batch collapse to one
    removal (same target slot); the returned per-tier counts are exact
    (bitmap population deltas, not per-query hit sums).
    """
    def mark(keys, dead, skip):
        n = keys.shape[0]
        psum = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                jnp.cumsum(dead.astype(jnp.int32))])
        lo = jnp.searchsorted(keys, q, side="left")
        hi = jnp.searchsorted(keys, q, side="right")
        tgt = lo + (psum[hi] - psum[lo])
        hit = (tgt < hi) & ~skip
        return dead.at[jnp.where(hit, tgt, n)].set(True, mode="drop"), hit

    new_ddead, dhit = mark(dk, ddead, jnp.zeros(q.shape, bool))
    new_bdead, _ = mark(base_keys, base_dead, dhit)
    nb = jnp.sum(new_bdead) - jnp.sum(base_dead)
    ndel = jnp.sum(new_ddead) - jnp.sum(ddead)
    return new_bdead, new_ddead, nb, ndel


@jax.jit
def _shed_suffix_jit(keys: Array, dead: Array, cut):
    """Truncate a sorted +inf-padded tier at position ``cut``: entries
    [cut:] become +inf padding / cleared tombstones.  Survivor positions
    are unchanged.  Returns (keys, dead, #tombstones dropped)."""
    keep = jnp.arange(keys.shape[0]) < cut
    nd = dead & keep
    return jnp.where(keep, keys, jnp.inf), nd, jnp.sum(dead) - jnp.sum(nd)


@jax.jit
def _shed_suffix_delta_jit(keys: Array, leaf: Array, dead: Array, cut):
    """:func:`_shed_suffix_jit` with the routed-leaf payload."""
    keep = jnp.arange(keys.shape[0]) < cut
    nd = dead & keep
    return (jnp.where(keep, keys, jnp.inf), jnp.where(keep, leaf, -1), nd,
            jnp.sum(dead) - jnp.sum(nd))


@jax.jit
def _shed_prefix_jit(keys: Array, dead: Array, cut):
    """Drop the first ``cut`` slots of a sorted +inf-padded tier and
    compact left (one gather — order preserved, tail re-padded).  Survivor
    positions all shift down by exactly ``cut``."""
    n = keys.shape[0]
    src = jnp.arange(n) + cut
    ok = src < n
    srcc = jnp.clip(src, 0, n - 1)
    nd = jnp.where(ok, dead[srcc], False)
    return (jnp.where(ok, keys[srcc], jnp.inf), nd,
            jnp.sum(dead) - jnp.sum(nd))


@jax.jit
def _shed_prefix_delta_jit(keys: Array, leaf: Array, dead: Array, cut):
    """:func:`_shed_prefix_jit` with the routed-leaf payload."""
    n = keys.shape[0]
    src = jnp.arange(n) + cut
    ok = src < n
    srcc = jnp.clip(src, 0, n - 1)
    nd = jnp.where(ok, dead[srcc], False)
    return (jnp.where(ok, keys[srcc], jnp.inf),
            jnp.where(ok, leaf[srcc], -1), nd,
            jnp.sum(dead) - jnp.sum(nd))


def leaf_window(leaves, err_lo, err_hi, b, q, n: int, leaf_kind: str):
    """Routed-leaf predict + error-bound window clip (shared by
    :func:`_find_jit` and the sharded per-shard path in
    ``core.distributed`` — only the *routing* that produces ``b``
    differs between them)."""
    p = jax.tree.map(lambda a: a[b], leaves)
    if leaf_kind == "linear":
        pred = models.linear_predict(p, q)
    else:
        h = jax.nn.relu(q[:, None] * p.w1 + p.b1)
        pred = jnp.sum(h * p.w2, -1) + p.b2
    lo = jnp.clip(jnp.floor(pred + err_lo[b]), 0, n - 1).astype(jnp.int32)
    hi = jnp.clip(jnp.ceil(pred + err_hi[b]) + 1, 1, n).astype(jnp.int32)
    return lo, hi


def two_tier_answer(base_keys, base_psum, dk, dpsum, q, lo, hi, iters: int):
    """The two-tier find tail, shared by :func:`_find_jit` and the sharded
    per-shard jnp path (``core.distributed``): seam-verified base window
    search, then the tombstone-mask / live-rank algebra.  A hit is any
    *live* entry in the equal-key run [pos, right): count live slots via
    the tombstone prefix sums (robust to partially tombstoned duplicate
    runs).  Returns (found, rank, base_pos)."""
    pos = rmi_mod.verified_search(base_keys, q, lo, hi, iters=iters)
    bhi = jnp.searchsorted(base_keys, q, side="right").astype(jnp.int32)
    base_hit = (bhi - pos) > (base_psum[bhi] - base_psum[pos])
    dpos = jnp.searchsorted(dk, q, side="left").astype(jnp.int32)
    dhi = jnp.searchsorted(dk, q, side="right").astype(jnp.int32)
    delta_hit = (dhi - dpos) > (dpsum[dhi] - dpsum[dpos])
    rank = (pos - base_psum[pos]) + (dpos - dpsum[dpos])
    return base_hit | delta_hit, rank, pos


@functools.partial(jax.jit, static_argnames=(
    "root_kind", "leaf_kind", "n_leaves", "route_n", "iters"))
def _find_jit(root, leaves, err_lo, err_hi, base_keys, base_dead, base_psum,
              dk, ddead, dpsum, q, *, root_kind: str, leaf_kind: str,
              n_leaves: int, route_n: int, iters: int):
    """f64 oracle of the fused dynamic kernel: base window search + delta
    probe + tombstone mask, one jit. Returns (found, rank, base_pos)."""
    n = base_keys.shape[0]
    b = rmi_mod.root_buckets(root_kind, root, q, n_leaves, route_n)
    lo, hi = leaf_window(leaves, err_lo, err_hi, b, q, n, leaf_kind)
    return two_tier_answer(base_keys, base_psum, dk, dpsum, q, lo, hi, iters)


def two_tier_range_answer(base_keys, base_psum, dk, dpsum, q_lo, q_hi,
                          lo, hi, iters: int):
    """:func:`two_tier_answer` generalized to an endpoint pair — the
    two-tier range tail shared by :func:`_range_find_jit` and the sharded
    per-shard jnp path (``core.distributed``).  ``rank_lo`` counts live
    keys < q_lo (leftmost boundary: the learned route + verified window
    search, exactly the point path); ``rank_hi`` counts live keys <= q_hi
    (rightmost boundary under duplicates: the side='right' searchsorted
    the point path already pays for its duplicate-run hit test).  rank_hi
    is clamped to rank_lo, so degenerate ranges (q_lo > q_hi, a tombstoned
    singleton, fully out-of-range windows) return an empty [lo, lo) span
    rather than a negative width.  ``lo``/``hi`` are q_lo's error-bound
    window.  Returns (rank_lo, rank_hi)."""
    blo = rmi_mod.verified_search(base_keys, q_lo, lo, hi, iters=iters)
    bhi = jnp.searchsorted(base_keys, q_hi, side="right").astype(jnp.int32)
    dlo = jnp.searchsorted(dk, q_lo, side="left").astype(jnp.int32)
    dhi = jnp.searchsorted(dk, q_hi, side="right").astype(jnp.int32)
    rank_lo = (blo - base_psum[blo]) + (dlo - dpsum[dlo])
    rank_hi = (bhi - base_psum[bhi]) + (dhi - dpsum[dhi])
    return rank_lo, jnp.maximum(rank_hi, rank_lo)


@functools.partial(jax.jit, static_argnames=(
    "root_kind", "leaf_kind", "n_leaves", "route_n", "iters"))
def _range_find_jit(root, leaves, err_lo, err_hi, base_keys, base_dead,
                    base_psum, dk, ddead, dpsum, q_lo, q_hi, *,
                    root_kind: str, leaf_kind: str, n_leaves: int,
                    route_n: int, iters: int):
    """f64 oracle of the fused range kernel (``ops.range_lookup``): route
    q_lo, window-search its left boundary, exact right boundary of q_hi,
    live-rank both.  Returns (rank_lo, rank_hi)."""
    n = base_keys.shape[0]
    b = rmi_mod.root_buckets(root_kind, root, q_lo, n_leaves, route_n)
    lo, hi = leaf_window(leaves, err_lo, err_hi, b, q_lo, n, leaf_kind)
    return two_tier_range_answer(base_keys, base_psum, dk, dpsum, q_lo, q_hi,
                                 lo, hi, iters)


@functools.partial(jax.jit, static_argnames=("root_kind", "n_leaves",
                                             "route_n"))
def _routed_buckets(root_kind: str, root, keys: Array, n_leaves: int,
                    route_n: int) -> Array:
    """Frozen-scale routing that sends +inf capacity padding to the dump
    bucket ``n_leaves`` (segment ops drop it; an unmasked inf saturates to
    INT32_MAX and would clip into the last live leaf)."""
    b = rmi_mod.root_buckets(root_kind, root, keys, n_leaves, route_n)
    return jnp.where(jnp.isfinite(keys), b, n_leaves)


@jax.jit
def _gather_moved(dk: Array, dleaf: Array, ddead: Array, rmask: Array):
    """Live delta entries routed to rebuilt leaves: (sorted keys with +inf
    backfill — a cumsum compaction of the already-sorted tier, no sort —
    membership mask, per-leaf moved counts)."""
    L = rmask.shape[0]
    move = (dleaf >= 0) & ~ddead & rmask[jnp.clip(dleaf, 0, L - 1)]
    mk, _ = _compact_sorted(dk, move, (), ())
    mcnt = jnp.bincount(jnp.where(move, dleaf, L), length=L + 1)[:L]
    return mk, move, mcnt


@functools.partial(jax.jit, static_argnames=("leaf_kind",))
def _compose_rebuild_jit(old_leaves, old_lo, old_hi, old_reused, old_sim,
                         new_leaves, new_lo, new_hi, new_reused, new_sim,
                         new_count, rmask, shift, widen, eps,
                         *, leaf_kind: str):
    """Assemble the post-rebuild leaf state in one jit: exact intercept
    shift (or widen) for untouched leaves, row-select of the refit results,
    and the full Lemma 4.1 budget vector."""
    if leaf_kind == "linear":
        shifted = old_leaves._replace(b=old_leaves.b + shift)
    else:
        shifted = old_leaves._replace(b2=old_leaves.b2 + shift)
    sel = lambda a, o: jnp.where(
        jnp.expand_dims(rmask, tuple(range(1, a.ndim))), a, o)
    leaves = jax.tree.map(sel, new_leaves, shifted)
    err_lo = jnp.where(rmask, new_lo, old_lo - widen)
    err_hi = jnp.where(rmask, new_hi, old_hi + widen)
    reused = jnp.where(rmask, new_reused, old_reused)
    sim = jnp.where(rmask, new_sim, old_sim)
    budget = insertion_budget(new_sim, eps, new_count)
    return leaves, err_lo, err_hi, reused, sim, budget


@functools.partial(jax.jit, static_argnames=("cap_out", "has_dead"))
def _merge_base_jit(base_keys: Array, base_dead: Array, moved: Array,
                    cap_out: int, has_dead: bool = True):
    """One sorted gather-merge of the moved delta entries into the base
    tier.  Both inputs carry +inf capacity padding which sorts past every
    live key; the output is re-padded to ``cap_out`` (the quantized base
    capacity), so base-tier shapes — and every jit specialization over them
    — only change on capacity crossings, not on every merge.  Tombstone
    flags ride the same gather map (skipped when the tier has no tombstones
    yet, ``has_dead=False``).
    """
    if not has_dead:
        allk, _ = _merge_sorted(base_keys, moved, cap_out, (), (), ())
        return allk, jnp.zeros((cap_out,), bool)
    allk, (dead,) = _merge_sorted(
        base_keys, moved, cap_out, (base_dead,),
        (jnp.zeros(moved.shape, bool),), (False,))
    return allk, dead


# ---------------------------------------------------------------------------
# The dynamic index.
# ---------------------------------------------------------------------------
@dataclass
class DynamicRMI:
    """RMI base tier + sorted device delta tier + Lemma 4.1 rebuild policy.

    All hot-path state (both tiers, tombstone bitmaps, prefix sums) is
    device-resident; the host keeps only per-leaf counters (numpy) and the
    incremental search-depth bookkeeping.
    """
    index: rmi_mod.RMIIndex
    pool: ModelPool | None
    eps: float
    route_n: int = 0                    # frozen key->leaf routing scale
    # delta tier (pow2 capacity, +inf padded, sorted ascending)
    delta_keys: Array = None            # (cap,) f64
    delta_leaf: Array = None            # (cap,) i32 routed leaf, -1 pads
    delta_dead: Array = None            # (cap,) bool
    delta_psum: Array = None            # (cap+1,) i32 exclusive dead psum
    delta_live: int = 0                 # live (finite & not dead) entries
    delta_dead_count: int = 0           # tombstoned delta entries (gates
                                        # the compaction-free merge path)
    # Dead-ratio-triggered delta compaction (ROADMAP "delta-tier churn
    # under sustained deletes"): tombstones are purged opportunistically by
    # the next insert/rebuild merge, but a delete-only workload has no such
    # merge — when the dead fraction of the tier exceeds this ratio,
    # delete_batch compacts the tier in place (one cumsum compaction).
    # None disables the trigger.
    compact_dead_ratio: float | None = _COMPACT_RATIO
    delta_compactions: int = 0          # compaction passes run
    # base tier bookkeeping (keys live inside ``index``, +inf padded to
    # pow2 capacity so rebuild merges don't retrace every jit consumer)
    base_n: int = 0                     # finite base keys (incl tombstoned)
    base_dead: Array = None             # (cap,) bool
    base_psum: Array = None             # (cap+1,) i32
    base_dead_count: int = 0            # tombstoned base entries
    # Lemma 4.1 accounting (host)
    n_inserts: np.ndarray = None        # per leaf, since last rebuild
    budget: np.ndarray = None
    rebuilds: int = 0
    deleted: int = 0
    capacity_shrinks: int = 0           # tier capacity step-downs taken
    # maybe_swap route cache: (keys ref, slice len, base, buckets) — valid
    # while the base keys array object is unchanged (rebuild/flush replace
    # it); keeps the maintenance pass O(selection), not O(base scan)
    _swap_route: tuple | None = None
    # Rebuild re-indexing policy: None (auto) runs Algorithm-1 pool
    # selection only when a leaf refit requires *training* (MLP leaves) —
    # for linear leaves the closed-form segment refit is free, optimal, and
    # earns the maximal Lemma 4.1 budget (sim = 1), so reuse could only
    # lose.  True forces pool selection (the paper's Algorithm 1 verbatim);
    # False disables it.
    reuse_on_rebuild: bool | None = None
    build_kwargs: dict = field(default_factory=dict)
    # Online drift monitoring + hot-swap reuse (core.drift; None = off so
    # the seed behavior — and every existing caller — is untouched).
    drift: drift_mod.DriftState | None = None
    swap_on_drift: bool = False         # try pool swaps before refits when
                                        # the drift latch is set
    swaps_committed: int = 0            # leaves hot-swapped (bound held)
    swap_rejects: int = 0               # swap attempts that fell back
    _win: np.ndarray = None             # per-leaf window widths (depth calc)
    _delta_f32: bool | None = None
    _kroot: Array = None                # packed kernel root (frozen: the
                                        # root model and route_n never
                                        # change after build)

    @classmethod
    def build(cls, keys, pool=None, eps: float = 0.9,
              reuse_on_rebuild: bool | None = None,
              compact_dead_ratio: float | None = _COMPACT_RATIO,
              drift_bins: int = 0, drift_hi: float = 0.15,
              drift_lo: float = 0.05, swap_on_drift: bool = False,
              **rmi_kwargs):
        """``drift_bins > 0`` turns on the online drift monitor
        (``core.drift``) at that histogram resolution, with the
        [drift_lo, drift_hi] hysteresis band; ``swap_on_drift`` addition-
        ally lets budget-exhausted leaves try an Algorithm-1 pool swap
        before the refit while the drift latch is set."""
        idx = rmi_mod.build_rmi(keys, pool=pool, **rmi_kwargs)
        n = idx.n
        # Frozen routing scale: floor at 1 so an empty build (a sharded
        # index's empty shard) keeps a well-defined key->leaf hash — its
        # zero root sends everything to leaf 0, which stays consistent
        # between insert- and find-time routing.
        route_n = max(n, 1)
        counts = np.bincount(
            np.asarray(rmi_mod.root_buckets(idx.root_kind, idx.root, idx.keys,
                                            idx.n_leaves, route_n)),
            minlength=idx.n_leaves)
        budget = np.array(insertion_budget(
            jnp.asarray(idx.leaf_sim), jnp.float64(eps),
            jnp.asarray(counts, jnp.float64)), copy=True)
        # Quantize the base tier to pow2 capacity with +inf padding: pads
        # sort past every live key and route to the dump bucket, so rebuild
        # merges change shapes (and retrace jits) only on capacity doubling.
        from ..kernels.lookup import pad_capacity
        cap = _capacity(n)
        drift = drift_mod.init_drift(idx.keys, m=drift_bins,
                                     thresh_hi=drift_hi,
                                     thresh_lo=drift_lo) \
            if drift_bins else None
        padded = pad_capacity(idx.keys, cap)
        idx = replace(idx, keys=padded, _f32_exact=None, _packed=None)
        d = cls(index=idx, pool=pool, eps=eps, route_n=route_n, base_n=n,
                reuse_on_rebuild=reuse_on_rebuild,
                compact_dead_ratio=compact_dead_ratio,
                drift=drift, swap_on_drift=swap_on_drift,
                delta_keys=jnp.full((_MIN_CAP,), jnp.inf, jnp.float64),
                delta_leaf=jnp.full((_MIN_CAP,), -1, jnp.int32),
                delta_dead=jnp.zeros((_MIN_CAP,), bool),
                delta_psum=jnp.zeros((_MIN_CAP + 1,), jnp.int32),
                base_dead=jnp.zeros((cap,), bool),
                base_psum=jnp.zeros((cap + 1,), jnp.int32),
                n_inserts=np.zeros(idx.n_leaves, np.int64),
                budget=budget, build_kwargs=rmi_kwargs)
        d._win = window_widths(idx.err_lo, idx.err_hi)
        idx._iters = clamped_depth(d._win, cap)
        return d

    # -- mutation ----------------------------------------------------------
    def insert(self, key: float) -> None:
        self.insert_batch(np.asarray([key], np.float64))

    def insert_batch(self, keys: np.ndarray) -> None:
        """Bulk insert: one vectorized route-sort-merge on device, one host
        sync for the Lemma 4.1 counters, batched rebuild of any leaves whose
        budget is exhausted."""
        keys = np.asarray(keys, np.float64).ravel()
        if keys.size == 0:
            return
        idx = self.index
        k = jnp.asarray(np.sort(keys))        # host sort: batches are host-
        lv = rmi_mod.root_buckets(idx.root_kind, idx.root, k, idx.n_leaves,
                                  self.route_n)  # born, np.sort >> XLA sort
        cap = max(self.delta_keys.shape[0],
                  _capacity(self.delta_live + keys.size))
        if self.delta_live == 0 and self.delta_dead_count == 0:
            self.delta_keys, self.delta_leaf = _fill_delta_jit(
                k, lv, cap_out=cap)
        elif self.delta_dead_count == 0:
            self.delta_keys, self.delta_leaf = _merge_delta_clean_jit(
                self.delta_keys, self.delta_leaf, k, lv, cap_out=cap)
        else:
            self.delta_keys, self.delta_leaf = _merge_delta_jit(
                self.delta_keys, self.delta_leaf, self.delta_dead, k, lv,
                cap_out=cap)
            self.delta_dead_count = 0
        self.delta_dead = jnp.zeros((cap,), bool)
        self.delta_psum = jnp.zeros((cap + 1,), jnp.int32)
        self.delta_live += keys.size
        self._delta_f32 = None
        if self.drift is not None:
            self.drift = drift_mod.update_drift(self.drift, k)
        cnt = np.asarray(_batch_counts_sorted(lv, idx.n_leaves)
                         if idx.root_kind == "linear"
                         else jnp.bincount(lv, length=idx.n_leaves))
        self.n_inserts += cnt
        over = np.flatnonzero(self.n_inserts > self.budget)
        if over.size and self.swap_on_drift and self.drift is not None \
                and self.pool is not None:
            # Drift-adaptive serving mode: structural repair is deferred
            # to the next idle-window maintenance pass (``maybe_swap``
            # sweeps budget-exhausted leaves — hot-swap when the bound
            # holds, refit otherwise).  Queries stay exact meanwhile:
            # buffered inserts live in the delta tier, which find/gather
            # search directly, so the insert path itself never pays an
            # O(n) merge or an O(pool) swap pass.
            return
        if over.size:
            self._rebuild_leaves(over)

    def delete(self, key: float) -> None:
        self.delete_batch(np.asarray([key], np.float64))

    def delete_batch(self, keys: np.ndarray) -> None:
        """§4 deletions as tombstone *bitmaps*: one vectorized scatter marks
        the leftmost live occurrence in the delta tier (buffered inserts die
        here — the seed left them live), else in the base tier.

        Duplicate keys *within one batch* collapse to a single removal
        (they resolve to the same tombstone slot); to retire several copies
        of the same key, issue sequential delete calls/batches."""
        q = jnp.asarray(np.asarray(keys, np.float64).ravel())
        if q.shape[0] == 0:
            return
        self.base_dead, self.delta_dead, nb, ndel = _delete_jit(
            self.index.keys, self.base_dead, self.delta_keys,
            self.delta_dead, q)
        self.base_psum = _psum(self.base_dead)
        self.delta_live -= int(ndel)
        self.delta_dead_count += int(ndel)
        self.base_dead_count += int(nb)
        self.deleted += int(nb) + int(ndel)
        if (self.compact_dead_ratio is not None and self.delta_dead_count
                and self.delta_dead_count >= self.compact_dead_ratio
                * (self.delta_live + self.delta_dead_count)):
            self._compact_delta()       # resets the delta psum to zeros
        else:
            self.delta_psum = _psum(self.delta_dead)

    def _compact_delta(self) -> None:
        """Purge tombstoned delta entries in place (one cumsum compaction +
        re-pad — the same pass insert/rebuild merges run, without merging
        anything).  Live entries, their order, and both tiers' live ranks
        are unchanged; only the dead fraction drops to zero."""
        cap = self.delta_keys.shape[0]
        self.delta_keys, self.delta_leaf = _merge_delta_jit(
            self.delta_keys, self.delta_leaf, self.delta_dead,
            jnp.zeros((0,), jnp.float64), jnp.zeros((0,), jnp.int32),
            cap_out=cap)
        self.delta_dead = jnp.zeros((cap,), bool)
        self.delta_psum = jnp.zeros((cap + 1,), jnp.int32)
        self.delta_dead_count = 0
        self.delta_compactions += 1
        self._delta_f32 = None          # tier contents changed

    # -- boundary-run migration primitives (sharded rebalancer) ------------
    def shed_suffix(self, split: float) -> None:
        """Drop every entry with key > ``split`` from both tiers — the
        donor half of an incremental migration to the *right* neighbour.
        Survivor positions are unchanged (a suffix truncation shifts
        nothing), so every model, error bound, packed kernel table, and the
        clamped search depth stay valid as-is.  ``split`` must land on an
        equal-key run boundary (callers snap it) so duplicate runs — and
        their tombstone-prefix invariant — move or stay whole."""
        cut_b = int(jnp.searchsorted(self.index.keys, jnp.float64(split),
                                     side="right"))
        if cut_b < self.base_n:
            keys, dead, shed_dead = _shed_suffix_jit(
                self.index.keys, self.base_dead, cut_b)
            # keys only lose finite entries to +inf padding: the packed
            # tables (models only) and f32-exactness survive untouched.
            self.index = replace(self.index, keys=keys)
            self.base_dead = dead
            self.base_dead_count -= int(shed_dead)
            self.base_psum = jnp.zeros((keys.shape[0] + 1,), jnp.int32) \
                if self.base_dead_count == 0 else _psum(dead)
            self.base_n = cut_b
        cut_d = int(jnp.searchsorted(self.delta_keys, jnp.float64(split),
                                     side="right"))
        nf = self.delta_live + self.delta_dead_count
        if cut_d < nf:
            dk, dleaf, ddead, sdead = _shed_suffix_delta_jit(
                self.delta_keys, self.delta_leaf, self.delta_dead, cut_d)
            self.delta_keys, self.delta_leaf, self.delta_dead = dk, dleaf, \
                ddead
            self.delta_dead_count -= int(sdead)
            self.delta_live -= (nf - cut_d) - int(sdead)
            self.delta_psum = _psum(ddead)

    def shed_prefix(self, split: float) -> None:
        """Drop every entry with key <= ``split`` — the donor half of an
        incremental migration to the *left* neighbour.  Both tiers compact
        left and every leaf intercept shifts down by exactly the number of
        removed base entries: the shift is uniform (all removals happen
        left of every survivor), so it is exact for either leaf kind under
        any root, and error bounds / clamped depth stay tight.  Routing is
        untouched (the frozen root model maps keys, not positions)."""
        cut_b = int(jnp.searchsorted(self.index.keys, jnp.float64(split),
                                     side="right"))
        if cut_b > 0:
            keys, dead, shed_dead = _shed_prefix_jit(
                self.index.keys, self.base_dead, cut_b)
            if self.index.leaf_kind == "linear":
                leaves = self.index.leaves._replace(
                    b=self.index.leaves.b - cut_b)
            else:
                leaves = self.index.leaves._replace(
                    b2=self.index.leaves.b2 - cut_b)
            # leaf intercepts changed: packed kernel tables go stale (the
            # cached packed *root* on ``_kroot`` stays — roots are frozen).
            self.index = replace(self.index, keys=keys, leaves=leaves,
                                 _packed=None)
            self.base_dead = dead
            self.base_dead_count -= int(shed_dead)
            self.base_psum = jnp.zeros((keys.shape[0] + 1,), jnp.int32) \
                if self.base_dead_count == 0 else _psum(dead)
            self.base_n -= cut_b
        cut_d = int(jnp.searchsorted(self.delta_keys, jnp.float64(split),
                                     side="right"))
        if cut_d > 0:
            dk, dleaf, ddead, sdead = _shed_prefix_delta_jit(
                self.delta_keys, self.delta_leaf, self.delta_dead, cut_d)
            self.delta_keys, self.delta_leaf, self.delta_dead = dk, dleaf, \
                ddead
            self.delta_dead_count -= int(sdead)
            self.delta_live -= (cut_d - int(sdead))
            self.delta_psum = _psum(ddead)

    def clone(self) -> "DynamicRMI":
        """Independent handle over the same (immutable) device arrays.

        Mutating methods rebind fields or mutate host numpy in place — the
        only in-place device-adjacent mutation is ``_rebuild_leaves``
        assigning ``self.index._iters`` — so a clone needs fresh host
        containers and a fresh ``RMIIndex`` wrapper, nothing deeper.  The
        elastic resharder cuts several pieces out of one source shard via
        clones."""
        d = replace(self, index=replace(self.index),
                    n_inserts=self.n_inserts.copy(),
                    budget=self.budget.copy(),
                    build_kwargs=dict(self.build_kwargs))
        if self.drift is not None:
            # Device arrays are immutable and updates rebind a fresh
            # DriftState, so a shallow copy fully decouples the clones.
            d.drift = replace(self.drift)
        d._win = self._win.copy()
        return d

    def shrink_capacity(self, hysteresis: int = 4) -> bool:
        """Step either tier's capacity class back down — the inverse of the
        grow-only policy in ``insert_batch``/``_rebuild_leaves``, for after
        migration sheds or delete-heavy churn.  Hysteresis band: a tier
        shrinks only when its capacity is ≥ ``hysteresis`` times the
        smallest class that fits, and steps down to ``hysteresis/2`` times
        that class
        — so a shrink always leaves a doubling of headroom and regrowing
        needs ≥ 2 doublings (no thrash at a class boundary, and a batch
        smaller than the tier's population can never re-cross one).  Finite
        entries occupy each tier's prefix, so a shrink is a pure slice:
        positions, fitted models, error bounds, packed kernel tables
        (models-only), and f32-exactness are untouched; only the clamped
        search depth is recomputed for the smaller capacity.  Returns True
        if any tier shrank."""
        hold = max(hysteresis // 2, 1)
        shrank = False
        idx = self.index
        cap_b = idx.keys.shape[0]
        want_b = _capacity(self.base_n) * hold
        if cap_b >= hysteresis * _capacity(self.base_n) and cap_b > want_b:
            keys = idx.keys[:want_b]
            self.base_dead = self.base_dead[:want_b]
            self.base_psum = jnp.zeros((want_b + 1,), jnp.int32) \
                if self.base_dead_count == 0 else _psum(self.base_dead)
            self.index = replace(idx, keys=keys)
            self.index._iters = clamped_depth(self._win, want_b)
            self.capacity_shrinks += 1
            shrank = True
        cap_d = self.delta_keys.shape[0]
        nf_d = self.delta_live + self.delta_dead_count
        want_d = _capacity(nf_d) * hold
        if cap_d >= hysteresis * _capacity(nf_d) and cap_d > want_d:
            self.delta_keys = self.delta_keys[:want_d]
            self.delta_leaf = self.delta_leaf[:want_d]
            self.delta_dead = self.delta_dead[:want_d]
            self.delta_psum = jnp.zeros((want_d + 1,), jnp.int32) \
                if self.delta_dead_count == 0 else _psum(self.delta_dead)
            self.capacity_shrinks += 1
            shrank = True
        return shrank

    def flush_delta(self) -> None:
        """Merge every live delta entry into the base tier now, refitting
        only the leaves that actually hold delta entries (the rest take
        :meth:`_rebuild_leaves`'s exact intercept shift) — the incremental
        answer to a delta-hot shard, replacing the old from-scratch shard
        rebuild."""
        if self.delta_live == 0:
            if self.delta_dead_count:
                self._compact_delta()
            return
        L = self.index.n_leaves
        livem = jnp.isfinite(self.delta_keys) & ~self.delta_dead
        cnt = jnp.bincount(jnp.where(livem, self.delta_leaf, L),
                           length=L + 1)[:L]
        lid = np.flatnonzero(np.asarray(cnt))
        if lid.size:
            self._rebuild_leaves(lid)
        # Full merge event: every buffered insert is now part of the base
        # tier and its leaves were refitted on it, so the drift baseline
        # absorbs the accumulated histogram and the latch clears
        # (core.drift lifecycle; partial per-leaf rebuilds do NOT
        # rebaseline — the global score keeps tracking the workload shift
        # until an explicit flush accepts it).
        if self.drift is not None:
            self.drift = drift_mod.rebaseline(self.drift)

    @property
    def insertion_headroom(self) -> float:
        """Aggregate Lemma 4.1 headroom (``bounds.insertion_headroom``):
        how many more inserts the current leaf budgets can absorb."""
        return insertion_headroom(self.budget, self.n_inserts)

    def packed_root(self, route_leaves: int | None = None) -> Array:
        """Packed kernel root block with the frozen routing scale folded in
        (``lookup.pack_root(route_scale=route_leaves / route_n)``), cached
        for the life of the structure — root model and ``route_n`` are
        frozen at build, so there is no invalidation path.  Callers must
        pass a consistent ``route_leaves`` (the sharded dispatch always
        uses its uniform ``n_leaves``)."""
        if self._kroot is None:
            from ..kernels import lookup as _lk
            scale = 1.0 if route_leaves is None \
                else route_leaves / self.route_n
            self._kroot = _lk.pack_root(self.index.root_kind,
                                        self.index.root, route_scale=scale)
        return self._kroot

    # -- rebuild -----------------------------------------------------------
    def _rebuild_leaves(self, leaf_ids: np.ndarray) -> None:
        """Batched Lemma 4.1 rebuild: merge the leaves' delta entries into
        the base tier (one sorted merge) and re-index them via pool
        selection — Algorithm 1 reuse first, refit on miss (``fit_leaves``)
        — with measured post-merge error bounds.  Untouched leaves get an
        exact intercept shift (monotone linear root) or a sound ±m widen
        (MLP root); depth and budgets update incrementally."""
        idx = self.index
        L = idx.n_leaves
        leaf_ids = np.asarray(leaf_ids, np.int64).ravel()
        self.rebuilds += int(leaf_ids.size)
        rmask_np = np.zeros(L, bool)
        rmask_np[leaf_ids] = True
        rmask = jnp.asarray(rmask_np)

        cap = self.delta_keys.shape[0]
        clean = self.delta_dead_count == 0
        if clean and idx.root_kind == "linear":
            # Monotone routing + no tombstones: per-leaf counts are run
            # lengths of the (sorted) routed-leaf table — no scatters.
            mcnt = np.asarray(_moved_counts_sorted(self.delta_leaf, rmask))
            m = int(mcnt.sum())
            if m == self.delta_live:
                # Whole-tier merge (the bulk regime): the sorted tier IS the
                # moved array; just reset the delta afterwards.
                mk = self.delta_keys
                self.delta_keys = jnp.full((cap,), jnp.inf, jnp.float64)
                self.delta_leaf = jnp.full((cap,), -1, jnp.int32)
            else:
                mk, move, _ = _gather_moved(self.delta_keys, self.delta_leaf,
                                            self.delta_dead, rmask)
                self.delta_keys, self.delta_leaf = _merge_delta_jit(
                    self.delta_keys, self.delta_leaf, move,
                    jnp.zeros((0,), jnp.float64), jnp.zeros((0,), jnp.int32),
                    cap_out=cap)
        else:
            mk, move, mcnt_d = _gather_moved(self.delta_keys,
                                             self.delta_leaf,
                                             self.delta_dead, rmask)
            mcnt = np.asarray(mcnt_d)
            m = int(mcnt.sum())
            self.delta_keys, self.delta_leaf = _merge_delta_jit(
                self.delta_keys, self.delta_leaf, self.delta_dead | move,
                jnp.zeros((0,), jnp.float64), jnp.zeros((0,), jnp.int32),
                cap_out=cap)
            self.delta_dead_count = 0
        self.delta_dead = jnp.zeros((cap,), bool)
        self.delta_psum = jnp.zeros((cap + 1,), jnp.int32)
        self.delta_live -= m

        self.base_n += m
        cap_new = max(idx.n, _capacity(self.base_n))
        # Trim the moved array to its finite prefix (pow2-stepped so shapes
        # stay cache-friendly) before the base merge.
        mp = min(_capacity(m), mk.shape[0])
        new_base, new_bdead = _merge_base_jit(
            idx.keys, self.base_dead, mk[:mp], cap_out=cap_new,
            has_dead=self.base_dead_count > 0)

        # Re-index the rebuilt leaves over the merged base (capacity pads
        # route to the dump bucket and drop out of every segment op).  The
        # fit only sees the finite prefix — sliced at a quantized boundary
        # so the O(n) fit passes skip the capacity padding without
        # multiplying jit cache entries.
        buckets = _routed_buckets(idx.root_kind, idx.root, new_base, L,
                                  self.route_n)
        sl = min(cap_new, -(-self.base_n // 8192) * 8192)
        want_reuse = self.reuse_on_rebuild if self.reuse_on_rebuild \
            is not None else idx.leaf_kind != "linear"
        fit = rmi_mod.fit_leaves(
            new_base[:sl], buckets[:sl], L, kind=idx.leaf_kind,
            pool=self.pool if want_reuse else None, paper_bounds=False,
            train_steps=self.build_kwargs.get("train_steps", 300),
            refit_mask=rmask, sorted_buckets=idx.root_kind == "linear")

        # Position accounting for untouched leaves: with a monotone (linear)
        # root every base key right of a rebuilt leaf shifts by exactly the
        # number of keys merged left of it — fold the shift into the model
        # intercepts, bounds stay tight.  A non-monotone (MLP) root only
        # bounds the shift by m, so widen instead (paper §4's "+1 per
        # insert", batched).
        shift = jnp.asarray(np.concatenate([[0.0], np.cumsum(mcnt)[:-1]]))
        widen = 0.0 if idx.root_kind == "linear" else float(m)
        leaves, err_lo, err_hi, reused, sim, budget = _compose_rebuild_jit(
            idx.leaves, idx.err_lo, idx.err_hi, idx.reused_mask,
            idx.leaf_sim, fit.leaves, fit.err_lo, fit.err_hi, fit.reused,
            fit.sim, fit.count, rmask, shift, jnp.float64(widen),
            jnp.float64(self.eps), leaf_kind=idx.leaf_kind)
        self.index = replace(
            idx, keys=new_base, leaves=leaves, err_lo=err_lo, err_hi=err_hi,
            reused_mask=reused, leaf_sim=sim,
            _iters=None, _packed=None, _f32_exact=None)

        # Incremental clamped depth: update only the touched width rows.
        if widen:
            self._win[~rmask_np] += 2.0 * widen
        err_np = np.asarray(jnp.stack([fit.err_lo, fit.err_hi]))
        self._win[leaf_ids] = window_widths(
            err_np[0, leaf_ids], err_np[1, leaf_ids])
        self.index._iters = clamped_depth(self._win, cap_new)

        self.base_dead = new_bdead
        self.base_psum = jnp.zeros((cap_new + 1,), jnp.int32) \
            if self.base_dead_count == 0 else _psum(new_bdead)

        # Lemma 4.1: fresh budgets for the rebuilt leaves (sim = 1 - dist on
        # a pool hit, 1 on a fresh fit).
        self.budget[leaf_ids] = np.asarray(budget)[leaf_ids]
        self.n_inserts[leaf_ids] = 0

    # -- drift-triggered hot swap ------------------------------------------
    def maybe_swap(self, leaf_ids=None) -> int:
        """Attempt an Algorithm-1 pool hot-swap on ``leaf_ids`` (default:
        every leaf with buffered inserts): one fused jit selects, adapts,
        bound-checks, and commits per-leaf — see ``core.drift`` for the
        commit gate.  Returns the number of leaves swapped.  Requires a
        monotone (linear) root and a kind-matched pool; otherwise (or with
        drift monitoring off) it is a no-op and callers fall through to
        the ordinary refit path."""
        idx = self.index
        if (self.drift is None or self.pool is None
                or self.pool.kind != idx.leaf_kind
                or idx.root_kind != "linear"):
            return 0
        if leaf_ids is None:
            # Maintenance-style call (facade / serve idle window).
            # Proactive swaps only fire when the drift latch is set,
            # mirroring the sharded pass; explicit leaf_ids (tests,
            # targeted callers) skip the gate — the caller already
            # decided to attempt.
            swaps = 0
            if bool(self.drift.drifted):
                # Only at-risk leaves: pressure within a quarter
                # Lemma-4.1 budget of forcing a merge.  A committed swap
                # resets their budget from the pool fit; swapping
                # low-pressure leaves would only shrink budgets (pool
                # sim < fresh-fit sim) and churn the packed tables.
                at_risk = np.flatnonzero(
                    self.n_inserts >= np.maximum(self.budget * 0.25, 1.0))
                if at_risk.size:
                    swaps = self.maybe_swap(at_risk)
            # Deferred-refit sweep (latched or not): ``insert_batch`` in
            # swap mode leaves budget-exhausted leaves for this idle
            # window — leaves a swap could not absorb (bound-check
            # reject, or no drift latch) take the ordinary refit here,
            # off the insert path.
            over = np.flatnonzero(self.n_inserts > self.budget)
            if over.size:
                self._rebuild_leaves(over)
            return swaps
        leaf_ids = np.asarray(leaf_ids, np.int64).ravel()
        if leaf_ids.size == 0:
            return 0
        if self.pool.sel_a is None:
            self.pool._refresh_tables()
        rp = 1 << max(int(leaf_ids.size) - 1, 0).bit_length()
        pad_ids = np.concatenate(
            [leaf_ids, np.full(rp - leaf_ids.size, leaf_ids[0])])
        cap = idx.keys.shape[0]
        sl = min(cap, -(-self.base_n // 8192) * 8192)
        rc = self._swap_route
        if rc is None or rc[0] is not idx.keys or rc[1] != sl:
            base = idx.keys[:sl]
            buckets = _routed_buckets(idx.root_kind, idx.root, base,
                                      idx.n_leaves, self.route_n)
            self._swap_route = rc = (idx.keys, sl, base, buckets)
        base, buckets = rc[2], rc[3]
        out = drift_mod.swap_leaves_jit(
            base, buckets, self.delta_keys, self.delta_leaf,
            jnp.asarray(pad_ids.astype(np.int32)),
            idx.leaves, idx.err_lo, idx.err_hi, idx.leaf_sim,
            idx.reused_mask, self.pool.sel_a, self.pool.sel_ps,
            self.pool.params, self.pool.domains,
            jnp.asarray(self.n_inserts[pad_ids], jnp.float64),
            jnp.float64(float(self._win.max())), jnp.float64(self.eps),
            leaf_kind=idx.leaf_kind, m=self.pool.m, n_leaves=idx.n_leaves)
        leaves, err_lo, err_hi, sim, reused, commit, nbud, nw, _ = out
        # One maintenance-path sync of the commit verdicts; the table
        # writes themselves already happened asynchronously on device.
        commit_np = np.asarray(commit)[:leaf_ids.size]
        nc = int(commit_np.sum())
        self.swap_rejects += int(leaf_ids.size) - nc
        if nc == 0:
            return 0
        self.index = replace(idx, leaves=leaves, err_lo=err_lo,
                             err_hi=err_hi, leaf_sim=sim,
                             reused_mask=reused, _packed=None)
        # Commit gate bounds every new window by the current width cap, so
        # the clamped search depth — and every jit keyed on it — is
        # untouched: zero retraces across swap commits.
        cid = leaf_ids[commit_np]
        self.budget[cid] = np.asarray(nbud)[:leaf_ids.size][commit_np]
        self._win[cid] = np.asarray(nw)[:leaf_ids.size][commit_np]
        # The committed window covers the leaf's buffered inserts (that is
        # what the bound check verified), so the swap starts a fresh
        # budget epoch: pending pressure is paid for, the new budget
        # meters future inserts.  Without this, pressure accumulates
        # across swaps and every leaf still ends in a merge.
        self.n_inserts[cid] = 0
        self.swaps_committed += nc
        self.pool.reuse_count += nc
        return nc

    # -- queries -----------------------------------------------------------
    @property
    def f32_exact(self) -> bool:
        """Both tiers round-trip through f32 (kernel-path precondition)."""
        if self._delta_f32 is None:
            d32 = self.delta_keys.astype(jnp.float32).astype(jnp.float64)
            self._delta_f32 = bool(jnp.all(d32 == self.delta_keys))
        return self.index.f32_exact and self._delta_f32

    def find(self, queries: Array, *, path: str = "auto",
             use_kernel: bool | None = None) -> tuple[Array, Array]:
        """(found, rank) per query. ``found`` is True iff a live (non-
        tombstoned) copy of the key exists in either tier; ``rank`` counts
        live keys < q across both tiers.  ``path`` selects the execution
        path (``core.paths.resolve_path``, same policy as ``rmi.lookup``);
        ``use_kernel`` is the deprecated bool shim."""
        idx = self.index
        q = jnp.asarray(queries, jnp.float64)
        if resolve_path(path, f32_exact=lambda: self.f32_exact,
                        use_kernel=use_kernel):
            from ..kernels import ops as kernel_ops
            root, mat, vec = idx.packed_tables()
            return kernel_ops.dynamic_find(
                q, root, mat, vec, idx.keys, self.base_dead, self.base_psum,
                self.delta_keys, self.delta_dead, self.delta_psum,
                n_leaves=idx.n_leaves, route_n=self.route_n,
                root_kind=idx.root_kind, leaf_kind=idx.leaf_kind,
                iters=idx.search_iters)
        found, rank, _ = _find_jit(
            idx.root, idx.leaves, idx.err_lo, idx.err_hi, idx.keys,
            self.base_dead, self.base_psum, self.delta_keys, self.delta_dead,
            self.delta_psum, q, root_kind=idx.root_kind,
            leaf_kind=idx.leaf_kind, n_leaves=idx.n_leaves,
            route_n=self.route_n, iters=idx.search_iters)
        return found, rank

    def find_range(self, q_lo: Array, q_hi: Array, *, path: str = "auto",
                   use_kernel: bool | None = None) -> tuple[Array, Array]:
        """(rank_lo, rank_hi) live ranks of the inclusive key ranges
        ``[q_lo[i], q_hi[i]]``: rank_lo is the leftmost live rank of q_lo,
        rank_hi the rightmost live rank of q_hi (duplicates included,
        tombstones excluded), so ``live_keys()[rank_lo:rank_hi]`` is
        exactly the range's content (:meth:`gather_range`).  rank_hi is
        clamped to rank_lo — degenerate ranges come back empty, never
        negative-width.  Path selection matches :meth:`find`."""
        idx = self.index
        ql = jnp.asarray(q_lo, jnp.float64)
        qh = jnp.asarray(q_hi, jnp.float64)
        if resolve_path(path, f32_exact=lambda: self.f32_exact,
                        use_kernel=use_kernel):
            from ..kernels import ops as kernel_ops
            root, mat, vec = idx.packed_tables()
            return kernel_ops.range_lookup(
                ql, qh, root, mat, vec, idx.keys, self.base_dead,
                self.base_psum, self.delta_keys, self.delta_dead,
                self.delta_psum, n_leaves=idx.n_leaves, route_n=self.route_n,
                root_kind=idx.root_kind, leaf_kind=idx.leaf_kind,
                iters=idx.search_iters)
        return _range_find_jit(
            idx.root, idx.leaves, idx.err_lo, idx.err_hi, idx.keys,
            self.base_dead, self.base_psum, self.delta_keys, self.delta_dead,
            self.delta_psum, ql, qh, root_kind=idx.root_kind,
            leaf_kind=idx.leaf_kind, n_leaves=idx.n_leaves,
            route_n=self.route_n, iters=idx.search_iters)

    def gather_range(self, rank_lo, rank_hi) -> list[np.ndarray]:
        """Materialize :meth:`find_range` spans: per-range sorted live keys
        (host numpy — ``live_keys()`` is computed once and sliced)."""
        live = self.live_keys()
        lo = np.asarray(rank_lo).ravel()
        hi = np.asarray(rank_hi).ravel()
        return [live[int(a):int(b)] for a, b in zip(lo, hi, strict=True)]

    def live_keys(self) -> np.ndarray:
        """Sorted live keys across both tiers (host numpy; ``find``'s rank
        indexes into exactly this array)."""
        bk = np.asarray(self.index.keys)
        bk = bk[np.isfinite(bk) & ~np.asarray(self.base_dead)]
        dk = np.asarray(self.delta_keys)
        dk = dk[np.isfinite(dk) & ~np.asarray(self.delta_dead)]
        return np.sort(np.concatenate([bk, dk]))

    @property
    def total_buffered(self) -> int:
        return int(self.delta_live)

    @property
    def live_count(self) -> int:
        """Live keys across both tiers (what ``find``'s rank indexes) —
        host counters only, no device sync."""
        return self.base_n - self.base_dead_count + self.delta_live

    @property
    def dead_fraction(self) -> float:
        """Tombstoned fraction of all stored (finite) entries — the sharded
        index's rebalance trigger reads this."""
        stored = self.base_n + self.delta_live + self.delta_dead_count
        return (self.base_dead_count + self.delta_dead_count) / max(stored, 1)


# ---------------------------------------------------------------------------
# The seed implementation (host per-leaf Python buffers), kept verbatim as
# the benchmark baseline for BENCH_updates.json before/after rows and as a
# throughput reference.  Known semantic defects (fixed above, retained here
# for fidelity to the measured baseline): find's rank only counts the routed
# leaf's buffer; delete never clears buffered entries; _rebuild_leaf resets
# counters without refitting the leaf model.
# ---------------------------------------------------------------------------
@dataclass
class HostBufferDynamicRMI:
    """Seed DynamicRMI: per-leaf host insert buffers + tombstone set."""
    index: rmi_mod.RMIIndex
    pool: ModelPool | None
    eps: float
    buffers: list[np.ndarray] = field(default_factory=list)     # per leaf
    n_inserts: np.ndarray = None                                # per leaf
    budget: np.ndarray = None                                   # Lemma 4.1
    tombstones: set = field(default_factory=set)
    rebuilds: int = 0
    build_kwargs: dict = field(default_factory=dict)

    @classmethod
    def build(cls, keys, pool=None, eps: float = 0.9, **rmi_kwargs):
        idx = rmi_mod.build_rmi(keys, pool=pool, **rmi_kwargs)
        counts = np.bincount(
            np.asarray(rmi_mod.root_buckets(idx.root_kind, idx.root, idx.keys,
                                            idx.n_leaves, idx.n)),
            minlength=idx.n_leaves)
        budget = np.array(insertion_budget(
            jnp.asarray(idx.leaf_sim), jnp.float64(eps),
            jnp.asarray(counts, jnp.float64)), copy=True)
        return cls(index=idx, pool=pool, eps=eps,
                   buffers=[np.empty((0,)) for _ in range(idx.n_leaves)],
                   n_inserts=np.zeros(idx.n_leaves, np.int64),
                   budget=budget, build_kwargs=rmi_kwargs)

    def insert(self, key: float) -> None:
        idx = self.index
        leaf = int(rmi_mod.root_buckets(idx.root_kind, idx.root,
                                        jnp.asarray([key], jnp.float64),
                                        idx.n_leaves, idx.n)[0])
        buf = self.buffers[leaf]
        self.buffers[leaf] = np.insert(buf, np.searchsorted(buf, key), key)
        self.n_inserts[leaf] += 1
        if self.n_inserts[leaf] > self.budget[leaf]:
            self._rebuild_leaf(leaf)

    def insert_batch(self, keys: np.ndarray) -> None:
        idx = self.index
        leaves = np.asarray(rmi_mod.root_buckets(
            idx.root_kind, idx.root, jnp.asarray(keys, jnp.float64),
            idx.n_leaves, idx.n))
        for leaf in np.unique(leaves):
            ks = keys[leaves == leaf]
            self.buffers[leaf] = np.sort(
                np.concatenate([self.buffers[leaf], ks]))
            self.n_inserts[leaf] += ks.size
            if self.n_inserts[leaf] > self.budget[leaf]:
                self._rebuild_leaf(leaf)

    def delete(self, key: float) -> None:
        self.tombstones.add(float(key))

    def _rebuild_leaf(self, leaf: int) -> None:
        self.rebuilds += 1
        self.n_inserts[leaf] = 0
        idx = self.index
        counts = np.bincount(np.asarray(rmi_mod.root_buckets(
            idx.root_kind, idx.root, idx.keys, idx.n_leaves, idx.n)),
            minlength=idx.n_leaves)
        n_leaf = counts[leaf] + self.buffers[leaf].size
        self.budget[leaf] = float(insertion_budget(
            jnp.float64(1.0), jnp.float64(self.eps), jnp.float64(n_leaf)))

    def find(self, queries: Array) -> tuple[Array, Array]:
        idx = self.index
        q = jnp.asarray(queries, jnp.float64)
        base_pos = rmi_mod.lookup(idx, q)
        leaves = rmi_mod.root_buckets(idx.root_kind, idx.root, q,
                                      idx.n_leaves, idx.n)
        base_hit = (base_pos < idx.n) & \
            (idx.keys[jnp.clip(base_pos, 0, idx.n - 1)] == q)
        qn = np.asarray(q)
        buf_hit = np.zeros(qn.shape, bool)
        buf_rank = np.zeros(qn.shape, np.int64)
        for i, (qq, lf) in enumerate(zip(qn, np.asarray(leaves), strict=True)):
            b = self.buffers[lf]
            j = np.searchsorted(b, qq)
            buf_rank[i] = j
            buf_hit[i] = j < b.size and b[j] == qq
        found = (np.asarray(base_hit) | buf_hit)
        if self.tombstones:
            dead = np.asarray([qq in self.tombstones for qq in qn])
            found &= ~dead
        return jnp.asarray(found), base_pos + jnp.asarray(buf_rank)

    @property
    def total_buffered(self) -> int:
        return int(self.n_inserts.sum())
