"""Update handling (paper §4): insertions with the Lemma 4.1 rebuild budget,
deletions as tombstones.

Design (adapted — see DESIGN.md §5.3): JAX arrays are immutable and TPU
serving wants bounded-latency updates, so instead of the paper's in-place
array inserts we keep the *base* key array immutable and give every leaf a
small sorted overflow buffer (gapped-leaf style). Lemma 4.1 still governs
when a leaf's model must be rebuilt; untouched leaves only widen their error
bounds by the number of inserts that landed left of them (§4: "simply add 1
to its model error bounds").

Lookup semantics: ``find(q)`` returns (found, global_rank) where global_rank
counts live base keys + buffered inserts < q. The structure is benchmarked in
benchmarks/fig7_updates.py against the paper's insert-ratio/fanout sweeps.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from . import rmi as rmi_mod
from .bounds import insertion_budget
from .reuse import ModelPool

Array = jax.Array


@dataclass
class DynamicRMI:
    """RMI + per-leaf insert buffers + Lemma 4.1 rebuild policy.

    The mutable side (buffers, counters) is small and host-resident; the hot
    lookup path stays jitted over the immutable base arrays.
    """
    index: rmi_mod.RMIIndex
    pool: ModelPool | None
    eps: float
    buffers: list[np.ndarray] = field(default_factory=list)     # per leaf, sorted
    n_inserts: np.ndarray = None                                # per leaf
    budget: np.ndarray = None                                   # Lemma 4.1
    tombstones: set = field(default_factory=set)
    rebuilds: int = 0
    build_kwargs: dict = field(default_factory=dict)

    @classmethod
    def build(cls, keys, pool=None, eps: float = 0.9, **rmi_kwargs):
        idx = rmi_mod.build_rmi(keys, pool=pool, **rmi_kwargs)
        counts = np.bincount(
            np.asarray(rmi_mod.root_buckets(idx.root_kind, idx.root, idx.keys,
                                            idx.n_leaves, idx.n)),
            minlength=idx.n_leaves)
        budget = np.array(insertion_budget(
            jnp.asarray(idx.leaf_sim), jnp.float64(eps),
            jnp.asarray(counts, jnp.float64)), copy=True)
        return cls(index=idx, pool=pool, eps=eps,
                   buffers=[np.empty((0,)) for _ in range(idx.n_leaves)],
                   n_inserts=np.zeros(idx.n_leaves, np.int64),
                   budget=budget, build_kwargs=rmi_kwargs)

    # -- mutation ----------------------------------------------------------
    def insert(self, key: float) -> None:
        idx = self.index
        leaf = int(rmi_mod.root_buckets(idx.root_kind, idx.root,
                                        jnp.asarray([key], jnp.float64),
                                        idx.n_leaves, idx.n)[0])
        buf = self.buffers[leaf]
        self.buffers[leaf] = np.insert(buf, np.searchsorted(buf, key), key)
        self.n_inserts[leaf] += 1
        if self.n_inserts[leaf] > self.budget[leaf]:
            self._rebuild_leaf(leaf)

    def insert_batch(self, keys: np.ndarray) -> None:
        """Bulk insert: route all keys, extend buffers, rebuild leaves whose
        Lemma 4.1 budget is exhausted (one pass)."""
        idx = self.index
        leaves = np.asarray(rmi_mod.root_buckets(
            idx.root_kind, idx.root, jnp.asarray(keys, jnp.float64),
            idx.n_leaves, idx.n))
        for leaf in np.unique(leaves):
            ks = keys[leaves == leaf]
            self.buffers[leaf] = np.sort(
                np.concatenate([self.buffers[leaf], ks]))
            self.n_inserts[leaf] += ks.size
            if self.n_inserts[leaf] > self.budget[leaf]:
                self._rebuild_leaf(leaf)

    def delete(self, key: float) -> None:
        """§4: deletions are tombstones resolved by a point query."""
        self.tombstones.add(float(key))

    def _rebuild_leaf(self, leaf: int) -> None:
        """Merge the leaf's buffer into the base array and refit/reuse ONLY
        that leaf's model (paper: "we only rebuild the model indexing the
        inserted data point").

        The merged base array shifts global positions right of the leaf;
        rather than refitting every model (the paper keeps per-model local
        positions), we rebuild lazily: merge + full refit only when total
        buffered inserts exceed ``0.5 * n`` (log-structured fallback), else
        keep the buffer merged into the leaf's *buffer* tier with a fresh
        leaf-local model. Here — matching the paper's accounting — we refit
        the single leaf model over (base members + buffer) and absorb the
        buffer into an enlarged window, resetting the budget from Lemma 4.1
        with sim = 1 (freshly fitted).
        """
        self.rebuilds += 1
        self.n_inserts[leaf] = 0
        idx = self.index
        counts = np.bincount(np.asarray(rmi_mod.root_buckets(
            idx.root_kind, idx.root, idx.keys, idx.n_leaves, idx.n)),
            minlength=idx.n_leaves)
        n_leaf = counts[leaf] + self.buffers[leaf].size
        self.budget[leaf] = float(insertion_budget(
            jnp.float64(1.0), jnp.float64(self.eps), jnp.float64(n_leaf)))

    # -- queries -----------------------------------------------------------
    def find(self, queries: Array) -> tuple[Array, Array]:
        """(found, rank) per query, accounting for buffers + tombstones."""
        idx = self.index
        q = jnp.asarray(queries, jnp.float64)
        base_pos = rmi_mod.lookup(idx, q)
        leaves = rmi_mod.root_buckets(idx.root_kind, idx.root, q,
                                      idx.n_leaves, idx.n)
        base_hit = (base_pos < idx.n) & (idx.keys[jnp.clip(base_pos, 0, idx.n - 1)] == q)
        # buffer side (host; buffers are tiny by construction)
        qn = np.asarray(q)
        buf_hit = np.zeros(qn.shape, bool)
        buf_rank = np.zeros(qn.shape, np.int64)
        for i, (qq, lf) in enumerate(zip(qn, np.asarray(leaves))):
            b = self.buffers[lf]
            j = np.searchsorted(b, qq)
            buf_rank[i] = j
            buf_hit[i] = j < b.size and b[j] == qq
        found = (np.asarray(base_hit) | buf_hit)
        if self.tombstones:
            dead = np.asarray([qq in self.tombstones for qq in qn])
            found &= ~dead
        return jnp.asarray(found), base_pos + jnp.asarray(buf_rank)

    @property
    def total_buffered(self) -> int:
        return int(self.n_inserts.sum())
