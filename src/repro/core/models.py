"""Index model zoo: linear models and the paper's 1-hidden-layer / 4-neuron
feed-forward network, with *batched* training.

TPU adaptation: the paper trains pool models sequentially on a GPU (Table 2:
109 s for 1,221 models at eps=0.9). Here every model in a pool is one slice of
a stacked parameter pytree and training is a single ``vmap``-batched Adam
program — the whole pool pre-trains in one jit call, with the tiny 4-neuron
matmuls batched onto the MXU.

Each model predicts a *storage position* from a key (paper §3 "Model
adaptation": p.addr ≈ M(p.key), positions 0..n-1). Error bounds are the
empirical residual extrema: position in [pred + err_lo, pred + err_hi].
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array

HIDDEN = 4  # paper: "one hidden layer of four neurons"


# ---------------------------------------------------------------------------
# Linear model. Params stacked as (..., 2) = [slope, intercept].
# ---------------------------------------------------------------------------
class LinearParams(NamedTuple):
    a: Array  # slope
    b: Array  # intercept


def linear_predict(p: LinearParams, x: Array) -> Array:
    return p.a * x + p.b


@jax.jit
def linear_fit(keys: Array, pos: Array) -> LinearParams:
    """Closed-form least squares of position on key. Batched via vmap; the
    segment (per-RMI-leaf) variant lives in kernels/linfit."""
    x = keys.astype(jnp.float64)
    y = pos.astype(jnp.float64)
    n = x.shape[0]
    sx, sy = x.sum(), y.sum()
    sxx, sxy = (x * x).sum(), (x * y).sum()
    denom = n * sxx - sx * sx
    a = jnp.where(jnp.abs(denom) > 1e-30, (n * sxy - sx * sy) / denom, 0.0)
    b = (sy - a * sx) / n
    return LinearParams(a=a, b=b)


# ---------------------------------------------------------------------------
# 1x4 MLP. Keys are fed normalized to [0,1]; output is position.
# ---------------------------------------------------------------------------
class MLPParams(NamedTuple):
    w1: Array  # (HIDDEN,)
    b1: Array  # (HIDDEN,)
    w2: Array  # (HIDDEN,)
    b2: Array  # ()


def mlp_init(key: Array, scale: float = 1.0) -> MLPParams:
    """Init for CDF-shaped targets on [0,1]: positive slopes with ReLU kinks
    spread across the domain so no unit is dead over the input range."""
    k1, k2 = jax.random.split(key)
    w1 = 1.0 + jnp.abs(jax.random.normal(k1, (HIDDEN,), jnp.float64)) * 2.0
    kinks = jnp.linspace(0.0, 0.75, HIDDEN).astype(jnp.float64)
    return MLPParams(
        w1=w1,
        b1=-w1 * kinks,
        w2=jnp.abs(jax.random.normal(k2, (HIDDEN,), jnp.float64)) * scale,
        b2=jnp.zeros((), jnp.float64),
    )


def mlp_predict(p: MLPParams, x: Array) -> Array:
    """x: scalar or (n,) normalized key -> predicted position (same shape)."""
    h = jax.nn.relu(jnp.expand_dims(x, -1) * p.w1 + p.b1)   # (..., HIDDEN)
    return h @ p.w2 + p.b2


class AdamState(NamedTuple):
    mu: MLPParams
    nu: MLPParams
    step: Array


@functools.partial(jax.jit, static_argnames=("steps",))
def mlp_train(key: Array, xs: Array, ys: Array, steps: int = 400,
              lr: float = 0.1, mask: Array | None = None) -> MLPParams:
    """Full-batch Adam fit of one tiny MLP: xs (n,) in [0,1] -> ys positions.

    vmap this over a leading pool axis to pre-train thousands of models as a
    single program (see ``train_pool``). ``mask`` (0/1 per point) supports
    batched ragged training over padded per-leaf segments.
    """
    if mask is None:
        mask = jnp.ones_like(xs)
    denom = jnp.maximum(mask.sum(), 1.0)
    yscale = jnp.maximum(jnp.max(jnp.abs(ys * mask)), 1.0)

    p0 = mlp_init(key)

    def loss_fn(p: MLPParams) -> Array:
        pred = mlp_predict(p, xs)
        return jnp.sum(mask * ((pred - ys) / yscale) ** 2) / denom

    def adam(carry, _):
        p, st = carry
        g = jax.grad(loss_fn)(p)
        step = st.step + 1
        mu = jax.tree.map(lambda m, gi: 0.9 * m + 0.1 * gi, st.mu, g)
        nu = jax.tree.map(lambda v, gi: 0.999 * v + 0.001 * gi * gi, st.nu, g)
        mhat = jax.tree.map(lambda m: m / (1 - 0.9 ** step), mu)
        vhat = jax.tree.map(lambda v: v / (1 - 0.999 ** step), nu)
        p = jax.tree.map(lambda pi, m, v: pi - lr * m / (jnp.sqrt(v) + 1e-8),
                         p, mhat, vhat)
        return (p, AdamState(mu, nu, step)), None

    zeros = jax.tree.map(jnp.zeros_like, p0)
    st0 = AdamState(zeros, zeros, jnp.zeros((), jnp.int32))
    (p, _), _ = jax.lax.scan(adam, (p0, st0), None, length=steps)
    return p


@functools.partial(jax.jit, static_argnames=("steps",))
def train_pool(seed: Array, xs: Array, ys: Array, steps: int = 400) -> MLPParams:
    """Pre-train a whole pool: xs/ys (P, ns) -> stacked MLPParams (P, ...).

    One program, one launch; the paper's two-orders-of-magnitude build-time
    claim comes from *reusing* these instead of retraining per dataset.
    """
    P = xs.shape[0]
    keys = jax.random.split(jax.random.PRNGKey(0) if seed is None else seed, P)
    return jax.vmap(lambda k, x, y: mlp_train(k, x, y, steps=steps))(keys, xs, ys)


# ---------------------------------------------------------------------------
# Error bounds (empirical residual extrema).
# ---------------------------------------------------------------------------
@jax.jit
def linear_err_bounds(p: LinearParams, xs: Array, pos: Array) -> tuple[Array, Array]:
    r = pos - linear_predict(p, xs)
    return jnp.min(r), jnp.max(r)


@jax.jit
def mlp_err_bounds(p: MLPParams, xs: Array, pos: Array) -> tuple[Array, Array]:
    r = pos - mlp_predict(p, xs)
    return jnp.min(r), jnp.max(r)
