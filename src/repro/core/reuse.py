"""Agile model reuse (paper Algorithm 1), TPU-native.

The paper keeps pre-trained models in a priority queue Q_MP sorted by error
bound and scans it linearly, returning the first entry whose distance to the
target is <= 1-eps. Here the pool is a stacked pytree and the scan is one
batched Algorithm-2 distance computation + a masked argmin — semantically
identical (the first eligible entry in ascending-error order IS the minimum-
error eligible entry) but O(1) depth on the MXU instead of a data-dependent
loop. Selection runs in a single jit; the Pallas-fused distance lives in
``repro.kernels.ksdist``.

Fresh-trained models are enqueued back into the pool (Algorithm 1 line 8),
preserving the ascending-error-bound order.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import cdf, models, synth
from .adapt import DomainSpec, adapt_linear, adapt_mlp, domain_of
from .bounds import reuse_err_bounds

Array = jax.Array


class PoolSelection(NamedTuple):
    found: Array      # bool — any pool entry within 1-eps?
    index: Array      # int32 — selected pool slot (min error bound among eligible)
    dist: Array       # float — Algorithm-2 distance of the selected entry


@functools.partial(jax.jit, static_argnames=())
def select_from_pool(pool_hists: Array, err_width: Array, target_hist: Array,
                     eps: Array) -> PoolSelection:
    """Batched Algorithm 1 selection: distances against the whole pool, then
    the minimum-error-bound entry among those with dist <= 1 - eps."""
    dists = cdf.hist_distance_pool(pool_hists, target_hist)
    eligible = dists <= (1.0 - eps)
    # err_width is sorted ascending at pool build; masked argmin over the
    # *rank* reproduces the paper's first-hit-in-queue-order semantics.
    rank = jnp.arange(pool_hists.shape[0])
    masked = jnp.where(eligible, rank, jnp.iinfo(jnp.int32).max)
    idx = jnp.argmin(masked)
    return PoolSelection(found=jnp.any(eligible), index=idx.astype(jnp.int32),
                         dist=dists[idx])


# Conservative slack added to the fused f32 distance so dist_h stays an
# upper bound of the exact KS distance despite the downcast (Eq. 3 safety).
_F32_GUARD = 1e-5


@jax.jit
def select_from_pool_fused(sel_a: Array, sel_ps: Array, target_hist: Array,
                           eps: Array) -> PoolSelection:
    """Fused fast path of :func:`select_from_pool` (jnp oracle of the Pallas
    kernel in ``repro.kernels.ksdist``).

    Pool-side prefix sums are precomputed at pool build: ``sel_a = H_S + P_S``
    and ``sel_ps = P_S`` (both (P, m) float32), so each selection is two
    broadcast-subtract-max passes instead of per-pair cumsums — the
    Algorithm-2 inner loop hoisted out of the scan, in f32 with a
    conservative guard term.
    """
    ht = target_hist.astype(jnp.float32)
    pt = jnp.concatenate([jnp.zeros((1,), jnp.float32), jnp.cumsum(ht)[:-1]])
    up = jnp.max(sel_a - pt[None, :], axis=1)            # (P,)
    dn = jnp.max((ht + pt)[None, :] - sel_ps, axis=1)    # (P,)
    dists = jnp.maximum(up, dn) + _F32_GUARD
    eligible = dists <= (1.0 - eps)
    rank = jnp.arange(sel_a.shape[0])
    masked = jnp.where(eligible, rank, jnp.iinfo(jnp.int32).max)
    idx = jnp.argmin(masked)
    return PoolSelection(found=jnp.any(eligible), index=idx.astype(jnp.int32),
                         dist=dists[idx].astype(jnp.float64))


def pool_prefix_tables(hists: Array) -> tuple[Array, Array]:
    """(sel_a, sel_ps) = (H_S + P_S, P_S) in f32 for the fused selection."""
    h = hists.astype(jnp.float32)
    ps = jnp.concatenate(
        [jnp.zeros((h.shape[0], 1), jnp.float32), jnp.cumsum(h, 1)[:, :-1]], 1)
    return h + ps, ps


@functools.partial(jax.jit, static_argnames=("chunk",))
def select_from_pool_batch(sel_a: Array, sel_ps: Array, target_hists: Array,
                           eps: Array, chunk: int = 128) -> PoolSelection:
    """Fused selection for MANY targets at once (all RMI leaves / RMRT level
    nodes). Processed in leaf-chunks so the (chunk, P, m) broadcast stays
    cache/VMEM-sized instead of materializing (L, P, m) — the same tiling the
    Pallas ksdist kernel uses on TPU."""
    L = target_hists.shape[0]
    pad = (-L) % chunk
    ht = jnp.pad(target_hists.astype(jnp.float32), ((0, pad), (0, 0)))
    pt = jnp.concatenate(
        [jnp.zeros((ht.shape[0], 1), jnp.float32), jnp.cumsum(ht, 1)[:, :-1]], 1)
    rank = jnp.arange(sel_a.shape[0])

    def one_chunk(args):
        h, p = args                                    # (chunk, m)
        up = jnp.max(sel_a[None] - p[:, None, :], axis=2)          # (chunk, P)
        dn = jnp.max((h + p)[:, None, :] - sel_ps[None], axis=2)   # (chunk, P)
        d = jnp.maximum(up, dn) + _F32_GUARD
        elig = d <= (1.0 - eps)
        masked = jnp.where(elig, rank[None], jnp.iinfo(jnp.int32).max)
        idx = jnp.argmin(masked, axis=1)
        return (jnp.any(elig, axis=1), idx.astype(jnp.int32),
                jnp.take_along_axis(d, idx[:, None], 1)[:, 0])

    nchunks = ht.shape[0] // chunk
    found, idx, dist = jax.lax.map(
        one_chunk, (ht.reshape(nchunks, chunk, -1),
                    pt.reshape(nchunks, chunk, -1)))
    flat = lambda a: a.reshape(-1)[:L]
    return PoolSelection(found=flat(found), index=flat(idx),
                         dist=flat(dist).astype(jnp.float64))


@dataclass
class AdaptedModel:
    """A model ready to index a target dataset (reused+adapted or fresh)."""
    kind: str                       # "linear" | "mlp"
    params: models.LinearParams | models.MLPParams
    err_lo: Array
    err_hi: Array
    reused: bool
    dist: float                    # Algorithm-2 distance used (0 for fresh)

    def predict(self, keys: Array) -> Array:
        if self.kind == "linear":
            return models.linear_predict(self.params, keys)
        return models.mlp_predict(self.params, keys)


@dataclass
class ModelPool:
    """Q_MP: stacked pre-trained models over synthetic datasets, sorted by
    ascending error-bound width. Host-mutable (enqueue), jit-read."""
    eps: float
    m: int
    kind: str                       # "linear" | "mlp"
    hists: Array                    # (P, m)
    params: models.LinearParams | models.MLPParams   # stacked (P, ...)
    err_lo: Array                   # (P,) on the source (synthetic) data
    err_hi: Array                   # (P,)
    domains: DomainSpec             # stacked (P,) source domains
    sel_a: Array | None = None      # (P, m) f32 fused-select table H_S + P_S
    sel_ps: Array | None = None     # (P, m) f32 fused-select table P_S
    reuse_count: int = 0
    trained_count: int = 0

    @property
    def size(self) -> int:
        return int(self.hists.shape[0])

    def _refresh_tables(self) -> None:
        self.sel_a, self.sel_ps = pool_prefix_tables(self.hists)

    # -- selection + adaptation ------------------------------------------
    def select(self, target_hist: Array) -> PoolSelection:
        if self.sel_a is None:
            self._refresh_tables()
        return select_from_pool_fused(self.sel_a, self.sel_ps, target_hist,
                                      jnp.float32(self.eps))

    def adapt(self, sel: PoolSelection, tgt: DomainSpec, n_t: Array,
              paper_bounds: bool = True,
              target_keys: Array | None = None) -> AdaptedModel:
        """Adapt the selected pool model to the target domain (Lemma 3.2
        folds) and derive its error bounds (Theorem 3.3).

        paper_bounds=True uses Theorem 3.3 exactly as published; otherwise
        (or additionally, when ``target_keys`` is given) residuals are
        measured on the target in one batched predict — still sound, tighter,
        and what a production deployment would ship.
        """
        i = sel.index
        src = jax.tree.map(lambda a: a[i], self.domains)
        p = jax.tree.map(lambda a: a[i], self.params)
        adapted = (adapt_linear if self.kind == "linear" else adapt_mlp)(p, src, tgt)
        s_dy = (tgt.y_end - tgt.y_start) / (src.y_end - src.y_start)
        lo, hi = reuse_err_bounds(self.err_lo[i], self.err_hi[i], sel.dist,
                                  n_t, s_dy)
        if not paper_bounds or target_keys is not None:
            pred = (models.linear_predict if self.kind == "linear"
                    else models.mlp_predict)(adapted, target_keys)
            r = jnp.arange(target_keys.shape[0], dtype=jnp.float64) - pred
            lo, hi = jnp.min(r), jnp.max(r)
        self.reuse_count += 1
        return AdaptedModel(kind=self.kind, params=adapted, err_lo=lo,
                            err_hi=hi, reused=True, dist=float(sel.dist))

    # -- Algorithm 1 end to end ------------------------------------------
    def reuse_or_train(self, sorted_keys: Array, *, enqueue: bool = True,
                       paper_bounds: bool = False,
                       train_steps: int = 400, seed: int = 0) -> AdaptedModel:
        """Algorithm 1 for one target dataset (keys sorted ascending)."""
        norm, lo_k, hi_k = cdf.normalize_keys(sorted_keys)
        th = cdf.histogram_sorted(norm, self.m, jnp.float64(0.0), jnp.float64(1.0))
        sel = self.select(th)
        tgt = domain_of(sorted_keys)
        n_t = jnp.asarray(sorted_keys.shape[0], jnp.float64)
        if bool(sel.found):
            return self.adapt(sel, tgt, n_t, paper_bounds=paper_bounds,
                              target_keys=None if paper_bounds else sorted_keys)
        # Miss: train fresh (Algorithm 1 lines 6-8) and enqueue.
        pos = jnp.arange(sorted_keys.shape[0], dtype=jnp.float64)
        if self.kind == "linear":
            p = models.linear_fit(sorted_keys, pos)
            elo, ehi = models.linear_err_bounds(p, sorted_keys, pos)
        else:
            p = models.mlp_train(jax.random.PRNGKey(seed), norm, pos,
                                 steps=train_steps)
            # Fold the key normalization into the model so it consumes raw keys.
            p = models.MLPParams(w1=p.w1 / (hi_k - lo_k),
                                 b1=p.b1 - p.w1 * lo_k / (hi_k - lo_k),
                                 w2=p.w2, b2=p.b2)
            elo, ehi = models.mlp_err_bounds(p, sorted_keys, pos)
        self.trained_count += 1
        fresh = AdaptedModel(kind=self.kind, params=p, err_lo=elo, err_hi=ehi,
                             reused=False, dist=0.0)
        if enqueue:
            self.enqueue(th, p, elo, ehi, tgt)
        return fresh

    def enqueue(self, hist: Array, params, err_lo: Array, err_hi: Array,
                dom: DomainSpec) -> None:
        """Insert a freshly trained model, keeping ascending error-width order."""
        width = float(err_hi - err_lo)
        widths = np.asarray(self.err_hi - self.err_lo)
        slot = int(np.searchsorted(widths, width))

        def ins(stack, item):
            item = jnp.asarray(item)[None]
            return jnp.concatenate([stack[:slot], item, stack[slot:]])

        self.hists = ins(self.hists, hist)
        self.params = jax.tree.map(ins, self.params, params)
        self.err_lo = ins(self.err_lo, err_lo)
        self.err_hi = ins(self.err_hi, err_hi)
        self.domains = jax.tree.map(ins, self.domains, dom)
        self._refresh_tables()


# ---------------------------------------------------------------------------
# Pool construction from the synthetic corpus.
# ---------------------------------------------------------------------------
def build_pool(sp: synth.SyntheticPool, kind: str = "mlp",
               train_steps: int = 400, seed: int = 0,
               m_sim: int = 64) -> ModelPool:
    """Pre-train the whole pool in one batched program and sort by error width.

    ``m_sim`` is the similarity-histogram resolution — the paper's metric
    parameter m, decoupled from the *generation* grid (sp.m). It must exceed
    the generation grid: with m_sim == m_gen every pool histogram has a bin
    of mass (1-eps), forcing dist_h >= 1-eps and starving reuse; at higher
    resolution dist_h approaches the exact KS distance from above (Eq. 3
    keeps it an upper bound at any m_sim).

    Synthetic keys live in [0,1] with positions 0..ns-1, so each source
    domain is x:[d[0], d[-1]], y:[0, ns-1].
    """
    data = jnp.asarray(sp.datasets)                    # (P, ns)
    P, ns = data.shape
    pos = jnp.broadcast_to(jnp.arange(ns, dtype=jnp.float64), (P, ns))

    if kind == "linear":
        params = jax.vmap(models.linear_fit)(data, pos)
        lo, hi = jax.vmap(models.linear_err_bounds)(params, data, pos)
    elif kind == "mlp":
        params = models.train_pool(jax.random.PRNGKey(seed), data, pos,
                                   steps=train_steps)
        lo, hi = jax.vmap(models.mlp_err_bounds)(params, data, pos)
    else:
        raise ValueError(kind)

    order = jnp.argsort(hi - lo)
    take = lambda a: a[order]
    domains = DomainSpec(
        x_start=data[:, 0], x_end=data[:, -1],
        y_start=jnp.zeros((P,), jnp.float64),
        y_end=jnp.full((P,), float(ns - 1), jnp.float64),
    )
    # Similarity histograms at metric resolution m_sim (bin range [0,1] —
    # domain adaptation handles the range mapping, so similarity is always
    # measured between *normalized* CDF shapes).
    sim_hists = jax.vmap(
        lambda d: cdf.histogram_sorted((d - d[0]) / (d[-1] - d[0]), m_sim,
                                       jnp.float64(0.0), jnp.float64(1.0))
    )(data)
    return ModelPool(
        eps=sp.eps, m=m_sim, kind=kind,
        hists=sim_hists[order],
        params=jax.tree.map(take, params),
        err_lo=lo[order], err_hi=hi[order],
        domains=jax.tree.map(take, domains),
    )
