"""Distributed learned-index service: range-partitioned keys under
``shard_map``, replicated model pool, all_to_all query routing.

Scale design (DESIGN.md §4): a production index over O(10^11) keys does not
fit one host. Keys are range-partitioned across the ``data`` mesh axis (the
pool — 30 MB at eps=0.9 — is replicated). A query batch arrives sharded;
each shard routes its queries to the owning shard with a capacity-bucketed
``all_to_all``, the owner answers with its local RMI (the same jitted lookup
path as the single-host index), and results return via the inverse
``all_to_all``. All collectives are explicit, so the dry-run roofline for
the index service is auditable like the LM cells.

This module is exercised two ways:
  * functionally on small meshes in tests (shard_map over 1-8 CPU devices),
  * structurally in the multi-pod dry-run (lower/compile on 256 devices) via
    ``repro.launch.dryrun --arch index_service``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from . import rmi as rmi_mod

Array = jax.Array


@dataclass
class ShardedIndex:
    """Per-shard RMI leaves + replicated routing table."""
    mesh: Mesh
    axis: str
    splits: Array            # (n_shards - 1,) range-partition boundaries
    # Stacked per-shard RMI components (leading dim = shard), each shard's
    # arrays padded to the max shard size.
    keys: Array              # (n_shards, cap)
    valid: Array             # (n_shards,) number of real keys per shard
    root: rmi_mod.models.LinearParams
    leaves: rmi_mod.models.LinearParams
    err_lo: Array
    err_hi: Array
    n_leaves: int
    search_iters: int | None = None   # error-window depth across all shards

    @property
    def n_shards(self) -> int:
        return int(self.keys.shape[0])


def build_sharded(keys: Array, mesh: Mesh, axis: str = "data",
                  n_leaves: int = 1024, pool=None) -> ShardedIndex:
    """Equal-count range partition; one RMI per shard (built batched)."""
    n_shards = mesh.shape[axis]
    keys = jnp.asarray(keys, jnp.float64)
    n = keys.shape[0]
    cap = -(-n // n_shards)
    splits = keys[jnp.arange(1, n_shards) * cap - 1]
    shards, valid = [], []
    roots, leaves, elos, ehis = [], [], [], []
    for s in range(n_shards):
        part = keys[s * cap:(s + 1) * cap]
        v = part.shape[0]
        idx = rmi_mod.build_rmi(part, n_leaves=n_leaves, kind="linear",
                                pool=pool)
        part = jnp.pad(part, (0, cap - v), constant_values=jnp.inf)
        shards.append(part)
        valid.append(v)
        roots.append(idx.root)
        leaves.append(idx.leaves)
        elos.append(idx.err_lo)
        ehis.append(idx.err_hi)
    stack = lambda xs: jax.tree.map(lambda *a: jnp.stack(a), *xs)
    from ..kernels.lookup import search_iters
    err_lo_all, err_hi_all = jnp.stack(elos), jnp.stack(ehis)
    return ShardedIndex(
        mesh=mesh, axis=axis, splits=splits,
        keys=jnp.stack(shards), valid=jnp.asarray(valid),
        root=stack(roots), leaves=stack(leaves),
        err_lo=err_lo_all, err_hi=err_hi_all, n_leaves=n_leaves,
        search_iters=search_iters(err_lo_all, err_hi_all, cap))


def make_lookup_fn(index: ShardedIndex, *, capacity_factor: float | None = None):
    """Returns a jitted distributed lookup: (q_local sharded on axis) ->
    global ranks, same sharding.

    ``capacity_factor``: per-destination slot budget as a multiple of the
    *balanced* load B/n_shards. None = worst-case B slots per destination
    (paper-faithful, never drops; all_to_all payload ~ n_shards x B).
    A factor like 2.0 shrinks the exchange by n_shards/2 at the cost of
    dropping queries beyond the budget (returned rank -1, retried by the
    caller) — EXPERIMENTS.md §Perf index-service iteration."""
    mesh, axis = index.mesh, index.axis
    n_shards = index.n_shards
    n_leaves = index.n_leaves
    cap = index.keys.shape[1]

    iters = index.search_iters      # static across shards; closure-captured

    def local_lookup(keys, root, leaves, elo, ehi, q):
        b = rmi_mod.root_buckets("linear", root, q, n_leaves, cap)
        p = jax.tree.map(lambda a: a[b], leaves)
        pred = rmi_mod.models.linear_predict(p, q)
        lo = jnp.clip(jnp.floor(pred + elo[b]), 0, cap - 1).astype(jnp.int32)
        hi = jnp.clip(jnp.ceil(pred + ehi[b]) + 1, 1, cap).astype(jnp.int32)
        return rmi_mod.verified_search(keys, q, lo, hi, iters=iters)

    def shard_fn(splits, keys, valid, root, leaves, elo, ehi, q_local):
        """Runs per shard. q_local: (B_local,). All index args are the
        *local* shard's slice (shard_map strips the leading shard dim)."""
        B = q_local.shape[0]
        me = jax.lax.axis_index(axis)
        dest = jnp.searchsorted(splits, q_local, side="left").astype(jnp.int32)
        # capacity-bucketed routing: C slots per destination shard
        if capacity_factor is None:
            C = B          # worst case: all local queries target one shard
        else:
            C = max(int(B * capacity_factor / n_shards), 1)
        slot_in_dest = _cumcount(dest, n_shards)
        send = jnp.full((n_shards, C), jnp.inf, q_local.dtype)
        send = send.at[dest, jnp.clip(slot_in_dest, 0, C - 1)].set(q_local)
        origin_pos = jnp.full((n_shards, C), -1, jnp.int32)
        origin_pos = origin_pos.at[dest, jnp.clip(slot_in_dest, 0, C - 1)].set(
            jnp.arange(B, dtype=jnp.int32))
        # exchange: row d of `send` goes to shard d
        recv = jax.lax.all_to_all(send, axis, 0, 0, tiled=False)
        rpos = jax.lax.all_to_all(origin_pos, axis, 0, 0, tiled=False)
        # answer locally (padded inf queries return `valid` = rank past end)
        rq = recv.reshape(-1)
        ranks = local_lookup(keys[0], jax.tree.map(lambda a: a[0], root),
                             jax.tree.map(lambda a: a[0], leaves),
                             elo[0], ehi[0], rq)
        ranks = jnp.minimum(ranks, valid[0]) + me * cap   # globalize
        ranks = ranks.reshape(n_shards, C)
        # return to origin
        back = jax.lax.all_to_all(ranks, axis, 0, 0, tiled=False)
        bpos = jax.lax.all_to_all(rpos, axis, 0, 0, tiled=False)
        # scatter answers to their origin slots; padding (pos -1) is routed
        # out of range and dropped. With a finite capacity_factor, queries
        # beyond the budget keep rank -1 (caller retries).
        flat_pos = bpos.reshape(-1)
        flat_val = back.reshape(-1)
        fill = jnp.full((B,), -1, ranks.dtype) if capacity_factor is not None \
            else jnp.zeros((B,), ranks.dtype)
        return fill.at[
            jnp.where(flat_pos >= 0, flat_pos, B)].set(flat_val, mode="drop")

    specs = dict(
        splits=P(), keys=P(axis), valid=P(axis), root=P(axis),
        leaves=P(axis), elo=P(axis), ehi=P(axis), q=P(axis))

    fn = jax.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(specs["splits"], specs["keys"], specs["valid"],
                  specs["root"], specs["leaves"], specs["elo"], specs["ehi"],
                  specs["q"]),
        out_specs=P(axis), check_vma=True)

    @jax.jit
    def lookup(q_global: Array) -> Array:
        return fn(index.splits, index.keys, index.valid, index.root,
                  index.leaves, index.err_lo, index.err_hi, q_global)

    return lookup


def _cumcount(ids: Array, n_bins: int) -> Array:
    """Occurrence rank of each element among equal ids (stable)."""
    n = ids.shape[0]
    order = jnp.argsort(ids, stable=True)
    sorted_ids = ids[order]
    start = jnp.searchsorted(sorted_ids, jnp.arange(n_bins))
    ranks_sorted = jnp.arange(n, dtype=jnp.int32) - start[sorted_ids].astype(jnp.int32)
    return jnp.zeros((n,), jnp.int32).at[order].set(ranks_sorted)
