"""Distributed learned-index service: range-partitioned keys under
``shard_map``, replicated model pool, all_to_all query routing.

Scale design (DESIGN.md §4): a production index over O(10^11) keys does not
fit one host. Keys are range-partitioned across the ``data`` mesh axis (the
pool — 30 MB at eps=0.9 — is replicated). A query batch arrives sharded;
each shard routes its queries to the owning shard with a capacity-bucketed
``all_to_all``, the owner answers with its local RMI (the same jitted lookup
path as the single-host index), and results return via the inverse
``all_to_all``. All collectives are explicit, so the dry-run roofline for
the index service is auditable like the LM cells.

This module is exercised two ways:
  * functionally on small meshes in tests (shard_map over 1-8 CPU devices),
  * structurally in the multi-pod dry-run (lower/compile on 256 devices) via
    ``repro.launch.dryrun --arch index_service``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from . import rmi as rmi_mod

Array = jax.Array


@dataclass
class ShardedIndex:
    """Per-shard RMI leaves + replicated routing table."""
    mesh: Mesh
    axis: str
    splits: Array            # (n_shards - 1,) range-partition boundaries
    # Stacked per-shard RMI components (leading dim = shard), each shard's
    # arrays padded to the max shard size.
    keys: Array              # (n_shards, cap)
    valid: Array             # (n_shards,) number of real keys per shard
    root: rmi_mod.models.LinearParams
    leaves: rmi_mod.models.LinearParams
    err_lo: Array
    err_hi: Array
    n_leaves: int
    search_iters: int | None = None   # error-window depth across all shards
    # Stacked packed kernel tables (lookup.pack_root / pack_leaves per
    # shard) so the per-shard answer can dispatch through the fused Pallas
    # kernel under shard_map.  Packed lazily on the first kernel-path
    # make_lookup_fn — jnp-path consumers (CPU meshes, the 256-device
    # dry-run) never pay for them.
    kroot: Array = None      # (n_shards, ROOT_ROWS, 128)
    kmat: Array = None       # (n_shards, 3H, Lp)
    kvec: Array = None       # (n_shards, 8, Lp)
    _f32_exact: bool | None = None

    @property
    def n_shards(self) -> int:
        return int(self.keys.shape[0])

    @property
    def f32_exact(self) -> bool:
        """Every shard's keys round-trip through f32 (kernel-path
        precondition; the +inf shard padding round-trips trivially).
        Lazily computed — one reduction over the stacked shards."""
        if self._f32_exact is None:
            k32 = self.keys.astype(jnp.float32).astype(jnp.float64)
            self._f32_exact = bool(jnp.all(k32 == self.keys))
        return self._f32_exact

    def packed_tables(self) -> tuple:
        """(kroot, kmat, kvec) stacked per-shard kernel tables, packed on
        first use and cached on the dataclass."""
        if self.kroot is None:
            from ..kernels import lookup as _lk
            kr, km, kv = [], [], []
            for s in range(self.n_shards):
                root_s = jax.tree.map(lambda a: a[s], self.root)
                leaves_s = jax.tree.map(lambda a: a[s], self.leaves)
                kr.append(_lk.pack_root("linear", root_s))
                w1, b1, w2, b2 = rmi_mod._leaf_table_arrays(
                    "linear", leaves_s, self.n_leaves)
                m, v = _lk.pack_leaves(w1, b1, w2, b2, self.err_lo[s],
                                       self.err_hi[s])
                km.append(m)
                kv.append(v)
            self.kroot = jnp.stack(kr)
            self.kmat = jnp.stack(km)
            self.kvec = jnp.stack(kv)
        return self.kroot, self.kmat, self.kvec


def build_sharded(keys: Array, mesh: Mesh, axis: str = "data",
                  n_leaves: int = 1024, pool=None) -> ShardedIndex:
    """Equal-count range partition; one RMI per shard (built batched)."""
    n_shards = mesh.shape[axis]
    keys = jnp.asarray(keys, jnp.float64)
    n = keys.shape[0]
    cap = -(-n // n_shards)
    splits = keys[jnp.minimum(jnp.arange(1, n_shards) * cap, n) - 1]
    shards, valid = [], []
    roots, leaves, elos, ehis = [], [], [], []
    for s in range(n_shards):
        part = keys[s * cap:(s + 1) * cap]
        v = part.shape[0]
        idx = rmi_mod.build_rmi(part, n_leaves=n_leaves, kind="linear",
                                pool=pool)
        part = jnp.pad(part, (0, cap - v), constant_values=jnp.inf)
        shards.append(part)
        valid.append(v)
        roots.append(idx.root)
        leaves.append(idx.leaves)
        elos.append(idx.err_lo)
        ehis.append(idx.err_hi)
    stack = lambda xs: jax.tree.map(lambda *a: jnp.stack(a), *xs)
    from ..kernels.lookup import search_iters
    err_lo_all, err_hi_all = jnp.stack(elos), jnp.stack(ehis)
    return ShardedIndex(
        mesh=mesh, axis=axis, splits=splits,
        keys=jnp.stack(shards), valid=jnp.asarray(valid),
        root=stack(roots), leaves=stack(leaves),
        err_lo=err_lo_all, err_hi=err_hi_all, n_leaves=n_leaves,
        search_iters=search_iters(err_lo_all, err_hi_all, cap))


def make_lookup_fn(index: ShardedIndex, *,
                   capacity_factor: float | None = None,
                   use_kernel: bool | None = None,
                   interpret: bool | None = None):
    """Returns a jitted distributed lookup: (q_local sharded on axis) ->
    global ranks, same sharding.

    ``capacity_factor``: per-destination slot budget as a multiple of the
    *balanced* load B/n_shards. None = worst-case B slots per destination
    (paper-faithful, never drops; all_to_all payload ~ n_shards x B).
    A factor like 2.0 shrinks the exchange by n_shards/2 at the cost of
    dropping queries beyond the budget (returned rank -1, retried by the
    caller) — EXPERIMENTS.md §Perf index-service iteration.

    ``use_kernel`` routes the per-shard answer through the fused Pallas
    kernel (``kernels.ops.index_lookup``: in-kernel routing + clamped tiled
    search + sparse seam verification) instead of the clamped jnp path —
    the same path-selection contract as ``rmi.lookup``: default on TPU
    backends when every shard's keys are f32-exact, explicit True on a
    non-f32-exact index raises (the kernel's f32 seam verification cannot
    detect f32 key collisions).  ``interpret`` forwards to the kernel
    (None = auto: interpreter off-TPU)."""
    mesh, axis = index.mesh, index.axis
    n_shards = index.n_shards
    n_leaves = index.n_leaves
    cap = index.keys.shape[1]

    iters = index.search_iters      # static across shards; closure-captured

    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu" and index.f32_exact
    elif use_kernel and not index.f32_exact:
        raise ValueError(
            "use_kernel=True on a sharded key space that is not f32-exact: "
            "the kernel's f32 seam verification cannot detect f32 key "
            "collisions, so wrong positions would be returned silently")

    if use_kernel:
        from ..kernels import ops as kernel_ops

        def local_lookup(tables, keys, q):
            kroot, kmat, kvec = tables
            return kernel_ops.index_lookup(
                q, kroot, kmat, kvec, keys, n_leaves=n_leaves,
                root_kind="linear", leaf_kind="linear", iters=iters,
                interpret=interpret)

        tables = index.packed_tables()
    else:
        def local_lookup(tables, keys, q):
            root, leaves, elo, ehi = tables
            b = rmi_mod.root_buckets("linear", root, q, n_leaves, cap)
            p = jax.tree.map(lambda a: a[b], leaves)
            pred = rmi_mod.models.linear_predict(p, q)
            lo = jnp.clip(jnp.floor(pred + elo[b]), 0,
                          cap - 1).astype(jnp.int32)
            hi = jnp.clip(jnp.ceil(pred + ehi[b]) + 1, 1,
                          cap).astype(jnp.int32)
            return rmi_mod.verified_search(keys, q, lo, hi, iters=iters)

        tables = (index.root, index.leaves, index.err_lo, index.err_hi)

    def shard_fn(splits, keys, valid, tables, q_local):
        """Runs per shard. q_local: (B_local,). All index args are the
        *local* shard's slice (shard_map strips the leading shard dim)."""
        B = q_local.shape[0]
        me = jax.lax.axis_index(axis)
        dest = jnp.searchsorted(splits, q_local, side="left").astype(jnp.int32)
        # capacity-bucketed routing: C slots per destination shard
        if capacity_factor is None:
            C = B          # worst case: all local queries target one shard
        else:
            C = max(int(B * capacity_factor / n_shards), 1)
        slot_in_dest = _cumcount(dest, n_shards)
        send = jnp.full((n_shards, C), jnp.inf, q_local.dtype)
        send = send.at[dest, jnp.clip(slot_in_dest, 0, C - 1)].set(q_local)
        origin_pos = jnp.full((n_shards, C), -1, jnp.int32)
        origin_pos = origin_pos.at[dest, jnp.clip(slot_in_dest, 0, C - 1)].set(
            jnp.arange(B, dtype=jnp.int32))
        # exchange: row d of `send` goes to shard d
        recv = jax.lax.all_to_all(send, axis, 0, 0, tiled=False)
        rpos = jax.lax.all_to_all(origin_pos, axis, 0, 0, tiled=False)
        # answer locally.  +inf exchange-padding slots are masked to a
        # member query first and answered `valid` (= rank past end)
        # directly: on an inf-padded (ragged) shard an inf query always
        # fails the left-boundary seam check, and a batch of them would
        # blow the sparse seam budget and demote every lookup to the dense
        # re-search fallback (both the kernel's _seam_fix and the jnp
        # path's verified_search).
        rq = recv.reshape(-1)
        live = rq < jnp.inf                  # excludes +inf pads and NaN
        ranks = local_lookup(jax.tree.map(lambda a: a[0], tables), keys[0],
                             jnp.where(live, rq, keys[0][0]))
        ranks = jnp.where(live, ranks, valid[0])
        ranks = jnp.minimum(ranks, valid[0]) + me * cap   # globalize
        ranks = ranks.reshape(n_shards, C)
        # return to origin
        back = jax.lax.all_to_all(ranks, axis, 0, 0, tiled=False)
        bpos = jax.lax.all_to_all(rpos, axis, 0, 0, tiled=False)
        # scatter answers to their origin slots; padding (pos -1) is routed
        # out of range and dropped. With a finite capacity_factor, queries
        # beyond the budget keep rank -1 (caller retries).
        flat_pos = bpos.reshape(-1)
        flat_val = back.reshape(-1)
        fill = jnp.full((B,), -1, ranks.dtype) if capacity_factor is not None \
            else jnp.zeros((B,), ranks.dtype)
        return fill.at[
            jnp.where(flat_pos >= 0, flat_pos, B)].set(flat_val, mode="drop")

    fn = jax.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(), P(axis), P(axis), P(axis), P(axis)),
        out_specs=P(axis), check_vma=True)

    @jax.jit
    def lookup(q_global: Array) -> Array:
        return fn(index.splits, index.keys, index.valid, tables, q_global)

    return lookup


def _cumcount(ids: Array, n_bins: int) -> Array:
    """Occurrence rank of each element among equal ids (stable)."""
    n = ids.shape[0]
    order = jnp.argsort(ids, stable=True)
    sorted_ids = ids[order]
    start = jnp.searchsorted(sorted_ids, jnp.arange(n_bins))
    ranks_sorted = jnp.arange(n, dtype=jnp.int32) - start[sorted_ids].astype(jnp.int32)
    return jnp.zeros((n,), jnp.int32).at[order].set(ranks_sorted)
