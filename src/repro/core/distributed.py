"""Distributed learned-index service: range-partitioned keys under
``shard_map``, replicated model pool, all_to_all query routing.

Scale design (DESIGN.md §4): a production index over O(10^11) keys does not
fit one host. Keys are range-partitioned across the ``data`` mesh axis (the
pool — 30 MB at eps=0.9 — is replicated). A query batch arrives sharded;
each shard routes its queries to the owning shard with a capacity-bucketed
``all_to_all``, the owner answers with its local RMI (the same jitted lookup
path as the single-host index), and results return via the inverse
``all_to_all``. All collectives are explicit, so the dry-run roofline for
the index service is auditable like the LM cells.

Partitioning invariants (shared by the static and dynamic index): shard
boundaries come from :func:`shard_bounds`, an equal-count split *snapped to
equal-key run starts*, so a run of duplicate keys is always owned by exactly
one shard.  ``splits[s]`` is the last key of shard s and every key of shard
s+1 is strictly greater, hence ``searchsorted(splits, q, side="left")``
routes every query/update for a key to the one shard that can own it and
the global leftmost live rank decomposes as (live keys in shards < dest) +
(local leftmost rank).  Shards may be *empty* (n < n_shards, or runs longer
than a balanced shard): they carry a trivial zero-model RMI over an all-+inf
key block, answer rank 0 / found False, and re-absorb load through
rebalancing.

Dynamic serving (``ShardedDynamicIndex``): each shard owns a full two-tier
``core.updates.DynamicRMI`` — base tier + sorted pow2-capacity delta tier
with tombstone bitmaps, per-leaf Lemma 4.1 budgets driving pool-reuse
rebuilds (``rmi.fit_leaves``).  ``insert_batch``/``delete_batch`` pre-bucket
keys by the split vector on the host and run one device merge per touched
shard; ``find`` dispatches the fused ``dynamic_lookup_pallas`` kernel — or
its jnp oracle — per shard under ``shard_map`` with the same
capacity-bucketed ``all_to_all`` exchange as the static path.  Per-shard
frozen routing scales ride the packed root blocks
(``lookup.pack_root(route_scale=...)``) so one statically-traced kernel
serves every shard.

Slice-cache invalidation contract (the maintenance cost model): the stacked
device state the ``shard_map`` dispatch consumes is assembled from
*per-shard slices* and maintained incrementally, so every mutation path
costs O(touched shards), never O(all shards):

  * Each shard stores its tiers at its **own** capacity class
    (``kernels.lookup.capacity_class`` — pow2, 128 floor); the assembled
    stack pads every slice to the *global* max class with +inf keys / zero
    tombstones / edge-extended prefix sums.
  * A mutation (routed merge, delete, rebuild, migration) marks only the
    touched shards dirty; the next ``find`` rewrites exactly those rows of
    the stacked arrays (one batched row-scatter per array) and leaves the
    rest untouched.  Packed kernel tables ride the same rows: per-shard
    ``mat``/``vec`` come from the shard's cached ``RMIIndex.packed_tables``
    and the root block from ``DynamicRMI.packed_root`` (cacheable forever —
    roots and routing scales are frozen at shard build).
  * Re-padding the whole stack happens **only** when the global capacity
    class actually changes — i.e. a shard's tier outgrows (or a rebuilt
    shard retires) the current global max.  A hot shard doubling *below*
    the global max stays a row-local event.
  * Shard-level scalars (live offsets, rebalance counters) live in a
    device-resident ``(n_shards, 4)`` counter table updated with O(touched)
    row scatters; the rebalance trigger is one jitted reduction over it
    returning two scalars, so trigger cost no longer scales with the host
    counter scan at O(1k) shards.

Skew handling: when the device trigger fires (delta ratio, dead ratio, or
raw live-count skew), whole boundary runs migrate to an adjacent shard and
the split between them moves — monotone and duplicate-run-safe because cuts
snap to run boundaries.  Migration is *incremental*: the donor sheds its
boundary region in place (``DynamicRMI.shed_suffix``/``shed_prefix`` — a
truncation or an exact uniform intercept shift; no refit), and the migrated
run rides the **delta tier** of the receiver via the ordinary routed merge,
at worst triggering localized Lemma 4.1 leaf rebuilds.  Only when the run
overflows the receiver's aggregate Lemma 4.1 insertion headroom
(``bounds.insertion_headroom`` — the regime where most leaves would churn
anyway) does the receiver fall back to one full rebuild; delta-hot shards
with balanced live counts flush their delta in place
(``DynamicRMI.flush_delta``) instead of rebuilding from scratch.

This module is exercised two ways:
  * functionally on small meshes in tests (shard_map over 1-8 CPU devices),
  * structurally in the multi-pod dry-run (lower/compile on 256 devices) via
    ``repro.launch.dryrun --arch index_service``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from . import drift as drift_mod
from . import rmi as rmi_mod
from .paths import resolve_path

Array = jax.Array


@dataclass
class ShardedIndex:
    """Per-shard RMI leaves + replicated routing table."""
    mesh: Mesh
    axis: str
    splits: Array            # (n_shards - 1,) range-partition boundaries
    # Stacked per-shard RMI components (leading dim = shard), each shard's
    # arrays padded to the max shard size.
    keys: Array              # (n_shards, cap)
    valid: Array             # (n_shards,) number of real keys per shard
    root: rmi_mod.models.LinearParams
    leaves: rmi_mod.models.LinearParams
    err_lo: Array
    err_hi: Array
    n_leaves: int
    search_iters: int | None = None   # error-window depth across all shards
    # Stacked packed kernel tables (lookup.pack_root / pack_leaves per
    # shard) so the per-shard answer can dispatch through the fused Pallas
    # kernel under shard_map.  Packed lazily on the first kernel-path
    # make_lookup_fn — jnp-path consumers (CPU meshes, the 256-device
    # dry-run) never pay for them.
    kroot: Array = None      # (n_shards, ROOT_ROWS, 128)
    kmat: Array = None       # (n_shards, 3H, Lp)
    kvec: Array = None       # (n_shards, 8, Lp)
    _f32_exact: bool | None = None

    @property
    def n_shards(self) -> int:
        return int(self.keys.shape[0])

    @property
    def f32_exact(self) -> bool:
        """Every shard's keys round-trip through f32 (kernel-path
        precondition; the +inf shard padding round-trips trivially).
        Lazily computed — one reduction over the stacked shards."""
        if self._f32_exact is None:
            k32 = self.keys.astype(jnp.float32).astype(jnp.float64)
            self._f32_exact = bool(jnp.all(k32 == self.keys))
        return self._f32_exact

    def packed_tables(self) -> tuple:
        """(kroot, kmat, kvec) stacked per-shard kernel tables, packed on
        first use and cached on the dataclass."""
        if self.kroot is None:
            from ..kernels import lookup as _lk
            kr, km, kv = [], [], []
            for s in range(self.n_shards):
                root_s = jax.tree.map(lambda a, s=s: a[s], self.root)
                leaves_s = jax.tree.map(lambda a, s=s: a[s], self.leaves)
                kr.append(_lk.pack_root("linear", root_s))
                w1, b1, w2, b2 = rmi_mod._leaf_table_arrays(
                    "linear", leaves_s, self.n_leaves)
                m, v = _lk.pack_leaves(w1, b1, w2, b2, self.err_lo[s],
                                       self.err_hi[s])
                km.append(m)
                kv.append(v)
            self.kroot = jnp.stack(kr)
            self.kmat = jnp.stack(km)
            self.kvec = jnp.stack(kv)
        return self.kroot, self.kmat, self.kvec


def shard_bounds(keys: np.ndarray, n_shards: int) -> np.ndarray:
    """Equal-count partition positions over sorted ``keys``, snapped to
    equal-key run *starts* so no duplicate run straddles a shard seam.

    Returns (n_shards + 1,) non-decreasing positions b with b[0] = 0 and
    b[-1] = n; shard s owns keys[b[s]:b[s+1]].  b[s] == b[s+1] marks an
    empty shard (n < n_shards, or a run longer than a balanced shard
    swallowing a boundary).  The snap guarantees the routing invariant the
    global-rank arithmetic rests on: every key of shard s+1 is strictly
    greater than the last key of shard s."""
    n = int(keys.shape[0])
    cap = -(-n // n_shards) if n else 0
    b = np.minimum(np.arange(n_shards + 1, dtype=np.int64) * max(cap, 1), n)
    for s in range(1, n_shards):
        p = int(b[s])
        if 0 < p < n and keys[p - 1] == keys[p]:
            b[s] = np.searchsorted(keys, keys[p], side="left")
    return np.maximum.accumulate(b)


def _splits_from_bounds(keys: np.ndarray, bounds: np.ndarray) -> np.ndarray:
    """(n_shards - 1,) split values: splits[s] = last key of shard s.
    Shards that are empty *with no key to their left* (an all-empty prefix)
    get -inf so any finite query routes past them; empty shards later in
    the order repeat the previous split (monotone either way)."""
    return np.asarray([keys[bounds[s + 1] - 1] if bounds[s + 1] > 0
                       else -np.inf for s in range(bounds.shape[0] - 2)],
                      np.float64)


def build_sharded(keys: Array, mesh: Mesh, axis: str = "data",
                  n_leaves: int = 1024, pool=None) -> ShardedIndex:
    """Equal-count range partition snapped to duplicate-run boundaries; one
    RMI per shard (empty shards get the trivial zero-model build)."""
    n_shards = mesh.shape[axis]
    keys = jnp.asarray(keys, jnp.float64)
    n = keys.shape[0]
    if n == 0:
        raise ValueError("build_sharded needs at least one key")
    kn = np.asarray(keys)
    bounds = shard_bounds(kn, n_shards)
    cap = max(int(np.diff(bounds).max()), 1)
    splits = jnp.asarray(_splits_from_bounds(kn, bounds))
    shards, valid = [], []
    roots, leaves, elos, ehis = [], [], [], []
    for s in range(n_shards):
        part = keys[int(bounds[s]):int(bounds[s + 1])]
        v = part.shape[0]
        idx = rmi_mod.build_rmi(part, n_leaves=n_leaves, kind="linear",
                                pool=pool)
        part = jnp.pad(part, (0, cap - v), constant_values=jnp.inf)
        shards.append(part)
        valid.append(v)
        roots.append(idx.root)
        leaves.append(idx.leaves)
        elos.append(idx.err_lo)
        ehis.append(idx.err_hi)
    stack = lambda xs: jax.tree.map(lambda *a: jnp.stack(a), *xs)
    from ..kernels.lookup import search_iters
    err_lo_all, err_hi_all = jnp.stack(elos), jnp.stack(ehis)
    return ShardedIndex(
        mesh=mesh, axis=axis, splits=splits,
        keys=jnp.stack(shards), valid=jnp.asarray(valid),
        root=stack(roots), leaves=stack(leaves),
        err_lo=err_lo_all, err_hi=err_hi_all, n_leaves=n_leaves,
        search_iters=search_iters(err_lo_all, err_hi_all, cap))


def make_lookup_fn(index: ShardedIndex, *,
                   capacity_factor: float | None = None,
                   path: str = "auto",
                   use_kernel: bool | None = None,
                   interpret: bool | None = None):
    """Returns a jitted distributed lookup: (q_local sharded on axis) ->
    global ranks, same sharding.

    ``capacity_factor``: per-destination slot budget as a multiple of the
    *balanced* load B/n_shards. None = worst-case B slots per destination
    (paper-faithful, never drops; all_to_all payload ~ n_shards x B).
    A factor like 2.0 shrinks the exchange by n_shards/2 at the cost of
    dropping queries beyond the budget (returned rank -1, retried by the
    caller) — EXPERIMENTS.md §Perf index-service iteration.

    ``path`` routes the per-shard answer through the fused Pallas kernel
    (``kernels.ops.index_lookup``: in-kernel routing + clamped tiled
    search + sparse seam verification) or the clamped jnp path — the
    shared :func:`core.paths.resolve_path` contract (``"auto"`` = kernel
    on TPU backends when every shard's keys are f32-exact).
    ``use_kernel=`` is the deprecated boolean shim.  ``interpret``
    forwards to the kernel (None = auto: interpreter off-TPU)."""
    mesh, axis = index.mesh, index.axis
    n_shards = index.n_shards
    n_leaves = index.n_leaves
    cap = index.keys.shape[1]

    iters = index.search_iters      # static across shards; closure-captured

    use_kernel = resolve_path(path, f32_exact=lambda: index.f32_exact,
                              use_kernel=use_kernel,
                              what="sharded key space")

    if use_kernel:
        from ..kernels import ops as kernel_ops

        def local_lookup(tables, keys, q):
            kroot, kmat, kvec = tables
            return kernel_ops.index_lookup(
                q, kroot, kmat, kvec, keys, n_leaves=n_leaves,
                root_kind="linear", leaf_kind="linear", iters=iters,
                interpret=interpret)

        tables = index.packed_tables()
    else:
        def local_lookup(tables, keys, q):
            root, leaves, elo, ehi = tables
            b = rmi_mod.root_buckets("linear", root, q, n_leaves, cap)
            p = jax.tree.map(lambda a: a[b], leaves)
            pred = rmi_mod.models.linear_predict(p, q)
            lo = jnp.clip(jnp.floor(pred + elo[b]), 0,
                          cap - 1).astype(jnp.int32)
            hi = jnp.clip(jnp.ceil(pred + ehi[b]) + 1, 1,
                          cap).astype(jnp.int32)
            return rmi_mod.verified_search(keys, q, lo, hi, iters=iters)

        tables = (index.root, index.leaves, index.err_lo, index.err_hi)

    def shard_fn(splits, keys, valid, tables, q_local):
        """Runs per shard. q_local: (B_local,). All index args are the
        *local* shard's slice (shard_map strips the leading shard dim)."""
        B = q_local.shape[0]
        me = jax.lax.axis_index(axis)
        # capacity-bucketed routing: C slots per destination shard
        if capacity_factor is None:
            C = B          # worst case: all local queries target one shard
        else:
            C = max(int(B * capacity_factor / n_shards), 1)

        def answer(rq, live):
            # +inf exchange-padding slots are masked to a member query
            # first and answered `valid` (= rank past end) directly: on an
            # inf-padded (ragged) shard an inf query always fails the
            # left-boundary seam check, and a batch of them would blow the
            # sparse seam budget and demote every lookup to the dense
            # re-search fallback (both the kernel's _seam_fix and the jnp
            # path's verified_search).  An *empty* shard has no member key
            # (keys[0][0] is itself +inf) — mask to 0.0, which resolves to
            # position 0 against its all-+inf block.
            member = jnp.where(jnp.isfinite(keys[0][0]), keys[0][0], 0.0)
            ranks = local_lookup(jax.tree.map(lambda a: a[0], tables),
                                 keys[0], jnp.where(live, rq, member))
            ranks = jnp.where(live, ranks, valid[0])
            return (jnp.minimum(ranks, valid[0]) + me * cap)[:, None]

        # With a finite capacity_factor, queries beyond the budget keep
        # rank -1 (caller retries).
        (ranks,) = _routed_exchange(
            axis, n_shards, splits, q_local, C, answer,
            (-1 if capacity_factor is not None else 0,))
        return ranks

    fn = jax.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(), P(axis), P(axis), P(axis), P(axis)),
        out_specs=P(axis), check_vma=True)

    @jax.jit
    def lookup(q_global: Array) -> Array:
        return fn(index.splits, index.keys, index.valid, tables, q_global)

    return lookup


def _cumcount(ids: Array, n_bins: int) -> Array:
    """Occurrence rank of each element among equal ids (stable)."""
    n = ids.shape[0]
    order = jnp.argsort(ids, stable=True)
    sorted_ids = ids[order]
    start = jnp.searchsorted(sorted_ids, jnp.arange(n_bins))
    ranks_sorted = jnp.arange(n, dtype=jnp.int32) - start[sorted_ids].astype(jnp.int32)
    return jnp.zeros((n,), jnp.int32).at[order].set(ranks_sorted)


def _routed_exchange(axis: str, n_shards: int, splits, q_local, C: int,
                     answer_fn, fills: tuple) -> list:
    """The capacity-bucketed query exchange shared by every shard_map body
    here (static lookup and dynamic find): route each local query to its
    owning shard (``searchsorted`` over the split vector, C slots per
    destination, +inf padding), ``all_to_all`` out, apply
    ``answer_fn(rq, live) -> (n_shards * C, P) int32 payload`` locally,
    and return the payload through the inverse exchange scattered back to
    each query's origin slot.

    Returns one (B,) int32 array per payload column; ``fills[k]`` is
    column k's value for unanswered slots (queries beyond a finite
    capacity budget, or exchange padding).
    """
    B = q_local.shape[0]
    dest = jnp.searchsorted(splits, q_local, side="left").astype(jnp.int32)
    slot = jnp.clip(_cumcount(dest, n_shards), 0, C - 1)
    send = jnp.full((n_shards, C), jnp.inf, q_local.dtype)
    send = send.at[dest, slot].set(q_local)
    opos = jnp.full((n_shards, C), -1, jnp.int32)
    opos = opos.at[dest, slot].set(jnp.arange(B, dtype=jnp.int32))
    recv = jax.lax.all_to_all(send, axis, 0, 0, tiled=False)
    rpos = jax.lax.all_to_all(opos, axis, 0, 0, tiled=False)
    rq = recv.reshape(-1)
    live = rq < jnp.inf                  # excludes +inf pads and NaN
    payload = answer_fn(rq, live)
    P = payload.shape[-1]
    back = jax.lax.all_to_all(payload.reshape(n_shards, C, P), axis, 0, 0,
                              tiled=False)
    bpos = jax.lax.all_to_all(rpos, axis, 0, 0, tiled=False)
    # scatter answers to their origin slots; padding (pos -1) is routed
    # out of range and dropped, leaving the fill value.
    tgt = jnp.where(bpos.reshape(-1) >= 0, bpos.reshape(-1), B)
    fv = back.reshape(-1, P)
    return [jnp.full((B,), fills[k], jnp.int32).at[tgt].set(fv[:, k],
                                                            mode="drop")
            for k in range(P)]


# ---------------------------------------------------------------------------
# Sharded dynamic index: per-shard two-tier DynamicRMI with routed updates,
# fused per-shard find under shard_map, and run-snapped split rebalancing.
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, donate_argnums=(0,))
def _row_scatter_jit(dst: Array, idx: Array, rows: Array) -> Array:
    return dst.at[idx].set(rows)


def scatter_rows_donated(dst: Array, idx: Array, rows: Array) -> Array:
    """Batched row scatter ``dst[idx] = rows`` with the destination buffer
    *donated*: the restack slice cache (and the serve front-end's tenant
    stack riding it) rewrites dirty rows truly in place instead of
    allocating a copy of the whole stacked array per mutation.  The caller
    must drop its handle to ``dst`` (it is invalidated by donation) and
    keep only the returned array.

    No-copy assertion: when XLA accepts the donation it consumes the input
    buffer and jax marks the handle deleted — ``dst.is_deleted()`` is the
    signal jax exposes for "the write aliased, no copy was scheduled"
    (refused donations leave the input alive and warn instead).  The CPU,
    GPU and TPU clients all honor input-output aliasing for this
    same-shape scatter, so a live ``dst`` after the call is a real
    regression, not backend noise.
    """
    out = _row_scatter_jit(dst, idx, rows)
    if not dst.is_deleted():
        raise AssertionError(
            "row-scatter restack was not donated: XLA refused the "
            "input-output alias and scheduled a copy")
    return out


@jax.jit
def _offs_jit(counts: Array) -> Array:
    """Per-shard global live-rank offsets from the device counter table:
    offs[s] = live keys in shards < s (one cumsum — no host scan)."""
    live = counts[:, 0] - counts[:, 1] + counts[:, 2]
    return (jnp.cumsum(live) - live).astype(jnp.int32)


@jax.jit
def _rebalance_trigger_jit(counts: Array, muted: Array, ratio, skew):
    """The rebalance trigger as one device reduction over the counter
    table (columns: base_n, base_dead, delta_live, delta_dead): returns
    (hot shard id or -1, skewed?, delta-hot?, dead-hot?) — the only values
    the host-side policy needs, so the per-batch trigger cost is four
    synced scalars instead of an O(n_shards) host counter scan."""
    livei = counts[:, 0] - counts[:, 1] + counts[:, 2]
    live = livei.astype(jnp.float64)
    dlive = counts[:, 2].astype(jnp.float64)
    deadf = (counts[:, 1] + counts[:, 3]).astype(jnp.float64)
    stored = (counts[:, 0] + counts[:, 2] + counts[:, 3]).astype(jnp.float64)
    delta_hot = dlive / jnp.maximum(live, 1.0) > ratio
    dead_hot = deadf / jnp.maximum(stored, 1.0) > ratio
    tier = delta_hot | dead_hot
    mean = jnp.maximum(jnp.sum(live) / live.shape[0], 1.0)
    skewed = (live > skew * mean) & (livei != muted)
    trig = tier | skewed
    hot = jnp.argmax(jnp.where(trig, live, -1.0)).astype(jnp.int32)
    any_ = jnp.any(trig)
    return (jnp.where(any_, hot, -1), skewed[hot] & any_,
            delta_hot[hot] & any_, dead_hot[hot] & any_)


@dataclass
class ShardedDynamicIndex:
    """Range-partitioned two-tier dynamic index (module docstring: layout,
    slice-cache invalidation contract, and rebalance policy).  Mutations
    are host-driven per shard (each shard is a ``core.updates.DynamicRMI``
    with its own delta tier, tombstones, and Lemma 4.1 rebuild policy);
    serving assembles the per-shard slices into stacked device arrays —
    maintained incrementally, O(touched shards) per mutation — and answers
    a query batch in one ``shard_map`` dispatch.  Queries must be finite
    (the exchange uses +inf as its padding sentinel, like
    ``make_lookup_fn``)."""
    mesh: Mesh
    axis: str
    splits: np.ndarray                  # (n_shards - 1,) host split values
    shards: list                        # per-shard core.updates.DynamicRMI
    eps: float
    n_leaves: int
    pool: object = None
    # Rebalance policy: a shard whose delta tier holds more than
    # ``rebalance_ratio`` of its live keys (insert-hot), whose dead fraction
    # crosses the same ratio (delete-hot), or whose live count exceeds
    # ``rebalance_skew`` x the mean, sheds/absorbs whole boundary runs
    # to/from an adjacent shard and the split between them moves.  None
    # disables rebalancing.
    rebalance_ratio: float | None = 0.5
    rebalance_skew: float = 2.0
    # Migration fallback rule: a migrated run rides the receiver's delta
    # tier while its size stays within this multiple of the receiver's
    # aggregate Lemma 4.1 insertion headroom (``bounds.insertion_headroom``).
    # Per-leaf budgets are rebuild *triggers*, not soundness limits — an
    # over-budget boundary leaf rebuilds locally during the routed merge —
    # so a small multiple keeps the refit work localized; a run several
    # times the headroom would refit most leaves anyway (or lands on a
    # trivial empty receiver, headroom 0), and falls back to one full
    # receiver rebuild.
    migrate_headroom_factor: float = 4.0
    rebalances: int = 0
    # Maintenance-cost accounting (the O(touched) contract, assertable):
    migrations_incremental: int = 0     # delta-riding migrations
    migrations_full: int = 0            # receiver headroom-overflow rebuilds
    restack_full: int = 0               # cold stack assemblies (capacity
                                        # class changes / first use)
    restack_rows: int = 0               # dirty slice rows rewritten in place
    capacity_shrinks: int = 0           # shards whose tiers stepped a
                                        # capacity class back down
    # Shards replaced by trivial empty shards during a damaged restore
    # (persist.restore_sharded on_corrupt="quarantine"): queries routed to
    # their ranges answer found=False until the operator re-feeds them.
    quarantined: list = field(default_factory=list)
    build_kwargs: dict = field(default_factory=dict)
    _stack: dict | None = None          # assembled stacked device state
    _dirty: set = field(default_factory=set)    # shard ids needing re-slice
    _counts: Array = None               # (n_shards, 4) i64 device counters:
                                        # base_n, base_dead, delta_live,
                                        # delta_dead
    # Skew triggers that migration cannot resolve (one duplicate run bigger
    # than the skew threshold: cuts snap to run boundaries, so there is
    # nothing to move) are muted at the failing live count — re-armed as
    # soon as the shard's live count changes.  Tier-ratio triggers never
    # need this: their in-place flush/rebuild fallback always clears them.
    _muted: Array = None                # (n_shards,) i64 live count, -1 off
    # Per-shard drift monitor mirror: (n_shards, 2) device table of
    # [KS score, drifted latch] rows (``drift.state_row``), refreshed with
    # the same O(touched) row scatters as the counter table so the
    # maintenance trigger (``maybe_swap``) costs one sync, never a host
    # scan over shard DriftStates.  All-zero when drift monitoring is off.
    _drift: Array = None                # (n_shards, 2) f64 [score, drifted]
    swaps_committed: int = 0            # pool hot-swaps across all shards
    # Host mirrors of per-shard shape/depth metadata, updated O(touched):
    # capacity classes decide when the global pad width must change, the
    # depth vector feeds the static search depth of the find trace.
    _bcaps: np.ndarray = None
    _dcaps: np.ndarray = None
    _iters_vec: np.ndarray = None

    @classmethod
    def build(cls, keys, mesh: Mesh, axis: str = "data",
              n_leaves: int = 256, pool=None, eps: float = 0.9,
              rebalance_ratio: float | None = 0.5,
              rebalance_skew: float = 2.0, **rmi_kwargs):
        """Partition sorted ``keys`` with :func:`shard_bounds` (run-snapped,
        empty shards allowed) and build one ``DynamicRMI`` per shard."""
        from .updates import DynamicRMI
        rmi_kwargs.setdefault("kind", "linear")
        if rmi_kwargs.get("root_kind", "linear") != "linear":
            raise ValueError(
                "ShardedDynamicIndex requires a monotone (linear) root: "
                "split routing and run snapping assume key order")
        kn = np.asarray(jnp.asarray(keys, jnp.float64))
        n_shards = mesh.shape[axis]
        bounds = shard_bounds(kn, n_shards)
        shards = [DynamicRMI.build(
            jnp.asarray(kn[bounds[s]:bounds[s + 1]]), pool=pool, eps=eps,
            n_leaves=n_leaves, **rmi_kwargs) for s in range(n_shards)]
        idx = cls(mesh=mesh, axis=axis,
                  splits=_splits_from_bounds(kn, bounds), shards=shards,
                  eps=eps, n_leaves=n_leaves, pool=pool,
                  rebalance_ratio=rebalance_ratio,
                  rebalance_skew=rebalance_skew, build_kwargs=rmi_kwargs)
        idx._init_maintenance()
        return idx

    def _init_maintenance(self) -> None:
        """Seed the device counter table, the skew mutes, and the host
        capacity/depth mirrors — the only full-shard scan outside a cold
        restack; everything after build updates these O(touched)."""
        S = self.n_shards
        self._bcaps = np.asarray(
            [d.index.keys.shape[0] for d in self.shards], np.int64)
        self._dcaps = np.asarray(
            [d.delta_keys.shape[0] for d in self.shards], np.int64)
        self._iters_vec = np.asarray(
            [d.index.search_iters for d in self.shards], np.int64)
        self._counts = jnp.asarray(
            [[d.base_n, d.base_dead_count, d.delta_live, d.delta_dead_count]
             for d in self.shards], jnp.int64)
        self._muted = jnp.full((S,), -1, jnp.int64)
        self._drift = jnp.stack(
            [drift_mod.state_row(d.drift) for d in self.shards])

    def _touch(self, ids) -> None:
        """Mark shards mutated: refresh their counter rows (one batched
        device row-scatter), host capacity/depth mirrors, and the dirty set
        the next restack consumes.  O(touched shards)."""
        ids = sorted({int(s) for s in ids})
        if not ids:
            return
        for s in ids:
            d = self.shards[s]
            # Eager capacity step-down (hysteresis inside shrink_capacity):
            # no shrinkable state survives a mutation, so a cold restack is
            # always a pure re-assembly of the logical state — the warm/cold
            # bit-exactness contract the restack-cache tests pin.
            if d.shrink_capacity():
                self.capacity_shrinks += 1
            self._bcaps[s] = d.index.keys.shape[0]
            self._dcaps[s] = d.delta_keys.shape[0]
            self._iters_vec[s] = d.index.search_iters
            self._dirty.add(s)
        vals = np.asarray(
            [[self.shards[s].base_n, self.shards[s].base_dead_count,
              self.shards[s].delta_live, self.shards[s].delta_dead_count]
             for s in ids], np.int64)
        self._counts = self._counts.at[jnp.asarray(ids)].set(
            jnp.asarray(vals))
        self._drift = self._drift.at[jnp.asarray(ids)].set(
            jnp.stack([drift_mod.state_row(self.shards[s].drift)
                       for s in ids]))

    # -- shape / bookkeeping ----------------------------------------------
    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def f32_exact(self) -> bool:
        """Every shard's tiers round-trip through f32 (kernel path
        precondition, same contract as ``DynamicRMI.find``)."""
        return all(d.f32_exact for d in self.shards)

    @property
    def total_live(self) -> int:
        return int(self.live_counts().sum())

    def live_counts(self) -> np.ndarray:
        return np.asarray([d.live_count for d in self.shards], np.int64)

    def live_keys(self) -> np.ndarray:
        """Sorted live keys across every shard (host; ``find``'s global
        rank indexes exactly this array)."""
        return np.concatenate([d.live_keys() for d in self.shards])

    # -- mutation ----------------------------------------------------------
    def _route(self, keys: np.ndarray) -> np.ndarray:
        return np.searchsorted(self.splits, keys, side="left")

    def insert_batch(self, keys) -> None:
        """Host pre-bucket by the split vector, one device merge per touched
        shard (each shard's ``DynamicRMI.insert_batch`` runs its own Lemma
        4.1 budget accounting and pool-reuse rebuilds).  Only the touched
        shards' cached slices invalidate."""
        keys = np.asarray(keys, np.float64).ravel()
        if keys.size == 0:
            return
        dest = self._route(keys)
        touched = np.unique(dest)
        for s in touched:
            self.shards[s].insert_batch(keys[dest == s])
        self._touch(touched)
        self._maybe_rebalance()

    def delete_batch(self, keys) -> None:
        """Routed tombstone deletes (per-shard semantics — duplicates within
        one batch collapse to a single removal, like ``DynamicRMI``)."""
        keys = np.asarray(keys, np.float64).ravel()
        if keys.size == 0:
            return
        dest = self._route(keys)
        touched = np.unique(dest)
        for s in touched:
            self.shards[s].delete_batch(keys[dest == s])
        self._touch(touched)
        self._maybe_rebalance()

    # -- rebalance ---------------------------------------------------------
    def _maybe_rebalance(self) -> None:
        """Load skew resolves by migration (boundary runs move between
        neighbours); tier triggers resolve *in place* — a delta-hot shard
        flushes its tier into the base (localized refits), a dead-hot shard
        rebuilds to purge base tombstones.  Migration deliberately does not
        answer tier triggers any more: the incremental donor/receiver paths
        leave tombstones and delta entries where they are, so only the
        in-place resolutions actually clear those ratios."""
        if self.rebalance_ratio is None or self.n_shards == 1:
            return
        hot_d, skew_d, delta_d, dead_d = _rebalance_trigger_jit(
            self._counts, self._muted, jnp.float64(self.rebalance_ratio),
            jnp.float64(self.rebalance_skew))
        hot = int(hot_d)
        if hot < 0:
            return
        if bool(skew_d):
            nb = [s for s in (hot - 1, hot + 1) if 0 <= s < self.n_shards]
            lv = {s: self.shards[s].live_count for s in [*nb, hot]}
            if lv[hot] >= min(lv[s] for s in nb):
                src, dst = hot, min(nb, key=lambda s: lv[s])     # shed
            else:
                src, dst = max(nb, key=lambda s: lv[s]), hot     # absorb
            if self._migrate(src, dst):
                self.rebalances += 1
                self._muted = self._muted.at[
                    jnp.asarray([src, dst])].set(-1)
                self._touch([src, dst])
                return
            if not (bool(delta_d) or bool(dead_d)):
                # Unmovable skew (one giant duplicate run): mute this
                # trigger at the current live count so every later batch
                # doesn't pay a fruitless donor live_keys() sync.
                self._muted = self._muted.at[hot].set(lv[hot])
                return
        if bool(dead_d):
            # Base tombstones only purge through a rebuild — in place, so
            # the trigger doesn't re-fire fruitlessly every batch.
            self._rebuild_shard(hot, self.shards[hot].live_keys())
        else:
            # Delta-hot: flush the tier into the base, refitting only the
            # leaves that hold delta entries.
            self.shards[hot].flush_delta()
        self.rebalances += 1
        self._touch([hot])

    def _migrate(self, src: int, dst: int) -> bool:
        """Move ~half the live-count excess of ``src`` to adjacent ``dst``
        as whole boundary runs and update the split between them —
        *incrementally*: the donor sheds its boundary region in place
        (``shed_suffix``/``shed_prefix`` — truncation or exact uniform
        intercept shift, no refit) and the migrated run rides the
        receiver's delta tier through the ordinary routed merge, at worst
        triggering localized Lemma 4.1 leaf rebuilds.  Only when the run
        overflows the receiver's aggregate Lemma 4.1 insertion headroom
        (the regime where most of its leaves would churn anyway — e.g. a
        trivial empty receiver) does the receiver fall back to one full
        rebuild; the donor never does.  Cuts snap to run boundaries so the
        strict-inequality routing invariant survives duplicate-heavy data;
        a cut that would move everything (one giant run) is skipped."""
        a = self.shards[src].live_keys()
        recv = self.shards[dst]
        m = int(a.size - recv.live_count) // 2
        if m <= 0 or a.size < 2:
            return False
        if dst == src + 1:
            c = int(np.searchsorted(a, a[a.size - m], side="left"))
            if c <= 0:
                return False
            moved, split_key = a[c:], float(a[c - 1])
            self.shards[src].shed_suffix(split_key)
            self.splits[src] = split_key
        else:
            c = int(np.searchsorted(a, a[m], side="left"))
            if c <= 0:
                return False
            moved, split_key = a[:c], float(a[c - 1])
            self.shards[src].shed_prefix(split_key)
            self.splits[dst] = split_key
        if moved.size <= self.migrate_headroom_factor * \
                recv.insertion_headroom:
            recv.insert_batch(moved)        # rides the delta tier
            self.migrations_incremental += 1
        else:
            live = recv.live_keys()
            merged = np.concatenate(
                [moved, live] if dst == src + 1 else [live, moved])
            self._rebuild_shard(dst, merged)
            self.migrations_full += 1
        return True

    def _rebuild_shard(self, s: int, keys: np.ndarray) -> None:
        from .updates import DynamicRMI
        self.shards[s] = DynamicRMI.build(
            jnp.asarray(keys), pool=self.pool, eps=self.eps,
            n_leaves=self.n_leaves, **self.build_kwargs)

    # -- drift maintenance -------------------------------------------------
    def drift_scores(self) -> np.ndarray:
        """(n_shards, 2) [KS score, drifted latch] snapshot of the device
        drift table (one sync; all-zero when monitoring is off)."""
        return np.asarray(self._drift)

    def maybe_swap(self) -> int:
        """Pool hot-swap pass over every drift-latched shard: read the
        device drift table once (the only sync), run each flagged shard's
        ``DynamicRMI.maybe_swap`` — Algorithm 1 pool selection over its
        over-budget leaves, committed per leaf only when the on-device
        Lemma 4.1 bound check holds — and push swapped shards through the
        dirty-row slice cache (``_touch``), so new leaf/bound rows rewrite
        in place on the next find instead of forcing a cold re-pad.
        Returns the number of leaves swapped across all shards."""
        if all(d.drift is None for d in self.shards):
            return 0
        latched = set(
            np.flatnonzero(self.drift_scores()[:, 1] > 0.0).tolist())
        total = 0
        for s, d in enumerate(self.shards):
            if d.drift is None:
                continue
            # Un-latched shards still take the maintenance pass: the
            # per-shard call is where deferred over-budget refits run
            # (swap-mode insert_batch keeps them off the insert path).
            if s not in latched and not (d.n_inserts > d.budget).any():
                continue
            rb0 = d.rebuilds
            n = d.maybe_swap()
            if n or d.rebuilds != rb0:
                total += n
                self._touch([s])
        self.swaps_committed += total
        return total

    # -- serving: the per-shard slice cache --------------------------------
    # Invalidation contract (module docstring): mutations mark shards dirty
    # via _touch; _stacked rewrites exactly the dirty rows of the stacked
    # arrays (one batched row-scatter per array), re-assembling from
    # scratch only when the *global* capacity class changed.
    @staticmethod
    def _pads(bcap: int, dcap: int):
        from ..kernels.lookup import pad_capacity as padk
        padz = lambda a, c: jnp.pad(a, (0, c - a.shape[0]))
        padp = lambda a, c: jnp.pad(a, (0, c + 1 - a.shape[0]), mode="edge")
        return padk, padz, padp

    def _slice_rows(self, s: int, bcap: int, dcap: int) -> dict:
        """One shard's slice set, padded to the global capacity classes —
        the unit of incremental restacking."""
        d = self.shards[s]
        padk, padz, padp = self._pads(bcap, dcap)
        return dict(
            route_n=jnp.float64(d.route_n),
            base=padk(d.index.keys, bcap),
            bdead=padz(d.base_dead, bcap),
            bpsum=padp(d.base_psum, bcap),
            dk=padk(d.delta_keys, dcap),
            ddead=padz(d.delta_dead, dcap),
            dpsum=padp(d.delta_psum, dcap),
            err_lo=d.index.err_lo,
            err_hi=d.index.err_hi)

    _ROW_KEYS = ("route_n", "base", "bdead", "bpsum", "dk", "ddead",
                 "dpsum", "err_lo", "err_hi")

    def _stacked(self) -> dict:
        """Assemble (or incrementally refresh) the stacked device state the
        ``shard_map`` dispatch consumes.  Dirty rows rewrite in place; a
        cold full assembly happens only on first use or when the global
        capacity class changes (a shard's tier outgrew — or a rebuilt shard
        retired — the current global max).  The packed kernel tables are a
        lazy sub-entry riding the same rows, so jnp-path consumers never
        pay for them."""
        bcap = int(self._bcaps.max())  # tracelint: ok[hot-sync](np mirror)
        dcap = int(self._dcaps.max())  # tracelint: ok[hot-sync](np mirror)
        st = self._stack
        if st is None or st["bcap"] != bcap or st["dcap"] != dcap:
            return self._restack_full(bcap, dcap)
        if self._dirty:
            self._restack_rows(st, sorted(self._dirty), bcap, dcap)
        return st

    def _restack_full(self, bcap: int, dcap: int) -> dict:
        """Cold assembly over every shard (first use / capacity-class
        change).  Also the capacity-class catch-all: shards that arrived
        oversized without passing through ``_touch`` (a just-restored or
        just-resharded index) step down here before the pad widths are
        fixed; for a maintained index the sweep is a no-op (``_touch``
        shrinks eagerly)."""
        for s, d in enumerate(self.shards):
            if d.shrink_capacity():
                self.capacity_shrinks += 1
                self._bcaps[s] = d.index.keys.shape[0]
                self._dcaps[s] = d.delta_keys.shape[0]
                self._iters_vec[s] = d.index.search_iters
        bcap = int(self._bcaps.max())  # tracelint: ok[hot-sync](np mirror)
        dcap = int(self._dcaps.max())  # tracelint: ok[hot-sync](np mirror)
        stack = lambda xs: jax.tree.map(lambda *a: jnp.stack(a), *xs)
        rows = [self._slice_rows(s, bcap, dcap)
                for s in range(self.n_shards)]
        self._stack = dict(
            bcap=bcap, dcap=dcap,
            splits=jnp.asarray(self.splits),
            offs=_offs_jit(self._counts),
            root=stack([d.index.root for d in self.shards]),
            leaves=stack([d.index.leaves for d in self.shards]),
            leaf_kind=self.shards[0].index.leaf_kind,
            iters=int(self._iters_vec.max()),   # tracelint: ok[hot-sync](np mirror)
            packed=None,
            **{k: jnp.stack([r[k] for r in rows]) for k in self._ROW_KEYS})
        self.restack_full += 1
        self._dirty.clear()
        return self._stack

    def _restack_rows(self, st: dict, ids: list, bcap: int,
                      dcap: int) -> None:
        """Rewrite the dirty shards' rows of the stacked arrays in place —
        one batched row-scatter per array, O(touched) slice work."""
        rows = [self._slice_rows(s, bcap, dcap) for s in ids]
        idx = jnp.asarray(ids)
        for k in self._ROW_KEYS:
            st[k] = scatter_rows_donated(
                st[k], idx, jnp.stack([r[k] for r in rows]))
        scat = lambda t, *r: scatter_rows_donated(t, idx, jnp.stack(r))
        st["root"] = jax.tree.map(
            scat, st["root"], *[self.shards[s].index.root for s in ids])
        st["leaves"] = jax.tree.map(
            scat, st["leaves"], *[self.shards[s].index.leaves for s in ids])
        if st["packed"] is not None:
            packs = [self._shard_pack(s) for s in ids]
            st["packed"] = tuple(
                scatter_rows_donated(t, idx,
                                     jnp.stack([p[i] for p in packs]))
                for i, t in enumerate(st["packed"]))
        st["offs"] = _offs_jit(self._counts)
        st["splits"] = jnp.asarray(self.splits)
        st["iters"] = int(self._iters_vec.max())  # tracelint: ok[hot-sync](np mirror)
        self.restack_rows += len(ids)
        self._dirty.clear()

    def _shard_pack(self, s: int) -> tuple:
        """One shard's packed kernel tables: mat/vec from the shard's
        cached ``RMIIndex.packed_tables``, the root block from the
        shard-lifetime ``DynamicRMI.packed_root`` cache (its frozen routing
        scale folded in, so the kernel traces once with static
        ``route_n = n_leaves``)."""
        d = self.shards[s]
        _, mat, vec = d.index.packed_tables()
        return d.packed_root(self.n_leaves), mat, vec

    def _packed_stack(self, st: dict) -> tuple:
        """Stacked per-shard kernel tables (lazy: first kernel-path find,
        then maintained row-wise by :meth:`_restack_rows`)."""
        if st["packed"] is None:
            packs = [self._shard_pack(s) for s in range(self.n_shards)]
            st["packed"] = tuple(jnp.stack([p[i] for p in packs])
                                 for i in range(3))
        return st["packed"]

    def find(self, queries, *, path: str = "auto",
             use_kernel: bool | None = None,
             interpret: bool | None = None) -> tuple[Array, Array]:
        """(found, global live rank) per query, one ``shard_map`` dispatch:
        queries route to their owning shard by the split vector (capacity-
        bucketed ``all_to_all``), the owner answers with its fused two-tier
        find — the ``dynamic_lookup_pallas`` kernel via ``ops.dynamic_find``
        or the jnp oracle — and the globalized answer returns through the
        inverse exchange.  Path-selection contract mirrors
        ``DynamicRMI.find`` (:func:`core.paths.resolve_path`)."""
        q = jnp.asarray(queries, jnp.float64)
        use_kernel = resolve_path(path, f32_exact=lambda: self.f32_exact,
                                  use_kernel=use_kernel,
                                  what="sharded key space")
        st = self._stacked()
        Q = q.shape[0]
        qp = -(-max(Q, 1) // self.n_shards) * self.n_shards
        if qp != Q:
            q = jnp.pad(q, (0, qp - Q))      # 0.0 pads; sliced off below
        fn = _sharded_dynamic_find_fn(
            self.mesh, self.axis, n_leaves=self.n_leaves,
            leaf_kind=st["leaf_kind"], iters=st["iters"],
            use_kernel=bool(use_kernel),
            interpret=interpret if interpret is None else bool(interpret))
        tables = self._packed_stack(st) if use_kernel else \
            (st["root"], st["leaves"], st["err_lo"], st["err_hi"])
        found, rank = fn(st["splits"], st["offs"], st["route_n"], st["base"],
                         st["bdead"], st["bpsum"], st["dk"], st["ddead"],
                         st["dpsum"], tables, q)
        return found[:Q], rank[:Q]

    def find_range(self, q_lo, q_hi, *, path: str = "auto",
                   use_kernel: bool | None = None,
                   interpret: bool | None = None) -> tuple[Array, Array]:
        """(rank_lo, rank_hi) global live ranks of the inclusive key ranges
        ``[q_lo[i], q_hi[i]]``, one ``shard_map`` dispatch: both endpoint
        arrays are concatenated and streamed through the same capacity-
        bucketed ``_routed_exchange`` as :meth:`find`, each endpoint's
        owning shard answers with BOTH its leftmost and rightmost local
        live rank (the fused range kernel or the jnp two-tier range tail),
        and the origin composes global ranks from the counter-table
        offsets — a range spanning shard seams needs no extra round trips
        because rank_lo rides the lo endpoint's shard and rank_hi the hi
        endpoint's.  A ``hi`` inside a duplicate run that *starts* a shard
        routes to that run's owning shard (runs never straddle seams —
        ``shard_bounds`` snaps to run starts), so its rightmost rank
        already counts every earlier shard through ``offs``.  rank_hi is
        clamped to rank_lo: degenerate ranges (lo > hi, tombstoned
        singletons, fully out-of-range) come back empty, never
        negative-width.  ``live_keys()[rank_lo:rank_hi]`` is the range's
        content.  Path-selection contract mirrors :meth:`find`."""
        ql = jnp.asarray(q_lo, jnp.float64)
        qh = jnp.asarray(q_hi, jnp.float64)
        if ql.shape != qh.shape:
            raise ValueError("find_range endpoint arrays must pair up")
        use_kernel = resolve_path(path, f32_exact=lambda: self.f32_exact,
                                  use_kernel=use_kernel,
                                  what="sharded key space")
        st = self._stacked()
        Q = ql.shape[0]
        qp = -(-max(Q, 1) // self.n_shards) * self.n_shards
        if qp != Q:
            ql = jnp.pad(ql, (0, qp - Q))    # 0.0 pads; sliced off below
            qh = jnp.pad(qh, (0, qp - Q))
        fn = _sharded_dynamic_range_fn(
            self.mesh, self.axis, n_leaves=self.n_leaves,
            leaf_kind=st["leaf_kind"], iters=st["iters"],
            use_kernel=bool(use_kernel),
            interpret=interpret if interpret is None else bool(interpret))
        tables = self._packed_stack(st) if use_kernel else \
            (st["root"], st["leaves"], st["err_lo"], st["err_hi"])
        rl, rr = fn(st["splits"], st["offs"], st["route_n"], st["base"],
                    st["bdead"], st["bpsum"], st["dk"], st["ddead"],
                    st["dpsum"], tables, jnp.concatenate([ql, qh]))
        rank_lo = rl[:qp][:Q]
        return rank_lo, jnp.maximum(rr[qp:][:Q], rank_lo)

    def gather_range(self, rank_lo, rank_hi) -> list[np.ndarray]:
        """Materialize :meth:`find_range` spans: per-range sorted live keys
        (host numpy — the global live array is assembled once and
        sliced)."""
        live = self.live_keys()
        lo = np.asarray(rank_lo).ravel()
        hi = np.asarray(rank_hi).ravel()
        return [live[int(a):int(b)] for a, b in zip(lo, hi, strict=True)]


@functools.lru_cache(maxsize=64)
def _sharded_dynamic_find_fn(mesh: Mesh, axis: str, *, n_leaves: int,
                             leaf_kind: str, iters: int, use_kernel: bool,
                             interpret: bool | None):
    """Jitted shard_map program for ``ShardedDynamicIndex.find``.  Cached on
    the static configuration so a mutate/find churn loop only re-traces when
    a capacity (array shape) actually crosses a power of two."""
    n_shards = mesh.shape[axis]

    if use_kernel:
        from ..kernels import ops as kernel_ops

        def local_find(tables, route_n, base, bdead, bpsum, dk, ddead,
                       dpsum, q):
            kroot, kmat, kvec = tables
            return kernel_ops.dynamic_find(
                q, kroot, kmat, kvec, base, bdead, bpsum, dk, ddead, dpsum,
                n_leaves=n_leaves, route_n=n_leaves, root_kind="linear",
                leaf_kind=leaf_kind, iters=iters, interpret=interpret)
    else:
        from . import updates as updates_mod

        def local_find(tables, route_n, base, bdead, bpsum, dk, ddead,
                       dpsum, q):
            root, leaves, elo, ehi = tables
            # f64 two-tier find (``updates._find_jit`` semantics) with the
            # frozen routing scale as a *traced* per-shard scalar — the
            # static-route_n jit cannot serve shards with different build
            # sizes under one shard_map trace.  Everything past this
            # routing line is the shared updates leaf_window /
            # two_tier_answer pair.
            b = jnp.clip((rmi_mod.models.linear_predict(root, q)
                          * n_leaves / route_n).astype(jnp.int32),
                         0, n_leaves - 1)
            lo, hi = updates_mod.leaf_window(leaves, elo, ehi, b, q,
                                             base.shape[0], leaf_kind)
            found, rank, _ = updates_mod.two_tier_answer(
                base, bpsum, dk, dpsum, q, lo, hi, iters)
            return found, rank

    def shard_fn(splits, offs, route_n, base, bdead, bpsum, dk, ddead,
                 dpsum, tables, q_local):
        def answer(rq, live):
            # +inf exchange pads mask to a member key (0.0 on an empty
            # shard's all-+inf block) so they never blow the sparse seam
            # budget; their answers are forced dead here.
            member = jnp.where(jnp.isfinite(base[0][0]), base[0][0], 0.0)
            qm = jnp.where(live, rq, member)
            found, rank = local_find(jax.tree.map(lambda a: a[0], tables),
                                     route_n[0], base[0], bdead[0],
                                     bpsum[0], dk[0], ddead[0], dpsum[0],
                                     qm)
            rank = jnp.where(live, rank.astype(jnp.int32) + offs[0], 0)
            return jnp.stack([rank, (found & live).astype(jnp.int32)],
                             axis=-1)

        rank, found = _routed_exchange(axis, n_shards, splits, q_local,
                                       q_local.shape[0], answer, (0, 0))
        return found.astype(bool), rank

    fn = jax.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(), P(axis), P(axis), P(axis), P(axis), P(axis), P(axis),
                  P(axis), P(axis), P(axis), P(axis)),
        out_specs=(P(axis), P(axis)), check_vma=True)
    return jax.jit(fn)


@functools.lru_cache(maxsize=64)
def _sharded_dynamic_range_fn(mesh: Mesh, axis: str, *, n_leaves: int,
                              leaf_kind: str, iters: int, use_kernel: bool,
                              interpret: bool | None):
    """Jitted shard_map program for ``ShardedDynamicIndex.find_range``.

    The local query row is the concatenation [lo endpoints | hi endpoints];
    every routed endpoint is answered with BOTH its leftmost and rightmost
    local live rank (payload columns), and the caller keeps the left
    column for lo slots and the right column for hi slots.  Answering both
    sides unconditionally keeps the exchange single-round and the kernel
    single-pass (``dynamic_range_pallas`` with q_lo == q_hi routes each
    endpoint once per key tile)."""
    n_shards = mesh.shape[axis]

    if use_kernel:
        from ..kernels import ops as kernel_ops

        def local_range(tables, route_n, base, bdead, bpsum, dk, ddead,
                        dpsum, q):
            kroot, kmat, kvec = tables
            return kernel_ops.range_lookup(
                q, q, kroot, kmat, kvec, base, bdead, bpsum, dk, ddead,
                dpsum, n_leaves=n_leaves, route_n=n_leaves,
                root_kind="linear", leaf_kind=leaf_kind, iters=iters,
                interpret=interpret)
    else:
        from . import updates as updates_mod

        def local_range(tables, route_n, base, bdead, bpsum, dk, ddead,
                        dpsum, q):
            root, leaves, elo, ehi = tables
            b = jnp.clip((rmi_mod.models.linear_predict(root, q)
                          * n_leaves / route_n).astype(jnp.int32),
                         0, n_leaves - 1)
            lo, hi = updates_mod.leaf_window(leaves, elo, ehi, b, q,
                                             base.shape[0], leaf_kind)
            return updates_mod.two_tier_range_answer(
                base, bpsum, dk, dpsum, q, q, lo, hi, iters)

    def shard_fn(splits, offs, route_n, base, bdead, bpsum, dk, ddead,
                 dpsum, tables, q_local):
        def answer(rq, live):
            # Same +inf exchange-pad masking as the point path: pads take a
            # member key so they never blow the sparse seam budget, and
            # their answers are zeroed here.
            member = jnp.where(jnp.isfinite(base[0][0]), base[0][0], 0.0)
            qm = jnp.where(live, rq, member)
            rlo, rhi = local_range(jax.tree.map(lambda a: a[0], tables),
                                   route_n[0], base[0], bdead[0], bpsum[0],
                                   dk[0], ddead[0], dpsum[0], qm)
            rlo = jnp.where(live, rlo.astype(jnp.int32) + offs[0], 0)
            rhi = jnp.where(live, rhi.astype(jnp.int32) + offs[0], 0)
            return jnp.stack([rlo, rhi], axis=-1)

        rlo, rhi = _routed_exchange(axis, n_shards, splits, q_local,
                                    q_local.shape[0], answer, (0, 0))
        return rlo, rhi

    fn = jax.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(), P(axis), P(axis), P(axis), P(axis), P(axis), P(axis),
                  P(axis), P(axis), P(axis), P(axis)),
        out_specs=(P(axis), P(axis)), check_vma=True)
    return jax.jit(fn)


# Trace-time counters for the serving retrace guard: the shard_map bodies
# below bump their key when (re)traced, so tests can pin "zero hot-path
# retraces across varying live batch sizes after warmup" exactly the way
# tests/test_updates.py pins the no-host-loop contract.
TRACE_COUNTS = {"tenant_find": 0, "tenant_range": 0}


@functools.lru_cache(maxsize=32)
def _tenant_stacked_find_fn(mesh: Mesh, axis: str, *, n_tenants: int,
                            n_leaves: int, leaf_kind: str, iters: int,
                            use_kernel: bool, interpret: bool | None):
    """Jitted shard_map program answering N independent tenants in one
    stacked dispatch (``serve.frontend.TenantPack``).

    Every operand carries a leading tenant axis over the per-shard stacked
    state (``P(None, axis)`` — tenant-replicated, shard-partitioned), and
    the body answers each tenant's query row through the same
    capacity-bucketed exchange + fused two-tier find as
    ``_sharded_dynamic_find_fn``.  Tenants of different build sizes share
    the one trace because their size differences are *data*, not shape:

      * tiers pad to the cross-tenant max capacity classes (+inf keys /
        zero tombstones / edge-extended prefix sums — the same trick the
        per-shard stack plays),
      * leaf tables pad to the widest tenant's ``n_leaves`` with the last
        live leaf replicated (``lookup.pad_packed_leaves``), so a routing
        overshoot lands on the window the tenant's own clip would pick,
      * routing rescales ride per-tenant *data*: the traced ``route_n``
        scalar on the jnp path, the ``pack_root(route_scale=...)`` fold on
        the kernel path — traced once with static
        ``n_leaves = route_n = max_t L_t``.

    Cached on the static configuration, so after the serve front-end's
    warmup the hot path never retraces: live batch sizes only vary the
    *contents* of the pow2-padded query rows.
    """
    n_shards = mesh.shape[axis]

    if use_kernel:
        from ..kernels import ops as kernel_ops

        def local_find(tables, route_n, base, bdead, bpsum, dk, ddead,
                       dpsum, q):
            kroot, kmat, kvec = tables
            return kernel_ops.dynamic_find(
                q, kroot, kmat, kvec, base, bdead, bpsum, dk, ddead, dpsum,
                n_leaves=n_leaves, route_n=n_leaves, root_kind="linear",
                leaf_kind=leaf_kind, iters=iters, interpret=interpret)
    else:
        from . import updates as updates_mod

        def local_find(tables, route_n, base, bdead, bpsum, dk, ddead,
                       dpsum, q):
            root, leaves, elo, ehi = tables
            b = jnp.clip((rmi_mod.models.linear_predict(root, q)
                          * n_leaves / route_n).astype(jnp.int32),
                         0, n_leaves - 1)
            lo, hi = updates_mod.leaf_window(leaves, elo, ehi, b, q,
                                             base.shape[0], leaf_kind)
            found, rank, _ = updates_mod.two_tier_answer(
                base, bpsum, dk, dpsum, q, lo, hi, iters)
            return found, rank

    def shard_fn(splits, offs, route_n, base, bdead, bpsum, dk, ddead,
                 dpsum, tables, q):
        TRACE_COUNTS["tenant_find"] += 1
        founds, ranks = [], []
        for t in range(n_tenants):
            def answer(rq, live, t=t):
                member = jnp.where(jnp.isfinite(base[t, 0, 0]),
                                   base[t, 0, 0], 0.0)
                qm = jnp.where(live, rq, member)
                found, rank = local_find(
                    jax.tree.map(lambda a: a[t][0], tables),
                    route_n[t, 0], base[t, 0], bdead[t, 0], bpsum[t, 0],
                    dk[t, 0], ddead[t, 0], dpsum[t, 0], qm)
                rank = jnp.where(live, rank.astype(jnp.int32) + offs[t, 0],
                                 0)
                return jnp.stack([rank, (found & live).astype(jnp.int32)],
                                 axis=-1)

            rank, found = _routed_exchange(axis, n_shards, splits[t], q[t],
                                           q[t].shape[0], answer, (0, 0))
            founds.append(found.astype(bool))
            ranks.append(rank)
        return jnp.stack(founds), jnp.stack(ranks)

    fn = jax.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(), P(None, axis), P(None, axis), P(None, axis),
                  P(None, axis), P(None, axis), P(None, axis),
                  P(None, axis), P(None, axis), P(None, axis),
                  P(None, axis)),
        out_specs=(P(None, axis), P(None, axis)), check_vma=True)
    return jax.jit(fn)


@functools.lru_cache(maxsize=32)
def _tenant_stacked_range_fn(mesh: Mesh, axis: str, *, n_tenants: int,
                             n_leaves: int, leaf_kind: str, iters: int,
                             use_kernel: bool, interpret: bool | None):
    """Range-query sibling of :func:`_tenant_stacked_find_fn` for the serve
    front-end's ``"range"`` request kind: each tenant's query row is the
    concatenation [lo endpoints | hi endpoints] (the
    :func:`_sharded_dynamic_range_fn` layout), answered per shard with
    both boundary ranks and returned as (rank_lo_row, rank_hi_row)
    matrices.  Same padding/rescale tricks, same zero-retrace contract
    (``TRACE_COUNTS["tenant_range"]``)."""
    n_shards = mesh.shape[axis]

    if use_kernel:
        from ..kernels import ops as kernel_ops

        def local_range(tables, route_n, base, bdead, bpsum, dk, ddead,
                        dpsum, q):
            kroot, kmat, kvec = tables
            return kernel_ops.range_lookup(
                q, q, kroot, kmat, kvec, base, bdead, bpsum, dk, ddead,
                dpsum, n_leaves=n_leaves, route_n=n_leaves,
                root_kind="linear", leaf_kind=leaf_kind, iters=iters,
                interpret=interpret)
    else:
        from . import updates as updates_mod

        def local_range(tables, route_n, base, bdead, bpsum, dk, ddead,
                        dpsum, q):
            root, leaves, elo, ehi = tables
            b = jnp.clip((rmi_mod.models.linear_predict(root, q)
                          * n_leaves / route_n).astype(jnp.int32),
                         0, n_leaves - 1)
            lo, hi = updates_mod.leaf_window(leaves, elo, ehi, b, q,
                                             base.shape[0], leaf_kind)
            return updates_mod.two_tier_range_answer(
                base, bpsum, dk, dpsum, q, q, lo, hi, iters)

    def shard_fn(splits, offs, route_n, base, bdead, bpsum, dk, ddead,
                 dpsum, tables, q):
        TRACE_COUNTS["tenant_range"] += 1
        rlos, rhis = [], []
        for t in range(n_tenants):
            def answer(rq, live, t=t):
                member = jnp.where(jnp.isfinite(base[t, 0, 0]),
                                   base[t, 0, 0], 0.0)
                qm = jnp.where(live, rq, member)
                rlo, rhi = local_range(
                    jax.tree.map(lambda a: a[t][0], tables),
                    route_n[t, 0], base[t, 0], bdead[t, 0], bpsum[t, 0],
                    dk[t, 0], ddead[t, 0], dpsum[t, 0], qm)
                rlo = jnp.where(live, rlo.astype(jnp.int32) + offs[t, 0], 0)
                rhi = jnp.where(live, rhi.astype(jnp.int32) + offs[t, 0], 0)
                return jnp.stack([rlo, rhi], axis=-1)

            rlo, rhi = _routed_exchange(axis, n_shards, splits[t], q[t],
                                        q[t].shape[0], answer, (0, 0))
            rlos.append(rlo)
            rhis.append(rhi)
        return jnp.stack(rlos), jnp.stack(rhis)

    fn = jax.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(), P(None, axis), P(None, axis), P(None, axis),
                  P(None, axis), P(None, axis), P(None, axis),
                  P(None, axis), P(None, axis), P(None, axis),
                  P(None, axis)),
        out_specs=(P(None, axis), P(None, axis)), check_vma=True)
    return jax.jit(fn)
